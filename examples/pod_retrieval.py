"""Pod-scale sharded retrieval demo: the corpus lives row-sharded over
every device of a mesh; one query runs the two-level top-k TOURNAMENT
(local stage-1 -> O(k * devices) proposal gather -> owner-only stage-2 ->
replicated rerank). Forces 8 host devices to demonstrate (must be set
before jax imports, hence the top of this file).

    PYTHONPATH=src python examples/pod_retrieval.py
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import (BitPlanarDB, RetrievalConfig, RetrievalEngine,  # noqa: E402
                        build_database, quantize_int8)
from repro.core.index import ShardedIndex  # noqa: E402
from repro.data import retrieval_corpus  # noqa: E402
from repro.launch.mesh import make_test_mesh  # noqa: E402


def main():
    mesh = make_test_mesh(data=4, model=2)
    print(f"mesh: {dict(mesh.shape)} = {mesh.devices.size} devices")

    docs, queries, gold = retrieval_corpus(num_docs=20000, dim=512,
                                           num_queries=8, noise=0.12, seed=1)
    t0 = time.time()
    index = ShardedIndex.build(jnp.asarray(docs), mesh)
    print(f"sharded {index.n_global} docs over {mesh.devices.size} shards "
          f"in {time.time()-t0:.1f}s "
          f"({index.db.msb_plane.sharding.spec} rows/shard)")

    cfg = RetrievalConfig(k=3, metric="cosine")
    qc, _ = quantize_int8(jnp.asarray(queries), per_vector=True)

    # single-host reference: the batch-native RetrievalEngine (one launch,
    # doc plane streamed once for the whole batch) — the same engine core
    # each shard runs locally inside the tournament below
    engine = RetrievalEngine(cfg)
    local_db = BitPlanarDB.from_quantized(build_database(jnp.asarray(docs)))
    local = engine.retrieve(qc, local_db)
    plan = engine.plan_for(local_db, batch=qc.shape[0])
    print("single-host batched engine: P@1 "
          f"{int(np.sum(np.asarray(local.indices)[:, 0] == gold))}/8, "
          f"stage-1 {plan.stage1_bytes:,} B once per batch "
          f"(per-query loop: {plan.stage1_bytes_vmapped:,} B)")

    retrieve = index.retrieve_fn(cfg)
    res = retrieve(qc)                       # batched tournament
    hits = int(np.sum(np.asarray(res.indices)[:, 0] == gold))
    print(f"tournament P@1: {hits}/8 "
          "(cross-shard traffic per query: "
          f"{50 * mesh.devices.size * 8} B of proposals — independent of "
          "corpus size)")
    for i in range(3):
        print(f"  q{i}: top-3 {np.asarray(res.indices)[i].tolist()} "
              f"(gold {gold[i]})")


if __name__ == "__main__":
    main()
