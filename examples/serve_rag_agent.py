"""End-to-end driver (the paper's kind: SERVING): a RAG-enabled agent
answering batched requests.

Pipeline (paper Fig. 1): personal-record corpus -> MiniLM-style embedder
-> INT8 nibble-planar database -> per request batch: encode query ->
TWO-STAGE HIERARCHICAL RETRIEVAL -> augmented prompt -> batched
prefill+decode on the generator LM. Logs the paper's per-query retrieval
energy ledger alongside the generations.

    PYTHONPATH=src python examples/serve_rag_agent.py [--requests 8]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import RetrievalConfig
from repro.models import embedder, get_model
from repro.serve import RAGPipeline


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--num-docs", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args()
    rng = np.random.default_rng(0)

    # generator: reduced qwen2-family LM served greedily
    gcfg = get_config("qwen2-0.5b", smoke=True)
    gen_api = get_model(gcfg)
    gen_params = gen_api.init(jax.random.PRNGKey(0))

    # embedder: MiniLM-style sentence encoder (the paper's)
    ecfg = embedder.MINILM_CFG.with_(num_layers=2, d_model=64, num_heads=4,
                                     num_kv_heads=4, d_ff=128,
                                     vocab_size=gcfg.vocab_size,
                                     pooled_dim=64)
    eparams = embedder.init_params(ecfg, jax.random.PRNGKey(1))

    # offline phase: the "personal medical record" corpus (synthetic tokens)
    doc_tokens = jnp.asarray(
        rng.integers(0, gcfg.vocab_size, (args.num_docs, 12)).astype(np.int32))
    t0 = time.time()
    pipe = RAGPipeline.build(ecfg, eparams, gen_api, gen_params, doc_tokens,
                             RetrievalConfig(k=2, metric="cosine"))
    print("[offline] built INT8 nibble-planar index over "
          f"{args.num_docs} docs in {time.time()-t0:.1f}s")

    # online phase: batched requests (queries = noisy copies of docs so the
    # retrieval ground truth is visible in the log)
    gold = rng.integers(0, args.num_docs, args.requests)
    queries = doc_tokens[jnp.asarray(gold)]
    t0 = time.time()
    out, ids, ledger = pipe.answer(queries, max_new=args.max_new)
    dt = time.time() - t0
    hits = int(np.sum(np.asarray(ids)[:, 0] == gold))
    print(f"[online] {args.requests} requests in {dt:.1f}s "
          f"({dt/args.requests:.2f}s/req incl. retrieval + "
          f"{args.max_new}-token decode)")
    print(f"  retrieval top-1 hit rate: {hits}/{args.requests}")
    print("  retrieval energy (paper cost model): "
          f"{ledger.total_uj:.2f} uJ/query, "
          f"DRAM share {100*ledger.proportions()['DRAM']:.1f}%")
    for i in range(min(3, args.requests)):
        print(f"  req{i}: retrieved docs {np.asarray(ids)[i].tolist()} "
              f"(gold {gold[i]}) -> tokens {np.asarray(out)[i][:8].tolist()}…")


if __name__ == "__main__":
    main()
