"""Multi-user wearable agent demo: per-user corpora, one shared arena.

Three users each carry a personal medical-record corpus. Records stream
in ONLINE (no offline index build, no rebuild on update), a mixed batch
of all three users' questions runs as one segment-masked retrieval
launch, and each user's answer is grounded ONLY in their own records —
user A can never retrieve user B's data even though both live in the
same nibble-planar arena.

    PYTHONPATH=src python examples/multi_user_agent.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import RetrievalConfig
from repro.models import embedder, get_model
from repro.serve import MultiTenantRAGPipeline

USERS = ["alice", "bob", "carol"]


def main():
    rng = np.random.default_rng(0)
    gcfg = get_config("qwen2-0.5b", smoke=True)
    gen_api = get_model(gcfg)
    gen_params = gen_api.init(jax.random.PRNGKey(0))
    ecfg = embedder.MINILM_CFG.with_(num_layers=2, d_model=64, num_heads=4,
                                     num_kv_heads=4, d_ff=128,
                                     vocab_size=gcfg.vocab_size,
                                     pooled_dim=64)
    eparams = embedder.init_params(ecfg, jax.random.PRNGKey(1))

    pipe = MultiTenantRAGPipeline.create(
        ecfg, eparams, gen_api, gen_params, capacity=256, doc_len=12,
        retrieval_cfg=RetrievalConfig(k=2, metric="cosine"))

    # --- online ingestion: each user's personal records stream in --------
    records = {}
    for uid, name in enumerate(USERS):
        toks = rng.integers(0, gcfg.vocab_size, (24, 12)).astype(np.int32)
        slots = pipe.ingest(uid, toks)
        records[uid] = (slots, toks)
        print(f"[{name:5}] ingested {len(slots)} records -> slots "
              f"[{slots[0]}..{slots[-1]}] (no rebuild)")

    # --- one mixed batch: every user asks about their OWN record #7 ------
    tids = np.arange(len(USERS), dtype=np.int32)
    queries = jnp.asarray(np.stack([records[u][1][7] for u in tids]))
    out, ids, ledger = pipe.answer(tids, queries, max_new=8)
    owner = np.asarray(pipe.index.arena.owner)
    for uid, name in enumerate(USERS):
        got = ids[uid][ids[uid] >= 0]
        owners = set(int(owner[s]) for s in got)
        print(f"[{name:5}] retrieved slots {[int(s) for s in got]} "
              f"(owners {owners or '-'}; expected slot "
              f"{records[uid][0][7]}) -> {out.shape[1]} answer tokens")
        assert owners <= {uid}, "cross-user leak!"
        assert int(got[0]) == int(records[uid][0][7])
    print(f"[energy] {ledger.total_uj:.2f} uJ/query "
          f"(DRAM {100 * ledger.proportions()['DRAM']:.1f}%)")

    # --- a record arrives AFTER the index exists: visible immediately ----
    new_rec = rng.integers(0, gcfg.vocab_size, (1, 12)).astype(np.int32)
    (new_slot,) = pipe.ingest(0, new_rec)
    res, _ = pipe.retrieve(np.asarray([0], np.int32), jnp.asarray(new_rec))
    assert int(np.asarray(res.indices)[0, 0]) == int(new_slot)
    print(f"[alice] new record -> slot {new_slot}, retrievable immediately "
          f"(rebuilds: {pipe.index.arena.stats.rebuilds})")

    # --- delete = tombstone; compaction reclaims and preserves results ---
    pipe.delete(0, [int(new_slot)])
    res, _ = pipe.retrieve(np.asarray([0], np.int32), jnp.asarray(new_rec))
    assert int(new_slot) not in np.asarray(res.indices)
    pipe.compact()
    res, _ = pipe.retrieve(
        np.asarray([0], np.int32),
        jnp.asarray(records[0][1][7][None]))
    top = int(np.asarray(res.indices)[0, 0])
    assert np.array_equal(pipe.doc_tokens[top], records[0][1][7])
    print("[alice] deleted record tombstoned; after compaction "
          f"({pipe.index.num_live} live rows) results still correct")

    # --- the serving runtime: deadline-batched admission with futures ----
    # Requests trickle in; a full batch launches immediately, a partial
    # one launches when its oldest deadline arrives — the agents never
    # wait longer than the configured slack for a slow batch to fill.
    from repro.core import quantize_int8
    from repro.serve import RuntimeConfig, ServingRuntime

    rt = ServingRuntime(pipe.index,
                        RuntimeConfig(max_batch=len(USERS), max_wait=0.010))
    handles = []
    for uid, name in enumerate(USERS):
        q_emb = pipe._embed(jnp.asarray(records[uid][1][3][None]))
        q_codes, _ = quantize_int8(q_emb, per_vector=True)
        handles.append(rt.submit(uid, np.asarray(q_codes[0]), now=0.0))
    # The batch filled, so the launch DISPATCHED immediately — but with
    # async_depth=2 (the default) it may still be IN FLIGHT on the
    # device: result(wait=False) returns None while unresolved, and
    # result() blocks until the answer is ready.
    assert rt.launches == 1
    for uid, (name, h) in enumerate(zip(USERS, handles)):
        got = np.asarray(h.result().indices)     # blocks until resolved
        assert int(got[0]) == int(pipe.index.table.slots(uid)[3])
    assert all(h.done() for h in handles)        # resolved, not just sent
    print(f"[serve ] {len(handles)} users answered in {rt.launches} "
          f"deadline-batched launch(es); a lone request launches after "
          f"{1e3 * rt.cfg.max_wait:.0f} ms instead of waiting forever")
    lone = rt.submit(0, np.asarray(q_codes[0]), now=0.0)
    assert rt.poll(now=0.005) == []          # young partial batch waits
    assert rt.poll(now=0.010) == [lone]      # deadline forces the launch


if __name__ == "__main__":
    main()
