"""Training driver: a ~100M-parameter dense LM on the synthetic learnable
stream, with checkpointing + the elastic restart harness.

CPU note: a full few-hundred-step run of the 100M model takes hours on
this 1-core container; default is a small smoke run — pass --steps 300
--full for the real thing on actual hardware.

    PYTHONPATH=src python examples/train_100m.py [--steps 20] [--full]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.data import LMTaskConfig, lm_batches
from repro.models import get_model
from repro.models.common import ModelConfig, param_count
from repro.runtime import ElasticTrainer
from repro.train import adamw, make_train_step

# ~100M params: 12L x 768 with a 32k vocab
CFG_100M = ModelConfig(name="lm-100m", family="dense", num_layers=12,
                       d_model=768, num_heads=12, num_kv_heads=4,
                       d_ff=2048, vocab_size=32768, attn_chunk=512)

CFG_SMOKE = CFG_100M.with_(num_layers=4, d_model=256, d_ff=512,
                           num_heads=8, num_kv_heads=4, vocab_size=2048)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--full", action="store_true",
                    help="use the real 100M config (slow on CPU)")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt_100m")
    args = ap.parse_args()

    cfg = CFG_100M if args.full else CFG_SMOKE
    api = get_model(cfg)
    opt = adamw(lr=3e-4, weight_decay=0.01)
    n = param_count(api.init(jax.random.PRNGKey(0)))
    print(f"model: {cfg.name} ({n/1e6:.1f}M params, "
          f"{'full' if args.full else 'smoke'})")

    def make_state(mesh):
        params = api.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        raw = jax.jit(make_train_step(api.loss_fn, opt))

        def step_fn(p, o, b, mesh):
            return raw(p, o, b)
        return params, opt_state, step_fn, None

    gen = lm_batches(LMTaskConfig(vocab_size=cfg.vocab_size,
                                  seq_len=args.seq, batch_size=args.batch))
    batches = ({k: jnp.asarray(v) for k, v in b.items()} for b in gen)

    trainer = ElasticTrainer(make_state=make_state,
                             ckpt=CheckpointManager(args.ckpt_dir, keep=2),
                             save_every=max(5, args.steps // 4))
    t0 = time.time()
    out = trainer.run(batches, num_steps=args.steps)
    dt = time.time() - t0
    losses = out["losses"]
    print(f"{args.steps} steps in {dt:.1f}s ({dt/args.steps:.2f}s/step)")
    print(f"loss: {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"(mean first 5: {sum(losses[:5])/5:.3f}, "
          f"last 5: {sum(losses[-5:])/5:.3f})")
    print(f"checkpoints under {args.ckpt_dir} (atomic, latest-2)")


if __name__ == "__main__":
    main()
