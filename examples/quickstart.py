"""Quickstart: the paper's hierarchical retrieval, batch-native, in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import (BitPlanarDB, RetrievalConfig, RetrievalEngine,
                        build_database, clustering, energy, exact_retrieve,
                        int4_retrieve, quantize_int8)
from repro.core.retrieval import cluster_pruned_retrieve
from repro.data import retrieval_corpus


def main():
    # --- offline: embed + INT8-quantize + nibble-planar pack the corpus ---
    docs, queries, gold = retrieval_corpus(num_docs=5000, dim=512,
                                           num_queries=16, noise=0.15,
                                           cluster_size=16,
                                           cluster_spread=0.15, seed=0)
    qdb = build_database(jnp.asarray(docs))           # INT8 codes + norms
    db = BitPlanarDB.from_quantized(qdb)              # MSB/LSB nibble planes
    print(f"corpus: {db.num_docs} docs x {db.dim} dims "
          f"({energy.db_bytes(db.num_docs)/2**20:.1f} MB INT8)")

    # --- online: ONE batched two-stage launch for the whole query batch ---
    # (the batch-native engine: stage 1 is a true (N, D/2) x (D/2, B)
    # matmul, so the doc plane streams from HBM once per BATCH)
    cfg = RetrievalConfig(k=5, metric="cosine")
    engine = RetrievalEngine(cfg)
    q_codes, _ = quantize_int8(jnp.asarray(queries), per_vector=True)
    batched = engine.retrieve(q_codes, db)            # (B, k) indices
    plan = engine.plan_for(db, batch=q_codes.shape[0])
    print(f"batched launch: stage-1 streams {plan.stage1_bytes:,} bytes "
          "once per batch (a per-query loop would stream "
          f"{plan.stage1_bytes_vmapped:,})")

    top1 = np.asarray(batched.indices)[:, 0]
    n = queries.shape[0]
    hits = {"hierarchical": int(np.sum(top1 == gold)), "int8": 0, "int4": 0}

    # single-query baselines (each lane of the batch == one of these calls)
    for i in range(n):
        q = q_codes[i]
        hits["int8"] += int(
            np.asarray(exact_retrieve(q, qdb, cfg).indices)[0] == gold[i])
        hits["int4"] += int(
            np.asarray(int4_retrieve(q, db, cfg).indices)[0] == gold[i])
    print(f"P@1  hierarchical={hits['hierarchical']/n:.2f}  "
          f"int8={hits['int8']/n:.2f}  int4={hits['int4']/n:.2f}")

    # --- beyond the paper: the cluster-pruned cascade ---
    # k-means the INT8 codes, group rows by cluster, and retrieve through
    # the 3-stage cascade: centroid prune -> gathered INT4 scan -> exact
    # INT8 rescore. Stage 1 now touches ~nprobe/K of the corpus.
    cents, labels = clustering.kmeans_int8(np.asarray(qdb.values), 64,
                                           iters=4, seed=0)
    order = clustering.cluster_grouped_order(labels)
    cdb = BitPlanarDB.from_quantized(build_database(jnp.asarray(docs[order])))
    labels = labels[order]
    codebook = clustering.ClusterCodebook.from_codes(cents)
    table = clustering.block_table(labels, 64, block_rows=64)
    pruned = cluster_pruned_retrieve(q_codes, cdb, codebook, table, labels,
                                     cfg, nprobe=8, block_rows=64)
    inv = np.empty_like(order)            # old row id -> grouped row id
    inv[order] = np.arange(len(order))
    hit = int(np.sum(np.asarray(pruned.indices)[:, 0] == inv[gold]))
    print(f"cascade (K=64, nprobe=8): P@1={hit/n:.2f}, stage-1 scans "
          f"{8 * table.shape[1] * 64}/{db.num_docs} rows per query")

    # --- the paper's energy ledger for this corpus ---
    for name, fn in (("hierarchical", energy.cost_hierarchical),
                     ("pure INT8", energy.cost_int8),
                     ("pure INT4", energy.cost_int4)):
        cb = fn(db.num_docs)
        print(f"{name:>13}: {cb.total_uj:8.2f} uJ/query  "
              f"(DRAM {100*cb.proportions()['DRAM']:.1f}%)")


if __name__ == "__main__":
    main()
