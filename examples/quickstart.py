"""Quickstart: the paper's hierarchical retrieval in 40 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax.numpy as jnp
import numpy as np

from repro.core import (BitPlanarDB, RetrievalConfig, build_database,
                        energy, exact_retrieve, int4_retrieve, quantize_int8,
                        two_stage_retrieve)
from repro.data import retrieval_corpus


def main():
    # --- offline: embed + INT8-quantize + nibble-planar pack the corpus ---
    docs, queries, gold = retrieval_corpus(num_docs=5000, dim=512,
                                           num_queries=16, noise=0.15,
                                           cluster_size=16,
                                           cluster_spread=0.15, seed=0)
    qdb = build_database(jnp.asarray(docs))           # INT8 codes + norms
    db = BitPlanarDB.from_quantized(qdb)              # MSB/LSB nibble planes
    print(f"corpus: {db.num_docs} docs x {db.dim} dims "
          f"({energy.db_bytes(db.num_docs)/2**20:.1f} MB INT8)")

    # --- online: two-stage hierarchical retrieval ---
    cfg = RetrievalConfig(k=5, metric="cosine")
    hits = {"hierarchical": 0, "int8": 0, "int4": 0}
    for i in range(queries.shape[0]):
        q, _ = quantize_int8(jnp.asarray(queries[i]))
        hits["hierarchical"] += int(
            np.asarray(two_stage_retrieve(q, db, cfg).indices)[0] == gold[i])
        hits["int8"] += int(
            np.asarray(exact_retrieve(q, qdb, cfg).indices)[0] == gold[i])
        hits["int4"] += int(
            np.asarray(int4_retrieve(q, db, cfg).indices)[0] == gold[i])
    n = queries.shape[0]
    print(f"P@1  hierarchical={hits['hierarchical']/n:.2f}  "
          f"int8={hits['int8']/n:.2f}  int4={hits['int4']/n:.2f}")

    # --- the paper's energy ledger for this corpus ---
    for name, fn in (("hierarchical", energy.cost_hierarchical),
                     ("pure INT8", energy.cost_int8),
                     ("pure INT4", energy.cost_int4)):
        cb = fn(db.num_docs)
        print(f"{name:>13}: {cb.total_uj:8.2f} uJ/query  "
              f"(DRAM {100*cb.proportions()['DRAM']:.1f}%)")


if __name__ == "__main__":
    main()
