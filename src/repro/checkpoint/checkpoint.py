"""Fault-tolerant sharded checkpointing (no orbax in this container).

Design:
  * Each pytree leaf is saved as one .npy file under a step directory,
    with a JSON manifest (treedef paths, shapes, dtypes).
  * ATOMIC PUBLISH: writes go to `step_<n>.tmp/`, fsync'd, then a single
    os.rename to `step_<n>/` — a crash mid-save can never leave a corrupt
    "latest" checkpoint (restore only ever sees fully renamed dirs).
  * ASYNC: `CheckpointManager.save_async` snapshots device arrays to host
    np arrays (cheap, blocking only on device transfer), then writes on a
    background thread — the train loop overlaps the I/O.
  * RETENTION: keeps the newest `keep` checkpoints, GC'ing older ones.
  * RESHARD-ON-RESTORE: restore() takes an optional sharding tree and
    device_puts each leaf to its (possibly different) target sharding —
    this is what elastic re-meshing uses after a node failure.

In a real multi-host pod each host writes only the shards it owns
(`process_index` prefix); on this single-process container that reduces
to whole arrays, but the layout keeps the multi-host path explicit.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_MANIFEST = "manifest.json"


def _flatten(tree: Any):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = []
    for path, _ in leaves:
        parts = []
        for e in path:
            if hasattr(e, "key"):
                parts.append(str(e.key))
            elif hasattr(e, "idx"):
                parts.append(str(e.idx))
            else:
                parts.append(str(e))
        names.append("__".join(parts) or "leaf")
    return [l for _, l in leaves], names, treedef


def save_checkpoint(directory: str, step: int, tree: Any) -> str:
    """Synchronous atomic save. Returns the published path."""
    leaves, names, _ = _flatten(tree)
    host = [np.asarray(l) for l in leaves]
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {"step": step, "leaves": []}
    for i, (name, arr) in enumerate(zip(names, host)):
        fn = f"{i:05d}.npy"
        np.save(os.path.join(tmp, fn), arr)
        manifest["leaves"].append({"name": name, "file": fn,
                                   "shape": list(arr.shape),
                                   "dtype": str(arr.dtype)})
    with open(os.path.join(tmp, _MANIFEST), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)                    # atomic publish
    return final


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and not d.endswith(".tmp") and \
                os.path.exists(os.path.join(directory, d, _MANIFEST)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore_checkpoint(directory: str, like: Any, step: int | None = None,
                       shardings: Any | None = None) -> tuple[Any, int]:
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). With `shardings`, each leaf is device_put to its
    target sharding (reshard-on-restore for elastic scaling)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, _MANIFEST)) as f:
        manifest = json.load(f)
    _, _, treedef = _flatten(like)
    arrays = [np.load(os.path.join(path, leaf["file"]))
              for leaf in manifest["leaves"]]
    tree = jax.tree_util.tree_unflatten(treedef, arrays)
    if shardings is not None:
        tree = jax.tree.map(lambda a, s: jax.device_put(a, s), tree,
                            shardings)
    return tree, step


class CheckpointManager:
    """Async save + retention. One in-flight save at a time (later saves
    wait — checkpointing slower than the save interval is a config bug we
    surface rather than hide)."""

    def __init__(self, directory: str, keep: int = 3):
        self.directory = directory
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    def save_async(self, step: int, tree: Any) -> None:
        self.wait()
        host = jax.tree.map(np.asarray, tree)   # snapshot before mutation

        def work():
            save_checkpoint(self.directory, step, host)
            self._gc()

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = sorted(
            int(d.split("_")[1]) for d in os.listdir(self.directory)
            if d.startswith("step_") and not d.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.directory, f"step_{s:08d}"),
                          ignore_errors=True)

    def restore_latest(self, like: Any, shardings: Any | None = None):
        self.wait()
        return restore_checkpoint(self.directory, like, shardings=shardings)
