from repro.data.synthetic import (LMTaskConfig, lm_batches, retrieval_corpus,
                                  shard_batch)
