"""Synthetic data pipeline (offline container: no downloadable corpora).

Two generators:

  * LM token streams with LEARNABLE structure — a mixture of affine
    next-token rules — so train-loss decrease is a meaningful signal in
    examples and tests (pure noise would bottom out at log V).
  * Retrieval corpora with PLANTED relevance: documents are random unit
    vectors; each query is a noisy copy of its gold document. This
    reproduces the paper's retrieval-precision protocol (Table I) when
    BEIR datasets are unavailable offline: P@k is measured against the
    planted gold (and against FP32-retrieval ground truth).

Batches are host-local numpy; `shard_batch` places the global batch with
the right NamedSharding (per-host slicing in a multi-host deployment
happens in the same call via jax.make_array_from_process_local_data).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import jax
import numpy as np
from jax.sharding import NamedSharding


@dataclasses.dataclass
class LMTaskConfig:
    vocab_size: int
    seq_len: int
    batch_size: int
    num_rules: int = 7
    noise: float = 0.05
    seed: int = 0


def lm_batches(cfg: LMTaskConfig) -> Iterator[dict]:
    """Deterministic stream of {tokens, labels} numpy batches."""
    rng = np.random.default_rng(cfg.seed)
    v = cfg.vocab_size
    a = rng.integers(1, v, size=cfg.num_rules)
    c = rng.integers(0, v, size=cfg.num_rules)
    while True:
        rule = rng.integers(0, cfg.num_rules, size=(cfg.batch_size, 1))
        toks = np.empty((cfg.batch_size, cfg.seq_len + 1), np.int32)
        toks[:, 0] = rng.integers(0, v, size=cfg.batch_size)
        for t in range(1, cfg.seq_len + 1):
            nxt = (toks[:, t - 1] * a[rule[:, 0]] + c[rule[:, 0]]) % v
            flip = rng.random(cfg.batch_size) < cfg.noise
            nxt = np.where(flip, rng.integers(0, v, cfg.batch_size), nxt)
            toks[:, t] = nxt
        yield {"tokens": toks[:, :-1].astype(np.int32),
               "labels": toks[:, 1:].astype(np.int32)}


def _unit(x):
    return x / np.linalg.norm(x, axis=-1, keepdims=True)


def retrieval_corpus(num_docs: int, dim: int = 512, num_queries: int = 64,
                     noise: float = 0.1, seed: int = 0,
                     cluster_size: int = 1, cluster_spread: float = 0.2):
    """Planted-relevance corpus: returns (docs (N,D), queries (Q,D),
    gold (Q,) int). Unit-norm float32 (as a normalized embedder emits).

    `noise` is the RELATIVE magnitude of the query perturbation (the noise
    direction is normalized, so noise=0.1 means |q - d_gold| ~ 0.1).
    cluster_size > 1 packs documents into clusters of near-duplicates
    (spread `cluster_spread` > noise) — the hard regime where quantization
    precision decides top-1, mirroring the paper's Table I protocol."""
    rng = np.random.default_rng(seed)
    if cluster_size > 1:
        n_centers = (num_docs + cluster_size - 1) // cluster_size
        centers = _unit(rng.normal(size=(n_centers, dim)))
        reps = np.repeat(centers, cluster_size, axis=0)[:num_docs]
        docs = _unit(reps + cluster_spread
                     * _unit(rng.normal(size=(num_docs, dim))))
    else:
        docs = _unit(rng.normal(size=(num_docs, dim)))
    docs = docs.astype(np.float32)
    gold = rng.integers(0, num_docs, size=num_queries)
    perturb = _unit(rng.normal(size=(num_queries, dim)))
    queries = _unit(docs[gold] + noise * perturb).astype(np.float32)
    return docs, queries, gold


def shard_batch(batch: dict, sharding: NamedSharding | dict) -> dict:
    """Place a host-local numpy batch onto the mesh."""
    def put(path_key, arr):
        s = sharding[path_key] if isinstance(sharding, dict) else sharding
        return jax.device_put(arr, s)
    return {k: put(k, v) for k, v in batch.items()}
