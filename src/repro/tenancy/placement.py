"""Tenant -> shard placement for pod-scale sharded serving.

The sharded serving runtime needs an EXPLICIT tenant->shard table with
two properties the elastic path depends on:

  * deterministic: the same tenant maps to the same shard set on every
    host, with no coordination traffic — placement is pure arithmetic
    over (tenant id, shard id), never mutable routing state that could
    drift between a router and a shard;
  * minimal movement on shrink: when a shard dies, ONLY the tenants it
    owned may move. Everyone else's placement (and therefore their arena
    contents, cache generations and in-flight work) is untouched.

Both come from rendezvous (highest-random-weight) hashing: each tenant
ranks every live shard by a stable per-(tenant, shard) hash and owns the
top `spread` shards. Removing a shard from the candidate set only
changes the ranking of tenants that ranked IT in their top `spread` —
the textbook HRW minimal-disruption property.

`spread` > 1 shards one tenant's corpus row-wise over several shards
(the pod-scale layout for corpora bigger than one arena); documents are
dealt round-robin over the owner set by their per-tenant ingest ordinal.
"""
from __future__ import annotations

import hashlib


def _weight(tenant_id: int, shard_id: int) -> int:
    """Stable per-(tenant, shard) rendezvous weight.

    blake2b rather than hash(): Python randomizes str/bytes hashing per
    process, and placement must agree across processes and restarts."""
    h = hashlib.blake2b(f"{tenant_id}:{shard_id}".encode(), digest_size=8)
    return int.from_bytes(h.digest(), "big")


class PlacementTable:
    """Rendezvous-hashed tenant -> shard-set mapping over live shards."""

    def __init__(self, shard_ids, *, spread: int = 1):
        shard_ids = [int(s) for s in shard_ids]
        if len(set(shard_ids)) != len(shard_ids):
            raise ValueError(f"duplicate shard ids: {shard_ids}")
        if not shard_ids:
            raise ValueError("need at least one shard")
        if spread < 1:
            raise ValueError("spread must be >= 1")
        self._live: list[int] = sorted(shard_ids)
        self.spread = spread
        self._tenants: set[int] = set()
        self._cache: dict[int, tuple[int, ...]] = {}

    # -- topology ----------------------------------------------------------

    @property
    def live_shards(self) -> list[int]:
        return list(self._live)

    @property
    def tenants(self) -> list[int]:
        """Every tenant ever routed through this table (registration is
        how remove_shard knows whose placement to diff)."""
        return sorted(self._tenants)

    # -- lookup ------------------------------------------------------------

    def owners(self, tenant_id: int) -> tuple[int, ...]:
        """The tenant's owner shards: top-`spread` live shards by
        rendezvous weight (descending; shard id breaks exact ties)."""
        tenant_id = int(tenant_id)
        if tenant_id < 0:
            raise ValueError(f"tenant id must be >= 0, got {tenant_id}")
        self._tenants.add(tenant_id)
        cached = self._cache.get(tenant_id)
        if cached is not None:
            return cached
        ranked = sorted(self._live,
                        key=lambda s: (-_weight(tenant_id, s), s))
        out = tuple(ranked[:min(self.spread, len(ranked))])
        self._cache[tenant_id] = out
        return out

    def shard_of(self, tenant_id: int) -> int:
        """The tenant's PRIMARY shard (owners()[0])."""
        return self.owners(tenant_id)[0]

    def doc_shard(self, tenant_id: int, ordinal: int) -> int:
        """Owner of one document: ordinals deal round-robin over the
        owner set, so a spread tenant's corpus splits near-evenly."""
        owners = self.owners(tenant_id)
        return owners[int(ordinal) % len(owners)]

    def table(self) -> dict[int, tuple[int, ...]]:
        """The explicit placement table (tenant -> owner shards) for every
        registered tenant — what an operator dashboard renders."""
        return {t: self.owners(t) for t in self.tenants}

    # -- elastic shrink ----------------------------------------------------

    def remove_shard(self, shard_id: int) -> dict[int, tuple[int, ...]]:
        """Drop a dead shard; returns {affected tenant: new owner set}.

        Affected tenants are exactly those whose owner set contained the
        dead shard — rendezvous hashing guarantees every other tenant's
        owner set is unchanged (asserted below, cheaply, because the
        elastic path's no-spurious-movement contract rides on it)."""
        shard_id = int(shard_id)
        if shard_id not in self._live:
            raise KeyError(f"shard {shard_id} is not live "
                           f"(live: {self._live})")
        if len(self._live) == 1:
            raise ValueError("cannot remove the last live shard")
        before = {t: self.owners(t) for t in self.tenants}
        self._live.remove(shard_id)
        self._cache.clear()
        moved: dict[int, tuple[int, ...]] = {}
        for t, old in before.items():
            new = self.owners(t)
            if shard_id in old:
                moved[t] = new
            else:
                assert new == old, (t, old, new)
        return moved
