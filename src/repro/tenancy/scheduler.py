"""Cross-tenant batch scheduler: many users, one kernel launch.

Requests from different tenants accumulate in a host-side queue; flush()
packs up to `max_batch` of them into ONE batched segment-masked two-stage
retrieval over the shared arena (the engine core — batch-native matmuls,
not a vmap). A mixed batch of B users therefore costs one launch AND one
stream of the arena's MSB plane for the whole batch, instead of B
sequential dispatches each re-streaming the plane over B per-user
databases. The exact analytic byte counts of every flush accumulate in
`stage1_bytes_streamed` / `stage1_bytes_vmapped`.

Partial batches are padded up to the next power of two with NO_TENANT
lanes (a sentinel matching no arena slot, so padding returns all-invalid
results and costs no extra compilation): jit caches one executable per
bucket, not one per queue length.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core.retrieval import NO_TENANT, RetrievalResult
from repro.tenancy.tenants import MultiTenantIndex


@dataclasses.dataclass(frozen=True)
class _Pending:
    request_id: int
    tenant_id: int
    query_codes: np.ndarray          # (D,) int8


def _bucket(n: int) -> int:
    return 1 << (n - 1).bit_length() if n > 1 else 1


class CrossTenantBatchScheduler:
    """Queue + flush loop around MultiTenantIndex.retrieve."""

    def __init__(self, index: MultiTenantIndex, *, max_batch: int = 16):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.index = index
        self.max_batch = max_batch
        self._queue: list[_Pending] = []
        self._next_id = 0
        self.launches = 0             # batched launches issued (diagnostics)
        # Analytic traffic ledger (engine.SchedulePlan units, exact bytes):
        # what the batched launches streamed vs what the same requests
        # would have streamed one query at a time.
        self.stage1_bytes_streamed = 0
        self.stage1_bytes_vmapped = 0
        # Per-CASCADE-STAGE ledger: stage name ("prune"/"approx"/"exact")
        # -> total bytes every flush streamed for that stage.
        self.stage_bytes: dict[str, int] = {}

    def submit(self, tenant_id: int, query_codes) -> int:
        """Enqueue one request; returns a ticket id resolved by flush()."""
        if int(tenant_id) < 0:
            raise ValueError(f"tenant id must be >= 0, got {tenant_id}")
        q = np.asarray(query_codes, np.int8)
        if q.ndim != 1 or q.shape[0] != self.index.arena.dim:
            raise ValueError(f"query must be ({self.index.arena.dim},) int8")
        rid = self._next_id
        self._next_id += 1
        self._queue.append(_Pending(rid, int(tenant_id), q))
        return rid

    def pending(self) -> int:
        return len(self._queue)

    def flush(self) -> dict[int, RetrievalResult]:
        """Drain the queue in max_batch groups; one launch per group.

        Returns {ticket id -> per-request RetrievalResult} with batch lanes
        sliced back out (padding lanes are dropped)."""
        out: dict[int, RetrievalResult] = {}
        while self._queue:
            group = self._queue[:self.max_batch]
            del self._queue[:len(group)]
            b = len(group)
            pb = _bucket(b)
            queries = np.zeros((pb, self.index.arena.dim), np.int8)
            tids = np.full((pb,), NO_TENANT, np.int32)
            for i, req in enumerate(group):
                queries[i] = req.query_codes
                tids[i] = req.tenant_id
            # tids stay host-side: index.retrieve derives the windowed
            # layout from them before anything touches the device.
            res = self.index.retrieve(jnp.asarray(queries), tids)
            self.launches += 1
            plan = self.index.last_plan
            if plan is not None:
                # stage1_bytes is what the launch ACTUALLY streamed (the
                # padded lanes included); the vmapped comparison counts
                # only the b REAL requests — a sequential server would
                # never have dispatched the padding lanes.
                self.stage1_bytes_streamed += plan.stage1_bytes
                self.stage1_bytes_vmapped += (
                    plan.stage1_bytes_vmapped // plan.batch) * b
                for s in plan.stages:
                    self.stage_bytes[s.name] = (
                        self.stage_bytes.get(s.name, 0) + s.bytes_hbm)
            for i, req in enumerate(group):
                out[req.request_id] = RetrievalResult(
                    indices=res.indices[i], scores=res.scores[i],
                    candidate_indices=res.candidate_indices[i])
        return out
