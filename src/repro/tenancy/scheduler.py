"""Cross-tenant batch scheduler: many users, one kernel launch.

Historically this module owned the host-side queue + flush loop; that
machinery grew into the full dynamic batcher in `repro.serve.runtime`
(deadline admission, future-style handles, per-tenant fairness, the
hot-cluster cache). `CrossTenantBatchScheduler` survives as the thin
synchronous facade over a `ServingRuntime` configured for the legacy
contract: strict FIFO grouping, no deadline-triggered launches, no
cache — flush() packs up to `max_batch` requests into ONE batched
segment-masked retrieval over the shared arena per group, padding
partial groups to power-of-two buckets with NO_TENANT lanes, exactly as
before. The exact analytic byte counts of every flush accumulate in
`stage1_bytes_streamed` / `stage1_bytes_vmapped` / `stage_bytes`.
"""
from __future__ import annotations

from repro.core.retrieval import RetrievalResult
from repro.tenancy.tenants import MultiTenantIndex


class CrossTenantBatchScheduler:
    """Queue + flush loop around MultiTenantIndex.retrieve.

    A compatibility facade: `repro.serve.runtime.ServingRuntime` is the
    full dynamic batcher this wraps (submit there returns future-style
    handles and batches launch on deadlines; here submit returns an int
    ticket resolved by an explicit flush())."""

    def __init__(self, index: MultiTenantIndex, *, max_batch: int = 16,
                 registry=None, tracer=None):
        # Imported here: repro.serve pulls in the RAG pipelines (which
        # import this package), so a module-level import would be cyclic.
        from repro.serve.runtime import RuntimeConfig, ServingRuntime
        self.index = index
        self.max_batch = max_batch
        self._rt = ServingRuntime(index, RuntimeConfig(
            max_batch=max_batch, max_wait=0.0, fairness="fifo",
            cache_bytes=0, auto_flush=False),
            registry=registry, tracer=tracer)

    @property
    def registry(self):
        """The wrapped runtime's metrics registry (repro.obs)."""
        return self._rt.registry

    @property
    def tracer(self):
        """The wrapped runtime's request-lifecycle tracer (repro.obs)."""
        return self._rt.tracer

    def submit(self, tenant_id: int, query_codes) -> int:
        """Enqueue one request; returns a ticket id resolved by flush()."""
        return self._rt.submit(tenant_id, query_codes).request_id

    def pending(self) -> int:
        return self._rt.pending()

    @property
    def launches(self) -> int:
        return self._rt.launches

    @property
    def stage1_bytes_streamed(self) -> int:
        return self._rt.stage1_bytes_streamed

    @property
    def stage1_bytes_vmapped(self) -> int:
        return self._rt.stage1_bytes_vmapped

    @property
    def stage_bytes(self) -> dict[str, int]:
        return self._rt.stage_bytes

    def flush(self) -> dict[int, RetrievalResult]:
        """Drain the queue in max_batch groups; one launch per group.

        Returns {ticket id -> per-request RetrievalResult} with batch
        lanes sliced back out (padding lanes are dropped)."""
        return {h.request_id: h.result() for h in self._rt.flush()}
