"""Fixed-capacity nibble-planar arenas with online insert/delete.

The wearable setting is streaming: a personal corpus grows continuously as
the agent monitors health signals, and the seed repo's offline
`build_database` (re-quantize + re-pack everything) is exactly the rebuild
the edge budget cannot afford. An `Arena` is a pre-allocated nibble-planar
slab — the same (msb_plane, lsb_plane, norms_sq) triple `BitPlanarDB`
streams on TPU — plus host-side slot bookkeeping:

  * insert: quantize-with-fixed-scale rows land in free slots via one
    `.at[slots].set` scatter per plane — O(rows inserted), never O(N).
  * delete: tombstone, not reshuffle. The slot's norm is zeroed (cosine
    key 0 — a dead row can never win stage 1), its planes are zeroed
    (MIPS score 0), and its owner is reset to FREE so segment masks
    exclude it. Live slot ids stay stable for in-flight readers.
  * compact: periodically repacks live rows to the slab's front (grouped
    per tenant, so each tenant becomes one contiguous segment), reclaims
    tombstones, and returns the old->new slot mapping.

The fixed quantization scale is the price of streaming: rows quantized at
different times must stay mutually comparable, so the scale is chosen once
(calibrated for unit-norm embedder outputs) instead of per-corpus.
`Arena.stats.rebuilds` counts full re-quantize passes; the online path
keeps it at zero by construction.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp
import numpy as np

from repro.core import bitplanar, quantization

FREE = -1  # owner value of free and tombstoned slots


class ArenaFull(RuntimeError):
    """Raised when an insert does not fit; compact() or grow a new arena."""


@dataclasses.dataclass
class ArenaStats:
    inserts: int = 0          # rows written online
    deletes: int = 0          # rows tombstoned
    compactions: int = 0      # repack passes
    rebuilds: int = 0         # full re-quantize passes (streaming path: 0)


class Arena:
    """One shared slab serving many tenants' rows side by side."""

    def __init__(self, capacity: int, dim: int, *, scale: float | None = None):
        if dim % 2:
            raise ValueError("dim must be even for nibble-planar packing")
        self.capacity = capacity
        self.dim = dim
        self.scale = jnp.float32(scale if scale is not None
                                 else quantization.unit_norm_scale(dim))
        self.msb_plane = jnp.zeros((capacity, dim // 2), jnp.uint8)
        self.lsb_plane = jnp.zeros((capacity, dim // 2), jnp.uint8)
        # 1-bit sign plane (stage-0 prescreen operand), maintained in
        # lockstep with the nibble planes; dims that don't pack 8-per-byte
        # simply don't get one (the prescreen requires dim % 8 == 0).
        self.sign_plane = (jnp.zeros((capacity, dim // 8), jnp.uint8)
                           if dim % 8 == 0 else None)
        self.norms_sq = jnp.zeros((capacity,), jnp.int32)
        self.owner = jnp.full((capacity,), FREE, jnp.int32)
        # slot -> cluster label (host-side; -1 = unassigned/free). The
        # arena is clustering-agnostic storage: labels are written by the
        # index layer (repro.core.clustering assigns them) and kept in
        # lockstep with the planes across delete/compact.
        self.cluster_labels = np.full((capacity,), -1, np.int32)
        self._next = 0                  # bump allocator over virgin slots
        self._tombstones = 0            # dead slots awaiting compaction
        self.generation = 0             # bumped on every mutation
        self._db_cache: tuple[int, bitplanar.BitPlanarDB] | None = None
        self.stats = ArenaStats()

    # -- capacity accounting -------------------------------------------------

    @property
    def num_live(self) -> int:
        return self._next - self._tombstones

    @property
    def num_free(self) -> int:
        """Slots insertable RIGHT NOW (tombstones only count after compact)."""
        return self.capacity - self._next

    def db(self) -> bitplanar.BitPlanarDB:
        """The slab viewed as the retrieval primitives' BitPlanarDB.

        Cached per generation: the view is rebuilt only after a mutation,
        so the query hot path hands jit a stable pytree."""
        if self._db_cache is None or self._db_cache[0] != self.generation:
            self._db_cache = (self.generation, bitplanar.BitPlanarDB(
                msb_plane=self.msb_plane, lsb_plane=self.lsb_plane,
                norms_sq=self.norms_sq, scale=self.scale,
                sign_plane=self.sign_plane))
        return self._db_cache[1]

    # -- online mutation -----------------------------------------------------

    def quantize(self, embeddings) -> jnp.ndarray:
        """Float embeddings -> INT8 codes under the arena's fixed scale."""
        return quantization.quantize_int8_fixed(embeddings, self.scale)

    def insert(self, codes, owner_id: int) -> np.ndarray:
        """Pack (B, D) int8 codes into free slots for `owner_id`.

        Returns the assigned slot ids (B,) int64. O(B) device work — the
        rest of the slab is untouched (no rebuild). Cluster labels are a
        separate second phase (`set_labels`), so a failed insert can
        never leave labeling half-applied."""
        codes = jnp.asarray(codes)
        if codes.dtype != jnp.int8:
            raise ValueError(f"codes must be int8 (got {codes.dtype}); "
                             "float embeddings go through ingest()/"
                             "quantize() first")
        b, d = codes.shape
        if d != self.dim:
            raise ValueError(f"dim mismatch: arena {self.dim}, rows {d}")
        if owner_id < 0:
            raise ValueError("tenant ids must be >= 0 (negatives are sentinels)")
        if b > self.num_free:
            raise ArenaFull(
                f"need {b} slots, have {self.num_free} "
                f"({self._tombstones} reclaimable via compact())")
        slots = np.arange(self._next, self._next + b)
        self._next += b
        idx = jnp.asarray(slots, jnp.int32)
        msb, lsb = bitplanar.pack_nibble_planes(codes)
        norms = jnp.sum(codes.astype(jnp.int32) ** 2, axis=-1)
        self.msb_plane = self.msb_plane.at[idx].set(msb)
        self.lsb_plane = self.lsb_plane.at[idx].set(lsb)
        if self.sign_plane is not None:
            self.sign_plane = self.sign_plane.at[idx].set(
                bitplanar.pack_sign_plane(codes))
        self.norms_sq = self.norms_sq.at[idx].set(norms)
        self.owner = self.owner.at[idx].set(jnp.int32(owner_id))
        self.generation += 1
        self.stats.inserts += b
        return slots

    def set_labels(self, slots, labels) -> None:
        """Label already-inserted slots with cluster ids (host-side only).

        The index layer assigns labels AFTER a successful insert (so a
        failed insert can never leave cluster bookkeeping half-updated);
        this is the API for that second phase."""
        slots = np.atleast_1d(np.asarray(slots, np.int64))
        labels = np.asarray(labels, np.int32).reshape(-1)
        if slots.shape[0] != labels.shape[0]:
            raise ValueError(f"need one label per slot ({slots.shape[0]}), "
                             f"got {labels.shape[0]}")
        if slots.size and (slots.min() < 0 or slots.max() >= self._next):
            raise IndexError("slot out of allocated range")
        self.cluster_labels[slots] = labels

    def read_codes(self, slots) -> jnp.ndarray:
        """Reconstruct the full INT8 codes of `slots` from the planes.

        Off the hot path (cluster bookkeeping on delete, diagnostics):
        O(rows read), exact inverse of the insert-time packing."""
        idx = jnp.asarray(np.atleast_1d(np.asarray(slots, np.int64)),
                          jnp.int32)
        return bitplanar.reconstruct_int8(
            jnp.take(self.msb_plane, idx, axis=0),
            jnp.take(self.lsb_plane, idx, axis=0))

    def delete(self, slots) -> None:
        """Tombstone slots: norm 0, planes 0, owner FREE.

        Ids are not recycled until compact(), so results already handed to
        callers keep pointing at (now dead, never-winning) slots.
        Duplicate and already-dead ids are counted once (liveness is read
        from the owner array, so num_live stays truthful)."""
        slots = np.unique(np.atleast_1d(np.asarray(slots, np.int64)))
        if slots.size == 0:
            return
        if slots[0] < 0 or slots[-1] >= self._next:
            raise IndexError("slot out of allocated range")
        idx = jnp.asarray(slots, jnp.int32)
        newly_dead = int(jnp.sum(jnp.take(self.owner, idx) >= 0))
        self.msb_plane = self.msb_plane.at[idx].set(0)
        self.lsb_plane = self.lsb_plane.at[idx].set(0)
        if self.sign_plane is not None:
            # A zero sign byte is the packed form of all-positive dims —
            # consistent with the zeroed nibble planes (code 0 -> bit 0).
            self.sign_plane = self.sign_plane.at[idx].set(0)
        self.norms_sq = self.norms_sq.at[idx].set(0)
        self.owner = self.owner.at[idx].set(FREE)
        self.cluster_labels[slots] = -1
        self.generation += 1
        self._tombstones += newly_dead
        self.stats.deletes += newly_dead

    def compact(self, order: np.ndarray | None = None) -> np.ndarray:
        """Repack live rows to the slab front; reclaim tombstones.

        order: optional live-slot ordering (e.g. grouped by tenant so each
        tenant ends up one contiguous segment); defaults to ascending slot.
        Returns mapping (capacity,) int64: old slot -> new slot, -1 if dead.
        Moves already-quantized rows — no re-quantization (not a rebuild).
        """
        own = np.asarray(self.owner)
        if order is None:
            live = np.nonzero(own >= 0)[0]
        else:
            live = np.asarray(order, np.int64)
            if live.size and not np.all(own[live] >= 0):
                raise ValueError("compaction order includes dead slots")
        num_live = live.size
        idx = jnp.asarray(live, jnp.int32)

        def repack(arr, fill):
            out = jnp.full_like(arr, fill)
            if num_live:
                out = out.at[:num_live].set(jnp.take(arr, idx, axis=0))
            return out

        self.msb_plane = repack(self.msb_plane, 0)
        self.lsb_plane = repack(self.lsb_plane, 0)
        if self.sign_plane is not None:
            self.sign_plane = repack(self.sign_plane, 0)
        self.norms_sq = repack(self.norms_sq, 0)
        self.owner = repack(self.owner, FREE)
        new_labels = np.full_like(self.cluster_labels, -1)
        new_labels[:num_live] = self.cluster_labels[live]
        self.cluster_labels = new_labels
        mapping = np.full(self.capacity, -1, np.int64)
        mapping[live] = np.arange(num_live)
        self._next = num_live
        self._tombstones = 0
        self.generation += 1
        self.stats.compactions += 1
        return mapping
