"""Tenant table + the multi-tenant index facade.

`TenantTable` is pure host-side metadata: tenant_id -> the arena slots the
tenant owns (insertion order preserved) plus the derived contiguous
row-slot segments. The device-side source of truth for query masking is
the arena's `owner` array — the table exists for allocation accounting,
compaction ordering (rows regrouped per tenant so each tenant is one
contiguous segment afterwards) and diagnostics.

`MultiTenantIndex` glues arena + table into the object the serving layer
holds: ingest (quantize + pack into free slots), delete (tombstone),
compact (repack + remap) and retrieve (segment-masked batched two-stage
retrieval over the shared slab — one launch for a mixed batch of tenants).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import clustering, engine, retrieval
from repro.tenancy.arena import Arena


class TenantTable:
    """tenant_id -> live arena slots (and their contiguous segments)."""

    def __init__(self):
        self._slots: dict[int, list[int]] = {}
        self._segments: dict[int, list[tuple[int, int]]] = {}  # cache

    def add(self, tenant_id: int) -> None:
        self._slots.setdefault(int(tenant_id), [])

    @property
    def tenant_ids(self) -> list[int]:
        return sorted(self._slots)

    def slots(self, tenant_id: int) -> list[int]:
        return list(self._slots.get(int(tenant_id), ()))

    def num_docs(self, tenant_id: int) -> int:
        return len(self._slots.get(int(tenant_id), ()))

    def record_insert(self, tenant_id: int, slots) -> None:
        self.add(tenant_id)
        self._slots[int(tenant_id)].extend(int(s) for s in np.atleast_1d(slots))
        self._segments.pop(int(tenant_id), None)

    def record_delete(self, tenant_id: int, slots) -> None:
        dead = {int(s) for s in np.atleast_1d(slots)}
        mine = self._slots.get(int(tenant_id))
        if mine is None or not dead <= set(mine):
            raise KeyError(f"tenant {tenant_id} does not own slots "
                           f"{sorted(dead - set(mine or ()))}")
        self._slots[int(tenant_id)] = [s for s in mine if s not in dead]
        self._segments.pop(int(tenant_id), None)

    def segments(self, tenant_id: int) -> list[tuple[int, int]]:
        """The tenant's slots as sorted half-open [start, stop) runs.

        Cached per tenant (invalidated by inserts/deletes/remaps): the
        batched query path reads this on every request."""
        tenant_id = int(tenant_id)
        cached = self._segments.get(tenant_id)
        if cached is not None:
            return cached
        slots = sorted(self._slots.get(tenant_id, ()))
        runs: list[tuple[int, int]] = []
        for s in slots:
            if runs and runs[-1][1] == s:
                runs[-1] = (runs[-1][0], s + 1)
            else:
                runs.append((s, s + 1))
        self._segments[tenant_id] = runs
        return runs

    def compaction_order(self, cluster_labels=None) -> np.ndarray:
        """Live slots grouped by tenant — compacting in this order leaves
        every tenant as ONE contiguous segment.

        cluster_labels: optional (capacity,) slot -> cluster map; when
        given, each tenant's slots are additionally grouped by cluster,
        so every (tenant, cluster) pair lands in a contiguous run — the
        layout that makes the cascade's selected clusters dense block
        gathers. Tenant contiguity (the windowed fast path's invariant)
        is preserved either way."""
        if cluster_labels is None:
            order = [s for t in self.tenant_ids for s in self._slots[t]]
        else:
            lab = np.asarray(cluster_labels)
            order = [s for t in self.tenant_ids
                     for s in sorted(self._slots[t],
                                     key=lambda sl: (lab[sl], sl))]
        return np.asarray(order, np.int64)

    def remap(self, mapping: np.ndarray) -> None:
        """Apply a compaction's old->new slot mapping."""
        for t, slots in self._slots.items():
            moved = [int(mapping[s]) for s in slots]
            if any(m < 0 for m in moved):
                raise ValueError(f"compaction dropped live slots of tenant {t}")
            self._slots[t] = moved
        self._segments.clear()


class MultiTenantIndex:
    """Shared-arena index serving many per-user corpora.

    One retrieval config (and thus one compiled retrieval program per batch
    shape) serves every tenant; per-request tenant ids select the segments.
    """

    def __init__(self, capacity: int, dim: int,
                 cfg: retrieval.RetrievalConfig | None = None,
                 *, scale: float | None = None,
                 clusters: clustering.ClusterParams | None = None):
        self.arena = Arena(capacity, dim, scale=scale)
        self.table = TenantTable()
        self.cfg = cfg or retrieval.RetrievalConfig()
        self._engine = engine.RetrievalEngine(self.cfg)
        # Optional cluster-pruned cascade: an online-maintained codebook
        # labels every ingested row; batched retrieves then run the
        # 3-stage cascade (centroid prune -> gathered INT4 scan -> exact
        # rescore) instead of scanning the whole arena.
        self.cluster_params = clusters
        if clusters is not None and capacity % clusters.block_rows:
            # A partial tail block would force the gather kernel to pad
            # (= copy) the whole plane on every launch; insist the block
            # size tiles the arena so the hot path streams in place.
            raise ValueError(
                f"block_rows {clusters.block_rows} must divide arena "
                f"capacity {capacity} (keeps the block-gather kernel's "
                f"plane un-padded on the query hot path)")
        self.clusters = (clustering.ClusterIndex(
            clusters.num_clusters, dim, seed=clusters.seed,
            iters=clusters.kmeans_iters) if clusters is not None else None)
        # Analytic SchedulePlan of the most recent retrieve() launch —
        # schedulers read this to account bytes streamed per flush.
        self.last_plan: engine.SchedulePlan | None = None
        # (arena generation, tenant-id bytes) -> windowed layout /
        # ClusterPolicy / None; schedulers re-issue the same tenant
        # groupings between mutations. Entries from older arena
        # generations are dead weight (cluster entries pin capacity-sized
        # device buffers), so the cache is dropped wholesale whenever the
        # arena mutates — see _layout_cache_for_generation.
        self._layout_cache: dict = {}
        self._layout_cache_gen = -1

    # -- ingestion / deletion ------------------------------------------------

    def ingest(self, tenant_id: int, embeddings) -> np.ndarray:
        """Online-ingest (B, D) float embeddings for one tenant.

        Quantizes under the arena's fixed scale and packs into free slots —
        no rebuild of existing rows. Returns assigned slot ids (B,)."""
        return self.ingest_codes(tenant_id, self.arena.quantize(embeddings))

    def ingest_codes(self, tenant_id: int, codes) -> np.ndarray:
        slots = self.arena.insert(codes, int(tenant_id))
        self.table.record_insert(tenant_id, slots)
        if self.clusters is not None:
            # Assign the new rows online (trains the codebook on the very
            # first batch) and label the slots; fresh rows land at the
            # arena tail, so their clusters pick up one extra block until
            # the next cluster-grouped compaction re-densifies them.
            # Labeling runs AFTER the insert succeeded, so a failed insert
            # never leaves the codebook's running sums half-updated.
            labels = self.clusters.add(np.asarray(codes, np.int8))
            self.arena.set_labels(slots, labels)
        return slots

    def delete(self, tenant_id: int, slots) -> None:
        """Tombstone a tenant's documents (checked against ownership)."""
        self.table.record_delete(tenant_id, slots)
        if self.clusters is not None:
            sl = np.unique(np.atleast_1d(np.asarray(slots, np.int64)))
            labels = self.arena.cluster_labels[sl]
            live = labels >= 0
            if live.any():
                codes = self.arena.read_codes(sl[live])
                self.clusters.remove(np.asarray(codes), labels[live])
        self.arena.delete(slots)

    def compact(self) -> np.ndarray:
        """Reclaim tombstones; returns old->new slot mapping (-1 = dead).

        With clustering enabled the repack order groups each tenant's
        rows by cluster (tenant contiguity preserved), and the codebook
        refreshes from its running sums — no corpus re-read."""
        labels = (self.arena.cluster_labels if self.clusters is not None
                  else None)
        mapping = self.arena.compact(self.table.compaction_order(labels))
        self.table.remap(mapping)
        if self.clusters is not None:
            self.clusters.refresh()
        return mapping

    # -- query ---------------------------------------------------------------

    @property
    def engine(self) -> engine.RetrievalEngine:
        """The index's RetrievalEngine, re-keyed if `cfg` was replaced
        (the engine is a stateless facade; the compiled-program cache is
        keyed on the cfg itself, so swapping cfg never serves stale code).
        """
        if self._engine.cfg != self.cfg:
            self._engine = engine.RetrievalEngine(self.cfg)
        return self._engine

    def _layout_cache_for_generation(self) -> dict:
        """The layout cache, valid for the CURRENT arena generation only:
        every mutation invalidates all cached layouts (their device
        buffers would otherwise accumulate, one dead set per generation,
        until the size backstop blew the live entries away too)."""
        if self._layout_cache_gen != self.arena.generation:
            self._layout_cache.clear()
            self._layout_cache_gen = self.arena.generation
        return self._layout_cache

    def _contiguous_layout(self, tenant_ids) -> tuple[jnp.ndarray, int] | None:
        """(per-lane segment starts, pow2 window) when every requested
        tenant is ONE contiguous slot run; None when fragmented (then only
        the full-arena masked scan is correct). Cached per (arena
        generation, cfg, tenant-id tuple) — cfg is part of the key because
        the window floor depends on cfg.k, and cfg may be replaced after
        construction."""
        cache = self._layout_cache_for_generation()
        key = (self.cfg, tenant_ids.tobytes())
        if key in cache:
            return cache[key]
        # window >= k keeps the in-window candidate budget well-posed even
        # for tenants holding fewer than k docs (lanes pad with -1).
        starts, longest = [], max(1, self.cfg.k)
        layout = None
        for t in tenant_ids:
            segs = self.table.segments(int(t))
            if len(segs) > 1:
                break
            start, stop = segs[0] if segs else (0, 0)
            starts.append(start)
            longest = max(longest, stop - start)
        else:
            window = 1 << (longest - 1).bit_length()  # bucket recompiles
            if window < self.arena.capacity:          # else: full scan
                layout = (jnp.asarray(np.asarray(starts, np.int32)),
                          jnp.asarray(tenant_ids, jnp.int32), window)
        if len(cache) > 512:          # many distinct tid tuples backstop
            cache.clear()
        cache[key] = layout
        return layout

    def _cluster_layout(self, tids_host
                        ) -> tuple[engine.ClusterPolicy, np.ndarray] | None:
        """The batch's (ClusterPolicy, host block table): per-LANE block
        tables listing, for each cluster, the arena blocks holding that
        (tenant, cluster)'s rows. Correct for ANY layout (fresh tail
        inserts and fragmented tenants just list more blocks — recall
        never depends on when compact() last ran); after cluster-grouped
        compaction each entry is a dense run. None when clustering is
        off/untrained or the gathered view could not hold k rows. The
        host-side np table mirrors `policy.cluster_blocks` — the serving
        runtime's slot-map lookups read it without a device sync. Cached
        for the current arena generation per (codebook generation, cfg,
        tenant-id tuple)."""
        if self.clusters is None or not self.clusters.trained:
            return None
        params = self.cluster_params
        cache = self._layout_cache_for_generation()
        key = ("cluster", self.clusters.generation, self.cfg,
               tids_host.tobytes())
        if key in cache:
            return cache[key]
        labels = self.arena.cluster_labels
        br = params.block_rows
        k_clusters = self.clusters.num_clusters
        tables = {}
        for t in np.unique(tids_host):
            if t < 0:
                continue
            # restricted to the tenant's own slots, so the table lists
            # exactly the blocks holding ITS rows — O(S log S) in the
            # tenant's rows (one vectorized groupby pass), not O(capacity)
            tables[int(t)] = clustering.block_table(
                labels, k_clusters, br, pad_pow2=False,
                rows=np.asarray(self.table.slots(int(t)), np.int64))
        mb = max((t.shape[1] for t in tables.values()), default=1)
        mb = 1 << (mb - 1).bit_length()      # pow2-bucket recompiles
        nprobe = min(params.nprobe, k_clusters)
        layout = None
        # The prune must BUY something: when fragmentation inflates the
        # per-lane gathered view to arena size (many interleaved
        # single-doc ingests before a compact), the windowed/masked scan
        # is the cheaper launch — fall back until compact() re-densifies.
        # The lower bound keeps the in-view top-k well-posed.
        if max(1, self.cfg.k) <= nprobe * mb * br < self.arena.capacity:
            table = np.full((len(tids_host), k_clusters, mb), -1, np.int32)
            for i, t in enumerate(tids_host):
                if int(t) in tables:
                    per = tables[int(t)]
                    table[i, :, :per.shape[1]] = per
            cb = self.clusters.codebook()
            policy = engine.ClusterPolicy(
                owner=self.arena.owner,
                tenant_ids=jnp.asarray(tids_host, jnp.int32),
                labels=jnp.asarray(labels),
                centroid_msb=cb.msb_plane, centroid_norms=cb.norms_sq,
                cluster_blocks=jnp.asarray(table),
                nprobe=nprobe, block_rows=br)
            layout = (policy, table)
        if len(cache) > 512:          # many distinct tid tuples backstop
            cache.clear()
        cache[key] = layout
        return layout

    def cluster_rows(self, tenant: int) -> dict[int, np.ndarray]:
        """Host-side per-cluster row ids of one tenant, each ASCENDING —
        the exact rows (and row order) that cluster's view streams in the
        batched cascade. The serving runtime's hot-cluster cache admits
        entries from these lists (a contiguous run packs densely into
        slab slots; row order is what keeps the packed view bit-identical
        to the cold cascade). Cached per (arena generation, codebook
        generation, tenant); empty dict when clustering is off/untrained.
        """
        if self.clusters is None or not self.clusters.trained:
            return {}
        cache = self._layout_cache_for_generation()
        key = ("cluster_rows", self.clusters.generation, int(tenant))
        if key in cache:
            return cache[key]
        out: dict[int, np.ndarray] = {}
        slots = np.sort(np.asarray(self.table.slots(int(tenant)), np.int64))
        if slots.size:
            labs = np.asarray(self.arena.cluster_labels)[slots]
            order = np.argsort(labs, kind="stable")   # rows stay ascending
            labs, rows = labs[order], slots[order].astype(np.int32)
            bounds = np.flatnonzero(np.diff(labs)) + 1
            for lab, grp in zip(labs[np.r_[0, bounds]] if labs.size else (),
                                np.split(rows, bounds)):
                if lab >= 0:
                    out[int(lab)] = grp
        if len(cache) > 512:
            cache.clear()
        cache[key] = out
        return out

    def cluster_policy(self, tenant_ids) -> engine.ClusterPolicy | None:
        """The ClusterPolicy a batched retrieve for `tenant_ids` would run
        (None when clustering is off/untrained or the prune would not beat
        the windowed/masked scan)."""
        layout = self.cluster_layout(tenant_ids)
        return None if layout is None else layout[0]

    def cluster_layout(self, tenant_ids
                       ) -> tuple[engine.ClusterPolicy, np.ndarray] | None:
        """The (ClusterPolicy, host-side (B, K, MB) np block table) a
        batched retrieve for `tenant_ids` would run. Public for the
        serving runtime: its hot-cluster cache resolves slot-map lookups
        against the host table (no device sync) and hands the engine a
        SlabPolicy built from the SAME policy — going through this method
        guarantees the cached path and the in-graph cascade can never see
        different block tables."""
        tids_host = np.atleast_1d(np.asarray(tenant_ids, np.int32))
        return self._cluster_layout(tids_host)

    def retrieve(self, query_codes, tenant_ids) -> retrieval.RetrievalResult:
        """Per-tenant retrieval; single query or mixed cross-tenant batch.

        Chooses the engine POLICY host-side and hands the batch to the one
        batched cascade core: with clustering enabled a batch runs the
        cluster-pruned cascade (each lane streams only its top-nprobe
        clusters' blocks); otherwise it takes the windowed fast path (each
        lane streams only its tenant's contiguous segment) whenever the
        layout allows — after interleaved ingests fragment a tenant, it
        falls back to the full-arena masked scan until compact() restores
        contiguity. The engine core is top-level jax.jit-compiled, so
        repeat calls at the same (batch, policy kind, window) shape reuse
        the executable. The launch's analytic SchedulePlan lands in
        `self.last_plan`.
        """
        query_codes = jnp.asarray(query_codes)
        db = self.arena.db()
        if query_codes.ndim == 1:
            if int(tenant_ids) < 0:
                raise ValueError(f"tenant id must be >= 0, got {tenant_ids}")
            policy = engine.MaskedPolicy(
                owner=self.arena.owner,
                tenant_ids=jnp.asarray(jnp.int32(tenant_ids))[None])
            self.last_plan = self.engine.plan_for(db, 1, policy)
            return self.engine.retrieve_single(query_codes, db, policy)
        tids_host = np.atleast_1d(np.asarray(tenant_ids, np.int32))
        # Negative ids are sentinels (-1 = FREE/tombstone owner, -2 =
        # NO_TENANT padding); only the padding sentinel may be queried —
        # anything else negative is a caller bug that must not match rows.
        bad = tids_host[(tids_host < 0) & (tids_host != retrieval.NO_TENANT)]
        if bad.size:
            raise ValueError("tenant ids must be >= 0 (or NO_TENANT for "
                             f"padding lanes), got {bad.tolist()}")
        layout = self._cluster_layout(tids_host)
        policy = None if layout is None else layout[0]
        if policy is None:
            layout = self._contiguous_layout(tids_host)
            if layout is not None:
                starts, tids, window = layout
                policy = engine.WindowedPolicy(owner=self.arena.owner,
                                               tenant_ids=tids,
                                               starts=starts, window=window)
            else:
                policy = engine.MaskedPolicy(
                    owner=self.arena.owner,
                    tenant_ids=jnp.asarray(tids_host))
        self.last_plan = self.engine.plan_for(db, len(tids_host), policy)
        return self.engine.retrieve(query_codes, db, policy)

    # -- introspection -------------------------------------------------------

    @property
    def capacity(self) -> int:
        return self.arena.capacity

    @property
    def num_live(self) -> int:
        return self.arena.num_live

    def utilization(self) -> float:
        return self.arena.num_live / self.arena.capacity
