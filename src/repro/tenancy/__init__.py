"""Multi-tenant streaming index: shared arenas, online ingest, batch serving.

The subsystem the wearable deployment needs on top of the paper's two-stage
retrieval: many per-user corpora packed into one pre-allocated nibble-planar
arena, online insert/delete without rebuild, and a scheduler that turns a
mixed batch of users' queries into a single vmapped kernel launch.
"""
from repro.tenancy.arena import Arena, ArenaFull, ArenaStats, FREE
from repro.tenancy.tenants import MultiTenantIndex, TenantTable
from repro.tenancy.scheduler import CrossTenantBatchScheduler
from repro.tenancy.placement import PlacementTable
