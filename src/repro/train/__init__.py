from repro.train.optim import Optimizer, adafactor, adamw, get_optimizer
from repro.train.step import (clip_by_global_norm, global_norm,
                              make_train_step)
