"""Optimizers (pure-pytree, no optax): AdamW and Adafactor.

AdamW keeps f32 first/second moments per parameter. Adafactor keeps
row/col-factored second moments for >=2-D parameters (factored over the
LAST TWO dims; leading layer-stack dims are kept) — the memory-sane choice
for the 400B MoE on a 256-chip pod (DESIGN.md §4). Both return update
trees with the same sharding as the parameters, so optimizer state shards
identically to the model under pjit.
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]  # (grads, state, params)


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(lr: float = 1e-3, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"step": jnp.zeros((), jnp.int32), "mu": zeros,
                "nu": jax.tree.map(jnp.copy, zeros)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - b1 ** t
        c2 = 1.0 - b2 ** t

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * jnp.square(g)
            u = (m / c1) / (jnp.sqrt(v / c2) + eps)
            u = u + weight_decay * p.astype(jnp.float32)
            return (p - lr * u.astype(p.dtype)).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_p = jax.tree.map(lambda o: o[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda o: o[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda o: o[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"step": step, "mu": new_m, "nu": new_v}

    return Optimizer(init=init, update=update)


# ---------------------------------------------------------------------------
# Adafactor (Shazeer & Stern 2018), factored over the last two dims
# ---------------------------------------------------------------------------

def adafactor(lr: float = 1e-3, decay: float = 0.8, eps1: float = 1e-30,
              eps2: float = 1e-3, clip_threshold: float = 1.0) -> Optimizer:
    def init(params):
        def leaf(p):
            if p.ndim >= 2:
                return {"vr": jnp.zeros(p.shape[:-1], jnp.float32),
                        "vc": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                        jnp.float32)}
            return {"v": jnp.zeros(p.shape, jnp.float32)}
        return {"step": jnp.zeros((), jnp.int32),
                "v": jax.tree.map(leaf, params)}

    def update(grads, state, params):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        beta = 1.0 - t ** -decay                     # increasing decay schedule

        def upd(g, s, p):
            g = g.astype(jnp.float32)
            g2 = jnp.square(g) + eps1
            if p.ndim >= 2:
                vr = beta * s["vr"] + (1 - beta) * jnp.mean(g2, axis=-1)
                vc = beta * s["vc"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = jnp.mean(vr, axis=-1, keepdims=True)
                u = g / (jnp.sqrt(vr / denom)[..., None]
                         * jnp.sqrt(vc)[..., None, :] + eps1)
                ns = {"vr": vr, "vc": vc}
            else:
                v = beta * s["v"] + (1 - beta) * g2
                u = g / (jnp.sqrt(v) + eps1)
                ns = {"v": v}
            # update clipping (RMS)
            rms = jnp.sqrt(jnp.mean(jnp.square(u)) + eps1)
            u = u / jnp.maximum(1.0, rms / clip_threshold)
            scale = jnp.maximum(
                eps2, jnp.sqrt(jnp.mean(jnp.square(p.astype(jnp.float32)))))
            return (p - (lr * scale * u).astype(p.dtype)).astype(p.dtype), ns

        flat_p, tdef = jax.tree.flatten(params)
        flat_g = tdef.flatten_up_to(grads)
        flat_s = tdef.flatten_up_to(state["v"])
        new = [upd(g, s, p) for g, s, p in zip(flat_g, flat_s, flat_p)]
        new_p = tdef.unflatten([n[0] for n in new])
        new_s = tdef.unflatten([n[1] for n in new])
        return new_p, {"step": step, "v": new_s}

    return Optimizer(init=init, update=update)


def get_optimizer(name: str, lr: float = 1e-3, **kw) -> Optimizer:
    if name == "adamw":
        return adamw(lr=lr, **kw)
    if name == "adafactor":
        return adafactor(lr=lr, **kw)
    raise ValueError(name)
