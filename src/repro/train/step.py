"""train_step factory: value_and_grad + microbatch accumulation + optimizer.

The returned function is pure (params, opt_state, batch) ->
(params, opt_state, metrics) and is meant to be jit'ed with in/out
shardings from repro.distributed.sharding. Gradient accumulation splits
the LOCAL batch axis into `grad_accum` microbatches and lax.scan's over
them (constant memory in the number of microbatches).

`grad_transform` is an optional hook applied to the gradient tree before
the optimizer — used for the two-level INT8-compressed cross-pod
all-reduce (repro.distributed.compression) and for global-norm clipping.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.train.optim import Optimizer


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def clip_by_global_norm(tree, max_norm: float):
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda l: (l * scale).astype(l.dtype), tree), norm


def make_train_step(loss_fn: Callable[[Any, Any], jax.Array],
                    optimizer: Optimizer, *, grad_accum: int = 1,
                    clip_norm: float | None = 1.0,
                    grad_transform: Callable | None = None):
    """loss_fn(params, batch) -> scalar. Returns train_step fn."""

    def one_grad(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            micro = jax.tree.map(
                lambda a: a.reshape(grad_accum, a.shape[0] // grad_accum,
                                    *a.shape[1:]), batch)

            def acc_step(carry, mb):
                loss_sum, gsum = carry
                loss, g = one_grad(params, mb)
                gsum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), gsum, g)
                return (loss_sum + loss, gsum), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss_sum, grads), _ = jax.lax.scan(
                acc_step, (jnp.zeros((), jnp.float32), zeros), micro)
            loss = loss_sum / grad_accum
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
        else:
            loss, grads = one_grad(params, batch)

        if grad_transform is not None:
            grads = grad_transform(grads)
        gnorm = jnp.zeros((), jnp.float32)
        if clip_norm is not None:
            grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, "grad_norm": gnorm}
        return params, opt_state, metrics

    return train_step
