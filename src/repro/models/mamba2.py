"""Mamba2 (SSD — state-space duality) in pure JAX.

Implements the chunked SSD algorithm (quadratic intra-chunk attention-like
form + linear inter-chunk state recurrence) for training/prefill, and the
O(1)-per-token recurrent form for decode. The chunked and recurrent paths
are numerically equivalent (tested).

Per-block dataflow (mamba_ssm reference layout, ngroups = 1):

    in_proj: d -> [z (d_in), xBC (d_in + 2n), dt (H)]
    causal depthwise conv(width w) + silu on xBC
    SSD over heads H = d_in / P with A = -exp(A_log) per head
    gated RMSNorm: norm(y * silu(z)); out_proj: d_in -> d

State for decode: ssm (B, H, P, N) f32 + conv tail (B, w-1, conv_dim).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import (ModelConfig, Params, constrain,
                                 cross_entropy_loss, dense_init, embed_init,
                                 residual_pattern, rmsnorm)


@dataclasses.dataclass
class SSMCache:
    state: jax.Array   # (L, B, H, P, N) f32
    conv: jax.Array    # (L, B, W-1, conv_dim)
    length: jax.Array  # (B,) int32


jax.tree_util.register_dataclass(
    SSMCache, data_fields=["state", "conv", "length"], meta_fields=[])


def conv_dim(cfg: ModelConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_block(cfg: ModelConfig, key) -> Params:
    d, din, n, h, w = (cfg.d_model, cfg.d_inner, cfg.ssm_state,
                       cfg.ssm_heads, cfg.ssm_conv_width)
    ks = jax.random.split(key, 4)
    dt = cfg.pdtype
    cd = conv_dim(cfg)
    return {
        "ln": jnp.ones((d,), dt),
        "in_proj": dense_init(ks[0], (d, 2 * din + 2 * n + h), dt),
        "conv_w": (jax.random.normal(ks[1], (w, cd), jnp.float32)
                   * (w * cd) ** -0.5).astype(dt),
        "conv_b": jnp.zeros((cd,), dt),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, h)).astype(jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "D": jnp.ones((h,), jnp.float32),
        "norm": jnp.ones((din,), dt),
        "out_proj": dense_init(ks[2], (din, d), dt, scale=din ** -0.5),
    }


def _split_in_proj(zxbcdt, cfg: ModelConfig):
    din, n, h = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = zxbcdt[..., :din]
    xbc = zxbcdt[..., din:2 * din + 2 * n]
    dt_raw = zxbcdt[..., 2 * din + 2 * n:]
    return z, xbc, dt_raw


def _causal_conv(xbc: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """Depthwise causal conv over sequence. xbc (B, L, C); w (W, C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1]] * w[i][None, None]
              for i in range(width))
    return jax.nn.silu(out + b[None, None].astype(out.dtype))


def _segsum(a: jax.Array) -> jax.Array:
    """a (..., T) -> (..., T, T) with S[i, j] = sum a[j+1..i] (j<=i), -inf above."""
    t = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    s = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((t, t), bool))
    return jnp.where(mask, s, -jnp.inf)


def ssd_chunked(x: jax.Array, a: jax.Array, b: jax.Array, c: jax.Array,
                chunk: int, initial_state: jax.Array | None = None):
    """Chunked SSD scan.

    x (B, L, H, P) — inputs ALREADY multiplied by dt;
    a (B, L, H)    — dt * A (negative decay log);
    b, c (B, L, N) — shared across heads (ngroups=1).
    Returns (y (B, L, H, P), final_state (B, H, P, N)); f32 math.
    """
    bs, l, h, p = x.shape
    n = b.shape[-1]
    assert l % chunk == 0, (l, chunk)
    nc = l // chunk
    x = x.astype(jnp.float32).reshape(bs, nc, chunk, h, p)
    a = a.astype(jnp.float32).reshape(bs, nc, chunk, h).transpose(0, 3, 1, 2)
    b = b.astype(jnp.float32).reshape(bs, nc, chunk, n)
    c = c.astype(jnp.float32).reshape(bs, nc, chunk, n)

    a_cs = jnp.cumsum(a, axis=-1)                         # (B, H, NC, Q)
    ldec = jnp.exp(_segsum(a))                            # (B, H, NC, Q, Q)
    # intra-chunk (quadratic) term
    y_diag = jnp.einsum("bcln,bcsn,bhcls,bcshp->bclhp", c, b, ldec, x)
    # per-chunk input -> end-of-chunk states
    decay_states = jnp.exp(a_cs[..., -1:] - a_cs)         # (B, H, NC, Q)
    states = jnp.einsum("bcsn,bhcs,bcshp->bchpn", b, decay_states, x)
    # inter-chunk recurrence
    chunk_decay = jnp.exp(a_cs[..., -1])                  # (B, H, NC)
    s0 = (jnp.zeros((bs, h, p, n), jnp.float32)
          if initial_state is None else initial_state.astype(jnp.float32))

    def step(prev, xs):
        st, dec = xs                                      # (B,H,P,N), (B,H)
        new = st + dec[..., None, None] * prev
        return new, prev                                  # emit state BEFORE chunk

    final, prev_states = jax.lax.scan(
        step, s0, (states.transpose(1, 0, 2, 3, 4),
                   chunk_decay.transpose(2, 0, 1)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)    # (B, NC, H, P, N)
    # contribution of carried-in state to each position
    state_decay = jnp.exp(a_cs)                           # (B, H, NC, Q)
    y_off = jnp.einsum("bcln,bchpn,bhcl->bclhp", c, prev_states, state_decay)
    y = (y_diag + y_off).reshape(bs, l, h, p)
    return y, final


def block_fwd(p: Params, x: jax.Array, cfg: ModelConfig,
              initial_state=None, conv_init=None):
    """Full-sequence mamba2 block. Returns (x_out, (final_state, conv_tail))."""
    h_heads, pdim, n, w = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                           cfg.ssm_conv_width)
    res = x
    xn = rmsnorm(x, p["ln"], cfg.norm_eps)
    zxbcdt = constrain(
        jnp.einsum("bld,de->ble", xn, p["in_proj"].astype(xn.dtype)),
        "dp", None, None)
    z, xbc, dt_raw = _split_in_proj(zxbcdt, cfg)
    if conv_init is not None:
        ext = jnp.concatenate([conv_init.astype(xbc.dtype), xbc], axis=1)
        xbc_c = _causal_conv(ext, p["conv_w"], p["conv_b"])[:, w - 1:]
    else:
        xbc_c = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xs = xbc_c[..., :cfg.d_inner]
    b_in = xbc_c[..., cfg.d_inner:cfg.d_inner + n]
    c_in = xbc_c[..., cfg.d_inner + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])
    a = -jnp.exp(p["A_log"])                              # (H,)
    xh = xs.reshape(*xs.shape[:2], h_heads, pdim)
    bs, l = xh.shape[0], xh.shape[1]
    chunk = min(cfg.ssm_chunk, l)
    if l % chunk:
        chunk = l                                         # tiny smoke shapes
    y, final = ssd_chunked(xh.astype(jnp.float32) * dt[..., None],
                           dt * a[None, None], b_in, c_in, chunk,
                           initial_state)
    y = y + p["D"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(bs, l, -1).astype(x.dtype)
    y = constrain(rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps),
                  "dp", None, "mp")
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(y.dtype))
    conv_tail = xbc[:, -(w - 1):] if l >= w - 1 else jnp.pad(
        xbc, ((0, 0), (w - 1 - l, 0), (0, 0)))
    return constrain(res + out, *residual_pattern(cfg)), (final, conv_tail)


def block_decode(p: Params, x: jax.Array, state: jax.Array,
                 conv_cache: jax.Array, cfg: ModelConfig):
    """One-token recurrent step. x (B, 1, D); state (B, H, P, N);
    conv_cache (B, W-1, conv_dim). Returns (x_out, new_state, new_conv)."""
    h_heads, pdim, n, w = (cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state,
                           cfg.ssm_conv_width)
    res = x
    xn = rmsnorm(x, p["ln"], cfg.norm_eps)
    zxbcdt = jnp.einsum("bld,de->ble", xn, p["in_proj"].astype(xn.dtype))
    z, xbc, dt_raw = _split_in_proj(zxbcdt, cfg)
    buf = jnp.concatenate([conv_cache.astype(xbc.dtype), xbc], axis=1)  # (B,W,C)
    conv_out = jnp.einsum("bwc,wc->bc", buf.astype(jnp.float32),
                          p["conv_w"].astype(jnp.float32))
    xbc_c = jax.nn.silu(conv_out + p["conv_b"].astype(jnp.float32))[:, None]
    new_conv = buf[:, 1:]
    xs = xbc_c[..., :cfg.d_inner]
    b_in = xbc_c[..., cfg.d_inner:cfg.d_inner + n][:, 0]   # (B, N)
    c_in = xbc_c[..., cfg.d_inner + n:][:, 0]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])[:, 0]  # (B,H)
    a = -jnp.exp(p["A_log"])
    xh = xs.reshape(xs.shape[0], h_heads, pdim).astype(jnp.float32)
    da = jnp.exp(dt * a[None])                             # (B, H)
    state = constrain(state * da[..., None, None] + jnp.einsum(
        "bh,bhp,bn->bhpn", dt, xh, b_in), "dp", "mp", None, None)
    y = jnp.einsum("bhpn,bn->bhp", state, c_in) + p["D"][None, :, None] * xh
    y = y.reshape(y.shape[0], 1, -1).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = jnp.einsum("ble,ed->bld", y, p["out_proj"].astype(y.dtype))
    return res + out, state, new_conv


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 3)
    sub = [init_block(cfg, jax.random.fold_in(ks[0], i))
           for i in range(cfg.num_layers)]
    blocks = jax.tree.map(lambda *a: jnp.stack(a), *sub)
    params = {
        "embed": embed_init(ks[1], (cfg.vocab_size, cfg.d_model), cfg.pdtype),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[2], (cfg.d_model, cfg.vocab_size),
                                       cfg.pdtype)
    return params


def _logits(params, x, cfg):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    return constrain(jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype)),
                     "dp", None, "mp")


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds=None) -> jax.Array:
    x = constrain(jnp.take(params["embed"], tokens, axis=0).astype(
        cfg.cdtype), "dp", None, None)

    def step(h, p):
        h2, _ = block_fwd(p, h, cfg)
        return h2, None

    fn = jax.checkpoint(step) if cfg.remat else step
    x, _ = jax.lax.scan(lambda c, p: fn(c, p), x, params["blocks"])
    return _logits(params, x, cfg)


def loss_fn(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    return cross_entropy_loss(forward(params, batch["tokens"], cfg),
                              batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int = 0) -> SSMCache:
    l, h, pd, n, w = (cfg.num_layers, cfg.ssm_heads, cfg.ssm_head_dim,
                      cfg.ssm_state, cfg.ssm_conv_width)
    return SSMCache(
        state=jnp.zeros((l, batch, h, pd, n), jnp.float32),
        conv=jnp.zeros((l, batch, w - 1, conv_dim(cfg)), cfg.cdtype),
        length=jnp.zeros((batch,), jnp.int32))


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
            max_len=None, lengths=None, prefix_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)

    def step(h, p):
        h2, (st, conv) = block_fwd(p, h, cfg)
        return h2, (st, conv)

    fn = jax.checkpoint(step) if cfg.remat else step
    x, (states, convs) = jax.lax.scan(lambda c, p: fn(c, p), x,
                                      params["blocks"])
    logits = _logits(params, x, cfg)
    b = tokens.shape[0]
    if lengths is None:
        lengths = jnp.full((b,), tokens.shape[1], jnp.int32)
    return logits, SSMCache(state=states, conv=convs, length=lengths)


def decode_step(params: Params, cache: SSMCache, tokens: jax.Array,
                cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)

    def step(h, xs):
        p, st, conv = xs
        h2, st2, conv2 = block_decode(p, h, st, conv, cfg)
        return h2, (st2, conv2)

    x, (states, convs) = jax.lax.scan(step, x,
                                      (params["blocks"], cache.state,
                                       cache.conv))
    return _logits(params, x, cfg), SSMCache(state=states, conv=convs,
                                             length=cache.length + 1)
