"""MiniLM-style sentence embedder (the paper's embedding model).

A small bidirectional transformer encoder + masked mean pooling + linear
projection to `pooled_dim` (512 in the paper) + L2 normalization — the
Sentence-BERT recipe with MiniLM-L6 dimensions. Produces the normalized
float embeddings that repro.core quantizes into the INT8 database, and is
trainable with an in-batch-negative contrastive (InfoNCE) loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (ModelConfig, Params, apply_rope, dense_init,
                                 embed_init, rmsnorm, rope_tables, swiglu)

MINILM_CFG = ModelConfig(
    name="minilm-embedder", family="dense", num_layers=6, d_model=384,
    num_heads=12, num_kv_heads=12, d_ff=1536, vocab_size=30522,
    pooled_dim=512, rope_theta=1e4, compute_dtype="float32", remat=False)


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    l, d, h, hd, f = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.hd,
                      cfg.d_ff)
    ks = jax.random.split(key, 10)
    dt = cfg.pdtype
    return {
        "embed": embed_init(ks[0], (cfg.vocab_size, d), dt),
        "blocks": {
            "ln1": jnp.ones((l, d), dt),
            "wq": dense_init(ks[1], (l, d, h * hd), dt),
            "wk": dense_init(ks[2], (l, d, h * hd), dt),
            "wv": dense_init(ks[3], (l, d, h * hd), dt),
            "wo": dense_init(ks[4], (l, h * hd, d), dt, scale=(h * hd) ** -0.5),
            "ln2": jnp.ones((l, d), dt),
            "w_gate": dense_init(ks[5], (l, d, f), dt),
            "w_up": dense_init(ks[6], (l, d, f), dt),
            "w_down": dense_init(ks[7], (l, f, d), dt, scale=f ** -0.5),
        },
        "final_norm": jnp.ones((d,), dt),
        "proj": dense_init(ks[8], (d, cfg.pooled_dim), dt),
    }


def encode(params: Params, tokens: jax.Array, cfg: ModelConfig,
           mask: jax.Array | None = None) -> jax.Array:
    """tokens (B, S) [+ mask (B, S) bool] -> L2-normalized (B, pooled_dim)."""
    if mask is None:
        mask = jnp.ones(tokens.shape, bool)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    s = x.shape[1]
    cos, sin = rope_tables(jnp.arange(s, dtype=jnp.int32), cfg.hd,
                           cfg.rope_theta)

    def block(h, p):
        hn = rmsnorm(h, p["ln1"], cfg.norm_eps)
        b = h.shape[0]
        q = jnp.einsum("bsd,de->bse", hn, p["wq"].astype(h.dtype)
                       ).reshape(b, s, cfg.num_heads, cfg.hd)
        k = jnp.einsum("bsd,de->bse", hn, p["wk"].astype(h.dtype)
                       ).reshape(b, s, cfg.num_heads, cfg.hd)
        v = jnp.einsum("bsd,de->bse", hn, p["wv"].astype(h.dtype)
                       ).reshape(b, s, cfg.num_heads, cfg.hd)
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        o = attn.naive_attention(q, k, v, causal=False)
        h = h + jnp.einsum("bse,ed->bsd", o.reshape(b, s, -1),
                           p["wo"].astype(h.dtype))
        hn = rmsnorm(h, p["ln2"], cfg.norm_eps)
        return h + swiglu(hn, p["w_gate"], p["w_up"], p["w_down"]), None

    x, _ = jax.lax.scan(block, x, params["blocks"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    m = mask.astype(jnp.float32)[..., None]
    pooled = jnp.sum(x.astype(jnp.float32) * m, axis=1) / jnp.maximum(
        jnp.sum(m, axis=1), 1.0)
    emb = pooled @ params["proj"].astype(jnp.float32)
    return emb / jnp.maximum(jnp.linalg.norm(emb, axis=-1, keepdims=True),
                             1e-9)


def info_nce_loss(params: Params, batch: dict, cfg: ModelConfig,
                  temperature: float = 0.05) -> jax.Array:
    """In-batch-negative contrastive loss over (query, positive-doc) pairs."""
    q = encode(params, batch["query_tokens"], cfg, batch.get("query_mask"))
    d = encode(params, batch["doc_tokens"], cfg, batch.get("doc_mask"))
    logits = (q @ d.T) / temperature                  # (B, B)
    labels = jnp.arange(q.shape[0])
    logz = jax.nn.logsumexp(logits, axis=-1)
    return jnp.mean(logz - logits[labels, labels])
