"""Attention: GQA with naive, chunked-causal (flash-style), and decode paths.

The chunked path never materializes the (S, S) score matrix: a static
Python loop walks query chunks; for query chunk i only key chunks 0..i are
touched (a STATIC slice — the compiled HLO does strictly causal work, no
masked-out upper-triangle FLOPs), with an online-softmax scan over key
chunks. Softmax statistics are f32; dots run in the compute dtype.

Shapes: q (B, S, H, hd); k, v (B, T, KH, hd); GQA groups G = H // KH.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


NEG_INF = -1e30


def _split_groups(q: jax.Array, kh: int) -> jax.Array:
    """(B, S, H, hd) -> (B, S, KH, G, hd)."""
    b, s, h, d = q.shape
    return q.reshape(b, s, kh, h // kh, d)


def naive_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    scale: float | None = None,
                    causal: bool = True) -> jax.Array:
    """Reference O(S^2)-memory masked attention (tests / tiny shapes)."""
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    scale = scale or hd ** -0.5
    qg = _split_groups(q, kh)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, t), bool))
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32))
    return out.reshape(b, s, h, hd).astype(q.dtype)


def naive_causal_attention(q, k, v, scale=None):
    return naive_attention(q, k, v, scale, causal=True)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      chunk: int = 2048, scale: float | None = None,
                      causal: bool = True) -> jax.Array:
    """Flash-style attention; never materializes the (S, T) score matrix.

    Causal: query chunk i touches only key chunks 0..i (static slice — no
    masked-out upper-triangle FLOPs in the compiled HLO). Non-causal
    (encoder): every query chunk scans all key chunks. S (and T) must be
    multiples of chunk, else the naive path is used."""
    b, s, h, hd = q.shape
    t, kh = k.shape[1], k.shape[2]
    if s <= chunk or s % chunk != 0 or t % chunk != 0:
        return naive_attention(q, k, v, scale, causal)
    nq = s // chunk
    nk = t // chunk
    scale = scale or hd ** -0.5
    qg = _split_groups(q, kh)                                  # (B,S,KH,G,hd)
    pos = jnp.arange(chunk, dtype=jnp.int32)

    def kv_step(carry, xs):
        acc, m, denom, qc = carry                              # qc (B,KH,G,C,hd)
        kc, vc, diag = xs                                      # (B,C,KH,hd)
        srs = jnp.einsum("bkgcd,btkd->bkgct", qc.astype(jnp.float32),
                         kc.astype(jnp.float32)) * scale       # (B,KH,G,C,C)
        srs = jnp.where(diag & (pos[None, :] > pos[:, None])[None, None, None],
                        NEG_INF, srs)
        new_m = jnp.maximum(m, jnp.max(srs, axis=-1))
        p = jnp.exp(srs - new_m[..., None])
        alpha = jnp.exp(m - new_m)
        denom = denom * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + jnp.einsum(
            "bkgct,btkd->bkgcd", p, vc.astype(jnp.float32))
        return (acc, new_m, denom, qc), None

    outs = []
    for i in range(nq):                                        # static loop
        # NOTE (§Perf A2, refuted hypothesis): pinning qc/ks/vs/acc to the
        # batch axes here ADDED 0.7-1.6 TB/step of resharding all-gathers
        # (train AND prefill) with no FLOP benefit — GSPMD already
        # propagates the batch sharding through this scan. All pins
        # removed; measurements in EXPERIMENTS.md §Perf.
        qc = jnp.moveaxis(qg[:, i * chunk:(i + 1) * chunk], 1, 3)
        n_kv = (i + 1) if causal else nk
        ks = k[:, :n_kv * chunk].reshape(b, n_kv, chunk, kh, hd)
        vs = v[:, :n_kv * chunk].reshape(b, n_kv, chunk, kh, hd)
        diag = (jnp.arange(n_kv) == i) if causal else jnp.zeros((n_kv,), bool)
        acc0 = jnp.zeros((b, kh, h // kh, chunk, hd), jnp.float32)
        m0 = jnp.full((b, kh, h // kh, chunk), NEG_INF, jnp.float32)
        d0 = jnp.zeros((b, kh, h // kh, chunk), jnp.float32)
        (acc, _, denom, _), _ = jax.lax.scan(
            kv_step, (acc0, m0, d0, qc),
            (jnp.moveaxis(ks, 1, 0), jnp.moveaxis(vs, 1, 0), diag))
        outs.append(acc / jnp.maximum(denom[..., None], 1e-30))
    out = jnp.concatenate(outs, axis=3)                        # (B,KH,G,S,hd)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, hd).astype(q.dtype)


def chunked_causal_attention(q, k, v, chunk: int = 2048, scale=None):
    return chunked_attention(q, k, v, chunk, scale, causal=True)


def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     length: jax.Array,
                     scale: float | None = None) -> jax.Array:
    """One-token attention against a (possibly partially filled) KV cache.

    q: (B, 1, H, hd); k_cache/v_cache: (B, T, KH, hd); length: () or (B,)
    int32 count of valid cache positions (new token already written).
    """
    b, _, h, hd = q.shape
    t, kh = k_cache.shape[1], k_cache.shape[2]
    scale = scale or hd ** -0.5
    qg = _split_groups(q, kh)[:, 0]                            # (B,KH,G,hd)
    scores = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(t, dtype=jnp.int32)[None, :] < jnp.reshape(
        length, (-1, 1)).astype(jnp.int32)                     # (B or 1, T)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgt,btkd->bkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, h, hd).astype(q.dtype)
