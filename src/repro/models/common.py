"""Shared model-definition substrate: config, layers, losses, init.

Pure-JAX (no flax): parameters are nested dicts of jnp arrays; repeated
transformer blocks keep their parameters STACKED along a leading layer
axis so the forward pass can lax.scan over layers (small HLO, fast
compiles at 95 layers, remat-friendly).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Literal

import jax
import jax.numpy as jnp

Params = Any  # nested dict pytree of jnp arrays


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Literal["dense", "moe", "ssm", "hybrid", "encdec", "vlm"]
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0              # 0 -> d_model // num_heads
    qkv_bias: bool = False         # qwen2-style QKV bias
    # --- MoE ---
    num_experts: int = 0
    moe_top_k: int = 1
    moe_layer_period: int = 1      # 1 = every layer MoE; 2 = interleaved
    shared_expert: bool = False
    capacity_factor: float = 1.25
    # --- SSM (Mamba2 / SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    # --- hybrid (Zamba2) ---
    hybrid_attn_period: int = 0    # shared attn block after every k SSM layers
    # --- enc-dec ---
    encoder_layers: int = 0
    # --- frontends (VLM / audio): stubbed embeddings prepended/encoded ---
    num_prefix_embeds: int = 0     # VLM: image patch embeddings per sample
    frontend_dim: int = 0          # embedding dim delivered by the stub
    # --- numerics / misc ---
    rope_theta: float = 1e6
    norm_eps: float = 1e-5
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"
    attn_chunk: int = 2048         # flash-attention block size
    remat: bool = True
    scan_layers: bool = True
    seq_shard: bool = False        # Megatron-SP: residuals S-sharded on TP
    optimizer: Literal["adamw", "adafactor"] = "adamw"
    tie_embeddings: bool = False
    # embedder head (MiniLM-style sentence encoder)
    pooled_dim: int = 0            # >0: mean-pool + project to this dim

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.num_heads

    @property
    def q_group(self) -> int:
        return self.num_heads // self.num_kv_heads

    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    @property
    def pdtype(self):
        return jnp.dtype(self.param_dtype)

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------

def dense_init(key, shape, dtype, *, scale: float | None = None):
    """Truncated-normal fan-in init (matches common LM practice)."""
    fan_in = shape[-2] if len(shape) >= 2 else shape[-1]
    std = scale if scale is not None else fan_in ** -0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


def embed_init(key, shape, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Layers
# ---------------------------------------------------------------------------

def rmsnorm(x: jax.Array, gamma: jax.Array, eps: float) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dt) * gamma.astype(dt)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate.astype(x.dtype))
    u = jnp.einsum("...d,df->...f", x, w_up.astype(x.dtype))
    h = jax.nn.silu(g) * u
    if h.ndim == 3:                       # (B, S, F): TP-shard the hidden
        h = constrain(h, "dp", None, "mp")
    return jnp.einsum("...f,fd->...d", h, w_down.astype(x.dtype))


def rope_tables(positions: jax.Array, head_dim: int,
                theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (...,S) int32 -> cos/sin tables (...,S, head_dim//2) f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, half) or (S, half).

    f32 rotation with a downcast at the boundary. (A bf16-rotation variant
    was tried for §Perf A1 on the hypothesis that the f32 upcast made the
    attention-input cotangents f32 before their TP all-reduce — REFUTED:
    the f32 all-reduces come from the CPU backend upcasting bf16 dot
    outputs, and the bf16 rope instead ADDED ~690 GB of resharding
    all-gathers. Reverted; see EXPERIMENTS.md.)
    """
    dt = x.dtype
    x = x.astype(jnp.float32)
    x1, x2 = jnp.split(x, 2, axis=-1)
    if cos.ndim == 2:
        cos, sin = cos[None, :, None, :], sin[None, :, None, :]
    else:
        cos, sin = cos[:, :, None, :], sin[:, :, None, :]
    return jnp.concatenate([x1 * cos - x2 * sin,
                            x1 * sin + x2 * cos], axis=-1).astype(dt)


def cross_entropy_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Mean CE over positions with label >= 0 (negative = padding).

    logits (..., V) any float dtype (upcast to f32); labels (...) int32.
    """
    logits = logits.astype(jnp.float32)
    logz = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = (labels >= 0).astype(jnp.float32)
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)


def param_count(params: Params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def residual_pattern(cfg) -> tuple:
    """Sharding pins for the (B, S, D) residual stream: plain TP keeps it
    batch-sharded only; Megatron-SP (cfg.seq_shard) also shards S over the
    model axis between blocks — TP output all-reduces become
    reduce-scatters and activation memory drops TPx (§Perf A2)."""
    return ("dp", "mp", None) if cfg.seq_shard else ("dp", None, None)


# ---------------------------------------------------------------------------
# Activation sharding constraints (GSPMD hints)
# ---------------------------------------------------------------------------

def constrain(x: jax.Array, *pattern: str | None) -> jax.Array:
    """Pin an activation's sharding: pattern entries are 'dp' (batch axes),
    'mp' (model axis), or None, one per dim.

    No-op outside a `jax.set_mesh` context (tests, single-device runs).
    Every entry is divisibility-guarded so the same model code serves all
    architectures (e.g. qwen2's 14 heads simply skip the 'mp' pin). These
    pins are what keep GSPMD's propagation in the Megatron-style plan —
    weights get all-gathered, activations stay batch/TP-sharded — instead
    of all-reducing full attention-score tensors (see EXPERIMENTS.md).
    """
    from jax.sharding import PartitionSpec  # local: avoid cycles

    from repro.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return x
    names = mesh.axis_names
    mp = "model" if "model" in names else None
    dp = tuple(n for n in names if n != "model")
    spec = []
    used = set()
    for dim, want in enumerate(pattern):
        d = x.shape[dim] if dim < x.ndim else 0
        if want == "dp" and "dp" not in used and dp:
            size = 1
            for a in dp:
                size *= mesh.shape[a]
            if d % size == 0 and d > 0:
                spec.append(dp if len(dp) > 1 else dp[0])
                used.add("dp")
                continue
        if want == "mp" and "mp" not in used and mp:
            if d % mesh.shape[mp] == 0 and d > 0:
                spec.append(mp)
                used.add("mp")
                continue
        spec.append(None)
    return jax.lax.with_sharding_constraint(x, PartitionSpec(*spec))


def constrain_kv(kc: jax.Array) -> jax.Array:
    """KV-cache slice (B, T, KH, hd): B->dp; KH->mp when divisible, else
    T->mp (context-parallel decode)."""
    from jax.sharding import PartitionSpec

    from repro.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty or kc.ndim != 4:
        return kc
    names = mesh.axis_names
    mp = "model" if "model" in names else None
    dp = tuple(n for n in names if n != "model")
    b, t, kh, _ = kc.shape
    dsz = 1
    for a in dp:
        dsz *= mesh.shape[a]
    bspec = (dp if len(dp) > 1 else dp[0]) if (dp and b % dsz == 0) else None
    if mp and kh % mesh.shape[mp] == 0:
        spec = PartitionSpec(bspec, None, mp, None)
    elif mp and t % mesh.shape[mp] == 0:
        spec = PartitionSpec(bspec, mp, None, None)
    else:
        spec = PartitionSpec(bspec, None, None, None)
    return jax.lax.with_sharding_constraint(kc, spec)
