"""Mixture-of-Experts transformer (llama4-style: top-1 routed + shared expert).

Deterministic-shape capacity-based dispatch (required under jit/pjit):
tokens pick their top-1 expert; each expert has capacity
ceil(tokens/E * capacity_factor); overflow tokens fall back to the residual
(and the shared expert). Dispatch/combine use scatter-add / gather with a
sacrificial overflow slot — no (tokens, E, capacity) one-hot tensor is ever
materialized, so dispatch costs O(tokens * d_model), not
O(tokens * E * capacity).

Expert weights are stacked (E, D, F) and shard over the `model` mesh axis
on E (expert parallelism); the scatter/gather becomes an all-to-all under
GSPMD. `moe_layer_period = k` makes every k-th layer MoE (maverick: 2,
interleaved; scout: 1, every layer); the scan unit is a superblock of
(k-1) dense layers + 1 MoE layer. Attention params are stacked for ALL
layers; dense-FFN params exist only for the dense sub-layers.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import dense
from repro.models.common import (ModelConfig, Params, apply_rope, constrain,
                                 cross_entropy_loss, dense_init,
                                 residual_pattern, rmsnorm, rope_tables,
                                 swiglu)

_FFN_KEYS = ("w_gate", "w_up", "w_down")


def _capacity(num_tokens: int, cfg: ModelConfig) -> int:
    return max(1, math.ceil(num_tokens / cfg.num_experts * cfg.capacity_factor))


def init_moe_ffn(cfg: ModelConfig, key) -> Params:
    e, d, f = cfg.num_experts, cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 7)
    dt = cfg.pdtype
    p = {
        "router": dense_init(ks[0], (d, e), dt, scale=d ** -0.5),
        "w_gate": dense_init(ks[1], (e, d, f), dt),
        "w_up": dense_init(ks[2], (e, d, f), dt),
        "w_down": dense_init(ks[3], (e, f, d), dt, scale=f ** -0.5),
    }
    if cfg.shared_expert:
        p["sh_gate"] = dense_init(ks[4], (d, f), dt)
        p["sh_up"] = dense_init(ks[5], (d, f), dt)
        p["sh_down"] = dense_init(ks[6], (f, d), dt, scale=f ** -0.5)
    return p


def _dp_shards() -> int:
    """Number of batch-axis shards in the ambient mesh (1 outside set_mesh)."""
    from repro.compat import get_abstract_mesh
    mesh = get_abstract_mesh()
    if mesh is None or mesh.empty:
        return 1
    n = 1
    for a in mesh.axis_names:
        if a != "model":
            n *= mesh.shape[a]
    return n


def moe_ffn(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """x (B, S, D) -> (B, S, D). Top-1 routing with capacity dropping.

    SHARD-ALIGNED hierarchical dispatch (§Perf B2): on a mesh with `ns`
    batch shards, capacity is enforced PER SHARD (standard large-scale
    practice) and tokens from batch shard i receive slots in the i-th
    capacity block, so the capacity dim of the expert buffer shards
    exactly along the batch axes: the scatter/gather stays local and only
    the expert dim crosses shards (the EP exchange). Without the
    alignment, GSPMD replicated the full global expert buffer per layer
    (~2 TB/step of all-gather+all-reduce on llama4 prefill_32k).
    """
    b, s, d = x.shape
    nt = b * s
    e = cfg.num_experts
    xt = x.reshape(nt, d)

    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    eidx = jnp.argmax(probs, axis=-1)                    # (nt,) top-1 expert
    gate = jnp.max(probs, axis=-1)                       # (nt,) router weight

    onehot = jax.nn.one_hot(eidx, e, dtype=jnp.int32)    # (nt, E)
    ns = _dp_shards()
    if ns > 1 and nt % ns == 0:
        ntl = nt // ns
        cap_l = _capacity(ntl, cfg)
        cap = ns * cap_l
        oh = onehot.reshape(ns, ntl, e)
        pos_b = jnp.cumsum(oh, axis=1) - oh              # per-shard position
        pos_in_e = jnp.sum(pos_b * oh, axis=-1)          # (ns, ntl)
        keep = (pos_in_e < cap_l).reshape(nt)
        blk = jnp.arange(ns, dtype=jnp.int32)[:, None]
        slot = (blk * cap_l + jnp.minimum(pos_in_e, cap_l)).reshape(nt)
        slot = jnp.where(keep, slot, cap)
    else:
        cap = _capacity(nt, cfg)
        pos = jnp.cumsum(onehot, axis=0) - onehot
        pos_in_e = jnp.sum(pos * onehot, axis=-1)        # (nt,)
        keep = pos_in_e < cap
        slot = jnp.where(keep, pos_in_e, cap)

    # scatter into (E, cap+1, D); slot `cap` swallows overflow
    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[eidx, slot].add(xt)
    # (E, cap, D): experts over `model` (EP). Pinning capacity to the
    # batch axes as well ("mp","dp",None) cuts the expert-FFN FLOPs 4.4x
    # (each EP shard otherwise runs the full global capacity), but GSPMD
    # cannot see that the aligned scatter is shard-local and replicates
    # the token buffer instead (+6.8x collective bytes — measured, §Perf
    # B2/B3). Until the dispatch is expressed as an explicit shard_map
    # all-to-all, the mp-only pin is the better operating point.
    buf = constrain(buf[:, :cap], "mp", None, None)
    g = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(x.dtype))
    u = jnp.einsum("ecd,edf->ecf", buf, p["w_up"].astype(x.dtype))
    y = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u,
                   p["w_down"].astype(x.dtype))          # (E, cap, D)
    y = constrain(y, "mp", None, None)

    out = y[eidx, jnp.minimum(slot, cap - 1)]            # (nt, D)
    out = out * (gate * keep).astype(x.dtype)[:, None]
    if cfg.shared_expert:
        out = out + swiglu(xt, p["sh_gate"], p["sh_up"], p["sh_down"])
    return out.reshape(b, s, d)


def aux_load_balance_loss(p: Params, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Switch-style load-balancing auxiliary loss (fraction * prob per expert)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d).astype(jnp.float32)
    probs = jax.nn.softmax(xt @ p["router"].astype(jnp.float32), axis=-1)
    eidx = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(eidx, cfg.num_experts, dtype=jnp.float32),
                    axis=0)
    mean_prob = jnp.mean(probs, axis=0)
    return cfg.num_experts * jnp.sum(frac * mean_prob)


# ---------------------------------------------------------------------------
# Full model: superblock = (period-1) dense layers + 1 MoE layer
# ---------------------------------------------------------------------------

def _num_superblocks(cfg: ModelConfig) -> int:
    assert cfg.num_layers % cfg.moe_layer_period == 0
    return cfg.num_layers // cfg.moe_layer_period


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    sb = _num_superblocks(cfg)
    period = cfg.moe_layer_period
    ks = jax.random.split(key, 3)

    full = dense.init_params(cfg, ks[0])
    blocks = full["blocks"]
    attn_blocks = {k: v for k, v in blocks.items() if k not in _FFN_KEYS}
    if period > 1:
        dense_ffn = {
            k: blocks[k].reshape(sb, period, *blocks[k].shape[1:])[:, :period - 1]
            for k in _FFN_KEYS}
    else:
        dense_ffn = {}

    moe_sub = [init_moe_ffn(cfg, jax.random.fold_in(ks[1], i))
               for i in range(sb)]
    moe_p = jax.tree.map(lambda *a: jnp.stack(a), *moe_sub)
    out = {"embed": full["embed"], "blocks": attn_blocks,
           "dense_ffn": dense_ffn, "moe": moe_p,
           "final_norm": full["final_norm"]}
    if "lm_head" in full:
        out["lm_head"] = full["lm_head"]
    return out


def _group_params(params, cfg: ModelConfig):
    sb = _num_superblocks(cfg)
    period = cfg.moe_layer_period
    blocks = jax.tree.map(
        lambda a: a.reshape(sb, period, *a.shape[1:]), params["blocks"])
    return blocks, params["dense_ffn"], params["moe"], sb, period


def _moe_attn_ffn(bp, mp, x, cos, sin, cfg: ModelConfig):
    """Attention + MoE FFN. bp has attention params only."""
    hn = rmsnorm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = dense._qkv(bp, hn, cfg)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = attn.chunked_causal_attention(q, k, v, cfg.attn_chunk)
    o = jnp.einsum("bse,ed->bsd", o.reshape(*o.shape[:2], -1),
                   bp["wo"].astype(x.dtype))
    x = constrain(x + o, *residual_pattern(cfg))
    hn = rmsnorm(x, bp["ln2"], cfg.norm_eps)
    x = constrain(x + moe_ffn(mp, hn, cfg), *residual_pattern(cfg))
    return x, (k, v)


def _run(params, x, cfg: ModelConfig, collect_kv: bool):
    s = x.shape[1]
    cos, sin = rope_tables(jnp.arange(s, dtype=jnp.int32), cfg.hd,
                           cfg.rope_theta)
    blocks, dense_ffn, moe_p, sb, period = _group_params(params, cfg)

    def superblock(h, xs):
        bp, fp, mp = xs
        kvs = []
        for j in range(period - 1):
            sub = jax.tree.map(lambda a: a[j], bp)
            sub.update(jax.tree.map(lambda a: a[j], fp))
            h, kv = dense.block_fwd(sub, h, cos, sin, cfg)
            kvs.append(kv)
        sub = jax.tree.map(lambda a: a[period - 1], bp)
        h, kv = _moe_attn_ffn(sub, mp, h, cos, sin, cfg)
        kvs.append(kv)
        if not collect_kv:
            return h, None
        return h, (jnp.stack([k for k, _ in kvs]),
                   jnp.stack([v for _, v in kvs]))

    fn = jax.checkpoint(superblock) if cfg.remat else superblock
    return jax.lax.scan(fn, x, (blocks, dense_ffn, moe_p))


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds=None) -> jax.Array:
    x = dense.embed_tokens(params, tokens, cfg, prefix_embeds)
    x, _ = _run(params, x, cfg, collect_kv=False)
    return dense._logits(params, x, cfg)


def loss_fn(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    logits = forward(params, batch["tokens"], cfg, batch.get("prefix_embeds"))
    return cross_entropy_loss(logits, batch["labels"])


init_cache = dense.init_cache


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
            max_len: int | None = None, lengths=None, prefix_embeds=None):
    x = dense.embed_tokens(params, tokens, cfg, prefix_embeds)
    b, s = x.shape[0], x.shape[1]
    x, (ks, vs) = _run(params, x, cfg, collect_kv=True)
    ks = ks.reshape(cfg.num_layers, *ks.shape[2:])
    vs = vs.reshape(cfg.num_layers, *vs.shape[2:])
    logits = dense._logits(params, x, cfg)
    t = max_len or s
    if t > s:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, t - s), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, t - s), (0, 0), (0, 0)))
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    return logits, dense.KVCache(k=ks, v=vs, length=lengths)


def _moe_attn_ffn_decode(bp, mp, x, kc, vc, length, cos, sin, cfg):
    hn = rmsnorm(x, bp["ln1"], cfg.norm_eps)
    q, k, v = dense._qkv(bp, hn, cfg)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    idx = (length - 1).astype(jnp.int32)
    rows = jnp.arange(x.shape[0])
    kc = kc.at[rows, idx].set(k[:, 0])       # scatter: touches B rows only
    vc = vc.at[rows, idx].set(v[:, 0])
    o = attn.decode_attention(q, kc, vc, length)
    o = jnp.einsum("bse,ed->bsd", o.reshape(x.shape[0], 1, -1),
                   bp["wo"].astype(x.dtype))
    x = x + o
    hn = rmsnorm(x, bp["ln2"], cfg.norm_eps)
    x = x + moe_ffn(mp, hn, cfg)
    return x, kc, vc


def decode_step(params: Params, cache: dense.KVCache, tokens: jax.Array,
                cfg: ModelConfig):
    x = dense.embed_tokens(params, tokens, cfg)
    length = cache.length + 1
    pos = (length - 1).astype(jnp.int32)[:, None]
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta)
    blocks, dense_ffn, moe_p, sb, period = _group_params(params, cfg)
    def reshape(a):
        return a.reshape(sb, period, *a.shape[1:])
    kcs, vcs = reshape(cache.k), reshape(cache.v)

    def superblock(h, xs):
        bp, fp, mp, kc, vc = xs
        nks, nvs = [], []
        for j in range(period - 1):
            sub = jax.tree.map(lambda a: a[j], bp)
            sub.update(jax.tree.map(lambda a: a[j], fp))
            h, nk, nv = dense.block_decode(sub, h, kc[j], vc[j], length,
                                           cos, sin, cfg)
            nks.append(nk)
            nvs.append(nv)
        sub = jax.tree.map(lambda a: a[period - 1], bp)
        h, nk, nv = _moe_attn_ffn_decode(sub, mp, h, kc[period - 1],
                                         vc[period - 1], length, cos, sin, cfg)
        nks.append(nk)
        nvs.append(nv)
        return h, (jnp.stack(nks), jnp.stack(nvs))

    x, (ks, vs) = jax.lax.scan(superblock, x,
                               (blocks, dense_ffn, moe_p, kcs, vcs))
    ks = ks.reshape(cfg.num_layers, *ks.shape[2:])
    vs = vs.reshape(cfg.num_layers, *vs.shape[2:])
    return dense._logits(params, x, cfg), dense.KVCache(k=ks, v=vs,
                                                        length=length)
