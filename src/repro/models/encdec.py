"""Encoder-decoder transformer (seamless-m4t-medium backbone).

The speech/text frontend is a STUB per the assignment: `input_specs()`
delivers precomputed frame embeddings (B, S_src, d_model) for the encoder.
Encoder: bidirectional GQA blocks. Decoder: causal self-attention +
cross-attention to the encoder output + SwiGLU FFN.

At serving time the encoder runs once during prefill; per-layer cross K/V
are cached (they never change during decode), and the decoder self-KV
cache grows per step.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (ModelConfig, Params, apply_rope, constrain,
                                 cross_entropy_loss, dense_init, embed_init,
                                 rmsnorm, rope_tables, swiglu)


@dataclasses.dataclass
class EncDecCache:
    self_k: jax.Array   # (Ld, B, T, KH, hd)
    self_v: jax.Array
    cross_k: jax.Array  # (Ld, B, S_src, KH, hd)
    cross_v: jax.Array
    length: jax.Array   # (B,) decoder positions filled


jax.tree_util.register_dataclass(
    EncDecCache,
    data_fields=["self_k", "self_v", "cross_k", "cross_v", "length"],
    meta_fields=[])


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    l, d, h, kh, hd, f, v = (cfg.num_layers, cfg.d_model, cfg.num_heads,
                             cfg.num_kv_heads, cfg.hd, cfg.d_ff,
                             cfg.vocab_size)
    le = cfg.encoder_layers or l
    ks = jax.random.split(key, 16)
    dt = cfg.pdtype

    def attn_mlp(key, n):
        k = jax.random.split(key, 8)
        return {
            "ln1": jnp.ones((n, d), dt),
            "wq": dense_init(k[0], (n, d, h * hd), dt),
            "wk": dense_init(k[1], (n, d, kh * hd), dt),
            "wv": dense_init(k[2], (n, d, kh * hd), dt),
            "wo": dense_init(k[3], (n, h * hd, d), dt, scale=(h * hd) ** -0.5),
            "ln2": jnp.ones((n, d), dt),
            "w_gate": dense_init(k[4], (n, d, f), dt),
            "w_up": dense_init(k[5], (n, d, f), dt),
            "w_down": dense_init(k[6], (n, f, d), dt, scale=f ** -0.5),
        }

    dec = attn_mlp(ks[0], l)
    k2 = jax.random.split(ks[1], 5)
    dec.update({
        "lnx": jnp.ones((l, d), dt),
        "xwq": dense_init(k2[0], (l, d, h * hd), dt),
        "xwk": dense_init(k2[1], (l, d, kh * hd), dt),
        "xwv": dense_init(k2[2], (l, d, kh * hd), dt),
        "xwo": dense_init(k2[3], (l, h * hd, d), dt, scale=(h * hd) ** -0.5),
    })
    return {
        "enc_blocks": attn_mlp(ks[2], le),
        "dec_blocks": dec,
        "embed": embed_init(ks[3], (v, d), dt),
        "enc_norm": jnp.ones((d,), dt),
        "final_norm": jnp.ones((d,), dt),
        "lm_head": dense_init(ks[4], (d, v), dt),
    }


def _proj_kv(p, x, cfg, prefix):
    b, s, _ = x.shape
    kh, hd = cfg.num_kv_heads, cfg.hd
    k = jnp.einsum("bsd,de->bse", x, p[prefix + "wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", x, p[prefix + "wv"].astype(x.dtype))
    return (constrain(k.reshape(b, s, kh, hd), "dp", None, "mp", None),
            constrain(v.reshape(b, s, kh, hd), "dp", None, "mp", None))


def _proj_q(p, x, cfg, prefix):
    b, s, _ = x.shape
    q = jnp.einsum("bsd,de->bse", x, p[prefix + "wq"].astype(x.dtype)
                   ).reshape(b, s, cfg.num_heads, cfg.hd)
    return constrain(q, "dp", None, "mp", None)


def encode(params: Params, frames: jax.Array, cfg: ModelConfig) -> jax.Array:
    """frames (B, S_src, D) stub embeddings -> encoder states (B, S_src, D)."""
    x = constrain(frames.astype(cfg.cdtype), "dp", None, None)
    s = x.shape[1]
    cos, sin = rope_tables(jnp.arange(s, dtype=jnp.int32), cfg.hd,
                           cfg.rope_theta)

    def block(h, p):
        hn = rmsnorm(h, p["ln1"], cfg.norm_eps)
        q = _proj_q(p, hn, cfg, "")
        k, v = _proj_kv(p, hn, cfg, "")
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        o = attn.chunked_attention(q, k, v, cfg.attn_chunk, causal=False)
        h = h + jnp.einsum("bse,ed->bsd", o.reshape(*o.shape[:2], -1),
                           p["wo"].astype(h.dtype))
        hn = rmsnorm(h, p["ln2"], cfg.norm_eps)
        return h + swiglu(hn, p["w_gate"], p["w_up"], p["w_down"]), None

    fn = jax.checkpoint(block) if cfg.remat else block
    x, _ = jax.lax.scan(fn, x, params["enc_blocks"])
    return rmsnorm(x, params["enc_norm"], cfg.norm_eps)


def _dec_block_fwd(p, x, enc, cos, sin, cfg):
    """Training/prefill decoder block. Returns (x, (k, v, xk, xv))."""
    hn = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q = _proj_q(p, hn, cfg, "")
    k, v = _proj_kv(p, hn, cfg, "")
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
    o = attn.chunked_attention(q, k, v, cfg.attn_chunk, causal=True)
    x = x + jnp.einsum("bse,ed->bsd", o.reshape(*o.shape[:2], -1),
                       p["wo"].astype(x.dtype))
    hn = rmsnorm(x, p["lnx"], cfg.norm_eps)
    xq = _proj_q(p, hn, cfg, "x")
    xk, xv = _proj_kv(p, enc, cfg, "x")
    o = attn.chunked_attention(xq, xk, xv, cfg.attn_chunk, causal=False)
    x = x + jnp.einsum("bse,ed->bsd", o.reshape(*o.shape[:2], -1),
                       p["xwo"].astype(x.dtype))
    hn = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + swiglu(hn, p["w_gate"], p["w_up"], p["w_down"])
    return x, (k, v, xk, xv)


def forward(params: Params, frames: jax.Array, tokens: jax.Array,
            cfg: ModelConfig) -> jax.Array:
    """Teacher-forcing decoder logits (B, S_tgt, V)."""
    enc = encode(params, frames, cfg)
    x = constrain(jnp.take(params["embed"], tokens, axis=0).astype(
        cfg.cdtype), "dp", None, None)
    s = x.shape[1]
    cos, sin = rope_tables(jnp.arange(s, dtype=jnp.int32), cfg.hd,
                           cfg.rope_theta)

    def block(h, p):
        h2, _ = _dec_block_fwd(p, h, enc, cos, sin, cfg)
        return h2, None

    fn = jax.checkpoint(block) if cfg.remat else block
    x, _ = jax.lax.scan(fn, x, params["dec_blocks"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    return constrain(jnp.einsum("bsd,dv->bsv", x,
                                params["lm_head"].astype(x.dtype)),
                     "dp", None, None)


def loss_fn(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    logits = forward(params, batch["frames"], batch["tokens"], cfg)
    return cross_entropy_loss(logits, batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int,
               src_len: int) -> EncDecCache:
    l, kh, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
    return EncDecCache(
        self_k=jnp.zeros((l, batch, max_len, kh, hd), cfg.cdtype),
        self_v=jnp.zeros((l, batch, max_len, kh, hd), cfg.cdtype),
        cross_k=jnp.zeros((l, batch, src_len, kh, hd), cfg.cdtype),
        cross_v=jnp.zeros((l, batch, src_len, kh, hd), cfg.cdtype),
        length=jnp.zeros((batch,), jnp.int32))


def prefill(params: Params, frames: jax.Array, tokens: jax.Array,
            cfg: ModelConfig, max_len: int | None = None, lengths=None):
    """Encode source + run target prompt. Returns (logits, cache)."""
    enc = encode(params, frames, cfg)
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    b, s = tokens.shape
    cos, sin = rope_tables(jnp.arange(s, dtype=jnp.int32), cfg.hd,
                           cfg.rope_theta)

    def block(h, p):
        h2, kv = _dec_block_fwd(p, h, enc, cos, sin, cfg)
        return h2, kv

    fn = jax.checkpoint(block) if cfg.remat else block
    x, (ks, vs, xks, xvs) = jax.lax.scan(fn, x, params["dec_blocks"])
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    t = max_len or s
    if t > s:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, t - s), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, t - s), (0, 0), (0, 0)))
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    return logits, EncDecCache(self_k=ks, self_v=vs, cross_k=xks,
                               cross_v=xvs, length=lengths)


def decode_step(params: Params, cache: EncDecCache, tokens: jax.Array,
                cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    length = cache.length + 1
    pos = (length - 1).astype(jnp.int32)[:, None]
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta)
    src_len = cache.cross_k.shape[2]

    def block(h, xs):
        p, kc, vc, xk, xv = xs
        hn = rmsnorm(h, p["ln1"], cfg.norm_eps)
        q = _proj_q(p, hn, cfg, "")
        k, v = _proj_kv(p, hn, cfg, "")
        q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)
        idx = (length - 1).astype(jnp.int32)
        rows = jnp.arange(h.shape[0])
        kc = kc.at[rows, idx].set(k[:, 0])   # scatter: touches B rows only
        vc = vc.at[rows, idx].set(v[:, 0])
        o = attn.decode_attention(q, kc, vc, length)
        h = h + jnp.einsum("bse,ed->bsd", o.reshape(h.shape[0], 1, -1),
                           p["wo"].astype(h.dtype))
        hn = rmsnorm(h, p["lnx"], cfg.norm_eps)
        xq = _proj_q(p, hn, cfg, "x")
        full = jnp.full((h.shape[0],), src_len, jnp.int32)
        o = attn.decode_attention(xq, xk, xv, full)
        h = h + jnp.einsum("bse,ed->bsd", o.reshape(h.shape[0], 1, -1),
                           p["xwo"].astype(h.dtype))
        hn = rmsnorm(h, p["ln2"], cfg.norm_eps)
        h = h + swiglu(hn, p["w_gate"], p["w_up"], p["w_down"])
        return h, (kc, vc)

    x, (ks, vs) = jax.lax.scan(
        block, x, (params["dec_blocks"], cache.self_k, cache.self_v,
                   cache.cross_k, cache.cross_v))
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))
    return logits, EncDecCache(self_k=ks, self_v=vs, cross_k=cache.cross_k,
                               cross_v=cache.cross_v, length=length)
