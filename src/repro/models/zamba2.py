"""Zamba2: Mamba2 backbone + SHARED attention blocks (hybrid).

Every `hybrid_attn_period` Mamba2 layers, one shared transformer block
(GQA attention + SwiGLU MLP) is applied. All applications reuse ONE set
of attention-block weights (Zamba's parameter-sharing trick); each
application keeps its OWN KV cache. (The upstream model also applies
per-application LoRA deltas to the shared block; that specialization is
omitted — recorded in DESIGN.md.)

Cache = SSM states for every Mamba layer + a KV cache with a leading
"application" axis (num_apps, B, T, KH, hd).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import dense, mamba2
from repro.models.common import (ModelConfig, Params, cross_entropy_loss,
                                 dense_init, embed_init, rope_tables)


@dataclasses.dataclass
class HybridCache:
    state: jax.Array   # (L, B, H, P, N) f32 — mamba states
    conv: jax.Array    # (L, B, W-1, conv_dim)
    k: jax.Array       # (APPS, B, T, KH, hd)
    v: jax.Array       # (APPS, B, T, KH, hd)
    length: jax.Array  # (B,)


jax.tree_util.register_dataclass(
    HybridCache, data_fields=["state", "conv", "k", "v", "length"],
    meta_fields=[])


def num_apps(cfg: ModelConfig) -> int:
    assert cfg.num_layers % cfg.hybrid_attn_period == 0
    return cfg.num_layers // cfg.hybrid_attn_period


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    ks = jax.random.split(key, 4)
    sub = [mamba2.init_block(cfg, jax.random.fold_in(ks[0], i))
           for i in range(cfg.num_layers)]
    blocks = jax.tree.map(lambda *a: jnp.stack(a), *sub)
    # shared attention block: reuse dense's per-layer layout with L=1, squeezed
    shared_full = dense.init_params(cfg.with_(num_layers=1), ks[1])
    shared = jax.tree.map(lambda a: a[0], shared_full["blocks"])
    params = {
        "embed": embed_init(ks[2], (cfg.vocab_size, cfg.d_model), cfg.pdtype),
        "blocks": blocks,
        "shared": shared,
        "final_norm": jnp.ones((cfg.d_model,), cfg.pdtype),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[3], (cfg.d_model, cfg.vocab_size),
                                       cfg.pdtype)
    return params


def _grouped(params, cfg):
    apps = num_apps(cfg)
    per = cfg.hybrid_attn_period
    return jax.tree.map(
        lambda a: a.reshape(apps, per, *a.shape[1:]), params["blocks"])


def _run(params, x, cfg: ModelConfig, collect: bool):
    s = x.shape[1]
    cos, sin = rope_tables(jnp.arange(s, dtype=jnp.int32), cfg.hd,
                           cfg.rope_theta)
    shared = params["shared"]

    def superblock(h, mp):
        def mstep(hh, p):
            h2, (st, conv) = mamba2.block_fwd(p, hh, cfg)
            return h2, (st, conv)
        h, (states, convs) = jax.lax.scan(mstep, h, mp)
        h, (k, v) = dense.block_fwd(shared, h, cos, sin, cfg)
        if collect:
            return h, (states, convs, k, v)
        return h, None

    fn = jax.checkpoint(superblock) if cfg.remat else superblock
    return jax.lax.scan(fn, x, _grouped(params, cfg))


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds=None) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    x, _ = _run(params, x, cfg, collect=False)
    return mamba2._logits(params, x, cfg)


def loss_fn(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    return cross_entropy_loss(forward(params, batch["tokens"], cfg),
                              batch["labels"])


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> HybridCache:
    l, h, pd, n, w = (cfg.num_layers, cfg.ssm_heads, cfg.ssm_head_dim,
                      cfg.ssm_state, cfg.ssm_conv_width)
    apps = num_apps(cfg)
    kv_shape = (apps, batch, max_len, cfg.num_kv_heads, cfg.hd)
    return HybridCache(
        state=jnp.zeros((l, batch, h, pd, n), jnp.float32),
        conv=jnp.zeros((l, batch, w - 1, mamba2.conv_dim(cfg)), cfg.cdtype),
        k=jnp.zeros(kv_shape, cfg.cdtype), v=jnp.zeros(kv_shape, cfg.cdtype),
        length=jnp.zeros((batch,), jnp.int32))


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
            max_len=None, lengths=None, prefix_embeds=None):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    b, s = tokens.shape
    x, (states, convs, ks, vs) = _run(params, x, cfg, collect=True)
    states = states.reshape(cfg.num_layers, *states.shape[2:])
    convs = convs.reshape(cfg.num_layers, *convs.shape[2:])
    logits = mamba2._logits(params, x, cfg)
    t = max_len or s
    if t > s:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, t - s), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, t - s), (0, 0), (0, 0)))
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    return logits, HybridCache(state=states, conv=convs, k=ks, v=vs,
                               length=lengths)


def decode_step(params: Params, cache: HybridCache, tokens: jax.Array,
                cfg: ModelConfig):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    length = cache.length + 1
    pos = (length - 1).astype(jnp.int32)[:, None]
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta)
    shared = params["shared"]
    apps = num_apps(cfg)
    l = cfg.num_layers
    def grp(a):
        return a.reshape(apps, l // apps, *a.shape[1:])

    def superblock(h, xs):
        mp, st, cv, kc, vc = xs

        def mstep(hh, inner):
            p, s_, c_ = inner
            h2, s2, c2 = mamba2.block_decode(p, hh, s_, c_, cfg)
            return h2, (s2, c2)
        h, (st2, cv2) = jax.lax.scan(mstep, h, (mp, st, cv))
        h, kc2, vc2 = dense.block_decode(shared, h, kc, vc, length,
                                         cos, sin, cfg)
        return h, (st2, cv2, kc2, vc2)

    x, (states, convs, ks, vs) = jax.lax.scan(
        superblock, x,
        (_grouped(params, cfg), grp(cache.state), grp(cache.conv),
         cache.k, cache.v))
    states = states.reshape(l, *states.shape[2:])
    convs = convs.reshape(l, *convs.shape[2:])
    return mamba2._logits(params, x, cfg), HybridCache(
        state=states, conv=convs, k=ks, v=vs, length=length)
