"""Uniform model API: family dispatch for init / loss / prefill / decode.

`get_model(cfg)` returns a ModelApi whose members close over cfg, so the
launcher, trainer, server, and dry-run treat every architecture the same
way. The `vlm` family is the dense model fed stub patch embeddings
(prefix_embeds); `encdec` carries its own batch layout (frames + tokens).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

from repro.models import dense, encdec, mamba2, moe, zamba2
from repro.models.common import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable[..., Any]
    loss_fn: Callable[..., Any]          # (params, batch) -> scalar
    prefill: Callable[..., Any]          # (params, batch, max_len) -> (logits, cache)
    decode_step: Callable[..., Any]      # (params, cache, tokens) -> (logits, cache)
    init_cache: Callable[..., Any]       # (batch_size, max_len, ...) -> cache


def get_model(cfg: ModelConfig) -> ModelApi:
    fam = cfg.family
    if fam in ("dense", "vlm"):
        mod = dense
    elif fam == "moe":
        mod = moe
    elif fam == "ssm":
        mod = mamba2
    elif fam == "hybrid":
        mod = zamba2
    elif fam == "encdec":
        return _encdec_api(cfg)
    else:
        raise ValueError(f"unknown family {fam!r}")

    def loss(params, batch):
        return mod.loss_fn(params, batch, cfg)

    def prefill(params, batch, max_len=None):
        return mod.prefill(params, batch["tokens"], cfg, max_len=max_len,
                           lengths=batch.get("lengths"),
                           prefix_embeds=batch.get("prefix_embeds"))

    def decode(params, cache, tokens):
        return mod.decode_step(params, cache, tokens, cfg)

    def init_cache(batch_size, max_len, **kw):
        return mod.init_cache(cfg, batch_size, max_len)

    return ModelApi(cfg=cfg, init=lambda key: mod.init_params(cfg, key),
                    loss_fn=loss, prefill=prefill, decode_step=decode,
                    init_cache=init_cache)


def _encdec_api(cfg: ModelConfig) -> ModelApi:
    def loss(params, batch):
        return encdec.loss_fn(params, batch, cfg)

    def prefill(params, batch, max_len=None):
        return encdec.prefill(params, batch["frames"], batch["tokens"], cfg,
                              max_len=max_len, lengths=batch.get("lengths"))

    def decode(params, cache, tokens):
        return encdec.decode_step(params, cache, tokens, cfg)

    def init_cache(batch_size, max_len, src_len=None, **kw):
        return encdec.init_cache(cfg, batch_size, max_len,
                                 src_len or max_len)

    return ModelApi(cfg=cfg, init=lambda key: encdec.init_params(cfg, key),
                    loss_fn=loss, prefill=prefill, decode_step=decode,
                    init_cache=init_cache)
