"""Model library: dense GQA, MoE, Mamba2 (SSD), Zamba2 hybrid, enc-dec,
VLM backbone, and the paper's MiniLM-style embedder."""
from repro.models.common import ModelConfig
from repro.models.registry import ModelApi, get_model
