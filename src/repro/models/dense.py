"""Dense decoder-only transformer (GQA + RoPE + SwiGLU, pre-RMSNorm).

Covers qwen2 (QKV bias), minitron, deepseek-coder-33b / deepseek-67b, and
the LM backbone of internvl2 (optional prefix embeddings from the stubbed
vision frontend). Layer parameters are stacked on a leading axis and the
forward pass scans over them (optionally rematerialized).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models.common import (ModelConfig, Params, apply_rope, constrain,
                                 constrain_kv, cross_entropy_loss,
                                 dense_init, embed_init, residual_pattern,
                                 rmsnorm, rope_tables, swiglu)


@dataclasses.dataclass
class KVCache:
    k: jax.Array        # (L, B, T, KH, hd)
    v: jax.Array        # (L, B, T, KH, hd)
    length: jax.Array   # (B,) int32 — valid positions per sequence


jax.tree_util.register_dataclass(
    KVCache, data_fields=["k", "v", "length"], meta_fields=[])


def init_params(cfg: ModelConfig, key: jax.Array) -> Params:
    l, d, h, kh, hd, f, v = (cfg.num_layers, cfg.d_model, cfg.num_heads,
                             cfg.num_kv_heads, cfg.hd, cfg.d_ff,
                             cfg.vocab_size)
    ks = jax.random.split(key, 12)
    dt = cfg.pdtype
    blocks = {
        "ln1": jnp.ones((l, d), dt),
        "wq": dense_init(ks[0], (l, d, h * hd), dt),
        "wk": dense_init(ks[1], (l, d, kh * hd), dt),
        "wv": dense_init(ks[2], (l, d, kh * hd), dt),
        "wo": dense_init(ks[3], (l, h * hd, d), dt, scale=(h * hd) ** -0.5),
        "ln2": jnp.ones((l, d), dt),
        "w_gate": dense_init(ks[4], (l, d, f), dt),
        "w_up": dense_init(ks[5], (l, d, f), dt),
        "w_down": dense_init(ks[6], (l, f, d), dt, scale=f ** -0.5),
    }
    if cfg.qkv_bias:
        blocks["bq"] = jnp.zeros((l, h * hd), dt)
        blocks["bk"] = jnp.zeros((l, kh * hd), dt)
        blocks["bv"] = jnp.zeros((l, kh * hd), dt)
    params = {
        "embed": embed_init(ks[7], (v, d), dt),
        "blocks": blocks,
        "final_norm": jnp.ones((d,), dt),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(ks[8], (d, v), dt)
    return params


def _qkv(p, x, cfg: ModelConfig):
    b, s, _ = x.shape
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hd
    q = jnp.einsum("bsd,de->bse", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,de->bse", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,de->bse", x, p["wv"].astype(x.dtype))
    if cfg.qkv_bias:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = constrain(q.reshape(b, s, h, hd), "dp", None, "mp", None)
    k = constrain(k.reshape(b, s, kh, hd), "dp", None, "mp", None)
    v = constrain(v.reshape(b, s, kh, hd), "dp", None, "mp", None)
    return q, k, v


def block_fwd(p, x, cos, sin, cfg: ModelConfig):
    """Full-sequence (train / prefill) block. Returns (x, (k, v))."""
    hn = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, hn, cfg)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    o = attn.chunked_causal_attention(q, k, v, cfg.attn_chunk)
    o = jnp.einsum("bse,ed->bsd", o.reshape(*o.shape[:2], -1),
                   p["wo"].astype(x.dtype))
    x = constrain(x + o, *residual_pattern(cfg))
    hn = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = constrain(x + swiglu(hn, p["w_gate"], p["w_up"], p["w_down"]),
                  *residual_pattern(cfg))
    return x, (k, v)


def block_decode(p, x, kc, vc, length, cos, sin, cfg: ModelConfig):
    """Single-token block against a per-layer KV cache slice.

    x (B,1,D); kc/vc (B,T,KH,hd); length (B,) = count INCLUDING this token.
    Returns (x, new_kc, new_vc).
    """
    hn = rmsnorm(x, p["ln1"], cfg.norm_eps)
    q, k, v = _qkv(p, hn, cfg)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)
    # write the new token at position length-1 per batch row. SCATTER, not
    # a one-hot masked rewrite: the one-hot form reads+writes the entire
    # (B, T, KH, hd) cache every step (2 extra cache passes of HBM
    # traffic); the scatter touches only B rows (§Perf C3).
    b = x.shape[0]
    idx = (length - 1).astype(jnp.int32)                      # (B,)
    rows = jnp.arange(b)
    kc = constrain_kv(kc.at[rows, idx].set(k[:, 0]))
    vc = constrain_kv(vc.at[rows, idx].set(v[:, 0]))
    o = attn.decode_attention(q, kc, vc, length)
    o = jnp.einsum("bse,ed->bsd", o.reshape(b, 1, -1),
                   p["wo"].astype(x.dtype))
    x = x + o
    hn = rmsnorm(x, p["ln2"], cfg.norm_eps)
    x = x + swiglu(hn, p["w_gate"], p["w_up"], p["w_down"])
    return x, kc, vc


def _scan_blocks(blocks, x, step_fn, cfg: ModelConfig, extra_xs=None):
    """scan over stacked layer params (+ optional per-layer xs)."""
    fn = step_fn
    if cfg.remat:
        fn = jax.checkpoint(fn)
    if cfg.scan_layers:
        xs = (blocks,) if extra_xs is None else (blocks, *extra_xs)
        return jax.lax.scan(lambda c, xs_: fn(c, *xs_), x, xs)
    carry, ys = x, []
    for i in range(cfg.num_layers):
        sl = jax.tree.map(lambda a: a[i], blocks)
        ex = () if extra_xs is None else tuple(
            jax.tree.map(lambda a: a[i], e) for e in extra_xs)
        carry, y = fn(carry, sl, *ex)
        ys.append(y)
    return carry, jax.tree.map(lambda *a: jnp.stack(a), *ys)


def embed_tokens(params, tokens, cfg: ModelConfig,
                 prefix_embeds: jax.Array | None = None) -> jax.Array:
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.cdtype)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(cfg.cdtype), x], axis=1)
    return constrain(x, "dp", None, None)


def _logits(params, x, cfg: ModelConfig):
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    head = (params["embed"].T if cfg.tie_embeddings else params["lm_head"])
    return constrain(jnp.einsum("bsd,dv->bsv", x, head.astype(x.dtype)),
                     "dp", None, "mp")


def forward(params: Params, tokens: jax.Array, cfg: ModelConfig,
            prefix_embeds: jax.Array | None = None) -> jax.Array:
    """Teacher-forcing forward -> logits (B, S(+P), V)."""
    x = embed_tokens(params, tokens, cfg, prefix_embeds)
    s = x.shape[1]
    cos, sin = rope_tables(jnp.arange(s, dtype=jnp.int32), cfg.hd,
                           cfg.rope_theta)

    def step(h, p):
        h2, _ = block_fwd(p, h, cos, sin, cfg)
        return h2, None

    x, _ = _scan_blocks(params["blocks"], x, step, cfg)
    return _logits(params, x, cfg)


def loss_fn(params: Params, batch: dict, cfg: ModelConfig) -> jax.Array:
    logits = forward(params, batch["tokens"], cfg,
                     batch.get("prefix_embeds"))
    labels = batch["labels"]
    if "prefix_embeds" in batch and batch["prefix_embeds"] is not None:
        p = batch["prefix_embeds"].shape[1]
        pad = jnp.full(labels.shape[:1] + (p,), -1, labels.dtype)
        labels = jnp.concatenate([pad, labels], axis=1)
    return cross_entropy_loss(logits, labels)


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> KVCache:
    shape = (cfg.num_layers, batch, max_len, cfg.num_kv_heads, cfg.hd)
    return KVCache(k=jnp.zeros(shape, cfg.cdtype),
                   v=jnp.zeros(shape, cfg.cdtype),
                   length=jnp.zeros((batch,), jnp.int32))


def prefill(params: Params, tokens: jax.Array, cfg: ModelConfig,
            max_len: int | None = None, lengths: jax.Array | None = None,
            prefix_embeds: jax.Array | None = None
            ) -> tuple[jax.Array, KVCache]:
    """Run the prompt, return (logits, primed KV cache)."""
    x = embed_tokens(params, tokens, cfg, prefix_embeds)
    b, s, _ = x.shape
    cos, sin = rope_tables(jnp.arange(s, dtype=jnp.int32), cfg.hd,
                           cfg.rope_theta)

    def step(h, p):
        h2, kv = block_fwd(p, h, cos, sin, cfg)
        return h2, kv

    x, (ks, vs) = _scan_blocks(params["blocks"], x, step, cfg)
    logits = _logits(params, x, cfg)
    t = max_len or s
    pad = t - s
    if pad:
        ks = jnp.pad(ks, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        vs = jnp.pad(vs, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    if lengths is None:
        lengths = jnp.full((b,), s, jnp.int32)
    return logits, KVCache(k=ks, v=vs, length=lengths)


def decode_step(params: Params, cache: KVCache, tokens: jax.Array,
                cfg: ModelConfig) -> tuple[jax.Array, KVCache]:
    """One decode step. tokens (B, 1) -> logits (B, 1, V), updated cache."""
    x = embed_tokens(params, tokens, cfg)
    length = cache.length + 1
    pos = (length - 1).astype(jnp.int32)[:, None]              # (B, 1)
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta)

    def step(h, p, kc, vc):
        h2, kc2, vc2 = block_decode(p, h, kc, vc, length, cos, sin, cfg)
        return h2, (kc2, vc2)

    x, (ks, vs) = _scan_blocks(params["blocks"], x, step, cfg,
                               extra_xs=(cache.k, cache.v))
    return _logits(params, x, cfg), KVCache(k=ks, v=vs, length=length)


# ---------------------------------------------------------------------------
# Quantized-KV decode (§Perf C3 / beyond-paper): the paper's two-stage
# hierarchical idea applied to the KV-cache "database". Keys live as INT8
# nibble planes; stage 1 scores every cached key from the MSB plane only,
# stage 2 runs exact attention on the top-k survivors (serve/sparse_kv).
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class QuantCache:
    k_msb: jax.Array    # (L, B, T, KH, hd//2) uint8
    k_lsb: jax.Array    # (L, B, T, KH, hd//2) uint8
    k_scale: jax.Array  # (L, B, T, KH) f32
    v: jax.Array        # (L, B, T, KH, hd)
    length: jax.Array   # (B,)
    # Optional Quest-style page-centroid sidecars (P = T // page_rows),
    # maintained incrementally by decode_step_quant — enable the engine's
    # KVPagePrune stage so the stage-1 scan reads npages*page_rows rows
    # instead of T.
    cent_msb: jax.Array | None = None    # (L, B, P, KH, hd//2) uint8
    cent_scale: jax.Array | None = None  # (L, B, P, KH) f32
    page_rows: int = 8


jax.tree_util.register_dataclass(
    QuantCache, data_fields=["k_msb", "k_lsb", "k_scale", "v", "length",
                             "cent_msb", "cent_scale"],
    meta_fields=["page_rows"])


def init_quant_cache(cfg: ModelConfig, batch: int, max_len: int,
                     page_rows: int | None = None) -> QuantCache:
    l, kh, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd
    cent_msb = cent_scale = None
    if page_rows is not None:
        if max_len % page_rows:
            raise ValueError(f"max_len={max_len} not a multiple of "
                             f"page_rows={page_rows}")
        p = max_len // page_rows
        cent_msb = jnp.zeros((l, batch, p, kh, hd // 2), jnp.uint8)
        cent_scale = jnp.zeros((l, batch, p, kh), jnp.float32)
    return QuantCache(
        k_msb=jnp.zeros((l, batch, max_len, kh, hd // 2), jnp.uint8),
        k_lsb=jnp.zeros((l, batch, max_len, kh, hd // 2), jnp.uint8),
        k_scale=jnp.zeros((l, batch, max_len, kh), jnp.float32),
        v=jnp.zeros((l, batch, max_len, kh, hd), cfg.cdtype),
        length=jnp.zeros((batch,), jnp.int32),
        cent_msb=cent_msb, cent_scale=cent_scale,
        page_rows=page_rows or 8)


def quantize_cache(cache: KVCache, page_rows: int | None = None
                   ) -> QuantCache:
    """Convert a prefill's bf16 KVCache into the nibble-planar QuantCache
    (keys re-quantized per (position, head); V shared by reference).
    With `page_rows` the page-centroid sidecars are built too, so the
    very first decode step can run the paged cascade over the prompt."""
    from repro.serve import sparse_kv

    ms, ls, ss = jax.vmap(sparse_kv.quantize_keys)(cache.k)
    cm = cs = None
    if page_rows is not None:
        def _cent(m, l, s, v):
            c = sparse_kv.build_page_centroids(
                sparse_kv.QuantKVCache(k_msb=m, k_lsb=l, k_scale=s, v=v),
                cache.length, page_rows)
            return c.cent_msb, c.cent_scale
        cm, cs = jax.vmap(_cent)(ms, ls, ss, cache.v)
    return QuantCache(k_msb=ms, k_lsb=ls, k_scale=ss, v=cache.v,
                      length=cache.length, cent_msb=cm, cent_scale=cs,
                      page_rows=page_rows or 8)


def decode_step_quant(params: Params, cache: QuantCache, tokens: jax.Array,
                      cfg: ModelConfig, top_k: int = 256,
                      npages: int | None = None,
                      prescreen_c0: int | None = None,
                      backend: str = "jnp"
                      ) -> tuple[jax.Array, QuantCache]:
    """Decode against the INT8 nibble-planar K cache via the engine's KV
    cascade. Per step per layer, HBM reads are the MSB plane (T*hd/2 B)
    + scales + top_k exact rows instead of the full 2*T*hd*2 B of bf16
    K+V; with `npages` (cache built by init_quant_cache(page_rows=...))
    the scan itself shrinks to npages*page_rows rows behind the
    Quest-style page prune, and `prescreen_c0` inserts the 1-bit
    sign-plane prescreen between prune and scan. Page centroids are
    maintained incrementally — only the appended-to page is re-averaged
    each step (EdgeRAG's online-index discipline applied to the cache)."""
    from repro.serve import sparse_kv

    has_pages = cache.cent_msb is not None
    if npages is not None and not has_pages:
        raise ValueError("npages requires a paged cache — build it with "
                         "init_quant_cache(page_rows=...)")
    page_rows = cache.page_rows
    x = embed_tokens(params, tokens, cfg)
    length = cache.length + 1
    pos = (length - 1).astype(jnp.int32)[:, None]
    cos, sin = rope_tables(pos, cfg.hd, cfg.rope_theta)
    b = tokens.shape[0]
    rows = jnp.arange(b)
    idx = (length - 1).astype(jnp.int32)

    def step(h, p, msb, lsb, scl, vc, *cent):
        hn = rmsnorm(h, p["ln1"], cfg.norm_eps)
        q, k, v = _qkv(p, hn, cfg)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
        nm, nl, nsc = sparse_kv.quantize_keys(k)        # (B,1,KH,hd//2) x2
        msb = msb.at[rows, idx].set(nm[:, 0])
        lsb = lsb.at[rows, idx].set(nl[:, 0])
        scl = scl.at[rows, idx].set(nsc[:, 0])
        vc = vc.at[rows, idx].set(v[:, 0])
        if cent:
            cm, cs = sparse_kv.update_page_centroids(
                msb, lsb, scl, cent[0], cent[1], length, page_rows)
            cent = (cm, cs)
        layer = sparse_kv.QuantKVCache(
            k_msb=msb, k_lsb=lsb, k_scale=scl, v=vc,
            cent_msb=cent[0] if cent else None,
            cent_scale=cent[1] if cent else None)
        o = sparse_kv.sparse_decode_attention(
            q, layer, length, top_k, npages=npages,
            prescreen_c0=prescreen_c0, page_rows=page_rows,
            backend=backend)
        o = jnp.einsum("bse,ed->bsd", o.reshape(b, 1, -1),
                       p["wo"].astype(h.dtype))
        h = h + o
        hn = rmsnorm(h, p["ln2"], cfg.norm_eps)
        h = h + swiglu(hn, p["w_gate"], p["w_up"], p["w_down"])
        return h, (msb, lsb, scl, vc, *cent)

    extra = (cache.k_msb, cache.k_lsb, cache.k_scale, cache.v)
    if has_pages:
        extra = extra + (cache.cent_msb, cache.cent_scale)
    x, ys = _scan_blocks(params["blocks"], x, step, cfg, extra_xs=extra)
    ms, ls, scs, vs = ys[:4]
    cm, cs = (ys[4], ys[5]) if has_pages else (None, None)
    return _logits(params, x, cfg), QuantCache(
        k_msb=ms, k_lsb=ls, k_scale=scs, v=vs, length=length,
        cent_msb=cm, cent_scale=cs, page_rows=page_rows)
