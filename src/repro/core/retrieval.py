"""Quantization-aware two-stage hierarchical retrieval (the paper's core).

Stage 1 — MSB-INT4 approximate retrieval: score EVERY document using only
the most-significant nibble of both query and document codes (read from the
nibble-planar MSB plane — half the HBM bytes), and keep an approximate
candidate set.

Stage 2 — INT8 full-precision retrieval: gather the candidates' full INT8
codes (MSB+LSB planes), rescore exactly, and rank the final top-k with the
non-division fraction comparator (cosine) or raw integer scores (MIPS).

The candidate-set policy follows the paper's Fig. 4 operating points:
``min(max_candidates, ceil(candidate_frac * N))`` with max 50 / frac 0.2.

`backend="jnp"` uses pure-jnp reference math; `backend="pallas"` routes the
two scoring stages through the Pallas TPU kernels in repro.kernels.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import bitplanar, quantization, similarity


@dataclasses.dataclass(frozen=True)
class RetrievalConfig:
    k: int = 5
    metric: Literal["cosine", "mips"] = "cosine"
    max_candidates: int = 50
    candidate_frac: float = 0.2
    backend: Literal["jnp", "pallas"] = "jnp"

    def num_candidates(self, num_docs: int) -> int:
        return max(self.k, min(self.max_candidates,
                               math.ceil(self.candidate_frac * num_docs)))


@dataclasses.dataclass(frozen=True)
class RetrievalResult:
    indices: jax.Array        # (k,) global document ids, best first
    scores: jax.Array         # (k,) exact int32 dot products
    candidate_indices: jax.Array  # (C,) stage-1 candidate ids (diagnostics)


jax.tree_util.register_pytree_node(
    RetrievalResult,
    lambda r: ((r.indices, r.scores, r.candidate_indices), None),
    lambda _, leaves: RetrievalResult(*leaves),
)


# ---------------------------------------------------------------------------
# Stage primitives (pure-jnp reference path; kernels mirror these)
# ---------------------------------------------------------------------------

def stage1_scores_jnp(q_msb: jax.Array, msb_plane: jax.Array) -> jax.Array:
    """Approximate MIPS on MSB nibbles. q_msb (D,) int8 in [-8,7];
    msb_plane (N, D//2) uint8 packed. Returns (N,) int32.

    Split-query formulation: byte j of the plane packs dims (2j, 2j+1), so
    the dot product is lo_signed . q_even + hi_signed . q_odd — scoring
    runs directly on the packed plane (two (N, D/2) matvecs) with the
    nibbles sign-extended by two arithmetic int8 shifts, never
    materializing the (N, D) interleaved unpack on the hot path.
    """
    b = msb_plane.view(jnp.int8)
    lo = (b << 4) >> 4                     # signed low nibbles (dims 0,2,..)
    hi = b >> 4                            # signed high nibbles (dims 1,3,..)
    return (similarity.int_matvec(lo, q_msb[0::2])
            + similarity.int_matvec(hi, q_msb[1::2]))


def stage2_scores_jnp(q: jax.Array, msb_rows: jax.Array,
                      lsb_rows: jax.Array) -> jax.Array:
    """Exact INT8 rescoring of gathered candidate rows. q (D,) int8."""
    docs = bitplanar.reconstruct_int8(msb_rows, lsb_rows)     # (C, D) int8
    return similarity.int_matvec(docs, q)


def _stage_fns(backend: str):
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.stage1_scores, kops.stage2_scores
    return stage1_scores_jnp, stage2_scores_jnp


# ---------------------------------------------------------------------------
# Full two-stage retrieval (single shard)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def two_stage_retrieve(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                       cfg: RetrievalConfig) -> RetrievalResult:
    """Run the hierarchical retrieval for one query over one DB shard.

    query_codes: (D,) int8 (already quantized by the embedder front-end).
    """
    n = db.num_docs
    c = cfg.num_candidates(n)
    stage1, stage2 = _stage_fns(cfg.backend)

    # ---- Stage 1: MSB-nibble approximate scoring over the whole corpus.
    q_msb = quantization.msb_nibble(query_codes)
    approx = stage1(q_msb, db.msb_plane)                       # (N,) int32
    if cfg.metric == "cosine":
        # Approximate cosine key; norms are tiny sidecar reads (paper stores
        # doc norms in DRAM alongside the planes).
        key1 = similarity.cosine_key_f32(approx, db.norms_sq)
    else:
        key1 = approx
    _, cand = jax.lax.top_k(key1, c)                           # (C,) ids

    # ---- Stage 2: exact INT8 rescoring of the candidate set only.
    msb_rows = jnp.take(db.msb_plane, cand, axis=0)
    lsb_rows = jnp.take(db.lsb_plane, cand, axis=0)
    exact = stage2(query_codes, msb_rows, lsb_rows)            # (C,) int32
    cand_norms = jnp.take(db.norms_sq, cand, axis=0)

    if cfg.metric == "cosine":
        local, scores = similarity.rerank_dense_comparator(exact, cand_norms, cfg.k)
    else:
        scores, local = similarity.topk_mips(exact, cfg.k)
    return RetrievalResult(indices=cand[local], scores=scores,
                           candidate_indices=cand)


@partial(jax.jit, static_argnames=("cfg",))
def exact_retrieve(query_codes: jax.Array, db: quantization.QuantizedDB,
                   cfg: RetrievalConfig) -> RetrievalResult:
    """Single-stage full-precision INT8 retrieval (the paper's baseline)."""
    scores = similarity.int_matvec(db.values, query_codes)
    if cfg.metric == "cosine":
        key = similarity.cosine_key_f32(scores, db.norms_sq)
    else:
        key = scores
    _, idx = jax.lax.top_k(key, cfg.k)
    return RetrievalResult(indices=idx, scores=scores[idx],
                           candidate_indices=idx)


@partial(jax.jit, static_argnames=("cfg",))
def int4_retrieve(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                  cfg: RetrievalConfig) -> RetrievalResult:
    """Pure-INT4 baseline: rank directly on MSB-nibble scores (no stage 2)."""
    q_msb = quantization.msb_nibble(query_codes)
    approx = stage1_scores_jnp(q_msb, db.msb_plane)
    if cfg.metric == "cosine":
        key = similarity.cosine_key_f32(approx, db.norms_sq)
    else:
        key = approx
    _, idx = jax.lax.top_k(key, cfg.k)
    return RetrievalResult(indices=idx, scores=approx[idx],
                           candidate_indices=idx)


def batched_retrieve(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                     cfg: RetrievalConfig) -> RetrievalResult:
    """vmap over a batch of queries: (B, D) int8 -> batched RetrievalResult."""
    return jax.vmap(lambda q: two_stage_retrieve(q, db, cfg))(query_codes)


# ---------------------------------------------------------------------------
# Segment-masked variants (multi-tenant arenas)
# ---------------------------------------------------------------------------

# Sentinel tenant id that matches no arena slot (free slots use -1), used to
# pad request batches: a NO_TENANT query returns all-invalid results.
NO_TENANT = -2

# Stage-2 score assigned to out-of-segment candidates. Most-negative-plus-one
# so s*s stays below 2**62 inside the non-division comparator's int64 limbs;
# any in-segment row (even with a negative score) orders strictly above it.
_MASKED_SCORE = jnp.int32(-(2 ** 31 - 1))


def stage1_keys_masked(q_msb: jax.Array, msb_plane: jax.Array,
                       norms_sq: jax.Array, member: jax.Array, metric: str,
                       backend: str = "jnp") -> jax.Array:
    """Segment-masked stage-1 ranking keys over (a window of) an arena.

    Scores every row on the MSB plane, converts to the metric's monotone
    key, and forces rows outside the caller's segments (`member` False) to
    -inf so they can never be proposed as candidates. Tombstoned rows
    additionally carry norm 0 (cosine key 0), so even an inconsistent
    membership mask cannot let a dead row win.
    """
    stage1, _ = _stage_fns(backend)
    approx = stage1(q_msb, msb_plane)                          # (N,) int32
    if metric == "cosine":
        key = similarity.cosine_key_f32(approx, norms_sq)
    else:
        key = approx.astype(jnp.float32)
    return jnp.where(member, key, -jnp.inf)


def stage2_scores_masked(query_codes: jax.Array, msb_plane: jax.Array,
                         lsb_plane: jax.Array, norms_sq: jax.Array,
                         cand: jax.Array, cand_member: jax.Array,
                         backend: str = "jnp") -> tuple[jax.Array, jax.Array]:
    """Exact INT8 rescoring of candidate rows, masking out-of-segment rows.

    Returns (scores, norms) with out-of-segment candidates pinned to
    (_MASKED_SCORE, 1) so the integer rerank comparator ranks them below
    every in-segment candidate. cand may contain such rows whenever the
    tenant owns fewer live slots than the candidate budget C.
    """
    _, stage2 = _stage_fns(backend)
    msb_rows = jnp.take(msb_plane, cand, axis=0)
    lsb_rows = jnp.take(lsb_plane, cand, axis=0)
    exact = stage2(query_codes, msb_rows, lsb_rows)            # (C,) int32
    scores = jnp.where(cand_member, exact, _MASKED_SCORE)
    norms = jnp.where(cand_member, jnp.take(norms_sq, cand, axis=0), 1)
    return scores, norms


def _rescore_and_rank(query_codes: jax.Array, msb_plane: jax.Array,
                      lsb_plane: jax.Array, norms_sq: jax.Array,
                      cand: jax.Array, cand_member: jax.Array,
                      cfg: RetrievalConfig) -> RetrievalResult:
    """Shared stage-2 + rerank tail of every masked variant: exact-rescore
    the candidate rows (ids index the given planes), rank with the metric,
    and mask out-of-segment results to (-1, 0)."""
    exact, cand_norms = stage2_scores_masked(query_codes, msb_plane,
                                             lsb_plane, norms_sq, cand,
                                             cand_member, cfg.backend)
    if cfg.metric == "cosine":
        local, scores = similarity.rerank_dense_comparator(exact, cand_norms,
                                                           cfg.k)
    else:
        scores, local = similarity.topk_mips(exact, cfg.k)
    valid = jnp.take(cand_member, local, axis=0)
    return RetrievalResult(
        indices=jnp.where(valid, cand[local], -1),
        scores=jnp.where(valid, scores, 0),
        candidate_indices=jnp.where(cand_member, cand, -1))


def _masked_two_stage(query_codes: jax.Array, msb_plane: jax.Array,
                      lsb_plane: jax.Array, norms_sq: jax.Array,
                      member: jax.Array, c: int,
                      cfg: RetrievalConfig) -> RetrievalResult:
    """Shared body of the masked variants (row ids local to the planes)."""
    q_msb = quantization.msb_nibble(query_codes)
    key1 = stage1_keys_masked(q_msb, msb_plane, norms_sq, member,
                              cfg.metric, cfg.backend)
    _, cand = jax.lax.top_k(key1, c)                           # (C,) rows
    cand_member = jnp.take(member, cand, axis=0)
    return _rescore_and_rank(query_codes, msb_plane, lsb_plane, norms_sq,
                             cand, cand_member, cfg)


@partial(jax.jit, static_argnames=("cfg",))
def two_stage_retrieve_masked(query_codes: jax.Array,
                              db: bitplanar.BitPlanarDB,
                              owner: jax.Array, tenant_id: jax.Array,
                              cfg: RetrievalConfig) -> RetrievalResult:
    """Hierarchical retrieval restricted to one tenant's arena segments.

    owner: (N,) int32 slot->tenant map (repro.tenancy.Arena.owner; free and
    tombstoned slots hold -1). Rows with owner != tenant_id are masked to
    -inf in stage 1 and pinned to the floor score in stage 2, so a query
    can never surface another tenant's (or a dead) document. Returned
    indices are arena slot ids; slots the tenant could not fill (fewer
    live docs than k) come back as -1 with score 0.

    This is the fully general path: it scans the WHOLE arena and works for
    arbitrarily fragmented tenants. When every tenant in a batch is one
    contiguous segment, prefer `windowed_retrieve_masked`.
    """
    # tenant_id < 0 matches nothing: -1 is the FREE/tombstone owner value
    # and NO_TENANT (-2) marks padding lanes, so negative ids must never
    # act as a segment key (a -1 "tenant" would resurrect tombstones).
    member = (owner == tenant_id) & (tenant_id >= 0)            # (N,) bool
    c = cfg.num_candidates(db.num_docs)
    return _masked_two_stage(query_codes, db.msb_plane, db.lsb_plane,
                             db.norms_sq, member, c, cfg)


@partial(jax.jit, static_argnames=("cfg", "window"))
def windowed_retrieve_masked(query_codes: jax.Array,
                             db: bitplanar.BitPlanarDB, owner: jax.Array,
                             tenant_ids: jax.Array, starts: jax.Array,
                             cfg: RetrievalConfig,
                             window: int) -> RetrievalResult:
    """Cross-tenant batch over a tenant-CONTIGUOUS arena, one launch.

    When each requested tenant occupies a single contiguous slot run (the
    invariant bump allocation establishes and tenant-grouped compaction
    restores), batch lane i only streams the `window` rows starting at its
    tenant's segment, via dynamic_slice — so a mixed batch of B users
    costs one launch AND only per-tenant work, instead of B arena-wide
    scans. Rows inside the window but outside the segment (neighbours,
    tombstones) are masked exactly like the full-scan variant. Returned
    indices are global arena slot ids.

    window: static upper bound on any requested tenant's segment length
    (callers round up to a power-of-two bucket to bound recompilation),
    and must be >= cfg.k (MultiTenantIndex guarantees this).

    The candidate budget is the SAME as the full-arena scan's — clamped
    to the window, in which case every in-window row is a candidate and
    the tenant is rescored exhaustively — so results never depend on
    which of the two code paths the arena's fragmentation state selects.
    """
    n = db.num_docs
    if window < cfg.k:
        raise ValueError(f"window {window} < k={cfg.k}: top-k over a "
                         f"window needs window >= k")
    c = min(cfg.num_candidates(n), window)
    hi = max(n - window, 0)

    def lane(q, tid, start):
        # Stage 1 streams only the window (the MSB-plane halving is ON TOP
        # of this); stage 2 gathers its few candidate rows straight from
        # the full planes by global id, so the LSB plane is never sliced.
        start = jnp.clip(start, 0, hi).astype(jnp.int32)
        msb_w = jax.lax.dynamic_slice_in_dim(db.msb_plane, start, window, 0)
        norms_w = jax.lax.dynamic_slice_in_dim(db.norms_sq, start, window, 0)
        owner_w = jax.lax.dynamic_slice_in_dim(owner, start, window, 0)
        member = (owner_w == tid) & (tid >= 0)     # see two_stage_retrieve_masked

        q_msb = quantization.msb_nibble(q)
        key1 = stage1_keys_masked(q_msb, msb_w, norms_w, member,
                                  cfg.metric, cfg.backend)
        _, cand = jax.lax.top_k(key1, c)               # window-local rows
        cand_member = jnp.take(member, cand, axis=0)
        gids = cand + start                            # global slot ids
        return _rescore_and_rank(q, db.msb_plane, db.lsb_plane,
                                 db.norms_sq, gids, cand_member, cfg)

    return jax.vmap(lane)(query_codes, tenant_ids, starts)


@partial(jax.jit, static_argnames=("cfg",))
def batched_retrieve_masked(query_codes: jax.Array,
                            db: bitplanar.BitPlanarDB, owner: jax.Array,
                            tenant_ids: jax.Array,
                            cfg: RetrievalConfig) -> RetrievalResult:
    """Cross-tenant batch: (B, D) queries + (B,) tenant ids, ONE launch.

    vmaps the segment-masked retrieval over a mixed batch of tenants
    against the shared arena — the scheduler's kernel-level primitive.
    """
    return jax.vmap(
        lambda q, t: two_stage_retrieve_masked(q, db, owner, t, cfg)
    )(query_codes, tenant_ids)
