"""Quantization-aware two-stage hierarchical retrieval (the paper's core).

Stage 1 — MSB-INT4 approximate retrieval: score EVERY document using only
the most-significant nibble of both query and document codes (read from the
nibble-planar MSB plane — half the HBM bytes), and keep an approximate
candidate set.

Stage 2 — INT8 full-precision retrieval: gather the candidates' full INT8
codes (MSB+LSB planes), rescore exactly, and rank the final top-k with the
non-division fraction comparator (cosine) or raw integer scores (MIPS).

The candidate-set policy follows the paper's Fig. 4 operating points:
``min(max_candidates, ceil(candidate_frac * N))`` with max 50 / frac 0.2.

`backend="jnp"` uses pure-jnp reference math; `backend="pallas"` routes the
two scoring stages through the Pallas TPU kernels in repro.kernels.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import bitplanar, quantization, similarity


@dataclasses.dataclass(frozen=True)
class RetrievalConfig:
    k: int = 5
    metric: Literal["cosine", "mips"] = "cosine"
    max_candidates: int = 50
    candidate_frac: float = 0.2
    backend: Literal["jnp", "pallas"] = "jnp"

    def num_candidates(self, num_docs: int) -> int:
        return max(self.k, min(self.max_candidates,
                               math.ceil(self.candidate_frac * num_docs)))


@dataclasses.dataclass(frozen=True)
class RetrievalResult:
    indices: jax.Array        # (k,) global document ids, best first
    scores: jax.Array         # (k,) exact int32 dot products
    candidate_indices: jax.Array  # (C,) stage-1 candidate ids (diagnostics)


jax.tree_util.register_pytree_node(
    RetrievalResult,
    lambda r: ((r.indices, r.scores, r.candidate_indices), None),
    lambda _, leaves: RetrievalResult(*leaves),
)


# ---------------------------------------------------------------------------
# Stage primitives (pure-jnp reference path; kernels mirror these)
# ---------------------------------------------------------------------------

def stage1_scores_jnp(q_msb: jax.Array, msb_plane: jax.Array) -> jax.Array:
    """Approximate MIPS on MSB nibbles. q_msb (D,) int8 in [-8,7];
    msb_plane (N, D//2) uint8 packed. Returns (N,) int32."""
    d_msb = bitplanar.unpack_nibble_plane_signed(msb_plane)   # (N, D)
    return similarity.int_matvec(d_msb, q_msb)


def stage2_scores_jnp(q: jax.Array, msb_rows: jax.Array,
                      lsb_rows: jax.Array) -> jax.Array:
    """Exact INT8 rescoring of gathered candidate rows. q (D,) int8."""
    docs = bitplanar.reconstruct_int8(msb_rows, lsb_rows)     # (C, D) int8
    return similarity.int_matvec(docs, q)


def _stage_fns(backend: str):
    if backend == "pallas":
        from repro.kernels import ops as kops
        return kops.stage1_scores, kops.stage2_scores
    return stage1_scores_jnp, stage2_scores_jnp


# ---------------------------------------------------------------------------
# Full two-stage retrieval (single shard)
# ---------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("cfg",))
def two_stage_retrieve(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                       cfg: RetrievalConfig) -> RetrievalResult:
    """Run the hierarchical retrieval for one query over one DB shard.

    query_codes: (D,) int8 (already quantized by the embedder front-end).
    """
    n = db.num_docs
    c = cfg.num_candidates(n)
    stage1, stage2 = _stage_fns(cfg.backend)

    # ---- Stage 1: MSB-nibble approximate scoring over the whole corpus.
    q_msb = quantization.msb_nibble(query_codes)
    approx = stage1(q_msb, db.msb_plane)                       # (N,) int32
    if cfg.metric == "cosine":
        # Approximate cosine key; norms are tiny sidecar reads (paper stores
        # doc norms in DRAM alongside the planes).
        key1 = similarity.cosine_key_f32(approx, db.norms_sq)
    else:
        key1 = approx
    _, cand = jax.lax.top_k(key1, c)                           # (C,) ids

    # ---- Stage 2: exact INT8 rescoring of the candidate set only.
    msb_rows = jnp.take(db.msb_plane, cand, axis=0)
    lsb_rows = jnp.take(db.lsb_plane, cand, axis=0)
    exact = stage2(query_codes, msb_rows, lsb_rows)            # (C,) int32
    cand_norms = jnp.take(db.norms_sq, cand, axis=0)

    if cfg.metric == "cosine":
        local, scores = similarity.rerank_dense_comparator(exact, cand_norms, cfg.k)
    else:
        scores, local = similarity.topk_mips(exact, cfg.k)
    return RetrievalResult(indices=cand[local], scores=scores,
                           candidate_indices=cand)


@partial(jax.jit, static_argnames=("cfg",))
def exact_retrieve(query_codes: jax.Array, db: quantization.QuantizedDB,
                   cfg: RetrievalConfig) -> RetrievalResult:
    """Single-stage full-precision INT8 retrieval (the paper's baseline)."""
    scores = similarity.int_matvec(db.values, query_codes)
    if cfg.metric == "cosine":
        key = similarity.cosine_key_f32(scores, db.norms_sq)
    else:
        key = scores
    _, idx = jax.lax.top_k(key, cfg.k)
    return RetrievalResult(indices=idx, scores=scores[idx],
                           candidate_indices=idx)


@partial(jax.jit, static_argnames=("cfg",))
def int4_retrieve(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                  cfg: RetrievalConfig) -> RetrievalResult:
    """Pure-INT4 baseline: rank directly on MSB-nibble scores (no stage 2)."""
    q_msb = quantization.msb_nibble(query_codes)
    approx = stage1_scores_jnp(q_msb, db.msb_plane)
    if cfg.metric == "cosine":
        key = similarity.cosine_key_f32(approx, db.norms_sq)
    else:
        key = approx
    _, idx = jax.lax.top_k(key, cfg.k)
    return RetrievalResult(indices=idx, scores=approx[idx],
                           candidate_indices=idx)


def batched_retrieve(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                     cfg: RetrievalConfig) -> RetrievalResult:
    """vmap over a batch of queries: (B, D) int8 -> batched RetrievalResult."""
    return jax.vmap(lambda q: two_stage_retrieve(q, db, cfg))(query_codes)
