"""Quantization-aware two-stage hierarchical retrieval (the paper's core).

Stage 1 — MSB-INT4 approximate retrieval: score EVERY document using only
the most-significant nibble of both query and document codes (read from the
nibble-planar MSB plane — half the HBM bytes), and keep an approximate
candidate set.

Stage 2 — INT8 full-precision retrieval: gather the candidates' full INT8
codes (MSB+LSB planes), rescore exactly, and rank the final top-k with the
non-division fraction comparator (cosine) or raw integer scores (MIPS).

The candidate-set policy follows the paper's Fig. 4 operating points:
``min(max_candidates, ceil(candidate_frac * N))`` with max 50 / frac 0.2.

Every variant in this module — plain, segment-masked, windowed, batched —
is a THIN wrapper over the one batched two-stage core in repro.core.engine:
it builds the membership/window policy for its calling convention and runs
the shared schedule. `backend="jnp"` uses pure-jnp reference math;
`backend="pallas"` routes both scoring stages through the batch-native
Pallas TPU kernels in repro.kernels.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import bitplanar, quantization, similarity


@dataclasses.dataclass(frozen=True)
class RetrievalConfig:
    k: int = 5
    metric: Literal["cosine", "mips"] = "cosine"
    max_candidates: int = 50
    candidate_frac: float = 0.2
    backend: Literal["jnp", "pallas"] = "jnp"
    # Stage-0 sign-plane prescreen budget: the cluster-pruned cascade
    # inserts a 1-bit sign-agreement scan between the centroid prune and
    # the INT4 scan, keeping only the top-C0 view rows per lane (clamped
    # to [k, view rows]) so stage 1 gathers C0 rows instead of the whole
    # probed view. None (the default) disables the stage entirely —
    # cascades, plans and golden pins are bit-for-bit the pre-prescreen
    # behavior. Ignored by policies without a centroid prune.
    prescreen_c0: int | None = None

    def num_candidates(self, num_docs: int) -> int:
        return max(self.k, min(self.max_candidates,
                               math.ceil(self.candidate_frac * num_docs)))

    def prescreen_budget(self, view_rows: int) -> int | None:
        """The effective stage-0 survivor count for a `view_rows`-row
        probed view (None when the prescreen is disabled) — the single
        clamp both the SignPrescreen stage and the analytic plan use."""
        if self.prescreen_c0 is None:
            return None
        return max(self.k, min(self.prescreen_c0, view_rows))


@dataclasses.dataclass(frozen=True)
class RetrievalResult:
    indices: jax.Array        # (k,) global document ids, best first
    scores: jax.Array         # (k,) exact int32 dot products
    candidate_indices: jax.Array  # (C,) stage-1 candidate ids (diagnostics)


jax.tree_util.register_pytree_node(
    RetrievalResult,
    lambda r: ((r.indices, r.scores, r.candidate_indices), None),
    lambda _, leaves: RetrievalResult(*leaves),
)


# Sentinel tenant id that matches no arena slot (free slots use -1), used to
# pad request batches: a NO_TENANT query returns all-invalid results.
NO_TENANT = -2


# ---------------------------------------------------------------------------
# Single-query stage primitives (reference math; kept as the oracles the
# kernel tests and benchmarks compare against — the serving paths run the
# engine's BATCHED primitives instead)
# ---------------------------------------------------------------------------

def stage1_scores_jnp(q_msb: jax.Array, msb_plane: jax.Array) -> jax.Array:
    """Approximate MIPS on MSB nibbles. q_msb (D,) int8 in [-8,7];
    msb_plane (N, D//2) uint8 packed. Returns (N,) int32.

    Split-query formulation: byte j of the plane packs dims (2j, 2j+1), so
    the dot product is lo_signed . q_even + hi_signed . q_odd — scoring
    runs directly on the packed plane (two (N, D/2) matvecs), never
    materializing the (N, D) interleaved unpack on the hot path.
    """
    lo, hi = bitplanar.split_nibbles_signed(msb_plane)
    return (similarity.int_matvec(lo, q_msb[0::2])
            + similarity.int_matvec(hi, q_msb[1::2]))


def stage2_scores_jnp(q: jax.Array, msb_rows: jax.Array,
                      lsb_rows: jax.Array) -> jax.Array:
    """Exact INT8 rescoring of gathered candidate rows. q (D,) int8."""
    docs = bitplanar.reconstruct_int8(msb_rows, lsb_rows)     # (C, D) int8
    return similarity.int_matvec(docs, q)


# ---------------------------------------------------------------------------
# Engine-backed retrieval variants
# ---------------------------------------------------------------------------

def two_stage_retrieve(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                       cfg: RetrievalConfig) -> RetrievalResult:
    """Run the hierarchical retrieval for one query over one DB shard.

    query_codes: (D,) int8 (already quantized by the embedder front-end).
    A B=1 lane of the batched engine core.
    """
    return _engine.RetrievalEngine(cfg).retrieve_single(query_codes, db)


def batched_retrieve(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                     cfg: RetrievalConfig) -> RetrievalResult:
    """(B, D) int8 queries -> batched RetrievalResult, ONE launch.

    Batch-native (not a vmap): stage 1 runs as one (N, D/2) x (D/2, B)
    matmul, so the doc plane streams from HBM once for the whole batch.
    """
    return _engine.retrieve_batched(query_codes, db, _engine.PlainPolicy(),
                                    cfg)


@partial(jax.jit, static_argnames=("cfg",))
def exact_retrieve(query_codes: jax.Array, db: quantization.QuantizedDB,
                   cfg: RetrievalConfig) -> RetrievalResult:
    """Single-stage full-precision INT8 retrieval (the paper's baseline)."""
    scores = similarity.int_matvec(db.values, query_codes)
    if cfg.metric == "cosine":
        key = similarity.cosine_key_f32(scores, db.norms_sq)
    else:
        key = scores
    _, idx = jax.lax.top_k(key, cfg.k)
    return RetrievalResult(indices=idx, scores=scores[idx],
                           candidate_indices=idx)


@partial(jax.jit, static_argnames=("cfg",))
def int4_retrieve(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                  cfg: RetrievalConfig) -> RetrievalResult:
    """Pure-INT4 baseline: rank directly on MSB-nibble scores (no stage 2)."""
    q_msb = quantization.msb_nibble(query_codes)
    approx = stage1_scores_jnp(q_msb, db.msb_plane)
    if cfg.metric == "cosine":
        key = similarity.cosine_key_f32(approx, db.norms_sq)
    else:
        key = approx
    _, idx = jax.lax.top_k(key, cfg.k)
    return RetrievalResult(indices=idx, scores=approx[idx],
                           candidate_indices=idx)


def cluster_pruned_retrieve(query_codes: jax.Array,
                            db: bitplanar.BitPlanarDB, codebook,
                            cluster_blocks, labels,
                            cfg: RetrievalConfig, *,
                            nprobe: int, block_rows: int,
                            owner: jax.Array | None = None,
                            tenant_ids: jax.Array | None = None
                            ) -> RetrievalResult:
    """Cluster-pruned cascade over one DB: (B, D) int8 queries, ONE launch.

    The 3-stage cascade (centroid prune -> gathered INT4 scan -> exact
    INT8 rescore): stage 0 scores the `codebook`'s K centroids
    (repro.core.clustering.ClusterCodebook), keeps each lane's top-
    `nprobe` clusters, and stage 1 streams ONLY those clusters' row
    blocks (`cluster_blocks`, from clustering.block_table; `labels` is
    the row -> cluster map the prune uses to keep each row visible only
    through its own cluster's block entry) — stage-1 bytes drop from
    O(N) to O(N * nprobe / K) per lane while stage 2 still rescores
    exactly. Single-corpus callers omit owner/tenant_ids (every gathered
    row is visible); arena callers pass them for segment masking,
    exactly as in the masked variants.
    """
    query_codes = jnp.asarray(query_codes)
    b = query_codes.shape[0]
    n = db.num_docs
    if (owner is None) != (tenant_ids is None):
        raise ValueError("owner and tenant_ids must be passed together "
                         "(segment masking needs both) or both omitted "
                         "(single corpus: every row visible)")
    if owner is None:
        owner = jnp.zeros((n,), jnp.int32)
        tenant_ids = jnp.zeros((b,), jnp.int32)
    policy = _engine.ClusterPolicy(
        owner=owner, tenant_ids=jnp.asarray(tenant_ids, jnp.int32),
        labels=jnp.asarray(labels, jnp.int32),
        centroid_msb=codebook.msb_plane, centroid_norms=codebook.norms_sq,
        cluster_blocks=jnp.asarray(cluster_blocks, jnp.int32),
        nprobe=nprobe, block_rows=block_rows)
    return _engine.retrieve_batched(query_codes, db, policy, cfg)


# ---------------------------------------------------------------------------
# Segment-masked variants (multi-tenant arenas)
# ---------------------------------------------------------------------------

def two_stage_retrieve_masked(query_codes: jax.Array,
                              db: bitplanar.BitPlanarDB,
                              owner: jax.Array, tenant_id: jax.Array,
                              cfg: RetrievalConfig) -> RetrievalResult:
    """Hierarchical retrieval restricted to one tenant's arena segments.

    owner: (N,) int32 slot->tenant map (repro.tenancy.Arena.owner; free and
    tombstoned slots hold -1). Rows with owner != tenant_id are masked to
    -inf in stage 1 and pinned to the floor score in stage 2, so a query
    can never surface another tenant's (or a dead) document. Returned
    indices are arena slot ids; slots the tenant could not fill (fewer
    live docs than k) come back as -1 with score 0.

    This is the fully general path: it scans the WHOLE arena and works for
    arbitrarily fragmented tenants. When every tenant in a batch is one
    contiguous segment, prefer `windowed_retrieve_masked`.
    """
    policy = _engine.MaskedPolicy(
        owner=owner, tenant_ids=jnp.asarray(tenant_id, jnp.int32)[None])
    return _engine.RetrievalEngine(cfg).retrieve_single(query_codes, db,
                                                        policy)


def batched_retrieve_masked(query_codes: jax.Array,
                            db: bitplanar.BitPlanarDB, owner: jax.Array,
                            tenant_ids: jax.Array,
                            cfg: RetrievalConfig) -> RetrievalResult:
    """Cross-tenant batch: (B, D) queries + (B,) tenant ids, ONE launch.

    The segment-masked batched core over the shared arena — the
    scheduler's kernel-level primitive. Stage 1 streams the arena's MSB
    plane ONCE for the whole mixed batch (true matmul, not B matvecs).
    """
    policy = _engine.MaskedPolicy(owner=owner,
                                  tenant_ids=jnp.asarray(tenant_ids,
                                                         jnp.int32))
    return _engine.retrieve_batched(query_codes, db, policy, cfg)


def windowed_retrieve_masked(query_codes: jax.Array,
                             db: bitplanar.BitPlanarDB, owner: jax.Array,
                             tenant_ids: jax.Array, starts: jax.Array,
                             cfg: RetrievalConfig,
                             window: int) -> RetrievalResult:
    """Cross-tenant batch over a tenant-CONTIGUOUS arena, one launch.

    When each requested tenant occupies a single contiguous slot run (the
    invariant bump allocation establishes and tenant-grouped compaction
    restores), batch lane i only streams the `window` rows starting at its
    tenant's segment — so a mixed batch of B users costs one launch AND
    only per-tenant work, instead of B arena-wide scans. Rows inside the
    window but outside the segment (neighbours, tombstones) are masked
    exactly like the full-scan variant. Returned indices are global arena
    slot ids.

    window: static upper bound on any requested tenant's segment length
    (callers round up to a power-of-two bucket to bound recompilation),
    and must be >= cfg.k (MultiTenantIndex guarantees this).
    """
    policy = _engine.WindowedPolicy(
        owner=owner, tenant_ids=jnp.asarray(tenant_ids, jnp.int32),
        starts=starts, window=window)
    return _engine.retrieve_batched(query_codes, db, policy, cfg)


# Bottom import: engine defines the shared batched core and imports the
# config/result types above, so this intentionally runs after they exist.
from repro.core import engine as _engine                     # noqa: E402
