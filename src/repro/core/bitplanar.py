"""Bit-planar / nibble-planar storage of INT8 embedding databases.

The paper stores a 512-dim INT8 embedding as 8 DRAM rows of 512 bits — one
row per bit position — so stage 1 can fetch only the 4 MSB rows (half the
traffic). TPUs cannot address single bits in HBM, so the streaming path of
this framework uses the *nibble-planar* degradation: two planes,

    msb_plane: (N, D/2) uint8 — two MSB nibbles packed per byte
    lsb_plane: (N, D/2) uint8 — two LSB nibbles packed per byte

which preserves exactly the 4+4 split the paper exploits (stage 1 touches
only msb_plane = 1/2 the bytes). The full 8-plane layout is also implemented
(pack_bitplanes/unpack_bitplanes) for fidelity and for the energy simulator,
which accounts traffic at bit-row granularity like the ASIC.

All pack/unpack functions are exact inverses (tested by property tests).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# Nibble planes (the TPU streaming layout)
# ---------------------------------------------------------------------------

def pack_nibble_planes(codes_int8: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Split (N, D) int8 into (msb_plane, lsb_plane), each (N, D//2) uint8.

    Byte j of a plane packs dims (2j, 2j+1): low nibble = dim 2j,
    high nibble = dim 2j+1. Nibbles are stored in raw two's-complement
    (msb nibble of value v is (v >> 4) & 0xF).
    """
    n, d = codes_int8.shape
    assert d % 2 == 0, "dimension must be even to pack 2 nibbles per byte"
    u = codes_int8.view(jnp.uint8) if codes_int8.dtype == jnp.int8 else codes_int8.astype(jnp.uint8)
    msb = (u >> 4) & jnp.uint8(0xF)           # (N, D) raw msb nibbles
    lsb = u & jnp.uint8(0xF)                  # (N, D) raw lsb nibbles

    def _pack(nib):  # (N, D) 4-bit values -> (N, D//2) bytes
        nib = nib.reshape(n, d // 2, 2)
        return (nib[..., 0] | (nib[..., 1] << 4)).astype(jnp.uint8)

    return _pack(msb), _pack(lsb)


def split_nibbles_signed(plane: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Packed plane -> (lo, hi) SIGNED int8 nibble matrices, NOT interleaved.

    lo holds even dims (2j), hi holds odd dims (2j+1), each same shape as
    `plane`. This is the hot-path split-query view: scoring runs directly
    on the packed layout (lo . q_even + hi . q_odd) with the nibbles
    sign-extended by two arithmetic int8 shifts — the (.., D) interleaved
    unpack is never materialized.
    """
    b = plane.view(jnp.int8)
    return (b << 4) >> 4, b >> 4


def unpack_nibble_plane_signed(plane: jax.Array) -> jax.Array:
    """(N, D//2) uint8 msb-plane -> (N, D) int8 signed nibbles in [-8, 7]."""
    lo = plane & jnp.uint8(0xF)
    hi = (plane >> 4) & jnp.uint8(0xF)
    nib = jnp.stack([lo, hi], axis=-1).reshape(plane.shape[0], -1)
    # sign-extend 4-bit two's complement
    return (nib.astype(jnp.int8) ^ jnp.int8(8)) - jnp.int8(8)


def unpack_nibble_plane_unsigned(plane: jax.Array) -> jax.Array:
    """(N, D//2) uint8 lsb-plane -> (N, D) int8 unsigned nibbles in [0, 15]."""
    lo = plane & jnp.uint8(0xF)
    hi = (plane >> 4) & jnp.uint8(0xF)
    return jnp.stack([lo, hi], axis=-1).reshape(plane.shape[0], -1).astype(jnp.int8)


def reconstruct_int8(msb_plane: jax.Array, lsb_plane: jax.Array) -> jax.Array:
    """Exact inverse of pack_nibble_planes."""
    msb = unpack_nibble_plane_signed(msb_plane).astype(jnp.int16)
    lsb = unpack_nibble_plane_unsigned(lsb_plane).astype(jnp.int16)
    return (msb * 16 + lsb).astype(jnp.int8)


def expand_block_rows(block_ids: jax.Array, block_rows: int) -> jax.Array:
    """(B, J) block ids -> (B, J * block_rows) row ids, block-major.

    THE row-numbering convention of the block-gather path: row r of block
    b is global row b * block_rows + r, laid out block after block. The
    jnp gather, the Pallas kernel's BlockSpec index math, and the
    cascade's prune bookkeeping all derive their row ids from here, so
    they cannot drift apart."""
    b = block_ids.shape[0]
    return (block_ids[:, :, None] * block_rows
            + jnp.arange(block_rows, dtype=jnp.int32)).reshape(b, -1)


def gather_blocks(plane: jax.Array, block_ids: jax.Array,
                  block_rows: int) -> tuple[jax.Array, jax.Array]:
    """Expand per-lane block ids into a materialized row gather.

    plane (N, D2) x block_ids (B, J) int32 (pre-clamped to valid blocks)
    -> (gathered (B, J * block_rows, D2), rows (B, J * block_rows) global
    row ids). Rows past N read as ZERO rows — exactly what the Pallas
    gather kernel's zero-padded plane streams — so every consumer of this
    helper (the jnp engine backend, the kernel oracle) shares one
    definition of the out-of-range convention and stays bit-equal to the
    kernel by construction.
    """
    n = plane.shape[0]
    rows = expand_block_rows(block_ids, block_rows)
    gathered = jnp.take(plane, jnp.minimum(rows, n - 1), axis=0)
    gathered = jnp.where((rows < n)[:, :, None], gathered, jnp.uint8(0))
    return gathered, rows


# ---------------------------------------------------------------------------
# Sign plane (the stage-0 prescreen's 1-bit layout)
# ---------------------------------------------------------------------------

def pack_sign_plane(codes_int8: jax.Array) -> jax.Array:
    """(N, D) int8 -> (N, D//8) uint8 sign plane.

    Bit k%8 of byte k//8 is the INT8 sign bit of dim k (1 iff the value is
    negative) — the same dim -> (byte, bit) convention as plane 7 of
    `pack_bitplanes`, so the sign plane IS the MSB bit-plane of the full
    8-plane layout, stored standalone at 1 bit/dim (4x fewer bytes than
    the MSB nibble plane). The stage-0 prescreen scores sign agreement
    over this plane before any nibble bytes are touched.
    """
    n, d = codes_int8.shape
    assert d % 8 == 0, "dimension must be a multiple of 8 for sign packing"
    bits = (codes_int8 < 0).astype(jnp.uint8).reshape(n, d // 8, 8)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint8)


def sign_plane_from_msb(msb_plane: jax.Array) -> jax.Array:
    """Derive the sign plane from a packed MSB nibble plane.

    Byte j of the nibble plane packs dims (2j, 2j+1) with the 4-bit two's-
    complement sign in bit 3 (low nibble / even dim) and bit 7 (high
    nibble / odd dim) — and the INT4 MSB nibble's sign bit IS the INT8
    sign bit, so the sign plane is a pure bit-extraction of the nibble
    plane. Exactly `pack_sign_plane(reconstruct_int8(msb, lsb))` for any
    lsb, which is what lets serving paths rebuild a combined sign plane
    from an already-combined nibble plane instead of running a second
    fill pipeline.
    """
    n, d2 = msb_plane.shape
    assert (d2 * 2) % 8 == 0
    lo = (msb_plane >> 3) & jnp.uint8(1)         # sign of even dims (2j)
    hi = (msb_plane >> 7) & jnp.uint8(1)         # sign of odd dims (2j+1)
    bits = jnp.stack([lo, hi], axis=-1).reshape(n, d2 * 2 // 8, 8)
    shifts = jnp.arange(8, dtype=jnp.uint8)
    return jnp.sum(bits << shifts, axis=-1).astype(jnp.uint8)


def unpack_sign_pm1(sign_plane: jax.Array) -> jax.Array:
    """(..., D//8) uint8 sign plane -> (..., D) int8 in {+1, -1}.

    Dim k maps to ``1 - 2 * bit`` (bit set = negative value = -1), so the
    sign-agreement score is a plain +/-1 dot product: ``sum_k sign(q_k) *
    sign(d_k) = 2 * agreements - D`` — a monotone transform of the
    XNOR-popcount count, computable on the MXU as an int8 matmul. A zero
    value (and a zeroed tombstone row) unpacks to +1 on every dim.
    """
    shifts = jnp.arange(8, dtype=jnp.uint8)
    bits = (sign_plane[..., :, None] >> shifts) & jnp.uint8(1)
    bits = bits.reshape(*sign_plane.shape[:-1], sign_plane.shape[-1] * 8)
    return (jnp.int8(1) - jnp.int8(2) * bits.astype(jnp.int8))


def sign_pm1(codes: jax.Array) -> jax.Array:
    """int8 codes/queries -> {+1, -1} int8 signs (0 maps to +1, matching
    `unpack_sign_pm1` of the packed plane bit-for-bit)."""
    return jnp.where(codes < 0, jnp.int8(-1), jnp.int8(1))


# ---------------------------------------------------------------------------
# Full 8-plane bit-planar layout (ASIC-faithful; used by the energy model)
# ---------------------------------------------------------------------------

def pack_bitplanes(codes_int8: jax.Array) -> jax.Array:
    """(N, D) int8 -> (8, N, D//8) uint8 bit-planes.

    Plane b holds bit b (b=7 is the sign/MSB bit) of all D dims, packed
    8 dims per byte (dim k -> byte k//8, bit k%8). Mirrors one DRAM row
    per bit position in the paper's layout.
    """
    n, d = codes_int8.shape
    assert d % 8 == 0
    u = codes_int8.view(jnp.uint8) if codes_int8.dtype == jnp.int8 else codes_int8.astype(jnp.uint8)
    planes = []
    shifts = jnp.arange(8, dtype=jnp.uint8)  # bit position within packed byte
    for b in range(8):
        bits = (u >> b) & jnp.uint8(1)                       # (N, D)
        bits = bits.reshape(n, d // 8, 8)
        packed = jnp.sum(bits << shifts, axis=-1).astype(jnp.uint8)
        planes.append(packed)
    return jnp.stack(planes, axis=0)


def unpack_bitplanes(planes: jax.Array, *, num_planes: int = 8) -> jax.Array:
    """(8, N, D//8) uint8 -> (N, D) int8.

    With num_planes < 8, only the top `num_planes` bit-planes are read
    (the rest stay "in DRAM") and the value is reconstructed with the
    missing low bits as zero — exactly what the stage-1 MSB read does.
    """
    _, n, db = planes.shape
    d = db * 8
    shifts = jnp.arange(8, dtype=jnp.uint8)
    acc = jnp.zeros((n, d), dtype=jnp.uint8)
    for b in range(8 - num_planes, 8):
        packed = planes[b]
        bits = ((packed[..., None] >> shifts) & jnp.uint8(1)).reshape(n, d)
        acc = acc | (bits << jnp.uint8(b))
    return acc.view(jnp.int8)


@dataclasses.dataclass(frozen=True)
class BitPlanarDB:
    """Nibble-planar database as streamed on TPU.

    msb_plane, lsb_plane: (N, D//2) uint8.
    norms_sq: (N,) int64 integer squared norms of the full INT8 codes.
    scale: dequant scale (see quantization.QuantizedDB).
    sign_plane: optional (N, D//8) uint8 1-bit sign plane for the stage-0
    prescreen (see `pack_sign_plane`). None when the corpus was built
    without one — the engine derives it from the MSB plane on demand, so
    prescreen-enabled retrieval works against any DB, but maintained
    storage (the arena) carries it explicitly so the derivation never
    lands on the hot path.
    """

    msb_plane: jax.Array
    lsb_plane: jax.Array
    norms_sq: jax.Array
    scale: jax.Array
    sign_plane: jax.Array | None = None

    @property
    def num_docs(self) -> int:
        return self.msb_plane.shape[0]

    @property
    def dim(self) -> int:
        return self.msb_plane.shape[1] * 2

    @classmethod
    def from_quantized(cls, db) -> "BitPlanarDB":
        msb, lsb = pack_nibble_planes(db.values)
        sign = (pack_sign_plane(db.values)
                if db.values.shape[1] % 8 == 0 else None)
        return cls(msb_plane=msb, lsb_plane=lsb, norms_sq=db.norms_sq,
                   scale=db.scale, sign_plane=sign)


jax.tree_util.register_pytree_node(
    BitPlanarDB,
    lambda db: ((db.msb_plane, db.lsb_plane, db.norms_sq, db.scale,
                 db.sign_plane), None),
    lambda _, leaves: BitPlanarDB(*leaves),
)
