"""Symmetric INT8 / INT4 quantization for embedding databases.

The paper stores every document embedding as INT8 (symmetric, zero-point 0)
and derives the stage-1 approximate representation from the most-significant
nibble of each INT8 value: for v in [-128, 127],

    msb(v)  = v >> 4            (arithmetic shift, range [-8, 7]   -> "INT4")
    lsb(v)  = v & 0xF           (range [0, 15], unsigned nibble)
    v       = msb(v) * 16 + lsb(v)      (exact reconstruction)

Stage 1 computes MIPS on (msb(q), msb(d)); stage 2 on the full INT8 values.
All functions are jit-safe pure JAX.
"""
from __future__ import annotations

import dataclasses
import math
from functools import partial

import jax
import jax.numpy as jnp

INT8_MAX = 127
INT4_MAX = 7


@dataclasses.dataclass(frozen=True)
class QuantizedDB:
    """An INT8-quantized embedding database.

    values: (N, D) int8 quantized embeddings.
    scale:  () or (N,) float32 dequant scale (x ~= values * scale).
    norms_sq: (N,) int32 — integer squared L2 norms of the INT8 codes,
        precomputed offline (the paper stores document norms in DRAM).
        Fits int32 for D <= 2**31 / 127**2 ~= 133k dims.
    """

    values: jax.Array
    scale: jax.Array
    norms_sq: jax.Array

    @property
    def num_docs(self) -> int:
        return self.values.shape[0]

    @property
    def dim(self) -> int:
        return self.values.shape[1]


def quantize_int8(x: jax.Array, *, per_vector: bool = False) -> tuple[jax.Array, jax.Array]:
    """Symmetric INT8 quantization. Returns (codes int8, scale f32)."""
    x = x.astype(jnp.float32)
    if per_vector:
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / INT8_MAX
    codes = jnp.clip(jnp.round(x / scale), -INT8_MAX - 1, INT8_MAX).astype(jnp.int8)
    return codes, jnp.squeeze(scale, axis=-1) if per_vector else scale


def quantize_int4(x: jax.Array, *, per_vector: bool = False) -> tuple[jax.Array, jax.Array]:
    """Symmetric INT4 quantization (codes stored widened to int8 in [-8, 7])."""
    x = x.astype(jnp.float32)
    if per_vector:
        amax = jnp.max(jnp.abs(x), axis=-1, keepdims=True)
    else:
        amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / INT4_MAX
    codes = jnp.clip(jnp.round(x / scale), -INT4_MAX - 1, INT4_MAX).astype(jnp.int8)
    return codes, jnp.squeeze(scale, axis=-1) if per_vector else scale


def unit_norm_scale(dim: int) -> float:
    """Default fixed scale for L2-normalized embeddings of dimension `dim`.

    The max-abs coordinate of a random unit vector concentrates near
    sqrt(2 ln D / D); 4/sqrt(D) covers it with slack, so codes use most of
    the INT8 range and only extreme outlier coordinates saturate.
    """
    return 4.0 / (INT8_MAX * math.sqrt(dim))


def quantize_int8_fixed(x: jax.Array, scale) -> jax.Array:
    """Symmetric INT8 quantization with a FIXED, caller-supplied scale.

    The streaming/online path quantizes rows at different times into one
    shared arena, so the scale cannot be re-derived from each batch (rows
    must stay mutually comparable). Values beyond scale*127 saturate.
    """
    x = jnp.asarray(x).astype(jnp.float32)
    return jnp.clip(jnp.round(x / scale),
                    -INT8_MAX - 1, INT8_MAX).astype(jnp.int8)


def dequantize(codes: jax.Array, scale: jax.Array) -> jax.Array:
    scale = jnp.asarray(scale)
    if scale.ndim == 1:  # per-vector
        scale = scale[:, None]
    return codes.astype(jnp.float32) * scale


def msb_nibble(codes_int8: jax.Array) -> jax.Array:
    """Most-significant nibble of INT8 codes: arithmetic >> 4, range [-8, 7]."""
    return (codes_int8.astype(jnp.int8) >> 4).astype(jnp.int8)


def lsb_nibble(codes_int8: jax.Array) -> jax.Array:
    """Least-significant nibble, range [0, 15] (unsigned), returned as int8."""
    return (codes_int8.astype(jnp.int8) & jnp.int8(0xF)).astype(jnp.int8)


def reconstruct_from_nibbles(msb: jax.Array, lsb: jax.Array) -> jax.Array:
    """Exact inverse of the (msb, lsb) split."""
    return (msb.astype(jnp.int16) * 16 + lsb.astype(jnp.int16)).astype(jnp.int8)


@partial(jax.jit, static_argnames=("per_vector",))
def build_database(embeddings: jax.Array, *, per_vector: bool = False) -> QuantizedDB:
    """Offline phase: quantize a float embedding matrix into a QuantizedDB."""
    codes, scale = quantize_int8(embeddings, per_vector=per_vector)
    norms_sq = jnp.sum(codes.astype(jnp.int32) ** 2, axis=-1)
    return QuantizedDB(values=codes, scale=scale, norms_sq=norms_sq)


jax.tree_util.register_pytree_node(
    QuantizedDB,
    lambda db: ((db.values, db.scale, db.norms_sq), None),
    lambda _, leaves: QuantizedDB(*leaves),
)
