"""INT8 k-means codebooks for the cascade's centroid-prune stage.

The two-stage hierarchy still streams the MSB nibble of EVERY document in
stage 1, so stage-1 bytes grow linearly with the arena — exactly what
breaks edge serving at scale. Following the IVF recipe EdgeRAG applies to
on-device RAG, a small codebook of K centroids is kept resident; a query
first scores the K centroids (stage 0), selects its top-`nprobe` clusters,
and the INT4 plane scan then touches only rows in those clusters. The
codebook lives in the SAME representation as the documents — INT8 codes
with a packed MSB nibble plane and integer squared norms — so centroid
scoring reuses the batched stage-1 kernels unchanged and stays exact
integer arithmetic (bit-identical between the jnp and Pallas backends).

Two layers:

  * `kmeans_int8` / `assign_codes` — offline batch clustering of INT8 code
    matrices. All distance math is exact int32 (argmin ||x-c||^2 via
    argmax 2<x,c> - ||c||^2), so assignment is deterministic across
    backends; means are computed in float and re-quantized to INT8, which
    keeps centroids streamable through the nibble-planar kernels.
  * `ClusterIndex` — the ONLINE maintainer the streaming arena needs: it
    holds per-cluster running sums/counts, assigns new rows to the nearest
    centroid in O(rows * K), retires deleted rows from the sums, and
    `refresh()` re-derives centroids from the running sums without ever
    re-reading the corpus (no rebuild — the EdgeRAG online-maintenance
    argument).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplanar, similarity


@dataclasses.dataclass(frozen=True)
class ClusterParams:
    """Host-side knobs for a cluster-pruned deployment.

    num_clusters: codebook size K (centroid plane = K * D/2 bytes,
        resident). nprobe: clusters scanned per query — the stage-1
        fraction is ~nprobe / K. block_rows: plane-block granularity of
        the gather (MXU-friendly multiples of 8; larger blocks = denser
        DMA, more over-read at cluster boundaries).
    """

    num_clusters: int
    nprobe: int = 8
    block_rows: int = 64
    kmeans_iters: int = 8
    seed: int = 0


@dataclasses.dataclass(frozen=True)
class ClusterCodebook:
    """K centroids in the documents' own INT8/nibble-planar representation.

    codes: (K, D) int8 centroid codes (same fixed scale as the corpus).
    msb_plane: (K, D//2) uint8 packed MSB nibbles — what stage 0 streams.
    norms_sq: (K,) int32 squared norms of the INT8 codes (cosine sidecar).
    """

    codes: jax.Array
    msb_plane: jax.Array
    norms_sq: jax.Array

    @property
    def num_clusters(self) -> int:
        return self.codes.shape[0]

    @property
    def dim(self) -> int:
        return self.codes.shape[1]

    @classmethod
    def from_codes(cls, codes) -> "ClusterCodebook":
        codes = jnp.asarray(codes, jnp.int8)
        msb, _ = bitplanar.pack_nibble_planes(codes)
        norms = jnp.sum(codes.astype(jnp.int32) ** 2, axis=-1)
        return cls(codes=codes, msb_plane=msb, norms_sq=norms)


jax.tree_util.register_pytree_node(
    ClusterCodebook,
    lambda c: ((c.codes, c.msb_plane, c.norms_sq), None),
    lambda _, leaves: ClusterCodebook(*leaves),
)


def assign_codes(codes, centroid_codes) -> np.ndarray:
    """Nearest-centroid assignment of INT8 codes, exact integer math.

    argmin_c ||x - c||^2 == argmax_c 2<x,c> - ||c||^2 (the ||x||^2 term is
    constant per row), computed entirely in int32, so the labels are
    deterministic and backend-independent. Returns (N,) int32 labels.
    """
    codes = jnp.asarray(codes, jnp.int8)
    cents = jnp.asarray(centroid_codes, jnp.int8)
    dots = similarity.int_matmul(cents, codes)              # (N, K) int32
    cnorm = jnp.sum(cents.astype(jnp.int32) ** 2, axis=-1)  # (K,)
    return np.asarray(jnp.argmax(2 * dots - cnorm[None, :], axis=1),
                      np.int32)


def kmeans_int8(codes, num_clusters: int, *, iters: int = 8,
                seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Batch k-means over an INT8 code matrix.

    Assignment runs in exact int32 (`assign_codes`); the update step takes
    float means and rounds back to INT8, so the returned centroids stay in
    the corpus representation (streamable planes, integer norms). Empty
    clusters keep their previous centroid. Deterministic for a given seed.

    Returns (centroid_codes (K, D) int8 numpy, labels (N,) int32 numpy).
    """
    codes_np = np.asarray(codes, np.int8)
    n = codes_np.shape[0]
    k = min(num_clusters, n)
    if k < 1:
        raise ValueError("kmeans needs at least one row and one cluster")
    rng = np.random.default_rng(seed)
    cents = codes_np[rng.permutation(n)[:k]].astype(np.int8)
    labels = np.zeros(n, np.int32)
    for _ in range(iters):
        labels = assign_codes(codes_np, cents)
        new = cents.astype(np.float64).copy()
        for c in range(k):
            members = codes_np[labels == c]
            if len(members):
                new[c] = members.astype(np.float64).mean(axis=0)
        cents = np.clip(np.rint(new), -128, 127).astype(np.int8)
    labels = assign_codes(codes_np, cents)
    return cents, labels


def cluster_grouped_order(labels) -> np.ndarray:
    """Row permutation grouping rows by cluster label (stable within a
    cluster). Packing a corpus in this order makes each cluster a handful
    of CONTIGUOUS blocks, so the prune's block gather is dense."""
    return np.argsort(np.asarray(labels), kind="stable")


def block_table(labels, num_clusters: int, block_rows: int, *,
                rows=None, min_blocks: int = 1,
                pad_pow2: bool = True) -> np.ndarray:
    """(K, MB) int32 table: the ids of the `block_rows`-row blocks holding
    each cluster's rows, -1 padded.

    Correct for ANY row layout (a fragmented cluster just lists more
    blocks); after cluster-grouped packing each cluster covers
    ~ceil(rows / block_rows) + 1 blocks. MB is the max over clusters,
    rounded up to a power of two (bounds jit recompiles, since MB is a
    static shape). Rows with label < 0 (free/tombstoned) are skipped.
    `rows` restricts the table to a subset of row ids (the multi-tenant
    layer passes one tenant's slots, so the cost is O(S log S) in the
    tenant's rows, not O(capacity)). One vectorized groupby pass —
    no per-cluster scan.
    """
    labels = np.asarray(labels)
    if rows is None:
        rows = np.nonzero((labels >= 0) & (labels < num_clusters))[0]
        labs = labels[rows]
    else:
        rows = np.asarray(rows, np.int64)
        labs = labels[rows]
        keep = (labs >= 0) & (labs < num_clusters)
        rows, labs = rows[keep], labs[keep]
    # unique (label, block) pairs, lexicographically sorted by label
    labs, blocks = np.unique(np.stack([labs, rows // block_rows]), axis=1)
    counts = np.bincount(labs, minlength=num_clusters)
    mb = max(min_blocks, int(counts.max()) if counts.size else 0)
    if pad_pow2:
        mb = 1 << (mb - 1).bit_length()
    table = np.full((num_clusters, mb), -1, np.int32)
    starts = np.concatenate(([0], np.cumsum(counts)[:-1]))
    table[labs, np.arange(labs.size) - starts[labs]] = blocks
    return table


class ClusterIndex:
    """Online-maintained cluster assignments for a streaming corpus.

    The codebook is trained once on the first ingested batch (lazily, via
    `kmeans_int8`) and then maintained incrementally: `add` assigns new
    rows in O(rows * K) and folds them into per-cluster running sums,
    `remove` retires deleted rows from the sums, and `refresh` re-derives
    the INT8 centroids from the sums — never touching the corpus again.
    `generation` bumps whenever the centroids change, so device-side
    codebook views can be cached per generation.
    """

    def __init__(self, num_clusters: int, dim: int, *, seed: int = 0,
                 iters: int = 8):
        if num_clusters < 1:
            raise ValueError("num_clusters must be >= 1")
        self.num_clusters = num_clusters
        self.dim = dim
        self.seed = seed
        self.iters = iters
        self.generation = 0
        self._centroids: np.ndarray | None = None          # (K, D) int8
        self._sums = np.zeros((num_clusters, dim), np.float64)
        self._counts = np.zeros(num_clusters, np.int64)
        self._codebook_cache: tuple[int, ClusterCodebook] | None = None

    @property
    def trained(self) -> bool:
        return self._centroids is not None

    def codebook(self) -> ClusterCodebook:
        """Device-side ClusterCodebook view, cached per generation."""
        if not self.trained:
            raise RuntimeError("ClusterIndex has no codebook yet (no rows "
                               "ingested); call add() first")
        if (self._codebook_cache is None
                or self._codebook_cache[0] != self.generation):
            self._codebook_cache = (
                self.generation, ClusterCodebook.from_codes(self._centroids))
        return self._codebook_cache[1]

    # -- online maintenance --------------------------------------------------

    def add(self, codes) -> np.ndarray:
        """Assign (B, D) int8 rows to clusters; returns (B,) int32 labels.

        The first call trains the codebook on the batch itself (K is
        clamped to the batch size if smaller — the codebook can only be as
        diverse as the data seen so far); later calls assign against the
        current centroids and update the running sums.
        """
        codes_np = np.asarray(codes, np.int8)
        if codes_np.ndim != 2 or codes_np.shape[1] != self.dim:
            raise ValueError(f"codes must be (B, {self.dim}) int8")
        if not self.trained:
            cents, labels = kmeans_int8(codes_np, self.num_clusters,
                                        iters=self.iters, seed=self.seed)
            if cents.shape[0] < self.num_clusters:       # tiny first batch
                pad = np.zeros((self.num_clusters - cents.shape[0],
                                self.dim), np.int8)
                cents = np.concatenate([cents, pad])
            self._centroids = cents
            self.generation += 1
        else:
            labels = assign_codes(codes_np, self._centroids)
        np.add.at(self._sums, labels, codes_np.astype(np.float64))
        np.add.at(self._counts, labels, 1)
        return labels

    def remove(self, codes, labels) -> None:
        """Retire deleted rows (given their codes AND labels) from the sums."""
        codes_np = np.asarray(codes, np.int8)
        labels = np.asarray(labels, np.int32)
        np.subtract.at(self._sums, labels, codes_np.astype(np.float64))
        np.subtract.at(self._counts, labels, 1)

    def refresh(self) -> None:
        """Re-derive centroids from the running sums (no corpus re-read).

        Empty clusters keep their previous centroid so their slot stays
        warm for future inserts. Bumps `generation` only if a centroid
        actually moved."""
        if not self.trained:
            return
        occ = self._counts > 0
        new = self._centroids.astype(np.float64).copy()
        new[occ] = self._sums[occ] / self._counts[occ, None]
        new = np.clip(np.rint(new), -128, 127).astype(np.int8)
        if not np.array_equal(new, self._centroids):
            self._centroids = new
            self.generation += 1
