"""Similarity computation: integer MIPS / cosine + non-division comparator.

The paper's rerank unit compares cosine similarities WITHOUT division or
sqrt: to order  s_a / sqrt(n_a)  vs  s_b / sqrt(n_b)  (s = integer dot
product, n = integer squared doc norm; the query norm is common and
cancels), it cross-multiplies squares:

    sign-aware compare of   s_a^2 * n_b   vs   s_b^2 * n_a

With D = 512 and INT8 codes, s^2*n needs up to ~69 bits, which overflows
int64. The hardware uses a wide comparator; here we emulate the wide
product exactly with 15-bit limbs held in uint32 lanes (no float, no
division, no 64-bit dependence — faithful to the paper's integer-only
rerank pipeline and safe inside jit/vmap on 32-bit-default JAX). A float32
fast path (score/sqrt(norm)) is also provided; property tests assert both
produce the same ordering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Exact integer dot product of int8 codes -> int32. a:(...,D) b:(...,D)."""
    return jnp.sum(a.astype(jnp.int32) * b.astype(jnp.int32), axis=-1)


def int_matvec(db: jax.Array, q: jax.Array) -> jax.Array:
    """(N, D) int8 x (D,) int8 -> (N,) int32 scores (MIPS), exact.

    When every partial sum provably fits float32's 24-bit integer window
    (D * 128 * 128 <= 2**24, i.e. D <= 1024 — codes reach -128, true for
    the paper's D=512), the product runs as an f32 GEMM — bit-exact, and
    on CPU it hits the BLAS path instead of XLA's scalar int8 loop (~10x
    on the arena-scan hot path). Larger D falls back to the int32 dot.
    """
    d = db.shape[-1]
    if d * 128 * 128 <= 2 ** 24:
        return jax.lax.dot_general(
            db.astype(jnp.float32), q.astype(jnp.float32),
            dimension_numbers=(((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        ).astype(jnp.int32)
    return jax.lax.dot_general(
        db.astype(jnp.int8), q.astype(jnp.int8),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def int_matmul(db: jax.Array, q: jax.Array) -> jax.Array:
    """(N, D) int8 x (B, D) int8 -> (B, N) int32 scores, exact.

    The batched-engine analogue of `int_matvec`: one true matmul, so the
    database rows are streamed from memory ONCE for the whole query batch
    instead of once per query. Same f32-GEMM exact fast path (every partial
    sum fits float32's 24-bit integer window when D * 128 * 128 <= 2**24).
    """
    dn = (((1,), (1,)), ((), ()))
    if db.shape[-1] * 128 * 128 <= 2 ** 24:
        return jax.lax.dot_general(
            q.astype(jnp.float32), db.astype(jnp.float32),
            dimension_numbers=dn,
            preferred_element_type=jnp.float32).astype(jnp.int32)
    return jax.lax.dot_general(
        q.astype(jnp.int8), db.astype(jnp.int8),
        dimension_numbers=dn, preferred_element_type=jnp.int32)


def int_bmm(rows: jax.Array, q: jax.Array) -> jax.Array:
    """(B, M, D) int8 x (B, D) int8 -> (B, M) int32, exact per-lane scores.

    Each batch lane dots its OWN row block against its own query (the
    windowed / gathered-candidate shape). Same exactness argument as
    `int_matmul` for the f32 fast path.
    """
    dn = (((2,), (1,)), ((0,), (0,)))
    if rows.shape[-1] * 128 * 128 <= 2 ** 24:
        return jax.lax.dot_general(
            rows.astype(jnp.float32), q.astype(jnp.float32),
            dimension_numbers=dn,
            preferred_element_type=jnp.float32).astype(jnp.int32)
    return jax.lax.dot_general(
        rows.astype(jnp.int8), q.astype(jnp.int8),
        dimension_numbers=dn, preferred_element_type=jnp.int32)


# 15-bit limbs: a product of two limbs is < 2**30, so every partial sum in
# the schoolbook multiply stays strictly below 2**31 and is exact in uint32.
_LIMB = 15
_LIMB_MASK = jnp.uint32((1 << _LIMB) - 1)


def _to_limbs(x: jax.Array, num_limbs: int) -> list[jax.Array]:
    """Non-negative int32/uint32 -> little-endian 15-bit limbs (uint32)."""
    x = x.astype(jnp.uint32)
    return [(x >> jnp.uint32(_LIMB * i)) & _LIMB_MASK for i in range(num_limbs)]


def _mul_limbs(a: list[jax.Array], b: list[jax.Array]) -> list[jax.Array]:
    """Exact schoolbook product of limb vectors -> len(a)+len(b) limbs."""
    out = [jnp.zeros_like(a[0]) for _ in range(len(a) + len(b))]
    for i, ai in enumerate(a):
        carry = jnp.zeros_like(ai)
        for j, bj in enumerate(b):
            t = out[i + j] + ai * bj + carry
            out[i + j] = t & _LIMB_MASK
            carry = t >> jnp.uint32(_LIMB)
        for k in range(i + len(b), len(out)):        # ripple the last carry
            t = out[k] + carry
            out[k] = t & _LIMB_MASK
            carry = t >> jnp.uint32(_LIMB)
    return out


def _limbs_gt_lt(a: list[jax.Array],
                 b: list[jax.Array]) -> tuple[jax.Array, jax.Array]:
    """Lexicographic (a > b, a < b) over equal-length limb vectors."""
    gt = jnp.zeros(a[0].shape, bool)
    eq = jnp.ones(a[0].shape, bool)
    for a_l, b_l in zip(reversed(a), reversed(b)):
        gt = gt | (eq & (a_l > b_l))
        eq = eq & (a_l == b_l)
    return gt, ~gt & ~eq


def fraction_greater(s_a: jax.Array, n_a: jax.Array,
                     s_b: jax.Array, n_b: jax.Array) -> jax.Array:
    """Non-division comparator:  s_a/sqrt(n_a) > s_b/sqrt(n_b)  (elementwise).

    s_*: int32 dot products (any magnitude except INT32_MIN, may be
    negative); n_*: int32 squared norms >= 0. Zero norms are treated as
    similarity 0 (degenerate all-zero code). Pure integer arithmetic — no
    division, sqrt, floats, or 64-bit types: the up-to-93-bit cross
    products s^2 * n are computed exactly in 15-bit limbs (the paper's
    wide comparator), so the function is safe under jit/vmap with JAX's
    default 32-bit ints.
    """
    s_a = jnp.asarray(s_a).astype(jnp.int32)
    s_b = jnp.asarray(s_b).astype(jnp.int32)
    n_a = jnp.asarray(n_a).astype(jnp.int32)
    n_b = jnp.asarray(n_b).astype(jnp.int32)
    sign_a = jnp.where(n_a > 0, jnp.sign(s_a), 0)
    sign_b = jnp.where(n_b > 0, jnp.sign(s_b), 0)

    # |s| <= 2**31 - 1 -> 3 limbs; s^2 -> 6 limbs; s^2 * n -> 9 limbs.
    abs_a = _to_limbs(jnp.abs(s_a), 3)
    abs_b = _to_limbs(jnp.abs(s_b), 3)
    prod_a = _mul_limbs(_mul_limbs(abs_a, abs_a),
                        _to_limbs(jnp.maximum(n_b, 1), 3))
    prod_b = _mul_limbs(_mul_limbs(abs_b, abs_b),
                        _to_limbs(jnp.maximum(n_a, 1), 3))
    mag_gt, mag_lt = _limbs_gt_lt(prod_a, prod_b)

    both_pos = (sign_a > 0) & (sign_b > 0)
    both_neg = (sign_a < 0) & (sign_b < 0)
    return jnp.where(
        sign_a != sign_b, sign_a > sign_b,
        jnp.where(both_pos, mag_gt, jnp.where(both_neg, mag_lt, False)),
    )


def cosine_key_f32(scores: jax.Array, norms_sq: jax.Array) -> jax.Array:
    """Float fast-path monotone key for cosine ranking: s / sqrt(n)."""
    n = jnp.maximum(norms_sq.astype(jnp.float32), 1.0)
    key = scores.astype(jnp.float32) * jax.lax.rsqrt(n)
    return jnp.where(norms_sq > 0, key, 0.0)


def rerank_dense_comparator(scores: jax.Array, norms_sq: jax.Array,
                            k: int) -> tuple[jax.Array, jax.Array]:
    """Paper-style dense-comparison rerank using the non-division comparator.

    Builds the full pairwise 'greater' matrix over K candidates (the paper's
    dense comparator array), ranks by win count with index tie-break, and
    returns (top-k indices into the candidate set, their int32 scores).
    Intended for candidate sets (K ~ 50), not the full corpus.
    """
    kk = scores.shape[0]
    gt = fraction_greater(scores[:, None], norms_sq[:, None],
                          scores[None, :], norms_sq[None, :])
    wins = jnp.sum(gt, axis=1)                       # (K,) number of candidates beaten
    # Higher wins = better. Tie-break on lower index (stable, deterministic).
    order_key = wins.astype(jnp.int32) * kk - jnp.arange(kk, dtype=jnp.int32)
    _, idx = jax.lax.top_k(order_key, k)
    return idx, scores[idx]


def topk_mips(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k by raw integer dot product (MIPS). Returns (values, indices)."""
    return jax.lax.top_k(scores, k)
