"""Similarity computation: integer MIPS / cosine + non-division comparator.

The paper's rerank unit compares cosine similarities WITHOUT division or
sqrt: to order  s_a / sqrt(n_a)  vs  s_b / sqrt(n_b)  (s = integer dot
product, n = integer squared doc norm; the query norm is common and
cancels), it cross-multiplies squares:

    sign-aware compare of   s_a^2 * n_b   vs   s_b^2 * n_a

With D = 512 and INT8 codes, s^2*n needs up to ~69 bits, which overflows
int64. The hardware uses a wide comparator; here we emulate the 128-bit
product exactly with 32-bit limbs (no float, no division — faithful to the
paper's integer-only rerank pipeline). A float32 fast path (score/sqrt(norm))
is also provided; property tests assert both produce the same ordering.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def int_dot(a: jax.Array, b: jax.Array) -> jax.Array:
    """Exact integer dot product of int8 codes -> int32. a:(...,D) b:(...,D)."""
    return jnp.sum(a.astype(jnp.int32) * b.astype(jnp.int32), axis=-1)


def int_matvec(db: jax.Array, q: jax.Array) -> jax.Array:
    """(N, D) int8 x (D,) int8 -> (N,) int32 scores (MIPS)."""
    return jax.lax.dot_general(
        db.astype(jnp.int8), q.astype(jnp.int8),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _mul_69bit(s_sq: jax.Array, n: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Exact (hi, lo) limbs of s_sq * n where s_sq < 2**47, n < 2**24.

    s_sq = h*2^32 + l;  s_sq*n = (h*n + (l*n >> 32)) * 2^32 + (l*n & M).
    All partials fit comfortably in int64. Must be called inside an
    enable_x64 scope (s_sq, n already int64).
    """
    mask32 = jnp.int64(0xFFFFFFFF)
    h = s_sq >> 32
    l = s_sq & mask32
    ln = l * n
    hi = h * n + (ln >> 32)
    lo = ln & mask32
    return hi, lo


def fraction_greater(s_a: jax.Array, n_a: jax.Array,
                     s_b: jax.Array, n_b: jax.Array) -> jax.Array:
    """Non-division comparator:  s_a/sqrt(n_a) > s_b/sqrt(n_b)  (elementwise).

    s_*: int32 dot products (may be negative); n_*: int32 squared norms >= 0.
    Zero norms are treated as similarity 0 (degenerate all-zero code).
    Pure integer arithmetic — no division, sqrt, or floats. The 69-bit
    cross products are computed in a scoped x64 region (the process default
    stays 32-bit for the rest of the framework).
    """
    with jax.enable_x64(True):
        s_a = jnp.asarray(s_a).astype(jnp.int64)
        s_b = jnp.asarray(s_b).astype(jnp.int64)
        n_a = jnp.asarray(n_a).astype(jnp.int64)
        n_b = jnp.asarray(n_b).astype(jnp.int64)
        sign_a = jnp.where(n_a > 0, jnp.sign(s_a), 0)
        sign_b = jnp.where(n_b > 0, jnp.sign(s_b), 0)

        hi_a, lo_a = _mul_69bit(s_a * s_a, jnp.maximum(n_b, 1))
        hi_b, lo_b = _mul_69bit(s_b * s_b, jnp.maximum(n_a, 1))
        mag_gt = (hi_a > hi_b) | ((hi_a == hi_b) & (lo_a > lo_b))
        mag_lt = (hi_a < hi_b) | ((hi_a == hi_b) & (lo_a < lo_b))

        both_pos = (sign_a > 0) & (sign_b > 0)
        both_neg = (sign_a < 0) & (sign_b < 0)
        return jnp.where(
            sign_a != sign_b, sign_a > sign_b,
            jnp.where(both_pos, mag_gt, jnp.where(both_neg, mag_lt, False)),
        )


def cosine_key_f32(scores: jax.Array, norms_sq: jax.Array) -> jax.Array:
    """Float fast-path monotone key for cosine ranking: s / sqrt(n)."""
    n = jnp.maximum(norms_sq.astype(jnp.float32), 1.0)
    key = scores.astype(jnp.float32) * jax.lax.rsqrt(n)
    return jnp.where(norms_sq > 0, key, 0.0)


def rerank_dense_comparator(scores: jax.Array, norms_sq: jax.Array,
                            k: int) -> tuple[jax.Array, jax.Array]:
    """Paper-style dense-comparison rerank using the non-division comparator.

    Builds the full pairwise 'greater' matrix over K candidates (the paper's
    dense comparator array), ranks by win count with index tie-break, and
    returns (top-k indices into the candidate set, their int32 scores).
    Intended for candidate sets (K ~ 50), not the full corpus.
    """
    kk = scores.shape[0]
    gt = fraction_greater(scores[:, None], norms_sq[:, None],
                          scores[None, :], norms_sq[None, :])
    wins = jnp.sum(gt, axis=1)                       # (K,) number of candidates beaten
    # Higher wins = better. Tie-break on lower index (stable, deterministic).
    order_key = wins.astype(jnp.int32) * kk - jnp.arange(kk, dtype=jnp.int32)
    _, idx = jax.lax.top_k(order_key, k)
    return idx, scores[idx]


def topk_mips(scores: jax.Array, k: int) -> tuple[jax.Array, jax.Array]:
    """Top-k by raw integer dot product (MIPS). Returns (values, indices)."""
    return jax.lax.top_k(scores, k)
