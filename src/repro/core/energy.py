"""Energy / memory-access / compute cost model (the paper's Python simulator).

Reproduces:
  * Table II  — per-module energy for a 1 MB INT8 database query,
  * Fig. 4    — memory-access & computation reduction vs corpus size,
  * Fig. 5(b) — energy per query for INT8 / INT4 / hierarchical formats,
  * Table III — energy/query comparison on a SciFact-sized corpus.

Accounting model (documented; the paper gives pJ/bit constants in Table II
and we derive traffic/ops from the architecture):

  DRAM bits   = bits streamed off-chip.  Stage 1 reads the 4 MSB bit-planes
                of every document (bit-planar storage makes this exact);
                stage 2 re-reads the full 8 bits of the C candidates.
  SRAM bits   = 2 x DRAM bits (streaming buffers are written then read once;
                query-stationary dataflow means the query contributes only
                D*8 bits once — negligible and included).
  PE bits     = MACs x (bits_a + bits_b + ACC_BITS): every MAC consumes two
                operands and updates a 32-bit accumulator.
  SimCalc bits= MACs x ACC_BITS  (partial-sum fusion across the 4 PEs,
                norm handling, final similarity).
  Rerank bits = comparisons x 2 x ACC_BITS, with the paper's streaming dense
                comparator doing N comparisons against the running top-C in
                stage 1 plus C*C dense comparisons in stage 2.

A second constant set (TPU_V5E) reuses the same accounting at pod scale so
the benefit of hierarchical retrieval can be stated for the TPU target
(HBM pJ/bit derived from public v5e HBM power/bandwidth estimates).
"""
from __future__ import annotations

import dataclasses
import functools
import math

ACC_BITS = 32
NORM_BITS = 32  # stored per-doc squared-norm sidecar


@dataclasses.dataclass(frozen=True)
class EnergyConstants:
    """pJ per bit moved/processed, per module."""
    name: str
    dram: float
    sram: float
    pe: float
    simcalc: float
    rerank: float


# Paper Table II (TSMC 28 nm; DRAM constants from Horowitz / Sze et al.)
PAPER_28NM = EnergyConstants(name="paper-28nm", dram=40.0, sram=0.2,
                             pe=0.0078, simcalc=0.0003, rerank=0.0001)

# TPU v5e-equivalent accounting: HBM2e ~= 819 GB/s; public estimates put HBM
# power at ~3-4 W per chip => ~0.5 pJ/bit effective; VMEM ~0.05 pJ/bit; MXU
# MAC energy folded into 'pe'. These are order-of-magnitude constants used
# ONLY for relative comparisons (hierarchical vs INT8) at pod scale.
TPU_V5E = EnergyConstants(name="tpu-v5e", dram=0.5, sram=0.05,
                          pe=0.002, simcalc=0.0003, rerank=0.0001)


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Per-module energy (pJ) + traffic/compute tallies for one query."""
    dram_bits: float
    sram_bits: float
    pe_bits: float
    simcalc_bits: float
    rerank_bits: float
    macs: float
    dram_pj: float
    sram_pj: float
    pe_pj: float
    simcalc_pj: float
    rerank_pj: float

    @property
    def total_pj(self) -> float:
        return (self.dram_pj + self.sram_pj + self.pe_pj
                + self.simcalc_pj + self.rerank_pj)

    @property
    def total_uj(self) -> float:
        return self.total_pj * 1e-6

    def proportions(self) -> dict[str, float]:
        t = self.total_pj
        return {"DRAM": self.dram_pj / t, "SRAM": self.sram_pj / t,
                "PE": self.pe_pj / t, "SimCalc": self.simcalc_pj / t,
                "Rerank": self.rerank_pj / t}


def _cost(n_docs: int, dim: int, *, doc_bits_read, mac_terms, compares,
          consts: EnergyConstants, include_norms: bool,
          cached_bits: float = 0.0) -> CostBreakdown:
    """cached_bits: doc bits served from ON-CHIP memory instead of DRAM
    (the serving runtime's hot-cluster cache). A streamed bit is written
    into SRAM then read back (2x); a cached bit is already resident and
    read once — so hits are charged 1x SRAM and zero DRAM."""
    dram_bits = doc_bits_read + (n_docs * NORM_BITS if include_norms else 0)
    sram_bits = 2 * dram_bits + cached_bits + dim * 8  # + one query load
    macs = sum(m for m, _, _ in mac_terms)
    pe_bits = sum(m * (ba + bb + ACC_BITS) for m, ba, bb in mac_terms)
    simcalc_bits = macs * ACC_BITS
    rerank_bits = compares * 2 * ACC_BITS
    return CostBreakdown(
        dram_bits=dram_bits, sram_bits=sram_bits, pe_bits=pe_bits,
        simcalc_bits=simcalc_bits, rerank_bits=rerank_bits, macs=macs,
        dram_pj=dram_bits * consts.dram, sram_pj=sram_bits * consts.sram,
        pe_pj=pe_bits * consts.pe, simcalc_pj=simcalc_bits * consts.simcalc,
        rerank_pj=rerank_bits * consts.rerank,
    )


def default_candidates(n_docs: int, max_candidates: int = 50,
                       frac: float = 0.2) -> int:
    return max(1, min(max_candidates, math.ceil(frac * n_docs)))


def cost_int8(n_docs: int, dim: int = 512, *, consts=PAPER_28NM,
              include_norms: bool = False) -> CostBreakdown:
    """Baseline: pure INT8 retrieval over the whole corpus."""
    return _cost(n_docs, dim,
                 doc_bits_read=n_docs * dim * 8,
                 mac_terms=[(n_docs * dim, 8, 8)],
                 compares=n_docs,
                 consts=consts, include_norms=include_norms)


def cost_int4(n_docs: int, dim: int = 512, *, consts=PAPER_28NM,
              include_norms: bool = False) -> CostBreakdown:
    """Baseline: pure INT4 (MSB nibble only) retrieval."""
    return _cost(n_docs, dim,
                 doc_bits_read=n_docs * dim * 4,
                 mac_terms=[(n_docs * dim, 4, 4)],
                 compares=n_docs,
                 consts=consts, include_norms=include_norms)


def cost_hierarchical(n_docs: int, dim: int = 512, *, candidates: int | None = None,
                      consts=PAPER_28NM, include_norms: bool = False) -> CostBreakdown:
    """The paper's two-stage scheme: MSB-INT4 over all docs + INT8 over C."""
    c = default_candidates(n_docs) if candidates is None else candidates
    return _cost(n_docs, dim,
                 doc_bits_read=n_docs * dim * 4 + c * dim * 8,
                 mac_terms=[(n_docs * dim, 4, 4), (c * dim, 8, 8)],
                 compares=n_docs + c * c,
                 consts=consts, include_norms=include_norms)


def cost_cascade(stages, dim: int = 512, *, batch: int = 1,
                 consts=PAPER_28NM,
                 include_norms: bool = False) -> CostBreakdown:
    """Measured-counts cost of ONE query of an N-stage retrieval cascade.

    `stages` is a launch's per-stage ledger — engine.SchedulePlan.stages,
    i.e. objects with `rows` (rows scored per lane), `bits` (operand
    width), `bytes_hbm` (plane bytes the whole LAUNCH streamed for the
    stage), optional `bytes_sram` (plane bytes the launch served from the
    serving runtime's hot-cluster cache — charged at SRAM rates, zero
    DRAM, same MACs) and `compares` — so the ledger charges what the
    schedule ACTUALLY streamed (windowed lanes their window, cluster-
    pruned lanes their probed blocks, cache hits the on-chip rate,
    shared-plane stages amortized over `batch`) instead of re-deriving
    traffic from the `default_candidates` heuristic and a full-corpus
    scan.
    """
    stages = tuple(stages)
    b = max(1, batch)
    doc_bits = sum(s.bytes_hbm * 8 for s in stages) / b
    cached_bits = sum(getattr(s, "bytes_sram", 0) * 8 for s in stages) / b
    mac_terms = [(s.rows * dim, s.bits, s.bits) for s in stages]
    compares = sum(s.compares for s in stages)
    # The norms sidecar is read once per stage-1-scored row (4-bit stages
    # rank on the approximate cosine key; the exact stage re-reads its
    # candidates' norms, already counted in its rows).
    norm_rows = sum(s.rows for s in stages if s.bits == 4)
    return _cost(norm_rows, dim, doc_bits_read=doc_bits,
                 mac_terms=mac_terms, compares=compares,
                 consts=consts, include_norms=include_norms,
                 cached_bits=cached_bits)


def cost_per_stage(stages, dim: int = 512, *, batch: int = 1,
                   consts=PAPER_28NM,
                   include_norms: bool = False) -> dict[str, CostBreakdown]:
    """Price each cascade stage of a launch SEPARATELY, keyed by its
    `plan.stages` name — no special-casing per stage kind, so a new
    stage (e.g. the 1-bit sign prescreen) is charged and exported the
    moment it appears in the ledger. Each stage is costed as a
    single-stage cascade; the per-query SRAM query-load term (dim * 8
    bits) is charged once per stage, so the stage sum exceeds the fused
    `cost_cascade` total by (len(stages) - 1) * dim * 8 * sram pJ —
    sub-permille, and the headline histogram keeps using the fused
    total."""
    return {s.name: cost_cascade((s,), dim, batch=batch, consts=consts,
                                 include_norms=include_norms)
            for s in stages}


@functools.lru_cache(maxsize=64)
def _stage_uj_coeffs(bits: int, dim: int, batch: int, consts,
                     include_norms: bool) -> tuple:
    """Per-stage price as LINEAR coefficients over the ledger fields.

    A single-stage `cost_cascade` total is linear in (bytes_hbm,
    bytes_sram, rows, compares); only these coefficients depend on
    (bits, dim, batch, consts) — all stable across a serving runtime's
    launches even when the cached path's hit/miss byte split varies
    every turn. The hot metrics path therefore pays a cache hit plus
    four multiply-adds per stage instead of pricing a fresh
    CostBreakdown, which is what keeps the per-stage energy export
    inside the observability overhead budget."""
    b = max(1, batch)
    per_hbm_byte = 8.0 / b * (consts.dram + 2.0 * consts.sram)
    per_sram_byte = 8.0 / b * consts.sram
    per_row = dim * ((2 * bits + ACC_BITS) * consts.pe
                     + ACC_BITS * consts.simcalc)
    if include_norms and bits == 4:
        per_row += NORM_BITS * (consts.dram + 2.0 * consts.sram)
    per_compare = 2.0 * ACC_BITS * consts.rerank
    query_load = dim * 8.0 * consts.sram
    return per_hbm_byte, per_sram_byte, per_row, per_compare, query_load


def stage_cost_uj(stage, dim: int = 512, *, batch: int = 1,
                  consts=PAPER_28NM, include_norms: bool = False) -> float:
    """Fast path for `cost_per_stage(...)[name].total_uj`: same price
    (to float round-off), no CostBreakdown construction — pinned against
    the exact single-stage cascade by test_energy."""
    a_hbm, a_sram, a_row, a_cmp, c0 = _stage_uj_coeffs(
        stage.bits, dim, max(1, batch), consts, include_norms)
    return (stage.bytes_hbm * a_hbm
            + getattr(stage, "bytes_sram", 0) * a_sram
            + stage.rows * a_row + stage.compares * a_cmp + c0) * 1e-6


def observe_cost(registry, cost: CostBreakdown, *, queries: int = 1,
                 stages=None, dim: int = 512, batch: int = 1,
                 consts=PAPER_28NM) -> None:
    """Record a launch's priced PER-QUERY cost into a metrics registry.

    Feeds the serving stack's energy distributions: `energy_uj_per_query`
    is the headline µJ/query histogram (p50/p99 over the ACTUAL served
    trace, not the last launch), plus a per-module breakdown so exporter
    output mirrors the paper's Table II columns. When the launch's
    `plan.stages` ledger is passed via `stages`, a per-STAGE breakdown
    (`energy_uj_per_query_stage`, labelled by stage name) is exported
    too — driven entirely by the ledger, so every stage the schedule
    runs (prune / prescreen / approx / exact) is split out without
    enumeration here. `queries` weights the sample by the launch's real
    batch occupancy so trace-level medians are per QUERY, not per
    launch. Duck-typed against repro.obs.MetricsRegistry and a no-op
    when disabled."""
    if not getattr(registry, "enabled", False):
        return
    registry.histogram("energy_uj_per_query").observe(cost.total_uj,
                                                      queries)
    for module, pj in (("dram", cost.dram_pj), ("sram", cost.sram_pj),
                       ("pe", cost.pe_pj), ("simcalc", cost.simcalc_pj),
                       ("rerank", cost.rerank_pj)):
        registry.histogram("energy_uj_per_query_module",
                           module=module).observe(pj * 1e-6, queries)
    if stages:
        for s in stages:
            registry.histogram("energy_uj_per_query_stage",
                               stage=s.name).observe(
                stage_cost_uj(s, dim, batch=batch, consts=consts), queries)


def observe_decode_cost(registry, cost: CostBreakdown, *,
                        tokens: int = 1) -> None:
    """Record a decode launch's priced PER-TOKEN cost.

    The decode-side sibling of `observe_cost`: the KV cascade's
    `kv_plan` ledger priced through the SAME `cost_cascade` model lands
    in `energy_uj_per_token`, so a serving trace exposes whole-turn
    µJ/token next to retrieval's µJ/query from one registry. `cost` must
    already be per token (one decode step); `tokens` weights the sample
    by the number of steps the launch covered."""
    if not getattr(registry, "enabled", False):
        return
    registry.histogram("energy_uj_per_token").observe(cost.total_uj,
                                                      tokens)
    for module, pj in (("dram", cost.dram_pj), ("sram", cost.sram_pj),
                       ("pe", cost.pe_pj), ("simcalc", cost.simcalc_pj),
                       ("rerank", cost.rerank_pj)):
        registry.histogram("energy_uj_per_token_module",
                           module=module).observe(pj * 1e-6, tokens)

# ---------------------------------------------------------------------------
# Paper-figure helpers
# ---------------------------------------------------------------------------

def memory_reduction(n_docs: int, dim: int = 512,
                     candidates: int | None = None) -> float:
    """Fig. 4 memory-access reduction of hierarchical vs pure INT8."""
    base = cost_int8(n_docs, dim).dram_bits
    ours = cost_hierarchical(n_docs, dim, candidates=candidates).dram_bits
    return 1.0 - ours / base


def compute_reduction(n_docs: int, dim: int = 512,
                      candidates: int | None = None) -> float:
    """Fig. 4 computation reduction (nibble-MAC-equivalents: an 8x8 MAC
    decomposes into 4 nibble MACs on the paper's 4-bit PEs)."""
    def nibble_macs(cb: CostBreakdown, terms):
        return sum(m * (ba // 4) * (bb // 4) for m, ba, bb in terms)
    c = default_candidates(n_docs) if candidates is None else candidates
    base = nibble_macs(None, [(n_docs * dim, 8, 8)])
    ours = nibble_macs(None, [(n_docs * dim, 4, 4), (c * dim, 8, 8)])
    return 1.0 - ours / base


def db_bytes(n_docs: int, dim: int = 512) -> int:
    return n_docs * dim  # INT8: 1 byte per dim


def docs_for_db_mb(mb: float, dim: int = 512) -> int:
    return int(mb * 1024 * 1024 // dim)
