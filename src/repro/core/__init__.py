"""Core library: the paper's hierarchical retrieval as composable JAX modules."""
from repro.core.quantization import (QuantizedDB, build_database, dequantize,
                                     lsb_nibble, msb_nibble, quantize_int4,
                                     quantize_int8, quantize_int8_fixed,
                                     reconstruct_from_nibbles,
                                     unit_norm_scale)
from repro.core.bitplanar import (BitPlanarDB, pack_bitplanes,
                                  pack_nibble_planes, reconstruct_int8,
                                  unpack_bitplanes,
                                  unpack_nibble_plane_signed,
                                  unpack_nibble_plane_unsigned)
from repro.core.similarity import (cosine_key_f32, fraction_greater, int_dot,
                                   int_matvec, rerank_dense_comparator,
                                   topk_mips)
from repro.core.retrieval import (NO_TENANT, RetrievalConfig, RetrievalResult,
                                  batched_retrieve, batched_retrieve_masked,
                                  cluster_pruned_retrieve, exact_retrieve,
                                  int4_retrieve, two_stage_retrieve,
                                  two_stage_retrieve_masked,
                                  windowed_retrieve_masked)
from repro.core.engine import (ClusterPolicy, MaskedPolicy, PlainPolicy,
                               RetrievalEngine, SchedulePlan, StagePlan,
                               WindowedPolicy)
from repro.core.clustering import (ClusterCodebook, ClusterIndex,
                                   ClusterParams, block_table,
                                   cluster_grouped_order, kmeans_int8)
from repro.core import energy
