"""Batch-native retrieval engine: ONE two-stage core behind every variant.

The paper's memory-access argument — stream the MSB nibble plane once and
touch full INT8 codes only for candidates — only survives batch serving if
batching is first-class all the way down. Previously each retrieval variant
(plain / segment-masked / windowed) vmapped a single-query path, so a mixed
batch of B tenants streamed the arena planes B times and the kernels ran
MXU-wasting matvecs. This module is the single batched implementation all
of them now share, layered as:

  policy   — WHICH rows each batch lane may touch, expressed as data:
             `PlainPolicy` (every row), `MaskedPolicy` (rows whose arena
             owner matches the lane's tenant), `WindowedPolicy` (a per-lane
             contiguous arena window, masked inside the window). Adding a
             visibility rule means adding a policy, not a retrieval path.
  schedule — the shared two-stage body `_two_stage_batched`: batched
             stage-1 scan over the policy's row view, per-lane candidate
             top-C, batched stage-2 gather + exact INT8 rescore, metric
             rerank (non-division comparator for cosine, top-k for MIPS).
  backend  — the three stage primitives the schedule calls, selected by
             `RetrievalConfig.backend`: pure-jnp reference math ("jnp") or
             the batch-native Pallas TPU kernels ("pallas"). Both are exact
             integer arithmetic, so they agree bit-for-bit.

Stage 1 for the plane-scan policies is a TRUE matmul — (N, D/2) plane x
(D/2, B) query panel — so the doc planes are streamed from HBM once per
BATCH instead of once per query (`SchedulePlan` carries the exact analytic
byte counts; benchmarks/retrieval_bench.py measures the wall-clock side).

The legacy entry points in repro.core.retrieval are thin wrappers that
build a policy and call this engine.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import bitplanar, quantization, similarity
from repro.core.retrieval import RetrievalConfig, RetrievalResult

INT32_MIN = jnp.iinfo(jnp.int32).min

# Stage-2 score assigned to out-of-segment candidates. Most-negative-plus-one
# so s*s stays below 2**62 inside the non-division comparator's int64 limbs;
# any in-segment row (even with a negative score) orders strictly above it.
MASKED_SCORE = jnp.int32(-(2 ** 31 - 1))


# ---------------------------------------------------------------------------
# Membership / window policies (pytrees: the TYPE selects the code path,
# the leaves are device data, so jit specializes per policy kind only)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlainPolicy:
    """Every row visible to every lane (the single-corpus case)."""


@dataclasses.dataclass(frozen=True)
class MaskedPolicy:
    """Lane i sees exactly the rows with ``owner == tenant_ids[i]``.

    owner: (N,) int32 slot -> tenant map (free/tombstoned slots hold -1).
    tenant_ids: (B,) int32; negative ids (NO_TENANT padding lanes) match
    nothing — -1 must never act as a segment key or it would resurrect
    tombstones. The fully general multi-tenant path: works for arbitrarily
    fragmented tenants at the cost of scanning the whole arena.
    """

    owner: jax.Array
    tenant_ids: jax.Array


@dataclasses.dataclass(frozen=True)
class WindowedPolicy:
    """MaskedPolicy restricted to one contiguous window per lane.

    When every requested tenant occupies a single contiguous slot run (the
    invariant bump allocation establishes and tenant-grouped compaction
    restores), lane i only streams the `window` rows at ``starts[i]`` —
    a mixed batch costs one launch AND only per-tenant work. Rows inside
    the window but outside the segment (neighbours, tombstones) are masked
    exactly like the full scan. `window` is static (callers round up to a
    power-of-two bucket to bound recompilation) and must be >= cfg.k.
    """

    owner: jax.Array
    tenant_ids: jax.Array
    starts: jax.Array
    window: int


jax.tree_util.register_pytree_node(
    PlainPolicy, lambda p: ((), None), lambda _, l: PlainPolicy())
jax.tree_util.register_pytree_node(
    MaskedPolicy, lambda p: ((p.owner, p.tenant_ids), None),
    lambda _, l: MaskedPolicy(*l))
jax.tree_util.register_pytree_node(
    WindowedPolicy, lambda p: ((p.owner, p.tenant_ids, p.starts), p.window),
    lambda w, l: WindowedPolicy(*l, window=w))

Policy = PlainPolicy | MaskedPolicy | WindowedPolicy


# ---------------------------------------------------------------------------
# Batched stage primitives (jnp reference backend; kernels mirror these)
# ---------------------------------------------------------------------------

def stage1_plane_batched_jnp(q_msb: jax.Array,
                             msb_plane: jax.Array) -> jax.Array:
    """Batched MSB-nibble MIPS over a shared plane: one true matmul.

    q_msb (B, D) int8 in [-8, 7]; msb_plane (N, D//2) packed uint8.
    Returns (B, N) int32. Split-query formulation as in stage1_scores_jnp:
    lo_signed . q_even + hi_signed . q_odd on the packed plane, so the
    (N, D) interleaved unpack is never materialized and the plane rows are
    read ONCE for the whole batch.
    """
    lo, hi = bitplanar.split_nibbles_signed(msb_plane)
    return (similarity.int_matmul(lo, q_msb[:, 0::2])
            + similarity.int_matmul(hi, q_msb[:, 1::2]))


def stage1_rows_batched_jnp(q_msb: jax.Array,
                            msb_rows: jax.Array) -> jax.Array:
    """Per-lane-rows stage 1 (the windowed policy's shape).

    q_msb (B, D) int8 nibbles; msb_rows (B, W, D//2) packed per-lane row
    blocks. Returns (B, W) int32 — lane i scores only its own rows.
    """
    lo, hi = bitplanar.split_nibbles_signed(msb_rows)
    return (similarity.int_bmm(lo, q_msb[:, 0::2])
            + similarity.int_bmm(hi, q_msb[:, 1::2]))


def stage2_rows_batched_jnp(q: jax.Array, msb_rows: jax.Array,
                            lsb_rows: jax.Array) -> jax.Array:
    """Exact INT8 rescoring of gathered per-lane candidate rows.

    q (B, D) int8; msb_rows/lsb_rows (B, C, D//2) uint8. Returns (B, C).
    """
    bsz, c, d2 = msb_rows.shape
    docs = bitplanar.reconstruct_int8(msb_rows.reshape(bsz * c, d2),
                                      lsb_rows.reshape(bsz * c, d2))
    return similarity.int_bmm(docs.reshape(bsz, c, 2 * d2), q)


def stage_fns(backend: str):
    """The schedule's three batched primitives for a backend:
    (stage1 shared-plane matmul, stage1 per-lane rows, stage2 rescore)."""
    if backend == "pallas":
        from repro.kernels import ops as kops
        return (kops.stage1_scores_batched, kops.stage1_scores_rows,
                kops.stage2_scores_batched)
    return (stage1_plane_batched_jnp, stage1_rows_batched_jnp,
            stage2_rows_batched_jnp)


# ---------------------------------------------------------------------------
# The shared two-stage schedule
# ---------------------------------------------------------------------------

def _vslice(arr: jax.Array, starts: jax.Array, window: int) -> jax.Array:
    """Per-lane dynamic windows: (N, ...) x (B,) starts -> (B, window, ...)."""
    return jax.vmap(
        lambda s: jax.lax.dynamic_slice_in_dim(arr, s, window, 0))(starts)


def _candidate_budget(cfg: RetrievalConfig, num_docs: int,
                      window: int | None) -> int:
    """Stage-2 budget C (the single source both the schedule and `plan`
    use). The windowed budget is the SAME as the full-scan one — clamped
    to the window, in which case every in-window row is a candidate and
    the tenant is rescored exhaustively — so results never depend on which
    code path the arena's fragmentation state selects."""
    c = cfg.num_candidates(num_docs)
    if window is not None:
        c = min(c, window)
    return c


def _two_stage_batched(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                       policy: Policy, cfg: RetrievalConfig
                       ) -> RetrievalResult:
    """The one batched two-stage body every retrieval variant runs.

    query_codes: (B, D) int8. Returns a batched RetrievalResult whose
    indices are global row/slot ids (-1 for lanes' unfillable positions
    under masking policies).
    """
    n = db.num_docs
    c = _candidate_budget(cfg, n, policy.window
                          if isinstance(policy, WindowedPolicy) else None)
    s1_plane, s1_rows, s2_rows = stage_fns(cfg.backend)
    q_msb = quantization.msb_nibble(query_codes)

    # ---- Stage 1: batched approximate scoring over the policy's row view.
    if isinstance(policy, WindowedPolicy):
        if policy.window < cfg.k:
            raise ValueError(f"window {policy.window} < k={cfg.k}: top-k "
                             f"over a window needs window >= k")
        starts = jnp.clip(policy.starts, 0,
                          max(n - policy.window, 0)).astype(jnp.int32)
        msb_view = _vslice(db.msb_plane, starts, policy.window)
        norms = _vslice(db.norms_sq, starts, policy.window)
        owner_view = _vslice(policy.owner, starts, policy.window)
        member = ((owner_view == policy.tenant_ids[:, None])
                  & (policy.tenant_ids >= 0)[:, None])
        scores = s1_rows(q_msb, msb_view)                  # (B, W) int32
        base = starts[:, None]
    else:
        scores = s1_plane(q_msb, db.msb_plane)             # (B, N) int32
        norms = db.norms_sq[None, :]
        if isinstance(policy, MaskedPolicy):
            member = ((policy.owner[None, :] == policy.tenant_ids[:, None])
                      & (policy.tenant_ids >= 0)[:, None])
        else:
            member = None
        base = None

    if cfg.metric == "cosine":
        # Approximate cosine key; norms are tiny sidecar reads (the paper
        # stores doc norms in DRAM alongside the planes). Tombstoned rows
        # carry norm 0 (key 0), so even an inconsistent membership mask
        # cannot let a dead row win.
        key1 = similarity.cosine_key_f32(scores, norms)
        if member is not None:
            key1 = jnp.where(member, key1, -jnp.inf)
    else:
        key1 = scores if member is None else jnp.where(member, scores,
                                                       INT32_MIN)
    _, cand_local = jax.lax.top_k(key1, c)                 # (B, C) view rows

    # ---- Stage 2: batched exact INT8 rescoring of the candidates only.
    # Candidate rows are gathered from the FULL planes by global id, so the
    # LSB plane is never sliced and the windowed path re-reads only C rows.
    cand = cand_local if base is None else cand_local + base
    cand_member = (None if member is None else
                   jnp.take_along_axis(member, cand_local, axis=1))
    msb_rows = jnp.take(db.msb_plane, cand, axis=0)        # (B, C, D//2)
    lsb_rows = jnp.take(db.lsb_plane, cand, axis=0)
    exact = s2_rows(query_codes, msb_rows, lsb_rows)       # (B, C) int32
    cand_norms = jnp.take(db.norms_sq, cand, axis=0)
    if cand_member is not None:
        # Out-of-segment candidates pin to (MASKED_SCORE, 1) so the integer
        # rerank comparator ranks them below every in-segment candidate.
        exact = jnp.where(cand_member, exact, MASKED_SCORE)
        cand_norms = jnp.where(cand_member, cand_norms, 1)

    # ---- Metric rerank (per lane; C is small).
    if cfg.metric == "cosine":
        local, top_scores = jax.vmap(
            lambda s, nn: similarity.rerank_dense_comparator(s, nn, cfg.k)
        )(exact, cand_norms)
    else:
        top_scores, local = jax.lax.top_k(exact, cfg.k)

    indices = jnp.take_along_axis(cand, local, axis=1)
    if cand_member is None:
        return RetrievalResult(indices=indices, scores=top_scores,
                               candidate_indices=cand)
    valid = jnp.take_along_axis(cand_member, local, axis=1)
    return RetrievalResult(
        indices=jnp.where(valid, indices, -1),
        scores=jnp.where(valid, top_scores, 0),
        candidate_indices=jnp.where(cand_member, cand, -1))


retrieve_batched = jax.jit(_two_stage_batched, static_argnames=("cfg",))


# ---------------------------------------------------------------------------
# Schedule planning (host-side, analytic — the paper's bytes currency)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """What one batched launch will stream, computed exactly (no timers).

    stage1_bytes is the batched engine's doc-plane traffic; for the
    plane-scan policies the plane is streamed ONCE per batch, so it does
    not scale with `batch` — stage1_bytes_vmapped is what the old
    one-query-at-a-time path streamed for the same work.
    """

    kind: Literal["plain", "masked", "windowed"]
    batch: int
    rows_scanned: int          # stage-1 rows per lane (N, or the window)
    candidates: int            # stage-2 budget C per lane
    stage1_bytes: int          # batched kernel: MSB-plane bytes from HBM
    stage1_bytes_vmapped: int  # the vmapped-scalar path, for comparison
    stage2_bytes: int          # gathered candidate rows (MSB+LSB planes)


def plan(cfg: RetrievalConfig, *, num_docs: int, dim: int, batch: int,
         kind: str = "plain", window: int | None = None) -> SchedulePlan:
    """Analytic schedule for one launch of the engine.

    For "plain"/"masked" every lane scans the shared plane: the batched
    matmul kernel fetches each plane block once per BATCH (bytes = N*D/2),
    while the vmapped-scalar path fetched it once per QUERY (B*N*D/2).
    For "windowed" each lane streams its own window, so bytes scale with B
    either way — the win there is one launch + per-tenant work only.
    """
    if kind == "windowed":
        if window is None:
            raise ValueError("windowed plan needs a window")
        rows = min(window, num_docs)
        s1 = batch * rows * (dim // 2)
        s1_vmapped = s1
    else:
        if window is not None:
            raise ValueError(f"{kind} plan does not take a window")
        rows = num_docs
        s1 = rows * (dim // 2)
        s1_vmapped = batch * s1
    c = _candidate_budget(cfg, num_docs, window)
    return SchedulePlan(kind=kind, batch=batch, rows_scanned=rows,
                        candidates=c, stage1_bytes=s1,
                        stage1_bytes_vmapped=s1_vmapped,
                        stage2_bytes=batch * c * dim)


# ---------------------------------------------------------------------------
# The engine facade
# ---------------------------------------------------------------------------

def _lane(res: RetrievalResult, i: int) -> RetrievalResult:
    return jax.tree_util.tree_map(lambda x: x[i], res)


@dataclasses.dataclass(frozen=True)
class RetrievalEngine:
    """Owns backend selection and the two-stage schedule for one config.

    One engine (and thus one compiled program per batch shape and policy
    kind) serves every caller: the thin wrappers in repro.core.retrieval,
    the multi-tenant index, and the serving pipelines all funnel here.
    """

    cfg: RetrievalConfig

    def retrieve(self, query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                 policy: Policy = PlainPolicy()) -> RetrievalResult:
        """Batched retrieval: (B, D) int8 queries -> batched result."""
        return retrieve_batched(query_codes, db, policy, self.cfg)

    def retrieve_single(self, query_codes: jax.Array,
                        db: bitplanar.BitPlanarDB,
                        policy: Policy = PlainPolicy()) -> RetrievalResult:
        """(D,) int8 query -> unbatched result (a B=1 lane of the core)."""
        return _lane(self.retrieve(query_codes[None], db, policy), 0)

    def plan_for(self, db: bitplanar.BitPlanarDB, batch: int,
                 policy: Policy = PlainPolicy()) -> SchedulePlan:
        """The analytic SchedulePlan for one launch against `db`."""
        kind = {PlainPolicy: "plain", MaskedPolicy: "masked",
                WindowedPolicy: "windowed"}[type(policy)]
        window = policy.window if isinstance(policy, WindowedPolicy) else None
        return plan(self.cfg, num_docs=db.num_docs, dim=db.dim, batch=batch,
                    kind=kind, window=window)
