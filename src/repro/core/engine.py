"""Batch-native retrieval engine: ONE staged cascade behind every variant.

The paper's memory-access argument — stream the MSB nibble plane once and
touch full INT8 codes only for candidates — only survives batch serving if
batching is first-class all the way down, and only survives SCALE if the
first full pass itself can be pruned. This module is the single batched
implementation every retrieval variant shares, layered as:

  policy   — WHICH rows each batch lane may touch, expressed as data:
             `PlainPolicy` (every row), `MaskedPolicy` (rows whose arena
             owner matches the lane's tenant), `WindowedPolicy` (a per-lane
             contiguous arena window), `ClusterPolicy` (rows in the
             lane's top-`nprobe` clusters of an IVF-style INT8 centroid
             codebook — see repro.core.clustering). Adding a visibility
             rule means adding a policy, not a retrieval path.
  schedule — an N-stage CASCADE: an ordered tuple of stage specs executed
             by one batched driver (`_cascade_batched`). Today's stages:
             `CentroidPrune` (score K centroids, keep the top-P clusters'
             row blocks), `ApproxScan` (batched INT4 MSB scan over the
             surviving row view + per-lane candidate top-C), and
             `ExactRescore` (batched INT8 gather + exact rescore + metric
             rerank). The paper's two-stage scheme is just the 2-element
             cascade; the cluster-pruned path is the 3-element one. A new
             stage (e.g. a binary-sketch pre-prune) is a new spec in
             `cascade_stages`, not a new retrieval path.
  backend  — the batched stage primitives the schedule calls, selected by
             `RetrievalConfig.backend`: pure-jnp reference math ("jnp") or
             the batch-native Pallas TPU kernels ("pallas"). Both are
             exact integer arithmetic, so they agree bit-for-bit.

Stage-1 row views come in three shapes: the shared plane (a TRUE
(N, D/2) x (D/2, B) matmul — doc planes stream from HBM once per BATCH),
per-lane contiguous windows, and per-lane BLOCK GATHERS (the cluster
prune's output: only blocks of selected clusters are streamed, via scalar-
prefetch on the Pallas backend). `SchedulePlan` carries exact analytic
byte counts per stage; benchmarks/retrieval_bench.py measures wall-clock.

The legacy entry points in repro.core.retrieval are thin wrappers that
build a policy and call this engine.
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp

from repro.core import bitplanar, quantization, similarity
from repro.core.retrieval import RetrievalConfig, RetrievalResult

INT32_MIN = jnp.iinfo(jnp.int32).min

# Stage-2 score assigned to out-of-segment candidates. Most-negative-plus-one
# so s*s stays below 2**62 inside the non-division comparator's int64 limbs;
# any in-segment row (even with a negative score) orders strictly above it.
MASKED_SCORE = jnp.int32(-(2 ** 31 - 1))


# ---------------------------------------------------------------------------
# Membership / window / cluster policies (pytrees: the TYPE selects the code
# path, the leaves are device data, so jit specializes per policy kind only)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class PlainPolicy:
    """Every row visible to every lane (the single-corpus case)."""


@dataclasses.dataclass(frozen=True)
class MaskedPolicy:
    """Lane i sees exactly the rows with ``owner == tenant_ids[i]``.

    owner: (N,) int32 slot -> tenant map (free/tombstoned slots hold -1).
    tenant_ids: (B,) int32; negative ids (NO_TENANT padding lanes) match
    nothing — -1 must never act as a segment key or it would resurrect
    tombstones. The fully general multi-tenant path: works for arbitrarily
    fragmented tenants at the cost of scanning the whole arena.
    """

    owner: jax.Array
    tenant_ids: jax.Array


@dataclasses.dataclass(frozen=True)
class WindowedPolicy:
    """MaskedPolicy restricted to one contiguous window per lane.

    When every requested tenant occupies a single contiguous slot run (the
    invariant bump allocation establishes and tenant-grouped compaction
    restores), lane i only streams the `window` rows at ``starts[i]`` —
    a mixed batch costs one launch AND only per-tenant work. Rows inside
    the window but outside the segment (neighbours, tombstones) are masked
    exactly like the full scan. `window` is static (callers round up to a
    power-of-two bucket to bound recompilation) and must be >= cfg.k.
    """

    owner: jax.Array
    tenant_ids: jax.Array
    starts: jax.Array
    window: int


@dataclasses.dataclass(frozen=True)
class ClusterPolicy:
    """IVF-style centroid prune: lane i scans only its top-`nprobe`
    clusters' row blocks (and, within them, only rows it owns).

    The arena rows are covered by fixed-size blocks of `block_rows` rows;
    `cluster_blocks` lists, per cluster, the ids of the blocks holding
    that cluster's rows (-1 padding): shape (K, MB) when the table is
    shared by every lane (single corpus), or (B, K, MB) when each lane
    has its own view (multi-tenant: lane i's table only lists blocks
    holding rows of ITS tenant, so foreign clusters read as empty and are
    never probed). Stage 0 scores the K centroids (same batched INT4
    kernel as stage 1 — the codebook is just another nibble plane), keeps
    the top `nprobe` valid clusters per lane, and expands their blocks
    into an explicit per-lane row view for the INT4 scan — so stage-1
    bytes drop from O(N) per batch to O(B * nprobe * rows_per_cluster).

    owner/tenant_ids mask exactly like MaskedPolicy (single-corpus callers
    pass zeros for both, which makes every gathered row visible).
    `nprobe`, `block_rows` are static; `nprobe` must be <= K and the
    expanded view must hold at least cfg.k rows.
    """

    owner: jax.Array            # (N,) int32
    tenant_ids: jax.Array       # (B,) int32
    labels: jax.Array           # (N,) int32 row -> cluster (-1 free/dead)
    centroid_msb: jax.Array     # (K, D//2) uint8 packed centroid nibbles
    centroid_norms: jax.Array   # (K,) int32 centroid squared norms
    cluster_blocks: jax.Array   # (K, MB) or (B, K, MB) int32, -1 padded
    nprobe: int
    block_rows: int


@dataclasses.dataclass(frozen=True)
class ViewPolicy:
    """An explicitly MATERIALIZED per-lane stage-1 row view.

    A generic entry point for callers that assembled the stage-1 rows
    themselves (the serving runtime's pre-slab cache path used this; the
    runtime now hands the engine a `SlabPolicy` instead so hit bytes stay
    device-resident). Bit-exact with the ClusterPolicy path by
    construction: `rows` and `member` come from the same expansion, and
    `msb_rows` holds the same plane bytes (padding regions may hold zeros
    instead of the clamped block-0 bytes the gather path streams, which
    is invisible — every padding row is masked out of both stages by
    `member`).

    rows: (B, R) global row ids of the view (-1 holes).
    member: (B, R) bool visibility mask (tenant + cluster + hole masking).
    msb_rows: (B, R, D//2) uint8 gathered stage-1 plane rows.
    """

    rows: jax.Array
    member: jax.Array
    msb_rows: jax.Array


@dataclasses.dataclass(frozen=True)
class SlabPolicy:
    """ClusterPolicy whose stage-1 blocks stream from TWO sources: the
    arena plane (misses) or a device-resident hot-cluster cache slab
    (hits) — the serving runtime's cached path.

    The slab is an EXTENSION REGION of one combined plane array,
    ``slab_plane = [arena msb_plane | cache slab rows]`` (rows >= N are
    cache-owned copies of hot clusters' rows), so "two sources" costs
    exactly one block gather: `slab_blocks` is the host-built per-launch
    indirection table — each entry either a plane block id (miss) or
    ``N/block_rows + slab block id`` (hit). Selection stays in-graph
    (the same centroid scoring + validity the cold cascade runs); the
    host only resolves the (tenant, cluster) -> slab-slot map into this
    bounded int32 table. Hit bytes are therefore never re-uploaded and a
    cluster shared by several lanes of one tenant is stored once.

    Slab blocks are DENSELY PACKED: a resident cluster's rows are copied
    contiguously into its slots instead of mirroring whole plane blocks,
    so a cluster run that straddles a plane-block boundary occupies
    ``ceil(rows/block_rows)`` slab blocks (the plane needs up to one
    more). Each combined-space block therefore carries two per-GENERATION
    scalars, `block_gid0`/`block_count`: the global plane row id of its
    first row and the number of live rows. For plane blocks these are
    ``block * block_rows`` and `block_rows`; for slab blocks the cache
    writes them at fill time. The view's global row ids and pad masking
    are derived from these in-graph — which is what lets a fully-warm
    launch run at a NARROWER static table width than the plane table
    (fewer gathered rows per probe), the slab's real latency win.

    Bit-parity with the ClusterPolicy cascade holds even though the slab
    path runs a leaner schedule:

      * the gather skips the reference path's clamp + zero-row mask —
        every id in `slab_blocks` is pre-validated (holes are clamped to
        block 0 and ride the member mask, exactly like the cold path's
        candidate masking) and `slab_plane` is a whole number of blocks;
      * `inv_norms` is a per-generation f32 sidecar of the cosine key's
        ``rsqrt(max(norm, 1))`` factor (0 for empty rows), so stage 1
        multiplies instead of gathering int64 norms and re-deriving the
        rsqrt per launch — same f32 bits, computed once;
      * `packed_labels` fuses the arena's per-row (owner, cluster label)
        pair into one int32 (`packed_membership`), so the member mask is
        one gather + one compare — injective, hence bit-identical to the
        cold path's ``own == tenant & label == cluster`` conjunction;
      * `cluster_valid` is the host-precomputed (B, K) selection
        validity — the same ``first block >= 0`` bits the in-graph prune
        derives from the plane table, so selection cannot differ between
        table widths;
      * packing preserves each cluster's ascending row order and every
        pad/hole/foreign row is masked before both top-k stages, so the
        surviving candidates and their order — and therefore the final
        outputs — are bit-identical to the cold cascade.
    """

    packed_labels: jax.Array    # (N,) int32 packed (owner, label) rows
    tenant_ids: jax.Array       # (B,) int32
    centroid_msb: jax.Array     # (K, D//2) uint8
    centroid_norms: jax.Array   # (K,) int32
    cluster_valid: jax.Array    # (B, K) bool selection validity
    slab_blocks: jax.Array      # (B, K, W) int32 combined-space blocks
    block_gid0: jax.Array       # (NB + S,) int32 first global row per block
    block_count: jax.Array      # (NB + S,) int32 live rows per block
    slab_plane: jax.Array       # (N + S*br, D//2) uint8 plane + cache slab
    inv_norms: jax.Array        # (N + S*br,) f32 rsqrt-norm sidecar
    nprobe: int
    block_rows: int
    # Adaptive-precision sidecars (None when the runtime serves without a
    # stage-0 prescreen / precision tiers — the PR 5 schedule unchanged):
    # `sign_plane` is the combined 1-bit sign plane mirroring
    # `slab_plane`'s geometry row for row (the cache derives it from the
    # combined nibble plane — sign bits are a pure bit-extraction, see
    # bitplanar.sign_plane_from_msb — so full-tier slab rows carry live
    # sign bytes without a second fill pipeline). `block_tier` is the
    # per-slot PRECISION sidecar: tier of every combined-space block
    # (0 = arena plane block, 1 = sign-tier resident — sign bytes
    # on-chip, nibble bytes still streamed from the plane, 2 = full-tier
    # slab block — both planes cache-resident). The in-graph cascade
    # reads `sign_plane`; `block_tier` feeds the runtime's exact
    # per-stage hit/miss byte ledger and the bench's tier assertions.
    sign_plane: jax.Array | None = None
    block_tier: jax.Array | None = None


jax.tree_util.register_pytree_node(
    PlainPolicy, lambda p: ((), None), lambda _, l: PlainPolicy())
jax.tree_util.register_pytree_node(
    MaskedPolicy, lambda p: ((p.owner, p.tenant_ids), None),
    lambda _, l: MaskedPolicy(*l))
jax.tree_util.register_pytree_node(
    WindowedPolicy, lambda p: ((p.owner, p.tenant_ids, p.starts), p.window),
    lambda w, l: WindowedPolicy(*l, window=w))
jax.tree_util.register_pytree_node(
    ClusterPolicy,
    lambda p: ((p.owner, p.tenant_ids, p.labels, p.centroid_msb,
                p.centroid_norms, p.cluster_blocks),
               (p.nprobe, p.block_rows)),
    lambda aux, l: ClusterPolicy(*l, nprobe=aux[0], block_rows=aux[1]))
jax.tree_util.register_pytree_node(
    ViewPolicy, lambda p: ((p.rows, p.member, p.msb_rows), None),
    lambda _, l: ViewPolicy(*l))
jax.tree_util.register_pytree_node(
    SlabPolicy,
    lambda p: ((p.packed_labels, p.tenant_ids, p.centroid_msb,
                p.centroid_norms, p.cluster_valid, p.slab_blocks,
                p.block_gid0, p.block_count, p.slab_plane, p.inv_norms,
                p.sign_plane, p.block_tier),
               (p.nprobe, p.block_rows)),
    lambda aux, l: SlabPolicy(*l[:10], nprobe=aux[0], block_rows=aux[1],
                              sign_plane=l[10], block_tier=l[11]))


def packed_membership(owner: jax.Array, labels: jax.Array,
                      num_clusters: int) -> jax.Array:
    """Fuse per-row (owner, cluster label) into one int32 sidecar.

    ``(owner + 1) * (K + 1) + (label + 1)`` — injective for owner >= -1
    and label in [-1, K), so ``packed[row] == (t + 1) * (K + 1) + c + 1``
    holds exactly when ``owner[row] == t and labels[row] == c``. Built
    once per arena generation by the serving cache; lets the slab
    cascade's member mask run as a single gather + compare."""
    k1 = num_clusters + 1
    return ((owner.astype(jnp.int32) + 1) * k1
            + labels.astype(jnp.int32) + 1)

Policy = (PlainPolicy | MaskedPolicy | WindowedPolicy | ClusterPolicy
          | ViewPolicy | SlabPolicy)


# ---------------------------------------------------------------------------
# Batched stage primitives (jnp reference backend; kernels mirror these)
# ---------------------------------------------------------------------------

def stage1_plane_batched_jnp(q_msb: jax.Array,
                             msb_plane: jax.Array) -> jax.Array:
    """Batched MSB-nibble MIPS over a shared plane: one true matmul.

    q_msb (B, D) int8 in [-8, 7]; msb_plane (N, D//2) packed uint8.
    Returns (B, N) int32. Split-query formulation as in stage1_scores_jnp:
    lo_signed . q_even + hi_signed . q_odd on the packed plane, so the
    (N, D) interleaved unpack is never materialized and the plane rows are
    read ONCE for the whole batch.
    """
    lo, hi = bitplanar.split_nibbles_signed(msb_plane)
    return (similarity.int_matmul(lo, q_msb[:, 0::2])
            + similarity.int_matmul(hi, q_msb[:, 1::2]))


def stage1_rows_batched_jnp(q_msb: jax.Array,
                            msb_rows: jax.Array) -> jax.Array:
    """Per-lane-rows stage 1 (the windowed policy's shape).

    q_msb (B, D) int8 nibbles; msb_rows (B, W, D//2) packed per-lane row
    blocks. Returns (B, W) int32 — lane i scores only its own rows.
    """
    lo, hi = bitplanar.split_nibbles_signed(msb_rows)
    return (similarity.int_bmm(lo, q_msb[:, 0::2])
            + similarity.int_bmm(hi, q_msb[:, 1::2]))


def stage1_gather_batched_jnp(q_msb: jax.Array, msb_plane: jax.Array,
                              block_ids: jax.Array, *,
                              block_rows: int) -> jax.Array:
    """Block-gathered stage 1 (the cluster prune's row view), reference.

    q_msb (B, D) int8 nibbles; msb_plane (N, D//2) packed; block_ids
    (B, J) int32 ids of `block_rows`-row plane blocks (already clamped to
    valid blocks — holes are masked downstream by the caller's member
    mask). Returns (B, J * block_rows) int32. Rows past the plane's end
    (a final partial block) score as zero rows — `bitplanar.gather_blocks`
    owns that convention, shared with the Pallas kernel's zero-padded
    plane, so the backends stay bit-equal even on the padding that
    masking later discards.
    """
    gathered, _ = bitplanar.gather_blocks(msb_plane, block_ids, block_rows)
    return stage1_rows_batched_jnp(q_msb, gathered)


def stage1_gather_resident_jnp(q_msb: jax.Array, plane: jax.Array,
                               block_ids: jax.Array, *,
                               block_rows: int) -> jax.Array:
    """Lean block-gathered stage 1 for PRE-VALIDATED ids (the slab path).

    Same contract as `stage1_gather_batched_jnp` minus the out-of-range
    convention: every id in `block_ids` must address a whole block of
    `plane` (the serving runtime guarantees this host-side — the arena
    is a block multiple and slab slots are always fully allocated), so
    the reference clamp + zero-row mask over the gathered (B, R, D//2)
    view is skipped. Bit-equal to the Pallas gather kernel, whose
    contract never included the clamp in the first place.
    """
    rows = bitplanar.expand_block_rows(block_ids, block_rows)
    return stage1_rows_batched_jnp(q_msb, jnp.take(plane, rows, axis=0))


def stage0_sign_plane_batched_jnp(q_sign: jax.Array,
                                  sign_plane: jax.Array) -> jax.Array:
    """Batched stage-0 sign-agreement scores over a shared sign plane.

    q_sign (B, D) int8 in {+1, -1}; sign_plane (N, D//8) packed uint8
    (bit k%8 of byte k//8 set == dim k negative). Returns (B, N) int32
    ``sum_k sign(q_k) * sign(d_k)`` — affinely equivalent to the XNOR-
    popcount agreement count (score = 2*agreement - D), so ranking by it
    IS ranking by popcount, in exact integer arithmetic on both backends.
    """
    docs = bitplanar.unpack_sign_pm1(sign_plane)               # (N, D) int8
    return similarity.int_matmul(docs, q_sign)


def stage0_sign_gather_batched_jnp(q_sign: jax.Array, sign_plane: jax.Array,
                                   block_ids: jax.Array, *,
                                   block_rows: int) -> jax.Array:
    """Block-gathered stage-0 sign scan (the prescreen's view), reference.

    Same gather convention as stage1_gather_batched_jnp: rows past the
    plane's end gather ZERO bytes, which unpack to all-(+1) rows scoring
    ``sum_k sign(q_k)`` — identical on both backends and masked
    downstream by membership (a sign score is never exposed unmasked).
    """
    gathered, _ = bitplanar.gather_blocks(sign_plane, block_ids, block_rows)
    return similarity.int_bmm(bitplanar.unpack_sign_pm1(gathered), q_sign)


def stage0_sign_gather_resident_jnp(q_sign: jax.Array, sign_plane: jax.Array,
                                    block_ids: jax.Array, *,
                                    block_rows: int) -> jax.Array:
    """Stage-0 gather over a PRE-VALIDATED combined sign plane (slab path):
    no clamp / zero-byte convention, mirroring stage1_gather_resident_jnp.
    """
    rows = bitplanar.expand_block_rows(block_ids, block_rows)
    docs = bitplanar.unpack_sign_pm1(jnp.take(sign_plane, rows, axis=0))
    return similarity.int_bmm(docs, q_sign)


def stage2_rows_batched_jnp(q: jax.Array, msb_rows: jax.Array,
                            lsb_rows: jax.Array) -> jax.Array:
    """Exact INT8 rescoring of gathered per-lane candidate rows.

    q (B, D) int8; msb_rows/lsb_rows (B, C, D//2) uint8. Returns (B, C).
    """
    bsz, c, d2 = msb_rows.shape
    docs = bitplanar.reconstruct_int8(msb_rows.reshape(bsz * c, d2),
                                      lsb_rows.reshape(bsz * c, d2))
    return similarity.int_bmm(docs.reshape(bsz, c, 2 * d2), q)


@dataclasses.dataclass(frozen=True)
class StageFns:
    """The cascade's batched primitives for one backend.

    plane:    stage-1 shared-plane matmul            (B, D) x (N, D/2)
    rows:     stage-1 per-lane materialized rows     (B, D) x (B, W, D/2)
    gather:   stage-1 per-lane block gather          (B, D) x plane + ids
    gather_resident: the gather over PRE-VALIDATED block ids (the slab
              path: no clamp / zero-row convention — the Pallas kernel
              unchanged, the jnp reference without the mask)
    centroid: stage-0 codebook scoring (the codebook is a nibble plane,
              so this is the plane matmul applied to (K, D/2))
    exact:    stage-2 INT8 rescore of gathered candidates
    sign_gather / sign_gather_resident: the 1-bit sign-plane prescreen's
              block gathers, mirroring gather / gather_resident over the
              packed (N, D/8) sign plane — XNOR-popcount agreement in its
              monotone ±1-dot form
    """

    plane: object
    rows: object
    gather: object
    gather_resident: object
    centroid: object
    exact: object
    sign_gather: object
    sign_gather_resident: object


def stage_fns(backend: str) -> StageFns:
    if backend == "pallas":
        from repro.kernels import ops as kops

        def _sign_gather_k(q_sign, sign_plane, block_ids, block_rows):
            return kops.stage0_sign_scores_gather(q_sign, sign_plane,
                                                  block_ids,
                                                  block_rows=block_rows)

        def _sign_gather_resident_k(q_sign, sign_plane, block_ids,
                                    block_rows):
            return kops.stage0_sign_scores_gather_resident(
                q_sign, sign_plane, block_ids, block_rows=block_rows)

        return StageFns(plane=kops.stage1_scores_batched,
                        rows=kops.stage1_scores_rows,
                        gather=kops.stage1_scores_gather,
                        gather_resident=kops.stage1_scores_gather_resident,
                        centroid=kops.centroid_scores_batched,
                        exact=kops.stage2_scores_batched,
                        sign_gather=_sign_gather_k,
                        sign_gather_resident=_sign_gather_resident_k)

    def _gather(q_msb, plane, block_ids, block_rows):
        return stage1_gather_batched_jnp(q_msb, plane, block_ids,
                                         block_rows=block_rows)

    def _gather_resident(q_msb, plane, block_ids, block_rows):
        return stage1_gather_resident_jnp(q_msb, plane, block_ids,
                                          block_rows=block_rows)

    def _sign_gather(q_sign, sign_plane, block_ids, block_rows):
        return stage0_sign_gather_batched_jnp(q_sign, sign_plane, block_ids,
                                              block_rows=block_rows)

    def _sign_gather_resident(q_sign, sign_plane, block_ids, block_rows):
        return stage0_sign_gather_resident_jnp(q_sign, sign_plane,
                                               block_ids,
                                               block_rows=block_rows)

    return StageFns(plane=stage1_plane_batched_jnp,
                    rows=stage1_rows_batched_jnp,
                    gather=_gather,
                    gather_resident=_gather_resident,
                    centroid=stage1_plane_batched_jnp,
                    exact=stage2_rows_batched_jnp,
                    sign_gather=_sign_gather,
                    sign_gather_resident=_sign_gather_resident)


# ---------------------------------------------------------------------------
# The cascade schedule
# ---------------------------------------------------------------------------

def _vslice(arr: jax.Array, starts: jax.Array, window: int) -> jax.Array:
    """Per-lane dynamic windows: (N, ...) x (B,) starts -> (B, window, ...)."""
    return jax.vmap(
        lambda s: jax.lax.dynamic_slice_in_dim(arr, s, window, 0))(starts)


def _candidate_budget(cfg: RetrievalConfig, num_docs: int,
                      view_rows: int | None) -> int:
    """Stage-2 budget C (the single source both the schedule and `plan`
    use). A restricted view's budget is the SAME as the full-scan one —
    clamped to the view (window or gathered probe rows), in which case
    every visible row is a candidate and the view is rescored
    exhaustively — so results never depend on which code path the arena's
    layout state selects."""
    c = cfg.num_candidates(num_docs)
    if view_rows is not None:
        c = min(c, view_rows)
    return c


def probe_rows(policy: "ClusterPolicy | SlabPolicy") -> int:
    """Static per-lane row count of the cluster policy's gathered view."""
    table = (policy.slab_blocks if isinstance(policy, SlabPolicy)
             else policy.cluster_blocks)
    return min(policy.nprobe,
               policy.centroid_msb.shape[0]) * table.shape[-1] \
        * policy.block_rows


@dataclasses.dataclass
class _CascadeState:
    """The currency cascade stages refine: WHICH rows are still alive.

    rows:   (B, R) explicit global row ids of the current view (-1 holes;
            the slab path clamps holes instead and lets `member` carry
            them), or None when the view is implicit (plane / window).
    member: visibility mask aligned with the view (None = all visible).
    block_ids: (B, J) clamped block ids backing `rows` when the view is a
            block gather (the scalar-prefetch kernel's operand; combined
            plane+slab space under a SlabPolicy).
    comb_rows: (B, R) COMBINED plane+slab row ids aligned with `rows`,
            set by the sign prescreen under a SlabPolicy (where `rows`
            holds arena-global ids but stage 1 must keep gathering from
            the combined array so hits stay physically on the slab).
    top_clusters: (B, nprobe) selected cluster ids when a centroid prune
            ran (the serving runtime reads this back for its cache
            ledger — selection itself stays in-graph).
    result: the final RetrievalResult, set by the terminal stage.
    """

    rows: jax.Array | None = None
    member: jax.Array | None = None
    block_ids: jax.Array | None = None
    comb_rows: jax.Array | None = None
    top_clusters: jax.Array | None = None
    result: RetrievalResult | None = None


@dataclasses.dataclass
class _CascadeCtx:
    """Per-launch invariants every stage reads.

    q_sign is the (B, D) ±1 sign view of the query codes (0 maps to +1,
    matching the packed sign plane's zero-byte convention) — computed
    only when the config enables the stage-0 prescreen, else None.
    """

    query_codes: jax.Array
    q_msb: jax.Array
    db: bitplanar.BitPlanarDB
    policy: Policy
    cfg: RetrievalConfig
    fns: StageFns
    q_sign: jax.Array | None = None


def select_clusters(q_msb: jax.Array, policy: "ClusterPolicy | SlabPolicy",
                    cfg: RetrievalConfig, fns: StageFns) -> jax.Array:
    """Stage 0's cluster selection: score the K centroids and keep each
    lane's top-`nprobe` VALID clusters (a cluster with no blocks for the
    lane's tenant must not spend a probe: its first block id is -1).

    Returns (B, nprobe) int32 cluster ids in rank order. Shared between
    the in-graph CentroidPrune stage and the serving runtime's host-side
    hot-cluster-cache path, so the two can never select differently.
    """
    k_clusters = policy.centroid_msb.shape[0]
    nprobe = min(policy.nprobe, k_clusters)
    scores = fns.centroid(q_msb, policy.centroid_msb)            # (B, K)
    if isinstance(policy, SlabPolicy):
        # Host-precomputed from the same plane table (first block >= 0):
        # identical bits at any launch table width.
        valid = policy.cluster_valid
    else:
        table = policy.cluster_blocks
        if table.ndim == 2:
            valid = (table[:, 0] >= 0)[None, :]
        else:
            valid = table[:, :, 0] >= 0
    if cfg.metric == "cosine":
        key = similarity.cosine_key_f32(scores, policy.centroid_norms)
        key = jnp.where(valid, key, -jnp.inf)
    else:
        key = jnp.where(valid, scores, INT32_MIN)
    _, top_clusters = jax.lax.top_k(key, nprobe)                 # (B, P)
    return top_clusters


def expand_cluster_view(policy: ClusterPolicy, top_clusters: jax.Array,
                        num_docs: int
                        ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Expand selected clusters' blocks into an explicit per-lane row view.

    Returns (rows (B, R) int32 with -1 holes, member (B, R) bool,
    clamped_block_ids (B, J) int32) — the currency ApproxScan's gather
    branch consumes. Shared with the serving runtime so a cached view's
    bookkeeping is the in-graph prune's bookkeeping, by construction.
    """
    pol, n = policy, num_docs
    table = pol.cluster_blocks
    if table.ndim == 2:
        blocks = jnp.take(table, top_clusters, axis=0)           # (B, P, MB)
    else:
        blocks = jnp.take_along_axis(
            table, top_clusters[:, :, None], axis=1)
    b, _, max_blocks = blocks.shape
    blocks = blocks.reshape(b, -1)                               # (B, J)
    br = pol.block_rows
    clamped = jnp.maximum(blocks, 0)
    # Row ids come from the SAME expansion the gather backends use
    # (bitplanar.expand_block_rows), so the prune's bookkeeping can
    # never desynchronize from what stage 1 actually streams.
    rows = bitplanar.expand_block_rows(clamped, br)
    hole = jnp.repeat(blocks < 0, br, axis=1) | (rows >= n)
    rows = jnp.where(hole, -1, rows)
    safe = jnp.maximum(rows, 0)
    own = jnp.take(pol.owner, safe, axis=0)
    # A block at a cluster boundary is listed under BOTH clusters; a
    # row is kept only through its OWN cluster's entry, so a row can
    # never appear twice in the view (duplicates would waste candidate
    # slots and could surface one doc twice in the final top-k).
    owning = jnp.repeat(jnp.repeat(top_clusters, max_blocks, axis=1),
                        br, axis=1)                              # (B, R)
    member = (~hole & (own == pol.tenant_ids[:, None])
              & (pol.tenant_ids >= 0)[:, None]
              & (jnp.take(pol.labels, safe, axis=0) == owning))
    return rows, member, clamped


def expand_slab_view(policy: SlabPolicy, top_clusters: jax.Array
                     ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """The slab path's lean expansion of the selected clusters.

    Returns (rows (B, R) int32 CLAMPED global plane row ids — holes and
    pads point at in-range rows and ride the member mask instead of a -1
    marking, member (B, R) bool, comb_ids (B, J) int32 clamped
    COMBINED-space block ids for the gather). Row ids are derived from
    the per-block `block_gid0`/`block_count` origin scalars, so the same
    code serves both whole-plane-block mirrors (gid0 = block *
    block_rows, count = block_rows — bitwise the cold path's expansion)
    and densely packed slab blocks (gid0 = the run row the block starts
    at, count < block_rows on the tail block, pads masked by `count`).
    The final outputs are sanitized by ExactRescore's member masking, so
    the -1 row marking is redundant work; parity with the cold cascade
    is pinned by tests on both backends.
    """
    pol = policy
    comb = jnp.take_along_axis(pol.slab_blocks,
                               top_clusters[:, :, None], axis=1)
    b, _, w = comb.shape
    comb = comb.reshape(b, -1)                                   # (B, J)
    br = pol.block_rows
    hole = comb < 0
    safe_blk = jnp.maximum(comb, 0)
    gid0 = jnp.take(pol.block_gid0, safe_blk, axis=0)            # (B, J)
    cnt = jnp.take(pol.block_count, safe_blk, axis=0)            # (B, J)
    offs = jnp.arange(br, dtype=jnp.int32)
    rows = (gid0[:, :, None] + offs[None, None, :]).reshape(b, -1)
    live = (offs[None, None, :] < cnt[:, :, None]).reshape(b, -1)
    n = pol.packed_labels.shape[0]
    rows = jnp.minimum(rows, n - 1)      # tail pads stay gatherable
    owning = jnp.repeat(jnp.repeat(top_clusters, w, axis=1),
                        br, axis=1)                              # (B, R)
    k1 = pol.centroid_msb.shape[0] + 1
    expected = (pol.tenant_ids[:, None] + 1) * k1 + owning + 1
    member = (~jnp.repeat(hole, br, axis=1) & live
              & (jnp.take(pol.packed_labels, rows, axis=0) == expected)
              & (pol.tenant_ids >= 0)[:, None])
    return rows, member, safe_blk


@dataclasses.dataclass(frozen=True)
class CentroidPrune:
    """Stage 0: score the K centroids, keep the top-`nprobe` clusters'
    blocks, and expand them into an explicit per-lane row view."""

    nprobe: int

    def run(self, state: _CascadeState, ctx: _CascadeCtx) -> _CascadeState:
        top_clusters = select_clusters(ctx.q_msb, ctx.policy, ctx.cfg,
                                       ctx.fns)
        if isinstance(ctx.policy, SlabPolicy):
            rows, member, comb = expand_slab_view(ctx.policy, top_clusters)
            return dataclasses.replace(state, rows=rows, member=member,
                                       block_ids=comb,
                                       top_clusters=top_clusters)
        rows, member, clamped = expand_cluster_view(ctx.policy, top_clusters,
                                                    ctx.db.num_docs)
        return dataclasses.replace(state, rows=rows, member=member,
                                   block_ids=clamped,
                                   top_clusters=top_clusters)


@dataclasses.dataclass(frozen=True)
class SignPrescreen:
    """Stage 0.5: 1-bit sign-agreement prescreen of the pruned row view.

    Streams only the packed SIGN plane (D/8 bytes per row — 4x fewer
    than the nibble plane) over the centroid prune's gathered view,
    scores sign agreement (±1 dot == 2*popcount(XNOR) - D, monotone-
    equivalent), and keeps each lane's top-`c0` members — so the INT4
    ApproxScan that follows gathers C0 rows instead of the full probe
    view. Two invariants make this safe and testable:

      * survivors are re-sorted into VIEW ORDER (`jnp.sort` on the
        selected view-local indices after top_k): the prescreen only
        DELETES rows from the view, it never reorders it, so at
        c0 >= view_rows the output view is the identity permutation of
        the input and the whole cascade is bit-identical to the
        no-prescreen schedule — the parity anchor the tests pin;
      * non-members (holes, pads, foreign tenants, tombstones) score
        INT32_MIN before the top_k, so with c0 >= k a lane with >= k
        live members can never lose one to a masked row — masked rows
        are only selected when there aren't c0 members at all, and then
        they still carry member=False into both downstream top-ks.

    Under a SlabPolicy the sign bytes stream from the COMBINED sign
    plane (hot clusters' sign rows live on-chip next to their nibble
    slab rows), and the surviving combined row ids are forwarded as
    `comb_rows` so stage 1's per-row gather keeps reading hits from the
    slab region rather than re-streaming the arena plane.
    """

    c0: int

    def run(self, state: _CascadeState, ctx: _CascadeCtx) -> _CascadeState:
        policy, cfg = ctx.policy, ctx.cfg
        r = state.rows.shape[1]
        c0 = cfg.prescreen_budget(r)
        comb_rows = None
        if isinstance(policy, SlabPolicy):
            sign_plane = policy.sign_plane
            if sign_plane is None:
                # Runtime didn't pre-derive the combined sign plane:
                # extract it from the combined nibble plane in-graph
                # (pure bit math — identical bytes, see bitplanar).
                sign_plane = bitplanar.sign_plane_from_msb(policy.slab_plane)
            scores = ctx.fns.sign_gather_resident(
                ctx.q_sign, sign_plane, state.block_ids,
                block_rows=policy.block_rows)
            comb_rows = bitplanar.expand_block_rows(state.block_ids,
                                                    policy.block_rows)
        else:
            sign_plane = ctx.db.sign_plane
            if sign_plane is None:
                sign_plane = bitplanar.sign_plane_from_msb(ctx.db.msb_plane)
            scores = ctx.fns.sign_gather(ctx.q_sign, sign_plane,
                                         state.block_ids,
                                         block_rows=policy.block_rows)
        key0 = jnp.where(state.member, scores, INT32_MIN)
        _, sel = jax.lax.top_k(key0, c0)                       # (B, C0)
        sel = jnp.sort(sel, axis=1)      # survivors keep view order
        rows = jnp.take_along_axis(state.rows, sel, axis=1)
        member = jnp.take_along_axis(state.member, sel, axis=1)
        if comb_rows is not None:
            comb_rows = jnp.take_along_axis(comb_rows, sel, axis=1)
        return dataclasses.replace(state, rows=rows, member=member,
                                   block_ids=None, comb_rows=comb_rows)


@dataclasses.dataclass(frozen=True)
class ApproxScan:
    """Stage 1: batched INT4 MSB scan over the surviving row view, then
    per-lane candidate top-C (the approximate-retrieval stage)."""

    def run(self, state: _CascadeState, ctx: _CascadeCtx) -> _CascadeState:
        db, policy, cfg = ctx.db, ctx.policy, ctx.cfg
        n = db.num_docs
        member = state.member
        view_rows = state.rows          # view-local -> global row id map
        key1 = None                     # set directly by the slab branch
        if isinstance(policy, SlabPolicy):
            # Slab-sourced gather (the serving runtime's cached path):
            # one lean block gather over the combined plane+slab array —
            # hits stream from the cache region, misses from the plane,
            # neither is clamped or zero-masked (ids are pre-validated
            # host-side). The cosine key multiplies the per-generation
            # f32 rsqrt-norm sidecar instead of gathering int64 norms:
            # same f32 bits as cosine_key_f32 on the gathered norms (the
            # trailing + 0.0 canonicalizes the sidecar's masked-zero rows
            # to the reference's literal +0.0).
            r = state.rows.shape[1]
            if r < cfg.k:
                raise ValueError(f"slab view holds {r} rows < k="
                                 f"{cfg.k}: raise nprobe or block_rows")
            c = _candidate_budget(cfg, n, r)
            if state.block_ids is not None:
                scores = ctx.fns.gather_resident(
                    ctx.q_msb, policy.slab_plane, state.block_ids,
                    block_rows=policy.block_rows)
                comb_rows = bitplanar.expand_block_rows(state.block_ids,
                                                        policy.block_rows)
            else:
                # Prescreened view: survivors arrive as combined-space
                # row ids — gather their nibble rows by ROW from the
                # combined array (hot clusters' survivors still read the
                # slab region, cold survivors the plane) and score with
                # the per-lane rows primitive. Same plane bytes as the
                # block gather at the surviving positions, so the
                # c0 >= view_rows anchor stays bit-identical.
                comb_rows = state.comb_rows
                msb_rows = jnp.take(policy.slab_plane, comb_rows, axis=0)
                scores = ctx.fns.rows(ctx.q_msb, msb_rows)
            if cfg.metric == "cosine":
                key1 = (scores.astype(jnp.float32)
                        * jnp.take(policy.inv_norms, comb_rows, axis=0)
                        + 0.0)
                key1 = jnp.where(member, key1, -jnp.inf)
            else:
                key1 = jnp.where(member, scores, INT32_MIN)
            base = None
        elif isinstance(policy, ViewPolicy):
            # Materialized view (the serving runtime's cache path): the
            # rows arrive as data — stage 1 runs the per-lane rows
            # primitive over them; norms stay tiny sidecar reads from the
            # full array, exactly like the gathered branch.
            r = policy.rows.shape[1]
            if r < cfg.k:
                raise ValueError(f"materialized view holds {r} rows < k="
                                 f"{cfg.k}: raise nprobe or block_rows")
            c = _candidate_budget(cfg, n, r)
            scores = ctx.fns.rows(ctx.q_msb, policy.msb_rows)  # (B, R) int32
            norms = jnp.take(db.norms_sq, jnp.maximum(policy.rows, 0),
                             axis=0)
            member = policy.member
            view_rows = policy.rows
            base = None
        elif isinstance(policy, WindowedPolicy):
            if policy.window < cfg.k:
                raise ValueError(f"window {policy.window} < k={cfg.k}: "
                                 "top-k over a window needs window >= k")
            c = _candidate_budget(cfg, n, policy.window)
            starts = jnp.clip(policy.starts, 0,
                              max(n - policy.window, 0)).astype(jnp.int32)
            msb_view = _vslice(db.msb_plane, starts, policy.window)
            norms = _vslice(db.norms_sq, starts, policy.window)
            owner_view = _vslice(policy.owner, starts, policy.window)
            member = ((owner_view == policy.tenant_ids[:, None])
                      & (policy.tenant_ids >= 0)[:, None])
            scores = ctx.fns.rows(ctx.q_msb, msb_view)         # (B, W) int32
            base = starts[:, None]
        elif state.rows is not None:
            # Gathered view (the centroid prune's output): stream only the
            # selected blocks. `rows` maps view-local -> global slot ids.
            r = state.rows.shape[1]
            if r < cfg.k:
                raise ValueError(f"gathered view holds {r} rows < k="
                                 f"{cfg.k}: raise nprobe or block_rows")
            c = _candidate_budget(cfg, n, r)
            if state.block_ids is not None:
                scores = ctx.fns.gather(ctx.q_msb, db.msb_plane,
                                        state.block_ids,
                                        block_rows=policy.block_rows)
            else:
                # Prescreened cluster view: survivors are global row ids
                # (-1 holes clamp to row 0 and ride the member mask; the
                # raw score at a masked position may differ from the
                # block-gather path's zero-row convention — the masked
                # KEY below is identical, which is what parity pins).
                msb_rows = jnp.take(db.msb_plane,
                                    jnp.maximum(state.rows, 0), axis=0)
                scores = ctx.fns.rows(ctx.q_msb, msb_rows)
            norms = jnp.take(db.norms_sq, jnp.maximum(state.rows, 0),
                             axis=0)
            base = None
        else:
            c = _candidate_budget(cfg, n, None)
            scores = ctx.fns.plane(ctx.q_msb, db.msb_plane)    # (B, N) int32
            norms = db.norms_sq[None, :]
            if isinstance(policy, MaskedPolicy):
                member = ((policy.owner[None, :]
                           == policy.tenant_ids[:, None])
                          & (policy.tenant_ids >= 0)[:, None])
            base = None

        if key1 is None and cfg.metric == "cosine":
            # Approximate cosine key; norms are tiny sidecar reads (the
            # paper stores doc norms in DRAM alongside the planes).
            # Tombstoned rows carry norm 0 (key 0), so even an
            # inconsistent membership mask cannot let a dead row win.
            key1 = similarity.cosine_key_f32(scores, norms)
            if member is not None:
                key1 = jnp.where(member, key1, -jnp.inf)
        elif key1 is None:
            key1 = scores if member is None else jnp.where(member, scores,
                                                           INT32_MIN)
        _, cand_local = jax.lax.top_k(key1, c)                 # (B, C) view
        if view_rows is not None:
            cand = jnp.take_along_axis(view_rows, cand_local, axis=1)
        elif base is not None:
            cand = cand_local + base
        else:
            cand = cand_local
        cand_member = (None if member is None else
                       jnp.take_along_axis(member, cand_local, axis=1))
        return dataclasses.replace(state, rows=cand, member=cand_member,
                                   block_ids=None)


@dataclasses.dataclass(frozen=True)
class ExactRescore:
    """Terminal stage: batched gather of the candidates' full INT8 codes,
    exact rescore, metric rerank (non-division comparator for cosine,
    top-k for MIPS)."""

    def run(self, state: _CascadeState, ctx: _CascadeCtx) -> _CascadeState:
        db, cfg = ctx.db, ctx.cfg
        cand, cand_member = state.rows, state.member
        # Candidate rows are gathered from the FULL planes by global id,
        # so the LSB plane is never sliced and restricted views re-read
        # only C rows. Holes (-1) clamp to row 0 and are pinned below
        # every real candidate by the membership mask.
        safe = jnp.maximum(cand, 0)
        msb_rows = jnp.take(db.msb_plane, safe, axis=0)        # (B, C, D//2)
        lsb_rows = jnp.take(db.lsb_plane, safe, axis=0)
        exact = ctx.fns.exact(ctx.query_codes, msb_rows, lsb_rows)
        cand_norms = jnp.take(db.norms_sq, safe, axis=0)
        if cand_member is not None:
            # Out-of-segment candidates pin to (MASKED_SCORE, 1) so the
            # integer rerank comparator ranks them below every in-segment
            # candidate.
            exact = jnp.where(cand_member, exact, MASKED_SCORE)
            cand_norms = jnp.where(cand_member, cand_norms, 1)

        if cfg.metric == "cosine":
            local, top_scores = jax.vmap(
                lambda s, nn: similarity.rerank_dense_comparator(s, nn,
                                                                 cfg.k)
            )(exact, cand_norms)
        else:
            top_scores, local = jax.lax.top_k(exact, cfg.k)

        indices = jnp.take_along_axis(cand, local, axis=1)
        if cand_member is None:
            result = RetrievalResult(indices=indices, scores=top_scores,
                                     candidate_indices=cand)
        else:
            valid = jnp.take_along_axis(cand_member, local, axis=1)
            result = RetrievalResult(
                indices=jnp.where(valid, indices, -1),
                scores=jnp.where(valid, top_scores, 0),
                candidate_indices=jnp.where(cand_member, cand, -1))
        return dataclasses.replace(state, result=result)


def cascade_stages(policy: Policy, cfg: RetrievalConfig) -> tuple:
    """The stage specs one launch will run, selected by policy type.

    The two-stage scheme is the 2-element cascade; the cluster-pruned
    path prepends the centroid prune. Future stages (e.g. a binary-sketch
    pre-prune between prune and scan) slot in here.
    """
    if isinstance(policy, (ClusterPolicy, SlabPolicy)):
        head: tuple = (CentroidPrune(policy.nprobe),)
        if cfg.prescreen_c0 is not None:
            # The adaptive-precision cascade: a 1-bit sign-plane
            # prescreen thins the pruned view before the INT4 scan.
            head += (SignPrescreen(cfg.prescreen_c0),)
        return head + (ApproxScan(), ExactRescore())
    # ViewPolicy enters at ApproxScan: its prune already ran host-side
    # and the view arrives as data.
    return (ApproxScan(), ExactRescore())


def _run_cascade(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                 policy: Policy, cfg: RetrievalConfig) -> _CascadeState:
    q_sign = (bitplanar.sign_pm1(query_codes)
              if cfg.prescreen_c0 is not None else None)
    ctx = _CascadeCtx(query_codes=query_codes,
                      q_msb=quantization.msb_nibble(query_codes),
                      db=db, policy=policy, cfg=cfg,
                      fns=stage_fns(cfg.backend), q_sign=q_sign)
    state = _CascadeState()
    for stage in cascade_stages(policy, cfg):
        state = stage.run(state, ctx)
    return state


def _cascade_batched(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                     policy: Policy, cfg: RetrievalConfig
                     ) -> RetrievalResult:
    """The one batched cascade driver every retrieval variant runs.

    query_codes: (B, D) int8. Returns a batched RetrievalResult whose
    indices are global row/slot ids (-1 for lanes' unfillable positions
    under masking policies).
    """
    return _run_cascade(query_codes, db, policy, cfg).result


def _cascade_batched_aux(query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                         policy: Policy, cfg: RetrievalConfig
                         ) -> tuple[RetrievalResult, jax.Array | None]:
    """The cascade plus its selection as an auxiliary output.

    Returns (result, top_clusters) — top_clusters is the (B, nprobe)
    int32 output of the in-graph CentroidPrune (None for policies without
    a prune stage). The serving runtime reads this tiny array back after
    a cached launch to maintain its slot map and hit/miss ledger, instead
    of re-running selection host-side."""
    state = _run_cascade(query_codes, db, policy, cfg)
    return state.result, state.top_clusters


retrieve_batched = jax.jit(_cascade_batched, static_argnames=("cfg",))
retrieve_batched_aux = jax.jit(_cascade_batched_aux, static_argnames=("cfg",))


# ---------------------------------------------------------------------------
# Schedule planning (host-side, analytic — the paper's bytes currency)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class StagePlan:
    """One cascade stage's exact analytic ledger for one batched launch.

    rows is per LANE (what one query's schedule scores); bytes_hbm is the
    total plane bytes the LAUNCH streams from HBM for this stage (shared-
    plane stages stream once per batch, per-lane views scale with B);
    bytes_sram is the plane bytes the launch served from ON-CHIP memory
    instead — the hot-cluster cache's hits, charged at SRAM rates by
    energy.cost_cascade (the rows still flow through the PEs: MAC counts
    are unchanged, only the fetch got cheaper); bits is the operand width
    of the stage's MACs; compares is the per-lane comparison count the
    stage's select/rerank performs.
    """

    name: str
    rows: int
    bits: int
    bytes_hbm: int
    compares: int
    bytes_sram: int = 0


@dataclasses.dataclass(frozen=True)
class SchedulePlan:
    """What one batched launch will stream, computed exactly (no timers).

    `stages` is the per-stage ledger (prune/approx/exact for the cluster
    cascade, approx/exact for the two-stage kinds) — the measured-counts
    feed for energy.cost_cascade. The flat stage1_* / stage2_* fields are
    the approx/exact stages' totals, kept because schedulers and serving
    ledgers read them directly: stage1_bytes is the batched engine's
    doc-plane traffic (for the plane-scan policies the plane is streamed
    ONCE per batch, so it does not scale with `batch`);
    stage1_bytes_vmapped is what the old one-query-at-a-time full-scan
    path streamed for the same work.
    """

    kind: Literal["plain", "masked", "windowed", "cluster", "view", "decode"]
    batch: int
    rows_scanned: int          # stage-1 rows per lane (N, window, or probe)
    candidates: int            # stage-2 budget C per lane
    stage1_bytes: int          # batched kernel: MSB-plane bytes from HBM
    stage1_bytes_vmapped: int  # the vmapped-scalar path, for comparison
    stage2_bytes: int          # gathered candidate rows (MSB+LSB planes)
    stages: tuple[StagePlan, ...] = ()
    stage1_bytes_sram: int = 0  # stage-1 bytes served from the hot cache

    def publish(self, registry) -> None:
        """Fan this launch's per-stage ledger out to a metrics registry.

        Duck-typed against repro.obs.MetricsRegistry (counter(name,
        **labels).inc(v)); a no-op for disabled registries. Host-side
        arithmetic over already-computed ints — never called from jitted
        code."""
        if not getattr(registry, "enabled", False):
            return
        for st in self.stages:
            registry.counter("stage_rows", stage=st.name).inc(
                st.rows * self.batch)
            registry.counter("stage_bytes_hbm", stage=st.name).inc(
                st.bytes_hbm)
            if st.bytes_sram:
                registry.counter("stage_bytes_sram", stage=st.name).inc(
                    st.bytes_sram)
            registry.counter("stage_compares", stage=st.name).inc(
                st.compares * self.batch)


def plan(cfg: RetrievalConfig, *, num_docs: int, dim: int, batch: int,
         kind: str = "plain", window: int | None = None,
         num_clusters: int | None = None,
         view_rows: int | None = None) -> SchedulePlan:
    """Analytic schedule for one launch of the engine.

    For "plain"/"masked" every lane scans the shared plane: the batched
    matmul kernel fetches each plane block once per BATCH (bytes = N*D/2),
    while the vmapped-scalar path fetched it once per QUERY (B*N*D/2).
    For "windowed" each lane streams its own window, so bytes scale with B
    either way — the win there is one launch + per-tenant work only.
    For "cluster" each lane streams only its `view_rows` gathered probe
    rows (O(N * nprobe / num_clusters) instead of O(N)) after a stage-0
    pass over the `num_clusters`-row centroid plane (streamed once per
    batch — the codebook is tiny and resident).
    """
    d2 = dim // 2
    if kind == "windowed":
        if window is None:
            raise ValueError("windowed plan needs a window")
        rows = min(window, num_docs)
        s1 = batch * rows * d2
        s1_vmapped = s1
        c = _candidate_budget(cfg, num_docs, window)
        stages = ()
    elif kind == "cluster":
        if num_clusters is None or view_rows is None:
            raise ValueError("cluster plan needs num_clusters and view_rows")
        rows = view_rows
        s1 = batch * rows * d2
        s1_vmapped = batch * num_docs * d2     # old path: full scan per query
        c = _candidate_budget(cfg, num_docs, view_rows)
        stages = (StagePlan(name="prune", rows=num_clusters, bits=4,
                            bytes_hbm=num_clusters * d2,
                            compares=num_clusters),)
        c0 = cfg.prescreen_budget(view_rows)
        if c0 is not None:
            # Stage-0 sign prescreen: streams the 1-bit sign plane over
            # the whole probe view (D/8 bytes/row, per lane), then the
            # INT4 approx stage gathers only the C0 survivors.
            stages += (StagePlan(name="prescreen", rows=view_rows, bits=1,
                                 bytes_hbm=batch * view_rows * (dim // 8),
                                 compares=view_rows),)
            rows = c0
            s1 = batch * c0 * d2
            c = _candidate_budget(cfg, num_docs, c0)
    elif kind == "view":
        # A materialized per-lane view (the runtime's cache path): same
        # stage-1 geometry as "cluster" but the prune ran host-side.
        if view_rows is None:
            raise ValueError("view plan needs view_rows")
        rows = view_rows
        s1 = batch * rows * d2
        s1_vmapped = batch * num_docs * d2
        c = _candidate_budget(cfg, num_docs, view_rows)
        stages = ()
    else:
        if window is not None:
            raise ValueError(f"{kind} plan does not take a window")
        rows = num_docs
        s1 = rows * d2
        s1_vmapped = batch * s1
        c = _candidate_budget(cfg, num_docs, None)
        stages = ()
    s2 = batch * c * dim
    stages += (StagePlan(name="approx", rows=rows, bits=4, bytes_hbm=s1,
                         compares=rows),
               StagePlan(name="exact", rows=c, bits=8, bytes_hbm=s2,
                         compares=c * c))
    return SchedulePlan(kind=kind, batch=batch, rows_scanned=rows,
                        candidates=c, stage1_bytes=s1,
                        stage1_bytes_vmapped=s1_vmapped,
                        stage2_bytes=s2, stages=stages)


def cache_split_plan(base: SchedulePlan, *, hbm_bytes: int,
                     sram_bytes: int,
                     prescreen_hbm: int | None = None,
                     prescreen_sram: int = 0) -> SchedulePlan:
    """Re-ledger a launch's approx stage for hot-cluster-cache service.

    The analytic plan charges the whole stage-1 view to HBM; when the
    serving runtime assembled the view partly from cached cluster slices,
    the MEASURED split is hbm_bytes (missed clusters, freshly streamed)
    vs sram_bytes (hits, served from on-chip cache). MAC/compare counts
    are untouched — the cache changes where bytes come from, not how many
    rows are scored. With the sign prescreen enabled the runtime also
    measures the stage-0 split (`prescreen_hbm`/`prescreen_sram` — sign
    bytes of resident clusters, any tier, serve on-chip); None leaves the
    analytic prescreen ledger untouched."""
    def _rewrite(s: StagePlan) -> StagePlan:
        if s.name == "approx":
            return dataclasses.replace(s, bytes_hbm=hbm_bytes,
                                       bytes_sram=sram_bytes)
        if s.name == "prescreen" and prescreen_hbm is not None:
            return dataclasses.replace(s, bytes_hbm=prescreen_hbm,
                                       bytes_sram=prescreen_sram)
        return s
    stages = tuple(_rewrite(s) for s in base.stages)
    return dataclasses.replace(base, stages=stages, stage1_bytes=hbm_bytes,
                               stage1_bytes_sram=sram_bytes)


# ---------------------------------------------------------------------------
# The engine facade
# ---------------------------------------------------------------------------

def _lane(res: RetrievalResult, i: int) -> RetrievalResult:
    return jax.tree_util.tree_map(lambda x: x[i], res)


@dataclasses.dataclass(frozen=True)
class RetrievalEngine:
    """Owns backend selection and the cascade schedule for one config.

    One engine (and thus one compiled program per batch shape and policy
    kind) serves every caller: the thin wrappers in repro.core.retrieval,
    the multi-tenant index, and the serving pipelines all funnel here.
    """

    cfg: RetrievalConfig

    def __post_init__(self):
        # Block-shape autotuning hook: if REPRO_AUTOTUNE_CACHE names a
        # valid artifact for this device, install it before any cascade
        # traces — block choice is resolved at trace time (see
        # kernels/autotune.py). No-op (deterministic DEFAULT_BLOCK_N)
        # without an artifact.
        from repro.kernels import autotune
        autotune.ensure_default_installed()

    def retrieve(self, query_codes: jax.Array, db: bitplanar.BitPlanarDB,
                 policy: Policy = PlainPolicy()) -> RetrievalResult:
        """Batched retrieval: (B, D) int8 queries -> batched result."""
        return retrieve_batched(query_codes, db, policy, self.cfg)

    def retrieve_single(self, query_codes: jax.Array,
                        db: bitplanar.BitPlanarDB,
                        policy: Policy = PlainPolicy()) -> RetrievalResult:
        """(D,) int8 query -> unbatched result (a B=1 lane of the core)."""
        return _lane(self.retrieve(query_codes[None], db, policy), 0)

    def retrieve_with_clusters(self, query_codes: jax.Array,
                               db: bitplanar.BitPlanarDB, policy: Policy
                               ) -> tuple[RetrievalResult, jax.Array | None]:
        """Batched retrieval plus the prune's (B, nprobe) cluster
        selection (None for policies without a prune stage). Same jitted
        cascade; the aux output lets the serving runtime account cache
        hits without re-deriving selection host-side."""
        return retrieve_batched_aux(query_codes, db, policy, self.cfg)

    def plan_for(self, db: bitplanar.BitPlanarDB, batch: int,
                 policy: Policy = PlainPolicy()) -> SchedulePlan:
        """The analytic SchedulePlan for one launch against `db`."""
        kind = {PlainPolicy: "plain", MaskedPolicy: "masked",
                WindowedPolicy: "windowed", ClusterPolicy: "cluster",
                ViewPolicy: "view", SlabPolicy: "cluster"}[type(policy)]
        window = policy.window if isinstance(policy, WindowedPolicy) else None
        if isinstance(policy, (ClusterPolicy, SlabPolicy)):
            num_clusters = policy.centroid_msb.shape[0]
            view_rows = probe_rows(policy)
        elif isinstance(policy, ViewPolicy):
            num_clusters = None
            view_rows = policy.rows.shape[1]
        else:
            num_clusters = view_rows = None
        return plan(self.cfg, num_docs=db.num_docs, dim=db.dim, batch=batch,
                    kind=kind, window=window, num_clusters=num_clusters,
                    view_rows=view_rows)


# ---------------------------------------------------------------------------
# The KV-cache corpus adapter: decode-step attention as a cascade
# ---------------------------------------------------------------------------
#
# A decode-step KV lookup is the same memory-bound shape as retrieval —
# score a query against N stored rows, keep k, touch full precision only
# for survivors — so it runs as the same staged cascade. The corpus is a
# KVCachePolicy (nibble-planar quantized K cache + bf16 V), the lanes are
# (batch, kv-head) pairs instead of queries, and the terminal stage is
# exact softmax ATTENTION over the survivors instead of a rerank:
#
#   KVPagePrune     — CentroidPrune over `page_rows`-sized key pages
#                     (Quest-style page selection: per-page INT8 mean-key
#                     centroids scored with the per-lane rows kernel)
#   KVSignPrescreen — SignPrescreen over the pruned pages' 1-bit sign
#                     plane via the scalar-prefetch stage-0 gather kernel
#   KVApproxTopK    — ApproxScan: f32 query x MSB-nibble keys (x per-row
#                     scale), GQA group-max, per-(batch, kv-head) top-k
#   KVExactAttend   — ExactRescore-shaped terminal: reconstruct INT8 keys
#                     for the k survivors, exact masked softmax attention
#
# With npages/prescreen off the cascade degenerates to the two-stage
# schedule serve.sparse_kv shipped originally, and is BIT-IDENTICAL to it
# (the parity suite pins this, including empty/short caches). `kv_plan`
# emits the same StagePlan ledger shape as `plan`, so energy.cost_cascade
# prices decode bytes exactly like retrieval bytes.

KV_NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class KVCascadeConfig:
    """Static schedule knobs for one decode-attention cascade.

    top_k: exact-attention budget per (batch, kv-head) lane.
    npages: pages kept by KVPagePrune (None = no prune: every position
        enters the approx scan — the original two-stage schedule).
    page_rows: rows per key page (the prune/prescreen block size; the
        cache length T must be a multiple when either stage is on).
    prescreen_c0: survivors kept by the 1-bit sign prescreen (None = off;
        requires npages — the sign gather runs over the pruned pages).
    backend: "jnp" | "pallas" for the integer stages (the f32 approx and
        exact-attend stages are shared verbatim between backends).
    scale: softmax scale (None = hd ** -0.5).
    """

    top_k: int
    npages: int | None = None
    page_rows: int = 8
    prescreen_c0: int | None = None
    backend: Literal["jnp", "pallas"] = "jnp"
    scale: float | None = None

    def __post_init__(self):
        if self.top_k < 1:
            raise ValueError("top_k must be >= 1")
        if self.prescreen_c0 is not None and self.npages is None:
            raise ValueError("prescreen_c0 gates the PRUNED pages' sign "
                             "gather: it needs npages")
        if self.npages is not None and self.page_rows < 1:
            raise ValueError("page_rows must be >= 1")


@dataclasses.dataclass(frozen=True)
class KVCachePolicy:
    """The decode corpus: one layer's quantized KV cache presented to the
    engine. Pure data (a pytree); the schedule is selected by the static
    KVCascadeConfig, mirroring how retrieval policies pair with
    RetrievalConfig.

    k_msb / k_lsb: (B, T, KH, hd//2) uint8 nibble planes of INT8 keys.
    k_scale: (B, T, KH) f32 per-(position, head) quant scales.
    v: (B, T, KH, hd) compute-dtype values.
    length: (B,) int32 valid positions per sequence.
    cent_msb / cent_scale: optional (B, P, KH, hd//2) / (B, P, KH) page
        centroids (P = T // page_rows) — required when npages is set.
    k_sign: optional (B, T, KH, hd//8) packed sign sidecar; the prescreen
        derives it from k_msb in-graph when absent (pure bit extraction,
        identical bytes — see bitplanar.sign_plane_from_msb).
    """

    k_msb: jax.Array
    k_lsb: jax.Array
    k_scale: jax.Array
    v: jax.Array
    length: jax.Array
    cent_msb: jax.Array | None = None
    cent_scale: jax.Array | None = None
    k_sign: jax.Array | None = None


jax.tree_util.register_pytree_node(
    KVCachePolicy,
    lambda p: ((p.k_msb, p.k_lsb, p.k_scale, p.v, p.length, p.cent_msb,
                p.cent_scale, p.k_sign), None),
    lambda _, l: KVCachePolicy(*l))


@dataclasses.dataclass
class _KVState:
    """The currency KV stages refine: WHICH cache positions are alive.

    rows:   (B, KH, R) cache position ids of the current view (None =
            implicit full view, the no-prune schedule).
    member: (B, KH, R) bool — position < length, gathered alongside rows.
    pages:  (B, KH, npages) selected page ids (ascending), kept so the
            prescreen can address the flat sign plane by block.
    out:    the (B, 1, H, hd) attention output, set by KVExactAttend.
    """

    rows: jax.Array | None = None
    member: jax.Array | None = None
    pages: jax.Array | None = None
    out: jax.Array | None = None


@dataclasses.dataclass
class _KVCtx:
    """Per-step invariants every KV stage reads. qg is the f32 grouped
    query (B, KH, G, hd); q_codes/q_scale are its per-head-vector INT8
    quantization (built only when a prune/prescreen stage needs integer
    query operands for the kernels)."""

    q: jax.Array
    qg: jax.Array
    policy: KVCachePolicy
    cfg: KVCascadeConfig
    fns: StageFns
    q_codes: jax.Array | None = None
    q_scale: jax.Array | None = None


def _kv_flat(x: jax.Array) -> jax.Array:
    """(B, T, KH, C) cache plane -> (B*KH*T, C) flat engine plane.

    Row (b*KH + kh)*T + t holds position t of lane (b, kh) — the layout
    that lets the existing scalar-prefetch gather kernels treat the whole
    batched cache as ONE corpus with per-lane block ids."""
    b, t, kh = x.shape[:3]
    return x.transpose(0, 2, 1, 3).reshape(b * kh * t, *x.shape[3:])


def _kv_flat_rows(rows: jax.Array, t: int) -> jax.Array:
    """(B, KH, R) cache positions -> flat plane row ids."""
    b, kh = rows.shape[:2]
    lane = (jnp.arange(b, dtype=jnp.int32)[:, None, None] * kh
            + jnp.arange(kh, dtype=jnp.int32)[None, :, None])
    return lane * t + rows


@dataclasses.dataclass(frozen=True)
class KVPagePrune:
    """Stage 0: score the per-page centroids, keep each (batch, kv-head)
    lane's top-`npages` valid pages, expand to an explicit position view.

    Selection mirrors CentroidPrune/select_clusters: integer centroid
    scores (per-lane rows kernel over the centroid nibble rows) scaled to
    f32 by the query and centroid scales, GQA group-max across the G
    query heads sharing the lane, invalid pages (entirely past `length`)
    masked to -inf before the top-k, and the selected pages re-sorted
    ASCENDING (the SignPrescreen convention: pruning deletes positions
    from the view, it never reorders it — so at full page coverage the
    view is the identity and the cascade converges to the unpruned
    schedule)."""

    npages: int

    def run(self, state: _KVState, ctx: _KVCtx) -> _KVState:
        pol, cfg = ctx.policy, ctx.cfg
        if pol.cent_msb is None or pol.cent_scale is None:
            raise ValueError("npages needs page centroids on the policy "
                             "(cent_msb/cent_scale — see "
                             "serve.sparse_kv.build_page_centroids)")
        b, t, kh, hd = pol.v.shape
        pr = cfg.page_rows
        if t % pr:
            raise ValueError(f"cache length {t} is not a multiple of "
                             f"page_rows={pr}")
        p = t // pr
        if pol.cent_msb.shape[1] != p:
            raise ValueError(f"centroid table holds {pol.cent_msb.shape[1]} "
                             f"pages, cache has {p}")
        npages = min(self.npages, p)
        g = ctx.qg.shape[2]
        q_nib = quantization.msb_nibble(ctx.q_codes).reshape(b * kh * g, hd)
        # Per-lane centroid rows, replicated across the lane's G query
        # heads (the codebook is tiny: P rows of hd/2 bytes).
        cent_rows = jnp.broadcast_to(
            pol.cent_msb.transpose(0, 2, 1, 3)[:, :, None],
            (b, kh, g, p, hd // 2)).reshape(b * kh * g, p, hd // 2)
        scores = ctx.fns.rows(q_nib, cent_rows)              # (B', P) int32
        key = (scores.astype(jnp.float32).reshape(b, kh, g, p)
               * ctx.q_scale.reshape(b, kh, g)[..., None]
               * pol.cent_scale.transpose(0, 2, 1)[:, :, None, :])
        key = jnp.max(key, axis=2)                           # (B, KH, P)
        first_row = jnp.arange(p, dtype=jnp.int32) * pr
        valid = first_row[None, None, :] < jnp.reshape(
            pol.length, (-1, 1, 1)).astype(jnp.int32)
        key = jnp.where(valid, key, -jnp.inf)
        _, pages = jax.lax.top_k(key, npages)                # (B, KH, NP)
        pages = jnp.sort(pages, axis=-1)     # pages keep cache order
        offs = jnp.arange(pr, dtype=jnp.int32)
        rows = (pages[..., None] * pr + offs).reshape(b, kh, npages * pr)
        member = rows < jnp.reshape(pol.length, (-1, 1, 1)).astype(jnp.int32)
        return dataclasses.replace(state, rows=rows, member=member,
                                   pages=pages)


@dataclasses.dataclass(frozen=True)
class KVSignPrescreen:
    """Stage 0.5: 1-bit sign-agreement prescreen of the pruned page view.

    Streams only the packed sign plane of the selected pages (hd/8 bytes
    per position — 4x fewer than the MSB nibble stage) through the
    stage-0 block-gather primitive over the FLAT cache plane (per-lane
    block ids address (lane, page) pairs), group-maxes the ±1-dot
    agreement across the lane's G query heads, and keeps the top-`c0`
    members. Survivors are re-sorted into view order, so at
    c0 >= view_rows the cascade is bit-identical to the no-prescreen
    schedule — the same parity anchor the retrieval SignPrescreen pins.
    """

    c0: int

    def run(self, state: _KVState, ctx: _KVCtx) -> _KVState:
        pol, cfg = ctx.policy, ctx.cfg
        b, t, kh, hd = pol.v.shape
        if hd % 8:
            raise ValueError(f"sign prescreen needs head_dim % 8 == 0, "
                             f"got {hd}")
        pr = cfg.page_rows
        g = ctx.qg.shape[2]
        r = state.rows.shape[2]
        c0 = min(self.c0, r)
        sign = pol.k_sign
        flat_sign = (bitplanar.sign_plane_from_msb(_kv_flat(pol.k_msb))
                     if sign is None else _kv_flat(sign))
        q_sign = bitplanar.sign_pm1(ctx.q_codes).reshape(b * kh * g, hd)
        lane = (jnp.arange(b, dtype=jnp.int32)[:, None, None] * kh
                + jnp.arange(kh, dtype=jnp.int32)[None, :, None])
        flat_pages = lane * (t // pr) + state.pages          # (B, KH, NP)
        blk = jnp.broadcast_to(flat_pages[:, :, None, :],
                               (b, kh, g, flat_pages.shape[-1]))
        scores = ctx.fns.sign_gather(q_sign, flat_sign,
                                     blk.reshape(b * kh * g, -1),
                                     block_rows=pr)          # (B', R) int32
        key = jnp.max(scores.reshape(b, kh, g, r), axis=2)   # (B, KH, R)
        key = jnp.where(state.member, key, INT32_MIN)
        _, sel = jax.lax.top_k(key, c0)                      # (B, KH, C0)
        sel = jnp.sort(sel, axis=-1)         # survivors keep view order
        rows = jnp.take_along_axis(state.rows, sel, axis=2)
        member = jnp.take_along_axis(state.member, sel, axis=2)
        return dataclasses.replace(state, rows=rows, member=member)


@dataclasses.dataclass(frozen=True)
class KVApproxTopK:
    """Stage 1: f32 query x MSB-nibble keys (x per-position scale), GQA
    group-max, NEG_INF masking of dead positions, per-lane top-k.

    The full-view branch is VERBATIM the original sparse_kv stage 1 (same
    einsum on the same operands), and the gathered branch reshapes its
    gathered rows into the same (B, R, KH, hd) layout before the same
    einsum — so at full page coverage both branches produce bit-identical
    scores and the selected positions match the legacy path's exactly."""

    top_k: int

    def run(self, state: _KVState, ctx: _KVCtx) -> _KVState:
        pol = ctx.policy
        b, t, kh, hd = pol.v.shape
        if state.rows is None:
            # Full view: every cached position scored from the MSB plane.
            k_msb = bitplanar.unpack_nibble_plane_signed(
                pol.k_msb.reshape(-1, hd // 2)).reshape(b, t, kh, hd)
            s1 = jnp.einsum("bkgd,btkd->bkgt", ctx.qg,
                            k_msb.astype(jnp.float32))
            s1 = s1 * pol.k_scale.transpose(0, 2, 1)[:, :, None, :]
            s1 = jnp.max(s1, axis=2)                         # (B, KH, T)
            valid = jnp.arange(t)[None, None, :] < jnp.reshape(
                pol.length, (-1, 1, 1)).astype(jnp.int32)
            s1 = jnp.where(valid, s1, KV_NEG_INF)
            k_eff = min(self.top_k, t)
            _, sel = jax.lax.top_k(s1, k_eff)                # (B, KH, k)
            member = sel < jnp.reshape(pol.length,
                                       (-1, 1, 1)).astype(jnp.int32)
            return dataclasses.replace(state, rows=sel, member=member)
        # Gathered view: stream only the surviving positions' nibble rows
        # from the flat plane, reshaped to the full branch's (B, R, KH, hd)
        # layout so the scoring expression is literally the same.
        r = state.rows.shape[2]
        fr = _kv_flat_rows(state.rows, t)
        g_msb = jnp.take(_kv_flat(pol.k_msb), fr.reshape(-1),
                         axis=0).reshape(b, kh, r, hd // 2)
        k_msb = bitplanar.unpack_nibble_plane_signed(
            g_msb.reshape(-1, hd // 2)).reshape(b, kh, r, hd)
        k_msb = k_msb.transpose(0, 2, 1, 3)                  # (B, R, KH, hd)
        scale_sel = jnp.take(_kv_flat(pol.k_scale[..., None])[:, 0],
                             fr.reshape(-1), axis=0).reshape(b, kh, r)
        s1 = jnp.einsum("bkgd,btkd->bkgt", ctx.qg,
                        k_msb.astype(jnp.float32))
        s1 = s1 * scale_sel[:, :, None, :]
        s1 = jnp.max(s1, axis=2)                             # (B, KH, R)
        s1 = jnp.where(state.member, s1, KV_NEG_INF)
        k_eff = min(self.top_k, r)
        _, sel = jax.lax.top_k(s1, k_eff)                    # view-local
        rows = jnp.take_along_axis(state.rows, sel, axis=2)
        member = jnp.take_along_axis(state.member, sel, axis=2)
        return dataclasses.replace(state, rows=rows, member=member)


@dataclasses.dataclass(frozen=True)
class KVExactAttend:
    """Terminal stage: gather the survivors' full nibble planes,
    reconstruct INT8 keys, exact masked softmax attention over them.

    Verbatim the original sparse_kv stage 2, including the masked-softmax
    zero-output fallback: when length < top_k the top-k necessarily
    selects invalid positions, and at length == 0 EVERY selected position
    is invalid — a plain softmax over the all-NEG_INF row would emit
    NaNs, so masked entries contribute exp 0 and an all-masked row
    divides by 1 and outputs exact zeros."""

    def run(self, state: _KVState, ctx: _KVCtx) -> _KVState:
        pol, cfg = ctx.policy, ctx.cfg
        b, t, kh, hd = pol.v.shape
        h = ctx.q.shape[2]
        k_eff = state.rows.shape[2]
        scale = cfg.scale or hd ** -0.5
        sel = state.rows
        bidx = jnp.arange(b)[:, None, None]
        hidx = jnp.arange(kh)[None, :, None]
        msb_sel = pol.k_msb.transpose(0, 2, 1, 3)[bidx, hidx, sel]
        lsb_sel = pol.k_lsb.transpose(0, 2, 1, 3)[bidx, hidx, sel]
        scale_sel = jnp.take_along_axis(
            pol.k_scale.transpose(0, 2, 1), sel, axis=-1)    # (B, KH, k)
        k_int = bitplanar.reconstruct_int8(
            msb_sel.reshape(-1, hd // 2),
            lsb_sel.reshape(-1, hd // 2)).reshape(b, kh, k_eff, hd)
        k_sel = k_int.astype(jnp.float32) * scale_sel[..., None]
        v_sel = pol.v.transpose(0, 2, 1, 3)[bidx, hidx,
                                            sel].astype(jnp.float32)
        s2 = jnp.einsum("bkgd,bktd->bkgt", ctx.qg, k_sel) * scale
        mask = state.member[:, :, None, :]
        s2 = jnp.where(mask, s2, KV_NEG_INF)
        e = jnp.where(mask,
                      jnp.exp(s2 - jnp.max(s2, axis=-1, keepdims=True)),
                      0.0)
        denom = jnp.sum(e, axis=-1, keepdims=True)
        p = e / jnp.where(denom > 0, denom, 1.0)
        out = jnp.einsum("bkgt,bktd->bkgd", p, v_sel)
        out = out.reshape(b, 1, h, hd).astype(ctx.q.dtype)
        return dataclasses.replace(state, out=out)


def kv_cascade_stages(cfg: KVCascadeConfig) -> tuple:
    """The stage specs one decode step runs, selected by the config."""
    stages: tuple = ()
    if cfg.npages is not None:
        stages += (KVPagePrune(cfg.npages),)
    if cfg.prescreen_c0 is not None:
        stages += (KVSignPrescreen(cfg.prescreen_c0),)
    return stages + (KVApproxTopK(cfg.top_k), KVExactAttend())


def _kv_cascade(q: jax.Array, policy: KVCachePolicy,
                cfg: KVCascadeConfig) -> jax.Array:
    """One decode step's staged KV attention.

    q (B, 1, H, hd) against the policy's cache; returns (B, 1, H, hd)."""
    b, _, h, hd = q.shape
    kh = policy.v.shape[2]
    g = h // kh
    qg = q.reshape(b, kh, g, hd).astype(jnp.float32)
    q_codes = q_scale = None
    if cfg.npages is not None or cfg.prescreen_c0 is not None:
        # Integer query operands for the kernel stages: per-head-vector
        # INT8 quantization (a per-lane positive scale — re-applied to the
        # centroid key before group-max so heads compare on equal terms).
        q_codes, q_scale = quantization.quantize_int8(
            qg.reshape(b * kh * g, hd), per_vector=True)
    ctx = _KVCtx(q=q, qg=qg, policy=policy, cfg=cfg,
                 fns=stage_fns(cfg.backend), q_codes=q_codes,
                 q_scale=q_scale)
    state = _KVState()
    for stage in kv_cascade_stages(cfg):
        state = stage.run(state, ctx)
    return state.out


kv_decode_batched = jax.jit(_kv_cascade, static_argnames=("cfg",))


def kv_plan(cfg: KVCascadeConfig, *, batch: int, kv_heads: int,
            q_heads: int, seq_len: int, head_dim: int,
            layers: int = 1) -> SchedulePlan:
    """Analytic StagePlan ledger for ONE decode step (all `layers`).

    Same currency as `plan`: `rows` is per LANE — here a lane is one
    SEQUENCE, so rows count every (layer, kv-head, query-head) MAC row
    the step scores for it — and `bytes_hbm` is what the whole batched
    step streams. Feed `.stages` to energy.cost_cascade with
    batch=`batch` to price µJ per TOKEN per sequence. The no-prune plan
    reconciles exactly with serve.sparse_kv.sparse_bytes_per_step (the
    pruned plans differ only by gather-block padding of the final
    partial page)."""
    t, hd, g = seq_len, head_dim, q_heads // kv_heads
    lanes = layers * kv_heads          # per sequence
    stages: tuple = ()
    r = t
    if cfg.npages is not None:
        p = -(-t // cfg.page_rows)
        npages = min(cfg.npages, p)
        stages += (StagePlan(
            name="prune", rows=lanes * g * p, bits=4,
            bytes_hbm=batch * lanes * p * (hd // 2 + 4),
            compares=lanes * p),)
        r = npages * cfg.page_rows
    if cfg.prescreen_c0 is not None:
        stages += (StagePlan(
            name="prescreen", rows=lanes * g * r, bits=1,
            bytes_hbm=batch * lanes * r * (hd // 8),
            compares=lanes * r),)
        r = min(cfg.prescreen_c0, r)
    k_eff = min(cfg.top_k, r)
    s1 = batch * lanes * r * (hd // 2 + 4)     # MSB plane + f32 scales
    # Exact stage: both nibble planes (hd bytes) + scales for the k
    # surviving keys, plus their bf16 V rows — K is reconstructed INT8,
    # V streams at compute precision.
    s2 = batch * lanes * k_eff * (hd + 4 + 2 * hd)
    stages += (StagePlan(name="approx", rows=lanes * g * r, bits=4,
                         bytes_hbm=s1, compares=lanes * r),
               StagePlan(name="exact", rows=lanes * g * 2 * k_eff, bits=8,
                         bytes_hbm=s2, compares=0))
    return SchedulePlan(kind="decode", batch=batch, rows_scanned=r,
                        candidates=k_eff, stage1_bytes=s1,
                        stage1_bytes_vmapped=s1, stage2_bytes=s2,
                        stages=stages)
