"""Pod-scale sharded hierarchical retrieval index.

The corpus is sharded row-wise over EVERY mesh device (the flattened
(pod, data, model) axes). One retrieval executes as:

  1. local stage-1 (MSB-nibble) scoring over the device's shard — BATCH-
     NATIVE: one (n_local, D/2) x (D/2, B) matmul via the engine's stage
     primitives, so the shard's plane streams once per batch,
  2. local top-C proposal per batch lane,
  3. all-gather of (score, global-id) proposals — O(B * C * devices)
     bytes, independent of corpus size (the "tournament"),
  4. global top-C selection (exact: the global top-C is always contained
     in the union of local top-Cs),
  5. stage-2 exact INT8 rescoring ONLY on the shard(s) owning each
     candidate — one batched (B, C) rescore — combined with a psum (each
     row owned exactly once),
  6. replicated final top-k via the non-division comparator.

The same function runs on a 1-device test mesh and the 512-device
production mesh (shard_map is mesh-polymorphic). Backend selection
(`cfg.backend`) routes the two scoring stages through the same jnp or
Pallas batched primitives the single-host engine uses.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import bitplanar, quantization, similarity
from repro.core.engine import stage_fns
from repro.core.retrieval import RetrievalConfig, RetrievalResult


def pad_database(db: bitplanar.BitPlanarDB, num_shards: int) -> bitplanar.BitPlanarDB:
    """Pad row count to a multiple of num_shards with all-zero docs.

    Zero docs have norm 0 => cosine similarity 0 and MIPS score 0. A score
    of 0 is NOT a floor — it beats every real document whenever all true
    scores are negative (MIPS over anti-correlated queries) — so
    `_tournament_retrieve` masks pad rows (gid >= n_global) out of both
    scoring stages explicitly instead of relying on their zero score.
    """
    n = db.num_docs
    pad = (-n) % num_shards
    if pad == 0:
        return db
    def zpad(a):
        return jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
    return bitplanar.BitPlanarDB(
        msb_plane=zpad(db.msb_plane), lsb_plane=zpad(db.lsb_plane),
        norms_sq=zpad(db.norms_sq), scale=db.scale)


def shard_database(db: bitplanar.BitPlanarDB, mesh: Mesh) -> bitplanar.BitPlanarDB:
    """Place a (padded) database row-sharded over all mesh axes."""
    axes = tuple(mesh.axis_names)
    row_sharded = NamedSharding(mesh, P(axes))
    replicated = NamedSharding(mesh, P())
    return bitplanar.BitPlanarDB(
        msb_plane=jax.device_put(db.msb_plane, row_sharded),
        lsb_plane=jax.device_put(db.lsb_plane, row_sharded),
        norms_sq=jax.device_put(db.norms_sq, row_sharded),
        scale=jax.device_put(db.scale, replicated))


def _tournament_retrieve(q: jax.Array, msb_plane: jax.Array,
                         lsb_plane: jax.Array, norms_sq: jax.Array,
                         *, cfg: RetrievalConfig, n_global: int,
                         axis: str) -> RetrievalResult:
    """Batch-native body run per-shard under shard_map.

    q: (B, D) replicated; planes sharded. Both scoring stages run the
    engine's batched primitives — the whole batch shares one shard scan."""
    n_local = msb_plane.shape[0]
    shard_id = jax.lax.axis_index(axis)
    offset = shard_id * n_local
    c = min(cfg.num_candidates(n_global), n_global)
    c_local = min(c, n_local)
    fns = stage_fns(cfg.backend)
    s1_plane, s2_rows = fns.plane, fns.exact

    # ---- Stage 1: local batched approximate scoring + local proposals.
    q_msb = quantization.msb_nibble(q)
    approx = s1_plane(q_msb, msb_plane)                  # (B, n_local) i32
    if cfg.metric == "cosine":
        key1 = similarity.cosine_key_f32(approx, norms_sq[None, :])
    else:
        key1 = approx.astype(jnp.float32)
    # Pad rows (gid >= n_global, appended by pad_database) score 0, which
    # WINS whenever every real score is negative. -inf removes them from
    # the proposal ranking outright: each shard always holds enough real
    # rows (sum over shards of min(c_local, real rows) >= C, since every
    # shard has the same n_local), so the global top-C is pad-free.
    real = (jnp.arange(n_local, dtype=jnp.int32) + offset) < n_global
    key1 = jnp.where(real[None, :], key1, -jnp.inf)
    loc_key, loc_idx = jax.lax.top_k(key1, c_local)      # (B, c_local)
    loc_gid = (loc_idx + offset).astype(jnp.int32)

    # ---- Tournament: gather proposals, pick global top-C per lane.
    # Shard-major flattening (S * c_local) keeps the same tie-break order
    # as a per-lane all_gather would produce.
    all_key = jax.lax.all_gather(loc_key, axis)          # (S, B, c_local)
    all_gid = jax.lax.all_gather(loc_gid, axis)
    b = q.shape[0]
    all_key = jnp.moveaxis(all_key, 0, 1).reshape(b, -1)
    all_gid = jnp.moveaxis(all_gid, 0, 1).reshape(b, -1)
    top_key, sel = jax.lax.top_k(all_key, c)
    cand_gid = jnp.take_along_axis(all_gid, sel, axis=1)  # (B, C) global ids

    # ---- Stage 2: batched exact rescoring by owners only, psum-combined.
    owned = (cand_gid >= offset) & (cand_gid < offset + n_local)
    local_rows = jnp.clip(cand_gid - offset, 0, n_local - 1)
    msb_rows = jnp.take(msb_plane, local_rows, axis=0)   # (B, C, D//2)
    lsb_rows = jnp.take(lsb_plane, local_rows, axis=0)
    exact = s2_rows(q, msb_rows, lsb_rows)               # (B, C) i32
    nrm = jnp.take(norms_sq, local_rows, axis=0)
    exact = jax.lax.psum(jnp.where(owned, exact, 0), axis)
    cand_norms = jax.lax.psum(jnp.where(owned, nrm, 0), axis)
    # Defense in depth for the final rerank: should a pad gid ever reach
    # the candidate set, its exact score must not be the winning 0.
    # (INT8 dots are bounded by 127^2 * D << 2^31, so INT32_MIN is a true
    # floor; norm 1 keeps the non-division cosine comparator well-posed.)
    pad_cand = cand_gid >= n_global
    exact = jnp.where(pad_cand, jnp.iinfo(jnp.int32).min, exact)
    cand_norms = jnp.where(pad_cand, 1, cand_norms)

    # ---- Replicated final rerank per lane.
    if cfg.metric == "cosine":
        local, scores = jax.vmap(
            lambda s, nn: similarity.rerank_dense_comparator(s, nn, cfg.k)
        )(exact, cand_norms)
    else:
        scores, local = jax.lax.top_k(exact, cfg.k)
    return RetrievalResult(
        indices=jnp.take_along_axis(cand_gid, local, axis=1),
        scores=scores, candidate_indices=cand_gid)


@dataclasses.dataclass(frozen=True)
class ShardedIndex:
    """A database sharded over a mesh + a jitted retrieval entry point."""

    db: bitplanar.BitPlanarDB
    mesh: Mesh
    n_global: int

    @classmethod
    def build(cls, embeddings: jax.Array, mesh: Mesh) -> "ShardedIndex":
        qdb = quantization.build_database(embeddings)
        bp = bitplanar.BitPlanarDB.from_quantized(qdb)
        n_global = bp.num_docs
        bp = pad_database(bp, mesh.devices.size)
        return cls(db=shard_database(bp, mesh), mesh=mesh, n_global=n_global)

    def retrieve_fn(self, cfg: RetrievalConfig):
        """Returns a jittable f(query_codes (D,) or (B, D)) -> RetrievalResult."""
        axes = tuple(self.mesh.axis_names)
        flat_axis = axes if len(axes) > 1 else axes[0]
        row = P(axes)

        def body(q, msb, lsb, nrm):
            fn = partial(_tournament_retrieve, cfg=cfg,
                         n_global=self.n_global, axis=flat_axis)
            if q.ndim == 1:
                # single query = a B=1 lane of the batch-native body
                return jax.tree_util.tree_map(lambda x: x[0],
                                              fn(q[None], msb, lsb, nrm))
            return fn(q, msb, lsb, nrm)

        from repro.compat import shard_map
        shmapped = shard_map(
            body, mesh=self.mesh,
            in_specs=(P(), row, row, row),
            out_specs=RetrievalResult(indices=P(), scores=P(),
                                      candidate_indices=P()),
            check_vma=False)

        @jax.jit
        def retrieve(query_codes):
            return shmapped(query_codes, self.db.msb_plane,
                            self.db.lsb_plane, self.db.norms_sq)

        return retrieve
