"""Architecture config registry: `get_config("<arch-id>")` / `--arch <id>`."""
from __future__ import annotations

import importlib

from repro.models.common import ModelConfig

ARCH_IDS = (
    "qwen2-0.5b",
    "minitron-4b",
    "deepseek-coder-33b",
    "deepseek-67b",
    "mamba2-2.7b",
    "llama4-maverick-400b-a17b",
    "llama4-scout-17b-a16e",
    "zamba2-2.7b",
    "internvl2-26b",
    "seamless-m4t-medium",
)

# the paper's own model, selectable too
EXTRA_IDS = ("minilm-embedder",)

_MOD = {aid: "repro.configs." + aid.replace("-", "_").replace(".", "_")
        for aid in ARCH_IDS + EXTRA_IDS}


def get_config(arch: str, smoke: bool = False) -> ModelConfig:
    if arch not in _MOD:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MOD)}")
    mod = importlib.import_module(_MOD[arch])
    return mod.SMOKE if smoke else mod.FULL
