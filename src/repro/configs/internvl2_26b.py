"""internvl2-26b [vlm] — 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT + InternLM2 [arXiv:2404.16821; hf].

The InternViT vision frontend is a STUB per the assignment:
`input_specs()` provides precomputed patch embeddings
(B, num_prefix_embeds, d_model) that are prepended to the token
embeddings; the LM backbone (InternLM2-20B dims) is fully implemented.
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="internvl2-26b", family="vlm", num_layers=48, d_model=6144,
    num_heads=48, num_kv_heads=8, d_ff=16384, vocab_size=92553,
    num_prefix_embeds=1024, frontend_dim=6144, rope_theta=1e6)

SMOKE = FULL.with_(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                   d_ff=128, vocab_size=128, num_prefix_embeds=8,
                   frontend_dim=64, attn_chunk=64)
