"""mamba2-2.7b [ssm] — 64L d_model=2560 (attn-free) vocab=50280,
ssm_state=128 — SSD (state-space duality) [arXiv:2405.21060; unverified].

d_inner = 2*d_model = 5120, head_dim 64 -> 80 SSD heads, ngroups=1.
num_heads/num_kv_heads/d_ff are unused by the SSM family (attention-free).
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="mamba2-2.7b", family="ssm", num_layers=64, d_model=2560,
    num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=50280,
    ssm_state=128, ssm_expand=2, ssm_head_dim=64, ssm_conv_width=4,
    ssm_chunk=256, tie_embeddings=True)

SMOKE = FULL.with_(num_layers=2, d_model=64, vocab_size=128,
                   ssm_state=16, ssm_head_dim=16, ssm_chunk=16)
