"""seamless-m4t-medium [audio] — 12L d_model=1024 16H (kv=16) d_ff=4096
vocab=256206 — enc-dec, multimodal [arXiv:2308.11596; hf].

12 encoder + 12 decoder layers. The speech frontend is a STUB per the
assignment: `input_specs()` provides precomputed frame embeddings
(B, S_src, d_model) as encoder input.
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="seamless-m4t-medium", family="encdec", num_layers=12,
    encoder_layers=12, d_model=1024, num_heads=16, num_kv_heads=16,
    d_ff=4096, vocab_size=256206, frontend_dim=1024, rope_theta=1e4)

SMOKE = FULL.with_(num_layers=2, encoder_layers=2, d_model=64, num_heads=4,
                   num_kv_heads=4, d_ff=128, vocab_size=128, frontend_dim=64,
                   attn_chunk=64)
