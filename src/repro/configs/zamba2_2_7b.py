"""zamba2-2.7b [hybrid] — 54L d_model=2560 32H (GQA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + SHARED attention blocks
[arXiv:2411.15242; hf].

54 Mamba2 layers; ONE shared transformer block (MHA kv=32, head_dim 80 +
SwiGLU d_ff=10240) applied after every 6 Mamba layers (9 applications,
all reusing the same weights; per-application LoRA deltas omitted —
DESIGN.md §5)."""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="zamba2-2.7b", family="hybrid", num_layers=54, d_model=2560,
    num_heads=32, num_kv_heads=32, head_dim=80, d_ff=10240,
    vocab_size=32000, ssm_state=64, ssm_expand=2, ssm_head_dim=64,
    ssm_conv_width=4, ssm_chunk=256, hybrid_attn_period=6)

SMOKE = FULL.with_(num_layers=4, d_model=64, num_heads=4, num_kv_heads=4,
                   head_dim=16, d_ff=128, vocab_size=128, ssm_state=16,
                   ssm_head_dim=16, ssm_chunk=16, hybrid_attn_period=2,
                   attn_chunk=64)
