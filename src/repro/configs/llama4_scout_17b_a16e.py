"""llama4-scout-17b-a16e [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 16e top-1 — MoE every layer + shared expert
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified]."""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe", num_layers=48,
    d_model=5120, num_heads=40, num_kv_heads=8, d_ff=8192,
    vocab_size=202048, num_experts=16, moe_top_k=1, moe_layer_period=1,
    shared_expert=True, capacity_factor=1.25, rope_theta=5e5)

SMOKE = FULL.with_(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                   d_ff=128, vocab_size=128, num_experts=4, attn_chunk=64)
