"""minilm-embedder — the PAPER's own embedding model (MiniLM-L6-v2 dims +
Sentence-BERT pooling, projected to the paper's 512-dim embeddings)."""
from repro.models.embedder import MINILM_CFG

FULL = MINILM_CFG

SMOKE = FULL.with_(num_layers=2, d_model=32, num_heads=4, num_kv_heads=4,
                   d_ff=64, vocab_size=128, pooled_dim=16)
