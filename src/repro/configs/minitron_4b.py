"""minitron-4b [dense] — 32L d_model=3072 24H (GQA kv=8) d_ff=9216
vocab=256000 — pruned nemotron [arXiv:2407.14679; hf]."""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="minitron-4b", family="dense", num_layers=32, d_model=3072,
    num_heads=24, num_kv_heads=8, d_ff=9216, vocab_size=256000,
    rope_theta=1e4)

SMOKE = FULL.with_(num_layers=2, d_model=64, num_heads=4, num_kv_heads=2,
                   d_ff=128, vocab_size=128, attn_chunk=64)
