"""llama4-maverick-400b-a17b [moe] — 48L d_model=5120 40H (GQA kv=8)
d_ff=8192 vocab=202048, MoE 128e top-1 — interleaved MoE (every other
layer) + shared expert [hf:meta-llama; unverified].

bf16 params + Adafactor: AdamW fp32 moments for 400B params exceed
per-chip HBM on a 256-chip v5e pod (see DESIGN.md §4).
"""
from repro.models.common import ModelConfig

FULL = ModelConfig(
    name="llama4-maverick-400b-a17b", family="moe", num_layers=48,
    d_model=5120, num_heads=40, num_kv_heads=8, d_ff=8192,
    vocab_size=202048, num_experts=128, moe_top_k=1, moe_layer_period=2,
    shared_expert=True, capacity_factor=1.25, param_dtype="bfloat16",
    optimizer="adafactor", rope_theta=5e5)

SMOKE = FULL.with_(num_layers=4, d_model=64, num_heads=4, num_kv_heads=2,
                   d_ff=128, vocab_size=128, num_experts=4, attn_chunk=64,
                   param_dtype="float32", optimizer="adamw")
