"""Pod-scale sharded multi-tenant serving with elastic failover.

Fuses the repo's three scale islands into one serving layer:

  * `tenancy.PlacementTable` — explicit, rendezvous-hashed tenant->shard
    placement (deterministic, minimal movement on shrink);
  * per-shard `MultiTenantIndex` + `ServingRuntime` pairs, each pinned to
    its own device — the PR 4-8 serving stack (deadline batching, hot
    slab cache replicated on the owning shard, async double-buffered
    dispatch) runs UNCHANGED shard-side;
  * `core/index.py`'s tournament merge semantics for spread tenants, and
    `runtime/elastic.py`'s shrink-and-resume posture for device loss.

One submit() fans a request out to the tenant's owner shards; each
owner runs the existing cascade over ITS rows only and proposes its
local top-k (exact stage-2 scores — every row is rescored by its owner,
the tournament's "owner-only exact rescore" with the all-gather realised
host-side); the merge takes the global top-k over the shard-major
concatenation, the same selection order `_tournament_retrieve` applies
on a device mesh. Results are translated from arena slots to per-tenant
DOCUMENT ORDINALS (the tenant-local ids assigned at ingest), which makes
them placement-invariant: the same trace on 1 shard and on an N-shard
mesh returns bit-identical (indices, scores).

Elastic failover (`fail_shard`) mirrors the training driver: mark the
shard dead, shrink the mesh to the survivors, re-place ONLY the lost
shard's tenants from the host-side corpus log (rendezvous hashing keeps
everyone else in place), re-ingest their documents in ordinal order
(arena generation bumps invalidate the affected shards' cache entries),
and resubmit the affected unresolved requests under the new placement.
Resolved handles are never recomputed and unresolved ones resolve
exactly once — the ledger proves zero dropped / zero duplicated.

Determinism notes (what the bit-parity gate rides on):
  * all shards quantize under the same fixed arena scale, so a document's
    INT8 codes are identical wherever it lands;
  * within a shard a tenant's slots ascend in ingest order, so per-shard
    tie-breaks match the single-arena tie-break (by ordinal);
  * spread > 1 requires the MIPS metric: exact int32 dot scores are
    globally comparable, so the host-side merge is a pure top-k. Cosine's
    non-division comparator needs per-candidate norms that never leave
    the shard, so cosine tenants place with spread 1 (enforced).
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.core import quantization
from repro.core.retrieval import RetrievalConfig, RetrievalResult
from repro.distributed.sharding import serving_shard_mesh
from repro.obs.metrics import NULL_REGISTRY
from repro.runtime.fault import HeartbeatMonitor
from repro.serve.runtime import RuntimeConfig, ServingRuntime
from repro.tenancy import MultiTenantIndex, PlacementTable


@dataclasses.dataclass(frozen=True)
class ShardedRuntimeConfig:
    """Topology + per-shard serving knobs.

    num_shards: serving shards (each one arena + one ServingRuntime,
        pinned round-robin onto the visible jax devices; on a 1-device
        host every shard shares it — the routing/merge/failover logic is
        identical, which is what the forced-host tests exploit).
    capacity_per_shard / dim / scale: per-shard arena geometry. The
        quantization scale is shared by ALL shards (fixed at build), so
        codes are placement-invariant.
    spread: shards per tenant (>1 row-shards one tenant's corpus over
        several arenas; requires metric == "mips", see module doc).
    retrieval / runtime: the per-shard RetrievalConfig / RuntimeConfig —
        every shard runs the same config, one compiled program set per
        shard process.
    clusters: optional per-shard ClusterParams. NOTE: each shard trains
        its own codebook on its own rows, so cluster-pruned candidate
        sets are placement-DEPENDENT; leave None (full masked/windowed
        scans) when bit-parity across placements is required.

    Bit-parity across shard counts additionally requires the stage-1
    candidate budget to cover every tenant's row count
    (``retrieval.num_candidates`` scales with arena occupancy, which
    differs per placement — set candidate_frac=1.0 / max_candidates >=
    the largest tenant so the approximate stage never cuts a real row).
    """

    num_shards: int = 4
    capacity_per_shard: int = 1024
    dim: int = 64
    spread: int = 1
    retrieval: RetrievalConfig = dataclasses.field(
        default_factory=RetrievalConfig)
    runtime: RuntimeConfig = dataclasses.field(default_factory=RuntimeConfig)
    clusters: object | None = None
    scale: float | None = None

    def __post_init__(self):
        if self.num_shards < 1:
            raise ValueError("num_shards must be >= 1")
        if not 1 <= self.spread <= self.num_shards:
            raise ValueError(f"spread must be in [1, num_shards], got "
                             f"{self.spread}")
        if self.spread > 1 and self.retrieval.metric != "mips":
            raise ValueError(
                "spread > 1 merges exact scores across shards, which is "
                "only well-defined for the globally-comparable MIPS "
                "metric (cosine needs per-candidate norms that never "
                "leave the owning shard) — use spread=1 for cosine")


class _Shard:
    __slots__ = ("sid", "device", "index", "runtime", "alive")

    def __init__(self, sid, device, index, runtime):
        self.sid = sid
        self.device = device
        self.index = index
        self.runtime = runtime
        self.alive = True


@dataclasses.dataclass
class _SReq:
    """One logical request: its query, its per-shard sub-handles, and its
    merged result (set exactly once)."""
    rid: int
    tenant_id: int
    query: np.ndarray
    deadline: float | None
    subs: dict = dataclasses.field(default_factory=dict)  # sid -> handle
    result: RetrievalResult | None = None
    resubmits: int = 0


class ShardedHandle:
    """Future-style handle for one sharded request (mirrors the
    single-runtime RequestHandle contract: `done()` never blocks,
    `result(wait=False)` returns None as the not-ready signal)."""

    __slots__ = ("_rt", "_req")

    def __init__(self, rt: "ShardedServingRuntime", req: _SReq):
        self._rt = rt
        self._req = req

    @property
    def request_id(self) -> int:
        return self._req.rid

    @property
    def tenant_id(self) -> int:
        return self._req.tenant_id

    @property
    def state(self) -> str:
        if self._req.result is not None:
            return "resolved"
        states = {h.state for h in self._req.subs.values()}
        return "in_flight" if states <= {"in_flight", "resolved"} \
            else "pending"

    def done(self) -> bool:
        return (self._req.result is not None
                or all(h.done() for h in self._req.subs.values()))

    def result(self, *, wait: bool = True) -> RetrievalResult | None:
        return self._rt._resolve(self._req, wait=wait)

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"ShardedHandle(id={self._req.rid}, "
                f"tenant={self._req.tenant_id}, {self.state})")


class ShardedServingRuntime:
    """Tenant-sharded serving over N per-device ServingRuntimes."""

    def __init__(self, cfg: ShardedRuntimeConfig | None = None, *,
                 devices=None, registry=None,
                 heartbeat_timeout_s: float = 30.0):
        self.cfg = cfg or ShardedRuntimeConfig()
        self.registry = NULL_REGISTRY if registry is None else registry
        devices = list(devices if devices is not None else jax.devices())
        c = self.cfg
        self._shards: dict[int, _Shard] = {}
        for sid in range(c.num_shards):
            dev = devices[sid % len(devices)]
            with jax.default_device(dev):
                index = MultiTenantIndex(
                    c.capacity_per_shard, c.dim, c.retrieval,
                    scale=c.scale, clusters=c.clusters)
                runtime = ServingRuntime(
                    index, c.runtime,
                    registry=self.registry.labeled(shard=str(sid)))
            self._shards[sid] = _Shard(sid, dev, index, runtime)
        self.placement = PlacementTable(range(c.num_shards), spread=c.spread)
        self.mesh = serving_shard_mesh([s.device
                                        for s in self._shards.values()])
        # Every shard's arena shares shard 0's fixed quantization scale
        # (same dim + same explicit scale => identical by construction;
        # asserted because placement-invariant codes ride on it).
        self._scale = self._shards[0].index.arena.scale
        assert all(float(s.index.arena.scale) == float(self._scale)
                   for s in self._shards.values())
        self.monitor = HeartbeatMonitor(timeout_s=heartbeat_timeout_s)
        for sid in self._shards:
            self.monitor.beat(str(sid))
        # Host-side corpus log: tenant -> ordinal -> INT8 codes (None =
        # deleted). THE failover source of truth — a lost shard's rows
        # are re-ingested from here, in ordinal order.
        self._corpus: dict[int, list[np.ndarray | None]] = {}
        # (sid, tenant) -> ordinals placed on that shard, ingest order.
        self._placed: dict[tuple[int, int], list[int]] = {}
        # (sid, tenant) -> {arena slot -> ordinal} (result translation).
        self._slot_ord: dict[tuple[int, int], dict[int, int]] = {}
        # tenant -> {ordinal -> (sid, slot)} (deletes + failover purge).
        self._ord_loc: dict[int, dict[int, tuple[int, int]]] = {}
        self._live_reqs: dict[int, _SReq] = {}
        self._next_rid = 0
        # -- exactly-once ledger -------------------------------------------
        self.submitted = 0
        self.resolved = 0
        self.resolved_by_tenant: dict[int, int] = {}
        self.resubmitted = 0
        self.failovers = 0
        self.docs_restored = 0

    # -- topology ------------------------------------------------------------

    @property
    def live_shards(self) -> list[int]:
        return [sid for sid, s in self._shards.items() if s.alive]

    def shard(self, sid: int) -> _Shard:
        return self._shards[sid]

    def _ctx(self, sid: int):
        return jax.default_device(self._shards[sid].device)

    def _check_live(self, sid: int) -> _Shard:
        s = self._shards[sid]
        if not s.alive:
            raise RuntimeError(f"shard {sid} is dead")
        return s

    # -- ingestion -----------------------------------------------------------

    def ingest(self, tenant_id: int, embeddings) -> np.ndarray:
        """Quantize under the shared fixed scale and place; returns the
        new documents' tenant-local ordinals."""
        codes = np.asarray(quantization.quantize_int8_fixed(
            np.asarray(embeddings, np.float32), self._scale))
        return self.ingest_codes(tenant_id, codes)

    def ingest_codes(self, tenant_id: int, codes) -> np.ndarray:
        tid = int(tenant_id)
        codes = np.asarray(codes, np.int8)
        if codes.ndim != 2 or codes.shape[1] != self.cfg.dim:
            raise ValueError(f"codes must be (B, {self.cfg.dim}) int8")
        log = self._corpus.setdefault(tid, [])
        base = len(log)
        ordinals = list(range(base, base + codes.shape[0]))
        by_shard: dict[int, list[int]] = {}
        for o in ordinals:
            by_shard.setdefault(self.placement.doc_shard(tid, o), []).append(o)
        for sid, ords in sorted(by_shard.items()):
            self._ingest_on(sid, tid, codes[[o - base for o in ords]], ords)
        log.extend(codes[i] for i in range(codes.shape[0]))
        return np.asarray(ordinals, np.int64)

    def _ingest_on(self, sid: int, tid: int, codes: np.ndarray,
                   ordinals: list[int]) -> None:
        shard = self._check_live(sid)
        with self._ctx(sid):
            slots = shard.index.ingest_codes(tid, codes)
        self._placed.setdefault((sid, tid), []).extend(ordinals)
        smap = self._slot_ord.setdefault((sid, tid), {})
        omap = self._ord_loc.setdefault(tid, {})
        for slot, o in zip(slots, ordinals):
            smap[int(slot)] = o
            omap[o] = (sid, int(slot))

    def delete(self, tenant_id: int, ordinals) -> None:
        """Tombstone documents by tenant-local ordinal (everywhere they
        live; deleted ordinals are skipped by failover re-ingest)."""
        tid = int(tenant_id)
        omap = self._ord_loc.get(tid, {})
        by_shard: dict[int, list[int]] = {}
        for o in np.atleast_1d(np.asarray(ordinals, np.int64)):
            o = int(o)
            sid, slot = omap[o]
            by_shard.setdefault(sid, []).append(slot)
            self._corpus[tid][o] = None
            del omap[o]
            del self._slot_ord[(sid, tid)][slot]
            self._placed[(sid, tid)].remove(o)
        for sid, slots in sorted(by_shard.items()):
            with self._ctx(sid):
                self._shards[sid].index.delete(tid, slots)

    def num_docs(self, tenant_id: int) -> int:
        return sum(1 for c in self._corpus.get(int(tenant_id), ())
                   if c is not None)

    # -- serving -------------------------------------------------------------

    def submit(self, tenant_id: int, query_codes, *,
               deadline: float | None = None,
               now: float | None = None) -> ShardedHandle:
        """Fan one request out to the tenant's owner shards."""
        tid = int(tenant_id)
        q = np.asarray(query_codes, np.int8)
        req = _SReq(self._next_rid, tid, q, deadline)
        self._next_rid += 1
        for sid in self.placement.owners(tid):
            shard = self._check_live(sid)
            with self._ctx(sid):
                req.subs[sid] = shard.runtime.submit(
                    tid, q, deadline=deadline, now=now)
        self._live_reqs[req.rid] = req
        self.submitted += 1
        return ShardedHandle(self, req)

    def poll(self, now: float | None = None) -> list[ShardedHandle]:
        """Poll every live shard, then harvest (non-blocking) any request
        whose sub-results all landed. Returns handles resolved here."""
        for sid in self.live_shards:
            with self._ctx(sid):
                self._shards[sid].runtime.poll(now)
            self.monitor.beat(str(sid))
        return self._harvest(blocking=False)

    def flush(self, now: float | None = None) -> list[ShardedHandle]:
        """Drain every live shard and resolve every outstanding request."""
        for sid in self.live_shards:
            with self._ctx(sid):
                self._shards[sid].runtime.flush(now)
            self.monitor.beat(str(sid))
        return self._harvest(blocking=True)

    def barrier(self) -> int:
        n = 0
        for sid in self.live_shards:
            with self._ctx(sid):
                n += self._shards[sid].runtime.barrier()
        self._harvest(blocking=False)
        return n

    def _harvest(self, *, blocking: bool) -> list[ShardedHandle]:
        out = []
        for req in list(self._live_reqs.values()):
            if blocking or all(h.done() for h in req.subs.values()):
                self._resolve(req, wait=True)
                out.append(ShardedHandle(self, req))
        return out

    def _resolve(self, req: _SReq, *, wait: bool) -> RetrievalResult | None:
        if req.result is not None:
            return req.result
        if not wait and not all(h.done() for h in req.subs.values()):
            return None
        parts = {}
        for sid in sorted(req.subs):
            with self._ctx(sid):
                parts[sid] = req.subs[sid].result(wait=True)
        req.result = self._merge(req.tenant_id, parts)
        # Exactly-once: the request leaves the live set the moment its
        # result exists — a later failover can never resubmit it, and a
        # second result() call returns the cached merge.
        assert self._live_reqs.pop(req.rid, None) is not None
        self.resolved += 1
        self.resolved_by_tenant[req.tenant_id] = (
            self.resolved_by_tenant.get(req.tenant_id, 0) + 1)
        return req.result

    # -- tournament merge ----------------------------------------------------

    def _xlate(self, sid: int, tid: int, arr: np.ndarray) -> np.ndarray:
        """Arena slots -> tenant-local ordinals (-1 pads pass through)."""
        smap = self._slot_ord.get((sid, tid), {})
        flat = np.asarray(arr).reshape(-1)
        out = np.empty(flat.shape, np.int64)
        for i, s in enumerate(flat):
            out[i] = smap.get(int(s), -1)
        return out.reshape(np.asarray(arr).shape)

    def _merge(self, tid: int, parts: dict[int, RetrievalResult]
               ) -> RetrievalResult:
        """Owner proposals -> global top-k, in tournament order.

        Each owner's (indices, scores) is its exact local top-k — the
        "local proposals, owner-rescored" half of the ShardedIndex
        tournament. The global top-k over their shard-major concatenation
        is exact (it is contained in the union of local top-ks) and the
        (score desc, ordinal asc) order reproduces the single-arena
        tie-break, because within a shard slots ascend in ordinal order.
        """
        k = self.cfg.retrieval.k
        items = []         # (score, ordinal) over all owners' proposals
        cands = []
        for sid in sorted(parts):
            r = parts[sid]
            idx = self._xlate(sid, tid, np.asarray(r.indices))
            sc = np.asarray(r.scores)
            cands.append(self._xlate(sid, tid,
                                     np.asarray(r.candidate_indices)))
            if len(parts) == 1:
                return RetrievalResult(indices=idx, scores=sc,
                                       candidate_indices=cands[0])
            for j in range(idx.shape[-1]):
                if idx[j] >= 0:
                    items.append((int(sc[j]), int(idx[j])))
        items.sort(key=lambda t: (-t[0], t[1]))
        indices = np.full((k,), -1, np.int64)
        scores = np.zeros((k,), np.int32)       # engine pad convention
        for j, (s, o) in enumerate(items[:k]):
            indices[j] = o
            scores[j] = s
        return RetrievalResult(indices=indices, scores=scores,
                               candidate_indices=np.concatenate(cands))

    # -- elastic failover ----------------------------------------------------

    def fail_shard(self, sid: int, now: float | None = None) -> dict:
        """Lose one shard and resume: shrink the mesh, re-place its
        tenants from the host corpus log, invalidate the affected cache
        generations, resubmit its unresolved requests. No request is
        dropped (every live handle resolves) or duplicated (resolved
        handles keep their result and never recompute)."""
        sid = int(sid)
        shard = self._check_live(sid)
        if len(self.live_shards) == 1:
            raise RuntimeError("cannot fail the last live shard")
        shard.alive = False
        self.monitor.remove(str(sid))
        moved = self.placement.remove_shard(sid)

        # Requests that routed through the dead shard (exactly those whose
        # tenant moved); their surviving sub-results are discarded — the
        # whole fan-out re-runs under the post-failure placement, which is
        # safe because results are placement-invariant.
        affected = [r for r in self._live_reqs.values() if sid in r.subs]

        restored = 0
        for tid in sorted(moved):
            lost = self._placed.pop((sid, tid), [])
            self._slot_ord.pop((sid, tid), None)
            codes, ords = [], []
            for o in lost:
                self._ord_loc[tid].pop(o, None)
                row = self._corpus[tid][o]
                if row is not None:
                    codes.append(row)
                    ords.append(o)
            by_shard: dict[int, tuple[list, list]] = {}
            for row, o in zip(codes, ords):
                dst = self.placement.doc_shard(tid, o)
                by_shard.setdefault(dst, ([], []))[0].append(row)
                by_shard[dst][1].append(o)
            for dst, (rows, os_) in sorted(by_shard.items()):
                self._ingest_on(dst, tid, np.stack(rows).astype(np.int8),
                                os_)
                restored += len(os_)
            # The re-ingest bumped the target arenas' generations; sync
            # the owning shards' slab caches NOW so stale entries for the
            # moved tenants are invalidated at failover time, not lazily
            # at their next launch.
            for dst in moved[tid]:
                cache = self._shards[dst].runtime.cache
                if cache is not None:
                    cache.sync_generation(
                        self._shards[dst].index.arena.generation)
        self.docs_restored += restored

        for req in affected:
            req.subs = {}
            for dst in self.placement.owners(req.tenant_id):
                with self._ctx(dst):
                    req.subs[dst] = self._shards[dst].runtime.submit(
                        req.tenant_id, req.query, deadline=req.deadline,
                        now=now)
            req.resubmits += 1
        self.resubmitted += len(affected)
        self.failovers += 1
        self.mesh = serving_shard_mesh(
            [self._shards[s].device for s in self.live_shards])
        return {"shard": sid, "live_shards": self.live_shards,
                "moved_tenants": sorted(moved),
                "docs_restored": restored,
                "requests_resubmitted": len(affected)}

    # -- ledgers -------------------------------------------------------------

    def ledger(self) -> dict:
        """Request ledger + per-shard byte ledgers, aggregated.

        `dropped` and `duplicated` are computed, not asserted: submitted
        splits exactly into resolved + outstanding, and resolutions are
        counted at the single site that sets a request's result."""
        shards = {sid: s.runtime for sid, s in self._shards.items()}
        return {
            "submitted": self.submitted,
            "resolved": self.resolved,
            "outstanding": len(self._live_reqs),
            "dropped": self.submitted - self.resolved - len(self._live_reqs),
            "duplicated": self.resolved - sum(
                self.resolved_by_tenant.values()),
            "resolved_by_tenant": dict(sorted(
                self.resolved_by_tenant.items())),
            "resubmitted": self.resubmitted,
            "failovers": self.failovers,
            "docs_restored": self.docs_restored,
            "shard_lanes_served": {sid: r.queries_served
                                   for sid, r in shards.items()},
            "launches": sum(r.launches for r in shards.values()),
            "stage1_bytes_hbm": sum(r.stage1_bytes_streamed
                                    for r in shards.values()),
            "stage1_bytes_sram": sum(r.stage1_bytes_sram
                                     for r in shards.values()),
        }
