"""Hierarchical sparse-KV decode attention (beyond-paper transfer).

The paper's two-stage idea applied to a DIFFERENT database: the KV cache.
During decode, attending a 32k-500k entry cache is memory-bound — each
step streams the full bf16 K and V. Here:

  Stage 1: score every cached key against the query using only the MSB
           nibble of an INT8-quantized key cache (1/4 the bytes of bf16 K),
  Stage 2: run exact attention ONLY on the top-k surviving positions
           (gather bf16 K/V rows for k << T tokens).

Traffic per step per layer: T*hd/2 bytes (nibble K-plane) + 2*k*hd*2
bytes, versus 2*T*hd*2 for dense — ~8x less for k << T. Attention with a
top-k token budget is the H2O/Quest family of approximations; the paper's
contribution here is the QUANTIZED two-stage filter + nibble-planar
layout, which we reuse verbatim from repro.core.

Exactness property (tested): softmax attention restricted to the true
top-k scores converges to full attention as k grows; with peaked score
distributions (the common case) small k suffices.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import bitplanar

NEG_INF = -1e30


@dataclasses.dataclass
class QuantKVCache:
    """INT8 K cache stored nibble-planar + bf16 V (per layer slice).

    k_msb / k_lsb: (B, T, KH, hd//2) uint8 nibble planes of INT8 keys.
    k_scale: (B, T, KH) f32 per-(position, head) quant scales.
    v: (B, T, KH, hd) compute-dtype values.
    """
    k_msb: jax.Array
    k_lsb: jax.Array
    k_scale: jax.Array
    v: jax.Array


jax.tree_util.register_dataclass(
    QuantKVCache, data_fields=["k_msb", "k_lsb", "k_scale", "v"],
    meta_fields=[])


def quantize_keys(k: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """k (B, T, KH, hd) -> (msb_plane, lsb_plane, scale) per (B,T,KH)."""
    b, t, kh, hd = k.shape
    amax = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(k.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    msb, lsb = bitplanar.pack_nibble_planes(codes.reshape(-1, hd))
    return (msb.reshape(b, t, kh, hd // 2), lsb.reshape(b, t, kh, hd // 2),
            scale)


def build_quant_cache(k: jax.Array, v: jax.Array) -> QuantKVCache:
    msb, lsb, scale = quantize_keys(k)
    return QuantKVCache(k_msb=msb, k_lsb=lsb, k_scale=scale, v=v)


def sparse_decode_attention(q: jax.Array, cache: QuantKVCache,
                            length: jax.Array, top_k: int,
                            scale: float | None = None) -> jax.Array:
    """q (B, 1, H, hd) against the quantized cache; returns (B, 1, H, hd).

    Stage 1 scores use msb-nibble keys (approximate, cheap); stage 2 runs
    exact softmax attention over the per-(B, KH) top-k positions.
    """
    b, _, h, hd = q.shape
    t, kh = cache.v.shape[1], cache.v.shape[2]
    g = h // kh
    scale = scale or hd ** -0.5
    k_eff = min(top_k, t)

    # ---- Stage 1: approximate scores from the MSB nibble plane only.
    k_msb = bitplanar.unpack_nibble_plane_signed(
        cache.k_msb.reshape(-1, hd // 2)).reshape(b, t, kh, hd)
    qg = q.reshape(b, kh, g, hd)
    s1 = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                    k_msb.astype(jnp.float32))
    s1 = s1 * cache.k_scale.transpose(0, 2, 1)[:, :, None, :]  # (B,KH,G,T)
    s1 = jnp.max(s1, axis=2)                                   # (B,KH,T) group-max
    valid = jnp.arange(t)[None, None, :] < jnp.reshape(
        length, (-1, 1, 1)).astype(jnp.int32)
    s1 = jnp.where(valid, s1, NEG_INF)
    _, sel = jax.lax.top_k(s1, k_eff)                          # (B, KH, k)

    # ---- Stage 2: exact attention on the selected positions only.
    # Gather the PLANES first, reconstruct only the k << T survivors
    # (reconstructing the full cache would re-read every LSB byte and
    # forfeit the bit-planar saving).
    bidx = jnp.arange(b)[:, None, None]
    hidx = jnp.arange(kh)[None, :, None]
    msb_sel = cache.k_msb.transpose(0, 2, 1, 3)[bidx, hidx, sel]
    lsb_sel = cache.k_lsb.transpose(0, 2, 1, 3)[bidx, hidx, sel]
    scale_sel = jnp.take_along_axis(
        cache.k_scale.transpose(0, 2, 1), sel, axis=-1)        # (B,KH,k)
    k_int = bitplanar.reconstruct_int8(
        msb_sel.reshape(-1, hd // 2),
        lsb_sel.reshape(-1, hd // 2)).reshape(b, kh, k_eff, hd)
    k_sel = k_int.astype(jnp.float32) * scale_sel[..., None]   # (B,KH,k,hd)
    v_sel = cache.v.transpose(0, 2, 1, 3)[bidx, hidx, sel].astype(jnp.float32)
    s2 = jnp.einsum("bkgd,bktd->bkgt", qg.astype(jnp.float32), k_sel) * scale
    sel_valid = sel < jnp.reshape(length, (-1, 1, 1)).astype(jnp.int32)
    mask = sel_valid[:, :, None, :]
    s2 = jnp.where(mask, s2, NEG_INF)
    # Masked softmax with a zero-output fallback: when length < top_k the
    # top_k over NEG_INF-masked stage-1 scores selects invalid positions,
    # and at length == 0 EVERY selected position is invalid — a plain
    # softmax over the all-NEG_INF row then emits NaNs (exp(0)/sum == 1/k
    # of garbage rows at best, 0/0 after masking at worst). For non-empty
    # rows this is bit-identical to jax.nn.softmax: masked entries
    # contribute exp(NEG_INF - max) == 0 either way.
    e = jnp.where(mask, jnp.exp(s2 - jnp.max(s2, axis=-1, keepdims=True)),
                  0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = e / jnp.where(denom > 0, denom, 1.0)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v_sel)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def dense_bytes_per_step(t: int, hd: int, kv_bytes: int = 2) -> int:
    """HBM bytes per (layer, kv-head) for dense decode: full K + V."""
    return 2 * t * hd * kv_bytes


def sparse_bytes_per_step(t: int, hd: int, top_k: int,
                          kv_bytes: int = 2) -> int:
    """Nibble K-plane scan + exact K/V gather of top-k rows (+ scales)."""
    return t * hd // 2 + t * 4 + 2 * top_k * hd * kv_bytes
