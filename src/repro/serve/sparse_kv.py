"""Sparse-KV decode attention: a thin wrapper over the engine's KV cascade.

The paper's two-stage idea applied to a DIFFERENT database: the KV cache.
During decode, attending a 32k-500k entry cache is memory-bound — each
step streams the full bf16 K and V. The schedule now lives in
repro.core.engine as a first-class cascade over a `KVCachePolicy`
(KVPagePrune -> KVSignPrescreen -> KVApproxTopK -> KVExactAttend); this
module is the cache-facing adapter:

  * `QuantKVCache` — the nibble-planar INT8 K + bf16 V storage (one
    layer slice), with optional Quest-style page-centroid sidecars;
  * `sparse_decode_attention` — the public entry point, now dispatching
    into `engine.kv_decode_batched`. With the default (no-prune) config
    it is BIT-IDENTICAL to `sparse_decode_attention_ref`, the original
    hand-rolled implementation kept verbatim below as the parity oracle
    (tests gate exact equality across lengths {0, <top_k, >=top_k} on
    both backends);
  * the decode byte model (`dense_bytes_per_step` /
    `sparse_bytes_per_step`) — reconciled exactly with the engine's
    `kv_plan` StagePlan ledger, so energy.cost_cascade prices decode
    bytes the same way it prices retrieval bytes.

Traffic per step per (layer, kv-head): T*hd/2 bytes (nibble K-plane)
+ T*4 (scales) + k*(hd + 4) (exact K planes + scales) + 2*k*hd (bf16 V),
versus 2*T*hd*2 for dense — >4x less for k << T, and the page prune cuts
the T-proportional term to npages*page_rows as well. Attention with a
top-k token budget is the H2O/Quest family of approximations; the
paper's contribution here is the QUANTIZED staged filter + nibble-planar
layout, reused verbatim from repro.core.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.core import bitplanar, engine

NEG_INF = -1e30


@dataclasses.dataclass
class QuantKVCache:
    """INT8 K cache stored nibble-planar + bf16 V (per layer slice).

    k_msb / k_lsb: (B, T, KH, hd//2) uint8 nibble planes of INT8 keys.
    k_scale: (B, T, KH) f32 per-(position, head) quant scales.
    v: (B, T, KH, hd) compute-dtype values.
    cent_msb / cent_scale: optional (B, P, KH, hd//2) / (B, P, KH) page
        centroids (P = T // page_rows) enabling the engine's Quest-style
        page prune — see `build_page_centroids` / `update_page_centroids`.
    """
    k_msb: jax.Array
    k_lsb: jax.Array
    k_scale: jax.Array
    v: jax.Array
    cent_msb: jax.Array | None = None
    cent_scale: jax.Array | None = None


jax.tree_util.register_dataclass(
    QuantKVCache, data_fields=["k_msb", "k_lsb", "k_scale", "v",
                               "cent_msb", "cent_scale"],
    meta_fields=[])


def quantize_keys(k: jax.Array) -> tuple[jax.Array, jax.Array, jax.Array]:
    """k (B, T, KH, hd) -> (msb_plane, lsb_plane, scale) per (B,T,KH)."""
    b, t, kh, hd = k.shape
    amax = jnp.max(jnp.abs(k.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(k.astype(jnp.float32) / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    msb, lsb = bitplanar.pack_nibble_planes(codes.reshape(-1, hd))
    return (msb.reshape(b, t, kh, hd // 2), lsb.reshape(b, t, kh, hd // 2),
            scale)


def build_quant_cache(k: jax.Array, v: jax.Array) -> QuantKVCache:
    msb, lsb, scale = quantize_keys(k)
    return QuantKVCache(k_msb=msb, k_lsb=lsb, k_scale=scale, v=v)


def _quantize_centroids(mean: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(..., hd) f32 page means -> (packed msb nibbles (..., hd//2),
    scale (...,)) — the same symmetric INT8 scheme as the keys, so the
    centroid plane is just another corpus the stage-1 kernels score."""
    hd = mean.shape[-1]
    amax = jnp.max(jnp.abs(mean), axis=-1)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    codes = jnp.clip(jnp.round(mean / scale[..., None]),
                     -127, 127).astype(jnp.int8)
    msb, _ = bitplanar.pack_nibble_planes(codes.reshape(-1, hd))
    return msb.reshape(*mean.shape[:-1], hd // 2), scale


def build_page_centroids(cache: QuantKVCache, length: jax.Array,
                         page_rows: int = 8) -> QuantKVCache:
    """Derive per-page mean-key centroids for the engine's page prune.

    Pages are `page_rows` consecutive positions; each centroid is the
    mean of the page's VALID (pos < length) dequantized keys, re-quantized
    to INT8 and stored MSB-nibble-packed (+ f32 scale) per (B, page, KH).
    Returns a new cache with cent_msb/cent_scale set. T must be a
    multiple of page_rows (decode caches pad their max_len up)."""
    b, t, kh, hd2 = cache.k_msb.shape
    hd = hd2 * 2
    if t % page_rows:
        raise ValueError(f"cache length {t} not a multiple of "
                         f"page_rows={page_rows}")
    p = t // page_rows
    k_int = bitplanar.reconstruct_int8(cache.k_msb.reshape(-1, hd2),
                                       cache.k_lsb.reshape(-1, hd2))
    k_f = (k_int.reshape(b, t, kh, hd).astype(jnp.float32)
           * cache.k_scale[..., None])
    pagev = k_f.reshape(b, p, page_rows, kh, hd)
    pos = (jnp.arange(p)[:, None] * page_rows
           + jnp.arange(page_rows)[None, :])                 # (P, pr)
    live = pos[None] < jnp.reshape(length, (-1, 1, 1)).astype(jnp.int32)
    cnt = jnp.sum(live, axis=2).astype(jnp.float32)          # (B, P)
    mean = (jnp.sum(jnp.where(live[..., None, None], pagev, 0.0), axis=2)
            / jnp.maximum(cnt, 1.0)[..., None, None])        # (B, P, KH, hd)
    cent_msb, cent_scale = _quantize_centroids(mean)
    return dataclasses.replace(cache, cent_msb=cent_msb,
                               cent_scale=cent_scale)


def update_page_centroids(k_msb: jax.Array, k_lsb: jax.Array,
                          k_scale: jax.Array, cent_msb: jax.Array,
                          cent_scale: jax.Array, length: jax.Array,
                          page_rows: int) -> tuple[jax.Array, jax.Array]:
    """Incrementally refresh ONE page's centroid after an append.

    The decode step writes position length-1; only that page's mean can
    change, so the online index maintenance (EdgeRAG's discipline applied
    to the KV cache) re-reads just `page_rows` quantized rows per step
    and re-quantizes one centroid — O(page_rows * hd) work, no rebuild.
    Returns the updated (cent_msb, cent_scale)."""
    b, t, kh, hd2 = k_msb.shape
    hd = hd2 * 2
    idx = (length - 1).astype(jnp.int32)                     # (B,)
    pidx = idx // page_rows
    start = pidx * page_rows
    offs = jnp.arange(page_rows, dtype=jnp.int32)
    rows = start[:, None] + offs[None, :]                    # (B, pr)
    pm = jnp.take_along_axis(k_msb, rows[:, :, None, None], axis=1)
    pl = jnp.take_along_axis(k_lsb, rows[:, :, None, None], axis=1)
    ps = jnp.take_along_axis(k_scale, rows[:, :, None], axis=1)
    k_f = (bitplanar.reconstruct_int8(pm.reshape(-1, hd2),
                                      pl.reshape(-1, hd2))
           .reshape(b, page_rows, kh, hd).astype(jnp.float32)
           * ps[..., None])
    ncnt = jnp.clip(length.astype(jnp.int32) - start, 1, page_rows)
    live = offs[None, :] < ncnt[:, None]                     # (B, pr)
    mean = (jnp.sum(jnp.where(live[:, :, None, None], k_f, 0.0), axis=1)
            / ncnt.astype(jnp.float32)[:, None, None])       # (B, KH, hd)
    nm, ns = _quantize_centroids(mean)
    rows_b = jnp.arange(b)
    return (cent_msb.at[rows_b, pidx].set(nm),
            cent_scale.at[rows_b, pidx].set(ns))


def kv_policy(cache: QuantKVCache, length: jax.Array
              ) -> engine.KVCachePolicy:
    """Present this cache slice as an engine corpus."""
    return engine.KVCachePolicy(
        k_msb=cache.k_msb, k_lsb=cache.k_lsb, k_scale=cache.k_scale,
        v=cache.v, length=jnp.asarray(length, jnp.int32),
        cent_msb=cache.cent_msb, cent_scale=cache.cent_scale)


def sparse_decode_attention(q: jax.Array, cache: QuantKVCache,
                            length: jax.Array, top_k: int,
                            scale: float | None = None, *,
                            npages: int | None = None,
                            prescreen_c0: int | None = None,
                            page_rows: int = 8,
                            backend: str = "jnp") -> jax.Array:
    """q (B, 1, H, hd) against the quantized cache; returns (B, 1, H, hd).

    Dispatches into the engine's KV cascade. The default (no npages /
    prescreen) schedule is the original two-stage filter — approximate
    MSB-nibble scores, exact masked softmax over the per-(B, KH) top-k —
    and is bit-identical to `sparse_decode_attention_ref`. `npages`
    prepends the Quest-style page prune (needs cent_msb on the cache);
    `prescreen_c0` adds the 1-bit sign prescreen between prune and scan;
    `backend` selects jnp vs Pallas kernels for the integer stages.
    """
    cfg = engine.KVCascadeConfig(
        top_k=top_k, npages=npages, page_rows=page_rows,
        prescreen_c0=prescreen_c0, backend=backend, scale=scale)
    return engine.kv_decode_batched(q, kv_policy(cache, length), cfg)


def sparse_decode_attention_ref(q: jax.Array, cache: QuantKVCache,
                                length: jax.Array, top_k: int,
                                scale: float | None = None) -> jax.Array:
    """The ORIGINAL hand-rolled two-stage implementation, kept verbatim
    as the bit-parity oracle for the engine-backed path (the parity suite
    gates exact equality, including the length<top_k / empty-cache
    masked-softmax edge cases)."""
    b, _, h, hd = q.shape
    t, kh = cache.v.shape[1], cache.v.shape[2]
    g = h // kh
    scale = scale or hd ** -0.5
    k_eff = min(top_k, t)

    # ---- Stage 1: approximate scores from the MSB nibble plane only.
    k_msb = bitplanar.unpack_nibble_plane_signed(
        cache.k_msb.reshape(-1, hd // 2)).reshape(b, t, kh, hd)
    qg = q.reshape(b, kh, g, hd)
    s1 = jnp.einsum("bkgd,btkd->bkgt", qg.astype(jnp.float32),
                    k_msb.astype(jnp.float32))
    s1 = s1 * cache.k_scale.transpose(0, 2, 1)[:, :, None, :]  # (B,KH,G,T)
    s1 = jnp.max(s1, axis=2)                                   # (B,KH,T) group-max
    valid = jnp.arange(t)[None, None, :] < jnp.reshape(
        length, (-1, 1, 1)).astype(jnp.int32)
    s1 = jnp.where(valid, s1, NEG_INF)
    _, sel = jax.lax.top_k(s1, k_eff)                          # (B, KH, k)

    # ---- Stage 2: exact attention on the selected positions only.
    # Gather the PLANES first, reconstruct only the k << T survivors
    # (reconstructing the full cache would re-read every LSB byte and
    # forfeit the bit-planar saving).
    bidx = jnp.arange(b)[:, None, None]
    hidx = jnp.arange(kh)[None, :, None]
    msb_sel = cache.k_msb.transpose(0, 2, 1, 3)[bidx, hidx, sel]
    lsb_sel = cache.k_lsb.transpose(0, 2, 1, 3)[bidx, hidx, sel]
    scale_sel = jnp.take_along_axis(
        cache.k_scale.transpose(0, 2, 1), sel, axis=-1)        # (B,KH,k)
    k_int = bitplanar.reconstruct_int8(
        msb_sel.reshape(-1, hd // 2),
        lsb_sel.reshape(-1, hd // 2)).reshape(b, kh, k_eff, hd)
    k_sel = k_int.astype(jnp.float32) * scale_sel[..., None]   # (B,KH,k,hd)
    v_sel = cache.v.transpose(0, 2, 1, 3)[bidx, hidx, sel].astype(jnp.float32)
    s2 = jnp.einsum("bkgd,bktd->bkgt", qg.astype(jnp.float32), k_sel) * scale
    sel_valid = sel < jnp.reshape(length, (-1, 1, 1)).astype(jnp.int32)
    mask = sel_valid[:, :, None, :]
    s2 = jnp.where(mask, s2, NEG_INF)
    # Masked softmax with a zero-output fallback: when length < top_k the
    # top_k over NEG_INF-masked stage-1 scores selects invalid positions,
    # and at length == 0 EVERY selected position is invalid — a plain
    # softmax over the all-NEG_INF row then emits NaNs (exp(0)/sum == 1/k
    # of garbage rows at best, 0/0 after masking at worst). For non-empty
    # rows this is bit-identical to jax.nn.softmax: masked entries
    # contribute exp(NEG_INF - max) == 0 either way.
    e = jnp.where(mask, jnp.exp(s2 - jnp.max(s2, axis=-1, keepdims=True)),
                  0.0)
    denom = jnp.sum(e, axis=-1, keepdims=True)
    p = e / jnp.where(denom > 0, denom, 1.0)
    out = jnp.einsum("bkgt,bktd->bkgd", p, v_sel)
    return out.reshape(b, 1, h, hd).astype(q.dtype)


def dense_bytes_per_step(t: int, hd: int, kv_bytes: int = 2) -> int:
    """HBM bytes per (layer, kv-head) for dense decode: full K + V."""
    return 2 * t * hd * kv_bytes


def sparse_bytes_per_step(t: int, hd: int, top_k: int,
                          kv_bytes: int = 2) -> int:
    """Nibble K-plane scan + scales + exact gather of the top-k rows.

    Exact accounting per (layer, kv-head) per step: the full MSB plane
    (t*hd/2) + f32 scales (4t), then BOTH nibble planes + scale for each
    of the k survivors (k*(hd+4) — K is reconstructed from INT8, never
    re-read at bf16) + their V rows at compute precision (k*hd*kv_bytes).
    Reconciles exactly with engine.kv_plan's no-prune approx+exact
    stages divided by (layers * batch * kv_heads)."""
    return t * hd // 2 + t * 4 + top_k * (hd + 4) + top_k * hd * kv_bytes


def decode_plan(cfg_or_topk, *, batch: int, kv_heads: int, q_heads: int,
                seq_len: int, head_dim: int,
                layers: int = 1) -> engine.SchedulePlan:
    """Convenience: the engine's kv_plan from either a KVCascadeConfig or
    a bare top_k (the no-prune schedule)."""
    cfg = (cfg_or_topk if isinstance(cfg_or_topk, engine.KVCascadeConfig)
           else engine.KVCascadeConfig(top_k=int(cfg_or_topk)))
    return engine.kv_plan(cfg, batch=batch, kv_heads=kv_heads,
                          q_heads=q_heads, seq_len=seq_len,
                          head_dim=head_dim, layers=layers)
