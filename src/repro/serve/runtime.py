"""Session-aware serving runtime: deadline batcher + hot-cluster cache.

The wearable workload is a stream of small, temporally-correlated request
bursts: T users' agents each fire a query every few seconds, and
consecutive queries of one session probe the SAME few clusters
(continuous monitoring revisits the same part of the corpus). This module
is the serving layer that exploits both properties on top of the
cluster-pruned cascade:

  * `ServingRuntime` — a dynamic batcher that grew out of the synchronous
    `tenancy.scheduler` submit/flush loop: requests get FUTURE-STYLE
    handles, admission is deadline-OR-max-batch (a batch launches the
    moment it is full, or when the oldest request's deadline arrives —
    whichever comes first), partial batches pad to power-of-two buckets
    (one compiled executable per bucket), and batch formation is
    per-tenant fair (round-robin across tenants ordered by deadline, so
    one chatty user cannot starve the rest of a flush).

  * `HotClusterCache` — an EdgeRAG-style byte-budgeted LRU over gathered
    stage-1 plane views, keyed by (arena generation, tenant, cluster).
    When a flush runs the cluster cascade, the prune's cluster selection
    runs host-side (the engine's own `select_clusters`, so the choice is
    identical by construction) and the per-lane stage-1 view is assembled
    from cached cluster slices plus fresh gathers; only the MISSES stream
    plane bytes from HBM. Any arena mutation bumps the generation and
    invalidates every entry — a stale view can never be served. A
    per-tenant RECENT-CLUSTER prior (the clusters the tenant's last turns
    probed) warms the cache between session turns.

  * The launch ledger (`engine.SchedulePlan` via `cache_split_plan`)
    splits stage-1 bytes into HBM misses vs SRAM hits, and
    `energy.cost_cascade` charges hits at SRAM rates — so the runtime
    reports the measured uJ/query saving of the cache, in the paper's
    own accounting currency.

Results are BIT-IDENTICAL to the uncached cascade (and to sequential
retrieval): the cache changes where stage-1 bytes come from, never what
is scored — pinned by the parity and property suites in
tests/test_serve_runtime.py and tests/test_runtime_properties.py.
"""
from __future__ import annotations

import collections
import dataclasses
import heapq
import math
import time

import jax.numpy as jnp
import numpy as np

from repro.core import energy, engine, quantization
from repro.core.retrieval import NO_TENANT, RetrievalResult


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Host-side serving knobs.

    max_batch: lanes per launch (full batch => immediate launch).
    max_wait: seconds a request may sit in the queue before its default
        deadline forces a (possibly partial) launch. 0 = launch only when
        full or explicitly flushed.
    fairness: "deadline_rr" interleaves tenants round-robin (ordered by
        their head request's deadline); "fifo" preserves strict arrival
        order (the legacy scheduler's grouping).
    cache_bytes: hot-cluster cache budget in bytes of cached stage-1
        plane views (0 disables caching — every flush streams from HBM).
    prior_clusters: how many recently-probed clusters to remember per
        tenant (the session prior that pre-warms the cache each flush).
    auto_flush: launch full batches directly from submit() instead of
        waiting for poll()/flush().
    """

    max_batch: int = 16
    max_wait: float = 0.005
    fairness: str = "deadline_rr"
    cache_bytes: int = 0
    prior_clusters: int = 8
    auto_flush: bool = True

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_wait < 0:
            raise ValueError("max_wait must be >= 0")
        if self.fairness not in ("deadline_rr", "fifo"):
            raise ValueError(f"unknown fairness policy {self.fairness!r}")
        if self.cache_bytes < 0 or self.prior_clusters < 0:
            raise ValueError("cache_bytes/prior_clusters must be >= 0")


class RequestHandle:
    """Future-style handle for one submitted query.

    Resolved by the runtime when the request's batch launches; `result()`
    drains the runtime if the request is still queued (or raises with
    ``wait=False``)."""

    __slots__ = ("request_id", "tenant_id", "deadline", "launch_index",
                 "_runtime", "_result")

    def __init__(self, runtime: "ServingRuntime", request_id: int,
                 tenant_id: int, deadline: float):
        self.request_id = request_id
        self.tenant_id = tenant_id
        self.deadline = deadline
        self.launch_index: int | None = None   # which launch resolved it
        self._runtime = runtime
        self._result: RetrievalResult | None = None

    def done(self) -> bool:
        return self._result is not None

    def result(self, *, wait: bool = True) -> RetrievalResult:
        if self._result is None:
            if not wait:
                raise RuntimeError(
                    f"request {self.request_id} still queued; poll() or "
                    "flush() the runtime (or call result(wait=True))")
            self._runtime.flush()
        assert self._result is not None
        return self._result

    def __repr__(self):  # pragma: no cover - debugging aid
        state = "done" if self.done() else "pending"
        return (f"RequestHandle(id={self.request_id}, "
                f"tenant={self.tenant_id}, {state})")


@dataclasses.dataclass
class _Pending:
    handle: RequestHandle
    query: np.ndarray             # (D,) int8
    seq: int                      # arrival order


@dataclasses.dataclass
class _CacheEntry:
    view: np.ndarray              # (nblocks * block_rows, D//2) uint8
    nbytes: int


class HotClusterCache:
    """Byte-budgeted LRU of gathered stage-1 cluster views.

    Entries are keyed (tenant, cluster) and valid only for the arena
    generation they were gathered under: `sync_generation` clears the
    whole cache whenever the arena mutated (insert/delete/compact all
    bump the generation), so a stale plane view can never be served —
    correctness never depends on the eviction heuristic. Within a
    generation, eviction is least-recently-used under `budget_bytes`.
    """

    def __init__(self, budget_bytes: int):
        if budget_bytes < 0:
            raise ValueError("budget_bytes must be >= 0")
        self.budget_bytes = budget_bytes
        self._entries: "collections.OrderedDict[tuple[int, int], _CacheEntry]" = (
            collections.OrderedDict())
        self._generation = -1
        self.bytes_used = 0
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.stale_evictions = 0
        self.rejected = 0          # views larger than the whole budget

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def generation(self) -> int:
        return self._generation

    def sync_generation(self, generation: int) -> None:
        """Invalidate everything gathered under an older arena state."""
        if generation != self._generation:
            self.stale_evictions += len(self._entries)
            self._entries.clear()
            self.bytes_used = 0
            self._generation = generation

    def get(self, tenant: int, cluster: int) -> _CacheEntry | None:
        entry = self._entries.get((tenant, cluster))
        if entry is None:
            self.misses += 1
            return None
        self._entries.move_to_end((tenant, cluster))
        self.hits += 1
        return entry

    def peek(self, tenant: int, cluster: int) -> bool:
        """Membership check without touching hit/miss counters or LRU."""
        return (tenant, cluster) in self._entries

    def touch(self, tenant: int, cluster: int) -> None:
        """Refresh an entry's LRU position without counting a hit."""
        if (tenant, cluster) in self._entries:
            self._entries.move_to_end((tenant, cluster))

    def put(self, tenant: int, cluster: int, view: np.ndarray) -> None:
        nbytes = int(view.nbytes)
        key = (tenant, cluster)
        old = self._entries.pop(key, None)
        if old is not None:
            self.bytes_used -= old.nbytes
        if nbytes > self.budget_bytes:
            # Refuse admission outright: squeezing one oversized view in
            # would first flush EVERY other tenant's warm entries and
            # then evict the new entry itself — an empty cache for
            # nothing. The cluster stays re-streamed from HBM instead.
            self.rejected += 1
            return
        self._entries[key] = _CacheEntry(view=view, nbytes=nbytes)
        self.bytes_used += nbytes
        while self.bytes_used > self.budget_bytes and self._entries:
            _, evicted = self._entries.popitem(last=False)
            self.bytes_used -= evicted.nbytes
            self.evictions += 1


class ServingRuntime:
    """Deadline-batched, cache-warmed serving loop over a MultiTenantIndex.

    The dynamic-batcher successor of `tenancy.CrossTenantBatchScheduler`
    (which is now a thin wrapper over this class): submit() returns a
    future-style RequestHandle, poll(now) launches every batch that is
    full or past its oldest deadline, flush() drains the queue. All
    ledgers accumulate in engine.SchedulePlan units (exact analytic
    bytes), split HBM vs cache-SRAM when the hot-cluster cache serves
    part of a launch's stage-1 view.
    """

    def __init__(self, index, cfg: RuntimeConfig | None = None):
        self.index = index
        self.cfg = cfg or RuntimeConfig()
        self.cache = (HotClusterCache(self.cfg.cache_bytes)
                      if self.cfg.cache_bytes > 0 else None)
        self._queues: "collections.OrderedDict[int, collections.deque[_Pending]]" = (
            collections.OrderedDict())
        self._num_pending = 0
        self._next_id = 0
        self._seq = 0
        # (generation, host mirror of the arena MSB plane) — misses gather
        # from here (the "HBM stream"); rebuilt only after a mutation.
        self._plane_host: tuple[int, np.ndarray] | None = None
        # tenant -> recently probed clusters, most recent first (the
        # session prior that warms the cache between turns).
        self._recent: dict[int, list[int]] = {}
        # -- ledgers (engine.SchedulePlan units, exact bytes) --------------
        self.launches = 0
        self.queries_served = 0
        self.stage1_bytes_streamed = 0    # HBM bytes, all launches
        self.stage1_bytes_sram = 0        # cache-served bytes, all launches
        self.stage1_bytes_vmapped = 0     # the one-query-at-a-time path
        self.prefetch_bytes = 0           # prior-warming gathers (HBM)
        self.stage_bytes: dict[str, int] = {}       # per-stage HBM
        self.stage_bytes_sram: dict[str, int] = {}  # per-stage cache-SRAM
        self.last_plan: engine.SchedulePlan | None = None

    # -- admission ----------------------------------------------------------

    def submit(self, tenant_id: int, query_codes, *,
               deadline: float | None = None,
               now: float | None = None) -> RequestHandle:
        """Enqueue one request; returns its future-style handle.

        deadline: absolute time (same clock as `now`) by which the
        request must be in a launch; defaults to now + cfg.max_wait."""
        if int(tenant_id) < 0:
            raise ValueError(f"tenant id must be >= 0, got {tenant_id}")
        q = np.asarray(query_codes, np.int8)
        if q.ndim != 1 or q.shape[0] != self.index.arena.dim:
            raise ValueError(f"query must be ({self.index.arena.dim},) int8")
        now = time.monotonic() if now is None else now
        if deadline is None:
            # max_wait == 0 means NO deadline-forced launches (the
            # legacy scheduler contract: launch only when full or
            # explicitly flushed), not launch-immediately.
            deadline = (now + self.cfg.max_wait if self.cfg.max_wait > 0
                        else math.inf)
        handle = RequestHandle(self, self._next_id, int(tenant_id), deadline)
        self._next_id += 1
        pend = _Pending(handle=handle, query=q, seq=self._seq)
        self._seq += 1
        self._queues.setdefault(int(tenant_id), collections.deque()).append(
            pend)
        self._num_pending += 1
        if self.cfg.auto_flush and self._num_pending >= self.cfg.max_batch:
            self._launch(self._form_batch())
        return handle

    def pending(self) -> int:
        return self._num_pending

    def _oldest_deadline(self) -> float | None:
        heads = [q[0].handle.deadline for q in self._queues.values() if q]
        return min(heads) if heads else None

    def ready(self, now: float | None = None) -> bool:
        """Would poll() launch something right now?"""
        if self._num_pending >= self.cfg.max_batch:
            return True
        oldest = self._oldest_deadline()
        if oldest is None:
            return False
        now = time.monotonic() if now is None else now
        return oldest <= now

    def next_deadline(self) -> float | None:
        """When the queue next forces a launch (None if empty or no
        pending request carries a finite deadline)."""
        oldest = self._oldest_deadline()
        return None if oldest is None or math.isinf(oldest) else oldest

    def poll(self, now: float | None = None) -> list[RequestHandle]:
        """Launch every batch that is full or past its oldest deadline.

        Returns the handles resolved by this call (possibly empty — a
        young partial batch keeps waiting for more traffic)."""
        now = time.monotonic() if now is None else now
        resolved: list[RequestHandle] = []
        while self._num_pending and self.ready(now):
            resolved.extend(self._launch(self._form_batch()))
        return resolved

    def flush(self) -> list[RequestHandle]:
        """Drain the queue unconditionally (deadlines ignored)."""
        resolved: list[RequestHandle] = []
        while self._num_pending:
            resolved.extend(self._launch(self._form_batch()))
        return resolved

    def _form_batch(self) -> list[_Pending]:
        """Pick up to max_batch pending requests.

        fifo: strict arrival order (the legacy scheduler's grouping).
        deadline_rr: one request per tenant, round-robin, tenants ordered
        by their head request's deadline (FIFO within a tenant) — the
        most urgent tenants are served first and no tenant can occupy
        more than its share of a contended flush."""
        group: list[_Pending] = []
        if self.cfg.fairness == "fifo":
            # k-way merge of the per-tenant FIFO queues by arrival seq:
            # O(B log T) per batch instead of a min() scan per request.
            heads = [(q[0].seq, t) for t, q in self._queues.items() if q]
            heapq.heapify(heads)
            while len(group) < self.cfg.max_batch and heads:
                _, tid = heapq.heappop(heads)
                group.append(self._pop_from(tid))
                queue = self._queues.get(tid)
                if queue:
                    heapq.heappush(heads, (queue[0].seq, tid))
        else:
            # One urgency sort per BATCH (head deadline, then arrival),
            # then round-robin passes over that order until the batch is
            # full or the queues drain.
            order = sorted(
                (t for t, q in self._queues.items() if q),
                key=lambda t: (self._queues[t][0].handle.deadline,
                               self._queues[t][0].seq))
            while len(group) < self.cfg.max_batch:
                progressed = False
                for tid in order:
                    if len(group) >= self.cfg.max_batch:
                        break
                    if self._queues.get(tid):
                        group.append(self._pop_from(tid))
                        progressed = True
                if not progressed:
                    break
        return group

    def _pop_from(self, tid: int) -> _Pending:
        """Pop a tenant's head request; drop its deque once drained so a
        long-lived runtime's admission scans stay proportional to the
        ACTIVE tenants, not every tenant ever seen."""
        queue = self._queues[tid]
        pend = queue.popleft()
        self._num_pending -= 1
        if not queue:
            del self._queues[tid]
        return pend

    # -- launching ----------------------------------------------------------

    @staticmethod
    def _bucket(n: int) -> int:
        return 1 << (n - 1).bit_length() if n > 1 else 1

    def _launch(self, group: list[_Pending]) -> list[RequestHandle]:
        b = len(group)
        if b == 0:
            return []
        pb = self._bucket(b)
        queries = np.zeros((pb, self.index.arena.dim), np.int8)
        tids = np.full((pb,), NO_TENANT, np.int32)
        for i, req in enumerate(group):
            queries[i] = req.query
            tids[i] = req.handle.tenant_id
        res, plan = self._execute(queries, tids)
        self.launches += 1
        self.queries_served += b
        if plan is not None:
            self.last_plan = plan
            # stage1_bytes is what the launch actually streamed from HBM
            # (padding lanes included); the vmapped comparison counts only
            # the b REAL requests — a sequential server would never have
            # dispatched the padding lanes.
            self.stage1_bytes_streamed += plan.stage1_bytes
            self.stage1_bytes_sram += plan.stage1_bytes_sram
            self.stage1_bytes_vmapped += (
                plan.stage1_bytes_vmapped // plan.batch) * b
            for s in plan.stages:
                self.stage_bytes[s.name] = (
                    self.stage_bytes.get(s.name, 0) + s.bytes_hbm)
                if s.bytes_sram:
                    self.stage_bytes_sram[s.name] = (
                        self.stage_bytes_sram.get(s.name, 0) + s.bytes_sram)
        for i, req in enumerate(group):
            req.handle.launch_index = self.launches - 1
            req.handle._result = RetrievalResult(
                indices=res.indices[i], scores=res.scores[i],
                candidate_indices=res.candidate_indices[i])
        return [req.handle for req in group]

    def _execute(self, queries: np.ndarray, tids: np.ndarray
                 ) -> tuple[RetrievalResult, engine.SchedulePlan | None]:
        if self.cache is not None:
            policy = self.index.cluster_policy(tids)
            if isinstance(policy, engine.ClusterPolicy):
                return self._execute_cached(queries, tids, policy)
        res = self.index.retrieve(jnp.asarray(queries), tids)
        return res, self.index.last_plan

    # -- the hot-cluster-cache path -----------------------------------------

    def _host_plane(self) -> np.ndarray:
        gen = self.index.arena.generation
        if self._plane_host is None or self._plane_host[0] != gen:
            self._plane_host = (gen, np.asarray(self.index.arena.msb_plane))
        return self._plane_host[1]

    def _gather_cluster(self, plane: np.ndarray, blocks: np.ndarray,
                        block_rows: int) -> np.ndarray:
        """Materialize one cluster's plane view (bitplanar.gather_blocks'
        conventions: rows past the plane read as zero rows)."""
        n = plane.shape[0]
        rows = (blocks[:, None] * block_rows
                + np.arange(block_rows)).reshape(-1)
        view = plane[np.minimum(rows, n - 1)].copy()
        view[rows >= n] = 0
        return view

    def _cluster_blocks_of(self, table: np.ndarray, lane: int,
                           cluster: int) -> np.ndarray:
        row = table[lane, cluster] if table.ndim == 3 else table[cluster]
        return row[row >= 0]

    def _warm_from_prior(self, table: np.ndarray, tids: np.ndarray,
                         plane: np.ndarray, block_rows: int) -> int:
        """Prefetch each batch tenant's recently-probed clusters.

        Touches entries that are still resident (refreshing their LRU
        position) and re-gathers ones an arena mutation invalidated —
        the bytes are charged to the launch as HBM traffic (`prefetch`),
        the win is that the session's NEXT probes hit."""
        bytes_fetched = 0
        lane_of = {}
        for i, t in enumerate(tids):
            if int(t) >= 0:
                lane_of.setdefault(int(t), i)
        for t, lane in lane_of.items():
            for c in self._recent.get(t, ()):
                if self.cache.peek(t, c):
                    self.cache.touch(t, c)
                    continue
                blocks = self._cluster_blocks_of(table, lane, c)
                if blocks.size == 0:
                    continue
                view = self._gather_cluster(plane, blocks, block_rows)
                self.cache.put(t, c, view)
                bytes_fetched += int(view.nbytes)
        return bytes_fetched

    def _execute_cached(self, queries: np.ndarray, tids: np.ndarray,
                        policy: engine.ClusterPolicy
                        ) -> tuple[RetrievalResult, engine.SchedulePlan]:
        index = self.index
        db = index.arena.db()
        self.cache.sync_generation(index.arena.generation)
        plane = self._host_plane()
        table = np.asarray(policy.cluster_blocks)
        br = policy.block_rows
        d2 = plane.shape[1]
        mb = table.shape[-1]
        q = jnp.asarray(queries)
        q_msb = quantization.msb_nibble(q)
        fns = engine.stage_fns(index.cfg.backend)
        # The SAME selection + expansion the in-graph CentroidPrune runs:
        # the cached path can never probe different clusters than the
        # uncached cascade would.
        top_clusters = engine.select_clusters(q_msb, policy, index.cfg, fns)
        rows, member, _ = engine.expand_cluster_view(policy, top_clusters,
                                                     db.num_docs)
        prefetched = self._warm_from_prior(table, tids, plane, br)
        tc = np.asarray(top_clusters)
        bsz, nprobe = tc.shape
        hit_bytes = miss_bytes = 0
        view = np.zeros((bsz, nprobe * mb * br, d2), np.uint8)
        for i in range(bsz):
            t = int(tids[i])
            if t < 0:
                continue                      # padding lane: all holes
            for p in range(nprobe):
                c = int(tc[i, p])
                entry = self.cache.get(t, c)
                if entry is None:
                    blocks = self._cluster_blocks_of(table, i, c)
                    if blocks.size == 0:
                        continue              # empty cluster: zero rows
                    cluster_view = self._gather_cluster(plane, blocks, br)
                    self.cache.put(t, c, cluster_view)
                    miss_bytes += int(cluster_view.nbytes)
                else:
                    cluster_view = entry.view
                    hit_bytes += entry.nbytes
                view[i, p * mb * br: p * mb * br + cluster_view.shape[0]] = (
                    cluster_view)
        vp = engine.ViewPolicy(rows=rows, member=member,
                               msb_rows=jnp.asarray(view))
        res = index.engine.retrieve(q, db, vp)
        # Ledger: the analytic cluster plan with the approx stage split
        # into measured HBM misses (+ prior prefetches) vs cache hits.
        base = engine.plan(index.cfg, num_docs=db.num_docs, dim=db.dim,
                           batch=bsz, kind="cluster",
                           num_clusters=policy.centroid_msb.shape[0],
                           view_rows=engine.probe_rows(policy))
        plan = engine.cache_split_plan(base,
                                       hbm_bytes=miss_bytes + prefetched,
                                       sram_bytes=hit_bytes)
        self.prefetch_bytes += prefetched
        index.last_plan = plan
        # Refresh each tenant's session prior with the clusters this turn
        # actually probed (most recent first, bounded).
        if self.cfg.prior_clusters:
            for i in range(bsz):
                t = int(tids[i])
                if t < 0:
                    continue
                fresh = list(dict.fromkeys(int(c) for c in tc[i]))
                old = [c for c in self._recent.get(t, []) if c not in fresh]
                self._recent[t] = (fresh + old)[:self.cfg.prior_clusters]
        return res, plan

    # -- reporting ----------------------------------------------------------

    def cache_stats(self) -> dict:
        if self.cache is None:
            return {"enabled": False}
        return {"enabled": True, "entries": len(self.cache),
                "bytes_used": self.cache.bytes_used,
                "budget_bytes": self.cache.budget_bytes,
                "hits": self.cache.hits, "misses": self.cache.misses,
                "evictions": self.cache.evictions,
                "stale_evictions": self.cache.stale_evictions,
                "rejected": self.cache.rejected}

    def energy_ledger(self, dim: int | None = None):
        """cost_cascade of the most recent launch's measured plan."""
        if self.last_plan is None:
            raise RuntimeError("no launch has run yet")
        return energy.cost_cascade(self.last_plan.stages,
                                   dim or self.index.arena.dim,
                                   batch=self.last_plan.batch)
