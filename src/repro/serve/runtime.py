"""Session-aware serving runtime: deadline batcher + hot-cluster cache.

The wearable workload is a stream of small, temporally-correlated request
bursts: T users' agents each fire a query every few seconds, and
consecutive queries of one session probe the SAME few clusters
(continuous monitoring revisits the same part of the corpus). This module
is the serving layer that exploits both properties on top of the
cluster-pruned cascade:

  * `ServingRuntime` — a dynamic batcher that grew out of the synchronous
    `tenancy.scheduler` submit/flush loop: requests get FUTURE-STYLE
    handles, admission is deadline-OR-max-batch (a batch launches the
    moment it is full, or when the oldest request's deadline arrives —
    whichever comes first), partial batches pad to power-of-two buckets
    (one compiled executable per bucket), and batch formation is
    per-tenant fair (round-robin across tenants ordered by deadline, so
    one chatty user cannot starve the rest of a flush). Launches are
    ASYNC: a dispatch leaves the batch's device arrays in flight as
    unresolved futures on a completion queue (up to `async_depth` deep,
    double-buffered by default) and the host immediately returns to
    admission — the next batch's formation, slab warming, fills and
    indirection-table build all overlap the current batch's device
    scoring. Handles resolve lazily: `done()` is a non-blocking readiness
    probe, `result(wait=False)` is a None not-ready signal, `result()`
    blocks only on the caller's own launch, and `flush()`/`barrier()`
    are full drains. The per-launch host bookkeeping of the cached path
    (the (B, nprobe) selection readback feeding the hit/miss ledger, LRU,
    miss admissions and session prior) rides the same queue one launch
    behind, so the host never sits between launches waiting on a
    readback.

  * `HotClusterCache` — an EdgeRAG-style byte-budgeted LRU of hot
    cluster views held in a DEVICE-RESIDENT SLAB: a cache-owned extension
    region of the arena's stage-1 plane (`[arena plane | slab rows]`,
    one combined array rebuilt per arena generation) plus a host-side
    (tenant, cluster) -> slab-slot map. A cached flush hands the engine a
    `SlabPolicy`: cluster selection runs IN-GRAPH (the same centroid
    scoring + validity the cold cascade runs — identical by
    construction) over a small host-built int32 indirection table that
    resolves each (lane, cluster) to either its arena plane blocks
    (miss — streamed from HBM) or its slab blocks (hit — cache-owned
    rows that are never re-uploaded and are stored once per tenant even
    when several lanes share them). Slab slots are DENSELY PACKED — a
    contiguous cluster run is copied row-contiguously, so it occupies
    ceil(rows/block_rows) slots where the plane view needs up to one
    more straddling block — and each slot carries (first row id,
    live-row count) origin scalars the cascade reads back in-graph.
    With `preload` on, a session tenant whose packed views fit the
    budget is pinned wholesale and served from the COMPACT slab table:
    narrower than the plane table, so fully-warm launches gather and
    score fewer stage-1 rows per probe — the cache's wall-clock win on
    top of its byte ledger. Fills are in-place device row copies
    (donated buffers); the host never mirrors the plane and no per-lane
    dense view is ever materialized or uploaded. Any arena mutation
    bumps the generation and invalidates every slot — a stale view can
    never be served. A per-tenant RECENT-CLUSTER prior (the clusters the
    tenant's last turns probed) warms the slab between session turns
    when preload is off or over budget, and empty clusters are memoized
    as zero-byte entries so repeat probes of them count as (free) hits
    instead of skewing the miss ledger.

  * The launch ledger (`engine.SchedulePlan` via `cache_split_plan`)
    splits stage-1 bytes into HBM misses vs SRAM hits, and
    `energy.cost_cascade` charges hits at SRAM rates — so the runtime
    reports the measured uJ/query saving of the cache, in the paper's
    own accounting currency.

Results are BIT-IDENTICAL to the uncached cascade (and to sequential
retrieval): the cache changes where stage-1 bytes come from, never what
is scored — pinned by the parity and property suites in
tests/test_serve_runtime.py and tests/test_runtime_properties.py.
"""
from __future__ import annotations

import collections
import dataclasses
import functools
import heapq
import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import bitplanar, energy, engine
from repro.core.retrieval import NO_TENANT, RetrievalResult
from repro.obs.metrics import MetricsRegistry, NULL_REGISTRY
from repro.obs.tracing import NULL_TRACER


@dataclasses.dataclass(frozen=True)
class RuntimeConfig:
    """Host-side serving knobs.

    max_batch: lanes per launch (full batch => immediate launch).
    max_wait: seconds a request may sit in the queue before its default
        deadline forces a (possibly partial) launch. 0 = launch only when
        full or explicitly flushed.
    fairness: "deadline_rr" interleaves tenants round-robin (ordered by
        their head request's deadline); "fifo" preserves strict arrival
        order (the legacy scheduler's grouping).
    cache_bytes: hot-cluster cache budget in bytes of cached stage-1
        plane views (0 disables caching — every flush streams from HBM).
    prior_clusters: how many recently-probed clusters to remember per
        tenant (the session prior that pre-warms the cache each flush).
    preload: EdgeRAG-style hot preload — pin every batch tenant's full
        cluster set into the slab at first contact, but ONLY when the
        whole batch fits the byte budget together (a budget under
        pressure falls back to the per-probe prior warming, never to
        admission/eviction thrash). Fully-resident tenants are then
        served from the cache's COMPACT block table: densely packed slab
        slots make it narrower than the plane table, so steady-state
        launches gather and score fewer rows per probe.
    auto_flush: launch full batches directly from submit() instead of
        waiting for poll()/flush().
    async_depth: how many dispatched launches may stay IN FLIGHT as
        unresolved device futures before the host blocks on the oldest
        one. 2 (the default) double-buffers: the host forms, warms and
        dispatches batch k+1 while the device scores batch k. 0 restores
        the legacy synchronous contract — every launch is resolved
        before `_launch` returns (the open-loop bench's baseline).
    precision_tiers: per-cluster ADAPTIVE PRECISION in the hot-cluster
        cache (the stage-0 prescreen's serving-side half). Hot clusters
        stay FULL tier (nibble plane rows slab-resident, stage-1 hits
        serve on-chip); under slot/byte pressure the LRU full entry is
        DEMOTED to the sign tier — its slab slots are freed but its
        1-bit sign bytes stay charged to the budget (stage-0 still
        serves on-chip; stage-1 re-streams the plane) — and cold misses
        are admitted at the sign tier first, promoted back to full on a
        re-probe. False (default) is the PR 5 cache unchanged: every
        entry full-tier, eviction drops entries outright.
    """

    max_batch: int = 16
    max_wait: float = 0.005
    fairness: str = "deadline_rr"
    cache_bytes: int = 0
    prior_clusters: int = 8
    preload: bool = False
    auto_flush: bool = True
    async_depth: int = 2
    precision_tiers: bool = False

    def __post_init__(self):
        if self.max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        if self.max_wait < 0:
            raise ValueError("max_wait must be >= 0")
        if self.async_depth < 0:
            raise ValueError("async_depth must be >= 0 (0 = synchronous)")
        if self.fairness not in ("deadline_rr", "fifo"):
            raise ValueError(f"unknown fairness policy {self.fairness!r}")
        if self.cache_bytes < 0 or self.prior_clusters < 0:
            raise ValueError("cache_bytes/prior_clusters must be >= 0")
        if self.preload and self.cache_bytes == 0:
            raise ValueError("preload=True pins clusters into the "
                             "hot-cluster cache slab: it needs a "
                             "cache_bytes budget > 0")
        if self.precision_tiers and self.cache_bytes == 0:
            raise ValueError("precision_tiers=True tiers the hot-cluster "
                             "cache's entries: it needs a cache_bytes "
                             "budget > 0")


class RequestHandle:
    """Future-style handle for one submitted query.

    State machine (`state` property):

        pending ──admission──> admitted ──dispatch──> in_flight
                                                          │ retire
                                                          ▼
                                                      resolved

    * ``pending``: queued, not yet picked into a batch.
    * ``admitted``: picked into a batch that is being formed/dispatched
      (a transient state — observable only from inside the runtime or if
      a dispatch raises).
    * ``in_flight``: the batch's device computation was dispatched; the
      result is an unresolved device future on the completion queue.
    * ``resolved``: the launch was retired — `result()` returns numpy
      row views immediately.

    `done()` never blocks: it reports resolved, or probes the in-flight
    launch's device buffers (`jax.Array.is_ready`) and retires the
    completion queue through it when they landed. `result(wait=False)`
    returns ``None`` as the well-defined not-ready signal (it used to
    raise). `result()` (``wait=True``) blocks only as far as needed:
    in-flight requests retire their own launch, queued requests drain
    the runtime via `flush()`."""

    __slots__ = ("request_id", "tenant_id", "deadline", "launch_index",
                 "_runtime", "_result", "_inflight")

    def __init__(self, runtime: "ServingRuntime", request_id: int,
                 tenant_id: int, deadline: float):
        self.request_id = request_id
        self.tenant_id = tenant_id
        self.deadline = deadline
        self.launch_index: int | None = None   # which launch admitted it
        self._runtime = runtime
        self._result: RetrievalResult | None = None
        self._inflight: "_InFlight | None" = None

    @property
    def state(self) -> str:
        if self._result is not None:
            return "resolved"
        if self._inflight is not None:
            return "in_flight"
        if self.launch_index is not None:
            return "admitted"
        return "pending"

    def done(self) -> bool:
        """Non-blocking: True iff `result()` would return immediately.

        An in-flight request whose device buffers landed is retired here
        (along with every earlier launch on the completion queue — the
        device executes in dispatch order, so they landed too)."""
        if self._result is not None:
            return True
        infl = self._inflight
        if infl is None or not infl.is_ready():
            return False
        self._runtime._retire_through(infl)
        return True

    def result(self, *, wait: bool = True) -> RetrievalResult | None:
        if self._result is None:
            if not wait:
                return self._result if self.done() else None
            if self._inflight is not None:
                self._runtime._retire_through(self._inflight)
            else:
                self._runtime.flush()
        assert self._result is not None
        return self._result

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"RequestHandle(id={self.request_id}, "
                f"tenant={self.tenant_id}, {self.state})")


@dataclasses.dataclass
class _Pending:
    handle: RequestHandle
    query: np.ndarray             # (D,) int8
    seq: int                      # arrival order
    submit_ts: float = 0.0        # submit clock (queue-wait histogram)


@dataclasses.dataclass
class _InFlight:
    """One dispatched-but-unresolved launch on the completion queue.

    `res` holds the launch's device arrays as futures; `book` is the
    deferred host bookkeeping of the cached path (the selection readback
    + ledger/LRU/admission/prior updates), run at retire time so the
    host never blocks on a readback between dispatches. `admit_now` is
    the launch's admission clock (queue-wait histogram + trace ends stay
    on the injectable clock — deterministic under simulated schedules);
    `dispatch_t` is the real monotonic dispatch instant the resolve-lag
    histogram measures against."""

    group: list[_Pending]
    res: RetrievalResult          # device arrays (futures until retired)
    launch_index: int
    admit_now: float
    dispatch_t: float
    book: "collections.abc.Callable[[], None] | None" = None

    def is_ready(self) -> bool:
        """Non-blocking device-completion probe. All three outputs come
        from one jitted program, so probing one suffices; arrays without
        `is_ready` (e.g. already-materialized numpy) count as ready."""
        probe = getattr(self.res.indices, "is_ready", None)
        return True if probe is None else bool(probe())


# Per-cluster precision tiers (the adaptive-precision cascade's
# serving-side half). A combined block belongs to exactly one tier:
TIER_PLANE = 0   # arena plane block (not cache-managed)
TIER_SIGN = 1    # resident at 1-bit precision: only the cluster's sign
#                  bytes are budget-charged; stage-0 serves on-chip,
#                  stage-1 re-streams the nibble plane from HBM
TIER_FULL = 2    # resident at full nibble precision: slab slots hold the
#                  4-bit msb rows; stage-0 AND stage-1 serve on-chip


@dataclasses.dataclass
class _SlabEntry:
    slab_blocks: np.ndarray       # (nblk,) int32 slab-region block ids
    n_rows: int                   # live rows packed into those blocks
    nbytes: int                   # budget charge: nblk*block_rows*
    #                               bytes_per_row (full tier) or the
    #                               cluster's 1-bit sign bytes (sign tier)
    tier: int = TIER_FULL         # TIER_SIGN or TIER_FULL
    plane_blocks: np.ndarray | None = None  # the cluster's plane block
    #                               ids (sign-tier routing + tier sidecar)


def _pow2(n: int) -> int:
    return 1 << (n - 1).bit_length() if n > 1 else 1


@functools.partial(jax.jit, donate_argnums=(0, 1, 2, 3))
def _apply_fills(plane, inv_norms, block_gid0, block_count,
                 row_src_dst, blk_ids, blk_gid0, blk_count):
    """In-place admission fills on the combined plane + its sidecars.

    row_src_dst is one (2, Fr) int32 array of (source plane row ->
    destination combined row) copies — ROW granular, so densely packed
    slab blocks can draw from mid-block run starts; blk_* are the (Fb,)
    per-block origin scalars (first global row id, live-row count) of
    the filled slab blocks. All four device buffers are DONATED: a fill
    touches only the written rows/scalars instead of re-materializing
    the slab. Callers pad Fr and Fb to powers of two by repeating the
    last element — duplicate writes of identical data, so the scatters
    stay deterministic."""
    src, dst = row_src_dst[0], row_src_dst[1]
    return (plane.at[dst].set(plane[src]),
            inv_norms.at[dst].set(inv_norms[src]),
            block_gid0.at[blk_ids].set(blk_gid0),
            block_count.at[blk_ids].set(blk_count))


@functools.partial(jax.jit, static_argnames=("num_clusters",))
def _packed_sidecar(owner, labels, *, num_clusters):
    return engine.packed_membership(owner, labels, num_clusters)


@jax.jit
def _sign_sidecar(msb_plane):
    """Combined 1-bit sign plane from the combined msb plane — one tiny
    device op per plane change (slab fill / rebuild); see
    bitplanar.sign_plane_from_msb for the bit-layout identity."""
    return bitplanar.sign_plane_from_msb(msb_plane)


@jax.jit
def _inv_norm_sidecar(norms_sq):
    """The cosine key's per-row f32 factor, precomputed once per arena
    generation: rsqrt(max(norm, 1)) for live rows, 0 for empty ones —
    gathering this and multiplying reproduces cosine_key_f32's bits
    exactly (same rsqrt input values, same f32 product)."""
    n = jnp.maximum(norms_sq.astype(jnp.float32), 1.0)
    return jnp.where(norms_sq > 0, jax.lax.rsqrt(n), 0.0)


class HotClusterCache:
    """Byte-budgeted LRU of hot cluster views in a device-resident slab.

    The slab is a cache-owned EXTENSION REGION of the arena's stage-1
    plane: one combined device array ``[arena plane | slab rows]`` (plus
    f32 inverse-norm and per-block origin sidecars), carved into
    `block_rows`-row slots. Entries are keyed (tenant, cluster); each
    holds the slab slots its cluster's rows were copied into. The host
    never sees the bytes — admission copies rows plane->slab ON DEVICE
    (donated, in place), and a launch consumes the slab through an int32
    indirection table (`combined_table`/`compact_table`) that points
    each resident (lane, cluster) at its slab slots and everything else
    at the arena plane.

    Slab slots are DENSELY PACKED: a contiguous cluster run is copied
    row-contiguously into ``ceil(rows/block_rows)`` slots (a fragmented
    run falls back to mirroring its whole plane blocks), and each slot
    records (first global row id, live-row count) origin scalars the
    cascade reads back in-graph. Packing is what lets `compact_table`
    hand a fully-resident launch a NARROWER block table than the plane's
    (a straddling run needs one more plane block than slab slots) — the
    slab's wall-clock win on top of never re-streaming hit bytes.

    Entries are valid only for the arena generation they were copied
    under: `sync_generation` clears the slot map (and lazily rebuilds the
    combined array) whenever the arena mutated, so a stale view can never
    be served — correctness never depends on the eviction heuristic.
    Within a generation, eviction is least-recently-used under
    `budget_bytes` (slot-granular). Empty clusters are admissible as
    zero-slot entries so their repeat probes are hits, not fresh misses.
    """

    def __init__(self, budget_bytes: int, *, registry=None,
                 precision_tiers: bool = False):
        if budget_bytes < 0:
            raise ValueError("budget_bytes must be >= 0")
        self.precision_tiers = precision_tiers
        # Counters live in a metrics registry (the serving runtime's when
        # observability is on, a private one otherwise — a counter update
        # is one int add either way, and hits/misses/... stay readable as
        # attributes for existing callers). snapshot()/reset_stats() give
        # WINDOWED reads: a long-lived runtime or a bench section resets,
        # runs its window, and reads rates for just that window instead
        # of a lifetime-cumulative mixed-window average.
        self.registry = registry if registry is not None else (
            MetricsRegistry())
        self._hits = self.registry.counter("cache_hits")
        self._misses = self.registry.counter("cache_misses")
        self._evictions = self.registry.counter("cache_evictions")
        self._stale_evictions = self.registry.counter(
            "cache_stale_evictions")
        self._rejected = self.registry.counter("cache_rejected")
        self._fill_bytes = self.registry.counter("cache_fill_bytes")
        self._fill_dispatches = self.registry.counter(
            "cache_fill_dispatches")
        self._demotions = self.registry.counter("cache_demotions")
        self._promotions = self.registry.counter("cache_promotions")
        self.budget_bytes = budget_bytes
        self.block_rows: int | None = None
        self.bytes_per_row: int | None = None
        self.num_slab_blocks = 0
        self._entries: "collections.OrderedDict[tuple[int, int], _SlabEntry]" = (
            collections.OrderedDict())
        self._free: list[int] = []
        self._generation = -1
        # version bumps on ANY slot-map membership change (put / evict /
        # invalidation): launches key their cached indirection tables on
        # it, so a steady-state (fully warm) flush re-uses the same
        # device table with zero host work.
        self.version = 0
        self._slab_plane = None       # jnp (N + S*block_rows, D//2) uint8
        self._inv_norms = None        # jnp (N + S*block_rows,) f32
        # Combined 1-bit sign plane + per-slot tier sidecar, both derived
        # lazily and cached per plane/slot-map state (see the properties).
        self._plane_version = 0       # bumps whenever _slab_plane changes
        self._sign_cache: tuple[int, jax.Array] | None = None
        self._tier_cache: tuple[int, jax.Array] | None = None
        self._packed = None           # jnp (N,) int32 membership sidecar
        self._gid0 = None             # jnp (NB + S,) int32 block origins
        self._cnt = None              # jnp (NB + S,) int32 live-row counts
        self._plane_rows = 0
        self._table_cache: dict = {}  # key -> (version, ...) device tables
        # Incremental indirection state: per tenant, the set of resident
        # clusters and a lazily-built (host row, combined row) pair kept
        # in sync by put/evict — so a launch's table build is a handful
        # of row copies, never a loop over every resident entry.
        self._by_tenant: dict[int, set[int]] = {}
        self._nonempty: dict[int, int] = {}   # resident nonempty entries
        self._tenant_rows: dict[int, tuple[int, np.ndarray, np.ndarray]] = {}
        # Pending admission fills, keyed by DESTINATION so a slot reissued
        # before the next flush deterministically carries its newest
        # owner's rows (stale row writes land on masked pads).
        self._fill_rows: dict[int, int] = {}          # dst slab row -> src
        self._fill_blocks: dict[int, tuple[int, int]] = {}  # slot -> scalars
        self.bytes_used = 0

    def __len__(self) -> int:
        return len(self._entries)

    # Registry-backed counters, still readable as plain attributes.
    @property
    def hits(self) -> int:
        return self._hits.value

    @property
    def misses(self) -> int:
        return self._misses.value

    @property
    def evictions(self) -> int:
        return self._evictions.value

    @property
    def stale_evictions(self) -> int:
        return self._stale_evictions.value

    @property
    def rejected(self) -> int:
        """Views larger than the whole slab (refused admission)."""
        return self._rejected.value

    @property
    def demotions(self) -> int:
        """Full-tier entries squeezed down to the sign tier."""
        return self._demotions.value

    @property
    def promotions(self) -> int:
        """Sign-tier entries re-admitted at full precision on a re-probe."""
        return self._promotions.value

    def snapshot(self) -> dict:
        """Current counter values (cumulative since the last
        `reset_stats`). Pair with `reset_stats` for windowed hit rates:
        ``reset_stats(); <serve a window>; snapshot()`` reads rates for
        exactly that window, not a lifetime average over mixed phases
        (cold fill + steady state)."""
        out = {"hits": self.hits, "misses": self.misses,
               "evictions": self.evictions,
               "stale_evictions": self.stale_evictions,
               "rejected": self.rejected,
               "fill_bytes": self._fill_bytes.value,
               "fill_dispatches": self._fill_dispatches.value}
        if self.precision_tiers:
            out["demotions"] = self.demotions
            out["promotions"] = self.promotions
            out["sign_entries"] = sum(
                1 for e in self._entries.values() if e.tier == TIER_SIGN)
            out["full_entries"] = sum(
                1 for e in self._entries.values() if e.tier == TIER_FULL)
        return out

    def reset_stats(self) -> None:
        """Zero the event counters (hit/miss/eviction/fill ledgers) —
        the cache CONTENTS and byte accounting are untouched, so this
        only re-bases what `snapshot` reports."""
        for c in (self._hits, self._misses, self._evictions,
                  self._stale_evictions, self._rejected, self._fill_bytes,
                  self._fill_dispatches, self._demotions, self._promotions):
            c.reset()

    @property
    def generation(self) -> int:
        return self._generation

    @property
    def slab_plane(self):
        return self._slab_plane

    @property
    def inv_norms(self):
        return self._inv_norms

    @property
    def packed_labels(self):
        return self._packed

    def _reset_slots(self) -> None:
        self._entries.clear()
        # allocation pops from the tail: reversed so slots hand out 0, 1, ...
        self._free = list(range(self.num_slab_blocks))[::-1]
        self._table_cache.clear()
        self._by_tenant.clear()
        self._nonempty.clear()
        self._tenant_rows.clear()
        self._fill_rows.clear()
        self._fill_blocks.clear()
        self.bytes_used = 0
        self.version += 1
        self._tier_cache = None

    def configure(self, block_rows: int, bytes_per_row: int) -> None:
        """Pin the slot geometry (idempotent; a change re-carves the slab
        and invalidates every entry)."""
        if (block_rows, bytes_per_row) == (self.block_rows,
                                           self.bytes_per_row):
            return
        if self.precision_tiers and bytes_per_row % 4:
            # sign bytes per row = bytes_per_row / 4 (1 bit vs 4 bits per
            # dim): the tiers' budget arithmetic needs it to be integral.
            raise ValueError("precision_tiers needs dim % 8 == 0 "
                             f"(bytes_per_row {bytes_per_row} % 4 != 0)")
        self._stale_evictions.inc(len(self._entries))
        self.block_rows = block_rows
        self.bytes_per_row = bytes_per_row
        self.num_slab_blocks = self.budget_bytes // (block_rows
                                                     * bytes_per_row)
        self._slab_plane = self._inv_norms = self._packed = None
        self._gid0 = self._cnt = None
        self._reset_slots()

    @property
    def generation(self) -> int:
        """The arena generation the slab currently mirrors."""
        return self._generation

    def sync_generation(self, generation: int) -> None:
        """Invalidate everything copied under an older arena state."""
        if generation != self._generation:
            self._stale_evictions.inc(len(self._entries))
            self._slab_plane = self._inv_norms = self._packed = None
            self._gid0 = self._cnt = None
            self._reset_slots()
            self._generation = generation

    def ensure_slab(self, msb_plane, norms_sq, owner, labels,
                    num_clusters: int) -> None:
        """(Re)build the combined plane + sidecars for this generation.

        One device concatenation per arena mutation — this replaces the
        pre-slab design's full HOST mirror of the plane (and the per-
        launch host->device view uploads that came with it). Also builds
        the launch sidecars the slab cascade consumes instead of
        re-deriving them per launch: the f32 inverse-norm factors and
        the packed (owner, label) membership rows."""
        if self._slab_plane is not None:
            return
        if self.block_rows is None:
            raise RuntimeError("configure() the slot geometry first")
        n, d2 = msb_plane.shape
        if n % self.block_rows:
            raise ValueError(f"plane rows {n} not a multiple of "
                             f"block_rows {self.block_rows}")
        self._plane_rows = n
        slab_rows = self.num_slab_blocks * self.block_rows
        self._slab_plane = jnp.concatenate(
            [msb_plane, jnp.zeros((slab_rows, d2), jnp.uint8)])
        self._inv_norms = jnp.concatenate(
            [_inv_norm_sidecar(norms_sq),
             jnp.zeros((slab_rows,), jnp.float32)])
        self._packed = _packed_sidecar(owner, labels,
                                       num_clusters=num_clusters)
        # Per-block origin scalars: plane blocks are their own origin
        # (gid0 = block * block_rows, full count); slab blocks start
        # empty (count 0 — an unfilled slot can never surface a row) and
        # are written by admission fills.
        nb = n // self.block_rows
        self._gid0 = jnp.concatenate(
            [jnp.arange(nb, dtype=jnp.int32) * self.block_rows,
             jnp.zeros((self.num_slab_blocks,), jnp.int32)])
        self._cnt = jnp.concatenate(
            [jnp.full((nb,), self.block_rows, jnp.int32),
             jnp.zeros((self.num_slab_blocks,), jnp.int32)])
        self._plane_version += 1

    @property
    def block_gid0(self):
        return self._gid0

    @property
    def block_count(self):
        return self._cnt

    @property
    def sign_plane(self):
        """Combined 1-bit sign plane ``[arena signs | slab signs]``,
        derived from the combined msb plane (the sign of an INT4 code IS
        its msb nibble's top bit, so one derivation covers both regions
        — no second fill pipeline) and cached per plane state: a
        steady-state warm launch with no pending fills re-serves the
        same device array. None when the dim doesn't pack 8-per-byte or
        before `ensure_slab`."""
        if self._slab_plane is None or (self._slab_plane.shape[1] * 2) % 8:
            return None
        if self._sign_cache is None or \
                self._sign_cache[0] != self._plane_version:
            self._sign_cache = (self._plane_version,
                                _sign_sidecar(self._slab_plane))
        return self._sign_cache[1]

    @property
    def block_tier(self):
        """Per-combined-block precision-tier sidecar, (NB + S,) int8:
        TIER_FULL on slab slots held by full-tier entries, TIER_SIGN on
        the plane blocks of sign-tier residents, TIER_PLANE elsewhere
        (including free slots). Diagnostic/ledger metadata — the cascade
        itself routes through the indirection table, never this array.
        Cached per slot-map version."""
        if self._slab_plane is None or self.block_rows is None:
            return None
        if self._tier_cache is None or self._tier_cache[0] != self.version:
            base = self._plane_rows // self.block_rows
            tier = np.zeros(base + self.num_slab_blocks, np.int8)
            for e in self._entries.values():
                if e.tier == TIER_FULL and e.slab_blocks.size:
                    tier[e.slab_blocks + base] = TIER_FULL
                elif e.tier == TIER_SIGN and e.plane_blocks is not None:
                    tier[e.plane_blocks] = TIER_SIGN
            self._tier_cache = (self.version, jnp.asarray(tier))
        return self._tier_cache[1]

    # -- slot map -----------------------------------------------------------

    def get(self, tenant: int, cluster: int) -> _SlabEntry | None:
        entry = self._entries.get((tenant, cluster))
        if entry is None:
            self._misses.inc()
            return None
        self._entries.move_to_end((tenant, cluster))
        self._hits.inc()
        return entry

    def lookup_lane(self, tenant: int, clusters) -> tuple[int, list[int]]:
        """Bulk `get()` for one lane's probed clusters.

        Returns (hit bytes, missing cluster ids) with the same counter
        and LRU semantics as per-cluster get() calls — one hit or miss
        per probed cluster, hits refreshed most-recent in probe order —
        but via one set-membership pass per lane instead of a dict
        transaction per probe (this runs on the serving hot path for
        every launch's (B, nprobe) selection readback)."""
        resident = self._by_tenant.get(tenant)
        if not resident:
            self._misses.inc(len(clusters))
            return 0, list(clusters)
        entries = self._entries
        hit_bytes = 0
        missing: list[int] = []
        nhits = 0
        for c in clusters:
            if c in resident:
                key = (tenant, c)
                hit_bytes += entries[key].nbytes
                entries.move_to_end(key)
                nhits += 1
            else:
                missing.append(c)
        self._hits.inc(nhits)
        self._misses.inc(len(missing))
        return hit_bytes, missing

    def lookup_lane_tiers(self, tenant: int, clusters
                          ) -> tuple[int, int, list[int], list[int]]:
        """`lookup_lane` with the per-tier split the precision-tier
        ledger needs: (full-tier hit bytes, sign-tier hit bytes,
        sign-tier-resident cluster ids, missing cluster ids). Sign-tier
        residents ARE hits (their sign bytes serve stage 0 on-chip and
        their LRU position refreshes) but their stage-1 plane blocks
        still stream from HBM — the caller charges those like misses and
        promotes them back to full tier."""
        resident = self._by_tenant.get(tenant)
        if not resident:
            self._misses.inc(len(clusters))
            return 0, 0, [], list(clusters)
        entries = self._entries
        full_bytes = sign_bytes = nhits = 0
        sign_hits: list[int] = []
        missing: list[int] = []
        for c in clusters:
            if c in resident:
                key = (tenant, c)
                e = entries[key]
                if e.tier == TIER_FULL:
                    full_bytes += e.nbytes
                else:
                    sign_bytes += e.nbytes
                    sign_hits.append(c)
                entries.move_to_end(key)
                nhits += 1
            else:
                missing.append(c)
        self._hits.inc(nhits)
        self._misses.inc(len(missing))
        return full_bytes, sign_bytes, sign_hits, missing

    def peek(self, tenant: int, cluster: int) -> bool:
        """Membership check without touching hit/miss counters or LRU."""
        return (tenant, cluster) in self._entries

    def touch(self, tenant: int, cluster: int) -> None:
        """Refresh an entry's LRU position without counting a hit."""
        if (tenant, cluster) in self._entries:
            self._entries.move_to_end((tenant, cluster))

    @staticmethod
    def _pack_plan(rows: np.ndarray, block_rows: int) -> tuple[bool, int]:
        """(packed?, slab slots) one cluster's rows will occupy:
        ``ceil(rows/br)`` when the run is contiguous (dense packing), its
        distinct plane blocks when fragmented (whole-block mirroring).
        The single source of admission arithmetic — `put` and the
        preload's demand check must never disagree."""
        n_rows = int(rows.size)
        if n_rows == 0:
            return True, 0
        if int(rows[-1]) - int(rows[0]) + 1 == n_rows:
            return True, -(-n_rows // block_rows)
        return False, int(np.unique(rows // block_rows).size)

    @classmethod
    def entry_blocks(cls, rows: np.ndarray, block_rows: int) -> int:
        """Slab slots one cluster's rows will occupy (see _pack_plan)."""
        return cls._pack_plan(np.atleast_1d(np.asarray(rows, np.int64)),
                              block_rows)[1]

    def put(self, tenant: int, cluster: int, rows, *,
            tier: int = TIER_FULL) -> np.ndarray | None:
        """Admit one (tenant, cluster)'s rows into the slab.

        `rows` are the cluster's global plane row ids for that tenant,
        ASCENDING (the order the cold cascade's view streams them — what
        keeps the packed view's candidate order bit-identical). A
        contiguous run is packed densely into ``ceil(rows/block_rows)``
        slots; a fragmented one mirrors its whole plane blocks. The row
        copies and origin scalars are queued for the next `flush_fills`.

        `tier` (precision_tiers mode only): TIER_FULL copies the nibble
        rows into slab slots as always; TIER_SIGN admits the cluster at
        1-bit precision — NO slots, no fills, only its sign bytes
        charged to the budget, with the indirection table left routing
        to the plane blocks (stage-1 streams HBM; stage-0 serves the
        sign bytes on-chip). Under tiers, slot pressure DEMOTES the LRU
        full entry to the sign tier instead of dropping it, and byte
        pressure drops sign-tier entries last.

        Returns the allocated slab slot ids (empty for an empty or
        sign-tier cluster), or None when the view is larger than the
        whole slab/budget. The oversized check runs BEFORE any resident
        entry is replaced: a rejected re-put must leave the existing
        valid entry (and its accounting) untouched instead of
        destroying it on the way to nowhere."""
        if self.block_rows is None:
            raise RuntimeError("configure() the slot geometry first")
        if tier == TIER_SIGN and not self.precision_tiers:
            raise ValueError("sign-tier admission needs precision_tiers")
        br = self.block_rows
        rows = np.atleast_1d(np.asarray(rows, np.int64)).astype(np.int32)
        n_rows = int(rows.size)
        if n_rows == 0:
            tier = TIER_FULL        # zero-slot memo: tiers are moot
        packed, nblk = self._pack_plan(rows, br)
        plane_blocks = np.unique(rows // br).astype(np.int32)
        sign_bytes = nblk * br * (self.bytes_per_row // 4)
        if packed:
            src = rows
            gid0s = [int(rows[0]) + i * br for i in range(nblk)] if n_rows \
                else []
            cnts = [min(br, n_rows - i * br) for i in range(nblk)]
        else:
            blocks = plane_blocks.astype(np.int64)
            src = (blocks[:, None] * br
                   + np.arange(br, dtype=np.int64)).reshape(-1)
            gid0s = (blocks * br).tolist()
            cnts = [br] * nblk
        if (nblk > self.num_slab_blocks if tier == TIER_FULL
                else sign_bytes > self.budget_bytes):
            # Refuse admission outright: squeezing one oversized view in
            # would first flush EVERY other tenant's warm entries and
            # then evict the new entry itself — an empty cache for
            # nothing. The cluster stays re-streamed from HBM instead.
            self._rejected.inc()
            return None
        key = (tenant, cluster)
        old = self._entries.pop(key, None)
        if old is not None:
            self._drop_entry(key, old)
        nslots = nblk if tier == TIER_FULL else 0
        while len(self._free) < nslots:
            # LRU scan skipping zero-slot entries: evicting an
            # empty-cluster memo frees nothing — it would only destroy
            # the memoization and inflate the eviction counter.
            victim = next((k for k, e in self._entries.items()
                           if e.slab_blocks.size), None)
            if victim is None:
                break
            if self.precision_tiers:
                self._demote(victim)    # free the slots, keep the signs
            else:
                self._drop_entry(victim, self._entries.pop(victim))
                self._evictions.inc()
        nbytes = (nblk * br * self.bytes_per_row if tier == TIER_FULL
                  else sign_bytes)
        if self.precision_tiers:
            # Byte pressure (sign charges consume budget without holding
            # slots): demote LRU full entries first, then drop LRU
            # sign-tier entries — full precision degrades before any
            # residency is lost outright.
            while self.bytes_used + nbytes > self.budget_bytes:
                vic = next((k for k, e in self._entries.items()
                            if e.tier == TIER_FULL and e.slab_blocks.size),
                           None)
                if vic is not None:
                    self._demote(vic)
                    continue
                vic = next((k for k, e in self._entries.items()
                            if e.nbytes), None)
                if vic is None:
                    break
                self._drop_entry(vic, self._entries.pop(vic))
                self._evictions.inc()
        dst = np.asarray([self._free.pop() for _ in range(nslots)],
                         np.int32)
        self._entries[key] = _SlabEntry(slab_blocks=dst, n_rows=n_rows,
                                        nbytes=nbytes, tier=tier,
                                        plane_blocks=plane_blocks)
        self.bytes_used += nbytes
        self._by_tenant.setdefault(tenant, set()).add(cluster)
        if n_rows and tier == TIER_FULL:
            self._nonempty[tenant] = self._nonempty.get(tenant, 0) + 1
        row = self._tenant_rows.get(tenant)
        if tier == TIER_FULL:
            self._fill_bytes.inc(nbytes)
            # Queue the admission fills: row copies land at the slots'
            # rows in packed order; scalar writes record each slot's
            # origin.
            for i, slot in enumerate(dst.tolist()):
                self._fill_blocks[slot] = (gid0s[i], cnts[i])
                seg = src[i * br:(i + 1) * br].tolist()
                slot_row0 = slot * br
                for j, s in enumerate(seg):
                    self._fill_rows[slot_row0 + j] = int(s)
            if row is not None:
                base = self._plane_rows // br
                row[2][cluster, :nblk] = dst + base
                row[2][cluster, nblk:] = -1
        elif row is not None:
            # Sign tier holds no slab rows: stage-1 keeps streaming the
            # cluster's plane blocks, so the combined row stays the
            # host plane row.
            row[2][cluster] = row[1][cluster]
        self.version += 1
        return dst

    def _demote(self, key: tuple[int, int]) -> None:
        """Squeeze a full-tier entry down to the sign tier IN PLACE:
        free its slab slots and shrink its budget charge to the
        cluster's 1-bit sign bytes, keeping its LRU position and
        residency. The incremental indirection row rolls back to the
        plane blocks (stage-1 re-streams; stage-0 stays on-chip)."""
        tenant, cluster = key
        e = self._entries[key]
        self.bytes_used -= e.nbytes
        self._free.extend(int(b) for b in e.slab_blocks)
        if e.n_rows:
            self._nonempty[tenant] = self._nonempty.get(tenant, 1) - 1
        sign_bytes = (e.slab_blocks.size * self.block_rows
                      * (self.bytes_per_row // 4))
        self._entries[key] = dataclasses.replace(
            e, slab_blocks=np.empty(0, np.int32), nbytes=sign_bytes,
            tier=TIER_SIGN)
        self.bytes_used += sign_bytes
        row = self._tenant_rows.get(tenant)
        if row is not None:
            row[2][cluster] = row[1][cluster]
        self._demotions.inc()
        self.version += 1

    def promote(self, tenant: int, cluster: int, rows) -> np.ndarray | None:
        """Re-admit a sign-tier resident at full precision (re-probe =
        the cluster is hot again)."""
        self._promotions.inc()
        return self.put(tenant, cluster, rows, tier=TIER_FULL)

    def _drop_entry(self, key: tuple[int, int], entry: _SlabEntry) -> None:
        """Return an entry's slots and roll its tenant's combined row back
        to the plane blocks (the incremental inverse of admission).

        Pending fills aimed at the freed slots are left queued: they are
        keyed by destination, so a slot reissued before the next flush
        simply overwrites them with its new owner's rows, and writes to
        a slot that stays free touch rows no table references — either
        way the flush stays deterministic."""
        tenant, cluster = key
        self.bytes_used -= entry.nbytes
        self._free.extend(int(b) for b in entry.slab_blocks)
        if entry.n_rows and entry.tier == TIER_FULL:
            # `fully_resident` (the compact-table precondition) counts
            # FULL-tier views only: a sign-tier resident has no slab rows
            # to serve a compact launch from.
            self._nonempty[tenant] = self._nonempty.get(tenant, 1) - 1
        clusters = self._by_tenant.get(tenant)
        if clusters is not None:
            clusters.discard(cluster)
        row = self._tenant_rows.get(tenant)
        if row is not None:
            row[2][cluster] = row[1][cluster]

    def fully_resident(self, tenant: int, nonempty_clusters: int) -> bool:
        """Whether every one of the tenant's `nonempty_clusters` real
        cluster views is currently slab-resident (entries are only ever
        admitted from those views, so a count match is set equality) —
        the precondition for serving the tenant from a compact table."""
        return self._nonempty.get(tenant, 0) >= nonempty_clusters

    def flush_fills(self) -> None:
        """Apply every queued admission fill in ONE device dispatch, in
        place (plane bytes, inverse-norm sidecar, and the filled slots'
        origin scalars). Deferral is safe because nothing reads slab
        rows between launches and every launch flushes before it builds
        its indirection table — a slot is always written before it can
        be served; a generation sync drops the queue with the slot map.
        Row and block counts are padded to powers of two so varying fill
        sizes re-use a bounded family of compiled scatters."""
        if not self._fill_blocks or self._slab_plane is None:
            return
        self._fill_dispatches.inc()
        base_row = self._plane_rows
        base_blk = self._plane_rows // self.block_rows
        rows = sorted(self._fill_rows.items())            # (dst, src)
        blks = sorted(self._fill_blocks.items())          # (slot, (g, c))
        self._fill_rows = {}
        self._fill_blocks = {}
        fr, fb = _pow2(len(rows)), _pow2(len(blks))
        rows += [rows[-1]] * (fr - len(rows))
        blks += [blks[-1]] * (fb - len(blks))
        src_dst = np.asarray([[s for _, s in rows],
                              [d + base_row for d, _ in rows]], np.int32)
        ids = np.asarray([b + base_blk for b, _ in blks], np.int32)
        g0 = np.asarray([g for _, (g, _) in blks], np.int32)
        cn = np.asarray([c for _, (_, c) in blks], np.int32)
        (self._slab_plane, self._inv_norms, self._gid0,
         self._cnt) = _apply_fills(
            self._slab_plane, self._inv_norms, self._gid0, self._cnt,
            jnp.asarray(src_dst), jnp.asarray(ids), jnp.asarray(g0),
            jnp.asarray(cn))
        self._plane_version += 1

    def _tenant_row(self, tenant: int, host_row: np.ndarray) -> np.ndarray:
        """The tenant's (K, MB) combined-space row: its host plane row
        with every resident cluster's prefix overridden by slab blocks.
        Built once (per table width) and then kept in sync INCREMENTALLY
        by put/evict — a launch never loops over resident entries.

        Entry/table alignment is a generation invariant: entries are
        admitted FROM these same tables and every arena mutation clears
        the slot map, so the override prefixes cannot desynchronize
        within a generation."""
        cached = self._tenant_rows.get(tenant)
        if cached is not None and cached[0] == host_row.shape[1]:
            return cached[2]
        comb_row = host_row.copy()
        base = self._plane_rows // self.block_rows
        for c in self._by_tenant.get(tenant, ()):
            e = self._entries.get((tenant, c))
            if e is not None and e.slab_blocks.size:
                nblk = e.slab_blocks.size
                comb_row[c, :nblk] = e.slab_blocks + base
                # A packed entry can hold the view in FEWER blocks than
                # the plane table lists (no straddle): hole the tail so
                # the leftover plane blocks can't re-surface its rows.
                comb_row[c, nblk:] = -1
        self._tenant_rows[tenant] = (host_row.shape[1], host_row.copy(),
                                     comb_row)
        return comb_row

    def combined_table(self, tids, host_table: np.ndarray):
        """The launch's (B, K, MB) int32 indirection table, on device.

        host_table is the index's np plane block table (the SAME table
        the ClusterPolicy carries); resident (lane, cluster) prefixes are
        redirected into the slab region via the incrementally-maintained
        per-tenant rows. Cached per (slot-map version, tenant tuple): a
        fully warm steady state re-issues the same device table with
        zero host work."""
        key = tids.tobytes()
        hit = self._table_cache.get(key)
        if hit is not None and hit[0] == self.version and \
                hit[1] == id(host_table):
            return hit[2]
        comb = host_table.copy()
        for i, t in enumerate(np.asarray(tids).tolist()):
            if t >= 0 and self._by_tenant.get(t):
                comb[i] = self._tenant_row(int(t), host_table[i])
        table = jnp.asarray(comb)
        if len(self._table_cache) > 64:
            self._table_cache.clear()
        self._table_cache[key] = (self.version, id(host_table), table)
        return table

    def compact_table(self, tids, num_clusters: int):
        """The fully-resident launch's (B, K, W) indirection table, W =
        the widest RESIDENT entry's slot count (pow2-bucketed so table
        widths — and therefore compiled cascades — stay bounded).

        Because packed slab entries never straddle plane-block
        boundaries, W is typically narrower than the plane table's MB —
        the launch gathers and scores fewer rows per probe. Only valid
        when every batch tenant is fully resident (`fully_resident`);
        the caller falls back to `combined_table` otherwise. Cached per
        (slot-map version, tenant tuple) like the full-width table."""
        key = ("compact", tids.tobytes())
        hit = self._table_cache.get(key)
        if hit is not None and hit[0] == self.version:
            return hit[1], hit[2]
        base = self._plane_rows // self.block_rows
        lanes = np.asarray(tids).tolist()
        w = 1
        for t in set(lanes):
            for c in self._by_tenant.get(t, ()):
                w = max(w, self._entries[(t, c)].slab_blocks.size)
        w = _pow2(w)
        comp = np.full((len(lanes), num_clusters, w), -1, np.int32)
        for i, t in enumerate(lanes):
            for c in self._by_tenant.get(t, ()):
                e = self._entries[(t, c)]
                comp[i, c, :e.slab_blocks.size] = e.slab_blocks + base
        table = jnp.asarray(comp)
        if len(self._table_cache) > 64:
            self._table_cache.clear()
        self._table_cache[key] = (self.version, table, w)
        return table, w


class ServingRuntime:
    """Deadline-batched, cache-warmed serving loop over a MultiTenantIndex.

    The dynamic-batcher successor of `tenancy.CrossTenantBatchScheduler`
    (which is now a thin wrapper over this class): submit() returns a
    future-style RequestHandle, poll(now) launches every batch that is
    full or past its oldest deadline, flush() drains the queue. All
    ledgers accumulate in engine.SchedulePlan units (exact analytic
    bytes), split HBM vs cache-SRAM when the hot-cluster cache serves
    part of a launch's stage-1 view.
    """

    def __init__(self, index, cfg: RuntimeConfig | None = None, *,
                 registry=None, tracer=None):
        self.index = index
        self.cfg = cfg or RuntimeConfig()
        # Observability handles (repro.obs). Defaults are the null
        # implementations: every instrumentation site below is a no-op
        # call and every derived publication (plan fan-out, energy
        # pricing) is skipped behind `registry.enabled` — the
        # metrics-off hot path is the pre-observability hot path, pinned
        # by the bench's parity + zero-extra-compiles + overhead gates.
        self.registry = NULL_REGISTRY if registry is None else registry
        self.tracer = NULL_TRACER if tracer is None else tracer
        reg = self.registry
        self._m_submitted = reg.counter("serve_requests_submitted")
        self._m_resolved = reg.counter("serve_requests_resolved")
        self._m_launches = reg.counter("serve_launches")
        self._m_deferred_fills = reg.counter("serve_deferred_fill_entries")
        self._m_prefetch_bytes = reg.counter("serve_prefetch_bytes")
        self._m_queue_wait = reg.histogram("serve_queue_wait_seconds")
        self._m_occupancy = reg.histogram("serve_batch_occupancy")
        self._m_launch_wall = reg.histogram("serve_launch_wall_seconds")
        self._m_inflight = reg.gauge("serve_inflight_depth")
        self._m_resolve_lag = reg.histogram("serve_resolve_lag_seconds")
        # Per-stage energy split: handles held per stage name (like the
        # gauges above) and SAMPLED every 8th launch — the split is a
        # steady-state distribution, not a per-launch ledger, and keeping
        # it off the per-launch path holds the metrics-enabled runtime
        # inside the <=2% observability overhead contract. The headline
        # energy_uj_per_query histogram stays per-launch exact.
        self._m_stage_uj: dict[str, object] = {}
        self._stage_energy_tick = 0
        # Clock discipline: `now` is injectable everywhere (simulated
        # clocks in tests); once any caller supplies one, implicit
        # clocks (flush() via result()) reuse the last seen value so
        # traces stay deterministic instead of mixing in wall time.
        self._last_now = 0.0
        self._simulated = False
        self.cache = (HotClusterCache(self.cfg.cache_bytes,
                                      registry=(reg if reg.enabled
                                                else None),
                                      precision_tiers=(
                                          self.cfg.precision_tiers))
                      if self.cfg.cache_bytes > 0 else None)
        self._queues: "collections.OrderedDict[int, collections.deque[_Pending]]" = (
            collections.OrderedDict())
        # Completion queue: dispatched launches whose device futures are
        # still unresolved, oldest first. Bounded by cfg.async_depth.
        self._inflight: "collections.deque[_InFlight]" = collections.deque()
        self._num_pending = 0
        self._next_id = 0
        self._seq = 0
        # tenant -> recently probed clusters, most recent first (the
        # session prior that warms the cache between turns).
        self._recent: dict[int, list[int]] = {}
        # launch signature -> analytic base SchedulePlan (pure shape
        # arithmetic; identical every steady-state turn).
        self._plan_cache: dict[tuple, engine.SchedulePlan] = {}
        # (arena generation, tids) -> device (B, K) selection validity.
        self._valid_cache: dict[tuple, jax.Array] = {}
        # (generation, tenant) -> (packed demand blocks, nonempty
        # clusters): the preload's admission arithmetic, computed once
        # per arena state instead of rescanning every launch.
        self._tenant_demand: dict[tuple, tuple[int, int]] = {}
        # -- ledgers (engine.SchedulePlan units, exact bytes) --------------
        self.launches = 0
        self.queries_served = 0
        self.stage1_bytes_streamed = 0    # HBM bytes, all launches
        self.stage1_bytes_sram = 0        # cache-served bytes, all launches
        self.stage1_bytes_vmapped = 0     # the one-query-at-a-time path
        self.prefetch_bytes = 0           # prior-warming gathers (HBM)
        self.stage_bytes: dict[str, int] = {}       # per-stage HBM
        self.stage_bytes_sram: dict[str, int] = {}  # per-stage cache-SRAM
        self.last_plan: engine.SchedulePlan | None = None
        # -- decode-side ledger (engine.kv_plan units) ---------------------
        self.decode_steps = 0
        self.decode_bytes_hbm = 0
        self.last_decode_plan: engine.SchedulePlan | None = None

    # -- admission ----------------------------------------------------------

    def submit(self, tenant_id: int, query_codes, *,
               deadline: float | None = None,
               now: float | None = None) -> RequestHandle:
        """Enqueue one request; returns its future-style handle.

        deadline: absolute time (same clock as `now`) by which the
        request must be in a launch; defaults to now + cfg.max_wait."""
        if int(tenant_id) < 0:
            raise ValueError(f"tenant id must be >= 0, got {tenant_id}")
        q = np.asarray(query_codes, np.int8)
        if q.ndim != 1 or q.shape[0] != self.index.arena.dim:
            raise ValueError(f"query must be ({self.index.arena.dim},) int8")
        now = self._clock(now)
        if deadline is None:
            # max_wait == 0 means NO deadline-forced launches (the
            # legacy scheduler contract: launch only when full or
            # explicitly flushed), not launch-immediately.
            deadline = (now + self.cfg.max_wait if self.cfg.max_wait > 0
                        else math.inf)
        handle = RequestHandle(self, self._next_id, int(tenant_id), deadline)
        self._next_id += 1
        pend = _Pending(handle=handle, query=q, seq=self._seq, submit_ts=now)
        self._seq += 1
        self._queues.setdefault(int(tenant_id), collections.deque()).append(
            pend)
        self._num_pending += 1
        self._m_submitted.inc()
        self.tracer.begin("request", handle.request_id, now=now,
                          tid=int(tenant_id), request=handle.request_id)
        if self.cfg.auto_flush and self._num_pending >= self.cfg.max_batch:
            self._launch(self._form_batch(), now)
        return handle

    def _clock(self, now: float | None) -> float:
        """Resolve an optional caller-supplied timestamp.

        The first explicit `now` switches the runtime to simulated time:
        from then on calls WITHOUT a timestamp (flush() via result())
        reuse the last seen value instead of mixing in wall-clock reads,
        so queue-wait histograms and traces stay deterministic under the
        test suite's simulated schedules."""
        if now is None:
            now = self._last_now if self._simulated else time.monotonic()
        else:
            self._simulated = True
        self._last_now = now
        return now

    def pending(self) -> int:
        return self._num_pending

    def _oldest_deadline(self) -> float | None:
        heads = [q[0].handle.deadline for q in self._queues.values() if q]
        return min(heads) if heads else None

    def ready(self, now: float | None = None) -> bool:
        """Would poll() launch something right now?"""
        if self._num_pending >= self.cfg.max_batch:
            return True
        oldest = self._oldest_deadline()
        if oldest is None:
            return False
        now = time.monotonic() if now is None else now
        return oldest <= now

    def next_deadline(self) -> float | None:
        """When the queue next forces a launch (None if empty or no
        pending request carries a finite deadline)."""
        oldest = self._oldest_deadline()
        return None if oldest is None or math.isinf(oldest) else oldest

    def poll(self, now: float | None = None) -> list[RequestHandle]:
        """Launch every batch that is full or past its oldest deadline.

        Returns the handles dispatched by this call (possibly empty — a
        young partial batch keeps waiting for more traffic). Dispatched
        handles are in flight, not necessarily resolved: poll() also
        opportunistically retires launches whose device buffers already
        landed (`reap`), but never blocks on one — that is what
        `flush()`/`barrier()`/`result()` are for."""
        now = self._clock(now)
        launched: list[RequestHandle] = []
        while self._num_pending and self.ready(now):
            launched.extend(self._launch(self._form_batch(), now))
        self.reap()
        return launched

    def flush(self, now: float | None = None) -> list[RequestHandle]:
        """Drain the queue unconditionally (deadlines ignored) and
        barrier: on return every handle this runtime ever dispatched is
        resolved and every deferred ledger/cache bookkeeping has run.
        Returns the handles drained from the queue by THIS call."""
        now = self._clock(now)
        launched: list[RequestHandle] = []
        while self._num_pending:
            launched.extend(self._launch(self._form_batch(), now))
        self.barrier()
        return launched

    def barrier(self) -> int:
        """Retire every in-flight launch (blocking), oldest first.

        Returns how many launches were retired. After a barrier all
        ledgers (`last_plan`, byte counters, cache stats, session
        priors) are final for everything dispatched so far."""
        n = 0
        while self._inflight:
            self._retire(self._inflight.popleft())
            n += 1
        return n

    def reap(self) -> int:
        """Non-blocking retire: resolve launches whose device buffers
        already landed, oldest first, stopping at the first one still
        executing. Returns how many launches were retired."""
        n = 0
        while self._inflight and self._inflight[0].is_ready():
            self._retire(self._inflight.popleft())
            n += 1
        return n

    def in_flight(self) -> int:
        """How many dispatched launches are currently unresolved."""
        return len(self._inflight)

    def _retire_through(self, target: _InFlight) -> None:
        """Retire queue head through `target` inclusive (the device runs
        launches in dispatch order, so everything older landed first)."""
        while self._inflight:
            infl = self._inflight.popleft()
            self._retire(infl)
            if infl is target:
                return

    def _retire(self, infl: _InFlight) -> None:
        """Resolve one launch: materialize the batch's device arrays
        (blocking if still executing), hand out numpy row views, close
        request spans, then run the launch's deferred bookkeeping —
        always in dispatch order, so the cache/ledger mutation sequence
        is the synchronous path's sequence."""
        res = infl.res
        # Materialize the batch ONCE and hand out numpy row views:
        # slicing jnp arrays per lane would dispatch 3 eager device ops
        # per request (a measurable per-flush tax at serving batch sizes).
        indices = np.asarray(res.indices)
        scores = np.asarray(res.scores)
        cands = np.asarray(res.candidate_indices)
        self._m_resolve_lag.observe(
            max(0.0, time.monotonic() - infl.dispatch_t))
        for i, req in enumerate(infl.group):
            req.handle._result = RetrievalResult(
                indices=indices[i], scores=scores[i],
                candidate_indices=cands[i])
            req.handle._inflight = None
            self._m_queue_wait.observe(
                max(0.0, infl.admit_now - req.submit_ts))
            self.tracer.end(req.handle.request_id, now=infl.admit_now,
                            request=req.handle.request_id,
                            launch=infl.launch_index)
        self._m_resolved.inc(len(infl.group))
        if infl.book is not None:
            infl.book()
        self._m_inflight.set(float(len(self._inflight)))

    def _form_batch(self) -> list[_Pending]:
        """Pick up to max_batch pending requests.

        fifo: strict arrival order (the legacy scheduler's grouping).
        deadline_rr: one request per tenant, round-robin, tenants ordered
        by their head request's deadline (FIFO within a tenant) — the
        most urgent tenants are served first and no tenant can occupy
        more than its share of a contended flush."""
        group: list[_Pending] = []
        if self.cfg.fairness == "fifo":
            # k-way merge of the per-tenant FIFO queues by arrival seq:
            # O(B log T) per batch instead of a min() scan per request.
            heads = [(q[0].seq, t) for t, q in self._queues.items() if q]
            heapq.heapify(heads)
            while len(group) < self.cfg.max_batch and heads:
                _, tid = heapq.heappop(heads)
                group.append(self._pop_from(tid))
                queue = self._queues.get(tid)
                if queue:
                    heapq.heappush(heads, (queue[0].seq, tid))
        else:
            # One urgency sort per BATCH (head deadline, then arrival),
            # then round-robin passes over that order until the batch is
            # full or the queues drain.
            order = sorted(
                (t for t, q in self._queues.items() if q),
                key=lambda t: (self._queues[t][0].handle.deadline,
                               self._queues[t][0].seq))
            while len(group) < self.cfg.max_batch:
                progressed = False
                for tid in order:
                    if len(group) >= self.cfg.max_batch:
                        break
                    if self._queues.get(tid):
                        group.append(self._pop_from(tid))
                        progressed = True
                if not progressed:
                    break
        return group

    def _pop_from(self, tid: int) -> _Pending:
        """Pop a tenant's head request; drop its deque once drained so a
        long-lived runtime's admission scans stay proportional to the
        ACTIVE tenants, not every tenant ever seen."""
        queue = self._queues[tid]
        pend = queue.popleft()
        self._num_pending -= 1
        if not queue:
            del self._queues[tid]
        return pend

    # -- launching ----------------------------------------------------------

    @staticmethod
    def _bucket(n: int) -> int:
        return 1 << (n - 1).bit_length() if n > 1 else 1

    def _launch(self, group: list[_Pending],
                now: float | None = None) -> list[RequestHandle]:
        """Dispatch one batch and enqueue it on the completion queue.

        Host cost here is admission + dispatch only: the device arrays
        stay in flight as futures and every readback-dependent step
        (handing out results, the cached path's ledger/LRU/admission
        bookkeeping) is deferred to `_retire`. With async_depth=0 the
        backpressure loop below retires the launch before returning —
        the legacy synchronous contract."""
        b = len(group)
        if b == 0:
            return []
        now = self._clock(now)
        pb = self._bucket(b)
        queries = np.zeros((pb, self.index.arena.dim), np.int8)
        tids = np.full((pb,), NO_TENANT, np.int32)
        for i, req in enumerate(group):
            queries[i] = req.query
            tids[i] = req.handle.tenant_id
            req.handle.launch_index = self.launches
            self.tracer.instant("admit", now=now, tid=req.handle.tenant_id,
                                request=req.handle.request_id,
                                launch=self.launches)
        t0 = time.monotonic()
        with self.tracer.span("launch", now=now, batch=b, padded=pb,
                              index=self.launches):
            res, plan, book = self._execute(queries, tids)
        # Dispatch wall only — execution overlaps the host from here on;
        # serve_resolve_lag_seconds (observed at retire) is the other half.
        self._m_launch_wall.observe(time.monotonic() - t0)
        self._m_launches.inc()
        self._m_occupancy.observe(float(b))
        self.launches += 1
        self.queries_served += b
        if plan is not None:
            self._account_plan(plan, b)
        infl = _InFlight(group=group, res=res, launch_index=self.launches - 1,
                         admit_now=now, dispatch_t=time.monotonic(),
                         book=book)
        for req in group:
            req.handle._inflight = infl
        self._inflight.append(infl)
        self._m_inflight.set(float(len(self._inflight)))
        # Backpressure: never more than async_depth unresolved launches —
        # beyond it, block on the oldest (it is the furthest along).
        while len(self._inflight) > self.cfg.async_depth:
            self._retire(self._inflight.popleft())
        return [req.handle for req in group]

    def _account_plan(self, plan: engine.SchedulePlan, b: int) -> None:
        """Fold one launch's SchedulePlan into the runtime ledgers.

        Runs at dispatch for the uncached path (the plan is analytic)
        and inside the deferred bookkeeping for the cached path (the
        hit/miss split needs the selection readback) — either way in
        launch order, so ledgers after a barrier match the synchronous
        path exactly."""
        self.last_plan = plan
        # stage1_bytes is what the launch actually streamed from HBM
        # (padding lanes included); the vmapped comparison counts only
        # the b REAL requests — a sequential server would never have
        # dispatched the padding lanes.
        self.stage1_bytes_streamed += plan.stage1_bytes
        self.stage1_bytes_sram += plan.stage1_bytes_sram
        self.stage1_bytes_vmapped += (
            plan.stage1_bytes_vmapped // plan.batch) * b
        for s in plan.stages:
            self.stage_bytes[s.name] = (
                self.stage_bytes.get(s.name, 0) + s.bytes_hbm)
            if s.bytes_sram:
                self.stage_bytes_sram[s.name] = (
                    self.stage_bytes_sram.get(s.name, 0) + s.bytes_sram)
        if self.registry.enabled:
            # Derived publications (per-stage fan-out + energy
            # pricing) only when someone is listening: keeps the
            # metrics-off launch path byte-identical to pre-obs.
            plan.publish(self.registry)
            dim = self.index.arena.dim
            energy.observe_cost(
                self.registry,
                energy.cost_cascade(plan.stages, dim, batch=plan.batch),
                queries=b)
            # Sampled per-stage split (see __init__): every 8th launch,
            # priced by the linear fast path on held handles.
            self._stage_energy_tick += 1
            if (self._stage_energy_tick - 1) % 8 == 0:
                for s in plan.stages:
                    h = self._m_stage_uj.get(s.name)
                    if h is None:
                        h = self._m_stage_uj[s.name] = self.registry.histogram(
                            "energy_uj_per_query_stage", stage=s.name)
                    h.observe(energy.stage_cost_uj(s, dim, batch=plan.batch),
                              b)

    def _execute(self, queries: np.ndarray, tids: np.ndarray
                 ) -> tuple[RetrievalResult, engine.SchedulePlan | None,
                            "collections.abc.Callable[[], None] | None"]:
        """Dispatch one batch; returns (device result, plan-if-known,
        deferred bookkeeping). The uncached path's plan is analytic —
        known at dispatch, no bookkeeping; the cached path defers its
        readback-dependent plan + cache bookkeeping to retire time."""
        if self.cache is not None:
            layout = self.index.cluster_layout(tids)
            if layout is not None:
                return self._execute_cached(queries, tids, *layout)
        res = self.index.retrieve(jnp.asarray(queries), tids)
        return res, self.index.last_plan, None

    # -- the hot-cluster-cache path -----------------------------------------

    def _warm_from_prior(self, tids: np.ndarray) -> int:
        """Prefetch each batch tenant's recently-probed clusters into the
        slab (device row copies — the host never touches the bytes).

        Touches entries that are still resident (refreshing their LRU
        position) and re-admits ones an arena mutation invalidated — the
        bytes are charged to the launch as HBM traffic (`prefetch`), the
        win is that the session's NEXT probes hit."""
        bytes_fetched = 0
        for t in set(int(x) for x in tids.tolist()):
            if t < 0:
                continue
            recent = self._recent.get(t)
            if not recent:
                continue     # nothing to warm: skip the host row scan
            rows_of = self.index.cluster_rows(t)
            for c in recent:
                if self.cache.peek(t, c):
                    self.cache.touch(t, c)
                    continue
                slots = self.cache.put(t, c, rows_of.get(c, ()))
                if slots is None:
                    continue          # oversized: stays HBM-streamed
                bytes_fetched += len(slots) * self.cache.block_rows * \
                    self.cache.bytes_per_row
        return bytes_fetched

    def _preload_tenants(self, tids: np.ndarray) -> tuple[int, bool]:
        """EdgeRAG-style hot preload: pin every batch tenant's cluster
        set into the slab, so the launch can run from the COMPACT table.

        Admits only when the whole batch's packed demand fits the budget
        TOGETHER — a short budget keeps the per-probe prior warming
        instead of thrashing admissions against evictions. Returns
        (prefetched HBM bytes, every-batch-tenant-fully-resident). A
        steady-state call is a handful of memoized dict lookups."""
        cache = self.cache
        br = cache.block_rows
        gen = self.index.arena.generation
        tenants = sorted(set(int(x) for x in tids.tolist()) - {-1})
        demand = 0
        stats = {}
        for t in tenants:
            key = (gen, t)
            st = self._tenant_demand.get(key)
            if st is None:
                rows_of = self.index.cluster_rows(t)
                st = (sum(cache.entry_blocks(r, br)
                          for r in rows_of.values()),
                      sum(1 for r in rows_of.values() if r.size))
                if len(self._tenant_demand) > 4096:
                    self._tenant_demand.clear()
                self._tenant_demand[key] = st
            stats[t] = st
            demand += st[0]
        if demand * br * cache.bytes_per_row > cache.budget_bytes:
            return 0, False
        bytes_fetched = 0
        for t in tenants:
            if cache.fully_resident(t, stats[t][1]):
                continue
            for c, rows in self.index.cluster_rows(t).items():
                if cache.peek(t, c):
                    continue
                slots = cache.put(t, c, rows)
                if slots is not None:
                    bytes_fetched += len(slots) * br * cache.bytes_per_row
        # Residency is re-verified for EVERY batch tenant only after all
        # admissions ran: slots held by non-batch residents can force a
        # later tenant's puts to evict an earlier batch tenant's entries
        # (the demand check bounds the batch, not the whole slab), and a
        # compact table for a partially-evicted tenant would silently
        # hole its clusters. Any shortfall falls back to the full-width
        # table — slower, never wrong.
        resident = all(cache.fully_resident(t, stats[t][1])
                       for t in tenants)
        return bytes_fetched, resident

    def _cluster_valid(self, tids: np.ndarray, host_table: np.ndarray):
        """Device (B, K) selection-validity bools — the plane table's
        ``first block >= 0`` bits, precomputed host-side so selection is
        identical at ANY launch table width. Cached per (arena
        generation, tenant tuple); the host table is deterministic per
        that key."""
        key = (self.index.arena.generation, tids.tobytes())
        hit = self._valid_cache.get(key)
        if hit is not None:
            return hit
        if len(self._valid_cache) > 64:
            self._valid_cache.clear()
        valid = jnp.asarray(host_table[:, :, 0] >= 0)
        self._valid_cache[key] = valid
        return valid

    def _execute_cached(self, queries: np.ndarray, tids: np.ndarray,
                        policy: engine.ClusterPolicy,
                        host_table: np.ndarray
                        ) -> tuple[RetrievalResult, None,
                                   "collections.abc.Callable[[], None]"]:
        """One launch through the device-resident slab path.

        Host work at dispatch is a handful of dict/array lookups: pin the
        slab to the arena generation, warm the session (priors, or the
        full preload when enabled), resolve the slot map into the launch
        indirection table — the COMPACT slab table when every batch
        tenant is fully resident, the full-width plane table otherwise;
        both cached per slot-map version, zero rebuild when fully warm —
        and launch ONE jitted cascade (`SlabPolicy`). Selection runs
        in-graph; the tiny (B, nprobe) selection readback that feeds the
        hit/miss ledger, the LRU, miss admissions (device row copies)
        and the session prior is DEFERRED into the returned bookkeeping
        closure, run at retire time in launch order — so the host forms
        and dispatches the next batch instead of stalling on this one's
        selection. Pipelined launches therefore warm from priors that
        may lag by the pipeline depth; that shifts only WHERE bytes come
        from (and when admissions land), never what is scored — results
        stay bit-identical to the synchronous path, and a barrier
        (flush) drains bookkeeping in launch order so per-flush ledgers
        match it exactly. No per-lane view is ever materialized on the
        host or uploaded, and hit rows are never re-streamed."""
        index = self.index
        db = index.arena.db()
        cache = self.cache
        br = policy.block_rows
        d2 = db.msb_plane.shape[1]
        k_clusters = policy.centroid_msb.shape[0]
        cache.configure(br, d2)
        if (self._inflight
                and cache.generation != index.arena.generation):
            # An arena mutation is about to invalidate the slab: retire
            # everything dispatched against the OLD generation first, so
            # their deferred bookkeeping reads the slot map its launches
            # actually encoded (exact synchronous semantics across
            # generations; mutations are rare, the sync is off the
            # steady-state path).
            self.barrier()
        cache.sync_generation(index.arena.generation)
        cache.ensure_slab(db.msb_plane, db.norms_sq, policy.owner,
                          policy.labels, k_clusters)
        compact = False
        prefetched = 0
        if self.cfg.preload:
            prefetched, compact = self._preload_tenants(tids)
        if not compact:
            prefetched += self._warm_from_prior(tids)
        # ONE fill dispatch per launch: the previous launch's deferred
        # miss admissions plus this launch's warming, applied before the
        # indirection table can reference their slots.
        cache.flush_fills()
        if compact:
            slab_blocks, width = cache.compact_table(tids, k_clusters)
            if min(policy.nprobe, k_clusters) * width * br < index.cfg.k:
                compact = False     # view too narrow to hold k: full width
        if not compact:
            slab_blocks = cache.combined_table(tids, host_table)
        # Stage-0 prescreen operand: the combined sign plane (derived
        # from the combined msb plane, cached per plane state) rides
        # along whenever the config prescreens — resident clusters'
        # sign bytes then serve stage 0 from the slab region.
        prescreen = (index.cfg.prescreen_c0 is not None
                     and index.arena.dim % 8 == 0)
        spolicy = engine.SlabPolicy(
            packed_labels=cache.packed_labels,
            tenant_ids=policy.tenant_ids, centroid_msb=policy.centroid_msb,
            centroid_norms=policy.centroid_norms,
            cluster_valid=self._cluster_valid(tids, host_table),
            slab_blocks=slab_blocks, block_gid0=cache.block_gid0,
            block_count=cache.block_count, slab_plane=cache.slab_plane,
            inv_norms=cache.inv_norms, nprobe=policy.nprobe, block_rows=br,
            sign_plane=(cache.sign_plane if prescreen else None),
            block_tier=(cache.block_tier if cache.precision_tiers
                        else None))
        res, top_clusters = index.engine.retrieve_with_clusters(
            jnp.asarray(queries), db, spolicy)
        # Dispatch done. Everything below needs the (B, nprobe) selection
        # readback — a device sync — so it is packaged into a closure the
        # completion queue runs at retire time (launch order), letting
        # the host overlap the NEXT batch's admission with this scoring.
        arena_gen = index.arena.generation
        b_real = int((tids >= 0).sum())
        probe_rows = engine.probe_rows(spolicy)

        c0 = (index.cfg.prescreen_budget(probe_rows) if prescreen
              else None)

        def book() -> None:
            # Admissions still run AFTER the whole hit/miss loop, so the
            # ledger reflects the slot-map snapshot at retire time; a
            # barrier per turn (flush) makes that the exact snapshot the
            # launch's table encoded, the synchronous path's ledger.
            tc = np.asarray(top_clusters)
            bsz = tc.shape[0]
            block_bytes = br * d2
            sign_block_bytes = br * (d2 // 4)   # 1-bit vs 4-bit rows
            tiers = cache.precision_tiers
            hit_bytes = miss_bytes = 0
            ps_sram = ps_hbm = 0      # stage-0 sign-byte split
            # A mutation between dispatch and retire means cluster_rows
            # now describes a DIFFERENT arena: admitting those rows into
            # this launch's (old-generation) slot map would be wrong,
            # and the next cached dispatch invalidates the slab anyway.
            stale = index.arena.generation != arena_gen
            to_admit: dict[tuple[int, int], int] = {}
            to_promote: dict[tuple[int, int], int] = {}
            for i in range(bsz):
                t = int(tids[i])
                if t < 0:
                    continue                  # padding lane: all holes
                row_table = host_table[i]
                probes = tc[i].tolist()
                if tiers:
                    (lane_full, lane_sign, sign_hits,
                     missing) = cache.lookup_lane_tiers(t, probes)
                    hit_bytes += lane_full
                    if c0 is not None:
                        # Resident probes serve stage 0 on-chip: full
                        # tier mirrors the slab's sign bytes (1/4 of its
                        # nibble charge), sign tier is the tier's whole
                        # point.
                        ps_sram += lane_full // 4 + lane_sign
                    for c in sign_hits:
                        key = (t, c)
                        if key not in to_promote:
                            to_promote[key] = int((row_table[c] >= 0).sum())
                        # sign tier holds no slab rows: stage 1 streamed
                        # the cluster's PLANE blocks from HBM
                        miss_bytes += to_promote[key] * block_bytes
                else:
                    lane_hit, missing = cache.lookup_lane(t, probes)
                    hit_bytes += lane_hit
                    if c0 is not None:
                        ps_sram += lane_hit // 4
                for c in missing:
                    key = (t, c)
                    if key not in to_admit:
                        to_admit[key] = int((row_table[c] >= 0).sum())
                    # a miss streamed the cluster's PLANE blocks from HBM
                    miss_bytes += to_admit[key] * block_bytes
                    if c0 is not None:
                        ps_hbm += to_admit[key] * sign_block_bytes
            if (to_admit or to_promote) and not stale:
                self._m_deferred_fills.inc(len(to_admit) + len(to_promote))
                for (t, c) in to_admit:
                    # Under tiers, first contact admits at 1-bit
                    # precision; a re-probe promotes to full.
                    cache.put(t, c, index.cluster_rows(t).get(c, ()),
                              tier=(TIER_SIGN if tiers else TIER_FULL))
                for (t, c) in to_promote:
                    cache.promote(t, c, index.cluster_rows(t).get(c, ()))
                    # fills applied by the NEXT launch's flush
            # Ledger: the analytic cluster plan with the approx stage
            # split into measured HBM misses (+ warming prefetches) vs
            # cache hits. The base plan is pure arithmetic over static
            # shapes — cached per launch signature so the steady state
            # doesn't rebuild an identical plan every turn.
            pkey = (db.num_docs, db.dim, bsz, k_clusters, probe_rows)
            base = self._plan_cache.get(pkey)
            if base is None:
                if len(self._plan_cache) > 256:  # num_docs moves per mutation
                    self._plan_cache.clear()
                base = engine.plan(index.cfg, num_docs=db.num_docs,
                                   dim=db.dim, batch=bsz, kind="cluster",
                                   num_clusters=k_clusters,
                                   view_rows=probe_rows)
                self._plan_cache[pkey] = base
            approx_hbm = miss_bytes + prefetched
            approx_sram = hit_bytes
            if c0 is not None and probe_rows:
                # A prescreened stage 1 gathers only the C0 survivors,
                # not the whole view: prorate the measured cluster-level
                # split by the survivor fraction (survivors spread
                # across probed clusters; an exact per-row residency
                # split would need a second selection readback).
                # Warming prefetches are real whole-cluster plane
                # copies — charged unprorated.
                frac = min(1.0, c0 / probe_rows)
                approx_hbm = int(miss_bytes * frac) + prefetched
                approx_sram = int(hit_bytes * frac)
            plan = engine.cache_split_plan(
                base, hbm_bytes=approx_hbm, sram_bytes=approx_sram,
                prescreen_hbm=(ps_hbm if c0 is not None else None),
                prescreen_sram=ps_sram)
            self.prefetch_bytes += prefetched
            self._m_prefetch_bytes.inc(prefetched)
            index.last_plan = plan
            self._account_plan(plan, b_real)
            # Refresh each tenant's session prior with the clusters this
            # turn actually probed (most recent first, bounded). Compact
            # launches skip it: the preload pins the whole session, so
            # the prior would never be consulted (it rebuilds within
            # prior_clusters turns if a budget/demand shift ever forces
            # the fallback path).
            if self.cfg.prior_clusters and not compact:
                for i in range(bsz):
                    t = int(tids[i])
                    if t < 0:
                        continue
                    fresh = list(dict.fromkeys(int(c) for c in tc[i]))
                    old = [c for c in self._recent.get(t, [])
                           if c not in fresh]
                    self._recent[t] = (fresh + old)[:self.cfg.prior_clusters]

        return res, None, book

    # -- reporting ----------------------------------------------------------

    def cache_stats(self) -> dict:
        self.barrier()    # stats are defined as of the last RETIRED launch
        if self.cache is None:
            return {"enabled": False}
        return {"enabled": True, "entries": len(self.cache),
                "bytes_used": self.cache.bytes_used,
                "budget_bytes": self.cache.budget_bytes,
                "slab_blocks": self.cache.num_slab_blocks,
                "slab_blocks_used": (self.cache.num_slab_blocks
                                     - len(self.cache._free)),
                **self.cache.snapshot()}

    def energy_ledger(self, dim: int | None = None):
        """cost_cascade of the most recent launch's measured plan."""
        self.barrier()    # the cached path's plan lands at retire time
        if self.last_plan is None:
            raise RuntimeError("no launch has run yet")
        return energy.cost_cascade(self.last_plan.stages,
                                   dim or self.index.arena.dim,
                                   batch=self.last_plan.batch)

    # -- decode accounting --------------------------------------------------

    def account_decode(self, plan: engine.SchedulePlan, *, dim: int,
                       tokens: int = 1):
        """Charge a decode run's KV-cascade ledger through this runtime.

        `plan` is ONE decode step's `engine.kv_plan` (kind="decode");
        `tokens` scales it to the whole run — the stage geometry is
        identical every step at a fixed cache length, so one plan prices
        the run the way one launch plan prices a retrieval batch. The
        scaled ledger fans out through the same `SchedulePlan.publish`
        counters as retrieval launches (stage_rows / stage_bytes_hbm per
        stage name), and the priced per-token cost lands in the
        `energy_uj_per_token` histogram — one runtime, one registry, two
        memory-bound workloads. Returns the per-token CostBreakdown."""
        if plan.kind != "decode":
            raise ValueError(f"account_decode wants a kind='decode' plan, "
                             f"got {plan.kind!r}")
        scaled = dataclasses.replace(
            plan,
            stages=tuple(dataclasses.replace(
                s, bytes_hbm=s.bytes_hbm * tokens,
                bytes_sram=s.bytes_sram * tokens,
                compares=s.compares * tokens) for s in plan.stages),
            stage1_bytes=plan.stage1_bytes * tokens,
            stage1_bytes_vmapped=plan.stage1_bytes_vmapped * tokens,
            stage2_bytes=plan.stage2_bytes * tokens)
        self.decode_steps += tokens
        self.decode_bytes_hbm += sum(s.bytes_hbm for s in scaled.stages)
        self.last_decode_plan = plan
        cost = energy.cost_cascade(plan.stages, dim, batch=plan.batch)
        if self.registry.enabled:
            scaled.publish(self.registry)
            energy.observe_decode_cost(self.registry, cost, tokens=tokens)
        return cost
