"""End-to-end RAG pipelines (Fig. 1 of the paper; single- and multi-tenant).

offline:  doc tokens --MiniLM embedder--> float embeddings --INT8 quant-->
          nibble-planar DB (optionally sharded over a mesh)
online:   query tokens -> query embedding -> INT8 codes
          -> TWO-STAGE HIERARCHICAL RETRIEVAL (the paper's core)
          -> augmented prompt = [retrieved doc tokens; query tokens]
          -> generator prefill + decode

`MultiTenantRAGPipeline` is the streaming/wearable variant: there is no
offline phase — per-user corpora are ingested online into a shared
fixed-capacity arena (repro.tenancy) and a mixed batch of users is served
by ONE segment-masked retrieval launch.

Both pipelines report the retrieval energy ledger per query batch via the
paper-calibrated cost model (core.energy), so serving logs expose the same
numbers the paper's Table II does.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (BitPlanarDB, RetrievalConfig, RetrievalEngine,
                        build_database, energy, quantize_int8)
from repro.core import engine as engine_mod
from repro.core.index import ShardedIndex
from repro.models import embedder as emb_mod
from repro.models.common import ModelConfig
from repro.models.registry import ModelApi
from repro.serve.sampler import generate, jitted_fns, sample_tokens
from repro.tenancy import MultiTenantIndex


@dataclasses.dataclass
class RAGPipeline:
    emb_cfg: ModelConfig
    emb_params: Any
    gen_api: ModelApi
    gen_params: Any
    retrieval_cfg: RetrievalConfig
    doc_tokens: jax.Array                  # (N, doc_len) int32
    db: BitPlanarDB | None = None          # single-host DB
    index: ShardedIndex | None = None      # pod-sharded DB (preferred)
    # index.retrieve_fn wraps shard_map in a FRESH jax.jit each time it is
    # called, so it must be built once and cached here — rebuilding it per
    # query forced a retrace+recompile on every request. The cache is a
    # (cfg, fn) pair KEYED on the config: replacing `retrieval_cfg` after
    # construction invalidates it instead of silently serving the old
    # k/metric/backend.
    _sharded_retrieve: Any = dataclasses.field(default=None, repr=False,
                                               compare=False)

    @classmethod
    def build(cls, emb_cfg, emb_params, gen_api, gen_params, doc_tokens,
              retrieval_cfg: RetrievalConfig | None = None, mesh=None,
              encode_batch: int = 64):
        """Offline phase: embed + quantize the document corpus."""
        retrieval_cfg = retrieval_cfg or RetrievalConfig()
        n = doc_tokens.shape[0]
        chunks = []
        enc = jax.jit(lambda p, t: emb_mod.encode(p, t, emb_cfg))
        for i in range(0, n, encode_batch):
            chunks.append(enc(emb_params, doc_tokens[i:i + encode_batch]))
        embs = jnp.concatenate(chunks, axis=0)
        if mesh is not None:
            index = ShardedIndex.build(embs, mesh)
            db = None
        else:
            index = None
            db = BitPlanarDB.from_quantized(build_database(embs))
        return cls(emb_cfg=emb_cfg, emb_params=emb_params, gen_api=gen_api,
                   gen_params=gen_params, retrieval_cfg=retrieval_cfg,
                   doc_tokens=doc_tokens, db=db, index=index)

    # -- retrieval ---------------------------------------------------------

    def retrieve(self, query_tokens: jax.Array):
        """query_tokens (B, L) -> (indices (B, k), energy ledger)."""
        q_emb = emb_mod.encode(self.emb_params, query_tokens, self.emb_cfg)
        q_codes, _ = quantize_int8(q_emb, per_vector=True)
        if self.index is not None:
            cached = self._sharded_retrieve
            if cached is None or cached[0] != self.retrieval_cfg:
                self._sharded_retrieve = (
                    self.retrieval_cfg,
                    self.index.retrieve_fn(self.retrieval_cfg))
            res = self._sharded_retrieve[1](q_codes)
            n_docs = self.index.n_global
        else:
            # Batch-native engine core: one launch, doc plane streamed
            # once for the whole query batch.
            res = RetrievalEngine(self.retrieval_cfg).retrieve(q_codes,
                                                               self.db)
            n_docs = self.db.num_docs
        dim = q_emb.shape[-1]
        # Charge what the engine's schedule actually streams — the
        # launch's per-stage ledger (shared-plane stage-1 bytes amortized
        # over the batch, exact stage sized by the candidate budget) —
        # not the analytic full-scan cost_hierarchical, which ignored the
        # batch amortization entirely and overcharged every multi-query
        # launch. Same pattern as MultiTenantRAGPipeline.retrieve.
        b = int(q_codes.shape[0])
        plan = engine_mod.plan(self.retrieval_cfg, num_docs=n_docs,
                               dim=dim, batch=b, kind="plain")
        ledger = energy.cost_cascade(plan.stages, dim, batch=plan.batch)
        return res, ledger

    # -- generation --------------------------------------------------------

    def answer(self, query_tokens: jax.Array, *, max_new: int = 32,
               temperature: float = 0.0, key=None):
        """Full RAG answer: retrieve, augment, generate.

        Returns (generated tokens (B, max_new), retrieved ids (B, k),
        energy ledger for the retrieval stage)."""
        res, ledger = self.retrieve(query_tokens)
        ids = res.indices                                 # (B, k)
        b, k = ids.shape
        docs = jnp.take(self.doc_tokens, ids.reshape(-1), axis=0)
        docs = docs.reshape(b, k * self.doc_tokens.shape[1])
        prompt = jnp.concatenate([docs, query_tokens], axis=1)
        vocab = self.gen_api.cfg.vocab_size
        prompt = jnp.clip(prompt, 0, vocab - 1)
        out, _ = generate(self.gen_api, self.gen_params, {"tokens": prompt},
                          max_new=max_new, temperature=temperature, key=key)
        return out, ids, ledger


@dataclasses.dataclass
class AgentTurnReport:
    """Accounting for one end-to-end agent turn (retrieve + decode)."""
    tokens: jax.Array            # (B, max_new) generated ids
    retrieved: np.ndarray        # (B, k) arena slot ids (-1 = no hit)
    retrieval_cost: Any          # energy.CostBreakdown, PER QUERY
    decode_cost: Any             # energy.CostBreakdown, PER TOKEN
    decode_plan: Any             # engine.SchedulePlan (kind="decode")
    uj_per_query: float
    uj_per_token: float
    decode_bytes_per_token: int      # measured ledger, whole batch
    dense_bytes_per_token: int       # dense-decode baseline, whole batch


@dataclasses.dataclass
class RAGAgent:
    """End-to-end agent turn: ONE `ServingRuntime` schedules both the
    retrieval launch and the decode-step KV cascade.

    The two memory-bound lookups of a wearable agent turn — corpus
    retrieval and per-step cache attention — run through the same engine
    cascade machinery and land in the same registry: retrieval publishes
    its measured `SchedulePlan` and µJ/query (as before), decode charges
    its `kv_plan` ledger via `runtime.account_decode` into µJ/token. The
    generator must be a dense-family model (the quantized-KV decode path
    lives in models/dense)."""

    pipeline: "MultiTenantRAGPipeline"
    runtime: Any                      # serve.runtime.ServingRuntime
    # decode cascade knobs (see sparse_kv.sparse_decode_attention)
    top_k: int = 64
    npages: int | None = None
    prescreen_c0: int | None = None
    page_rows: int = 8
    backend: str = "jnp"
    _decode_jit: Any = dataclasses.field(default=None, repr=False,
                                         compare=False)

    def __post_init__(self):
        api = self.pipeline.gen_api
        if api is None or api.cfg.family != "dense":
            raise ValueError("RAGAgent needs a dense-family generator "
                             "(quantized-KV decode lives in models/dense)")
        if self.runtime.index is not self.pipeline.index:
            raise ValueError("runtime must serve the pipeline's index — "
                             "one runtime schedules retrieval AND decode")

    # -- decode plumbing ---------------------------------------------------

    def _decode_step(self):
        if self._decode_jit is None:
            from repro.models import dense
            cfg = self.pipeline.gen_api.cfg
            knobs = dict(top_k=self.top_k, npages=self.npages,
                         prescreen_c0=self.prescreen_c0,
                         backend=self.backend)
            self._decode_jit = jax.jit(
                lambda p, c, t: dense.decode_step_quant(p, c, t, cfg,
                                                        **knobs))
        return self._decode_jit

    def _total_len(self, prompt_len: int, max_new: int) -> int:
        total = prompt_len + max_new
        if self.npages is not None:
            total = -(-total // self.page_rows) * self.page_rows
        return total

    # -- the turn ----------------------------------------------------------

    def turn(self, tenant_ids, query_tokens: jax.Array, *,
             max_new: int = 16, temperature: float = 0.0, key=None,
             now: float | None = None) -> AgentTurnReport:
        """Retrieve through the runtime, generate with the KV cascade,
        charge both against one registry. Returns an AgentTurnReport."""
        from repro.models import dense

        pipe = self.pipeline
        api, cfg = pipe.gen_api, pipe.gen_api.cfg
        # 1. retrieval: per-request admission through the runtime (the
        # scheduler batches the tenants into one segment-masked launch).
        q_emb = pipe._embed(jnp.asarray(query_tokens))
        q_codes, _ = quantize_int8(q_emb, per_vector=True)
        codes = np.asarray(q_codes)
        handles = [self.runtime.submit(int(t), codes[i], now=now)
                   for i, t in enumerate(np.asarray(tenant_ids))]
        self.runtime.flush(now=now)
        ids = np.stack([np.asarray(h.result().indices) for h in handles])
        retrieval_cost = self.runtime.energy_ledger(q_emb.shape[-1])
        # 2. prompt assembly (invalid hits contribute zero tokens).
        b, k = ids.shape
        flat = ids.reshape(-1)
        docs = np.where((flat >= 0)[:, None],
                        pipe.doc_tokens[np.maximum(flat, 0)], 0)
        docs = jnp.asarray(docs.reshape(b, k * pipe.doc_tokens.shape[1]))
        prompt = jnp.concatenate([docs, jnp.asarray(query_tokens)], axis=1)
        prompt = jnp.clip(prompt, 0, cfg.vocab_size - 1)
        # 3. prefill (cached jit — no per-turn recompiles), then convert
        # the bf16 cache to the nibble-planar QuantCache once.
        total = self._total_len(prompt.shape[1], max_new)
        prefill_fn, _ = jitted_fns(api)
        logits, cache = prefill_fn(self.pipeline.gen_params,
                                   {"tokens": prompt}, max_len=total)
        qcache = dense.quantize_cache(
            cache, page_rows=self.page_rows if self.npages else None)
        # 4. decode loop: every step's attention is the engine cascade.
        key = key if key is not None else jax.random.PRNGKey(0)
        step = self._decode_step()
        tok = sample_tokens(logits[:, -1:], key, temperature)
        outs = [tok]
        for i in range(max_new - 1):
            key = jax.random.fold_in(key, i)
            logits, qcache = step(pipe.gen_params, qcache, tok)
            tok = sample_tokens(logits, key, temperature)
            outs.append(tok)
        toks = jnp.concatenate(outs, axis=1)
        # 5. decode accounting: one kv_plan prices the run (the stage
        # geometry is fixed at the cache's allocated length), charged
        # through the SAME runtime as the retrieval launch.
        kv_cfg = engine_mod.KVCascadeConfig(
            top_k=self.top_k, npages=self.npages, page_rows=self.page_rows,
            prescreen_c0=self.prescreen_c0, backend=self.backend)
        plan = engine_mod.kv_plan(kv_cfg, batch=b,
                                  kv_heads=cfg.num_kv_heads,
                                  q_heads=cfg.num_heads, seq_len=total,
                                  head_dim=cfg.hd, layers=cfg.num_layers)
        decode_cost = self.runtime.account_decode(plan, dim=cfg.hd,
                                                  tokens=max_new)
        from repro.serve import sparse_kv
        dense_bytes = (b * cfg.num_layers * cfg.num_kv_heads
                       * sparse_kv.dense_bytes_per_step(total, cfg.hd))
        return AgentTurnReport(
            tokens=toks, retrieved=ids, retrieval_cost=retrieval_cost,
            decode_cost=decode_cost, decode_plan=plan,
            uj_per_query=retrieval_cost.total_uj,
            uj_per_token=decode_cost.total_uj,
            decode_bytes_per_token=sum(s.bytes_hbm for s in plan.stages),
            dense_bytes_per_token=dense_bytes)


@dataclasses.dataclass
class MultiTenantRAGPipeline:
    """Streaming RAG serving many per-user corpora from ONE shared arena.

    No offline build: tenants ingest documents online (encode -> fixed-scale
    INT8 quantize -> pack into free arena slots, O(rows) per ingest) and a
    mixed batch of tenants' queries runs as one vmapped segment-masked
    two-stage retrieval. Document tokens live in a host-side slot-addressed
    store kept in lockstep with the arena (including across compactions).

    The retrieval entry points are top-level jitted functions, so repeat
    calls at the same batch shape reuse the compiled executable — no
    per-request retrace.
    """

    emb_cfg: ModelConfig
    emb_params: Any
    gen_api: ModelApi | None
    gen_params: Any
    index: MultiTenantIndex
    doc_tokens: np.ndarray                 # (capacity, doc_len) int32
    _encode: Any = dataclasses.field(default=None, repr=False, compare=False)

    @classmethod
    def create(cls, emb_cfg, emb_params, gen_api, gen_params, *,
               capacity: int, doc_len: int,
               retrieval_cfg: RetrievalConfig | None = None,
               clusters=None):
        """clusters: optional repro.core.clustering.ClusterParams —
        enables the cluster-pruned cascade for this pipeline's index."""
        index = MultiTenantIndex(capacity, emb_cfg.pooled_dim,
                                 retrieval_cfg or RetrievalConfig(),
                                 clusters=clusters)
        return cls(emb_cfg=emb_cfg, emb_params=emb_params, gen_api=gen_api,
                   gen_params=gen_params, index=index,
                   doc_tokens=np.zeros((capacity, doc_len), np.int32))

    def _embed(self, tokens: jax.Array) -> jax.Array:
        if self._encode is None:
            cfg = self.emb_cfg
            self._encode = jax.jit(lambda p, t: emb_mod.encode(p, t, cfg))
        return self._encode(self.emb_params, tokens)

    # -- online corpus mutation -------------------------------------------

    def ingest(self, tenant_id: int, doc_tokens) -> np.ndarray:
        """Add (B, doc_len) docs to one tenant's corpus; returns slot ids."""
        doc_tokens = np.asarray(doc_tokens, np.int32)
        embs = self._embed(jnp.asarray(doc_tokens))
        slots = self.index.ingest(tenant_id, embs)
        self.doc_tokens[slots] = doc_tokens
        return slots

    def delete(self, tenant_id: int, slots) -> None:
        self.index.delete(tenant_id, slots)

    def compact(self) -> np.ndarray:
        """Reclaim tombstones; remaps the token store with the arena."""
        mapping = self.index.compact()
        moved = np.nonzero(mapping >= 0)[0]
        new_tokens = np.zeros_like(self.doc_tokens)
        new_tokens[mapping[moved]] = self.doc_tokens[moved]
        self.doc_tokens = new_tokens
        return mapping

    # -- query -------------------------------------------------------------

    def retrieve(self, tenant_ids, query_tokens: jax.Array):
        """(B,) tenant ids + (B, L) query tokens -> (results, energy ledger).

        Queries of DIFFERENT tenants batch together: one embedder forward,
        one segment-masked retrieval launch over the shared arena."""
        q_emb = self._embed(jnp.asarray(query_tokens))
        # Per-vector query quantization: only the DOC rows must share the
        # arena's fixed scale; a query-side scale rescales all of one
        # query's scores equally and cannot change its ranking.
        q_codes, _ = quantize_int8(q_emb, per_vector=True)
        res = self.index.retrieve(q_codes, tenant_ids)
        # Account what the engine's schedule ACTUALLY streams: the
        # launch's per-stage SchedulePlan ledger (windowed lanes charge
        # their window, cluster-pruned lanes their probed blocks, the
        # centroid plane its K rows) instead of re-deriving traffic from
        # a full-arena scan and the default-candidates heuristic.
        plan = self.index.last_plan
        if plan is not None:
            ledger = energy.cost_cascade(plan.stages, q_emb.shape[-1],
                                         batch=plan.batch)
        else:
            ledger = energy.cost_hierarchical(self.index.capacity,
                                              q_emb.shape[-1])
        return res, ledger

    def answer(self, tenant_ids, query_tokens: jax.Array, *,
               max_new: int = 32, temperature: float = 0.0, key=None):
        """Retrieve per-tenant context and generate, one mixed batch.

        Invalid hits (tenant owns fewer than k live docs) contribute
        all-zero context tokens. Returns (tokens, slot ids, ledger)."""
        if self.gen_api is None:
            raise ValueError("pipeline was created without a generator")
        res, ledger = self.retrieve(tenant_ids, query_tokens)
        ids = np.asarray(res.indices)                     # (B, k)
        b, k = ids.shape
        flat = ids.reshape(-1)
        docs = np.where((flat >= 0)[:, None],
                        self.doc_tokens[np.maximum(flat, 0)], 0)
        docs = jnp.asarray(docs.reshape(b, k * self.doc_tokens.shape[1]))
        prompt = jnp.concatenate([docs, jnp.asarray(query_tokens)], axis=1)
        vocab = self.gen_api.cfg.vocab_size
        prompt = jnp.clip(prompt, 0, vocab - 1)
        out, _ = generate(self.gen_api, self.gen_params, {"tokens": prompt},
                          max_new=max_new, temperature=temperature, key=key)
        return out, ids, ledger
