"""End-to-end RAG pipeline (Fig. 1 of the paper).

offline:  doc tokens --MiniLM embedder--> float embeddings --INT8 quant-->
          nibble-planar DB (optionally sharded over a mesh)
online:   query tokens -> query embedding -> INT8 codes
          -> TWO-STAGE HIERARCHICAL RETRIEVAL (the paper's core)
          -> augmented prompt = [retrieved doc tokens; query tokens]
          -> generator prefill + decode

The pipeline also reports the retrieval energy ledger per query batch via
the paper-calibrated cost model (core.energy), so serving logs expose the
same numbers the paper's Table II does.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import (BitPlanarDB, RetrievalConfig, batched_retrieve,
                        build_database, energy, quantize_int8)
from repro.core.index import ShardedIndex
from repro.models import embedder as emb_mod
from repro.models.common import ModelConfig
from repro.models.registry import ModelApi
from repro.serve.sampler import generate


@dataclasses.dataclass
class RAGPipeline:
    emb_cfg: ModelConfig
    emb_params: Any
    gen_api: ModelApi
    gen_params: Any
    retrieval_cfg: RetrievalConfig
    doc_tokens: jax.Array                  # (N, doc_len) int32
    db: BitPlanarDB | None = None          # single-host DB
    index: ShardedIndex | None = None      # pod-sharded DB (preferred)

    @classmethod
    def build(cls, emb_cfg, emb_params, gen_api, gen_params, doc_tokens,
              retrieval_cfg: RetrievalConfig | None = None, mesh=None,
              encode_batch: int = 64):
        """Offline phase: embed + quantize the document corpus."""
        retrieval_cfg = retrieval_cfg or RetrievalConfig()
        n = doc_tokens.shape[0]
        chunks = []
        enc = jax.jit(lambda p, t: emb_mod.encode(p, t, emb_cfg))
        for i in range(0, n, encode_batch):
            chunks.append(enc(emb_params, doc_tokens[i:i + encode_batch]))
        embs = jnp.concatenate(chunks, axis=0)
        if mesh is not None:
            index = ShardedIndex.build(embs, mesh)
            db = None
        else:
            index = None
            db = BitPlanarDB.from_quantized(build_database(embs))
        return cls(emb_cfg=emb_cfg, emb_params=emb_params, gen_api=gen_api,
                   gen_params=gen_params, retrieval_cfg=retrieval_cfg,
                   doc_tokens=doc_tokens, db=db, index=index)

    # -- retrieval ---------------------------------------------------------

    def retrieve(self, query_tokens: jax.Array):
        """query_tokens (B, L) -> (indices (B, k), energy ledger)."""
        q_emb = emb_mod.encode(self.emb_params, query_tokens, self.emb_cfg)
        q_codes, _ = quantize_int8(q_emb, per_vector=True)
        if self.index is not None:
            fn = self.index.retrieve_fn(self.retrieval_cfg)
            res = fn(q_codes)
            n_docs = self.index.n_global
        else:
            res = batched_retrieve(q_codes, self.db, self.retrieval_cfg)
            n_docs = self.db.num_docs
        dim = q_emb.shape[-1]
        ledger = energy.cost_hierarchical(n_docs, dim)
        return res, ledger

    # -- generation --------------------------------------------------------

    def answer(self, query_tokens: jax.Array, *, max_new: int = 32,
               temperature: float = 0.0, key=None):
        """Full RAG answer: retrieve, augment, generate.

        Returns (generated tokens (B, max_new), retrieved ids (B, k),
        energy ledger for the retrieval stage)."""
        res, ledger = self.retrieve(query_tokens)
        ids = res.indices                                 # (B, k)
        b, k = ids.shape
        docs = jnp.take(self.doc_tokens, ids.reshape(-1), axis=0)
        docs = docs.reshape(b, k * self.doc_tokens.shape[1])
        prompt = jnp.concatenate([docs, query_tokens], axis=1)
        vocab = self.gen_api.cfg.vocab_size
        prompt = jnp.clip(prompt, 0, vocab - 1)
        out, _ = generate(self.gen_api, self.gen_params, {"tokens": prompt},
                          max_new=max_new, temperature=temperature, key=key)
        return out, ids, ledger
