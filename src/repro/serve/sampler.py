"""Batched autoregressive sampling loop over any ModelApi."""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.models.registry import ModelApi


def sample_tokens(logits: jax.Array, key, temperature: float = 0.0
                  ) -> jax.Array:
    """logits (B, 1, V) -> next tokens (B, 1)."""
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    scaled = logits[:, -1].astype(jnp.float32) / temperature
    return jax.random.categorical(key, scaled)[:, None].astype(jnp.int32)


def generate(api: ModelApi, params: Any, batch: dict, *, max_new: int,
             max_len: int | None = None, temperature: float = 0.0,
             key=None, jit: bool = True):
    """Prefill the prompt batch, then decode `max_new` tokens.

    Returns (generated (B, max_new) int32, final cache). Lockstep batched
    decoding (continuous batching handled one level up in rag.serve_loop).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    prompt_len = batch["tokens"].shape[1]
    total = max_len or (prompt_len + max_new)

    prefill = jax.jit(api.prefill, static_argnames=("max_len",)) if jit \
        else api.prefill
    decode = jax.jit(api.decode_step) if jit else api.decode_step

    logits, cache = prefill(params, batch, max_len=total)
    tok = sample_tokens(logits[:, -1:], key, temperature)
    outs = [tok]
    for i in range(max_new - 1):
        key = jax.random.fold_in(key, i)
        logits, cache = decode(params, cache, tok)
        tok = sample_tokens(logits, key, temperature)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1), cache
