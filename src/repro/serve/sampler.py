"""Batched autoregressive sampling loop over any ModelApi."""
from __future__ import annotations

import weakref
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.registry import ModelApi

# Jitted (prefill, decode_step) per ModelApi instance. Keyed on id() with
# a weakref staleness guard (ModelApi instances may not be hashable /
# weak-hashable as dict keys across registries): if a new object reuses a
# dead id, the guard misses and we re-wrap. Without this cache every
# generate() call wrapped api.prefill/api.decode_step in a FRESH jax.jit,
# whose per-wrapper trace cache made every request recompile the model.
_JIT_CACHE: dict[int, tuple] = {}


def jitted_fns(api: ModelApi):
    """The per-api cached (jitted_prefill, jitted_decode_step) pair."""
    ent = _JIT_CACHE.get(id(api))
    if ent is not None and ent[0]() is api:
        return ent[1]
    fns = (jax.jit(api.prefill, static_argnames=("max_len",)),
           jax.jit(api.decode_step))
    try:
        ref = weakref.ref(api)
    except TypeError:           # non-weakrefable api: pin it alive instead
        ref = (lambda a: (lambda: a))(api)
    _JIT_CACHE[id(api)] = (ref, fns)
    return fns


def sample_tokens(logits: jax.Array, key, temperature: float = 0.0
                  ) -> jax.Array:
    """logits (B, 1, V) -> next tokens (B, 1)."""
    if temperature <= 0.0:
        return jnp.argmax(logits[:, -1], axis=-1)[:, None].astype(jnp.int32)
    scaled = logits[:, -1].astype(jnp.float32) / temperature
    return jax.random.categorical(key, scaled)[:, None].astype(jnp.int32)


def generate(api: ModelApi, params: Any, batch: dict, *, max_new: int,
             max_len: int | None = None, temperature: float = 0.0,
             key=None, jit: bool = True):
    """Prefill the prompt batch, then decode `max_new` tokens.

    Returns (generated (B, max_new) int32, final cache). Lockstep batched
    decoding (continuous batching handled one level up in rag.serve_loop).
    """
    key = key if key is not None else jax.random.PRNGKey(0)
    prompt_len = batch["tokens"].shape[1]
    total = max_len or (prompt_len + max_new)

    if jit:
        prefill, decode = jitted_fns(api)
    else:
        prefill, decode = api.prefill, api.decode_step

    logits, cache = prefill(params, batch, max_len=total)
    tok = sample_tokens(logits[:, -1:], key, temperature)
    outs = [tok]
    for i in range(max_new - 1):
        key = jax.random.fold_in(key, i)
        logits, cache = decode(params, cache, tok)
        tok = sample_tokens(logits, key, temperature)
        outs.append(tok)
    return jnp.concatenate(outs, axis=1), cache
