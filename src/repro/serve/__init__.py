from repro.serve.sampler import generate, jitted_fns, sample_tokens
from repro.serve.rag import (AgentTurnReport, MultiTenantRAGPipeline,
                             RAGAgent, RAGPipeline)
from repro.serve.runtime import (HotClusterCache, RequestHandle,
                                 RuntimeConfig, ServingRuntime)
from repro.serve.sharded import (ShardedHandle, ShardedRuntimeConfig,
                                 ShardedServingRuntime)
from repro.serve import sparse_kv
