from repro.serve.sampler import generate, sample_tokens
from repro.serve.rag import MultiTenantRAGPipeline, RAGPipeline
from repro.serve.runtime import (HotClusterCache, RequestHandle,
                                 RuntimeConfig, ServingRuntime)
from repro.serve.sharded import (ShardedHandle, ShardedRuntimeConfig,
                                 ShardedServingRuntime)
from repro.serve import sparse_kv
