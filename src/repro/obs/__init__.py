"""Unified observability layer: metrics registry, tracing, exporters.

The serving stack publishes its exact analytic ledgers (stage bytes,
cache hits, µJ/query) and request lifecycles here. Host-side only —
never inside jitted code — and zero-cost when disabled via
`NULL_REGISTRY`/`NULL_TRACER`. See repro.obs.metrics / .tracing /
.export for the pieces, and the README's "Observability" section for
the architecture and overhead contract.
"""
from repro.obs.metrics import (Counter, Gauge, Histogram, LabeledRegistry,
                               MetricsRegistry, NullRegistry, NULL_REGISTRY)
from repro.obs.tracing import (NullTracer, NULL_TRACER, TraceEvent, Tracer)
from repro.obs.export import (chrome_trace, metrics_jsonl_records,
                              parse_prometheus, prometheus_text,
                              trace_jsonl_records, write_chrome_trace,
                              write_jsonl)

__all__ = [
    "Counter", "Gauge", "Histogram", "LabeledRegistry", "MetricsRegistry",
    "NullRegistry",
    "NULL_REGISTRY", "NullTracer", "NULL_TRACER", "TraceEvent", "Tracer",
    "chrome_trace", "metrics_jsonl_records", "parse_prometheus",
    "prometheus_text", "trace_jsonl_records", "write_chrome_trace",
    "write_jsonl",
]
