"""Span-based request-lifecycle tracer with an injectable clock.

The serving runtime's whole control flow is already driven by an
injectable clock (`ServingRuntime.submit(now)/poll(now)`), which is what
makes its test suite deterministic under simulated time. The tracer
follows the same convention: every recording call takes an optional
``now`` and only falls back to the wall clock when the caller doesn't
provide one — so a simulated-clock serving run produces a bit-identical
trace every time.

Two span styles:

  * ``with tracer.span("flush", now=...):`` — a synchronous phase; emits
    one COMPLETE event (begin + duration) when the block exits.
  * ``tracer.begin(name, key, now)`` / ``tracer.end(key, now)`` — an
    ASYNC lifecycle that outlives any one call frame (a request between
    submit and resolve). Keys must be unique among open spans: a double
    begin or an end without a begin raises immediately instead of
    silently producing an unbalanced trace.

Events are plain host-side records (`TraceEvent`); exporters in
repro.obs.export render them as JSON-lines or Chrome ``trace_event``
JSON (openable in Perfetto / chrome://tracing). Like the metrics
registry, tracing is host-side only — never inside jitted code — and
`NullTracer` (`NULL_TRACER`) makes every call a no-op when disabled.
"""
from __future__ import annotations

import contextlib
import dataclasses
import time


@dataclasses.dataclass
class TraceEvent:
    """One trace record.

    ph follows Chrome trace_event phases: "B"/"E" (async begin/end),
    "X" (complete, with `dur`), "i" (instant). `ts`/`dur` are SECONDS in
    whatever clock produced them (exporters scale to µs)."""

    name: str
    ph: str
    ts: float
    tid: int | str = 0
    dur: float | None = None
    attrs: dict = dataclasses.field(default_factory=dict)


class Tracer:
    """Append-only host-side event recorder."""

    enabled = True

    def __init__(self, clock=time.monotonic):
        self.clock = clock
        self.events: list[TraceEvent] = []
        self._open: dict[object, TraceEvent] = {}

    def _now(self, now: float | None) -> float:
        return self.clock() if now is None else now

    def __len__(self) -> int:
        return len(self.events)

    # -- recording --------------------------------------------------------

    def instant(self, name: str, *, now: float | None = None,
                tid: int | str = 0, **attrs) -> None:
        self.events.append(TraceEvent(name=name, ph="i", ts=self._now(now),
                                      tid=tid, attrs=attrs))

    def begin(self, name: str, key, *, now: float | None = None,
              tid: int | str = 0, **attrs) -> None:
        """Open an async span identified by `key` (e.g. a request id)."""
        if key in self._open:
            raise ValueError(f"span key {key!r} already open "
                             f"({self._open[key].name})")
        ev = TraceEvent(name=name, ph="B", ts=self._now(now), tid=tid,
                        attrs=attrs)
        self._open[key] = ev
        self.events.append(ev)

    def end(self, key, *, now: float | None = None, **attrs) -> None:
        """Close the async span opened under `key`."""
        opened = self._open.pop(key, None)
        if opened is None:
            raise KeyError(f"end() for span key {key!r} that is not open")
        self.events.append(TraceEvent(name=opened.name, ph="E",
                                      ts=self._now(now), tid=opened.tid,
                                      attrs=attrs))

    @contextlib.contextmanager
    def span(self, name: str, *, now: float | None = None,
             tid: int | str = 0, **attrs):
        """Synchronous phase: one complete ("X") event on exit.

        With an explicit `now` the duration is 0 in simulated time
        (deterministic); without one, start/end are read from the
        tracer's clock."""
        t0 = self._now(now)
        try:
            yield self
        finally:
            t1 = t0 if now is not None else self._now(None)
            self.events.append(TraceEvent(name=name, ph="X", ts=t0,
                                          tid=tid, dur=t1 - t0,
                                          attrs=attrs))

    # -- introspection ----------------------------------------------------

    def open_spans(self) -> list:
        """Keys of spans begun but not yet ended (a finished serving run
        must report none — the trace-completeness property)."""
        return list(self._open)

    def spans(self, name: str | None = None) -> list[TraceEvent]:
        """Events, optionally filtered by name."""
        if name is None:
            return list(self.events)
        return [e for e in self.events if e.name == name]

    def clear(self) -> None:
        self.events.clear()
        self._open.clear()


class NullTracer:
    """Tracing switched off: every call a no-op, `span` an empty context."""

    enabled = False
    events: list = []

    def instant(self, name, *, now=None, tid=0, **attrs):
        pass

    def begin(self, name, key, *, now=None, tid=0, **attrs):
        pass

    def end(self, key, *, now=None, **attrs):
        pass

    @contextlib.contextmanager
    def span(self, name, *, now=None, tid=0, **attrs):
        yield self

    def open_spans(self):
        return []

    def spans(self, name=None):
        return []

    def clear(self):
        pass

    def __len__(self):
        return 0


NULL_TRACER = NullTracer()
