"""Exporters: Prometheus text format, JSON-lines, Chrome trace_event.

Three render targets for one run's registry + tracer:

  * `prometheus_text(registry)` — the Prometheus text exposition format
    (counters/gauges verbatim, histograms as cumulative ``_bucket{le=}``
    series plus ``_sum``/``_count``), scrape-ready. A minimal validating
    `parse_prometheus` lives here too so CI can assert the export stays
    well-formed without a prometheus client dependency.
  * `write_jsonl(path, registry, tracer)` — one JSON object per line:
    every metric as a ``{"type": "metric", ...}`` record, every trace
    event as ``{"type": "event", ...}`` — the grep/jq-friendly event
    log.
  * `chrome_trace(tracer)` / `write_chrome_trace(path, tracer)` — the
    Chrome ``trace_event`` JSON array format. Open the file in Perfetto
    (https://ui.perfetto.dev) or chrome://tracing to see every request's
    submit→resolve span laid out on its tenant's track.

All exporters are read-only over the registry/tracer state and safe to
call mid-run (a snapshot of the moment they run).
"""
from __future__ import annotations

import json
import re


def _escape_label(v) -> str:
    return str(v).replace("\\", r"\\").replace('"', r'\"').replace(
        "\n", r"\n")


_NAME_RE = re.compile(r"[a-zA-Z_:][a-zA-Z0-9_:]*")
_SANITIZE_RE = re.compile(r"[^a-zA-Z0-9_:]")


def _prom_name(name: str) -> str:
    name = _SANITIZE_RE.sub("_", name)
    if not name or not _NAME_RE.fullmatch(name):
        name = "_" + name
    return name


def _prom_labels(labels, extra=()) -> str:
    items = list(labels) + list(extra)
    if not items:
        return ""
    inner = ",".join(f'{_prom_name(str(k))}="{_escape_label(v)}"'
                     for k, v in items)
    return "{" + inner + "}"


def _fmt(v: float) -> str:
    if v == float("inf"):
        return "+Inf"
    f = float(v)
    return repr(int(f)) if f.is_integer() else repr(f)


def prometheus_text(registry) -> str:
    """Render a registry in the Prometheus text exposition format."""
    by_name: dict[tuple, list] = {}
    for kind, m in registry.metrics():
        by_name.setdefault((kind, _prom_name(m.name)), []).append(m)
    lines = []
    for (kind, name), metrics in by_name.items():
        lines.append(f"# TYPE {name} {kind}")
        for m in metrics:
            if kind in ("counter", "gauge"):
                lines.append(f"{name}{_prom_labels(m.labels)} "
                             f"{_fmt(m.value)}")
                continue
            # histogram: cumulative buckets at each occupied upper edge
            # (+ the zero bucket's edge) then +Inf, _sum, _count.
            cum = m.zero_count
            if m.zero_count:
                lines.append(f"{name}_bucket"
                             f"{_prom_labels(m.labels, [('le', '0')])}"
                             f" {cum}")
            for i in sorted(m.buckets):
                cum += m.buckets[i]
                le = _fmt(m.bucket_edge(i))
                lines.append(f"{name}_bucket"
                             f"{_prom_labels(m.labels, [('le', le)])}"
                             f" {cum}")
            lines.append(f"{name}_bucket"
                         f"{_prom_labels(m.labels, [('le', '+Inf')])}"
                         f" {m.count}")
            lines.append(f"{name}_sum{_prom_labels(m.labels)} "
                         f"{_fmt(m.total)}")
            lines.append(f"{name}_count{_prom_labels(m.labels)} {m.count}")
    return "\n".join(lines) + "\n"


_SAMPLE_RE = re.compile(
    r"^(?P<name>[a-zA-Z_:][a-zA-Z0-9_:]*)"
    r"(?:\{(?P<labels>[^}]*)\})?\s+(?P<value>\S+)$")
_LABEL_RE = re.compile(r'([a-zA-Z_:][a-zA-Z0-9_:]*)="((?:[^"\\]|\\.)*)"')


def parse_prometheus(text: str) -> dict[str, list]:
    """Minimal validating parser for the text format this module emits.

    Returns {metric name -> [(labels dict, float value), ...]}. Raises
    ValueError on any malformed line — the CI smoke step runs the export
    through this so a formatting regression fails the build instead of
    breaking a scrape endpoint later."""
    out: dict[str, list] = {}
    for lineno, line in enumerate(text.splitlines(), 1):
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"malformed sample on line {lineno}: {line!r}")
        raw = m.group("labels")
        labels = {}
        if raw:
            consumed = _LABEL_RE.findall(raw)
            rebuilt = ",".join(f'{k}="{v}"' for k, v in consumed)
            if rebuilt != raw:
                raise ValueError(
                    f"malformed labels on line {lineno}: {raw!r}")
            labels = dict(consumed)
        val = m.group("value")
        value = float("inf") if val == "+Inf" else float(val)
        out.setdefault(m.group("name"), []).append((labels, value))
    return out


def metrics_jsonl_records(registry) -> list[dict]:
    records = []
    for kind, m in registry.metrics():
        rec = {"type": "metric", "kind": kind, "name": m.name,
               "labels": dict(m.labels)}
        if kind == "histogram":
            rec.update(m.summary())
        else:
            rec["value"] = m.value
        records.append(rec)
    return records


def trace_jsonl_records(tracer) -> list[dict]:
    records = []
    for e in tracer.spans():
        rec = {"type": "event", "name": e.name, "ph": e.ph, "ts": e.ts,
               "tid": e.tid}
        if e.dur is not None:
            rec["dur"] = e.dur
        if e.attrs:
            rec["attrs"] = e.attrs
        records.append(rec)
    return records


def write_jsonl(path: str, registry=None, tracer=None) -> int:
    """Write the metrics snapshot and/or trace events as JSON lines.

    Returns the number of records written."""
    records = []
    if registry is not None:
        records += metrics_jsonl_records(registry)
    if tracer is not None:
        records += trace_jsonl_records(tracer)
    with open(path, "w") as f:
        for rec in records:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
    return len(records)


def chrome_trace(tracer, *, pid: int = 0) -> dict:
    """Render a tracer as Chrome trace_event JSON (the object form).

    ts/dur are converted to MICROSECONDS per the format spec. Async
    B/E span pairs are emitted as duration begin/end events on
    ``tid = event.tid`` (the runtime uses the tenant id), so Perfetto
    lays each tenant's requests out on its own track."""
    events = []
    for e in tracer.spans():
        rec = {"name": e.name, "ph": e.ph, "ts": e.ts * 1e6, "pid": pid,
               "tid": e.tid, "args": dict(e.attrs)}
        if e.ph == "X":
            rec["dur"] = (e.dur or 0.0) * 1e6
        if e.ph == "i":
            rec["s"] = "t"
        events.append(rec)
    return {"traceEvents": events, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, tracer, *, pid: int = 0) -> int:
    """Write `chrome_trace` JSON to `path`; returns the event count."""
    doc = chrome_trace(tracer, pid=pid)
    with open(path, "w") as f:
        json.dump(doc, f)
    return len(doc["traceEvents"])
