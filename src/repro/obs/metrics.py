"""Metrics substrate: counters, gauges, log-bucketed histograms, registry.

The repo's observables are already exact analytic ledgers (SchedulePlan
stage bytes, cache hit/miss counts, energy.cost_cascade pJ) but each
lives in its own ad-hoc dict with no time dimension and no export. This
module is the common substrate they publish into:

  * `Counter` / `Gauge` — monotone totals and last-value samples.
  * `Histogram`        — LOG-BUCKETED distribution with exact counts:
    bucket edges are ``2 ** (i / buckets_per_doubling)``, so any
    reported percentile is the geometric midpoint of the bucket holding
    the exact order statistic and is within a documented RELATIVE error
    bound of it (``rel_error_bound = 2 ** (1 / (2*bpd)) - 1``, ~2.2% at
    the default 16 buckets per doubling) regardless of the value range —
    no a-priori min/max, storage is a sparse dict keyed by bucket index.
  * `MetricsRegistry`  — get-or-create by (name, labels); callers on hot
    paths hold the returned metric object so a publish is one int add.
  * `NullRegistry`     — the disabled layer: same API, every operation a
    no-op, `enabled` False so instrumentation blocks can skip derived
    work (plan publishing, energy pricing) entirely. Serving code paths
    default to `NULL_REGISTRY`, making observability strictly opt-in.

Overhead contract: everything here is HOST-side python on either side of
a launch — metrics never appear inside jitted code, so enabling them can
never change a trace shape or force a recompile (pinned by the
serving-bench parity gate and tests/test_serve_runtime.py).

Registries MERGE: ``a.merge(b)`` accumulates counters, bucket counts and
gauge last-writes, so per-worker registries can be combined into one
fleet view; percentiles depend only on integer bucket counts, so merging
is order-independent (associative/commutative) for every reported
quantile. Single-threaded by design (the serving loop is host-side
python); no locks are taken.
"""
from __future__ import annotations

import math


def _label_key(labels: dict) -> tuple:
    return tuple(sorted(labels.items()))


def _format_name(name: str, labels: tuple) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={v}" for k, v in labels)
    return f"{name}{{{inner}}}"


class Counter:
    """A monotonically increasing total (resettable for windowed reads)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple = ()):
        self.name = name
        self.labels = labels
        self.value = 0

    def inc(self, n: int | float = 1) -> None:
        if n < 0:
            raise ValueError(f"counter {self.name} cannot decrease (n={n})")
        self.value += n

    def reset(self) -> None:
        self.value = 0

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"Counter({_format_name(self.name, self.labels)}={self.value})"


class Gauge:
    """A last-value sample (queue depth, hit rate, bytes resident)."""

    __slots__ = ("name", "labels", "value")

    def __init__(self, name: str, labels: tuple = ()):
        self.name = name
        self.labels = labels
        self.value = 0.0

    def set(self, v: float) -> None:
        self.value = v

    def reset(self) -> None:
        self.value = 0.0

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"Gauge({_format_name(self.name, self.labels)}={self.value})"


class Histogram:
    """Log-bucketed distribution with exact counts and bounded-error
    percentiles.

    Bucket i covers ``[2**(i/bpd), 2**((i+1)/bpd))`` with representative
    value ``2**((i+0.5)/bpd)`` (the geometric midpoint), where bpd =
    `buckets_per_doubling`. `percentile(q)` locates the bucket holding
    the exact rank-``ceil(q/100 * count)`` order statistic by cumulative
    count and returns its representative, so the reported value is
    within `rel_error_bound` of the exact order statistic for any value
    distribution. Non-positive observations land in a dedicated zero
    bucket (reported exactly as 0.0) so simulated-clock durations of
    zero stay exact.
    """

    __slots__ = ("name", "labels", "buckets_per_doubling", "buckets",
                 "count", "total", "zero_count", "min", "max")

    def __init__(self, name: str, labels: tuple = (), *,
                 buckets_per_doubling: int = 16):
        if buckets_per_doubling < 1:
            raise ValueError("buckets_per_doubling must be >= 1")
        self.name = name
        self.labels = labels
        self.buckets_per_doubling = buckets_per_doubling
        self.buckets: dict[int, int] = {}
        self.count = 0
        self.total = 0.0
        self.zero_count = 0
        self.min = math.inf
        self.max = -math.inf

    @property
    def rel_error_bound(self) -> float:
        """Max relative error of any reported percentile vs the exact
        order statistic (geometric-midpoint representative of a
        ``2**(1/bpd)``-growth bucket)."""
        return 2.0 ** (1.0 / (2 * self.buckets_per_doubling)) - 1.0

    def observe(self, v: float, n: int = 1) -> None:
        """Record value `v`; `n` > 1 records it as n identical samples
        (one launch pricing a per-query cost for a batch of n)."""
        v = float(v)
        if math.isnan(v):
            raise ValueError(f"histogram {self.name}: NaN observation")
        if n < 1:
            raise ValueError(f"histogram {self.name}: n must be >= 1")
        self.count += n
        self.total += v * n
        self.min = min(self.min, v)
        self.max = max(self.max, v)
        if v <= 0.0:
            self.zero_count += n
            return
        i = math.floor(math.log2(v) * self.buckets_per_doubling)
        self.buckets[i] = self.buckets.get(i, 0) + n

    def bucket_edge(self, i: int) -> float:
        """Upper edge of bucket i (Prometheus `le` boundary)."""
        return 2.0 ** ((i + 1) / self.buckets_per_doubling)

    def bucket_rep(self, i: int) -> float:
        """Representative (geometric midpoint) of bucket i."""
        return 2.0 ** ((i + 0.5) / self.buckets_per_doubling)

    def percentile(self, q: float) -> float:
        """Bounded-relative-error estimate of the q-th percentile.

        Returns the representative of the bucket holding the exact
        rank-``max(1, ceil(q/100 * count))`` order statistic (NaN on an
        empty histogram)."""
        if not 0 <= q <= 100:
            raise ValueError(f"percentile q={q} outside [0, 100]")
        if self.count == 0:
            return math.nan
        rank = max(1, math.ceil(q / 100.0 * self.count))
        if rank <= self.zero_count:
            return 0.0
        cum = self.zero_count
        for i in sorted(self.buckets):
            cum += self.buckets[i]
            if cum >= rank:
                return self.bucket_rep(i)
        return self.bucket_rep(max(self.buckets))   # fp-rounding guard

    def percentiles(self, qs=(50, 95, 99)) -> dict[str, float]:
        return {f"p{q:g}": self.percentile(q) for q in qs}

    def merge(self, other: "Histogram") -> None:
        """Accumulate another histogram's counts into this one.

        Bucket counts are integers, so merge order can never change any
        reported percentile (associative + commutative)."""
        if other.buckets_per_doubling != self.buckets_per_doubling:
            raise ValueError("cannot merge histograms with different "
                             "bucket geometry")
        for i, c in other.buckets.items():
            self.buckets[i] = self.buckets.get(i, 0) + c
        self.count += other.count
        self.total += other.total
        self.zero_count += other.zero_count
        self.min = min(self.min, other.min)
        self.max = max(self.max, other.max)

    def reset(self) -> None:
        self.buckets.clear()
        self.count = 0
        self.total = 0.0
        self.zero_count = 0
        self.min = math.inf
        self.max = -math.inf

    def summary(self) -> dict:
        out = {"count": self.count, "sum": self.total}
        if self.count:
            out.update(min=self.min, max=self.max,
                       mean=self.total / self.count,
                       **self.percentiles())
        return out

    def __repr__(self):  # pragma: no cover - debugging aid
        return (f"Histogram({_format_name(self.name, self.labels)}, "
                f"count={self.count})")


class MetricsRegistry:
    """Get-or-create home for every metric, keyed (name, sorted labels).

    One registry per serving process (or per window — registries merge).
    Hot-path callers fetch their metric objects ONCE and hold them; the
    per-event cost is then a single int/float update with no dict
    lookup. `enabled` is True so instrumentation blocks that derive
    values (plan publishing, energy pricing) run; the `NullRegistry`
    counterpart turns the whole layer off.
    """

    enabled = True

    def __init__(self):
        self._metrics: dict[tuple, Counter | Gauge | Histogram] = {}

    def _get(self, kind: str, cls, name: str, labels: dict, **kw):
        key = (kind, name, _label_key(labels))
        m = self._metrics.get(key)
        if m is None:
            m = cls(name, key[2], **kw)
            self._metrics[key] = m
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get("counter", Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get("gauge", Gauge, name, labels)

    def histogram(self, name: str, *, buckets_per_doubling: int = 16,
                  **labels) -> Histogram:
        return self._get("histogram", Histogram, name, labels,
                         buckets_per_doubling=buckets_per_doubling)

    # -- convenience one-shots (cold paths; hot paths hold the object) ----

    def inc(self, name: str, n: int | float = 1, **labels) -> None:
        self.counter(name, **labels).inc(n)

    def set_gauge(self, name: str, v: float, **labels) -> None:
        self.gauge(name, **labels).set(v)

    def observe(self, name: str, v: float, **labels) -> None:
        self.histogram(name, **labels).observe(v)

    # -- introspection ----------------------------------------------------

    def metrics(self):
        """(kind, metric) pairs in insertion order."""
        return [(k[0], m) for k, m in self._metrics.items()]

    def get(self, kind: str, name: str, **labels):
        """The metric if it exists, else None (never creates)."""
        return self._metrics.get((kind, name, _label_key(labels)))

    def snapshot(self) -> dict:
        """Plain-data view of every metric (JSON-ready)."""
        out: dict[str, dict] = {"counters": {}, "gauges": {},
                                "histograms": {}}
        for (kind, name, labels), m in self._metrics.items():
            key = _format_name(name, labels)
            if kind == "counter":
                out["counters"][key] = m.value
            elif kind == "gauge":
                out["gauges"][key] = m.value
            else:
                out["histograms"][key] = m.summary()
        return out

    def merge(self, other: "MetricsRegistry") -> "MetricsRegistry":
        """Accumulate another registry into this one (see module doc:
        associative for counters and every histogram percentile; gauges
        take the other registry's last write). Returns self."""
        for key, m in other._metrics.items():
            kind, name, labels = key
            mine = self._metrics.get(key)
            if mine is None:
                kw = ({"buckets_per_doubling": m.buckets_per_doubling}
                      if kind == "histogram" else {})
                mine = type(m)(name, labels, **kw)
                self._metrics[key] = mine
            if kind == "counter":
                mine.inc(m.value)
            elif kind == "gauge":
                mine.set(m.value)
            else:
                mine.merge(m)
        return self

    def reset(self) -> None:
        for m in self._metrics.values():
            m.reset()

    def labeled(self, **labels) -> "LabeledRegistry":
        """A view of this registry that stamps `labels` onto every metric
        it creates (e.g. `registry.labeled(shard="3")`): per-shard serving
        runtimes instrument themselves normally and their series land
        side by side in ONE registry, distinguished by label."""
        return LabeledRegistry(self, labels)


class LabeledRegistry:
    """A label-injecting facade over a MetricsRegistry (same API).

    Caller-supplied labels win on collision, so a site can still
    sub-divide a labeled view's series."""

    enabled = True

    def __init__(self, parent, labels: dict):
        self._parent = parent
        self._labels = dict(labels)

    def _merged(self, labels: dict) -> dict:
        return {**self._labels, **labels}

    def counter(self, name: str, **labels) -> Counter:
        return self._parent.counter(name, **self._merged(labels))

    def gauge(self, name: str, **labels) -> Gauge:
        return self._parent.gauge(name, **self._merged(labels))

    def histogram(self, name: str, *, buckets_per_doubling: int = 16,
                  **labels) -> Histogram:
        return self._parent.histogram(
            name, buckets_per_doubling=buckets_per_doubling,
            **self._merged(labels))

    def inc(self, name: str, n: int | float = 1, **labels) -> None:
        self.counter(name, **labels).inc(n)

    def set_gauge(self, name: str, v: float, **labels) -> None:
        self.gauge(name, **labels).set(v)

    def observe(self, name: str, v: float, **labels) -> None:
        self.histogram(name, **labels).observe(v)

    def get(self, kind: str, name: str, **labels):
        return self._parent.get(kind, name, **self._merged(labels))

    def labeled(self, **labels) -> "LabeledRegistry":
        return LabeledRegistry(self._parent, self._merged(labels))

    def metrics(self):
        return self._parent.metrics()

    def snapshot(self) -> dict:
        return self._parent.snapshot()


class _NullMetric:
    """One no-op object behind every NullRegistry handle."""

    __slots__ = ()
    name = "null"
    labels = ()
    value = 0
    count = 0
    total = 0.0

    def inc(self, n=1):
        pass

    def set(self, v):
        pass

    def observe(self, v, n=1):
        pass

    def reset(self):
        pass

    def percentile(self, q):
        return math.nan

    def percentiles(self, qs=(50, 95, 99)):
        return {}

    def summary(self):
        return {"count": 0, "sum": 0.0}


_NULL_METRIC = _NullMetric()


class NullRegistry:
    """The observability layer switched OFF: same API, every call a no-op.

    `enabled` is False so instrumentation sites can skip work that only
    exists to be published (energy pricing, plan fan-out) — the serving
    hot path with a NullRegistry does exactly what it did before the
    observability layer existed, pinned by the bench's parity +
    zero-extra-compiles gate."""

    enabled = False

    def counter(self, name, **labels):
        return _NULL_METRIC

    def gauge(self, name, **labels):
        return _NULL_METRIC

    def histogram(self, name, *, buckets_per_doubling=16, **labels):
        return _NULL_METRIC

    def inc(self, name, n=1, **labels):
        pass

    def set_gauge(self, name, v, **labels):
        pass

    def observe(self, name, v, **labels):
        pass

    def metrics(self):
        return []

    def get(self, kind, name, **labels):
        return None

    def snapshot(self):
        return {"counters": {}, "gauges": {}, "histograms": {}}

    def merge(self, other):
        return self

    def reset(self):
        pass

    def labeled(self, **labels):
        """Labels on nothing are nothing: the null view is its own
        labeled view (keeps `registry.labeled(...)` unconditional)."""
        return self


NULL_REGISTRY = NullRegistry()
