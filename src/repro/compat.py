"""JAX version-compatibility shims.

The framework targets the modern JAX API surface (`jax.shard_map`,
`jax.set_mesh`, `jax.sharding.get_abstract_mesh`, shard_map's `check_vma`
flag), but edge deployments often pin older runtimes (the container ships
0.4.x). Each shim resolves to whatever the installed version provides and
degrades explicitly:

  * get_abstract_mesh() -> None where abstract-mesh tracking does not
    exist; callers treat that as "no ambient mesh" and skip GSPMD
    activation hints (a performance hint, never a correctness change).
  * shard_map() -> jax.experimental.shard_map with check_vma mapped onto
    the old check_rep flag.
  * set_mesh() -> the Mesh object's own context manager (legacy
    resource-env activation) when jax.set_mesh is absent.
"""
from __future__ import annotations

import jax


def get_abstract_mesh():
    """The ambient abstract mesh, or None when this JAX can't track one."""
    fn = getattr(jax.sharding, "get_abstract_mesh", None)
    return None if fn is None else fn()


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = False):
    """jax.shard_map across versions (old spelling: experimental, check_rep)."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _shard_map
    return _shard_map(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                      check_rep=check_vma)


def set_mesh(mesh):
    """Context manager activating `mesh` (jax.set_mesh where available)."""
    fn = getattr(jax, "set_mesh", None)
    return mesh if fn is None else fn(mesh)  # Mesh is a context manager


def _auto_axis_types(n: int) -> dict:
    """axis_types kwarg ({(}AxisType.Auto,)*n) where AxisType exists."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    return {} if axis_type is None else {
        "axis_types": (axis_type.Auto,) * n}


def make_mesh(axis_shapes, axis_names, *, devices=None):
    """jax.make_mesh with Auto axis types on versions that take them."""
    return jax.make_mesh(axis_shapes, axis_names, devices=devices,
                         **_auto_axis_types(len(axis_names)))


def mesh_from_device_array(devices, axis_names):
    """jax.sharding.Mesh(...) with Auto axis types where supported."""
    return jax.sharding.Mesh(devices, axis_names,
                             **_auto_axis_types(len(axis_names)))
