"""Elastic checkpoint-restart training driver.

The loop every large-scale trainer runs:

    while budget:
        try:   train until failure (heartbeats checked between steps)
        except/on-failure:
               drop dead workers -> rebuild a smaller mesh from survivors
               -> re-derive shardings -> RESTORE latest checkpoint with
               reshard-on-restore -> continue

The driver is device-count-agnostic: on this container it exercises the
full logic with simulated failures (FailureInjector raises at chosen
steps and shrinks the device set), which is exactly the path a real
deployment takes when jax.distributed reports a lost host. Mesh shapes
degrade along the data axis first (model parallelism is assumed intact
within surviving nodes — a failed TP group kills its whole replica).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Sequence

import jax
import numpy as np
from jax.sharding import Mesh

from repro.compat import mesh_from_device_array

from repro.checkpoint import CheckpointManager
from repro.runtime.fault import HeartbeatMonitor, StragglerDetector


class WorkerFailure(RuntimeError):
    def __init__(self, workers: Sequence[str]):
        super().__init__(f"workers failed: {list(workers)}")
        self.workers = list(workers)


@dataclasses.dataclass
class FailureInjector:
    """Deterministic failure schedule for tests/examples: step -> #devices
    to drop."""
    schedule: dict[int, int]

    def check(self, step: int, devices: list) -> list:
        drop = self.schedule.get(step, 0)
        if drop and len(devices) > drop:
            raise WorkerFailure([str(d.id) for d in devices[-drop:]])
        return devices


def build_mesh_from(devices: Sequence, model_parallel: int) -> Mesh:
    """Largest (data, model) mesh from the surviving devices."""
    n = len(devices)
    mp = model_parallel
    while mp > 1 and n % mp:
        mp //= 2
    dp = n // mp
    devs = np.asarray(devices[:dp * mp]).reshape(dp, mp)
    return mesh_from_device_array(devs, ("data", "model"))


@dataclasses.dataclass
class ElasticTrainer:
    """Wires train_step + checkpoint manager + failure handling together.

    make_state:  (mesh) -> (params, opt_state, step_fn, shardings) — called
                 on every (re)mesh;
    ckpt:        CheckpointManager;
    save_every:  checkpoint cadence in steps.
    """
    make_state: Callable[[Mesh], tuple[Any, Any, Callable, Any]]
    ckpt: CheckpointManager
    save_every: int = 10
    model_parallel: int = 1
    heartbeat_timeout_s: float = 30.0

    def run(self, batches, num_steps: int,
            injector: FailureInjector | None = None,
            devices: Sequence | None = None) -> dict:
        devices = list(devices if devices is not None else jax.devices())
        monitor = HeartbeatMonitor(timeout_s=self.heartbeat_timeout_s)
        stragglers = StragglerDetector()
        history: list[float] = []
        restarts = 0
        step = 0

        while step < num_steps:
            mesh = build_mesh_from(devices, self.model_parallel)
            params, opt_state, step_fn, shardings = self.make_state(mesh)
            latest = None
            try:
                (params, opt_state), latest = self.ckpt.restore_latest(
                    (params, opt_state), shardings)
                step = latest
                # Steps latest..failure-1 are about to re-run; their
                # pre-failure losses would otherwise stay as duplicates
                # (history[i] is step i's loss, appended before step += 1).
                del history[latest:]
            except FileNotFoundError:
                pass
            # Monitor exactly the mesh's devices: build_mesh_from takes
            # devices[:dp*mp], and heartbeats/step-times recorded for a
            # device OUTSIDE the mesh would keep reporting it as a live
            # (or straggling) worker it no longer is.
            in_mesh = devices[:mesh.devices.size]

            try:
                while step < num_steps:
                    if injector is not None:
                        devices = injector.check(step, devices)
                    t0 = time.monotonic()
                    batch = next(batches)
                    params, opt_state, metrics = step_fn(
                        params, opt_state, batch, mesh)
                    dt = time.monotonic() - t0
                    for d in in_mesh:
                        monitor.beat(str(d.id))
                        stragglers.record(str(d.id), dt)
                    history.append(float(metrics["loss"]))
                    step += 1
                    if step % self.save_every == 0 or step == num_steps:
                        self.ckpt.save_async(step, (params, opt_state))
                self.ckpt.wait()
            except WorkerFailure as wf:
                restarts += 1
                self.ckpt.wait()
                dead = set(wf.workers)
                devices = [d for d in devices if str(d.id) not in dead]
                # Dead workers leave the monitors too: a restart must not
                # carry their stale heartbeats/step-times into the shrunk
                # mesh's failure or straggler reports.
                for w in dead:
                    monitor.remove(w)
                    stragglers.remove(w)
                if not devices:
                    raise
                continue

        return {"losses": history, "restarts": restarts,
                "final_devices": len(devices),
                "monitored": monitor.workers(),
                "stragglers": stragglers.stragglers()}
