from repro.runtime.fault import HeartbeatMonitor, StragglerDetector
from repro.runtime.elastic import (ElasticTrainer, FailureInjector,
                                   WorkerFailure, build_mesh_from)
