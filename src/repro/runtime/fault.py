"""Fault tolerance primitives: heartbeats, failure detection, stragglers.

At 1000+ nodes, failures are the steady state. The runtime keeps:
  * a HeartbeatMonitor — every worker stamps a monotonic timestamp;
    a worker silent for `timeout_s` is declared failed;
  * a StragglerDetector — per-step durations per worker; a worker whose
    rolling step time exceeds mean + k*std of the cohort is flagged so the
    driver can (a) exclude it at the next elastic re-mesh or (b) rebalance.

Both are deliberately transport-agnostic (timestamps come from any
source: process heartbeat threads here, GCS pings in a real deployment)
so the logic is testable on one host with simulated clocks.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Callable


@dataclasses.dataclass
class HeartbeatMonitor:
    timeout_s: float = 10.0
    clock: Callable[[], float] = time.monotonic

    def __post_init__(self):
        self._last: dict[str, float] = {}
        self._lock = threading.Lock()

    def beat(self, worker: str, at: float | None = None) -> None:
        with self._lock:
            self._last[worker] = self.clock() if at is None else at

    def workers(self) -> list[str]:
        with self._lock:
            return sorted(self._last)

    def failed(self) -> list[str]:
        now = self.clock()
        with self._lock:
            return sorted(w for w, t in self._last.items()
                          if now - t > self.timeout_s)

    def alive(self) -> list[str]:
        dead = set(self.failed())
        return [w for w in self.workers() if w not in dead]

    def remove(self, worker: str) -> None:
        with self._lock:
            self._last.pop(worker, None)


@dataclasses.dataclass
class StragglerDetector:
    window: int = 20
    k_sigma: float = 3.0
    min_steps: int = 5

    def __post_init__(self):
        self._times: dict[str, list[float]] = {}

    def record(self, worker: str, step_time_s: float) -> None:
        hist = self._times.setdefault(worker, [])
        hist.append(step_time_s)
        if len(hist) > self.window:
            del hist[0]

    def remove(self, worker: str) -> None:
        """Forget a worker (dropped from the mesh after a failure)."""
        self._times.pop(worker, None)

    def _mean(self, xs):
        return sum(xs) / len(xs)

    def stragglers(self) -> list[str]:
        """Workers whose recent mean step time is an outlier vs the REST of
        the cohort (leave-one-out: including the straggler in mu/sigma
        masks it at small cohort sizes)."""
        means = {w: self._mean(h) for w, h in self._times.items()
                 if len(h) >= self.min_steps}
        if len(means) < 3:
            return []
        out = []
        for w, v in means.items():
            others = [x for ww, x in means.items() if ww != w]
            mu = self._mean(others)
            var = self._mean([(x - mu) ** 2 for x in others])
            sigma = max(var ** 0.5, 0.05 * mu, 1e-9)
            if v > mu + self.k_sigma * sigma:
                out.append(w)
        return sorted(out)
