from repro.distributed import compression, sharding
