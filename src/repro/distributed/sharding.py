"""Sharding rules: param / batch / cache PartitionSpecs for any mesh.

Strategy (1000+-chip posture, DESIGN.md §4):
  * 2-D "hybrid" sharding: tensor-parallel over `model`, FSDP over the
    batch axes (`data`, plus `pod` when present).
  * Every rule is DIVISIBILITY-GUARDED: if a dim doesn't divide the mesh
    axis, the rule degrades (falls back to another dim or replication)
    instead of failing — this is what lets ONE rule set cover all 10
    assigned architectures (qwen2's 14 heads, seamless's 256206 vocab,
    mamba2's 50280 vocab, batch=1 long-context decode, ...).
  * KV caches: batch -> data; kv-heads -> model when divisible, else the
    SEQUENCE dim of the cache -> model (context-parallel decode — GSPMD
    turns the softmax into partial reductions + a small all-reduce).

Specs are derived from abstract shapes (jax.eval_shape) — nothing is
materialized, so the same code paths serve tests (1 device) and the
512-device dry-run.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.tree_util import DictKey, GetAttrKey, SequenceKey

from repro.compat import mesh_from_device_array
from repro.models.common import ModelConfig


def serving_shard_mesh(devices) -> Mesh:
    """1-D ("shard",) mesh over the serving shards' devices.

    The sharded serving runtime's topology object: one axis, one device
    per shard slot (devices may repeat when shards co-locate on a small
    host — jax meshes require distinct devices, so repeats are dropped
    and the runtime keeps its own shard->device map for dispatch). On
    elastic shrink the runtime rebuilds this mesh from the survivors —
    the same degrade-don't-fail posture as the training rules above."""
    devs = list(dict.fromkeys(devices))     # de-dupe, order-preserving
    if not devs:
        raise ValueError("need at least one device")
    return mesh_from_device_array(np.asarray(devs), ("shard",))


def mesh_axes(mesh: Mesh) -> tuple[tuple[str, ...], str]:
    """Returns (batch_axes, model_axis) for our mesh layouts."""
    names = tuple(mesh.axis_names)
    if "model" in names:
        mp = "model"
        dp = tuple(n for n in names if n != "model")
    else:
        mp = None
        dp = names
    return dp, mp


def _size(mesh: Mesh, axes) -> int:
    if axes is None:
        return 1
    if isinstance(axes, str):
        axes = (axes,)
    return int(np.prod([mesh.shape[a] for a in axes]))


def _fits(dim: int, mesh: Mesh, axes) -> bool:
    return axes is not None and dim % _size(mesh, axes) == 0


def _path_names(path) -> list[str]:
    out = []
    for e in path:
        if isinstance(e, DictKey):
            out.append(str(e.key))
        elif isinstance(e, GetAttrKey):
            out.append(str(e.name))
        elif isinstance(e, SequenceKey):
            out.append(str(e.idx))
    return out


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

SERVE_REPLICATE_BYTES = 128 * 1024 * 1024   # per layer-slice per device


def param_spec(path, shape: tuple[int, ...], mesh: Mesh,
               cfg: ModelConfig, serve: bool = False,
               dtype_bytes: int = 4) -> P:
    """serve=True replicates SMALL weights over the batch axes (no FSDP):
    at decode, FSDP-sharded weights must be all-gathered EVERY step for a
    handful of tokens — the dominant serving collective (EXPERIMENTS.md
    §Perf C1). The rule is SIZE-AWARE: a tensor whose per-layer,
    per-model-shard slice exceeds SERVE_REPLICATE_BYTES (e.g. llama4
    expert banks) stays batch-sharded — replicating it would blow HBM,
    and its gather amortizes over a 32k-token prefill anyway. TP over
    `model` is always kept."""
    dp, mp = mesh_axes(mesh)
    names = _path_names(path)
    name = names[-1] if names else ""
    nd = len(shape)

    if serve and nd >= 2:
        slice_elems = 1
        for d in shape[1:] if nd >= 3 else shape:   # per stacked-layer slice
            slice_elems *= d
        per_dev = slice_elems * dtype_bytes / _size(mesh, mp)
        serve = per_dev <= SERVE_REPLICATE_BYTES

    def trailing(*pattern):
        """pattern entries: 'dp' | 'mp' | None per trailing dim; leading
        (stack) dims replicated. Divisibility-guarded, axes used once."""
        spec = [None] * nd
        used = set()
        for i, want in enumerate(pattern):
            d = nd - len(pattern) + i
            if d < 0:
                continue
            if want == "dp" and serve:
                continue
            if want == "dp" and "dp" not in used and _fits(shape[d], mesh, dp):
                spec[d] = dp if len(dp) > 1 else dp[0]
                used.add("dp")
            elif want == "mp" and "mp" not in used and _fits(shape[d], mesh, mp):
                spec[d] = mp
                used.add("mp")
        return P(*spec)

    if name == "embed":
        v, d = shape
        if _fits(v, mesh, mp):
            return trailing("mp", "dp")
        return trailing(None, "mp")            # shard d_model instead
    if name == "lm_head" or name == "proj":
        d, v = shape
        if _fits(v, mesh, mp):
            return trailing("dp", "mp")
        return trailing("mp", None)
    if name in ("wq", "wk", "wv", "w_gate", "w_up", "sh_gate", "sh_up",
                "in_proj", "xwq", "xwk", "xwv"):
        if name in ("w_gate", "w_up") and nd >= 3 and len(names) >= 2 \
                and names[-2] == "moe":
            # (SB, E, D, F): expert-parallel over model, FSDP over D
            return trailing("mp", "dp", None)
        return trailing("dp", "mp")            # (…, D, O)
    if name in ("wo", "w_down", "sh_down", "out_proj", "xwo"):
        if name == "w_down" and nd >= 3 and len(names) >= 2 \
                and names[-2] == "moe":
            return trailing("mp", None, "dp")  # (SB, E, F, D)
        return trailing("mp", "dp")            # (…, O, D)
    if name in ("bq", "bk", "bv"):
        return trailing("mp")
    if name == "router":
        return trailing("dp", None)            # (SB, D, E)
    # norms, conv, A_log, dt_bias, D, scalar state: replicated
    return P()


def param_shardings(abstract_params: Any, mesh: Mesh,
                    cfg: ModelConfig, serve: bool = False) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, param_spec(
            p, l.shape, mesh, cfg, serve=serve,
            dtype_bytes=jnp.dtype(l.dtype).itemsize)),
        abstract_params)


def opt_state_shardings(abstract_opt_state: Any, abstract_params: Any,
                        mesh: Mesh, cfg: ModelConfig) -> Any:
    """Optimizer moments shard like their parameter. AdamW mu/nu mirror the
    param tree; Adafactor factored vr/vc inherit the matching param dims."""
    pspecs = jax.tree_util.tree_map_with_path(
        lambda p, l: param_spec(p, l.shape, mesh, cfg), abstract_params)
    flat_specs = {tuple(_path_names(p)): s for p, s in
                  jax.tree_util.tree_flatten_with_path(pspecs)[0]}

    def resolve(path, leaf):
        names = tuple(_path_names(path))
        if names and names[-1] == "step":
            return NamedSharding(mesh, P())
        # strip the optimizer-state prefix ("mu"/"nu"/"v") and suffix
        # ("vr"/"vc"/"v") to find the matching param path
        core = names[1:] if names and names[0] in ("mu", "nu", "v") else names
        suffix = None
        if core and core[-1] in ("vr", "vc", "v"):
            suffix = core[-1]
            core = core[:-1]
        spec = flat_specs.get(tuple(core))
        if spec is None:
            return NamedSharding(mesh, P())
        parts = list(spec) + [None] * (leaf.ndim + 2 - len(spec))
        if suffix == "vr":        # param dims minus the LAST dim
            parts = parts[:leaf.ndim]
        elif suffix == "vc":      # param dims minus the SECOND-TO-LAST dim
            parts = parts[:leaf.ndim + 1]
            parts = parts[:-2] + [parts[-1]]
        else:                     # mirrors the param exactly
            parts = parts[:leaf.ndim]
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(resolve, abstract_opt_state)


# ---------------------------------------------------------------------------
# Batches and caches
# ---------------------------------------------------------------------------

def batch_spec(shape: tuple[int, ...], mesh: Mesh) -> P:
    dp, _ = mesh_axes(mesh)
    if shape and _fits(shape[0], mesh, dp):
        return P(dp if len(dp) > 1 else dp[0], *([None] * (len(shape) - 1)))
    return P(*([None] * len(shape)))


def batch_shardings(abstract_batch: Any, mesh: Mesh) -> Any:
    return jax.tree.map(
        lambda l: NamedSharding(mesh, batch_spec(l.shape, mesh)),
        abstract_batch)


def cache_spec(path, shape: tuple[int, ...], mesh: Mesh,
               cfg: ModelConfig) -> P:
    """KV/SSM cache sharding. Leaf names: k/v/self_k/.../state/conv/length."""
    dp, mp = mesh_axes(mesh)
    names = _path_names(path)
    name = names[-1] if names else ""
    nd = len(shape)
    if name == "length" or nd <= 1:
        return P()
    if name == "k_scale":                      # (L, B, T, KH)
        spec = [None] * nd
        if _fits(shape[1], mesh, dp):
            spec[1] = dp if len(dp) > 1 else dp[0]
        if _fits(shape[3], mesh, mp):
            spec[3] = mp
        elif _fits(shape[2], mesh, mp):
            spec[2] = mp
        return P(*spec)
    if name in ("k", "v", "self_k", "self_v", "cross_k", "cross_v",
                "k_msb", "k_lsb"):
        # (L|APPS, B, T, KH, hd)
        spec = [None] * nd
        b_dim, t_dim, kh_dim = 1, 2, 3
        used_dp = False
        if _fits(shape[b_dim], mesh, dp):
            spec[b_dim] = dp if len(dp) > 1 else dp[0]
            used_dp = True
        if _fits(shape[kh_dim], mesh, mp):
            spec[kh_dim] = mp
        elif _fits(shape[t_dim], mesh, mp):
            spec[t_dim] = mp                  # context-parallel decode
        if not used_dp:
            rem = [a for a in dp if shape[t_dim] % (mesh.shape[a]
                   * (_size(mesh, mp) if spec[t_dim] == mp else 1)) == 0]
            if rem and spec[t_dim] in (None, mp):
                extra = tuple(rem)
                spec[t_dim] = (extra + (mp,)) if spec[t_dim] == mp else (
                    extra if len(extra) > 1 else extra[0])
        return P(*spec)
    if name == "state":                        # (L, B, H, P, N)
        spec = [None] * nd
        if _fits(shape[1], mesh, dp):
            spec[1] = dp if len(dp) > 1 else dp[0]
        if _fits(shape[2], mesh, mp):
            spec[2] = mp
        return P(*spec)
    if name == "conv":                         # (L, B, W-1, C)
        spec = [None] * nd
        if _fits(shape[1], mesh, dp):
            spec[1] = dp if len(dp) > 1 else dp[0]
        if _fits(shape[3], mesh, mp):
            spec[3] = mp
        return P(*spec)
    return P()


def cache_shardings(abstract_cache: Any, mesh: Mesh, cfg: ModelConfig) -> Any:
    return jax.tree_util.tree_map_with_path(
        lambda p, l: NamedSharding(mesh, cache_spec(p, l.shape, mesh, cfg)),
        abstract_cache)
