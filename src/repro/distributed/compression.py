"""INT8 gradient compression with error feedback + two-level reduction.

Cross-pod links are the scarcest bandwidth at multi-pod scale. The
two-level schedule (DESIGN.md §4):

  1. intra-pod reduce-scatter in f32 (fast ICI),
  2. INT8-quantize the local shard (per-tensor max-abs scale) and
     all-reduce ACROSS pods on the compressed payload -> 4x fewer
     cross-pod bytes,
  3. dequantize, all-gather intra-pod.

Error feedback: the quantization residual e_t is added to the NEXT step's
gradient before compression, which keeps the accumulated bias bounded
(Karimireddy et al., 2019) — tested via the convergence property test.

`compress_decompress` is the numerics core (jit-safe, shape-preserving);
`make_two_level_all_reduce` wires it into a shard_map over (pod, data)
for explicit-collective training loops.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8_tensor(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8_tensor(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_decompress(g: jax.Array, err: jax.Array
                        ) -> tuple[jax.Array, jax.Array]:
    """One error-feedback round: returns (decompressed g, new residual)."""
    g32 = g.astype(jnp.float32) + err
    q, scale = quantize_int8_tensor(g32)
    deq = dequantize_int8_tensor(q, scale)
    return deq.astype(g.dtype), g32 - deq


def init_error_state(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def apply_error_feedback(grads: Any, err_state: Any) -> tuple[Any, Any]:
    out = jax.tree.map(compress_decompress, grads, err_state)
    new_g = jax.tree.map(lambda o: o[0], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_e = jax.tree.map(lambda o: o[1], out,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_e


def make_two_level_all_reduce(mesh, *, pod_axis: str = "pod",
                              data_axis: str = "data"):
    """Explicit two-level mean-all-reduce of a per-device gradient tree.

    For use under shard_map(..., axis_names including pod/data). Intra-pod
    f32 psum_scatter, INT8 across pods, all-gather back. Returns a fn
    g_tree -> g_tree (mean over pod x data)."""
    npod = mesh.shape[pod_axis]
    ndata = mesh.shape[data_axis]

    def reduce_leaf(g):
        orig_shape = g.shape
        flat = g.reshape(-1).astype(jnp.float32)
        pad = (-flat.shape[0]) % ndata
        flat = jnp.pad(flat, (0, pad))
        # 1) intra-pod reduce-scatter (f32)
        shard = jax.lax.psum_scatter(flat, data_axis, scatter_dimension=0,
                                     tiled=True)
        # 2) cross-pod all-reduce on INT8 payload. The scale must be
        #    AGREED BEFORE quantizing (pmax of local amax): summing codes
        #    quantized under different scales is not dequantizable.
        amax = jax.lax.pmax(jnp.max(jnp.abs(shard)), pod_axis)
        scale = jnp.maximum(amax, 1e-12) / 127.0
        q = jnp.clip(jnp.round(shard / scale), -127, 127).astype(jnp.int8)
        summed = jax.lax.psum(q.astype(jnp.int32), pod_axis)
        shard = summed.astype(jnp.float32) * scale
        # 3) intra-pod all-gather
        full = jax.lax.all_gather(shard, data_axis, tiled=True)
        full = full / (npod * ndata)
        if pad:
            full = full[:-pad]
        return full.reshape(orig_shape).astype(g.dtype)

    return lambda tree: jax.tree.map(reduce_leaf, tree)
