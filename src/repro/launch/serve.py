"""Serving launcher: RAG pipeline (retrieval + generation) for any arch.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --num-docs 256 --requests 8 [--metric cosine] [--topk 3]

Builds the offline index (MiniLM-style embedder -> INT8 nibble-planar DB,
sharded over the mesh when --data/--model > 1), then serves batched
requests through the paper's two-stage hierarchical retrieval and the
generator's prefill+decode, logging the Table-II-calibrated energy ledger
per query.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.core import RetrievalConfig
from repro.launch.mesh import make_test_mesh
from repro.models import embedder, get_model
from repro.serve import RAGPipeline


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--num-docs", type=int, default=256)
    ap.add_argument("--doc-len", type=int, default=12)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--topk", type=int, default=3)
    ap.add_argument("--metric", choices=("cosine", "mips"), default="cosine")
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    args = ap.parse_args(argv)

    rng = np.random.default_rng(0)
    gcfg = get_config(args.arch, smoke=args.smoke)
    if gcfg.family == "encdec":
        raise SystemExit("RAG serving drives decoder-LM archs; "
                         "seamless decodes from frames, not augmented text")
    gen_api = get_model(gcfg)
    gen_params = gen_api.init(jax.random.PRNGKey(0))

    ecfg = embedder.MINILM_CFG.with_(num_layers=2, d_model=64, num_heads=4,
                                     num_kv_heads=4, d_ff=128,
                                     vocab_size=gcfg.vocab_size,
                                     pooled_dim=64)
    eparams = embedder.init_params(ecfg, jax.random.PRNGKey(1))

    docs = jnp.asarray(rng.integers(
        0, gcfg.vocab_size, (args.num_docs, args.doc_len)).astype(np.int32))
    mesh = (make_test_mesh(args.data, args.model)
            if args.data * args.model > 1 else None)
    t0 = time.time()
    pipe = RAGPipeline.build(
        ecfg, eparams, gen_api, gen_params, docs,
        RetrievalConfig(k=args.topk, metric=args.metric), mesh=mesh)
    print(f"[offline] index over {args.num_docs} docs in "
          f"{time.time() - t0:.1f}s (mesh={'none' if mesh is None else dict(mesh.shape)})")

    gold = rng.integers(0, args.num_docs, args.requests)
    queries = docs[jnp.asarray(gold)]
    t0 = time.time()
    out, ids, ledger = pipe.answer(queries, max_new=args.max_new)
    dt = time.time() - t0
    hits = int(np.sum(np.asarray(ids)[:, 0] == gold))
    print(f"[online] {args.requests} reqs in {dt:.1f}s; top-1 hit "
          f"{hits}/{args.requests}; retrieval energy "
          f"{ledger.total_uj:.2f} uJ/query "
          f"(DRAM {100 * ledger.proportions()['DRAM']:.1f}%)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
