"""While-aware HLO analysis: exact dot-FLOPs and collective bytes.

XLA's HloCostAnalysis (and a naive text scan) counts a `while` body ONCE,
but our models lax.scan over layers (and over attention KV chunks), so
both FLOPs and collective bytes would be undercounted by the trip count.

This module parses `compiled.as_text()` (post-SPMD, scheduled HLO):

  1. split the module into computations,
  2. build a symbol table (op name -> shape) per computation,
  3. walk the call graph from the entry computation, carrying a
     MULTIPLIER: while-loop bodies multiply by the loop trip count
     (parsed from the `compare(..., constant(N))` in the loop condition);
     fusions / calls / to_apply multiply by 1; conditionals take both
     branches (upper bound),
  4. accumulate per-device
       * dot FLOPs: 2 * prod(result dims) * prod(contracting dims)
         (MAC-dominant accounting — elementwise/transcendental excluded,
         standard MFU practice),
       * collective result bytes per op kind.

Validated against hand-counted toys (scan of matmuls: exactly trips x
one-body) in tests/test_hlo_analysis.py.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(")
_TRIP_CFG = re.compile(r'"known_trip_count":\{"n":"(\d+)"')
_OP_LINE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+)$")
_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_TUPLE_SHAPE = re.compile(r"^\(")


def _shape_of(typestr: str):
    """First (dtype, dims) in a type string like 'f32[8,128]{1,0}'."""
    m = _SHAPE.match(typestr.strip().lstrip("("))
    if not m:
        return None
    dt = m.group(1)
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dt, dims


def _all_shapes(typestr: str):
    out = []
    for m in _SHAPE.finditer(typestr):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        dims = [int(d) for d in m.group(2).split(",") if d]
        out.append((dt, dims))
    return out


def _nbytes(dt, dims):
    n = _DTYPE_BYTES.get(dt, 0)
    for d in dims:
        n *= d
    return n


def parse_module(text: str) -> dict[str, list[str]]:
    """Split HLO text into {computation_name: [op lines]}."""
    comps: dict[str, list[str]] = {}
    cur = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("->" in line) and ("{" in line):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = m.group(1)
                comps[cur] = []
                continue
        if line.strip() == "}":
            continue
        if cur is not None:
            comps[cur].append(line.strip())
    return comps


def _entry_name(text: str, comps) -> str:
    for line in text.splitlines():
        if line.startswith("ENTRY"):
            m = _COMP_HDR.match(line[len("ENTRY"):].strip())
            if m:
                return m.group(1)
    # fallback: the computation named like the module's main
    return next(iter(comps))


class _Comp:
    def __init__(self, lines: list[str]):
        self.lines = lines
        self.shapes: dict[str, str] = {}
        for ln in lines:
            m = _OP_LINE.match(ln)
            if m:
                self.shapes[m.group(1)] = m.group(2)

    def type_of(self, ref: str) -> str | None:
        return self.shapes.get(ref.lstrip("%"))


def _trip_count(cond: _Comp, comps: dict[str, "_Comp"]) -> int:
    """Max integer constant in the condition computation (and any
    computation it calls) — scan conditions compare the induction var
    against the trip count."""
    best = 1
    seen = set()

    def walk(c: _Comp):
        for ln in c.lines:
            for m in re.finditer(r"constant\((\d+)\)", ln):
                best_local = int(m.group(1))
                nonlocal best
                best = max(best, best_local)
            for m in re.finditer(r"(?:calls|to_apply|condition|body)="
                                 r"%?([\w\.\-]+)", ln):
                name = m.group(1)
                if name in comps and name not in seen:
                    seen.add(name)
                    walk(comps[name])
    walk(cond)
    return best


def _dot_flops(line: str, comp: _Comp, rhs: str) -> float:
    """2 * prod(result) * prod(contracting dims of lhs)."""
    res = _shape_of(rhs)
    if res is None:
        return 0.0
    _, rdims = res
    args = re.findall(r"\(([^)]*)\)", rhs)
    refs = re.findall(r"%([\w\.\-]+)", args[0]) if args else []
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
    cdims = [int(d) for d in m.group(1).split(",") if d] if m else []
    k = 1
    if refs:
        lhs_t = comp.type_of(refs[0])
        if lhs_t:
            sh = _shape_of(lhs_t)
            if sh:
                _, ldims = sh
                for cd in cdims:
                    if cd < len(ldims):
                        k *= ldims[cd]
    nres = 1
    for d in rdims:
        nres *= d
    return 2.0 * nres * k


def analyze(text: str, top_ops: int = 0) -> dict:
    """Returns {'dot_flops', 'collectives': {kind: bytes, 'total': ...},
    'collective_counts': {kind: n (static ops x multiplier)}} and, with
    top_ops > 0, the largest individual collective contributors
    (bytes x loop multiplier, with the op_name metadata for attribution)."""
    raw = parse_module(text)
    comps = {k: _Comp(v) for k, v in raw.items()}
    entry = _entry_name(text, comps)

    flops = 0.0
    coll = {c: 0.0 for c in COLLECTIVES}
    ccount = defaultdict(float)
    contributors: list[tuple[float, str, str, str]] = []
    dot_contribs: list[tuple[float, str, str]] = []
    visiting: list[str] = []

    def walk(name: str, mult: float):
        nonlocal flops
        comp = comps.get(name)
        if comp is None or name in visiting:
            return
        visiting.append(name)
        for ln in comp.lines:
            m = _OP_LINE.match(ln)
            if not m:
                continue
            rhs = m.group(2)
            opm = re.match(r"(?:\(?[\w\[\],{}/ ]*\)?\s*)?([a-z][a-z0-9\-]*)"
                           r"(?:\.\d+)?\(", rhs.split(" ", 1)[1]
                           if _SHAPE.match(rhs) or rhs.startswith("(")
                           else rhs)
            # op name: the token right before the first '(' after the type
            op = None
            mm = re.search(r"\}?\s*([a-z][a-z0-9\-]*)\(", rhs)
            if mm:
                op = mm.group(1)
            if op == "dot":
                f = mult * _dot_flops(ln, comp, rhs)
                flops += f
                if top_ops:
                    meta = re.search(r'op_name="([^"]*)"', ln)
                    dot_contribs.append(
                        (f, rhs.split("dot")[0].strip()[:50],
                         meta.group(1)[-100:] if meta else ""))
            elif op in COLLECTIVES or (op or "").rstrip("-start").rstrip(
                    "-done") in COLLECTIVES:
                base = (op[:-6] if op.endswith("-start") else
                        op[:-5] if op.endswith("-done") else op)
                if base in COLLECTIVES and not op.endswith("-done"):
                    bytes_ = sum(_nbytes(dt, dims)
                                 for dt, dims in _all_shapes(
                                     rhs.split(base)[0]))
                    coll[base] += mult * bytes_
                    ccount[base] += mult
                    if top_ops:
                        meta = re.search(r'op_name="([^"]*)"', ln)
                        shape = rhs.split(base)[0].strip()[:60]
                        contributors.append(
                            (mult * bytes_, base, shape,
                             meta.group(1)[-110:] if meta else ""))
            if op == "while":
                bm = re.search(r"body=%?([\w\.\-]+)", rhs)
                cm = re.search(r"condition=%?([\w\.\-]+)", rhs)
                tc = _TRIP_CFG.search(rhs)          # XLA-annotated trip count
                if tc:
                    trips = int(tc.group(1))
                elif cm and cm.group(1) in comps:
                    trips = _trip_count(comps[cm.group(1)], comps)
                else:
                    trips = 1
                if bm:
                    walk(bm.group(1), mult * trips)
            else:
                for sub in re.finditer(r"(?:calls|to_apply)=%?([\w\.\-]+)",
                                       rhs):
                    walk(sub.group(1), mult)
                if op == "conditional":
                    for sub in re.finditer(
                            r"(?:true_computation|false_computation|"
                            r"branch_computations)=\{?%?([\w\.\-,% ]+)", rhs):
                        for nm in re.split(r"[,%\s]+", sub.group(1)):
                            if nm in comps:
                                walk(nm, mult)
        visiting.pop()

    walk(entry, 1.0)
    coll_out = {k: int(v) for k, v in coll.items()}
    coll_out["total"] = int(sum(coll.values()))
    out = {"dot_flops": flops, "collectives": coll_out,
           "collective_counts": {k: int(v) for k, v in ccount.items()}}
    if top_ops:
        contributors.sort(reverse=True)
        out["top_collectives"] = contributors[:top_ops]
        dot_contribs.sort(reverse=True)
        out["top_dots"] = dot_contribs[:top_ops]
    return out
