"""Production mesh builders (functions, not constants — importing this
module never touches jax device state)."""
from __future__ import annotations

from repro.compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (data=16, model=16) = 256 chips. Multi-pod adds a
    leading pod axis: (pod=2, data=16, model=16) = 512 chips."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Small mesh over however many local devices exist (tests/examples)."""
    return make_mesh((data, model), ("data", "model"))


def make_train_opt_mesh(*, multi_pod: bool = False):
    """§Perf A4: rebalanced training mesh over the SAME chips — TP=4
    instead of TP=16. TP activation all-reduces scale with tokens/device
    x TP-fraction, FSDP weight gathers scale with params x passes; at
    (data=64, model=4) the two meet near the compute roofline for the
    60-400B dense models (napkin + measurement in EXPERIMENTS.md)."""
    shape = (2, 64, 4) if multi_pod else (64, 4)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)
