"""Assigned input-shape cells + abstract input builders for the dry-run.

Every (arch x shape) cell lowers ONE step function with ShapeDtypeStruct
stand-ins (weak-type-correct, shardable, no allocation):

  train_4k     -> train_step   (loss + grads + optimizer update)
  prefill_32k  -> prefill      (prompt pass, returns primed cache)
  decode_32k   -> decode_step  (1 new token, KV/SSM cache of seq_len)
  long_500k    -> decode_step  (sub-quadratic archs only: ssm / hybrid —
                  pure full-attention archs are skipped per the
                  assignment; see DESIGN.md §5)
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import ModelConfig
from repro.models.registry import ModelApi


@dataclasses.dataclass(frozen=True)
class ShapeCase:
    name: str
    kind: str          # "train" | "prefill" | "decode"
    seq: int
    batch: int


SHAPES = {
    "train_4k": ShapeCase("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeCase("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeCase("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeCase("long_500k", "decode", 524288, 1),
}

SUBQUADRATIC_FAMILIES = ("ssm", "hybrid")


def applicable(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    if shape_name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
        return False, ("skipped: 500k-token decode needs sub-quadratic "
                       f"attention; {cfg.family} is full-attention")
    return True, ""


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def abstract_batch(cfg: ModelConfig, case: ShapeCase) -> dict:
    """Training/prefill batch stand-ins for one global batch."""
    b, s = case.batch, case.seq
    if cfg.family == "encdec":
        return {"frames": _sds((b, s, cfg.d_model), jnp.bfloat16),
                "tokens": _sds((b, s), jnp.int32),
                "labels": _sds((b, s), jnp.int32)}
    if cfg.family == "vlm":
        p = cfg.num_prefix_embeds
        return {"tokens": _sds((b, s - p), jnp.int32),
                "labels": _sds((b, s - p), jnp.int32),
                "prefix_embeds": _sds((b, p, cfg.d_model), jnp.bfloat16)}
    return {"tokens": _sds((b, s), jnp.int32),
            "labels": _sds((b, s), jnp.int32)}


def abstract_cache(cfg: ModelConfig, api: ModelApi, case: ShapeCase):
    """Abstract KV/SSM cache of seq_len for decode cells."""
    kw = {"src_len": case.seq} if cfg.family == "encdec" else {}
    return jax.eval_shape(
        lambda: api.init_cache(case.batch, case.seq, **kw))


def abstract_decode_tokens(case: ShapeCase):
    return _sds((case.batch, 1), jnp.int32)
