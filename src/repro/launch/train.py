"""Training launcher: any assigned architecture on any mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b \
        --smoke --steps 20 [--data 1 --model 1] [--grad-accum 2] \
        [--compress-grads] [--ckpt-dir /tmp/ckpt]

On this CPU container use --smoke (reduced config). On a real pod, drop
--smoke and size --data/--model to the slice (the same code path the
512-device dry-run exercises). Fault tolerance comes from the elastic
driver: failures detected between steps trigger re-mesh + restore.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.configs import ARCH_IDS, get_config
from repro.data import LMTaskConfig, lm_batches
from repro.distributed import compression, sharding as sh
from repro.models import get_model
from repro.runtime import ElasticTrainer
from repro.train import get_optimizer, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--data", type=int, default=1)
    ap.add_argument("--model", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--compress-grads", action="store_true",
                    help="INT8 error-feedback gradient compression")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--save-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = get_config(args.arch, smoke=args.smoke)
    api = get_model(cfg)
    opt = get_optimizer(cfg.optimizer, lr=args.lr)

    err_state = {}

    def make_state(mesh):
        params = api.init(jax.random.PRNGKey(0))
        aparams = jax.eval_shape(lambda: params)
        pspec = sh.param_shardings(aparams, mesh, cfg)
        params = jax.device_put(params, pspec)
        astate = jax.eval_shape(opt.init, aparams)
        ospec = sh.opt_state_shardings(astate, aparams, mesh, cfg)
        opt_state = jax.jit(opt.init, out_shardings=ospec)(params)

        grad_transform = None
        if args.compress_grads:
            err_state["e"] = compression.init_error_state(params)

            def grad_transform(grads):  # noqa: F811
                g, err_state["e"] = compression.apply_error_feedback(
                    grads, err_state["e"])
                return g

        raw = make_train_step(api.loss_fn, opt, grad_accum=args.grad_accum,
                              grad_transform=grad_transform)
        jitted = jax.jit(raw)

        def step_fn(p, o, b, mesh):
            from repro.compat import set_mesh
            with set_mesh(mesh):
                return jitted(p, o, b)

        return params, opt_state, step_fn, (pspec, ospec)

    gen = lm_batches(LMTaskConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                                  batch_size=args.batch))

    def batches():
        for b in gen:
            batch = {k: jnp.asarray(v) for k, v in b.items()}
            if cfg.family == "encdec":
                batch["frames"] = jnp.zeros(
                    (args.batch, args.seq, cfg.d_model), jnp.float32)
            if cfg.family == "vlm":
                batch["prefix_embeds"] = jnp.zeros(
                    (args.batch, cfg.num_prefix_embeds, cfg.d_model),
                    jnp.float32)
            yield batch

    trainer = ElasticTrainer(make_state=make_state,
                             ckpt=CheckpointManager(args.ckpt_dir, keep=3),
                             save_every=args.save_every,
                             model_parallel=args.model)
    t0 = time.time()
    out = trainer.run(batches(), num_steps=args.steps)
    dt = time.time() - t0
    print(f"{args.arch}: {args.steps} steps in {dt:.1f}s; "
          f"loss {out['losses'][0]:.3f} -> {out['losses'][-1]:.3f}; "
          f"restarts {out['restarts']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
