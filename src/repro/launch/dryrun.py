"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell, builds abstract params / optimizer state / inputs
(ShapeDtypeStruct only — nothing allocated), attaches NamedShardings from
repro.distributed.sharding, then:

    lowered  = jax.jit(step, in_shardings=..., out_shardings=...).lower(...)
    compiled = lowered.compile()
    print(compiled.memory_analysis())   # proves it fits per-device HBM
    print(compiled.cost_analysis())     # FLOPs / bytes for the roofline

plus collective-byte accounting parsed from the partitioned HLO text
(all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute result sizes). Results append to a JSON file consumed
by EXPERIMENTS.md §Dry-run / §Roofline.

Usage:
    python -m repro.launch.dryrun --arch qwen2-0.5b --shape train_4k \
        --mesh single --out results/dryrun.json
    python -m repro.launch.dryrun --all --mesh both   # every cell
"""
from __future__ import annotations

import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import sys
import time
import traceback

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config
from repro.distributed import sharding as sh
from repro.launch import shapes as shp
from repro.launch import hlo_analysis
from repro.launch.mesh import make_production_mesh, make_train_opt_mesh
from repro.models.registry import get_model
from repro.train import get_optimizer, make_train_step


def _mem_dict(mem) -> dict:
    if mem is None:
        return {}
    keys = ("generated_code_size_in_bytes", "argument_size_in_bytes",
            "output_size_in_bytes", "temp_size_in_bytes",
            "alias_size_in_bytes", "peak_memory_in_bytes")
    return {k: int(getattr(mem, k)) for k in keys if hasattr(mem, k)}


def build_step(arch: str, shape_name: str, mesh, variant: str = "baseline"):
    """Returns (fn, abstract_args, in_shardings, out_shardings).

    variant="opt" applies the §Perf hillclimb configuration:
      * serve cells: bf16 weights, no remat wrapper, weights replicated
        over the batch axes (no per-step FSDP all-gathers),
      * train cells: Megatron-SP sequence-sharded residual stream.
    """
    cfg = get_config(arch)
    case = shp.SHAPES[shape_name]
    serve_params = False
    if variant == "kvq":
        # §Perf C3: opt serve settings + INT8 nibble-planar K cache with
        # two-stage hierarchical attention (decode cells, dense/vlm only)
        assert case.kind == "decode" and cfg.family in ("dense", "vlm")
        cfg = cfg.with_(param_dtype="bfloat16", remat=False)
        api = get_model(cfg)
        aparams = jax.eval_shape(api.init, jax.random.PRNGKey(0))
        pspec = sh.param_shardings(aparams, mesh, cfg, serve=True)
        from repro.models import dense as dense_mod
        acache = jax.eval_shape(
            lambda: dense_mod.init_quant_cache(cfg, case.batch, case.seq))
        cspec = sh.cache_shardings(acache, mesh, cfg)
        atok = shp.abstract_decode_tokens(case)
        tspec = sh.batch_shardings(atok, mesh)

        def qstep(params, cache, tokens):
            return dense_mod.decode_step_quant(params, cache, tokens, cfg)

        alogits = jax.eval_shape(qstep, aparams, acache, atok)[0]
        lspec = sh.batch_shardings(alogits, mesh)
        return (qstep, (aparams, acache, atok), (pspec, cspec, tspec),
                (lspec, cspec), {"donate_argnums": (1,)})
    if variant == "opt":
        # Per-cell selection from the measured sweep (EXPERIMENTS.md §Perf):
        #  * decode: bf16 weights REPLICATED over batch axes (kills the
        #    per-token FSDP gathers; 9-15x) + donated caches;
        #  * prefill: bf16 weights, BASELINE sharding (replication
        #    regressed the big dense archs 2-3x via forced reshards);
        #  * train: rebalanced (64,4) mesh for non-MoE (2.4-4.1x); MoE
        #    keeps (16,16) (experts need the wide model axis).
        # Megatron-SP was tried and REFUTED (§Perf A3) — plain TP kept.
        if case.kind == "decode":
            cfg = cfg.with_(param_dtype="bfloat16", remat=False)
            serve_params = True
        elif case.kind == "prefill":
            cfg = cfg.with_(param_dtype="bfloat16", remat=False)
    api = get_model(cfg)
    aparams = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    pspec = sh.param_shardings(aparams, mesh, cfg, serve=serve_params)
    repl = NamedSharding(mesh, P())

    if case.kind == "train":
        opt = get_optimizer(cfg.optimizer)
        astate = jax.eval_shape(opt.init, aparams)
        ospec = sh.opt_state_shardings(astate, aparams, mesh, cfg)
        abatch = shp.abstract_batch(cfg, case)
        bspec = sh.batch_shardings(abatch, mesh)
        step = make_train_step(api.loss_fn, opt)
        mspec = {"loss": repl, "grad_norm": repl}
        return (step, (aparams, astate, abatch), (pspec, ospec, bspec),
                (pspec, ospec, mspec), {"donate_argnums": (0, 1)})

    if case.kind == "prefill":
        abatch = shp.abstract_batch(cfg, case)
        abatch.pop("labels", None)
        bspec = sh.batch_shardings(abatch, mesh)

        def step(params, batch):
            return api.prefill(params, batch, max_len=case.seq)

        _, acache = jax.eval_shape(step, aparams, abatch)
        cspec = sh.cache_shardings(acache, mesh, cfg)
        alogits = jax.eval_shape(step, aparams, abatch)[0]
        lspec = sh.batch_shardings(alogits, mesh)
        return (step, (aparams, abatch), (pspec, bspec), (lspec, cspec), {})

    # decode — the cache is DONATED (production decode always aliases the
    # KV buffers in-place; without donation the cache is double-counted
    # and deepseek-67b decode peaks at 21 GB > 16 GB HBM; §Perf C2)
    acache = shp.abstract_cache(cfg, api, case)
    cspec = sh.cache_shardings(acache, mesh, cfg)
    atok = shp.abstract_decode_tokens(case)
    tspec = sh.batch_shardings(atok, mesh)

    def step(params, cache, tokens):
        return api.decode_step(params, cache, tokens)

    alogits = jax.eval_shape(step, aparams, acache, atok)[0]
    lspec = sh.batch_shardings(alogits, mesh)
    return (step, (aparams, acache, atok), (pspec, cspec, tspec),
            (lspec, cspec), {"donate_argnums": (1,)})


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             verbose: bool = True, variant: str = "baseline") -> dict:
    cfg = get_config(arch)
    ok, why = shp.applicable(cfg, shape_name)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind}
    if not ok:
        rec.update(status="skipped", reason=why)
        return rec
    if (variant == "opt" and shp.SHAPES[shape_name].kind == "train"
            and cfg.family != "moe"):
        mesh = make_train_opt_mesh(multi_pod=(mesh_kind == "multi"))
    else:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    from repro.compat import set_mesh
    with set_mesh(mesh):                     # activates activation pins
        t0 = time.time()
        fn, args, in_sh, out_sh, jkw = build_step(arch, shape_name, mesh,
                                                  variant=variant)
        lowered = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                          **jkw).lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    mem = _mem_dict(compiled.memory_analysis())
    cost = dict(compiled.cost_analysis() or {})
    cost = {k: float(v) for k, v in cost.items()
            if isinstance(v, (int, float)) and k in
            ("flops", "bytes accessed", "transcendentals",
             "bytes accessed0{}", "bytes accessedout{}")}
    # while-aware per-device dot-FLOPs + collective bytes (hlo_analysis)
    hlo = hlo_analysis.analyze(compiled.as_text())
    rec.update(status="ok", devices=int(mesh.devices.size),
               lower_s=round(t_lower, 1), compile_s=round(t_compile, 1),
               memory=mem, cost=cost, dot_flops=hlo["dot_flops"],
               collectives=hlo["collectives"],
               collective_counts=hlo["collective_counts"])
    if verbose:
        print(f"  memory_analysis: {mem}")
        print(f"  cost_analysis:   {cost}")
        print(f"  dot_flops/dev:   {hlo['dot_flops']:.3e}")
        print(f"  collectives:     {hlo['collectives']}")
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=tuple(shp.SHAPES))
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", choices=("baseline", "opt"),
                    default="baseline")
    ap.add_argument("--out", default="results/dryrun.json")
    args = ap.parse_args(argv)

    cells = []
    meshes = ("single", "multi") if args.mesh == "both" else (args.mesh,)
    if args.all:
        for a in ARCH_IDS:
            for s in shp.SHAPES:
                for m in meshes:
                    cells.append((a, s, m))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape, m) for m in meshes]

    results = []
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)
    done = {(r["arch"], r["shape"], r["mesh"]) for r in results
            if r.get("status") in ("ok", "skipped")}

    failures = 0
    for a, s, m in cells:
        if (a, s, m) in done:
            print(f"[cached] {a} x {s} x {m}")
            continue
        print(f"[dryrun] {a} x {s} x {m} ({args.variant})")
        try:
            rec = run_cell(a, s, m, variant=args.variant)
        except Exception as e:  # noqa: BLE001 — record and continue
            traceback.print_exc()
            rec = {"arch": a, "shape": s, "mesh": m, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            failures += 1
        results = [r for r in results if
                   (r["arch"], r["shape"], r["mesh"]) != (a, s, m)]
        results.append(rec)
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
        print(f"  -> {rec['status']}")
    print(f"done: {len(cells)} cells, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
