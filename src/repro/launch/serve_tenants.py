"""Multi-tenant streaming-serving launcher: simulated ingest+query trace.

    PYTHONPATH=src python -m repro.launch.serve_tenants --tenants 8 \
        --capacity 1024 --steps 40 [--clusters 16 --cache-kb 256] \
        [--generate] [--seed 0]

Drives the wearable deployment shape end to end: T users share one
nibble-planar arena; every trace step either INGESTS a burst of new
personal records for one user (online quantize+pack — no rebuild),
DELETES some (tombstones), or serves a mixed QUERY batch for several
users through the SERVING RUNTIME (repro.serve.runtime): requests get
future-style handles, batches launch on deadline-or-max-batch admission,
and with --clusters + --cache-kb the hot-cluster cache serves repeated
stage-1 views from on-chip memory instead of HBM. Compaction runs
whenever tombstones exceed a threshold. The driver checks isolation (a
user's results only ever come from their own corpus) and hit-rate
(queries are noisy re-encodings of ingested docs), and reports
queries/sec, ingest rows/sec, the cache's hit/byte ledger and the
per-query energy ledger.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core import RetrievalConfig, energy, quantize_int8
from repro.core.clustering import ClusterParams
from repro.models import embedder, get_model
from repro.obs import (MetricsRegistry, Tracer, prometheus_text,
                       write_chrome_trace)
from repro.serve import MultiTenantRAGPipeline, RuntimeConfig, ServingRuntime


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--tenants", type=int, default=8)
    ap.add_argument("--capacity", type=int, default=1024)
    ap.add_argument("--doc-len", type=int, default=12)
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--burst", type=int, default=16,
                    help="docs per ingest event")
    ap.add_argument("--batch", type=int, default=8,
                    help="max queries per scheduler flush")
    ap.add_argument("--topk", type=int, default=3)
    ap.add_argument("--generate", action="store_true",
                    help="also run generator answers for the last batch")
    ap.add_argument("--clusters", type=int, default=0,
                    help="enable the cluster-pruned cascade with this "
                         "many centroids (0 = two-stage full scan)")
    ap.add_argument("--nprobe", type=int, default=4)
    ap.add_argument("--cache-kb", type=int, default=0,
                    help="hot-cluster cache budget in KiB — the size of "
                         "the device-resident slab carved next to the "
                         "arena plane (0 = off; needs --clusters)")
    ap.add_argument("--prescreen-c0", type=int, default=0,
                    help="1-bit sign-plane stage-0 prescreen: keep this "
                         "many survivor rows per lane before the nibble "
                         "gather (0 = off; needs --clusters). Cuts "
                         "stage-0+1 bytes by 4V/(V+4*C0) for a V-row "
                         "probe view")
    ap.add_argument("--precision-tiers", action="store_true",
                    help="per-cluster precision tiers in the hot-cluster "
                         "cache: cold clusters are admitted at the 1-bit "
                         "SIGN tier (sign bytes only, no slab rows) and "
                         "promoted to the full nibble slab on re-probe; "
                         "needs --cache-kb")
    ap.add_argument("--no-preload", action="store_true",
                    help="disable the EdgeRAG-style hot preload (pin a "
                         "session's clusters into the slab when the "
                         "budget fits; preloaded tenants are served "
                         "from the compact slab table)")
    ap.add_argument("--max-wait-ms", type=float, default=5.0,
                    help="deadline slack before a partial batch launches")
    ap.add_argument("--arrival", choices=("closed", "poisson", "bursty"),
                    default="closed",
                    help="closed (default): the mixed trace's query events "
                         "flush inline. poisson/bursty: after the trace, "
                         "run an OPEN-LOOP query phase — request bursts "
                         "arrive on a seeded wall-clock schedule at "
                         "--rate, and per-burst latency (arrival -> all "
                         "resolved) is reported with the queue-wait vs "
                         "compute-wait split")
    ap.add_argument("--rate", type=float, default=200.0,
                    help="open-loop arrival rate in requests/sec "
                         "(bursts of --batch arrive at rate/batch per sec)")
    ap.add_argument("--async-depth", type=int, default=2,
                    help="in-flight launch depth (0 = legacy synchronous "
                         "dispatch)")
    ap.add_argument("--autotune", action="store_true",
                    help="run the measured kernel block autotuner before "
                         "serving and install the winning table")
    ap.add_argument("--autotune-cache", type=str, default=None,
                    help="autotuner artifact path: load it if valid for "
                         "this device, else (with --autotune) save the "
                         "fresh search there")
    ap.add_argument("--shards", type=int, default=0,
                    help="after the main trace, run the SHARDED serving "
                         "phase: the tenants' corpora placed over this "
                         "many shards (rendezvous-hashed placement, one "
                         "ServingRuntime per shard, host-side tournament "
                         "merge), parity-checked bit-for-bit against a "
                         "single-shard baseline (0 = off)")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject an elastic failover in the sharded "
                         "phase: kill one shard before request #N of the "
                         "sharded trace — its tenants re-place onto the "
                         "survivors, in-flight requests resubmit, and "
                         "the exactly-once ledger is asserted (needs "
                         "--shards >= 2)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-out", type=str, default=None,
                    help="write the end-of-run metrics registry here in "
                         "Prometheus text exposition format")
    ap.add_argument("--trace-out", type=str, default=None,
                    help="write the request-lifecycle trace here as Chrome "
                         "trace_event JSON (open in ui.perfetto.dev)")
    args = ap.parse_args(argv)
    if args.tenants < 1 or args.capacity < args.burst:
        ap.error("need --tenants >= 1 and --capacity >= --burst")
    if args.cache_kb and not args.clusters:
        ap.error("--cache-kb caches CLUSTER views: it needs --clusters > 0 "
                 "(without clustering every flush scans windows/masks and "
                 "the cache would silently never be consulted)")
    if args.prescreen_c0 and not args.clusters:
        ap.error("--prescreen-c0 gates the CASCADE's nibble gather: it "
                 "needs --clusters > 0 (the two-stage full scan has no "
                 "stage-0)")
    if args.precision_tiers and not args.cache_kb:
        ap.error("--precision-tiers tiers the hot-cluster cache: it needs "
                 "--cache-kb > 0")
    if args.fail_at >= 0 and args.shards < 2:
        ap.error("--fail-at injects a shard loss: it needs --shards >= 2 "
                 "(there must be a survivor to re-place onto)")

    rng = np.random.default_rng(args.seed)
    _maybe_autotune(args)
    gcfg = get_config("qwen2-0.5b", smoke=True)
    gen_api = get_model(gcfg) if args.generate else None
    gen_params = gen_api.init(jax.random.PRNGKey(0)) if args.generate else None
    ecfg = embedder.MINILM_CFG.with_(num_layers=2, d_model=64, num_heads=4,
                                     num_kv_heads=4, d_ff=128,
                                     vocab_size=gcfg.vocab_size,
                                     pooled_dim=64)
    eparams = embedder.init_params(ecfg, jax.random.PRNGKey(1))

    pipe = MultiTenantRAGPipeline.create(
        ecfg, eparams, gen_api, gen_params, capacity=args.capacity,
        doc_len=args.doc_len,
        retrieval_cfg=RetrievalConfig(k=args.topk, metric="cosine",
                                      prescreen_c0=(args.prescreen_c0
                                                    or None)),
        clusters=(ClusterParams(num_clusters=args.clusters,
                                nprobe=args.nprobe, block_rows=32)
                  if args.clusters else None))
    # The launcher always serves through a REAL registry (per-event cost
    # is one int add; it also feeds the energy/latency report below);
    # tracing records one event per request lifecycle stage, so it is
    # opt-in via --trace-out.
    registry = MetricsRegistry()
    tracer = Tracer() if args.trace_out else None
    runtime = ServingRuntime(pipe.index, RuntimeConfig(
        max_batch=args.batch, max_wait=args.max_wait_ms / 1e3,
        cache_bytes=args.cache_kb * 1024,
        preload=args.cache_kb > 0 and not args.no_preload,
        auto_flush=False, async_depth=args.async_depth,
        precision_tiers=args.precision_tiers),
        registry=registry, tracer=tracer)

    docs_of: dict[int, list[tuple[int, np.ndarray]]] = {
        t: [] for t in range(args.tenants)}     # (slot, tokens) live docs
    ingested = queries = hits = leaks = 0
    t_ingest = t_query = 0.0

    for step in range(args.steps):
        event = rng.choice(["ingest", "ingest", "query", "query", "delete"])
        tenant = int(rng.integers(args.tenants))
        if event == "ingest" or not docs_of[tenant]:
            toks = rng.integers(0, gcfg.vocab_size,
                                (args.burst, args.doc_len)).astype(np.int32)
            if pipe.index.arena.num_free < args.burst:
                pipe.compact()
                # refresh recorded slots after the move
                for t in docs_of:
                    mapped = pipe.index.table.slots(t)
                    docs_of[t] = [(s, d[1]) for s, d in
                                  zip(mapped, docs_of[t])]
            if pipe.index.arena.num_free < args.burst:
                continue                        # arena genuinely full
            t0 = time.perf_counter()
            slots = pipe.ingest(tenant, toks)
            t_ingest += time.perf_counter() - t0
            docs_of[tenant].extend(zip((int(s) for s in slots), toks))
            ingested += args.burst
        elif event == "delete" and len(docs_of[tenant]) > args.burst:
            victims = [docs_of[tenant].pop(0)[0] for _ in range(4)]
            pipe.delete(tenant, victims)
        else:                                   # query burst, mixed tenants
            want = []
            for _ in range(args.batch):
                t = int(rng.integers(args.tenants))
                if not docs_of[t]:
                    continue
                slot, toks = docs_of[t][int(rng.integers(len(docs_of[t])))]
                q_emb = pipe._embed(jnp.asarray(toks[None]))
                q_codes, _ = quantize_int8(q_emb, per_vector=True)
                want.append((runtime.submit(t, np.asarray(q_codes[0])),
                             t, slot))
            t0 = time.perf_counter()
            runtime.flush()
            t_query += time.perf_counter() - t0
            for handle, t, slot in want:
                got = np.asarray(handle.result().indices)
                valid = got[got >= 0]
                owner = np.asarray(pipe.index.arena.owner)
                leaks += int(np.sum(owner[valid] != t))
                hits += int(len(valid) > 0 and valid[0] == slot)
                queries += 1

    st = pipe.index.arena.stats
    print(f"[trace] {args.steps} steps: {ingested} docs ingested "
          f"({st.deletes} tombstoned, {st.compactions} compactions, "
          f"{st.rebuilds} rebuilds), {queries} queries in "
          f"{runtime.launches} launches")
    if queries:
        print(f"[query ] {queries / max(t_query, 1e-9):8.1f} q/s   top-1 hit "
              f"{hits}/{queries}   cross-tenant leaks {leaks} (must be 0)")
    if ingested:
        print(f"[ingest] {ingested / max(t_ingest, 1e-9):8.1f} rows/s online "
              f"(no rebuild; arena {pipe.index.num_live}/"
              f"{pipe.index.capacity} live)")
    if runtime.cache is not None and queries:
        cs = runtime.cache_stats()
        served = runtime.stage1_bytes_streamed + runtime.stage1_bytes_sram
        print(f"[cache ] {cs['hits']}/{cs['hits'] + cs['misses']} cluster "
              f"hits, {runtime.stage1_bytes_sram:,}/{max(served, 1):,} "
              f"stage-1 bytes from cache "
              f"({cs['stale_evictions']} stale evictions)")
        if args.precision_tiers:
            print(f"[cache ] precision tiers: {cs['demotions']} demotions "
                  f"-> SIGN, {cs['promotions']} promotions -> FULL, "
                  f"resident full/sign {cs['full_entries']}/"
                  f"{cs['sign_entries']}")
    # Per-query energy from the ACTUAL served trace: every launch priced
    # its measured SchedulePlan into the registry's µJ/query histogram
    # (weighted by real batch occupancy), so the medians below describe
    # the distribution the trace experienced — not whichever launch
    # happened to run last. The analytic fallback covers --steps traces
    # that never served a query.
    ehist = registry.get("histogram", "energy_uj_per_query")
    if ehist is not None and ehist.count:
        ep = ehist.percentiles((50, 99))
        print(f"[energy] {ep['p50']:.2f} uJ/query median "
              f"(p99 {ep['p99']:.2f}, {ehist.count} queries served)")
        # Stage split (from the per-stage ledger histogram): how much of
        # each query went to the 1-bit stage-0 prescreen vs the nibble
        # stage-1 gather it gates.
        s0 = registry.get("histogram", "energy_uj_per_query_stage",
                          stage="prescreen")
        s1 = registry.get("histogram", "energy_uj_per_query_stage",
                          stage="approx")
        if s0 is not None and s0.count and s1 is not None and s1.count:
            m0 = s0.percentiles((50,))["p50"]
            m1 = s1.percentiles((50,))["p50"]
            print(f"[energy] stage-0 sign prescreen {m0:.3f} uJ/query vs "
                  f"stage-1 nibble gather {m1:.3f} uJ/query (medians; "
                  f"the 1-bit pass costs {m0 / max(m1, 1e-12):.1%} of the "
                  f"stage it gates)")
    else:
        ledger = energy.cost_hierarchical(pipe.index.capacity,
                                          ecfg.pooled_dim)
        print(f"[energy] {ledger.total_uj:.2f} uJ/query (analytic "
              f"full-corpus estimate; no query was served)")
    # Decode-side energy at the deployment's reference context: the same
    # cost_cascade pricing applied to the KV cascade's StagePlan ledger,
    # so the generator's per-token HBM bill prints next to the
    # retrieval-side per-query bill it shares a runtime with.
    from repro.core import engine as engine_mod
    from repro.serve import sparse_kv as skv
    dt, dhd, dk = 4096, 64, 256
    dplan = engine_mod.kv_plan(
        engine_mod.KVCascadeConfig(top_k=dk), batch=1, kv_heads=4,
        q_heads=8, seq_len=dt, head_dim=dhd, layers=4)
    dcost = energy.cost_cascade(dplan.stages, dhd, batch=dplan.batch)
    dbytes = sum(st.bytes_hbm for st in dplan.stages)
    dense_b = skv.dense_bytes_per_step(dt, dhd) * 4 * 4   # x layers x kv-heads
    print(f"[decode] {dcost.total_uj:.3f} uJ/token at T={dt} "
          f"(top-{dk} cascade: {dbytes:,} B/step vs "
          f"{dense_b:,} dense, {dense_b / max(dbytes, 1):.1f}x cut)")
    if args.arrival != "closed":
        _openloop_phase(args, pipe, runtime, docs_of, rng)
    sharded_ok = _sharded_phase(args, rng) if args.shards else True
    _obs_report(args, registry, tracer)

    if args.generate and queries:
        tids = np.asarray([t for t in range(args.tenants)
                           if docs_of[t]][:4], np.int32)
        qtoks = jnp.asarray(np.stack([docs_of[int(t)][0][1] for t in tids]))
        out, ids, _ = pipe.answer(tids, qtoks, max_new=8)
        print(f"[gen   ] answered {out.shape[0]} users, "
              f"{out.shape[1]} tokens each")
    return 1 if (leaks or not sharded_ok) else 0


def _sharded_phase(args, rng) -> bool:
    """--shards: pod-scale sharded serving over the elastic failover path.

    A synthetic per-tenant INT8 corpus (codes are what the placement
    layer moves; the embedding front end is exercised by the main trace
    above) is placed over --shards rendezvous-hashed shards and serves a
    mixed trace; the SAME trace on a single shard is the parity
    baseline — results must be bit-identical, since placement may never
    change answers. --fail-at N kills a shard mid-trace: its tenants
    re-place onto the survivors from the host-side corpus log, in-flight
    requests resubmit under the new placement, and the ledger must prove
    zero dropped / duplicated."""
    from repro.core.retrieval import RetrievalConfig
    from repro.serve.sharded import (ShardedRuntimeConfig,
                                     ShardedServingRuntime)
    tenants, dpt, dim = args.tenants, max(args.burst, 8), 64
    docs = {t: rng.integers(-40, 41, (dpt, dim), dtype=np.int8)
            for t in range(tenants)}
    trace = [(t, rng.integers(-40, 41, (dim,), dtype=np.int8))
             for t in list(range(tenants)) * max(2, args.steps // tenants)]
    rcfg = RetrievalConfig(k=args.topk, metric="mips", candidate_frac=1.0,
                           max_candidates=max(50, dpt))

    def build(s):
        rt = ShardedServingRuntime(ShardedRuntimeConfig(
            num_shards=s, capacity_per_shard=tenants * dpt, dim=dim,
            retrieval=rcfg,
            runtime=RuntimeConfig(max_batch=args.batch, max_wait=1.0,
                                  cache_bytes=0, auto_flush=False)))
        for t in range(tenants):
            rt.ingest_codes(t, docs[t])
        return rt

    def drive(rt, fail_at=-1):
        handles, now, report = [], 0.0, None
        for i, (t, q) in enumerate(trace):
            if i == fail_at:
                # kill the shard owning THIS request's tenant, so the
                # failover demonstrably moves tenants and re-routes work
                report = rt.fail_shard(rt.placement.shard_of(t), now=now)
            now += 1e-3
            handles.append(rt.submit(t, q, now=now))
            if i % args.batch == args.batch - 1:
                rt.poll(now=now)
        rt.flush(now=now + 1)
        return [(np.asarray(h.result().indices),
                 np.asarray(h.result().scores)) for h in handles], report

    t0 = time.perf_counter()
    base, _ = drive(build(1))
    rt = build(args.shards)
    got, report = drive(rt, fail_at=args.fail_at)
    wall = time.perf_counter() - t0
    led = rt.ledger()
    parity = all(np.array_equal(s1, s2) and (args.fail_at >= 0
                                             or np.array_equal(i1, i2))
                 for (i1, s1), (i2, s2) in zip(base, got))
    once = (led["submitted"] == led["resolved"] == len(trace)
            and led["dropped"] == 0 and led["duplicated"] == 0)
    print(f"[shard ] {args.shards} shards, {tenants} tenants x {dpt} docs, "
          f"{len(trace)} requests in {wall:.2f}s   placement "
          f"{ {t: rt.placement.shard_of(t) for t in range(tenants)} }")
    if report is not None:
        print(f"[shard ] failover at request {args.fail_at}: lost shard "
              f"{report['shard']}, moved tenants {report['moved_tenants']}, "
              f"restored {report['docs_restored']} docs, resubmitted "
              f"{report['requests_resubmitted']} in-flight")
    print(f"[shard ] parity vs single shard: {parity}   exactly-once: "
          f"{once} ({led['resolved']}/{led['submitted']} resolved, "
          f"dropped {led['dropped']}, duplicated {led['duplicated']})")
    return parity and once


def _maybe_autotune(args) -> None:
    """--autotune / --autotune-cache: install a measured block-shape
    table before any engine compiles, so serving traces with the tuned
    shapes. A cached artifact is loaded when valid for THIS device;
    otherwise --autotune runs the search (and saves it if a cache path
    was given)."""
    from repro.kernels import autotune
    if args.autotune_cache:
        table = autotune.load(args.autotune_cache)
        if table is not None:
            autotune.install(table)
            print(f"[tune  ] loaded {args.autotune_cache} "
                  f"({len(table.entries)} tuned points)")
            return
        if not args.autotune:
            print(f"[tune  ] {args.autotune_cache} missing/stale for this "
                  "device; serving with DEFAULT_BLOCK_N (pass --autotune "
                  "to re-measure)")
            return
    if not args.autotune:
        return
    table = autotune.autotune(reps=3)
    autotune.install(table)
    worst = min((e["speedup_vs_default"] for e in table.entries.values()),
                default=1.0)
    print(f"[tune  ] measured {len(table.entries)} points "
          f"(worst speedup vs default {worst:.2f}x)")
    if args.autotune_cache:
        table.save(args.autotune_cache)
        print(f"[tune  ] saved -> {args.autotune_cache}")


def _openloop_phase(args, pipe, runtime, docs_of, rng) -> None:
    """Open-loop query phase: bursts of --batch requests arrive on a
    seeded wall-clock schedule (--arrival poisson|bursty at --rate
    requests/sec) against the still-warm runtime. Per-burst latency is
    arrival -> all handles resolved, so a backlogged server pays its
    queue in the tail; between arrivals the driver reaps finished
    launches (the async pipeline's lazy-retire path)."""
    from repro.core import quantize_int8 as _q8
    live = [t for t in docs_of if docs_of[t]]
    if not live:
        print("[openlp] no live docs; skipping open-loop phase")
        return
    bursts = max(4, args.steps // 2)
    batches = []                            # precomputed off the clock
    for _ in range(bursts):
        batch = []
        for _ in range(args.batch):
            t = int(rng.choice(live))
            _, toks = docs_of[t][int(rng.integers(len(docs_of[t])))]
            q_emb = pipe._embed(jnp.asarray(toks[None]))
            codes, _ = _q8(q_emb, per_vector=True)
            batch.append((t, np.asarray(codes[0])))
        batches.append(batch)
    gap = args.batch / max(args.rate, 1e-9)
    if args.arrival == "poisson":
        arrivals = np.cumsum(rng.exponential(gap, size=bursts))
    else:                                   # bursty: two-state MMPP
        arrivals, t, state = [], 0.0, 0
        for _ in range(bursts):
            t += float(rng.exponential(gap * (0.4 if state == 0 else 1.6)))
            arrivals.append(t)
            if rng.random() < 0.3:
                state = 1 - state
        arrivals = np.asarray(arrivals)
    for batch in batches[:2]:               # untimed warm pass
        for t, q in batch:
            runtime.submit(t, q)
        runtime.flush()

    pending, lat = [], []
    t0 = time.perf_counter()

    def now():
        return time.perf_counter() - t0

    def harvest():
        while pending and all(h.done() for h in pending[0][1]):
            arr, _ = pending.pop(0)
            lat.append(now() - arr)

    for batch, arr in zip(batches, arrivals):
        while True:
            remaining = arr - now()
            if remaining <= 0:
                break
            runtime.reap()
            harvest()
            # yield between probes — a hot-spinning driver starves the
            # XLA executor of the cycles the in-flight launches need
            time.sleep(min(2e-4, max(remaining, 0.0)))
        hs = [runtime.submit(t, q, now=now()) for t, q in batch]
        runtime.flush()                     # partial bursts must not strand
        pending.append((arr, hs))
        harvest()
    runtime.flush()
    harvest()
    p50, p95, p99 = (float(np.percentile(lat, p)) * 1e3
                     for p in (50, 95, 99))
    print(f"[openlp] {args.arrival} arrivals, {bursts} bursts x "
          f"{args.batch} req @ {args.rate:.0f} req/s "
          f"(async_depth={args.async_depth})")
    print(f"[openlp] burst latency p50/p95/p99 {p50:.2f}/{p95:.2f}/"
          f"{p99:.2f} ms")


def _obs_report(args, registry, tracer) -> None:
    """End-of-run observability summary + optional artifact exports."""
    rows = []
    for hname, label, unit, scale in (
            ("serve_queue_wait_seconds", "queue wait", "ms", 1e3),
            ("serve_launch_wall_seconds", "launch wall", "ms", 1e3),
            ("serve_resolve_lag_seconds", "resolve lag", "ms", 1e3),
            ("serve_batch_occupancy", "batch occupancy", "req", 1.0),
            ("energy_uj_per_query", "energy/query", "uJ", 1.0)):
        h = registry.get("histogram", hname)
        if h is None or not h.count:
            continue
        pc = h.percentiles((50, 95, 99))
        rows.append((label, h.count, pc["p50"] * scale, pc["p95"] * scale,
                     pc["p99"] * scale, unit))
    for stage, label in (("prescreen", "energy stage-0"),
                         ("approx", "energy stage-1")):
        h = registry.get("histogram", "energy_uj_per_query_stage",
                         stage=stage)
        if h is None or not h.count:
            continue
        pc = h.percentiles((50, 95, 99))
        rows.append((label, h.count, pc["p50"], pc["p95"], pc["p99"], "uJ"))
    if rows:
        print(f"[obs   ] {'metric':<16} {'count':>7} {'p50':>9} "
              f"{'p95':>9} {'p99':>9}")
        for label, count, p50, p95, p99, unit in rows:
            print(f"[obs   ] {label:<16} {count:>7} {p50:>9.3f} "
                  f"{p95:>9.3f} {p99:>9.3f}  {unit}")
    # where did request time go: waiting in the batch window (scheduling)
    # vs launch + retire (compute)? The split tells an operator whether
    # to tune --window/--batch (queue-bound) or block shapes (compute-bound)
    qw = registry.get("histogram", "serve_queue_wait_seconds")
    lw = registry.get("histogram", "serve_launch_wall_seconds")
    rl = registry.get("histogram", "serve_resolve_lag_seconds")
    queue_s = qw.total if qw is not None and qw.count else 0.0
    compute_s = sum(h.total for h in (lw, rl)
                    if h is not None and h.count)
    split = queue_s + compute_s
    if split > 0:
        print(f"[obs   ] time split: queue wait {queue_s * 1e3:.1f} ms "
              f"({100 * queue_s / split:.0f}%) vs compute "
              f"(launch+resolve) {compute_s * 1e3:.1f} ms "
              f"({100 * compute_s / split:.0f}%)")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            f.write(prometheus_text(registry))
        print(f"[obs   ] metrics -> {args.metrics_out} (prometheus text)")
    if args.trace_out and tracer is not None:
        n = write_chrome_trace(args.trace_out, tracer)
        print(f"[obs   ] trace   -> {args.trace_out} "
              f"({n} events; open in ui.perfetto.dev)")


if __name__ == "__main__":
    raise SystemExit(main())
