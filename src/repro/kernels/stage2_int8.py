"""Pallas TPU kernel: stage-2 full-INT8 exact rescoring of candidates.

The candidate rows (top-C from stage 1, C ~ 50) have been gathered into
dense (C, D//2) MSB and LSB planes. The kernel reconstructs the INT8
values in-register (msb*16 + lsb, exactly inverting the nibble split) and
runs the exact int8 MAC on the MXU. The query is again pinned in VMEM.

On the paper's 4-bit PEs an 8x8 multiply is decomposed into 4 nibble
products (their refs [24][25]); on TPU the MXU natively does int8, so the
reconstruction happens in VREG and the MAC is a single int8 dot — same
arithmetic result, hardware-appropriate mapping (DESIGN.md §8).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.stage1_int4 import _sext4_i8

DEFAULT_BLOCK_C = 64


def _reconstruct_even_odd(msb: jax.Array, lsb: jax.Array):
    """Packed planes -> (even-dim, odd-dim) int8 value matrices."""
    me = _sext4_i8(msb & jnp.uint8(0xF)).astype(jnp.int16)
    mo = _sext4_i8((msb >> 4) & jnp.uint8(0xF)).astype(jnp.int16)
    le = (lsb & jnp.uint8(0xF)).astype(jnp.int16)
    lo = ((lsb >> 4) & jnp.uint8(0xF)).astype(jnp.int16)
    de = (me * 16 + le).astype(jnp.int8)
    do = (mo * 16 + lo).astype(jnp.int8)
    return de, do


def _stage2_kernel(q_ref, msb_ref, lsb_ref, out_ref):
    """q_ref: (2, D2) int8 pinned; planes: (BC, D2) uint8; out: (1, BC)."""
    de, do = _reconstruct_even_odd(msb_ref[...], lsb_ref[...])
    q = q_ref[...]
    dn = (((1,), (0,)), ((), ()))
    s = jax.lax.dot_general(de, q[0], dn, preferred_element_type=jnp.int32)
    s += jax.lax.dot_general(do, q[1], dn, preferred_element_type=jnp.int32)
    out_ref[0, :] = s


def _stage2_batched_kernel(q_ref, msb_ref, lsb_ref, out_ref):
    """q_ref: (1, 2, D2) int8; planes: (1, BC, D2) uint8; out: (1, 1, BC).

    Batched variant: grid axis 0 walks batch lanes (each lane rescores its
    OWN gathered candidate rows with its OWN query), axis 1 walks that
    lane's candidate blocks — the whole (B, C) rescore is ONE launch."""
    de, do = _reconstruct_even_odd(msb_ref[0], lsb_ref[0])
    q = q_ref[0]
    dn = (((1,), (0,)), ((), ()))
    s = jax.lax.dot_general(de, q[0], dn, preferred_element_type=jnp.int32)
    s += jax.lax.dot_general(do, q[1], dn, preferred_element_type=jnp.int32)
    out_ref[0, 0, :] = s


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def stage2_int8_batched_pallas(q_eo8: jax.Array, msb_rows: jax.Array,
                               lsb_rows: jax.Array, *,
                               block_c: int = DEFAULT_BLOCK_C,
                               interpret: bool = True) -> jax.Array:
    """q_eo8: (B, 2, D//2) int8 full query values (even dims; odd dims).
    msb_rows/lsb_rows: (B, C, D//2) uint8 gathered per-lane candidates,
    C % block_c == 0. Returns (B, C) int32 exact scores, one launch."""
    b, c, d2 = msb_rows.shape
    assert c % block_c == 0, (c, block_c)
    nb = c // block_c
    out = pl.pallas_call(
        _stage2_batched_kernel,
        grid=(b, nb),
        in_specs=[
            pl.BlockSpec((1, 2, d2), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_c, d2), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, block_c, d2), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_c), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, 1, c), jnp.int32),
        interpret=interpret,
    )(q_eo8, msb_rows, lsb_rows)
    return out[:, 0, :]


@functools.partial(jax.jit, static_argnames=("block_c", "interpret"))
def stage2_int8_pallas(q_eo8: jax.Array, msb_rows: jax.Array,
                       lsb_rows: jax.Array, *,
                       block_c: int = DEFAULT_BLOCK_C,
                       interpret: bool = True) -> jax.Array:
    """q_eo8: (2, D//2) int8 full query values (even dims; odd dims).
    msb_rows/lsb_rows: (C, D//2) uint8, C % block_c == 0. Returns (C,) int32."""
    c, d2 = msb_rows.shape
    assert c % block_c == 0, (c, block_c)
    nb = c // block_c
    out = pl.pallas_call(
        _stage2_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((2, d2), lambda i: (0, 0)),        # query: stationary
            pl.BlockSpec((block_c, d2), lambda i: (i, 0)),
            pl.BlockSpec((block_c, d2), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_c), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_c), jnp.int32),
        interpret=interpret,
    )(q_eo8, msb_rows, lsb_rows)
    return out.reshape(c)
