"""Pallas TPU kernel: block-GATHERED stage-1 MSB-nibble MIPS.

The cluster-pruned cascade's stage 1 must scan only the rows of each
lane's selected clusters. Materializing that gather on the host (copy the
blocks, then run the dense per-lane kernel) would stream every selected
row TWICE — once for the copy, once for the scan. This kernel instead
uses `pltpu.PrefetchScalarGridSpec` scalar prefetch: the per-lane block-id
table is available before the kernel body runs, so each grid step's
BlockSpec index_map DMAs the selected plane block HBM->VMEM directly —
the gather IS the scan's input stream, and unselected blocks are never
touched.

Dataflow per grid step (i = batch lane, j = probe-block slot):

  * the lane's packed query pair stays resident in VMEM across its whole
    block sweep (query-stationary, as in the dense stage-1 kernels);
  * plane block `block_ids[i, j]` streams HBM->VMEM (the data-dependent
    index_map — the only difference from the dense per-lane kernel);
  * nibbles unpack in-register and the MAC runs as an MXU matvec.

block_ids must be pre-clamped to valid blocks (holes -> 0); the caller
masks hole scores downstream via its membership mask, exactly like the
dense paths mask out-of-segment rows. The plane is padded to a block
multiple with zero rows, so out-of-range rows score 0 — the jnp reference
(engine.stage1_gather_batched_jnp) reproduces this bit-for-bit.

The serving runtime's hot-cluster cache drives this SAME kernel over TWO
sources at once: its `plane` operand is the combined ``[arena plane |
device-resident cache slab]`` array, and the prefetched id table mixes
arena-region block ids (cache misses — streamed from HBM) with
slab-region ids (hits — the cache-owned copies, never re-uploaded). The
kernel is indifferent: a block id is a block id; on hardware the slab
region is the natural candidate for pinning in faster memory. That path
is pre-validated host-side, so its jnp reference is the unclamped
engine.stage1_gather_resident_jnp / ref.stage1_gather_resident_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.stage1_int4 import unpack_plane_even_odd

DEFAULT_BLOCK_ROWS = 64


def _stage1_gather_kernel(ids_ref, q_ref, plane_ref, out_ref):
    """ids_ref: (B, J) int32 prefetched block ids (consumed by index_maps);
    q_ref: (1, 2, D2) int8 lane query pair; plane_ref: (BR, D2) uint8 —
    the block the index_map selected; out: (1, 1, BR)."""
    del ids_ref  # only read by the BlockSpec index_maps
    even, odd = unpack_plane_even_odd(plane_ref[...])
    q = q_ref[0]
    dn = (((1,), (0,)), ((), ()))
    s = jax.lax.dot_general(even, q[0], dn, preferred_element_type=jnp.int32)
    s += jax.lax.dot_general(odd, q[1], dn, preferred_element_type=jnp.int32)
    out_ref[0, 0, :] = s


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stage1_int4_gather_pallas(q_eo: jax.Array, msb_plane: jax.Array,
                              block_ids: jax.Array, *,
                              block_rows: int = DEFAULT_BLOCK_ROWS,
                              interpret: bool = True) -> jax.Array:
    """q_eo: (B, 2, D//2) int8 signed MSB nibble pairs (even; odd dims).
    msb_plane: (N, D//2) uint8 with N % block_rows == 0 (zero-padded).
    block_ids: (B, J) int32 ids in [0, N / block_rows) — the lane's
    selected plane blocks, already clamped (no -1 holes).
    Returns (B, J * block_rows) int32: lane i's scores over its gathered
    rows, in block-table order. ONE launch, grid (B, J); only the
    selected blocks ever stream from HBM.
    """
    n, d2 = msb_plane.shape
    b, j = block_ids.shape
    assert n % block_rows == 0, (n, block_rows)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, j),
        in_specs=[
            pl.BlockSpec((1, 2, d2), lambda i, jj, ids: (i, 0, 0)),
            pl.BlockSpec((block_rows, d2),
                         lambda i, jj, ids: (ids[i, jj], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_rows),
                               lambda i, jj, ids: (i, 0, jj)),
    )
    out = pl.pallas_call(
        _stage1_gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1, j * block_rows), jnp.int32),
        interpret=interpret,
    )(block_ids, q_eo, msb_plane)
    return out[:, 0, :]
