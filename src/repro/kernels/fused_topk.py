"""Pallas TPU kernel: fused stage-1 scoring + per-block top-k (beyond-paper).

The baseline stage-1 writes all N int32 scores back to HBM and then runs a
global top-k — an N*4-byte writeback plus an N*4-byte re-read. This kernel
keeps each block's scores in VMEM and emits only that block's top-k
(score, global-id) pairs, shrinking the score writeback from N to
(N / block_n) * k entries (e.g. 256x smaller for block_n=512, k=8 — see
EXPERIMENTS.md §Perf).

Selection is an unrolled-scan iterative argmax (k is small and static),
with ties broken toward the lower index — matching ref.fused_topk_ref
bit-exactly. The final cross-block top-C reduction happens in the wrapper
on (N/block_n)*k entries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.stage1_int4 import unpack_plane_even_odd

DEFAULT_BLOCK_N = 512
INT32_MIN = jnp.iinfo(jnp.int32).min


def _fused_kernel(q_ref, plane_ref, out_s_ref, out_i_ref, *, k: int,
                  block_n: int):
    even, odd = unpack_plane_even_odd(plane_ref[...])
    q = q_ref[...]
    dn = (((1,), (0,)), ((), ()))
    s = jax.lax.dot_general(even, q[0], dn, preferred_element_type=jnp.int32)
    s += jax.lax.dot_general(odd, q[1], dn, preferred_element_type=jnp.int32)

    base = pl.program_id(0) * block_n
    iota = jax.lax.iota(jnp.int32, block_n)

    def step(work, _):
        idx = jnp.argmax(work)                  # lowest index on ties
        val = jnp.max(work)
        work = jnp.where(iota == idx, INT32_MIN, work)
        return work, (val, idx.astype(jnp.int32))

    _, (vals, idxs) = jax.lax.scan(step, s, None, length=k)
    out_s_ref[0, :] = vals
    out_i_ref[0, :] = base + idxs


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def fused_topk_pallas(q_eo: jax.Array, msb_plane: jax.Array, *, k: int = 8,
                      block_n: int = DEFAULT_BLOCK_N,
                      interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """q_eo: (2, D//2) int8 signed MSB nibbles; msb_plane: (N, D//2) uint8.
    Returns (scores, global_ids), each (N // block_n, k) int32."""
    n, d2 = msb_plane.shape
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    kernel = functools.partial(_fused_kernel, k=k, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((2, d2), lambda i: (0, 0)),        # query: stationary
            pl.BlockSpec((block_n, d2), lambda i: (i, 0)),  # docs: streamed
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
        ],
        interpret=interpret,
    )(q_eo, msb_plane)
