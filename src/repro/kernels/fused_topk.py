"""Pallas TPU kernel: fused stage-1 scoring + per-block top-k (beyond-paper).

The baseline stage-1 writes all N int32 scores back to HBM and then runs a
global top-k — an N*4-byte writeback plus an N*4-byte re-read. This kernel
keeps each block's scores in VMEM and emits only that block's top-k
(score, global-id) pairs, shrinking the score writeback from N to
(N / block_n) * k entries (e.g. 256x smaller for block_n=512, k=8 — see
EXPERIMENTS.md §Perf).

Selection is an unrolled-scan iterative argmax (k is small and static),
with ties broken toward the lower index — matching ref.fused_topk_ref
bit-exactly. The final cross-block top-C reduction happens in the wrapper
on (N/block_n)*k entries.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.stage1_int4 import unpack_plane_even_odd

DEFAULT_BLOCK_N = 512
INT32_MIN = jnp.iinfo(jnp.int32).min


def _fused_kernel(q_ref, plane_ref, out_s_ref, out_i_ref, *, k: int,
                  block_n: int):
    even, odd = unpack_plane_even_odd(plane_ref[...])
    q = q_ref[...]
    dn = (((1,), (0,)), ((), ()))
    s = jax.lax.dot_general(even, q[0], dn, preferred_element_type=jnp.int32)
    s += jax.lax.dot_general(odd, q[1], dn, preferred_element_type=jnp.int32)

    base = pl.program_id(0) * block_n
    iota = jax.lax.iota(jnp.int32, block_n)

    def step(work, _):
        idx = jnp.argmax(work)                  # lowest index on ties
        val = jnp.max(work)
        work = jnp.where(iota == idx, INT32_MIN, work)
        return work, (val, idx.astype(jnp.int32))

    _, (vals, idxs) = jax.lax.scan(step, s, None, length=k)
    out_s_ref[0, :] = vals
    out_i_ref[0, :] = base + idxs


def _fused_batched_kernel(q_ref, plane_ref, owner_ref, tid_ref, out_s_ref,
                          out_i_ref, *, k: int, block_n: int, masked: bool):
    """Batched fused stage-1 + per-block top-k, one (doc-block, lane) cell.

    The grid is (num_blocks, BATCH) with the batch axis INNERMOST: the doc
    block's BlockSpec index ignores the lane, so Pallas fetches each plane
    block from HBM once and keeps it VMEM-resident while every lane scores
    it — once-per-batch streaming. With `masked`, the lane's tenant segment
    mask is applied to the scores IN VMEM before selection, so masked rows
    never leave the kernel (no (B, N) masked-score writeback at all)."""
    even, odd = unpack_plane_even_odd(plane_ref[...])
    q = q_ref[0]
    dn = (((1,), (0,)), ((), ()))
    s = jax.lax.dot_general(even, q[0], dn, preferred_element_type=jnp.int32)
    s += jax.lax.dot_general(odd, q[1], dn, preferred_element_type=jnp.int32)
    if masked:
        tid = tid_ref[0]
        member = (owner_ref[0, :] == tid) & (tid >= 0)
        s = jnp.where(member, s, INT32_MIN)

    base = pl.program_id(0) * block_n
    iota = jax.lax.iota(jnp.int32, block_n)

    def step(work, _):
        idx = jnp.argmax(work)                  # lowest index on ties
        val = jnp.max(work)
        work = jnp.where(iota == idx, INT32_MIN, work)
        return work, (val, idx.astype(jnp.int32))

    _, (vals, idxs) = jax.lax.scan(step, s, None, length=k)
    out_s_ref[0, 0, :] = vals
    out_i_ref[0, 0, :] = base + idxs


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def fused_topk_batched_pallas(q_eo: jax.Array, msb_plane: jax.Array,
                              owner: jax.Array | None = None,
                              tenant_ids: jax.Array | None = None, *,
                              k: int = 8, block_n: int = DEFAULT_BLOCK_N,
                              interpret: bool = True
                              ) -> tuple[jax.Array, jax.Array]:
    """q_eo: (B, 2, D//2) int8 signed MSB nibbles; msb_plane: (N, D//2)
    uint8; optionally owner (N,) int32 + tenant_ids (B,) int32 to apply the
    per-lane segment mask inside the kernel (rows outside lane i's tenant
    score INT32_MIN and can never be emitted). Returns (scores, global_ids),
    each (B, N // block_n, k) int32."""
    n, d2 = msb_plane.shape
    b = q_eo.shape[0]
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    masked = owner is not None
    if masked != (tenant_ids is not None):
        raise ValueError("owner and tenant_ids must be passed together")
    kernel = functools.partial(_fused_batched_kernel, k=k, block_n=block_n,
                               masked=masked)
    if not masked:  # zero-size placeholders keep one kernel signature
        owner = jnp.zeros((n,), jnp.int32)
        tenant_ids = jnp.zeros((b,), jnp.int32)
    return pl.pallas_call(
        kernel,
        grid=(nb, b),                                    # lanes innermost
        in_specs=[
            pl.BlockSpec((1, 2, d2), lambda i, j: (j, 0, 0)),   # lane query
            pl.BlockSpec((block_n, d2), lambda i, j: (i, 0)),   # doc block:
            # index ignores j => resident across the whole inner lane sweep
            pl.BlockSpec((1, block_n), lambda i, j: (0, i)),    # owner block
            pl.BlockSpec((1,), lambda i, j: (j,)),              # lane tenant
        ],
        out_specs=[
            pl.BlockSpec((1, 1, k), lambda i, j: (j, i, 0)),
            pl.BlockSpec((1, 1, k), lambda i, j: (j, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, nb, k), jnp.int32),
            jax.ShapeDtypeStruct((b, nb, k), jnp.int32),
        ],
        interpret=interpret,
    )(q_eo, msb_plane, owner.reshape(1, n), tenant_ids)


@functools.partial(jax.jit, static_argnames=("k", "block_n", "interpret"))
def fused_topk_pallas(q_eo: jax.Array, msb_plane: jax.Array, *, k: int = 8,
                      block_n: int = DEFAULT_BLOCK_N,
                      interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """q_eo: (2, D//2) int8 signed MSB nibbles; msb_plane: (N, D//2) uint8.
    Returns (scores, global_ids), each (N // block_n, k) int32."""
    n, d2 = msb_plane.shape
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    kernel = functools.partial(_fused_kernel, k=k, block_n=block_n)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((2, d2), lambda i: (0, 0)),        # query: stationary
            pl.BlockSpec((block_n, d2), lambda i: (i, 0)),  # docs: streamed
        ],
        out_specs=[
            pl.BlockSpec((1, k), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
            jax.ShapeDtypeStruct((nb, k), jnp.int32),
        ],
        interpret=interpret,
    )(q_eo, msb_plane)
