"""jit'd public wrappers around the Pallas kernels.

These expose the same signatures the pure-jnp reference engine uses
(repro.core.retrieval stage functions), handling query even/odd packing,
row padding to block multiples, and interpret-mode selection (interpret on
CPU, compiled Mosaic on TPU).

Block shapes: the tunable wrappers (stage1_* matmuls and the fused top-k)
take `block_n=None` and resolve the block at *trace time* from the
installed `repro.kernels.autotune` table (measured per device and batch
bucket), falling back deterministically to the kernel's `DEFAULT_BLOCK_N`
when no table is installed. Pass an explicit `block_n` to bypass the
table (tests and the autotuner itself do). Block choice never affects
results — only the schedule — which is pinned by the parity suites.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import autotune as _at
from repro.kernels import fused_topk as _fk
from repro.kernels import stage0_sign as _s0
from repro.kernels import stage1_gather as _sg
from repro.kernels import stage1_int4 as _s1
from repro.kernels import stage2_int8 as _s2


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pack_query_even_odd(q: jax.Array) -> jax.Array:
    """(D,) int8 -> (2, D//2) int8: row 0 = even dims, row 1 = odd dims."""
    return jnp.stack([q[0::2], q[1::2]]).astype(jnp.int8)


def pack_queries_even_odd(q: jax.Array) -> jax.Array:
    """(B, D) int8 -> (B, 2, D//2) int8 per-lane [even; odd] panels."""
    return jnp.stack([q[:, 0::2], q[:, 1::2]], axis=1).astype(jnp.int8)


def pack_query_panel(q: jax.Array) -> jax.Array:
    """(B, D) int8 -> (2, B, D//2) int8 batch panels ([even dims; odd dims])
    — the stationary operand of the batched stage-1 matmul kernel."""
    return jnp.stack([q[:, 0::2], q[:, 1::2]]).astype(jnp.int8)


def pack_query_signs(q: jax.Array) -> jax.Array:
    """(B, D) int8 -> (B, D) int8 in {+1, -1} — the stage-0 kernels'
    stationary query operand (kept dense: it is tiny, and pre-unpacking
    it sidesteps a second in-kernel bit unpack). Zero maps to +1,
    matching `bitplanar.unpack_sign_pm1` of the packed doc plane."""
    from repro.core.bitplanar import sign_pm1
    return sign_pm1(q)


def _pad_rows(a: jax.Array, mult: int) -> jax.Array:
    pad = (-a.shape[0]) % mult
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad), (0, 0)))


def _pad_axis1(a: jax.Array, mult: int) -> jax.Array:
    pad = (-a.shape[1]) % mult
    if pad == 0:
        return a
    return jnp.pad(a, ((0, 0), (0, pad), (0, 0)))


def stage1_scores(q_msb: jax.Array, msb_plane: jax.Array,
                  block_n: int | None = None) -> jax.Array:
    """Kernel-backed drop-in for retrieval.stage1_scores_jnp.

    q_msb: (D,) int8 signed MSB nibbles of the query.
    msb_plane: (N, D//2) packed uint8. Returns (N,) int32.
    block_n None -> the installed autotune table's choice (default 1024).
    """
    if block_n is None:
        block_n = _at.lookup("stage1_single", 1, _s1.DEFAULT_BLOCK_N)
    return _stage1_scores_jit(q_msb, msb_plane, block_n)


@functools.partial(jax.jit, static_argnames=("block_n",))
def _stage1_scores_jit(q_msb: jax.Array, msb_plane: jax.Array,
                       block_n: int) -> jax.Array:
    n = msb_plane.shape[0]
    block_n = min(block_n, max(8, n))
    plane = _pad_rows(msb_plane, block_n)
    q_eo = pack_query_even_odd(q_msb)
    out = _s1.stage1_int4_pallas(q_eo, plane, block_n=block_n,
                                 interpret=_interpret())
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_c",))
def stage2_scores(q: jax.Array, msb_rows: jax.Array, lsb_rows: jax.Array,
                  block_c: int = _s2.DEFAULT_BLOCK_C) -> jax.Array:
    """Kernel-backed drop-in for retrieval.stage2_scores_jnp.

    q: (D,) int8 full-precision query codes.
    msb_rows/lsb_rows: (C, D//2) packed uint8 gathered candidates.
    Returns (C,) int32 exact scores.
    """
    c = msb_rows.shape[0]
    block_c = min(block_c, max(8, c))
    msb = _pad_rows(msb_rows, block_c)
    lsb = _pad_rows(lsb_rows, block_c)
    q_eo8 = pack_query_even_odd(q)
    out = _s2.stage2_int8_pallas(q_eo8, msb, lsb, block_c=block_c,
                                 interpret=_interpret())
    return out[:c]


def stage1_scores_batched(q_msb: jax.Array, msb_plane: jax.Array,
                          block_n: int | None = None) -> jax.Array:
    """Kernel-backed drop-in for engine.stage1_plane_batched_jnp.

    q_msb: (B, D) int8 signed MSB nibbles of the whole query batch.
    msb_plane: (N, D//2) packed uint8. Returns (B, N) int32. ONE launch;
    each doc block is streamed from HBM once per BATCH, not once per query.
    block_n None -> the installed autotune table's choice for this batch
    bucket (default 1024).
    """
    if block_n is None:
        block_n = _at.lookup("stage1_batched", q_msb.shape[0],
                             _s1.DEFAULT_BLOCK_N)
    return _stage1_scores_batched_jit(q_msb, msb_plane, block_n)


@functools.partial(jax.jit, static_argnames=("block_n",))
def _stage1_scores_batched_jit(q_msb: jax.Array, msb_plane: jax.Array,
                               block_n: int) -> jax.Array:
    n = msb_plane.shape[0]
    block_n = min(block_n, max(8, n))
    plane = _pad_rows(msb_plane, block_n)
    q_panel = pack_query_panel(q_msb)
    out = _s1.stage1_int4_batched_pallas(q_panel, plane, block_n=block_n,
                                         interpret=_interpret())
    return out[:, :n]


def stage1_scores_rows(q_msb: jax.Array, msb_rows: jax.Array,
                       block_w: int | None = None) -> jax.Array:
    """Kernel-backed drop-in for engine.stage1_rows_batched_jnp.

    q_msb: (B, D) int8 nibbles; msb_rows: (B, W, D//2) per-lane packed row
    blocks (e.g. each tenant's arena window). Returns (B, W) int32.
    block_w None -> the installed autotune table's choice (default 1024)."""
    if block_w is None:
        block_w = _at.lookup("stage1_rows", q_msb.shape[0],
                             _s1.DEFAULT_BLOCK_N)
    return _stage1_scores_rows_jit(q_msb, msb_rows, block_w)


@functools.partial(jax.jit, static_argnames=("block_w",))
def _stage1_scores_rows_jit(q_msb: jax.Array, msb_rows: jax.Array,
                            block_w: int) -> jax.Array:
    w = msb_rows.shape[1]
    block_w = min(block_w, max(8, w))
    rows = _pad_axis1(msb_rows, block_w)
    q_eo = pack_queries_even_odd(q_msb)
    out = _s1.stage1_int4_rows_pallas(q_eo, rows, block_w=block_w,
                                      interpret=_interpret())
    return out[:, :w]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def stage1_scores_gather(q_msb: jax.Array, msb_plane: jax.Array,
                         block_ids: jax.Array, *,
                         block_rows: int = _sg.DEFAULT_BLOCK_ROWS
                         ) -> jax.Array:
    """Kernel-backed drop-in for engine.stage1_gather_batched_jnp.

    q_msb: (B, D) int8 nibbles; msb_plane: (N, D//2) packed uint8;
    block_ids: (B, J) int32 ids of `block_rows`-row plane blocks (already
    clamped to valid blocks). Returns (B, J * block_rows) int32. The
    gather happens INSIDE the kernel via scalar prefetch — only the
    selected blocks stream from HBM; rows past N (the plane's zero
    padding) score 0, matching the jnp reference bit-for-bit.

    When N is not a block_rows multiple the plane is zero-padded HERE,
    which copies it every launch — serving paths size their arenas to a
    block multiple (MultiTenantIndex enforces this) so the pad is a
    no-op and only ad-hoc callers pay it."""
    plane = _pad_rows(msb_plane, block_rows)
    q_eo = pack_queries_even_odd(q_msb)
    return _sg.stage1_int4_gather_pallas(q_eo, plane, block_ids,
                                         block_rows=block_rows,
                                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_rows",))
def stage1_scores_gather_resident(q_msb: jax.Array, plane: jax.Array,
                                  block_ids: jax.Array, *,
                                  block_rows: int = _sg.DEFAULT_BLOCK_ROWS
                                  ) -> jax.Array:
    """The block gather over a RESIDENT, pre-validated plane (slab path).

    Kernel-backed drop-in for engine.stage1_gather_resident_jnp: the
    serving runtime's combined plane+slab array is always a whole number
    of `block_rows` blocks and every id in `block_ids` addresses a live
    block (misses point into the arena region, hits into the cache slab
    region), so the general wrapper's pad-to-multiple step is skipped
    outright instead of being a per-launch no-op check. The kernel's
    contract never included clamping — the gather IS the scan's input
    stream, two memory regions behind one scalar-prefetched id table."""
    n = plane.shape[0]
    if n % block_rows:
        raise ValueError(f"resident plane must be a block multiple, got "
                         f"{n} rows with block_rows={block_rows}")
    q_eo = pack_queries_even_odd(q_msb)
    return _sg.stage1_int4_gather_pallas(q_eo, plane, block_ids,
                                         block_rows=block_rows,
                                         interpret=_interpret())


def stage0_sign_scores_batched(q_sign: jax.Array, sign_plane: jax.Array,
                               block_n: int | None = None) -> jax.Array:
    """Kernel-backed drop-in for engine.stage0_sign_plane_batched_jnp.

    q_sign: (B, D) int8 in {+1, -1} (pack_query_signs); sign_plane:
    (N, D//8) packed uint8. Returns (B, N) int32 sign-agreement scores.
    ONE launch; each sign block streams from HBM once per BATCH.
    block_n None -> the installed autotune table's choice for this batch
    bucket ("stage0_sign" family, default 1024)."""
    if block_n is None:
        block_n = _at.lookup("stage0_sign", q_sign.shape[0],
                             _s0.DEFAULT_BLOCK_N)
    return _stage0_sign_scores_batched_jit(q_sign, sign_plane, block_n)


@functools.partial(jax.jit, static_argnames=("block_n",))
def _stage0_sign_scores_batched_jit(q_sign: jax.Array, sign_plane: jax.Array,
                                    block_n: int) -> jax.Array:
    n = sign_plane.shape[0]
    block_n = min(block_n, max(8, n))
    plane = _pad_rows(sign_plane, block_n)
    out = _s0.stage0_sign_batched_pallas(q_sign, plane, block_n=block_n,
                                         interpret=_interpret())
    return out[:, :n]


@functools.partial(jax.jit, static_argnames=("block_rows",))
def stage0_sign_scores_gather(q_sign: jax.Array, sign_plane: jax.Array,
                              block_ids: jax.Array, *,
                              block_rows: int = _sg.DEFAULT_BLOCK_ROWS
                              ) -> jax.Array:
    """Kernel-backed drop-in for engine.stage0_sign_gather_batched_jnp.

    q_sign: (B, D) int8 {+1, -1}; sign_plane: (N, D//8) packed uint8;
    block_ids: (B, J) int32 clamped block ids — the SAME table the
    stage-1 gather consumes. Returns (B, J * block_rows) int32. The
    plane is zero-padded to a block multiple here (a no-op for arenas
    sized to a block multiple); zero bytes unpack to all-+1 rows on both
    backends and are masked downstream."""
    plane = _pad_rows(sign_plane, block_rows)
    return _s0.stage0_sign_gather_pallas(q_sign, plane, block_ids,
                                         block_rows=block_rows,
                                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_rows",))
def stage0_sign_scores_gather_resident(q_sign: jax.Array, plane: jax.Array,
                                       block_ids: jax.Array, *,
                                       block_rows: int = _sg.DEFAULT_BLOCK_ROWS
                                       ) -> jax.Array:
    """The stage-0 gather over a RESIDENT, pre-validated combined sign
    plane (the slab path) — same contract as
    stage1_scores_gather_resident, one plane-width narrower."""
    n = plane.shape[0]
    if n % block_rows:
        raise ValueError(f"resident sign plane must be a block multiple, "
                         f"got {n} rows with block_rows={block_rows}")
    return _s0.stage0_sign_gather_pallas(q_sign, plane, block_ids,
                                         block_rows=block_rows,
                                         interpret=_interpret())


@functools.partial(jax.jit, static_argnames=("block_k",))
def centroid_scores_batched(q_msb: jax.Array, centroid_msb: jax.Array,
                            block_k: int = _s1.DEFAULT_BLOCK_N) -> jax.Array:
    """Batched centroid scoring for the cascade's stage-0 prune.

    The codebook is stored exactly like the corpus — a packed MSB nibble
    plane — so this IS the batched stage-1 matmul kernel applied to the
    (K, D//2) centroid plane: q_msb (B, D) int8 nibbles -> (B, K) int32.
    The whole codebook is one or two VMEM-resident blocks (K is small),
    streamed once per batch."""
    return stage1_scores_batched(q_msb, centroid_msb, block_n=block_k)


def centroid_scores_rows(q_msb: jax.Array, centroid_rows: jax.Array,
                         block_p: int | None = None) -> jax.Array:
    """Per-lane centroid scoring for the KV-decode page prune.

    Unlike the shared-codebook `centroid_scores_batched`, each query lane
    carries its OWN codebook — the page centroids of one (batch, kv-head)
    cache lane, `(B, P, D//2)` packed MSB nibbles — so this is the
    per-lane-rows stage-1 kernel applied to centroid planes:
    q_msb (B, D) int8 nibbles -> (B, P) int32. P (pages per lane) is
    small, so the codebook block is VMEM-resident per lane."""
    return stage1_scores_rows(q_msb, centroid_rows, block_w=block_p)


@functools.partial(jax.jit, static_argnames=("block_c",))
def stage2_scores_batched(q: jax.Array, msb_rows: jax.Array,
                          lsb_rows: jax.Array,
                          block_c: int = _s2.DEFAULT_BLOCK_C) -> jax.Array:
    """Kernel-backed drop-in for engine.stage2_rows_batched_jnp.

    q: (B, D) int8 full-precision queries; msb_rows/lsb_rows: (B, C, D//2)
    gathered per-lane candidate planes. Returns (B, C) int32, ONE launch."""
    c = msb_rows.shape[1]
    block_c = min(block_c, max(8, c))
    msb = _pad_axis1(msb_rows, block_c)
    lsb = _pad_axis1(lsb_rows, block_c)
    q_eo8 = pack_queries_even_odd(q)
    out = _s2.stage2_int8_batched_pallas(q_eo8, msb, lsb, block_c=block_c,
                                         interpret=_interpret())
    return out[:, :c]


def fused_candidates_batched(q_msb: jax.Array, msb_plane: jax.Array,
                             owner: jax.Array | None = None,
                             tenant_ids: jax.Array | None = None, *, c: int,
                             k_per_block: int = 8,
                             block_n: int | None = None) -> jax.Array:
    """Batched fused stage-1 candidate generation (optionally masked).
    block_n None -> the installed autotune table's choice (default 512).

    q_msb: (B, D) int8 nibbles. With owner/tenant_ids, each lane's tenant
    segment mask is applied INSIDE the kernel, so out-of-segment scores
    never leave VMEM. Returns (B, c) int32 global doc ids; same exactness
    condition as `fused_candidates` per lane. Lanes whose live segment is
    smaller than c pad with masked entries (id < n but score INT32_MIN
    upstream — callers mask via membership like the dense path)."""
    if block_n is None:
        block_n = _at.lookup("fused_topk", q_msb.shape[0],
                             _fk.DEFAULT_BLOCK_N)
    return _fused_candidates_batched_jit(q_msb, msb_plane, owner, tenant_ids,
                                         c=c, k_per_block=k_per_block,
                                         block_n=block_n)


@functools.partial(jax.jit, static_argnames=("c", "k_per_block", "block_n"))
def _fused_candidates_batched_jit(q_msb: jax.Array, msb_plane: jax.Array,
                                  owner: jax.Array | None = None,
                                  tenant_ids: jax.Array | None = None, *,
                                  c: int, k_per_block: int = 8,
                                  block_n: int = _fk.DEFAULT_BLOCK_N
                                  ) -> jax.Array:
    n = msb_plane.shape[0]
    block_n = min(block_n, max(8, n))
    plane = _pad_rows(msb_plane, block_n)
    if owner is not None:
        owner = jnp.pad(owner, (0, plane.shape[0] - n),
                        constant_values=-1)           # padding rows: no owner
    q_eo = pack_queries_even_odd(q_msb)
    scores, ids = _fk.fused_topk_batched_pallas(
        q_eo, plane, owner, tenant_ids, k=k_per_block, block_n=block_n,
        interpret=_interpret())
    flat_s = scores.reshape(scores.shape[0], -1)
    flat_i = ids.reshape(ids.shape[0], -1)
    flat_s = jnp.where(flat_i < n, flat_s, jnp.iinfo(jnp.int32).min)
    _, sel = jax.lax.top_k(flat_s, c)
    return jnp.take_along_axis(flat_i, sel, axis=1)


def fused_candidates(q_msb: jax.Array, msb_plane: jax.Array, *, c: int,
                     k_per_block: int = 8,
                     block_n: int | None = None) -> jax.Array:
    """Stage-1 candidate generation via the fused score+top-k kernel.

    Returns (c,) int32 global doc ids (approximate top-c). Exact whenever
    c <= k_per_block * num_blocks and no block contributes more than
    k_per_block of the true top-c (guaranteed when k_per_block >= c or by
    choosing k_per_block >= c / num_blocks safety factor — see tests).
    block_n None -> the installed autotune table's choice (default 512).
    """
    if block_n is None:
        block_n = _at.lookup("fused_topk", 1, _fk.DEFAULT_BLOCK_N)
    return _fused_candidates_jit(q_msb, msb_plane, c=c,
                                 k_per_block=k_per_block, block_n=block_n)


@functools.partial(jax.jit, static_argnames=("c", "k_per_block", "block_n"))
def _fused_candidates_jit(q_msb: jax.Array, msb_plane: jax.Array, *, c: int,
                          k_per_block: int = 8,
                          block_n: int = _fk.DEFAULT_BLOCK_N) -> jax.Array:
    n = msb_plane.shape[0]
    block_n = min(block_n, max(8, n))
    plane = _pad_rows(msb_plane, block_n)
    q_eo = pack_query_even_odd(q_msb)
    scores, ids = _fk.fused_topk_pallas(q_eo, plane, k=k_per_block,
                                        block_n=block_n,
                                        interpret=_interpret())
    flat_s = scores.reshape(-1)
    flat_i = ids.reshape(-1)
    # padded rows score 0 with id >= n; mask them out
    flat_s = jnp.where(flat_i < n, flat_s, jnp.iinfo(jnp.int32).min)
    _, sel = jax.lax.top_k(flat_s, c)
    return flat_i[sel]
