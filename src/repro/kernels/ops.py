"""jit'd public wrappers around the Pallas kernels.

These expose the same signatures the pure-jnp reference engine uses
(repro.core.retrieval stage functions), handling query even/odd packing,
row padding to block multiples, and interpret-mode selection (interpret on
CPU, compiled Mosaic on TPU).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import fused_topk as _fk
from repro.kernels import stage1_int4 as _s1
from repro.kernels import stage2_int8 as _s2


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


def pack_query_even_odd(q: jax.Array) -> jax.Array:
    """(D,) int8 -> (2, D//2) int8: row 0 = even dims, row 1 = odd dims."""
    return jnp.stack([q[0::2], q[1::2]]).astype(jnp.int8)


def _pad_rows(a: jax.Array, mult: int) -> jax.Array:
    pad = (-a.shape[0]) % mult
    if pad == 0:
        return a
    return jnp.pad(a, ((0, pad), (0, 0)))


@functools.partial(jax.jit, static_argnames=("block_n",))
def stage1_scores(q_msb: jax.Array, msb_plane: jax.Array,
                  block_n: int = _s1.DEFAULT_BLOCK_N) -> jax.Array:
    """Kernel-backed drop-in for retrieval.stage1_scores_jnp.

    q_msb: (D,) int8 signed MSB nibbles of the query.
    msb_plane: (N, D//2) packed uint8. Returns (N,) int32.
    """
    n = msb_plane.shape[0]
    block_n = min(block_n, max(8, n))
    plane = _pad_rows(msb_plane, block_n)
    q_eo = pack_query_even_odd(q_msb)
    out = _s1.stage1_int4_pallas(q_eo, plane, block_n=block_n,
                                 interpret=_interpret())
    return out[:n]


@functools.partial(jax.jit, static_argnames=("block_c",))
def stage2_scores(q: jax.Array, msb_rows: jax.Array, lsb_rows: jax.Array,
                  block_c: int = _s2.DEFAULT_BLOCK_C) -> jax.Array:
    """Kernel-backed drop-in for retrieval.stage2_scores_jnp.

    q: (D,) int8 full-precision query codes.
    msb_rows/lsb_rows: (C, D//2) packed uint8 gathered candidates.
    Returns (C,) int32 exact scores.
    """
    c = msb_rows.shape[0]
    block_c = min(block_c, max(8, c))
    msb = _pad_rows(msb_rows, block_c)
    lsb = _pad_rows(lsb_rows, block_c)
    q_eo8 = pack_query_even_odd(q)
    out = _s2.stage2_int8_pallas(q_eo8, msb, lsb, block_c=block_c,
                                 interpret=_interpret())
    return out[:c]


@functools.partial(jax.jit, static_argnames=("c", "k_per_block", "block_n"))
def fused_candidates(q_msb: jax.Array, msb_plane: jax.Array, *, c: int,
                     k_per_block: int = 8,
                     block_n: int = _fk.DEFAULT_BLOCK_N) -> jax.Array:
    """Stage-1 candidate generation via the fused score+top-k kernel.

    Returns (c,) int32 global doc ids (approximate top-c). Exact whenever
    c <= k_per_block * num_blocks and no block contributes more than
    k_per_block of the true top-c (guaranteed when k_per_block >= c or by
    choosing k_per_block >= c / num_blocks safety factor — see tests).
    """
    n = msb_plane.shape[0]
    block_n = min(block_n, max(8, n))
    plane = _pad_rows(msb_plane, block_n)
    q_eo = pack_query_even_odd(q_msb)
    scores, ids = _fk.fused_topk_pallas(q_eo, plane, k=k_per_block,
                                        block_n=block_n,
                                        interpret=_interpret())
    flat_s = scores.reshape(-1)
    flat_i = ids.reshape(-1)
    # padded rows score 0 with id >= n; mask them out
    flat_s = jnp.where(flat_i < n, flat_s, jnp.iinfo(jnp.int32).min)
    _, sel = jax.lax.top_k(flat_s, c)
    return flat_i[sel]
