"""Pallas TPU kernel: stage-1 MSB-nibble (INT4) MIPS, query-stationary.

Maps the paper's query-stationary PE dataflow onto a Pallas pipeline:

  * the packed query block's BlockSpec index_map returns (0, 0) for every
    grid step, so the query tile stays RESIDENT in VMEM (query-stationary);
  * document MSB-plane blocks stream HBM->VMEM through the grid — only the
    MSB nibble plane is ever touched (half the HBM bytes, the bit-planar
    saving);
  * nibbles are unpacked in-register (VREG) and the MAC runs on the MXU via
    int8 x int8 -> int32 dot_general with a 256-deep contraction
    (D/2 = 256 = 2 x 128, MXU-aligned).

The packed byte holds dim 2j in its low nibble and dim 2j+1 in its high
nibble, so instead of interleaving (a lane shuffle the MXU hates) we split
the QUERY into even/odd dim vectors and accumulate two matvecs:

    score = lo_nibbles @ q_even + hi_nibbles @ q_odd
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 1024 doc rows per grid step. At D=512 a block is 1024 x 256 bytes =
# 256 KiB of VMEM (512 KiB double-buffered) — comfortably inside a TPU
# core's ~16 MiB budget, MXU-aligned (the contraction stays D/2-deep).
# This is the deterministic FALLBACK shape: the measured autotuner
# (repro.kernels.autotune) owns the per-device, per-batch-bucket choice
# and the ops.py wrappers consult its installed table first. 1024 remains
# a sane default because per-grid-step interpreter overhead on the CPU
# path dominates below ~512 rows/block (the 256-row block once measured
# 0.76x the jnp reference). See README "kernel block autotuner".
DEFAULT_BLOCK_N = 1024
INT32_MIN = jnp.iinfo(jnp.int32).min


def _sext4_i8(nib_u8: jax.Array) -> jax.Array:
    """Sign-extend 4-bit two's complement (in uint8) -> int8 in [-8, 7]."""
    return ((nib_u8 ^ jnp.uint8(8)).astype(jnp.int8) - jnp.int8(8))


def unpack_plane_even_odd(plane: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(BN, D2) packed uint8 -> (even, odd) signed int8 nibble matrices."""
    even = _sext4_i8(plane & jnp.uint8(0xF))
    odd = _sext4_i8((plane >> 4) & jnp.uint8(0xF))
    return even, odd


def _stage1_kernel(q_ref, plane_ref, out_ref):
    """q_ref: (2, D2) int8 pinned; plane_ref: (BN, D2) uint8; out: (1, BN)."""
    even, odd = unpack_plane_even_odd(plane_ref[...])
    q = q_ref[...]
    dn = (((1,), (0,)), ((), ()))
    s = jax.lax.dot_general(even, q[0], dn, preferred_element_type=jnp.int32)
    s += jax.lax.dot_general(odd, q[1], dn, preferred_element_type=jnp.int32)
    out_ref[0, :] = s


def _stage1_batched_kernel(q_ref, plane_ref, out_ref):
    """q_ref: (2, B, D2) int8 pinned; plane_ref: (BN, D2) uint8; out: (B, BN).

    The MAC is a TRUE matmul — (BN, D2) doc block x (D2, B) query panel —
    so the MXU sees a B-wide contraction instead of B repeated matvecs,
    and each doc block is unpacked (and fetched from HBM) once PER BATCH.
    """
    even, odd = unpack_plane_even_odd(plane_ref[...])
    q = q_ref[...]
    dn = (((1,), (1,)), ((), ()))
    s = jax.lax.dot_general(q[0], even, dn, preferred_element_type=jnp.int32)
    s += jax.lax.dot_general(q[1], odd, dn, preferred_element_type=jnp.int32)
    out_ref[...] = s


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def stage1_int4_batched_pallas(q_eo: jax.Array, msb_plane: jax.Array, *,
                               block_n: int = DEFAULT_BLOCK_N,
                               interpret: bool = True) -> jax.Array:
    """Batch-native stage 1: q_eo (2, B, D//2) int8 signed MSB nibbles
    (even dims; odd dims), msb_plane (N, D//2) uint8, N % block_n == 0.
    Returns (B, N) int32. The query panel is grid-invariant (stationary in
    VMEM); every doc block streams HBM->VMEM exactly once for the whole
    batch — the bytes-streamed win over vmapping the scalar kernel."""
    n, d2 = msb_plane.shape
    b = q_eo.shape[1]
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    out = pl.pallas_call(
        _stage1_batched_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((2, b, d2), lambda i: (0, 0, 0)),  # queries: pinned
            pl.BlockSpec((block_n, d2), lambda i: (i, 0)),  # docs: streamed
        ],
        out_specs=pl.BlockSpec((b, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        interpret=interpret,
    )(q_eo, msb_plane)
    return out


def _stage1_rows_kernel(q_ref, rows_ref, out_ref):
    """q_ref: (1, 2, D2) int8; rows_ref: (1, BW, D2) uint8; out: (1, 1, BW).

    Per-lane variant for the windowed policy: grid axis 0 walks batch
    lanes (each with its OWN row block, e.g. a tenant's arena window),
    axis 1 walks that lane's row blocks."""
    even, odd = unpack_plane_even_odd(rows_ref[0])
    q = q_ref[0]
    dn = (((1,), (0,)), ((), ()))
    s = jax.lax.dot_general(even, q[0], dn, preferred_element_type=jnp.int32)
    s += jax.lax.dot_general(odd, q[1], dn, preferred_element_type=jnp.int32)
    out_ref[0, 0, :] = s


@functools.partial(jax.jit, static_argnames=("block_w", "interpret"))
def stage1_int4_rows_pallas(q_eo: jax.Array, msb_rows: jax.Array, *,
                            block_w: int = DEFAULT_BLOCK_N,
                            interpret: bool = True) -> jax.Array:
    """Per-lane-rows stage 1: q_eo (B, 2, D//2) int8 nibbles, msb_rows
    (B, W, D//2) uint8 with W % block_w == 0. Returns (B, W) int32 — one
    launch for the whole batch (grid (B, W/block_w))."""
    b, w, d2 = msb_rows.shape
    assert w % block_w == 0, (w, block_w)
    nw = w // block_w
    out = pl.pallas_call(
        _stage1_rows_kernel,
        grid=(b, nw),
        in_specs=[
            pl.BlockSpec((1, 2, d2), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((1, block_w, d2), lambda i, j: (i, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_w), lambda i, j: (i, 0, j)),
        out_shape=jax.ShapeDtypeStruct((b, 1, w), jnp.int32),
        interpret=interpret,
    )(q_eo, msb_rows)
    return out[:, 0, :]


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def stage1_int4_pallas(q_eo: jax.Array, msb_plane: jax.Array, *,
                       block_n: int = DEFAULT_BLOCK_N,
                       interpret: bool = True) -> jax.Array:
    """q_eo: (2, D//2) int8 signed MSB nibbles (even dims; odd dims).
    msb_plane: (N, D//2) uint8, N % block_n == 0. Returns (N,) int32."""
    n, d2 = msb_plane.shape
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    out = pl.pallas_call(
        _stage1_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((2, d2), lambda i: (0, 0)),       # query: stationary
            pl.BlockSpec((block_n, d2), lambda i: (i, 0)),  # docs: streamed
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_n), jnp.int32),
        interpret=interpret,
    )(q_eo, msb_plane)
    return out.reshape(n)
