"""Pallas TPU kernel: stage-1 MSB-nibble (INT4) MIPS, query-stationary.

Maps the paper's query-stationary PE dataflow onto a Pallas pipeline:

  * the packed query block's BlockSpec index_map returns (0, 0) for every
    grid step, so the query tile stays RESIDENT in VMEM (query-stationary);
  * document MSB-plane blocks stream HBM->VMEM through the grid — only the
    MSB nibble plane is ever touched (half the HBM bytes, the bit-planar
    saving);
  * nibbles are unpacked in-register (VREG) and the MAC runs on the MXU via
    int8 x int8 -> int32 dot_general with a 256-deep contraction
    (D/2 = 256 = 2 x 128, MXU-aligned).

The packed byte holds dim 2j in its low nibble and dim 2j+1 in its high
nibble, so instead of interleaving (a lane shuffle the MXU hates) we split
the QUERY into even/odd dim vectors and accumulate two matvecs:

    score = lo_nibbles @ q_even + hi_nibbles @ q_odd
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 256
INT32_MIN = jnp.iinfo(jnp.int32).min


def _sext4_i8(nib_u8: jax.Array) -> jax.Array:
    """Sign-extend 4-bit two's complement (in uint8) -> int8 in [-8, 7]."""
    return ((nib_u8 ^ jnp.uint8(8)).astype(jnp.int8) - jnp.int8(8))


def unpack_plane_even_odd(plane: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(BN, D2) packed uint8 -> (even, odd) signed int8 nibble matrices."""
    even = _sext4_i8(plane & jnp.uint8(0xF))
    odd = _sext4_i8((plane >> 4) & jnp.uint8(0xF))
    return even, odd


def _stage1_kernel(q_ref, plane_ref, out_ref):
    """q_ref: (2, D2) int8 pinned; plane_ref: (BN, D2) uint8; out: (1, BN)."""
    even, odd = unpack_plane_even_odd(plane_ref[...])
    q = q_ref[...]
    dn = (((1,), (0,)), ((), ()))
    s = jax.lax.dot_general(even, q[0], dn, preferred_element_type=jnp.int32)
    s += jax.lax.dot_general(odd, q[1], dn, preferred_element_type=jnp.int32)
    out_ref[0, :] = s


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def stage1_int4_pallas(q_eo: jax.Array, msb_plane: jax.Array, *,
                       block_n: int = DEFAULT_BLOCK_N,
                       interpret: bool = True) -> jax.Array:
    """q_eo: (2, D//2) int8 signed MSB nibbles (even dims; odd dims).
    msb_plane: (N, D//2) uint8, N % block_n == 0. Returns (N,) int32."""
    n, d2 = msb_plane.shape
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    out = pl.pallas_call(
        _stage1_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((2, d2), lambda i: (0, 0)),       # query: stationary
            pl.BlockSpec((block_n, d2), lambda i: (i, 0)),  # docs: streamed
        ],
        out_specs=pl.BlockSpec((1, block_n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, block_n), jnp.int32),
        interpret=interpret,
    )(q_eo, msb_plane)
    return out.reshape(n)
