"""Pallas TPU kernels for the paper's compute hot-spots.

  stage1_int4   — query-stationary MSB-nibble MIPS over the whole corpus
  stage1_gather — block-GATHERED stage-1 for the cluster-pruned cascade
                  (scalar-prefetch DMA: only selected blocks stream)
  stage2_int8   — exact INT8 rescoring of the gathered candidate set
  fused_topk    — stage-1 scoring fused with per-block top-k (beyond-paper)

ops.py: jit'd wrappers (interpret on CPU, Mosaic on TPU).
ref.py: pure-jnp oracles; tests assert exact equality against them.
autotune.py: measured block-shape search; ops wrappers consult the
installed table (falling back to DEFAULT_BLOCK_N when none).
"""
from repro.kernels import autotune, ops, ref
from repro.kernels.stage1_int4 import stage1_int4_pallas
from repro.kernels.stage1_gather import stage1_int4_gather_pallas
from repro.kernels.stage2_int8 import stage2_int8_pallas
from repro.kernels.fused_topk import fused_topk_pallas
