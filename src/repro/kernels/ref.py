"""Pure-jnp oracles for every Pallas kernel in this package.

Each function computes exactly what the corresponding kernel computes,
with no Pallas involvement. Kernel tests sweep shapes/dtypes and
assert_allclose (exact equality for the integer kernels) against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _sext4(nib_u8: jax.Array) -> jax.Array:
    """Sign-extend a 4-bit two's-complement nibble held in uint8 -> int32."""
    n = nib_u8.astype(jnp.int32)
    return jnp.where(n >= 8, n - 16, n)


def unpack_even_odd_signed(plane: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(N, D//2) packed uint8 -> signed nibbles of (even dims, odd dims)."""
    even = _sext4(plane & jnp.uint8(0xF))
    odd = _sext4((plane >> 4) & jnp.uint8(0xF))
    return even, odd


def unpack_even_odd_unsigned(plane: jax.Array) -> tuple[jax.Array, jax.Array]:
    even = (plane & jnp.uint8(0xF)).astype(jnp.int32)
    odd = ((plane >> 4) & jnp.uint8(0xF)).astype(jnp.int32)
    return even, odd


def stage1_scores_ref(q_eo: jax.Array, msb_plane: jax.Array) -> jax.Array:
    """Oracle for the stage-1 MSB-nibble MIPS kernel.

    q_eo: (2, D//2) int32/int8 — row 0 = query MSB nibbles of even dims,
          row 1 = odd dims (signed values in [-8, 7]).
    msb_plane: (N, D//2) uint8 packed MSB nibbles.
    Returns (N,) int32 approximate scores.
    """
    even, odd = unpack_even_odd_signed(msb_plane)       # (N, D//2) int32
    q = q_eo.astype(jnp.int32)
    return even @ q[0] + odd @ q[1]


def stage2_scores_ref(q_eo8: jax.Array, msb_rows: jax.Array,
                      lsb_rows: jax.Array) -> jax.Array:
    """Oracle for the stage-2 full-INT8 rescoring kernel.

    q_eo8: (2, D//2) int32/int8 — full INT8 query values (even, odd dims).
    msb_rows/lsb_rows: (C, D//2) uint8 packed candidate planes.
    Returns (C,) int32 exact INT8 dot products.
    """
    me, mo = unpack_even_odd_signed(msb_rows)
    le, lo_ = unpack_even_odd_unsigned(lsb_rows)
    de = me * 16 + le                                    # int32 values [-128,127]
    do = mo * 16 + lo_
    q = q_eo8.astype(jnp.int32)
    return de @ q[0] + do @ q[1]


def stage1_scores_batched_ref(q_eo: jax.Array,
                              msb_plane: jax.Array) -> jax.Array:
    """Oracle for the batched stage-1 matmul kernel.

    q_eo: (2, B, D//2) — [even dims; odd dims] panels of the whole batch.
    Returns (B, N) int32."""
    even, odd = unpack_even_odd_signed(msb_plane)        # (N, D//2) int32
    q = q_eo.astype(jnp.int32)
    return q[0] @ even.T + q[1] @ odd.T


def stage1_rows_batched_ref(q_eo: jax.Array, msb_rows: jax.Array) -> jax.Array:
    """Oracle for the per-lane-rows stage-1 kernel.

    q_eo: (B, 2, D//2); msb_rows: (B, W, D//2). Returns (B, W) int32."""
    return jnp.stack([stage1_scores_ref(q_eo[i], msb_rows[i])
                      for i in range(msb_rows.shape[0])])


def centroid_scores_rows_ref(q_eo: jax.Array,
                             centroid_rows: jax.Array) -> jax.Array:
    """Oracle for the per-lane centroid-rows kernel (KV page prune).

    Each lane scores its own page-centroid codebook; numerically this IS
    the per-lane-rows oracle with W = pages. q_eo: (B, 2, D//2);
    centroid_rows: (B, P, D//2). Returns (B, P) int32."""
    return stage1_rows_batched_ref(q_eo, centroid_rows)


def stage1_gather_batched_ref(q_eo: jax.Array, msb_plane: jax.Array,
                              block_ids: jax.Array,
                              block_rows: int) -> jax.Array:
    """Oracle for the block-gathered stage-1 kernel.

    q_eo: (B, 2, D//2); msb_plane: (N, D//2); block_ids: (B, J) int32
    clamped block ids. Returns (B, J * block_rows) int32; rows past the
    plane's end score 0 — the row-expansion/zero-pad convention lives in
    bitplanar.gather_blocks (shared with the kernel's padded plane), so
    the oracle can only diverge in the scoring math itself."""
    from repro.core.bitplanar import gather_blocks
    gathered, _ = gather_blocks(msb_plane, block_ids, block_rows)
    return jnp.stack([stage1_scores_ref(q_eo[i], gathered[i])
                      for i in range(block_ids.shape[0])])


def stage1_gather_resident_ref(q_eo: jax.Array, plane: jax.Array,
                               block_ids: jax.Array,
                               block_rows: int) -> jax.Array:
    """Oracle for the gather kernel over a RESIDENT pre-validated plane
    (the serving runtime's combined plane+slab array: every block id is
    live, the plane is a whole number of blocks, so no clamp or zero-row
    convention applies — pure gather + score)."""
    from repro.core.bitplanar import expand_block_rows
    rows = expand_block_rows(block_ids, block_rows)
    gathered = jnp.take(plane, rows, axis=0)
    return jnp.stack([stage1_scores_ref(q_eo[i], gathered[i])
                      for i in range(block_ids.shape[0])])


def stage0_sign_batched_ref(q_sign: jax.Array,
                            sign_plane: jax.Array) -> jax.Array:
    """Oracle for the batched stage-0 sign-agreement kernel.

    q_sign: (B, D) int8 in {+1, -1}; sign_plane: (N, D//8) packed uint8.
    Returns (B, N) int32 scores ``sum_k sign(q_k) * sign(d_k)`` — the
    monotone-equivalent form of the XNOR-popcount agreement count."""
    from repro.core.bitplanar import unpack_sign_pm1
    docs = unpack_sign_pm1(sign_plane).astype(jnp.int32)      # (N, D)
    return q_sign.astype(jnp.int32) @ docs.T


def stage0_sign_gather_ref(q_sign: jax.Array, sign_plane: jax.Array,
                           block_ids: jax.Array,
                           block_rows: int) -> jax.Array:
    """Oracle for the block-gathered stage-0 kernel.

    q_sign: (B, D) int8 {+1, -1}; sign_plane: (N, D//8); block_ids:
    (B, J) int32 clamped block ids. Returns (B, J * block_rows) int32.
    Rows past the plane's end gather ZERO BYTES (bitplanar.gather_blocks,
    shared with the kernel's zero-padded plane), which unpack to all-+1
    rows scoring ``sum_k sign(q_k)`` — identical on both backends and
    masked downstream by membership."""
    from repro.core.bitplanar import gather_blocks, unpack_sign_pm1
    gathered, _ = gather_blocks(sign_plane, block_ids, block_rows)
    docs = unpack_sign_pm1(gathered).astype(jnp.int32)        # (B, R, D)
    return jnp.einsum("bd,brd->br", q_sign.astype(jnp.int32), docs)


def stage0_sign_gather_resident_ref(q_sign: jax.Array, sign_plane: jax.Array,
                                    block_ids: jax.Array,
                                    block_rows: int) -> jax.Array:
    """Oracle for the stage-0 gather over a RESIDENT pre-validated sign
    plane (the serving runtime's combined plane+slab sign array — every
    block id live, plane a whole number of blocks, no clamp/zero-byte
    convention: pure gather + sign dot)."""
    from repro.core.bitplanar import expand_block_rows, unpack_sign_pm1
    rows = expand_block_rows(block_ids, block_rows)
    docs = unpack_sign_pm1(jnp.take(sign_plane, rows, axis=0))
    return jnp.einsum("bd,brd->br", q_sign.astype(jnp.int32),
                      docs.astype(jnp.int32))


def stage2_scores_batched_ref(q_eo8: jax.Array, msb_rows: jax.Array,
                              lsb_rows: jax.Array) -> jax.Array:
    """Oracle for the batched stage-2 rescoring kernel.

    q_eo8: (B, 2, D//2); msb_rows/lsb_rows: (B, C, D//2). Returns (B, C)."""
    return jnp.stack([stage2_scores_ref(q_eo8[i], msb_rows[i], lsb_rows[i])
                      for i in range(msb_rows.shape[0])])


def fused_topk_batched_ref(q_eo: jax.Array, msb_plane: jax.Array,
                           block_n: int, k: int,
                           owner: jax.Array | None = None,
                           tenant_ids: jax.Array | None = None
                           ) -> tuple[jax.Array, jax.Array]:
    """Oracle for the batched (optionally segment-masked) fused kernel.

    Returns (scores, ids), each (B, num_blocks, k)."""
    outs_s, outs_i = [], []
    for i in range(q_eo.shape[0]):
        if owner is None:
            s, gid = fused_topk_ref(q_eo[i], msb_plane, block_n, k)
        else:
            scores = stage1_scores_ref(q_eo[i], msb_plane)
            member = (owner == tenant_ids[i]) & (tenant_ids[i] >= 0)
            scores = jnp.where(member, scores, jnp.iinfo(jnp.int32).min)
            s, gid = _blockwise_topk(scores, block_n, k)
        outs_s.append(s)
        outs_i.append(gid)
    return jnp.stack(outs_s), jnp.stack(outs_i)


def _blockwise_topk(scores: jax.Array, block_n: int,
                    k: int) -> tuple[jax.Array, jax.Array]:
    """Per-block iterative argmax with low-index tie-break on given scores."""
    n = scores.shape[0]
    assert n % block_n == 0
    work = scores.reshape(n // block_n, block_n)
    idx_base = jnp.arange(n, dtype=jnp.int32).reshape(n // block_n, block_n)
    out_s, out_i = [], []
    for _ in range(k):
        j = jnp.argmax(work, axis=1)
        rows = jnp.arange(work.shape[0])
        out_s.append(work[rows, j])
        out_i.append(idx_base[rows, j])
        work = work.at[rows, j].set(jnp.iinfo(jnp.int32).min)
    return jnp.stack(out_s, axis=1), jnp.stack(out_i, axis=1)


def fused_topk_ref(q_eo: jax.Array, msb_plane: jax.Array, block_n: int,
                   k: int) -> tuple[jax.Array, jax.Array]:
    """Oracle for the fused stage-1 score + per-block top-k kernel.

    Returns (scores, ids): each (num_blocks, k); ids are GLOBAL row
    indices. Ties broken toward the lower index (matches the kernel's
    iterative argmax).
    """
    # iterative argmax with low-index tie-break == top_k on (score, -idx)
    scores = stage1_scores_ref(q_eo, msb_plane)          # (N,)
    return _blockwise_topk(scores, block_n, k)
