"""Pallas TPU kernel: stage-0 sign-plane (1-bit) prescreen, query-stationary.

The adaptive-precision cascade's cheapest stage: score sign AGREEMENT over
the packed 1-bit sign plane (`bitplanar.pack_sign_plane` — 8 dims/byte,
4x fewer HBM bytes than the stage-1 MSB nibble plane) and keep only the
top-C0 survivors per lane for the INT4 scan. The classical formulation is
an XNOR + popcount; on the MXU the monotone-equivalent form is cheaper:

    agreement-score = sum_k sign(q_k) * sign(d_k) = 2 * agreements - D

so the kernel unpacks each packed doc byte to eight {+1, -1} int8 lanes
in-register (bit set = negative = -1, `bitplanar.unpack_sign_pm1`'s
convention) and runs a plain int8 x int8 -> int32 dot on the MXU. The
query operand arrives PRE-UNPACKED as (B, D) {+1, -1} int8 (`ops.
pack_query_signs`): it is tiny, stays pinned in VMEM across the whole
grid (query-stationary, exactly like the stage-1 kernels), and keeping it
dense sidesteps a second in-kernel unpack.

Two variants mirror the stage-1 pair:

  * `stage0_sign_batched_pallas` — dense batched matmul over the whole
    plane, grid (num_blocks,), doc sign blocks streamed HBM->VMEM once
    per BATCH (the shape `stage1_int4_batched_pallas` uses);
  * `stage0_sign_gather_pallas` — scalar-prefetch block gather driven by
    the SAME per-lane block-id table as the stage-1 gather (the cluster
    prune's output), so only selected clusters' sign blocks ever stream.

Zero bytes (the plane's padding rows and tombstoned rows) unpack to all
+1 dims and score ``sum_k sign(q_k)`` — NOT zero. That is the shared
convention with the jnp reference (`bitplanar.gather_blocks` zeroes the
BYTES, both backends unpack them identically), and every such row is
masked out downstream by the membership mask before any top-k.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Same fallback block shape as the stage-1 kernels: a sign block is 4x
# fewer bytes at equal rows, so 1024 rows x D/8 bytes is comfortably
# VMEM-resident; the measured autotuner ("stage0_sign" family) owns the
# per-device choice.
DEFAULT_BLOCK_N = 1024


def unpack_block_pm1(block_u8: jax.Array) -> jax.Array:
    """(BN, D8) packed uint8 -> (BN, D8*8) int8 in {+1, -1}, in-kernel.

    Dim k = 8 * (k // 8) + k % 8 (byte-major then bit), matching
    `bitplanar.pack_sign_plane`. Shift counts use a 2D+ broadcasted iota
    (TPU Pallas disallows 1D iota)."""
    bn, d8 = block_u8.shape
    shifts = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 8), 2)
    bits = (block_u8[:, :, None].astype(jnp.int32) >> shifts) & 1
    return (1 - 2 * bits).astype(jnp.int8).reshape(bn, d8 * 8)


def _stage0_batched_kernel(q_ref, plane_ref, out_ref):
    """q_ref: (B, D) int8 {+1,-1} pinned; plane_ref: (BN, D8) uint8 packed
    sign bytes; out: (B, BN). True matmul — each doc sign block is
    unpacked (and fetched from HBM) once per BATCH."""
    docs = unpack_block_pm1(plane_ref[...])
    dn = (((1,), (1,)), ((), ()))
    out_ref[...] = jax.lax.dot_general(q_ref[...], docs, dn,
                                       preferred_element_type=jnp.int32)


@functools.partial(jax.jit, static_argnames=("block_n", "interpret"))
def stage0_sign_batched_pallas(q_sign: jax.Array, sign_plane: jax.Array, *,
                               block_n: int = DEFAULT_BLOCK_N,
                               interpret: bool = True) -> jax.Array:
    """Batch-native stage 0: q_sign (B, D) int8 in {+1, -1}, sign_plane
    (N, D//8) uint8 packed sign bits, N % block_n == 0. Returns (B, N)
    int32 sign-agreement scores (2 * agreements - D). The query panel is
    grid-invariant (stationary in VMEM); every sign block streams
    HBM->VMEM exactly once for the whole batch."""
    n, d8 = sign_plane.shape
    b = q_sign.shape[0]
    assert n % block_n == 0, (n, block_n)
    nb = n // block_n
    out = pl.pallas_call(
        _stage0_batched_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((b, d8 * 8), lambda i: (0, 0)),    # queries: pinned
            pl.BlockSpec((block_n, d8), lambda i: (i, 0)),  # docs: streamed
        ],
        out_specs=pl.BlockSpec((b, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), jnp.int32),
        interpret=interpret,
    )(q_sign, sign_plane)
    return out


def _stage0_gather_kernel(ids_ref, q_ref, plane_ref, out_ref):
    """ids_ref: (B, J) int32 prefetched block ids (consumed by the
    BlockSpec index_maps); q_ref: (1, D) int8 lane signs; plane_ref:
    (BR, D8) uint8 — the sign block the index_map selected; out:
    (1, 1, BR)."""
    del ids_ref  # only read by the BlockSpec index_maps
    docs = unpack_block_pm1(plane_ref[...])
    dn = (((1,), (0,)), ((), ()))
    s = jax.lax.dot_general(docs, q_ref[0], dn,
                            preferred_element_type=jnp.int32)
    out_ref[0, 0, :] = s


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def stage0_sign_gather_pallas(q_sign: jax.Array, sign_plane: jax.Array,
                              block_ids: jax.Array, *,
                              block_rows: int,
                              interpret: bool = True) -> jax.Array:
    """Block-gathered stage 0: q_sign (B, D) int8 in {+1, -1}; sign_plane
    (N, D//8) uint8 with N % block_rows == 0 (zero-padded); block_ids
    (B, J) int32 ids in [0, N / block_rows) — the SAME clamped per-lane
    table the stage-1 gather consumes, so the prescreen's view geometry
    can never drift from the scan it is pruning. Returns (B, J *
    block_rows) int32 sign-agreement scores in block-table order. ONE
    launch, grid (B, J), scalar-prefetched ids: only selected blocks
    ever stream from HBM."""
    n, d8 = sign_plane.shape
    b, j = block_ids.shape
    assert n % block_rows == 0, (n, block_rows)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(b, j),
        in_specs=[
            pl.BlockSpec((1, d8 * 8), lambda i, jj, ids: (i, 0)),
            pl.BlockSpec((block_rows, d8),
                         lambda i, jj, ids: (ids[i, jj], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_rows),
                               lambda i, jj, ids: (i, 0, jj)),
    )
    out = pl.pallas_call(
        _stage0_gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, 1, j * block_rows), jnp.int32),
        interpret=interpret,
    )(block_ids, q_sign, sign_plane)
    return out[:, 0, :]
