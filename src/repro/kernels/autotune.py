"""Measured block-shape autotuner for the stage-1 / fused-top-k kernels.

`DEFAULT_BLOCK_N`'s 256 -> 1024 crossover in `stage1_int4.py` was found by
hand on one machine: interpret-mode Pallas pays a fixed host cost per grid
step, so bigger blocks win on CPU, while a compiled TPU kernel wants blocks
sized to VMEM working sets. Neither constant is right everywhere. This
module replaces the hand-found number with a small *measured* search:

    table = autotune.autotune()          # time candidates on THIS device
    autotune.install(table)              # ops.* wrappers now consult it
    table.save("BENCH_autotune.json")    # artifact, keyed by device kind

The search grid is (kernel, batch bucket) x block_n candidates; the batch
buckets mirror the serving runtime's pow2 padding so a lookup at trace
time hits the bucket the launch was actually padded to. Results are cached
to a JSON artifact stamped with (device_kind, backend, interpret); loading
a table recorded on different hardware is refused (stale-device
invalidation) and every lookup falls back to `DEFAULT_BLOCK_N`
deterministically when no table is installed, so behavior without an
artifact is exactly the pre-autotuner behavior.

The chosen block always times at >= 1.0x the default *by construction*:
`DEFAULT_BLOCK_N` is itself a candidate and selection is argmin over
measured medians (ties prefer the default). The gather kernels'
`block_rows` is NOT tuned here — it is a layout constant baked into the
arena/slab indirection tables, not a free schedule knob.

Set ``REPRO_AUTOTUNE_CACHE=/path/to/table.json`` to have every
`RetrievalEngine` load + install the artifact at construction.
"""
from __future__ import annotations

import functools
import json
import os
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import fused_topk as _fk
from repro.kernels import stage0_sign as _s0
from repro.kernels import stage1_int4 as _s1

SCHEMA_VERSION = 1

#: Kernels with a free block knob. Keyed by the name used in table entries;
#: values are the ops.py wrapper each one feeds.
KERNELS = ("stage1_single", "stage1_batched", "stage1_rows", "fused_topk",
           "stage0_sign")

DEFAULT_CANDIDATES = (128, 256, 512, 1024, 2048)
DEFAULT_BATCHES = (1, 8, 32)


def device_signature() -> dict:
    """(device_kind, backend, interpret) — the key a tuned table is valid
    for. interpret tracks the backend (Mosaic on TPU, interpreter
    elsewhere), but is recorded separately: it is the single biggest
    determinant of the crossover point."""
    dev = jax.devices()[0]
    backend = jax.default_backend()
    return {"device_kind": dev.device_kind, "backend": backend,
            "interpret": backend != "tpu"}


def _pow2_bucket(batch: int) -> int:
    return 1 << max(0, int(np.ceil(np.log2(max(1, int(batch))))))


class TuneTable:
    """A measured (kernel, batch bucket) -> block shape map for one device.

    entries: {"<kernel>/b<bucket>": {"kernel", "batch_bucket", "block_n",
    "timings_ms", "default_ms", "speedup_vs_default"}}.
    """

    def __init__(self, signature: dict, entries: dict | None = None,
                 meta: dict | None = None):
        self.signature = dict(signature)
        self.entries = dict(entries or {})
        self.meta = dict(meta or {})

    @staticmethod
    def key(kernel: str, batch_bucket: int) -> str:
        return f"{kernel}/b{batch_bucket}"

    def best(self, kernel: str, batch: int) -> int | None:
        """Tuned block for `kernel` at `batch`, or None if the kernel was
        never benched. Exact pow2-bucket hit first, else the nearest
        measured bucket (log distance) — the runtime pads to pow2 buckets,
        so exact hits are the common case."""
        bucket = _pow2_bucket(batch)
        hit = self.entries.get(self.key(kernel, bucket))
        if hit is not None:
            return int(hit["block_n"])
        near = [e for e in self.entries.values() if e["kernel"] == kernel]
        if not near:
            return None
        pick = min(near, key=lambda e: abs(
            np.log2(max(1, e["batch_bucket"])) - np.log2(bucket)))
        return int(pick["block_n"])

    def to_json(self) -> dict:
        return {"schema": SCHEMA_VERSION, "signature": self.signature,
                "meta": self.meta, "entries": self.entries}

    @classmethod
    def from_json(cls, obj: dict, *, require_current_device: bool = True
                  ) -> "TuneTable | None":
        """Rebuild a table from its JSON form. Returns None (never raises)
        when the payload is malformed, from a different schema, or — with
        `require_current_device` — recorded on different hardware: a stale
        artifact must degrade to the deterministic default, not steer
        block shapes measured on some other machine."""
        try:
            if obj.get("schema") != SCHEMA_VERSION:
                return None
            table = cls(obj["signature"], obj.get("entries", {}),
                        obj.get("meta", {}))
            for e in table.entries.values():
                int(e["block_n"]), str(e["kernel"]), int(e["batch_bucket"])
        except (KeyError, TypeError, ValueError):
            return None
        if require_current_device and table.signature != device_signature():
            return None
        return table

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            json.dump(self.to_json(), f, indent=2, sort_keys=True)
            f.write("\n")


def load(path: str) -> TuneTable | None:
    """Load an artifact; None on missing/corrupt file or a signature that
    does not match the current device (see `TuneTable.from_json`)."""
    try:
        with open(path) as f:
            obj = json.load(f)
    except (OSError, json.JSONDecodeError):
        return None
    return TuneTable.from_json(obj)


# ---------------------------------------------------------------------------
# Install / lookup — the ops.py side of the contract
# ---------------------------------------------------------------------------

_INSTALLED: TuneTable | None = None


def install(table: TuneTable | None) -> None:
    """Make `table` the process-wide tuned-shape source consulted by the
    ops.py wrappers. Installation is trace-time only: programs already
    compiled keep the block shape they were traced with, so install before
    warming the engines you care about (the bench tunes first)."""
    global _INSTALLED
    _INSTALLED = table


def installed() -> TuneTable | None:
    return _INSTALLED


def clear_installed() -> None:
    install(None)


def lookup(kernel: str, batch: int, default: int) -> int:
    """The single resolution point: installed table's choice for (kernel,
    batch bucket), else `default` — deterministically `DEFAULT_BLOCK_N`
    from the call sites, so no artifact == pre-autotuner behavior."""
    if _INSTALLED is None:
        return default
    best = _INSTALLED.best(kernel, batch)
    return default if best is None else best


ENV_CACHE = "REPRO_AUTOTUNE_CACHE"


@functools.lru_cache(maxsize=None)
def _load_env_cache(path: str) -> TuneTable | None:
    return load(path)


def ensure_default_installed() -> TuneTable | None:
    """Engine-construction hook: if ``REPRO_AUTOTUNE_CACHE`` names a valid
    artifact for this device, install it (once — memoized per path).
    Never raises; a stale or unreadable artifact leaves the deterministic
    default in place."""
    path = os.environ.get(ENV_CACHE)
    if not path:
        return _INSTALLED
    table = _load_env_cache(path)
    if table is not None and _INSTALLED is None:
        install(table)
    return _INSTALLED


# ---------------------------------------------------------------------------
# Measurement
# ---------------------------------------------------------------------------

def _timed_ms(fn: Callable[[], object], reps: int) -> float:
    """Median wall-clock of `fn` with every rep fully synchronized —
    block_until_ready inside the timed region, or async dispatch would
    time the enqueue instead of the kernel."""
    jax.block_until_ready(fn())                       # compile + warm
    ts = []
    for _ in range(max(1, reps)):
        t0 = time.perf_counter()
        jax.block_until_ready(fn())
        ts.append(time.perf_counter() - t0)
    ts.sort()
    return ts[len(ts) // 2] * 1e3


def _runner(kernel: str, rng: np.random.Generator, *, n: int, d: int,
            batch: int):
    """(make(block)->thunk, max_block) for one (kernel, batch) point, or
    (None, 0) when the point is not meaningful (e.g. batched single)."""
    from repro.kernels import ops  # deferred: ops imports this module

    plane = jnp.asarray(rng.integers(0, 256, size=(n, d // 2),
                                     dtype=np.int64).astype(np.uint8))
    q = jnp.asarray(rng.integers(-8, 8, size=(batch, d),
                                 dtype=np.int64).astype(np.int8))
    if kernel == "stage1_single":
        if batch != 1:
            return None, 0
        q0 = q[0]
        return (lambda bn: lambda: ops.stage1_scores(
            q0, plane, block_n=bn)), n
    if kernel == "stage1_batched":
        return (lambda bn: lambda: ops.stage1_scores_batched(
            q, plane, block_n=bn)), n
    if kernel == "stage1_rows":
        # per-lane row views (arena windows / gathered probe rows): the
        # knob is the per-lane block width, bounded by the view size
        w = min(n, 2048)
        rows = jnp.asarray(rng.integers(0, 256, size=(batch, w, d // 2),
                                        dtype=np.int64).astype(np.uint8))
        return (lambda bn: lambda: ops.stage1_scores_rows(
            q, rows, block_w=bn)), w
    if kernel == "fused_topk":
        # k_per_block == c keeps the fused kernel's exactness contract
        # (c <= k_per_block * num_blocks) valid at EVERY candidate block
        c = min(16, n)
        if batch == 1:
            q0 = q[0]
            return (lambda bn: lambda: ops.fused_candidates(
                q0, plane, c=c, k_per_block=c, block_n=bn)), n
        return (lambda bn: lambda: ops.fused_candidates_batched(
            q, plane, c=c, k_per_block=c, block_n=bn)), n
    if kernel == "stage0_sign":
        # 1-bit prescreen: packed sign plane + pre-unpacked {+1,-1} queries
        if d % 8:
            return None, 0
        sign_plane = jnp.asarray(rng.integers(0, 256, size=(n, d // 8),
                                              dtype=np.int64).astype(np.uint8))
        q_sign = ops.pack_query_signs(q)
        return (lambda bn: lambda: ops.stage0_sign_scores_batched(
            q_sign, sign_plane, block_n=bn)), n
    raise ValueError(f"unknown kernel {kernel!r}")


def autotune(*, n: int = 2048, d: int = 256,
             batches: tuple[int, ...] = DEFAULT_BATCHES,
             candidates: tuple[int, ...] = DEFAULT_CANDIDATES,
             reps: int = 3, seed: int = 0,
             kernels: tuple[str, ...] = KERNELS,
             verbose: bool = False) -> TuneTable:
    """Time every (kernel, batch bucket, block) point and keep the argmin.

    `DEFAULT_BLOCK_N` is always injected into the candidate set and wins
    ties, so `speedup_vs_default >= 1.0` holds at every entry by
    construction — the bench gates on exactly that invariant.
    """
    rng = np.random.default_rng(seed)
    table = TuneTable(device_signature(),
                      meta={"n": n, "d": d, "reps": reps, "seed": seed,
                            "candidates": list(candidates),
                            "default_block_n": _s1.DEFAULT_BLOCK_N,
                            "fused_default_block_n": _fk.DEFAULT_BLOCK_N})
    for kernel in kernels:
        default = {"fused_topk": _fk.DEFAULT_BLOCK_N,
                   "stage0_sign": _s0.DEFAULT_BLOCK_N}.get(
                       kernel, _s1.DEFAULT_BLOCK_N)
        for batch in batches:
            make, max_block = _runner(kernel, rng, n=n, d=d, batch=batch)
            if make is None:
                continue
            clamp = max(8, max_block)
            cands = sorted({min(int(c), clamp) for c in candidates}
                           | {min(default, clamp)})
            timings = {c: _timed_ms(make(c), reps) for c in cands}
            d_eff = min(default, clamp)
            # argmin; ties prefer the default so a flat profile keeps the
            # deterministic pre-autotuner shape
            chosen = min(cands, key=lambda c: (timings[c], c != d_eff))
            bucket = _pow2_bucket(batch)
            entry = {"kernel": kernel, "batch_bucket": bucket,
                     "block_n": chosen,
                     "timings_ms": {str(c): timings[c] for c in cands},
                     "default_block_n": d_eff,
                     "default_ms": timings[d_eff],
                     "speedup_vs_default": timings[d_eff] / timings[chosen]}
            table.entries[TuneTable.key(kernel, bucket)] = entry
            if verbose:
                print(f"  autotune {kernel:>15s} b{bucket:<3d} -> "
                      f"block {chosen:>4d} "
                      f"({entry['speedup_vs_default']:.2f}x vs default "
                      f"{d_eff})")
    return table
