"""Kernel block autotuner: table semantics, artifact lifecycle, ops wiring.

The contract pinned here: (a) with no table installed every op resolves
to the hand-written `DEFAULT_BLOCK_N` — behavior without an artifact is
exactly the pre-autotuner behavior; (b) a tuned table only ever REROUTES
block shapes, never results (block_n is a schedule knob, bit-exact by
the kernel contract); (c) artifacts are keyed to the device that
measured them — a stale artifact degrades to the default, it never
steers shapes tuned on other hardware; (d) the chosen block times at
>= 1.0x the default at every benched point by construction.
"""
import json

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import autotune, ops
from repro.kernels import fused_topk as _fk
from repro.kernels import stage1_int4 as _s1


@pytest.fixture(autouse=True)
def _clean_table():
    """Installation is process-global; never leak it across tests."""
    autotune.clear_installed()
    yield
    autotune.clear_installed()


def tiny_table(entries=None):
    return autotune.TuneTable(
        autotune.device_signature(),
        entries or {"stage1_batched/b8": {
            "kernel": "stage1_batched", "batch_bucket": 8, "block_n": 512,
            "timings_ms": {"512": 1.0, "1024": 2.0}, "default_block_n": 1024,
            "default_ms": 2.0, "speedup_vs_default": 2.0}})


# ---------------------------------------------------------------------------
# Lookup and fallback semantics
# ---------------------------------------------------------------------------

def test_lookup_without_table_is_deterministic_default():
    assert autotune.installed() is None
    assert autotune.lookup("stage1_batched", 8, _s1.DEFAULT_BLOCK_N) == \
        _s1.DEFAULT_BLOCK_N
    assert autotune.lookup("no_such_kernel", 1, 77) == 77


def test_installed_table_resolves_bucket_and_falls_back():
    autotune.install(tiny_table())
    # exact pow2 bucket hit (batch 5 pads to bucket 8)
    assert autotune.lookup("stage1_batched", 8, 1024) == 512
    assert autotune.lookup("stage1_batched", 5, 1024) == 512
    # nearest measured bucket when the exact one was never benched
    assert autotune.lookup("stage1_batched", 64, 1024) == 512
    # un-benched kernel: deterministic default
    assert autotune.lookup("fused_topk", 8, _fk.DEFAULT_BLOCK_N) == \
        _fk.DEFAULT_BLOCK_N
    autotune.clear_installed()
    assert autotune.lookup("stage1_batched", 8, 1024) == 1024


# ---------------------------------------------------------------------------
# Artifact lifecycle: round-trip, corruption, stale-device invalidation
# ---------------------------------------------------------------------------

def test_table_json_round_trip(tmp_path):
    t = tiny_table()
    path = str(tmp_path / "tune.json")
    t.save(path)
    back = autotune.load(path)
    assert back is not None
    assert back.signature == t.signature
    assert back.entries == t.entries
    assert back.best("stage1_batched", 8) == 512


def test_stale_device_artifact_is_refused(tmp_path):
    t = tiny_table()
    obj = t.to_json()
    obj["signature"]["device_kind"] = "TPU v9000"
    path = tmp_path / "stale.json"
    path.write_text(json.dumps(obj))
    assert autotune.load(str(path)) is None          # wrong hardware
    # ...but the payload itself is intact: opting out of the device check
    # (offline inspection) still parses it
    assert autotune.TuneTable.from_json(
        obj, require_current_device=False) is not None


def test_malformed_artifacts_degrade_to_none(tmp_path):
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert autotune.load(str(bad)) is None
    assert autotune.load(str(tmp_path / "missing.json")) is None
    assert autotune.TuneTable.from_json({"schema": 999}) is None
    assert autotune.TuneTable.from_json(
        {"schema": autotune.SCHEMA_VERSION, "signature": {},
         "entries": {"x": {"kernel": "k"}}},     # entry missing block_n
        require_current_device=False) is None


def test_env_cache_installs_at_engine_construction(tmp_path, monkeypatch):
    from repro.core import RetrievalConfig
    from repro.tenancy import MultiTenantIndex
    path = str(tmp_path / "env_tune.json")
    tiny_table().save(path)
    monkeypatch.setenv(autotune.ENV_CACHE, path)
    autotune._load_env_cache.cache_clear()
    assert autotune.installed() is None
    MultiTenantIndex(64, 32, RetrievalConfig(k=2))   # builds an engine
    got = autotune.installed()
    assert got is not None and got.best("stage1_batched", 8) == 512


# ---------------------------------------------------------------------------
# Measured search: the >= 1.0x invariant and ops bit parity
# ---------------------------------------------------------------------------

def test_autotune_speedup_vs_default_at_least_one():
    """DEFAULT_BLOCK_N is always a candidate and argmin picks the chosen
    block, so every entry's speedup is >= 1.0 by construction — the
    bench gate relies on exactly this."""
    table = autotune.autotune(n=256, d=32, batches=(1, 4),
                              candidates=(64, 256), reps=1,
                              kernels=("stage1_batched", "fused_topk",
                                       "stage0_sign"))
    assert table.entries, "search produced no entries"
    for e in table.entries.values():
        assert e["speedup_vs_default"] >= 1.0
        assert str(e["default_block_n"]) in e["timings_ms"]
        assert str(e["block_n"]) in e["timings_ms"]


def test_tuned_ops_bit_identical_to_default(tmp_path):
    """A tuned table reroutes block shapes only: stage-1 scores and fused
    candidates under an installed table are bitwise what the default
    shapes produce."""
    rng = np.random.default_rng(0)
    n, d, b = 512, 32, 4
    plane = jnp.asarray(rng.integers(0, 256, (n, d // 2)).astype(np.uint8))
    q = jnp.asarray(rng.integers(-8, 8, (b, d)).astype(np.int8))
    base_scores = np.asarray(ops.stage1_scores_batched(q, plane))
    base_cand = ops.fused_candidates_batched(q, plane, c=8, k_per_block=8)
    autotune.install(autotune.TuneTable(autotune.device_signature(), {
        "stage1_batched/b4": {"kernel": "stage1_batched", "batch_bucket": 4,
                              "block_n": 128},
        "fused_topk/b4": {"kernel": "fused_topk", "batch_bucket": 4,
                          "block_n": 64}}))
    tuned_scores = np.asarray(ops.stage1_scores_batched(q, plane))
    tuned_cand = ops.fused_candidates_batched(q, plane, c=8, k_per_block=8)
    np.testing.assert_array_equal(base_scores, tuned_scores)
    np.testing.assert_array_equal(np.asarray(base_cand[0]),
                                  np.asarray(tuned_cand[0]))
    np.testing.assert_array_equal(np.asarray(base_cand[1]),
                                  np.asarray(tuned_cand[1]))
    # explicit block_n bypasses the table entirely
    explicit = np.asarray(ops.stage1_scores_batched(q, plane, block_n=256))
    np.testing.assert_array_equal(base_scores, explicit)
