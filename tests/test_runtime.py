import itertools

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.models import get_model
from repro.runtime import (ElasticTrainer, FailureInjector, HeartbeatMonitor,
                           StragglerDetector, build_mesh_from)
from repro.train import adamw, make_train_step


def test_heartbeat_failure_detection():
    now = [0.0]
    mon = HeartbeatMonitor(timeout_s=5.0, clock=lambda: now[0])
    mon.beat("w0")
    mon.beat("w1")
    now[0] = 3.0
    mon.beat("w0")
    now[0] = 7.0
    assert mon.failed() == ["w1"]
    assert mon.alive() == ["w0"]


def test_straggler_detection():
    det = StragglerDetector(k_sigma=2.0, min_steps=5)
    for i in range(10):
        for w in ("w0", "w1", "w2", "w3"):
            det.record(w, 1.0 + 0.01 * i)
        det.record("slow", 3.0)
    assert det.stragglers() == ["slow"]


def test_straggler_detector_remove_forgets_worker():
    det = StragglerDetector(k_sigma=2.0, min_steps=5)
    for i in range(10):
        for w in ("w0", "w1", "w2", "w3"):
            det.record(w, 1.0 + 0.01 * i)
        det.record("slow", 3.0)
    det.remove("slow")
    assert det.stragglers() == []


def test_elastic_trainer_monitors_only_in_mesh_devices(tmp_path):
    """A device the mesh never included (fakes beyond the real mesh size)
    must not appear in the heartbeat monitor's worker set."""
    cfg = get_config("qwen2-0.5b", smoke=True).with_(vocab_size=64)
    api = get_model(cfg)
    opt = adamw(lr=1e-3)
    toks = np.random.default_rng(0).integers(0, 64, (4, 16)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    trainer = ElasticTrainer(
        make_state=_make_state_factory(cfg, api, opt),
        ckpt=CheckpointManager(str(tmp_path)), save_every=4)

    class FakeDev:
        def __init__(self, i):
            self.id = i

    import repro.runtime.elastic as el
    orig = el.build_mesh_from
    el.build_mesh_from = lambda d, mp: orig(jax.devices(), 1)
    try:
        out = trainer.run(itertools.repeat(batch), num_steps=4,
                          devices=[FakeDev(0), FakeDev(7)])
    finally:
        el.build_mesh_from = orig
    n_mesh = min(len(jax.devices()), 2)
    assert out["monitored"] == ["0", "7"][:n_mesh]


def test_build_mesh_from_survivors():
    devs = jax.devices()
    mesh = build_mesh_from(devs, model_parallel=1)
    assert mesh.devices.size == len(devs)


def _make_state_factory(cfg, api, opt):
    def make_state(mesh):
        params = api.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        raw = make_train_step(api.loss_fn, opt)

        def step_fn(params, opt_state, batch, mesh):
            return jax.jit(raw)(params, opt_state, batch)

        return params, opt_state, step_fn, None
    return make_state


def test_elastic_trainer_restarts_after_failure(tmp_path):
    """Inject a failure at step 12: driver must checkpoint-restart, resume
    from step 10 (last save), and finish all 20 steps."""
    cfg = get_config("qwen2-0.5b", smoke=True).with_(vocab_size=64)
    api = get_model(cfg)
    opt = adamw(lr=1e-3)
    toks = np.random.default_rng(0).integers(0, 64, (4, 16)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    batches = itertools.repeat(batch)

    trainer = ElasticTrainer(
        make_state=_make_state_factory(cfg, api, opt),
        ckpt=CheckpointManager(str(tmp_path), keep=2), save_every=5)

    class FakeDev:
        def __init__(self, i):
            self.id = i

    devs = [FakeDev(0), FakeDev(1)]

    # monkeypatch build: our fake devices can't build a real mesh; use the
    # real device for compute, fakes only for failure bookkeeping
    import repro.runtime.elastic as el
    orig = el.build_mesh_from
    el.build_mesh_from = lambda d, mp: orig(jax.devices(), 1)
    try:
        out = trainer.run(batches, num_steps=20,
                          injector=FailureInjector({12: 1}), devices=devs)
    finally:
        el.build_mesh_from = orig
    assert out["restarts"] == 1
    assert out["final_devices"] == 1
    # Steps 10..11 ran, failed at 12, restored to 10 and re-ran: the
    # replayed steps' pre-failure losses must be truncated at restore, so
    # the history holds EXACTLY one loss per step (22 pre-fix).
    assert len(out["losses"]) == 20
    # The dead worker must be dropped from the heartbeat monitor on
    # restart — a restarted driver reporting device 1 as a live worker
    # would mask the very failure it just survived.
    assert "1" not in out["monitored"]


def test_elastic_trainer_no_failure(tmp_path):
    cfg = get_config("qwen2-0.5b", smoke=True).with_(vocab_size=64)
    api = get_model(cfg)
    opt = adamw(lr=1e-3)
    toks = np.random.default_rng(0).integers(0, 64, (4, 16)).astype(np.int32)
    batch = {"tokens": jnp.asarray(toks), "labels": jnp.asarray(toks)}
    trainer = ElasticTrainer(
        make_state=_make_state_factory(cfg, api, opt),
        ckpt=CheckpointManager(str(tmp_path)), save_every=4)
    out = trainer.run(itertools.repeat(batch), num_steps=8)
    assert out["restarts"] == 0 and len(out["losses"]) == 8
