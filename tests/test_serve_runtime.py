"""Serving runtime: deadline batcher, fairness, hot-cluster cache parity.

The bit-exactness contract under test: the runtime's batching, padding,
and hot-cluster cache may change WHEN work runs and WHERE stage-1 bytes
come from, but never WHAT any request retrieves — including across arena
mutations, where a stale cached view must be evicted, not served.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RetrievalConfig, quantize_int8
from repro.core.clustering import ClusterParams
from repro.serve.runtime import (HotClusterCache, RequestHandle,
                                 RuntimeConfig, ServingRuntime)
from repro.tenancy import MultiTenantIndex

DIM = 64


def make_clustered_index(tenants=4, docs_per_tenant=96, k=3, seed=0,
                         num_clusters=8, nprobe=2, block_rows=32,
                         capacity=1024, prescreen_c0=None):
    rng = np.random.default_rng(seed)
    idx = MultiTenantIndex(capacity, DIM,
                           RetrievalConfig(k=k, prescreen_c0=prescreen_c0),
                           clusters=ClusterParams(num_clusters=num_clusters,
                                                  nprobe=nprobe,
                                                  block_rows=block_rows))
    docs = {}
    for t in range(tenants):
        d = rng.normal(size=(docs_per_tenant, DIM)).astype(np.float32)
        idx.ingest(t, jnp.asarray(d))
        docs[t] = d
    idx.compact()
    queries = {t: np.asarray(quantize_int8(jnp.asarray(d[:8]),
                                           per_vector=True)[0])
               for t, d in docs.items()}
    return idx, queries


def make_plain_index(tenants=3, seed=0, capacity=256, k=3):
    """No clustering; interleaved ingests FRAGMENT every tenant so the
    batched path falls back to the full-arena masked scan (whose per-lane
    results are independent of batch composition by construction)."""
    rng = np.random.default_rng(seed)
    idx = MultiTenantIndex(capacity, DIM, RetrievalConfig(k=k))
    docs = {t: [] for t in range(tenants)}
    for _ in range(3):
        for t in range(tenants):
            d = rng.normal(size=(5, DIM)).astype(np.float32)
            idx.ingest(t, jnp.asarray(d))
            docs[t].append(d)
    docs = {t: np.concatenate(v) for t, v in docs.items()}
    assert any(len(idx.table.segments(t)) > 1 for t in range(tenants))
    queries = {t: np.asarray(quantize_int8(jnp.asarray(d[:6]),
                                           per_vector=True)[0])
               for t, d in docs.items()}
    return idx, queries


# ---------------------------------------------------------------------------
# Admission: deadlines, max-batch, fairness, handles
# ---------------------------------------------------------------------------

def test_deadline_admission_virtual_clock():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, max_wait=5.0,
                                           auto_flush=False))
    h = rt.submit(0, q[0][0], now=0.0)
    assert not rt.ready(now=0.0) and rt.poll(now=4.9) == []
    assert not h.done() and rt.pending() == 1
    assert rt.next_deadline() == 5.0
    launched = rt.poll(now=5.0)                 # deadline forces the launch
    assert launched == [h] and rt.pending() == 0
    assert h.result() is not None and h.done()  # result() retires the launch


def test_full_batch_launches_immediately_from_submit():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=2, max_wait=100.0))
    h1 = rt.submit(0, q[0][0], now=0.0)
    assert not h1.done()                        # partial batch waits
    assert h1.state == "pending"
    h2 = rt.submit(1, q[1][0], now=0.0)
    # the full batch dispatched straight from submit(); with async
    # dispatch the handles are at least in flight (resolved once the
    # device lands — result() forces that without draining the queue)
    assert rt.launches == 1
    assert h1.state in ("in_flight", "resolved")
    assert h1.result() is not None and h2.result() is not None
    assert h1.done() and h2.done() and rt.launches == 1


def test_explicit_deadline_overrides_max_wait():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, max_wait=100.0,
                                           auto_flush=False))
    h = rt.submit(0, q[0][0], now=0.0, deadline=1.0)
    assert rt.poll(now=0.5) == [] and rt.poll(now=1.0) == [h]


def test_result_wait_false_is_none_until_ready_and_drains():
    """The handle state machine: result(wait=False) is a well-defined
    None not-ready signal at every pre-resolved state (it used to raise
    on queued requests), and result() drains exactly as far as needed."""
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, auto_flush=False))
    h = rt.submit(0, q[0][0], now=0.0)
    assert h.state == "pending"
    assert h.result(wait=False) is None         # queued: not ready, no raise
    assert h.state == "pending"                 # ...and no side effects
    res = h.result()                            # future-style: drains
    assert h.done() and h.state == "resolved"
    assert np.asarray(res.indices).shape == (3,)
    assert h.result(wait=False) is res          # resolved: wait irrelevant


def test_handle_states_through_async_pipeline():
    """pending -> in_flight -> resolved observable under async dispatch;
    done() is non-blocking and barrier() retires everything."""
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=2, max_wait=100.0,
                                           auto_flush=False, async_depth=2))
    h1 = rt.submit(0, q[0][0], now=0.0)
    h2 = rt.submit(1, q[1][0], now=0.0)
    assert rt.poll(now=0.0) == [h1, h2]         # full batch: dispatched
    assert rt.launches == 1
    assert {h1.state, h2.state} <= {"in_flight", "resolved"}
    assert rt.in_flight() <= 1                  # poll may have reaped it
    rt.barrier()
    assert rt.in_flight() == 0
    assert h1.state == h2.state == "resolved"
    assert h1.done() and h2.done()


def test_async_depth_zero_is_synchronous():
    """async_depth=0 restores the legacy contract: a launch is resolved
    before the dispatching call returns."""
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=2, max_wait=100.0,
                                           async_depth=0))
    h1 = rt.submit(0, q[0][0], now=0.0)
    h2 = rt.submit(1, q[1][0], now=0.0)         # auto_flush dispatches
    assert h1.state == h2.state == "resolved"   # ...and retires inline
    assert rt.in_flight() == 0 and h1.done() and h2.done()


def test_async_backpressure_bounds_inflight_depth():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=1, max_wait=100.0,
                                           auto_flush=False, async_depth=2))
    handles = [rt.submit(t % 3, q[t % 3][t % 4], now=0.0) for t in range(6)]
    rt.poll(now=1000.0)                         # 6 single-lane launches
    assert rt.launches == 6
    assert rt.in_flight() <= 2                  # never beyond async_depth
    rt.barrier()
    assert all(h.state == "resolved" for h in handles)


def test_round_robin_fairness_no_tenant_starvation():
    """A chatty tenant floods the queue; the first launch must still carry
    the quiet tenants' requests instead of 4 lanes of the flooder."""
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=4, auto_flush=False))
    chatty = [rt.submit(0, q[0][i], now=0.0) for i in range(6)]
    quiet = [rt.submit(t, q[t][0], now=0.0) for t in (1, 2)]
    rt.flush()
    first = [h for h in chatty + quiet if h.launch_index == 0]
    assert {h.tenant_id for h in first} == {0, 1, 2}
    assert sum(h.tenant_id == 0 for h in first) == 2
    # FIFO within a tenant: the flooder's own requests resolve in order.
    order = sorted(chatty, key=lambda h: h.request_id)
    launches = [h.launch_index for h in order]
    assert launches == sorted(launches)


def test_fifo_mode_preserves_arrival_grouping():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=4, fairness="fifo",
                                           auto_flush=False))
    handles = [rt.submit(0, q[0][i], now=0.0) for i in range(5)]
    handles.append(rt.submit(1, q[1][0], now=0.0))
    rt.flush()
    assert [h.launch_index for h in handles] == [0, 0, 0, 0, 1, 1]


def test_partial_batch_pads_to_pow2_bucket():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, auto_flush=False))
    for i in range(3):
        rt.submit(0, q[0][i], now=0.0)
    rt.flush()
    assert rt.last_plan.batch == 4              # 3 real lanes + 1 padding
    assert rt.queries_served == 3


def test_submit_validation():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx)
    with pytest.raises(ValueError, match="tenant id"):
        rt.submit(-1, q[0][0])
    with pytest.raises(ValueError, match="query must be"):
        rt.submit(0, q[0][0][:DIM // 2])
    with pytest.raises(ValueError, match="max_batch"):
        RuntimeConfig(max_batch=0)
    with pytest.raises(ValueError, match="fairness"):
        RuntimeConfig(fairness="lifo")


# ---------------------------------------------------------------------------
# Hot-cluster cache: bit-exact parity, invalidation, accounting
# ---------------------------------------------------------------------------

def run_batch(rt, idx_queries, tenants):
    handles = [rt.submit(t, idx_queries[t][i], now=0.0)
               for t in tenants for i in range(2)]
    rt.flush()
    return handles


def test_cache_hit_path_bit_identical_to_miss_path():
    """Turn 2 re-issues turn 1's queries: every cluster view comes from
    the cache, and every result must be bit-identical to the cold turn
    AND to the uncached ClusterPolicy cascade."""
    idx, q = make_clustered_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, cache_bytes=1 << 20,
                                           auto_flush=False))
    cold = run_batch(rt, q, range(4))
    assert rt.cache_stats()["misses"] > 0
    hbm_after_cold = rt.stage1_bytes_streamed
    warm = run_batch(rt, q, range(4))
    assert rt.stage1_bytes_streamed == hbm_after_cold   # fully warm: 0 HBM
    assert rt.last_plan.stage1_bytes == 0
    assert rt.last_plan.stage1_bytes_sram > 0
    # uncached reference (same grouping, direct index.retrieve)
    tids = np.asarray([t for t in range(4) for _ in range(2)], np.int32)
    Q = jnp.asarray(np.stack([q[t][i] for t in range(4) for i in range(2)]))
    ref = idx.retrieve(Q, tids)
    for lane, (c, w) in enumerate(zip(cold, warm)):
        for res in (c.result(), w.result()):
            assert jnp.array_equal(res.indices, ref.indices[lane])
            assert jnp.array_equal(res.scores, ref.scores[lane])
            assert jnp.array_equal(res.candidate_indices,
                                   ref.candidate_indices[lane])


def test_cache_straddling_arena_mutation_evicts_stale_views():
    """Warm the cache, MUTATE the arena (insert + delete), query again:
    the stale generation's views must be evicted, and the results must
    equal a fresh uncached retrieval over the mutated arena."""
    rng = np.random.default_rng(7)
    idx, q = make_clustered_index(seed=7)
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, cache_bytes=1 << 20,
                                           auto_flush=False))
    run_batch(rt, q, range(4))                      # warm
    assert len(rt.cache) > 0
    gen_before = idx.arena.generation
    new = rng.normal(size=(4, DIM)).astype(np.float32)
    idx.ingest(0, jnp.asarray(new))                 # mutation 1
    idx.delete(1, idx.table.slots(1)[:2])           # mutation 2
    assert idx.arena.generation > gen_before
    handles = run_batch(rt, q, range(4))
    assert rt.cache_stats()["stale_evictions"] > 0
    tids = np.asarray([t for t in range(4) for _ in range(2)], np.int32)
    Q = jnp.asarray(np.stack([q[t][i] for t in range(4) for i in range(2)]))
    ref = idx.retrieve(Q, tids)
    for lane, h in enumerate(handles):
        res = h.result()
        assert jnp.array_equal(res.indices, ref.indices[lane])
        assert jnp.array_equal(res.scores, ref.scores[lane])
    # a query for the newly ingested doc sees the post-mutation arena
    # exactly as the uncached cascade does (no stale view hides it)
    qn, _ = quantize_int8(jnp.asarray(new[:1]), per_vector=True)
    h = rt.submit(0, np.asarray(qn[0]), now=0.0)
    rt.flush()
    fresh = idx.retrieve(qn, np.asarray([0], np.int32))
    assert jnp.array_equal(h.result().indices, fresh.indices[0])
    assert jnp.array_equal(h.result().scores, fresh.scores[0])
    # and the tombstoned rows can never surface
    gone = np.asarray(idx.arena.owner) < 0
    for hh in handles:
        got = np.asarray(hh.result().indices)
        assert not gone[got[got >= 0]].any()


def test_cache_budget_shrinkage_monotone_hbm_bytes():
    """Shrinking the byte budget can only increase HBM traffic on the
    same trace (and never changes results)."""
    byts, results = [], []
    for budget in (1 << 20, 6 * 1024, 0):
        idx, q = make_clustered_index(seed=3)
        rt = ServingRuntime(idx, RuntimeConfig(max_batch=8,
                                               cache_bytes=budget,
                                               auto_flush=False))
        hs = []
        for _ in range(3):
            hs.extend(run_batch(rt, q, range(4)))
        byts.append(rt.stage1_bytes_streamed)
        results.append([np.asarray(h.result().indices) for h in hs])
    assert byts[0] <= byts[1] <= byts[2]
    assert byts[0] < byts[2]
    for got in results[1:]:
        for a, b in zip(results[0], got):
            np.testing.assert_array_equal(a, b)


def test_session_prior_rewarms_cache_after_mutation():
    """After a mutation invalidates the cache, the tenant's recent-cluster
    prior prefetches its session's clusters at the next flush — so the
    probes themselves hit."""
    rng = np.random.default_rng(5)
    idx, q = make_clustered_index(seed=5)
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, cache_bytes=1 << 20,
                                           prior_clusters=8,
                                           auto_flush=False))
    run_batch(rt, q, range(4))                      # establishes priors
    idx.ingest(0, jnp.asarray(rng.normal(size=(4, DIM)).astype(np.float32)))
    hits_before = rt.cache_stats()["hits"]
    run_batch(rt, q, range(4))                      # same session turns
    assert rt.prefetch_bytes > 0
    assert rt.cache_stats()["hits"] > hits_before


def _blk_rows(*blocks, br=4):
    """Row ids of whole plane blocks (mirror-equivalent views)."""
    return np.concatenate([np.arange(br) + b * br for b in blocks])


def test_lru_cache_unit_behavior():
    """Slot-map LRU over the slab arena: two slots of 40 bytes each."""
    cache = HotClusterCache(budget_bytes=100)
    cache.configure(block_rows=4, bytes_per_row=10)   # slot = 40 B, 2 slots
    cache.sync_generation(1)
    assert cache.num_slab_blocks == 2
    assert list(cache.put(0, 0, _blk_rows(3))) == [0]  # blk 3 -> slot 0
    assert list(cache.put(0, 1, _blk_rows(5))) == [1]
    assert cache.get(0, 0) is not None                # 0 now most recent
    slots = cache.put(0, 2, _blk_rows(7))             # evicts LRU = (0, 1)
    assert slots is not None and len(slots) == 1
    assert cache.bytes_used <= 100 and len(cache) == 2
    assert cache.peek(0, 0) and not cache.peek(0, 1)
    assert cache.evictions == 1
    cache.sync_generation(2)                          # arena mutated
    assert len(cache) == 0 and cache.stale_evictions == 2
    assert len(cache._free) == 2                      # slots reclaimed
    with pytest.raises(ValueError):
        HotClusterCache(budget_bytes=-1)


def test_packed_admission_uses_fewer_slots_than_straddling_blocks():
    """A contiguous run that straddles a plane-block boundary packs into
    ceil(rows/br) slots — one fewer than mirroring its two blocks — and
    a fragmented run falls back to whole-block mirroring."""
    cache = HotClusterCache(budget_bytes=400)
    cache.configure(block_rows=4, bytes_per_row=10)
    cache.sync_generation(1)
    straddle = np.arange(2, 6)                 # rows 2..5: blocks 0 and 1
    assert len(cache.put(0, 0, straddle)) == 1          # packed: 1 slot
    assert cache._entries[(0, 0)].n_rows == 4
    fragmented = np.asarray([0, 1, 9, 10])     # two separate runs
    assert len(cache.put(0, 1, fragmented)) == 2        # mirrors 2 blocks
    assert cache.entry_blocks(straddle, 4) == 1
    assert cache.entry_blocks(fragmented, 4) == 2


def test_eviction_skips_zero_slot_empty_cluster_memos():
    """Slot pressure must evict entries that actually FREE slots: an
    empty-cluster memo holds none, so evicting it would only destroy the
    memoization (re-skewing the miss ledger) and inflate the counter."""
    cache = HotClusterCache(budget_bytes=100)
    cache.configure(block_rows=4, bytes_per_row=10)   # 2 slots
    cache.sync_generation(1)
    cache.put(0, 5, [])                 # empty-cluster memo, oldest
    cache.put(0, 0, _blk_rows(1))
    cache.put(0, 1, _blk_rows(2))
    cache.put(0, 2, _blk_rows(3))       # needs a slot: evicts (0, 0)
    assert cache.peek(0, 5)             # the zero-slot memo survived
    assert not cache.peek(0, 0) and cache.evictions == 1
    with pytest.raises(ValueError, match="preload"):
        RuntimeConfig(preload=True)     # preload needs a budget


def test_oversized_view_rejected_without_flushing_cache():
    """A view larger than the whole slab must be refused admission —
    NOT evict every resident tenant's entries on its way to nowhere."""
    cache = HotClusterCache(budget_bytes=100)
    cache.configure(block_rows=4, bytes_per_row=10)   # 2 slots
    cache.sync_generation(1)
    cache.put(0, 0, _blk_rows(1))
    cache.put(1, 0, _blk_rows(2))
    assert cache.put(2, 7, _blk_rows(3, 4, 5)) is None  # > slab: rejected
    assert cache.rejected == 1 and cache.evictions == 0
    assert cache.peek(0, 0) and cache.peek(1, 0) and not cache.peek(2, 7)
    assert cache.bytes_used == 80


def test_rejected_reput_keeps_resident_entry():
    """Regression: the oversized check must run BEFORE the resident entry
    is popped — a rejected re-put of an existing key used to destroy the
    valid cached entry and leak its bytes from the working set."""
    cache = HotClusterCache(budget_bytes=100)
    cache.configure(block_rows=4, bytes_per_row=10)   # 2 slots
    cache.sync_generation(1)
    cache.put(0, 0, _blk_rows(1))
    used = cache.bytes_used
    assert cache.put(0, 0, _blk_rows(1, 2, 3)) is None  # oversized re-put
    assert cache.rejected == 1
    assert cache.peek(0, 0)                           # entry survived
    assert cache.bytes_used == used                   # no byte leak
    entry = cache.get(0, 0)
    assert entry is not None and entry.n_rows == 4
    # and a legal re-put still replaces (old slots reclaimed, no leak)
    assert cache.put(0, 0, _blk_rows(2, 3)) is not None
    assert cache.bytes_used == 80 and len(cache) == 1


def test_empty_clusters_memoized_as_zero_byte_hits():
    """Regression: empty-cluster probes used to be uncacheable, so every
    repeat probe counted a fresh miss and skewed the hit rate. They are
    now memoized as zero-slot entries: repeats hit (for free), and the
    fully-warm plan still charges zero stage-1 HBM bytes."""
    # Tenant 3's docs all sit in ONE planted cluster, so its lanes must
    # probe nprobe=2 clusters of which at least one is empty for it.
    rng = np.random.default_rng(9)
    idx = MultiTenantIndex(1024, DIM, RetrievalConfig(k=3),
                           clusters=ClusterParams(num_clusters=8, nprobe=2,
                                                  block_rows=32))
    docs = {}
    for t in range(3):
        d = rng.normal(size=(96, DIM)).astype(np.float32)
        idx.ingest(t, jnp.asarray(d))
        docs[t] = d
    base = rng.normal(size=(1, DIM)).astype(np.float32)
    d3 = (base + 0.01 * rng.normal(size=(24, DIM))).astype(np.float32)
    idx.ingest(3, jnp.asarray(d3))
    docs[3] = d3
    idx.compact()
    labels = np.asarray(idx.arena.cluster_labels)
    owner = np.asarray(idx.arena.owner)
    assert len(set(labels[owner == 3])) < 2           # sparse tenant
    queries = {t: np.asarray(quantize_int8(jnp.asarray(d[:2]),
                                           per_vector=True)[0])
               for t, d in docs.items()}
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, cache_bytes=1 << 20,
                                           prior_clusters=0,
                                           auto_flush=False))
    run_batch(rt, queries, range(4))                  # cold turn
    misses_cold = rt.cache_stats()["misses"]
    assert misses_cold > 0
    for _ in range(3):                                # identical re-probes
        run_batch(rt, queries, range(4))
    stats = rt.cache_stats()
    assert stats["misses"] == misses_cold             # no repeat misses
    assert stats["hits"] > 0
    assert rt.last_plan.stage1_bytes == 0             # fully warm
    # hit rate converges instead of being dragged down by empty probes
    assert stats["hits"] / (stats["hits"] + stats["misses"]) >= 0.7


def test_preload_under_slab_pressure_stays_bit_identical():
    """Regression: with the slab sized for only PART of the tenant set,
    a batch's preload admissions can evict another batch tenant's
    entries (the demand check bounds the batch, not the whole slab) —
    the runtime must then fall back to the full-width table instead of
    serving a compact table with silently holed clusters. Rotating
    batches churn admissions/evictions; every result must stay
    bit-identical to the uncached index."""
    idx, q = make_clustered_index(tenants=4)
    # Budget ~ covers roughly half the tenants' packed views at once.
    demand = sum(
        HotClusterCache.entry_blocks(rows, 32) * 32 * (DIM // 2)
        for t in range(4) for rows in idx.cluster_rows(t).values())
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8,
                                           cache_bytes=demand // 2,
                                           preload=True, auto_flush=False))
    batches = [(0,), (1,), (2, 3), (0, 1), (1, 2, 3), (0, 1, 2, 3), (0, 1)]
    for tenants in batches:
        handles = [(t, i, rt.submit(t, q[t][i], now=0.0))
                   for t in tenants for i in range(2)]
        rt.flush()
        for t, i, h in handles:
            ref = idx.retrieve(jnp.asarray(q[t][i])[None],
                               np.asarray([t], np.int32))
            res = h.result()
            assert jnp.array_equal(res.indices, ref.indices[0])
            assert jnp.array_equal(res.scores, ref.scores[0])
    assert rt.cache_stats()["evictions"] > 0    # pressure actually hit


def test_preload_serves_compact_table_when_budget_fits():
    """With the whole tenant set inside the budget, preloaded launches
    run from the compact slab table (narrower than the plane table) and
    every probe hits — still bit-identical to the uncached index."""
    idx, q = make_clustered_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, cache_bytes=1 << 20,
                                           preload=True, auto_flush=False))
    for _ in range(2):
        handles = run_batch(rt, q, range(4))
    stats = rt.cache_stats()
    assert stats["misses"] == 0                 # preload pinned everything
    assert rt.last_plan.stage1_bytes == 0
    assert rt.last_plan.stage1_bytes_sram > 0
    tids = np.asarray([t for t in range(4) for _ in range(2)], np.int32)
    Q = jnp.asarray(np.stack([q[t][i] for t in range(4) for i in range(2)]))
    ref = idx.retrieve(Q, tids)
    for lane, h in enumerate(handles):
        assert jnp.array_equal(h.result().indices, ref.indices[lane])
        assert jnp.array_equal(h.result().scores, ref.scores[lane])
    # the compact table is narrower than (or equal to) the plane table,
    # and the plan's view accounting reflects the narrower launch
    _, table = idx.cluster_layout(tids)
    compact, w = rt.cache.compact_table(tids, table.shape[1])
    assert w <= table.shape[2]


def test_max_wait_zero_means_no_deadline_launches():
    """max_wait=0 is the legacy contract: partial batches launch only
    when full or explicitly flushed, never by the clock."""
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=4, max_wait=0.0,
                                           auto_flush=False))
    h = rt.submit(0, q[0][0], now=0.0)
    assert rt.next_deadline() is None
    assert rt.poll(now=1e9) == [] and not h.done()  # clock can't force it
    explicit = rt.submit(1, q[1][0], now=0.0, deadline=5.0)
    assert set(rt.poll(now=5.0)) == {h, explicit}   # explicit still works
    assert rt.pending() == 0


def test_runtime_ledger_matches_plan_accounting():
    idx, q = make_clustered_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, cache_bytes=1 << 20,
                                           auto_flush=False))
    run_batch(rt, q, range(4))
    plan = rt.last_plan
    assert plan.kind == "cluster"
    assert rt.stage_bytes["approx"] == plan.stage1_bytes
    assert rt.stage_bytes["prune"] == plan.stages[0].bytes_hbm
    # hits + misses account every probed byte of the launch
    run_batch(rt, q, range(4))
    plan2 = rt.last_plan
    approx = [s for s in plan2.stages if s.name == "approx"][0]
    assert approx.bytes_hbm == plan2.stage1_bytes == 0
    assert approx.bytes_sram == plan2.stage1_bytes_sram > 0
    ledger = rt.energy_ledger()
    assert ledger.total_uj > 0


def test_scheduler_wrapper_still_fifo_and_ledgered():
    """The legacy CrossTenantBatchScheduler facade keeps its contract on
    top of the runtime: int tickets, FIFO groups, byte ledgers."""
    from repro.tenancy import CrossTenantBatchScheduler
    idx, q = make_clustered_index()
    sched = CrossTenantBatchScheduler(idx, max_batch=4)
    rids = [sched.submit(t, q[t][0]) for t in range(4)]
    rids += [sched.submit(0, q[0][1])]
    assert sched.pending() == 5
    out = sched.flush()
    assert sched.pending() == 0 and sched.launches == 2
    assert set(out) == set(rids)
    assert sched.stage1_bytes_streamed > 0
    assert sched.stage_bytes == {
        s.name: s.bytes_hbm for s in idx.last_plan.stages} or \
        sum(sched.stage_bytes.values()) > 0


def test_cached_path_trace_stability():
    """The silent failure mode of shape-dependent view building is a
    recompile per launch. The slab path must compile a BOUNDED number of
    jit traces across launches with varying hit/miss patterns, batch
    sizes, and cache states: one cascade trace per pow2 batch bucket
    (hit/miss patterns only change ARRAY VALUES — the indirection table,
    never shapes) and a pow2-bounded family of fill scatters."""
    from repro.core.engine import retrieve_batched_aux
    from repro.serve.runtime import _apply_fills
    idx, q = make_clustered_index(docs_per_tenant=96)
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8,
                                           cache_bytes=24 * 1024,
                                           auto_flush=False))
    casc0 = retrieve_batched_aux._cache_size()
    fill0 = _apply_fills._cache_size()
    # Many launches: varying batch compositions (1..8 lanes), repeated
    # and disjoint tenant mixes, a tiny budget that forces eviction/
    # re-admission churn, and arena mutations in between.
    rng = np.random.default_rng(0)

    def varied_launches(turns):
        # lane counts cycle over every pow2 bucket {1, 2, 4, 8} with a
        # fixed tenant rotation (shapes deterministic per bucket) while
        # the QUERIES vary freely — so consecutive launches see fresh
        # hit/miss/eviction patterns at identical trace shapes
        for i in range(turns):
            for j in range((1, 2, 3, 8)[i % 4]):
                t = j % 4
                rt.submit(t, q[t][int(rng.integers(8))], now=0.0)
            rt.flush()

    varied_launches(12)
    idx.ingest(0, jnp.asarray(rng.normal(size=(4, DIM)).astype(np.float32)))
    varied_launches(4)
    casc_traces = retrieve_batched_aux._cache_size() - casc0
    # pow2 batch buckets {1, 2, 4, 8} x at most 2 table-width buckets
    # (full-width vs compact, and the mutation can re-bucket the block
    # table once) -> bounded, nowhere near the 16 launches.
    assert casc_traces <= 12, f"cascade recompiled per launch: {casc_traces}"
    # fill scatters: pow2 (row-count, block-count) bucket pairs,
    # logarithmic^2 in the largest fill, reused across launches
    assert _apply_fills._cache_size() - fill0 <= 24
    # The sharp property: once the shape buckets exist, MORE launches with
    # fresh hit/miss/eviction patterns compile NOTHING new — patterns only
    # change array values (the indirection table), never trace shapes.
    stable0 = retrieve_batched_aux._cache_size()
    varied_launches(8)
    assert retrieve_batched_aux._cache_size() == stable0
    assert rt.cache_stats()["hits"] > 0 and rt.cache_stats()["evictions"] > 0


def test_cache_stats_snapshot_and_reset_windows():
    """Satellite fix: cache counters were lifetime-cumulative only.
    `snapshot()` gives a plain-data view and `reset_stats()` opens a new
    window (steady-state hit rates after a fill phase) WITHOUT touching
    residency — entries, slab bytes, and results are unaffected."""
    idx, q = make_clustered_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=4,
                                           cache_bytes=256 * 1024,
                                           auto_flush=False))
    for turn in range(3):                    # fill phase: misses then hits
        for t in range(4):
            rt.submit(t, q[t][turn], now=0.0)
        rt.flush()
    fill = rt.cache.snapshot()
    assert fill["misses"] > 0 and fill["fill_bytes"] > 0
    assert set(fill) == {"hits", "misses", "evictions", "stale_evictions",
                         "rejected", "fill_bytes", "fill_dispatches"}
    entries_before = len(rt.cache)
    rt.cache.reset_stats()
    assert rt.cache.hits == 0 and rt.cache.misses == 0
    assert len(rt.cache) == entries_before   # residency untouched
    for turn in range(3):                    # steady state: all hits
        for t in range(4):
            rt.submit(t, q[t][turn], now=0.0)
        rt.flush()
    steady = rt.cache.snapshot()
    assert steady["hits"] > 0 and steady["misses"] == 0
    assert steady["fill_bytes"] == 0
    # cache_stats() serves the same windowed numbers
    cs = rt.cache_stats()
    assert cs["hits"] == steady["hits"] and cs["fill_bytes"] == 0
    assert cs["bytes_used"] == rt.cache.bytes_used > 0


def test_observability_zero_compiles_and_bit_parity():
    """The observability overhead contract, unit-scale: serving the SAME
    schedule with a real registry + tracer must (a) return bit-identical
    results, (b) compile ZERO additional jit traces (metrics never reach
    jitted code), and (c) leave a balanced trace whose totals match the
    registry."""
    from repro.core.engine import retrieve_batched_aux
    from repro.obs import MetricsRegistry, Tracer
    from repro.serve.runtime import _apply_fills
    idx, q = make_clustered_index(docs_per_tenant=96)
    cfg = RuntimeConfig(max_batch=8, cache_bytes=256 * 1024,
                        auto_flush=False)

    def drive(rt):
        out = []
        for turn in range(4):
            hs = [rt.submit(t, q[t][turn % 8], now=float(turn))
                  for t in range(4)]
            rt.flush()
            out.extend(h.result() for h in hs)
        return out

    base = drive(ServingRuntime(idx, cfg))   # compiles the shape buckets
    casc0 = retrieve_batched_aux._cache_size()
    fill0 = _apply_fills._cache_size()
    reg, tracer = MetricsRegistry(), Tracer()
    obs = drive(ServingRuntime(idx, cfg, registry=reg, tracer=tracer))
    assert retrieve_batched_aux._cache_size() == casc0
    assert _apply_fills._cache_size() == fill0
    for a, b in zip(base, obs):
        assert jnp.array_equal(a.indices, b.indices)
        assert jnp.array_equal(a.scores, b.scores)
        assert jnp.array_equal(a.candidate_indices, b.candidate_indices)
    assert tracer.open_spans() == []
    assert reg.get("counter", "serve_requests_submitted").value == 16
    assert reg.get("counter", "serve_requests_resolved").value == 16
    assert reg.get("counter", "serve_launches").value == 4
    assert reg.get("histogram", "serve_batch_occupancy").count == 4
    assert reg.get("histogram", "energy_uj_per_query").count == 16
    # per-stage plan fan-out reached the registry
    assert reg.get("counter", "stage_bytes_hbm", stage="approx").value > 0
    # cache counters live on the SAME registry when one is supplied
    assert reg.get("counter", "cache_misses").value > 0


# ---------------------------------------------------------------------------
# Async pipeline parity: the deferred-bookkeeping contract
# ---------------------------------------------------------------------------

def test_async_pipeline_matches_sync_seeded_schedules():
    """Deterministic counterpart of the hypothesis property in
    test_runtime_properties.py (which needs hypothesis installed): under
    seeded random submit/poll/flush schedules with mid-schedule
    result(wait=False) probes, the async pipeline's results are
    bit-identical to the synchronous path and it forms the same
    launches."""
    idx, q = make_plain_index()

    def drive(depth, seed):
        rng = np.random.default_rng(seed)
        rt = ServingRuntime(idx, RuntimeConfig(
            max_batch=int(rng.choice([1, 2, 4])), max_wait=1.0,
            auto_flush=False, async_depth=depth))
        now, handles = 0.0, []
        for _ in range(24):
            op = rng.integers(3)
            if op == 0:
                t = int(rng.integers(3))
                handles.append(rt.submit(t, q[t][int(rng.integers(6))],
                                         now=now, deadline=now + 5.0))
            elif op == 1:
                now += float(rng.uniform(0.0, 2.0))
                rt.poll(now=now)
                if handles:
                    handles[-1].result(wait=False)   # non-blocking probe
            else:
                rt.flush()
        rt.flush()
        assert rt.in_flight() == 0
        return rt.launches, [h.result() for h in handles]

    for seed in range(4):
        launches_s, res_s = drive(0, seed)
        launches_a, res_a = drive(2, seed)
        assert launches_a == launches_s
        for rs, ra in zip(res_s, res_a):
            assert jnp.array_equal(rs.indices, ra.indices)
            assert jnp.array_equal(rs.scores, ra.scores)
            assert jnp.array_equal(rs.candidate_indices, ra.candidate_indices)


def test_async_cached_path_parity_and_ledgers():
    """The slab path's DEFERRED bookkeeping (selection readback, hit/miss
    ledger, admissions, session prior all run at retire time): results
    are bit-identical to the synchronous cached run, and with a barrier
    per turn the byte ledgers match it exactly too. A multi-launch flush
    (true pipelining: launch k+1 dispatches before launch k's bookkeeping
    ran) must still be bit-identical — only the ledgers may shift, since
    admissions land one launch late."""
    idx, q = make_clustered_index(seed=7)

    def run(depth, max_batch):
        rt = ServingRuntime(idx, RuntimeConfig(
            max_batch=max_batch, cache_bytes=1 << 20, prior_clusters=8,
            auto_flush=False, async_depth=depth))
        outs = []
        for turn in range(6):
            hs = [rt.submit(t, q[t][(turn + j) % 8], now=float(turn))
                  for t in range(4) for j in range(2)]
            rt.flush()
            outs.append(np.stack([np.asarray(h.result().indices)
                                  for h in hs]))
        stats = rt.cache_stats()
        return (outs, rt.stage1_bytes_streamed, rt.stage1_bytes_sram,
                stats["hits"], stats["misses"])

    # one launch per flush: barrier after every launch => ledger parity
    outs_s, hbm_s, sram_s, hits_s, miss_s = run(0, max_batch=8)
    outs_a, hbm_a, sram_a, hits_a, miss_a = run(2, max_batch=8)
    for a, s in zip(outs_a, outs_s):
        assert np.array_equal(a, s)
    assert (hbm_a, sram_a, hits_a, miss_a) == (hbm_s, sram_s, hits_s, miss_s)

    # two launches per flush: the second dispatch overlaps the first
    # launch's deferred bookkeeping — results must not move a bit
    outs_s4, *_ = run(0, max_batch=4)
    outs_a4, *_ = run(2, max_batch=4)
    for a, s in zip(outs_a4, outs_s4):
        assert np.array_equal(a, s)


def test_handles_are_single_assignment():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=2))
    h = rt.submit(0, q[0][0], now=0.0)
    rt.flush()
    first = h.result()
    assert h.result() is first                      # stable after resolve
    assert isinstance(h, RequestHandle)
    assert dataclasses.is_dataclass(rt.cfg)


# ---------------------------------------------------------------------------
# Per-cluster precision tiers (adaptive-precision cascade, serving side)
# ---------------------------------------------------------------------------

def _tier_reference(idx, q, tenants):
    tids = np.asarray([t for t in tenants for _ in range(2)], np.int32)
    Q = jnp.asarray(np.stack([q[t][i] for t in tenants for i in range(2)]))
    return idx.retrieve(Q, tids)


def _assert_lanes_match(handles, ref):
    for lane, h in enumerate(handles):
        res = h.result()
        assert jnp.array_equal(res.indices, ref.indices[lane])
        assert jnp.array_equal(res.scores, ref.scores[lane])
        assert jnp.array_equal(res.candidate_indices,
                               ref.candidate_indices[lane])


def test_precision_tiers_admit_sign_promote_on_reprobe():
    """Tier lifecycle under an AMPLE budget: misses admit at the SIGN
    tier (no slab slots), a re-probe promotes to FULL (plane bytes
    charged once, as the miss they replace), and the third pass serves
    full-tier hits with ZERO stage-1 HBM bytes — every pass bit-identical
    to the uncached prescreen cascade."""
    idx, q = make_clustered_index(prescreen_c0=32)
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, cache_bytes=1 << 20,
                                           precision_tiers=True,
                                           auto_flush=False))
    ref = _tier_reference(idx, q, range(4))

    _assert_lanes_match(run_batch(rt, q, range(4)), ref)   # pass 1: cold
    s1 = rt.cache.snapshot()
    assert s1["sign_entries"] > 0 and s1["full_entries"] == 0
    assert s1["promotions"] == 0

    _assert_lanes_match(run_batch(rt, q, range(4)), ref)   # pass 2: promote
    s2 = rt.cache.snapshot()
    assert s2["promotions"] > 0 and s2["full_entries"] > 0

    hbm_before = rt.stage1_bytes_streamed
    _assert_lanes_match(run_batch(rt, q, range(4)), ref)   # pass 3: warm
    assert rt.stage1_bytes_streamed == hbm_before    # full-tier hits: 0 HBM
    assert rt.last_plan.stage1_bytes == 0
    assert rt.last_plan.stage1_bytes_sram > 0
    s3 = rt.cache.snapshot()
    assert s3["hits"] > s2["hits"]


def test_precision_tiers_demote_under_pressure_bit_identical():
    """A slab budget far below the working set forces FULL->SIGN
    demotions instead of outright evictions; results must stay
    bit-identical to the uncached cascade and to a full-precision-cache
    runtime serving the same trace, and the sign tier (which holds no
    slab slots) must retain more residents than the slab could."""
    idx, q = make_clustered_index(prescreen_c0=32)
    tight = 4 * 32 * (DIM // 2)      # 4 slab slots; working set is ~8+
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, cache_bytes=tight,
                                           precision_tiers=True,
                                           auto_flush=False))
    rt_full = ServingRuntime(idx, RuntimeConfig(max_batch=8,
                                                cache_bytes=tight,
                                                auto_flush=False))
    ref = _tier_reference(idx, q, range(4))
    for _ in range(3):
        _assert_lanes_match(run_batch(rt, q, range(4)), ref)
        _assert_lanes_match(run_batch(rt_full, q, range(4)), ref)
    snap = rt.cache.snapshot()
    assert snap["demotions"] > 0
    assert snap["sign_entries"] + snap["full_entries"] > rt.cache.num_slab_blocks
    # sign residency prescreens without slab slots, so the tiered cache
    # must not stream MORE stage-1 plane bytes than the thrashing
    # full-precision cache on the same trace
    assert rt.stage1_bytes_streamed <= rt_full.stage1_bytes_streamed
