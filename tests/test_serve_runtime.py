"""Serving runtime: deadline batcher, fairness, hot-cluster cache parity.

The bit-exactness contract under test: the runtime's batching, padding,
and hot-cluster cache may change WHEN work runs and WHERE stage-1 bytes
come from, but never WHAT any request retrieves — including across arena
mutations, where a stale cached view must be evicted, not served.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import RetrievalConfig, quantize_int8
from repro.core.clustering import ClusterParams
from repro.serve.runtime import (HotClusterCache, RequestHandle,
                                 RuntimeConfig, ServingRuntime)
from repro.tenancy import MultiTenantIndex

DIM = 64


def make_clustered_index(tenants=4, docs_per_tenant=96, k=3, seed=0,
                         num_clusters=8, nprobe=2, block_rows=32,
                         capacity=1024):
    rng = np.random.default_rng(seed)
    idx = MultiTenantIndex(capacity, DIM, RetrievalConfig(k=k),
                           clusters=ClusterParams(num_clusters=num_clusters,
                                                  nprobe=nprobe,
                                                  block_rows=block_rows))
    docs = {}
    for t in range(tenants):
        d = rng.normal(size=(docs_per_tenant, DIM)).astype(np.float32)
        idx.ingest(t, jnp.asarray(d))
        docs[t] = d
    idx.compact()
    queries = {t: np.asarray(quantize_int8(jnp.asarray(d[:8]),
                                           per_vector=True)[0])
               for t, d in docs.items()}
    return idx, queries


def make_plain_index(tenants=3, seed=0, capacity=256, k=3):
    """No clustering; interleaved ingests FRAGMENT every tenant so the
    batched path falls back to the full-arena masked scan (whose per-lane
    results are independent of batch composition by construction)."""
    rng = np.random.default_rng(seed)
    idx = MultiTenantIndex(capacity, DIM, RetrievalConfig(k=k))
    docs = {t: [] for t in range(tenants)}
    for _ in range(3):
        for t in range(tenants):
            d = rng.normal(size=(5, DIM)).astype(np.float32)
            idx.ingest(t, jnp.asarray(d))
            docs[t].append(d)
    docs = {t: np.concatenate(v) for t, v in docs.items()}
    assert any(len(idx.table.segments(t)) > 1 for t in range(tenants))
    queries = {t: np.asarray(quantize_int8(jnp.asarray(d[:6]),
                                           per_vector=True)[0])
               for t, d in docs.items()}
    return idx, queries


# ---------------------------------------------------------------------------
# Admission: deadlines, max-batch, fairness, handles
# ---------------------------------------------------------------------------

def test_deadline_admission_virtual_clock():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, max_wait=5.0,
                                           auto_flush=False))
    h = rt.submit(0, q[0][0], now=0.0)
    assert not rt.ready(now=0.0) and rt.poll(now=4.9) == []
    assert not h.done() and rt.pending() == 1
    assert rt.next_deadline() == 5.0
    resolved = rt.poll(now=5.0)                 # deadline forces the launch
    assert resolved == [h] and h.done() and rt.pending() == 0


def test_full_batch_launches_immediately_from_submit():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=2, max_wait=100.0))
    h1 = rt.submit(0, q[0][0], now=0.0)
    assert not h1.done()                        # partial batch waits
    h2 = rt.submit(1, q[1][0], now=0.0)
    assert h1.done() and h2.done() and rt.launches == 1


def test_explicit_deadline_overrides_max_wait():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, max_wait=100.0,
                                           auto_flush=False))
    h = rt.submit(0, q[0][0], now=0.0, deadline=1.0)
    assert rt.poll(now=0.5) == [] and rt.poll(now=1.0) == [h]


def test_result_drains_and_wait_false_raises():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, auto_flush=False))
    h = rt.submit(0, q[0][0], now=0.0)
    with pytest.raises(RuntimeError, match="still queued"):
        h.result(wait=False)
    res = h.result()                            # future-style: drains
    assert h.done() and np.asarray(res.indices).shape == (3,)


def test_round_robin_fairness_no_tenant_starvation():
    """A chatty tenant floods the queue; the first launch must still carry
    the quiet tenants' requests instead of 4 lanes of the flooder."""
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=4, auto_flush=False))
    chatty = [rt.submit(0, q[0][i], now=0.0) for i in range(6)]
    quiet = [rt.submit(t, q[t][0], now=0.0) for t in (1, 2)]
    rt.flush()
    first = [h for h in chatty + quiet if h.launch_index == 0]
    assert {h.tenant_id for h in first} == {0, 1, 2}
    assert sum(h.tenant_id == 0 for h in first) == 2
    # FIFO within a tenant: the flooder's own requests resolve in order.
    order = sorted(chatty, key=lambda h: h.request_id)
    launches = [h.launch_index for h in order]
    assert launches == sorted(launches)


def test_fifo_mode_preserves_arrival_grouping():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=4, fairness="fifo",
                                           auto_flush=False))
    handles = [rt.submit(0, q[0][i], now=0.0) for i in range(5)]
    handles.append(rt.submit(1, q[1][0], now=0.0))
    rt.flush()
    assert [h.launch_index for h in handles] == [0, 0, 0, 0, 1, 1]


def test_partial_batch_pads_to_pow2_bucket():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, auto_flush=False))
    for i in range(3):
        rt.submit(0, q[0][i], now=0.0)
    rt.flush()
    assert rt.last_plan.batch == 4              # 3 real lanes + 1 padding
    assert rt.queries_served == 3


def test_submit_validation():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx)
    with pytest.raises(ValueError, match="tenant id"):
        rt.submit(-1, q[0][0])
    with pytest.raises(ValueError, match="query must be"):
        rt.submit(0, q[0][0][:DIM // 2])
    with pytest.raises(ValueError, match="max_batch"):
        RuntimeConfig(max_batch=0)
    with pytest.raises(ValueError, match="fairness"):
        RuntimeConfig(fairness="lifo")


# ---------------------------------------------------------------------------
# Hot-cluster cache: bit-exact parity, invalidation, accounting
# ---------------------------------------------------------------------------

def run_batch(rt, idx_queries, tenants):
    handles = [rt.submit(t, idx_queries[t][i], now=0.0)
               for t in tenants for i in range(2)]
    rt.flush()
    return handles


def test_cache_hit_path_bit_identical_to_miss_path():
    """Turn 2 re-issues turn 1's queries: every cluster view comes from
    the cache, and every result must be bit-identical to the cold turn
    AND to the uncached ClusterPolicy cascade."""
    idx, q = make_clustered_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, cache_bytes=1 << 20,
                                           auto_flush=False))
    cold = run_batch(rt, q, range(4))
    assert rt.cache_stats()["misses"] > 0
    hbm_after_cold = rt.stage1_bytes_streamed
    warm = run_batch(rt, q, range(4))
    assert rt.stage1_bytes_streamed == hbm_after_cold   # fully warm: 0 HBM
    assert rt.last_plan.stage1_bytes == 0
    assert rt.last_plan.stage1_bytes_sram > 0
    # uncached reference (same grouping, direct index.retrieve)
    tids = np.asarray([t for t in range(4) for _ in range(2)], np.int32)
    Q = jnp.asarray(np.stack([q[t][i] for t in range(4) for i in range(2)]))
    ref = idx.retrieve(Q, tids)
    for lane, (c, w) in enumerate(zip(cold, warm)):
        for res in (c.result(), w.result()):
            assert jnp.array_equal(res.indices, ref.indices[lane])
            assert jnp.array_equal(res.scores, ref.scores[lane])
            assert jnp.array_equal(res.candidate_indices,
                                   ref.candidate_indices[lane])


def test_cache_straddling_arena_mutation_evicts_stale_views():
    """Warm the cache, MUTATE the arena (insert + delete), query again:
    the stale generation's views must be evicted, and the results must
    equal a fresh uncached retrieval over the mutated arena."""
    rng = np.random.default_rng(7)
    idx, q = make_clustered_index(seed=7)
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, cache_bytes=1 << 20,
                                           auto_flush=False))
    run_batch(rt, q, range(4))                      # warm
    assert len(rt.cache) > 0
    gen_before = idx.arena.generation
    new = rng.normal(size=(4, DIM)).astype(np.float32)
    idx.ingest(0, jnp.asarray(new))                 # mutation 1
    idx.delete(1, idx.table.slots(1)[:2])           # mutation 2
    assert idx.arena.generation > gen_before
    handles = run_batch(rt, q, range(4))
    assert rt.cache_stats()["stale_evictions"] > 0
    tids = np.asarray([t for t in range(4) for _ in range(2)], np.int32)
    Q = jnp.asarray(np.stack([q[t][i] for t in range(4) for i in range(2)]))
    ref = idx.retrieve(Q, tids)
    for lane, h in enumerate(handles):
        res = h.result()
        assert jnp.array_equal(res.indices, ref.indices[lane])
        assert jnp.array_equal(res.scores, ref.scores[lane])
    # a query for the newly ingested doc sees the post-mutation arena
    # exactly as the uncached cascade does (no stale view hides it)
    qn, _ = quantize_int8(jnp.asarray(new[:1]), per_vector=True)
    h = rt.submit(0, np.asarray(qn[0]), now=0.0)
    rt.flush()
    fresh = idx.retrieve(qn, np.asarray([0], np.int32))
    assert jnp.array_equal(h.result().indices, fresh.indices[0])
    assert jnp.array_equal(h.result().scores, fresh.scores[0])
    # and the tombstoned rows can never surface
    gone = np.asarray(idx.arena.owner) < 0
    for hh in handles:
        got = np.asarray(hh.result().indices)
        assert not gone[got[got >= 0]].any()


def test_cache_budget_shrinkage_monotone_hbm_bytes():
    """Shrinking the byte budget can only increase HBM traffic on the
    same trace (and never changes results)."""
    byts, results = [], []
    for budget in (1 << 20, 6 * 1024, 0):
        idx, q = make_clustered_index(seed=3)
        rt = ServingRuntime(idx, RuntimeConfig(max_batch=8,
                                               cache_bytes=budget,
                                               auto_flush=False))
        hs = []
        for _ in range(3):
            hs.extend(run_batch(rt, q, range(4)))
        byts.append(rt.stage1_bytes_streamed)
        results.append([np.asarray(h.result().indices) for h in hs])
    assert byts[0] <= byts[1] <= byts[2]
    assert byts[0] < byts[2]
    for got in results[1:]:
        for a, b in zip(results[0], got):
            np.testing.assert_array_equal(a, b)


def test_session_prior_rewarms_cache_after_mutation():
    """After a mutation invalidates the cache, the tenant's recent-cluster
    prior prefetches its session's clusters at the next flush — so the
    probes themselves hit."""
    rng = np.random.default_rng(5)
    idx, q = make_clustered_index(seed=5)
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, cache_bytes=1 << 20,
                                           prior_clusters=8,
                                           auto_flush=False))
    run_batch(rt, q, range(4))                      # establishes priors
    idx.ingest(0, jnp.asarray(rng.normal(size=(4, DIM)).astype(np.float32)))
    hits_before = rt.cache_stats()["hits"]
    run_batch(rt, q, range(4))                      # same session turns
    assert rt.prefetch_bytes > 0
    assert rt.cache_stats()["hits"] > hits_before


def test_lru_cache_unit_behavior():
    cache = HotClusterCache(budget_bytes=100)
    v = np.zeros(40, np.uint8)
    cache.sync_generation(1)
    cache.put(0, 0, v)
    cache.put(0, 1, v)
    assert cache.get(0, 0) is not None              # 0 now most recent
    cache.put(0, 2, v)                              # evicts LRU = (0, 1)
    assert cache.bytes_used <= 100 and len(cache) == 2
    assert cache.peek(0, 0) and not cache.peek(0, 1)
    assert cache.evictions == 1
    cache.sync_generation(2)                        # arena mutated
    assert len(cache) == 0 and cache.stale_evictions == 2
    with pytest.raises(ValueError):
        HotClusterCache(budget_bytes=-1)


def test_oversized_view_rejected_without_flushing_cache():
    """A view larger than the whole budget must be refused admission —
    NOT evict every resident tenant's entries on its way to nowhere."""
    cache = HotClusterCache(budget_bytes=100)
    cache.sync_generation(1)
    cache.put(0, 0, np.zeros(40, np.uint8))
    cache.put(1, 0, np.zeros(40, np.uint8))
    cache.put(2, 7, np.zeros(400, np.uint8))        # > budget: rejected
    assert cache.rejected == 1 and cache.evictions == 0
    assert cache.peek(0, 0) and cache.peek(1, 0) and not cache.peek(2, 7)
    assert cache.bytes_used == 80


def test_max_wait_zero_means_no_deadline_launches():
    """max_wait=0 is the legacy contract: partial batches launch only
    when full or explicitly flushed, never by the clock."""
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=4, max_wait=0.0,
                                           auto_flush=False))
    h = rt.submit(0, q[0][0], now=0.0)
    assert rt.next_deadline() is None
    assert rt.poll(now=1e9) == [] and not h.done()  # clock can't force it
    explicit = rt.submit(1, q[1][0], now=0.0, deadline=5.0)
    assert set(rt.poll(now=5.0)) == {h, explicit}   # explicit still works
    assert rt.pending() == 0


def test_runtime_ledger_matches_plan_accounting():
    idx, q = make_clustered_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=8, cache_bytes=1 << 20,
                                           auto_flush=False))
    run_batch(rt, q, range(4))
    plan = rt.last_plan
    assert plan.kind == "cluster"
    assert rt.stage_bytes["approx"] == plan.stage1_bytes
    assert rt.stage_bytes["prune"] == plan.stages[0].bytes_hbm
    # hits + misses account every probed byte of the launch
    run_batch(rt, q, range(4))
    plan2 = rt.last_plan
    approx = [s for s in plan2.stages if s.name == "approx"][0]
    assert approx.bytes_hbm == plan2.stage1_bytes == 0
    assert approx.bytes_sram == plan2.stage1_bytes_sram > 0
    ledger = rt.energy_ledger()
    assert ledger.total_uj > 0


def test_scheduler_wrapper_still_fifo_and_ledgered():
    """The legacy CrossTenantBatchScheduler facade keeps its contract on
    top of the runtime: int tickets, FIFO groups, byte ledgers."""
    from repro.tenancy import CrossTenantBatchScheduler
    idx, q = make_clustered_index()
    sched = CrossTenantBatchScheduler(idx, max_batch=4)
    rids = [sched.submit(t, q[t][0]) for t in range(4)]
    rids += [sched.submit(0, q[0][1])]
    assert sched.pending() == 5
    out = sched.flush()
    assert sched.pending() == 0 and sched.launches == 2
    assert set(out) == set(rids)
    assert sched.stage1_bytes_streamed > 0
    assert sched.stage_bytes == {
        s.name: s.bytes_hbm for s in idx.last_plan.stages} or \
        sum(sched.stage_bytes.values()) > 0


def test_handles_are_single_assignment():
    idx, q = make_plain_index()
    rt = ServingRuntime(idx, RuntimeConfig(max_batch=2))
    h = rt.submit(0, q[0][0], now=0.0)
    rt.flush()
    first = h.result()
    assert h.result() is first                      # stable after resolve
    assert isinstance(h, RequestHandle)
    assert dataclasses.is_dataclass(rt.cfg)
