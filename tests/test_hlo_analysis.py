import jax
import jax.numpy as jnp

from repro.launch.hlo_analysis import analyze, parse_module


def _compile(f, *shapes):
    args = [jax.ShapeDtypeStruct(s, jnp.float32) for s in shapes]
    return jax.jit(f).lower(*args).compile()


def test_scan_flops_scaled_by_trip_count():
    def f(x, w):
        def body(c, _):
            return c @ w, None
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y
    c = _compile(f, (128, 128), (128, 128))
    r = analyze(c.as_text())
    assert r["dot_flops"] == 10 * 2 * 128 ** 3


def test_nested_scan_flops():
    def g(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            c2, _ = jax.lax.scan(inner, c, None, length=3)
            return c2, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y
    c = _compile(g, (64, 64), (64, 64))
    r = analyze(c.as_text())
    assert r["dot_flops"] == 12 * 2 * 64 ** 3


def test_plain_dot_flops():
    c = _compile(lambda a, b: a @ b, (32, 64), (64, 16))
    r = analyze(c.as_text())
    assert r["dot_flops"] == 2 * 32 * 64 * 16


def test_parse_module_splits_computations():
    def f(x):
        def body(c, _):
            return c * 2.0, None
        y, _ = jax.lax.scan(body, x, None, length=5)
        return y
    c = _compile(f, (8,))
    comps = parse_module(c.as_text())
    assert len(comps) >= 2          # entry + loop body/cond
