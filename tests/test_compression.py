import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis; see requirements.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.distributed import compression as comp


def test_int8_roundtrip_error_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (1000,)) * 5
    q, scale = comp.quantize_int8_tensor(x)
    err = jnp.max(jnp.abs(comp.dequantize_int8_tensor(q, scale) - x))
    assert float(err) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """With error feedback, the SUM of decompressed gradients converges to
    the sum of true gradients (residual stays bounded)."""
    key = jax.random.PRNGKey(1)
    err = jnp.zeros((256,))
    total_true = jnp.zeros((256,))
    total_sent = jnp.zeros((256,))
    for i in range(50):
        key, k = jax.random.split(key)
        g = jax.random.normal(k, (256,)) * (1.0 + i % 3)
        total_true += g
        sent, err = comp.compress_decompress(g, err)
        total_sent += sent
    # everything not yet sent lives in the residual
    np.testing.assert_allclose(np.asarray(total_sent + err),
                               np.asarray(total_true), rtol=1e-4, atol=1e-3)
    assert float(jnp.max(jnp.abs(err))) < 1.0


def test_apply_error_feedback_tree():
    g = {"a": jnp.ones((8,)), "b": {"c": jnp.full((4,), -2.0)}}
    e = comp.init_error_state(g)
    out, e2 = comp.apply_error_feedback(g, e)
    assert jax.tree.structure(out) == jax.tree.structure(g)
    np.testing.assert_allclose(np.asarray(out["a"]), np.ones(8), atol=0.02)


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_compress_preserves_large_values(seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=64).astype(np.float32) * 100)
    q, s = comp.quantize_int8_tensor(x)
    deq = comp.dequantize_int8_tensor(q, s)
    assert float(jnp.max(jnp.abs(deq - x))) <= float(s) * 0.5 + 1e-4


def test_two_level_all_reduce_single_device_mesh():
    """On a (pod=1, data=1) mesh the two-level reduction must be exact
    identity-mean (numerics of the quantize/dequantize path)."""
    from repro.compat import make_mesh, shard_map
    mesh = make_mesh((1, 1), ("pod", "data"))
    reduce_fn = comp.make_two_level_all_reduce(mesh)
    g = {"w": jax.random.normal(jax.random.PRNGKey(0), (33,))}

    out = shard_map(lambda t: reduce_fn(t), mesh=mesh,
                    in_specs=jax.sharding.PartitionSpec(),
                    out_specs=jax.sharding.PartitionSpec(),
                    check_vma=False)(g)
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127.0
    np.testing.assert_allclose(np.asarray(out["w"]), np.asarray(g["w"]),
                               atol=scale * 0.5 + 1e-6)
