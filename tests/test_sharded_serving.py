"""Sharded multi-tenant serving: placement, routing, tournament merge,
elastic failover — plus the pad-row regression for the device-mesh
tournament in core/index.py.

The in-process tests run every shard on the default single device (the
routing / translation / merge / failover logic is device-count
agnostic); the @slow subprocess tests re-run the parity and failover
gates on a REAL 4-way forced-host device mesh.
"""
import os
import subprocess
import sys

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.retrieval import RetrievalConfig
from repro.obs import MetricsRegistry
from repro.serve.runtime import RuntimeConfig, ServingRuntime
from repro.serve.sharded import ShardedRuntimeConfig, ShardedServingRuntime
from repro.tenancy import MultiTenantIndex, PlacementTable

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))

DIM = 32
K = 4
NT = 5          # tenants
ND = 20         # docs per tenant


def _corpus(seed=0):
    rng = np.random.default_rng(seed)
    docs = {t: rng.integers(-40, 41, (ND, DIM), dtype=np.int8)
            for t in range(NT)}
    qs = {t: rng.integers(-40, 41, (DIM,), dtype=np.int8)
          for t in range(NT)}
    return docs, qs


def _cfg(num_shards, spread=1, metric="mips", max_batch=4):
    # candidate_frac=1.0: the stage-1 budget covers every tenant's rows
    # in EVERY placement, the documented precondition for bit-parity
    # across shard counts.
    return ShardedRuntimeConfig(
        num_shards=num_shards, capacity_per_shard=256, dim=DIM,
        spread=spread,
        retrieval=RetrievalConfig(k=K, metric=metric, candidate_frac=1.0),
        runtime=RuntimeConfig(max_batch=max_batch, max_wait=1.0,
                              cache_bytes=0, auto_flush=False))


def _exact(docs, qs, t):
    return docs[t].astype(np.int64) @ qs[t].astype(np.int64)


def _check_scores(docs, qs, t, r):
    """Score-exact oracle (tie-tolerant on indices: the engine breaks
    exact-score ties by stage-1 candidate rank, not ordinal)."""
    exact = _exact(docs, qs, t)
    want = np.sort(exact)[::-1][:K]
    got_i, got_s = np.asarray(r.indices), np.asarray(r.scores)
    assert np.array_equal(got_s, want), (t, got_s, want)
    assert (got_i >= 0).all() and len(set(got_i.tolist())) == K
    assert np.array_equal(exact[got_i], got_s)


# ---------------------------------------------------------------------------
# PlacementTable
# ---------------------------------------------------------------------------

def test_placement_deterministic_and_minimal_movement():
    a = PlacementTable(range(4))
    b = PlacementTable(range(4))
    owners = {t: a.owners(t) for t in range(50)}
    assert owners == {t: b.owners(t) for t in range(50)}   # pure hash
    assert len({o[0] for o in owners.values()}) == 4        # uses all shards
    victim = a.shard_of(0)
    moved = a.remove_shard(victim)
    for t in range(50):
        if t in moved:
            assert victim not in a.owners(t)
        else:
            assert a.owners(t) == owners[t]                 # nobody else moves


def test_placement_spread_owners_distinct_and_doc_round_robin():
    p = PlacementTable(range(4), spread=3)
    for t in range(10):
        own = p.owners(t)
        assert len(own) == 3 and len(set(own)) == 3
        assert [p.doc_shard(t, o) for o in range(6)] == list(own) * 2


def test_placement_cannot_remove_last_shard():
    p = PlacementTable([0, 1])
    p.remove_shard(0)
    with pytest.raises(Exception):
        p.remove_shard(1)


# ---------------------------------------------------------------------------
# core/index.py pad-row regression (satellite bugfix)
# ---------------------------------------------------------------------------

def test_tournament_pad_rows_masked_for_all_negative_corpus():
    """pad_database appends zero docs (score 0). With an all-negative
    MIPS corpus, 0 beats every real doc — pre-fix the tournament returned
    the pad ids (>= n_global); the fix masks them out of both stages."""
    from repro.compat import make_mesh
    from repro.core import quantization
    from repro.core.bitplanar import BitPlanarDB
    from repro.core.index import ShardedIndex, pad_database, shard_database

    rng = np.random.default_rng(7)
    q = rng.normal(size=(64,)).astype(np.float32)
    # docs anti-correlated with q => every exact MIPS score is negative
    emb = (-q[None, :] + 0.05 * rng.normal(size=(6, 64))).astype(np.float32)
    db = quantization.build_database(jnp.asarray(emb))
    bp = BitPlanarDB.from_quantized(db)
    n_global = bp.num_docs
    mesh = make_mesh((1,), ("data",))
    idx = ShardedIndex(db=shard_database(pad_database(bp, 4), mesh),
                       mesh=mesh, n_global=n_global)   # 2 pad rows
    qc = np.asarray(quantization.quantize_int8_fixed(jnp.asarray(q),
                                                     bp.scale), np.int8)
    r = idx.retrieve_fn(RetrievalConfig(k=3, metric="mips"))(qc)
    got = np.asarray(r.indices)
    assert (got < n_global).all(), f"pad rows returned: {got}"
    assert (np.asarray(r.scores) < 0).all()
    # candidates may mention pads structurally, but never the results


# ---------------------------------------------------------------------------
# Sharded runtime: routing + merge parity
# ---------------------------------------------------------------------------

def test_one_shard_sharded_matches_plain_runtime_bitwise():
    """A 1-shard ShardedServingRuntime is the plain ServingRuntime plus a
    slot->ordinal translation — indices (translated), scores, and byte
    ledgers must all be bit-identical."""
    docs, qs = _corpus()
    cfg = _cfg(1)
    srt = ShardedServingRuntime(cfg)
    idx = MultiTenantIndex(cfg.capacity_per_shard, DIM, cfg.retrieval)
    prt = ServingRuntime(idx, cfg.runtime)
    base = {}
    for t in range(NT):
        srt.ingest_codes(t, docs[t])
        slots = idx.ingest_codes(t, docs[t])
        base[t] = int(slots[0])
    hs = {t: srt.submit(t, qs[t], now=0.0) for t in range(NT)}
    hp = {t: prt.submit(t, qs[t], now=0.0) for t in range(NT)}
    srt.flush(now=0.1)
    prt.flush(now=0.1)
    for t in range(NT):
        rs, rp = hs[t].result(), hp[t].result()
        plain_ords = np.where(np.asarray(rp.indices) >= 0,
                              np.asarray(rp.indices) - base[t], -1)
        assert np.array_equal(np.asarray(rs.indices), plain_ords), t
        assert np.array_equal(np.asarray(rs.scores), np.asarray(rp.scores))
    led = srt.ledger()
    assert led["stage1_bytes_hbm"] == prt.stage1_bytes_streamed
    assert led["launches"] == prt.launches
    assert led["shard_lanes_served"] == {0: prt.queries_served}


def test_multi_shard_matches_single_shard_bitwise():
    """Placement invariance: the same trace on 1 shard and on 3 shards
    returns bit-identical (ordinals, scores) per request."""
    docs, qs = _corpus()
    results = {}
    for s in (1, 3):
        rt = ShardedServingRuntime(_cfg(s))
        for t in range(NT):
            rt.ingest_codes(t, docs[t])
        hs = {t: rt.submit(t, qs[t], now=0.0) for t in range(NT)}
        rt.flush(now=0.1)
        results[s] = {t: hs[t].result() for t in range(NT)}
        led = rt.ledger()
        assert led["dropped"] == 0 and led["duplicated"] == 0
    for t in range(NT):
        a, b = results[1][t], results[3][t]
        assert np.array_equal(np.asarray(a.indices),
                              np.asarray(b.indices)), t
        assert np.array_equal(np.asarray(a.scores), np.asarray(b.scores)), t
        _check_scores(docs, qs, t, b)


def test_spread_two_merge_matches_brute_force():
    docs, qs = _corpus(3)
    rt = ShardedServingRuntime(_cfg(3, spread=2))
    for t in range(NT):
        rt.ingest_codes(t, docs[t])
        assert len(rt.placement.owners(t)) == 2
    hs = {t: rt.submit(t, qs[t], now=0.0) for t in range(NT)}
    rt.flush(now=0.1)
    for t in range(NT):
        _check_scores(docs, qs, t, hs[t].result())
        assert len(hs[t]._req.subs) == 2        # really fanned out


def test_spread_requires_mips():
    with pytest.raises(ValueError, match="spread"):
        _cfg(3, spread=2, metric="cosine")


def test_cosine_single_owner_end_to_end():
    docs, qs = _corpus(5)
    rt = ShardedServingRuntime(_cfg(3, metric="cosine"))
    for t in range(NT):
        rt.ingest_codes(t, docs[t])
    h = rt.submit(2, qs[2], now=0.0)
    rt.flush(now=0.1)
    r = h.result()
    assert (np.asarray(r.indices) >= 0).all()
    # cosine rank oracle (scale-free): compare against float cosine
    exact = _exact(docs, qs, 2).astype(np.float64)
    cos = exact / np.sqrt((docs[2].astype(np.float64) ** 2).sum(1))
    assert set(np.asarray(r.indices).tolist()) == \
        set(np.argsort(-cos, kind="stable")[:K].tolist())


# ---------------------------------------------------------------------------
# Elastic failover
# ---------------------------------------------------------------------------

def test_failover_exactly_once_and_correct():
    docs, qs = _corpus(11)
    rt = ShardedServingRuntime(_cfg(3))
    for t in range(NT):
        rt.ingest_codes(t, docs[t])
    pre = {t: rt.submit(t, qs[t], now=0.0) for t in range(NT)}
    rt.flush(now=0.1)                      # resolve BEFORE the failure
    mid = {t: rt.submit(t, qs[t], now=0.2) for t in range(NT)}
    victim = rt.placement.shard_of(0)
    rep = rt.fail_shard(victim, now=0.3)
    assert victim not in rt.live_shards
    assert rep["requests_resubmitted"] >= 1
    assert rep["docs_restored"] == ND * len(rep["moved_tenants"])
    post = {t: rt.submit(t, qs[t], now=0.4) for t in range(NT)}
    rt.flush(now=0.5)
    for t in range(NT):
        for h in (pre[t], mid[t], post[t]):
            _check_scores(docs, qs, t, h.result())
    led = rt.ledger()
    assert led["submitted"] == 3 * NT
    assert led["resolved"] == 3 * NT
    assert led["dropped"] == 0 and led["duplicated"] == 0
    assert led["resolved_by_tenant"] == {t: 3 for t in range(NT)}
    assert led["failovers"] == 1
    assert str(victim) not in rt.monitor.workers()
    assert rt.mesh.devices.size <= len(rt.live_shards)


def test_failover_resolved_results_are_not_recomputed():
    docs, qs = _corpus(13)
    rt = ShardedServingRuntime(_cfg(2))
    for t in range(NT):
        rt.ingest_codes(t, docs[t])
    h = rt.submit(0, qs[0], now=0.0)
    rt.flush(now=0.1)
    r1 = h.result()
    rt.fail_shard(rt.placement.shard_of(0), now=0.2)
    assert h.result() is r1                 # cached, never re-run
    assert rt.ledger()["resolved"] == 1


def test_failover_skips_deleted_docs():
    docs, qs = _corpus(17)
    rt = ShardedServingRuntime(_cfg(2))
    for t in range(NT):
        rt.ingest_codes(t, docs[t])
    rt.delete(0, [0, 3])
    rt.fail_shard(rt.placement.shard_of(0), now=0.0)
    assert rt.num_docs(0) == ND - 2
    h = rt.submit(0, qs[0], now=0.1)
    rt.flush(now=0.2)
    got = np.asarray(h.result().indices)
    assert 0 not in got and 3 not in got
    exact = _exact(docs, qs, 0)
    exact[[0, 3]] = np.iinfo(np.int64).min
    assert np.array_equal(np.asarray(h.result().scores),
                          np.sort(exact)[::-1][:K])


def test_cannot_fail_last_shard_or_use_dead_shard():
    docs, qs = _corpus()
    rt = ShardedServingRuntime(_cfg(2))
    rt.ingest_codes(0, docs[0])
    rt.fail_shard(rt.placement.shard_of(0))
    with pytest.raises(RuntimeError):
        rt.fail_shard(rt.live_shards[0])


def test_per_shard_labeled_metrics():
    docs, qs = _corpus()
    reg = MetricsRegistry()
    rt = ShardedServingRuntime(_cfg(2), registry=reg)
    for t in range(NT):
        rt.ingest_codes(t, docs[t])
    for t in range(NT):
        rt.submit(t, qs[t], now=0.0)
    rt.flush(now=0.1)
    per_shard = [reg.get("counter", "serve_requests_submitted",
                         shard=str(s)) for s in (0, 1)]
    assert all(c is not None for c in per_shard)
    assert sum(c.value for c in per_shard) == NT


# ---------------------------------------------------------------------------
# Schedule fuzz: failover composed with arbitrary interleavings
# ---------------------------------------------------------------------------

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYP = True
except ImportError:                                      # pragma: no cover
    HAVE_HYP = False

if HAVE_HYP:
    _ops = st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(0, NT - 1)),
            st.tuples(st.just("poll"), st.just(0)),
            st.tuples(st.just("flush"), st.just(0)),
            st.tuples(st.just("fail"), st.integers(0, 2)),
        ),
        min_size=1, max_size=25)

    @settings(max_examples=15, deadline=None)
    @given(schedule=_ops, num_shards=st.sampled_from([2, 3]))
    def test_failover_fuzz_never_drops_or_duplicates(schedule, num_shards):
        docs, qs = _corpus(23)
        rt = ShardedServingRuntime(_cfg(num_shards))
        for t in range(NT):
            rt.ingest_codes(t, docs[t])
        now, handles, fails = 0.0, [], 0
        for op, a in schedule:
            now += 0.01
            if op == "submit":
                handles.append((a, rt.submit(a, qs[a], now=now)))
            elif op == "poll":
                rt.poll(now=now)
            elif op == "flush":
                rt.flush(now=now)
            elif op == "fail" and len(rt.live_shards) > 1:
                rt.fail_shard(rt.live_shards[a % len(rt.live_shards)],
                              now=now)
                fails += 1
        rt.flush(now=now + 1)
        for t, h in handles:
            assert h.done()
            _check_scores(docs, qs, t, h.result())
        led = rt.ledger()
        assert led["submitted"] == len(handles)
        assert led["resolved"] == len(handles)
        assert led["outstanding"] == 0
        assert led["dropped"] == 0 and led["duplicated"] == 0
        assert led["failovers"] == fails


# ---------------------------------------------------------------------------
# Forced-host multi-device parity (subprocess, real 4-way mesh)
# ---------------------------------------------------------------------------

def run_sub(code: str, timeout=600):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


@pytest.mark.slow
def test_sharded_serving_multidevice_parity_and_failover():
    """On a REAL 4-device mesh: 4-shard results bit-match the 1-shard
    baseline, and a mid-trace device loss completes the trace with zero
    dropped / duplicated requests."""
    run_sub("""
import numpy as np, jax
from repro.core.retrieval import RetrievalConfig
from repro.serve.runtime import RuntimeConfig
from repro.serve.sharded import ShardedRuntimeConfig, ShardedServingRuntime
assert len(jax.devices()) == 8, jax.devices()
rng = np.random.default_rng(0)
NT, ND, DIM, K = 6, 24, 32, 4
docs = {t: rng.integers(-40, 41, (ND, DIM), dtype=np.int8) for t in range(NT)}
qs = [(t, rng.integers(-40, 41, (DIM,), dtype=np.int8))
      for t in list(range(NT)) * 3]

def build(s):
    cfg = ShardedRuntimeConfig(
        num_shards=s, capacity_per_shard=256, dim=DIM,
        retrieval=RetrievalConfig(k=K, metric='mips', candidate_frac=1.0),
        runtime=RuntimeConfig(max_batch=4, max_wait=1.0, cache_bytes=0,
                              auto_flush=False))
    rt = ShardedServingRuntime(cfg, devices=jax.devices()[:s])
    for t in range(NT):
        rt.ingest_codes(t, docs[t])
    return rt

def trace(rt, fail_at=None):
    out, now = [], 0.0
    for i, (t, q) in enumerate(qs):
        if fail_at is not None and i == fail_at:
            rep = rt.fail_shard(rt.live_shards[0], now=now)
            assert rep['requests_resubmitted'] >= 0
        now += 0.01
        out.append((t, rt.submit(t, q, now=now)))
        if i % 5 == 4:
            rt.poll(now=now)
    rt.flush(now=now + 1)
    return [(t, np.asarray(h.result().indices), np.asarray(h.result().scores))
            for t, h in out]

base = trace(build(1))
four = trace(build(4))
assert len({s.device for s in build(4)._shards.values()}) == 4
for (t1, i1, s1), (t4, i4, s4) in zip(base, four):
    assert t1 == t4 and np.array_equal(i1, i4) and np.array_equal(s1, s4), t1
rt = build(4)
lost = trace(rt, fail_at=len(qs) // 2)
led = rt.ledger()
assert led['dropped'] == 0 and led['duplicated'] == 0, led
assert led['resolved'] == len(qs) and led['failovers'] == 1, led
for (t1, i1, s1), (tL, iL, sL) in zip(base, lost):
    assert t1 == tL and np.array_equal(s1, sL), (t1, s1, sL)
print('OK multidevice parity + failover')
""")
