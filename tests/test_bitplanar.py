import jax.numpy as jnp
import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis (pip install -r "
                         "requirements.txt); the rest of the suite runs "
                         "without it")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import bitplanar as bp  # noqa: E402


def codes(seed=0, n=37, d=64):
    return jnp.asarray(
        np.random.default_rng(seed).integers(-128, 128, (n, d)).astype(
            np.int8))


def test_nibble_roundtrip():
    c = codes()
    msb, lsb = bp.pack_nibble_planes(c)
    assert msb.shape == (37, 32) and msb.dtype == jnp.uint8
    rec = bp.reconstruct_int8(msb, lsb)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(c))


def test_msb_plane_halves_bytes():
    c = codes(n=10, d=512)
    msb, _ = bp.pack_nibble_planes(c)
    assert msb.size == c.size // 2          # the paper's 50% traffic saving


def test_8plane_roundtrip():
    c = codes(1)
    planes = bp.pack_bitplanes(c)
    assert planes.shape == (8, 37, 8)
    np.testing.assert_array_equal(np.asarray(bp.unpack_bitplanes(planes)),
                                  np.asarray(c))


def test_partial_planes_equal_msb_truncation():
    c = codes(2)
    planes = bp.pack_bitplanes(c)
    got = np.asarray(bp.unpack_bitplanes(planes, num_planes=4), np.int8)
    want = ((np.asarray(c, np.int8) >> 4) << 4).astype(np.int8)
    np.testing.assert_array_equal(got, want)


@given(st.integers(0, 2**31 - 1), st.integers(2, 16))
@settings(max_examples=20, deadline=None)
def test_nibble_roundtrip_property(seed, half_d):
    c = codes(seed % 1000, n=5, d=2 * half_d)
    msb, lsb = bp.pack_nibble_planes(c)
    np.testing.assert_array_equal(
        np.asarray(bp.reconstruct_int8(msb, lsb)), np.asarray(c))
    signed = np.asarray(bp.unpack_nibble_plane_signed(msb), np.int32)
    np.testing.assert_array_equal(signed,
                                  np.asarray(c, np.int8).astype(np.int32) >> 4)
