import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis; see requirements.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import similarity as sim

D = 512
SMAX = D * 127 * 127            # max |dot| for D int8 dims
NMAX = D * 127 * 127            # max squared norm

score = st.integers(-SMAX, SMAX)
norm = st.integers(0, NMAX)


@given(score, norm, score, norm)
@settings(max_examples=300, deadline=None)
def test_fraction_greater_matches_exact_math(sa, na, sb, nb):
    """The integer non-division comparator must agree with exact rational
    comparison of sa/sqrt(na) vs sb/sqrt(nb) (computed in python ints)."""
    def key(s, n):
        if n == 0:
            return (0, 0)
        return (1 if s > 0 else (-1 if s < 0 else 0), s * s * (1 if s >= 0 else -1), n)

    def exact_gt(sa, na, sb, nb):
        ka, kb = key(sa, na), key(sb, nb)
        if ka[0] != kb[0]:
            return ka[0] > kb[0]
        if ka[0] == 0:
            return False
        # same sign, nonzero: compare sa^2/na vs sb^2/nb with sign
        lhs = sa * sa * nb
        rhs = sb * sb * na
        if ka[0] > 0:
            return lhs > rhs
        return lhs < rhs

    got = bool(sim.fraction_greater(jnp.int32(sa), jnp.int32(na),
                                    jnp.int32(sb), jnp.int32(nb)))
    assert got == exact_gt(sa, na, sb, nb), (sa, na, sb, nb)


def test_int_matvec_exact():
    rng = np.random.default_rng(0)
    db = rng.integers(-128, 128, (100, D)).astype(np.int8)
    qv = rng.integers(-128, 128, (D,)).astype(np.int8)
    got = np.asarray(sim.int_matvec(jnp.asarray(db), jnp.asarray(qv)))
    want = db.astype(np.int64) @ qv.astype(np.int64)
    np.testing.assert_array_equal(got.astype(np.int64), want)


def test_rerank_dense_comparator_matches_float_sort():
    rng = np.random.default_rng(1)
    scores = jnp.asarray(rng.integers(-10**6, 10**6, 50).astype(np.int32))
    norms = jnp.asarray(rng.integers(1, 10**6, 50).astype(np.int32))
    idx, _ = sim.rerank_dense_comparator(scores, norms, 10)
    fkey = np.asarray(scores, np.float64) / np.sqrt(np.asarray(norms,
                                                               np.float64))
    want = np.argsort(-fkey, kind="stable")[:10]
    np.testing.assert_array_equal(np.asarray(idx), want)


def test_cosine_key_zero_norm():
    key = sim.cosine_key_f32(jnp.asarray([5, -3]), jnp.asarray([0, 0]))
    np.testing.assert_array_equal(np.asarray(key), [0.0, 0.0])
