"""Per-assigned-architecture smoke tests: reduced config of the same
family, one forward/train step on CPU, output shapes + no NaNs, plus a
prefill+decode step. Full configs are exercised only via the dry-run."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import get_model

B, S = 2, 16


def make_batch(cfg):
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(jax.random.PRNGKey(2),
                                            (B, S, cfg.d_model), jnp.float32)
    if cfg.family == "vlm":
        batch["prefix_embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (B, cfg.num_prefix_embeds, cfg.d_model),
            jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_grad(arch):
    cfg = get_config(arch, smoke=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    loss, grads = jax.jit(jax.value_and_grad(api.loss_fn))(params, batch)
    assert np.isfinite(float(loss)), arch
    gn = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0, arch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_prefill_decode(arch):
    cfg = get_config(arch, smoke=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg)
    batch.pop("labels")
    max_len = S + 4 + (cfg.num_prefix_embeds if cfg.family == "vlm" else 0)
    logits, cache = jax.jit(api.prefill, static_argnames=("max_len",))(
        params, batch, max_len=max_len)
    exp_s = S + (cfg.num_prefix_embeds if cfg.family == "vlm" else 0)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert logits.shape[1] == exp_s
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any()), arch
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    lg2, cache = jax.jit(api.decode_step)(params, cache, tok)
    assert lg2.shape == (B, 1, cfg.vocab_size)
    assert not bool(jnp.isnan(lg2.astype(jnp.float32)).any()), arch


def test_full_configs_match_assignment():
    """Exact dims from the assignment table."""
    c = get_config("qwen2-0.5b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (24, 896, 14, 2, 4864, 151936)
    assert c.qkv_bias
    c = get_config("minitron-4b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (32, 3072, 24, 8, 9216, 256000)
    c = get_config("deepseek-coder-33b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (62, 7168, 56, 8, 19200, 32256)
    c = get_config("deepseek-67b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (95, 8192, 64, 8, 22016, 102400)
    c = get_config("mamba2-2.7b")
    assert (c.num_layers, c.d_model, c.vocab_size, c.ssm_state) == \
        (64, 2560, 50280, 128)
    c = get_config("llama4-maverick-400b-a17b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.num_experts) == (48, 5120, 40, 8, 8192, 202048,
                                             128)
    c = get_config("llama4-scout-17b-a16e")
    assert (c.num_experts, c.moe_top_k) == (16, 1)
    c = get_config("zamba2-2.7b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size, c.ssm_state) == (54, 2560, 32, 32, 10240, 32000,
                                           64)
    c = get_config("internvl2-26b")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (48, 6144, 48, 8, 16384, 92553)
    c = get_config("seamless-m4t-medium")
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == (12, 1024, 16, 16, 4096, 256206)
