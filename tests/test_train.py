import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import LMTaskConfig, lm_batches
from repro.models import get_model
from repro.train import adafactor, adamw, make_train_step


def test_adamw_matches_numpy_reference():
    opt = adamw(lr=0.1, b1=0.9, b2=0.99, eps=1e-8)
    p = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    g = {"w": jnp.asarray([0.5, 0.5, -1.0])}
    state = opt.init(p)
    p1, state = opt.update(g, state, p)
    # numpy reference
    m = 0.1 * np.asarray(g["w"])
    v = 0.01 * np.asarray(g["w"]) ** 2
    u = (m / 0.1) / (np.sqrt(v / 0.01) + 1e-8)
    want = np.asarray(p["w"]) - 0.1 * u
    np.testing.assert_allclose(np.asarray(p1["w"]), want, atol=1e-6)


def test_adamw_weight_decay():
    opt = adamw(lr=0.1, weight_decay=0.1)
    p = {"w": jnp.asarray([1.0])}
    g = {"w": jnp.asarray([0.0])}
    state = opt.init(p)
    p1, _ = opt.update(g, state, p)
    assert float(p1["w"][0]) < 1.0        # decays toward zero


def test_adafactor_reduces_loss_on_quadratic():
    opt = adafactor(lr=0.05)
    w = {"w": jnp.ones((8, 8))}
    state = opt.init(w)
    tgt = jnp.zeros((8, 8))
    def loss(p):
        return jnp.mean((p["w"] - tgt) ** 2)
    l0 = float(loss(w))
    for _ in range(50):
        g = jax.grad(loss)(w)
        w, state = opt.update(g, state, w)
    assert float(loss(w)) < 0.3 * l0


def test_adafactor_state_is_factored():
    opt = adafactor()
    p = {"w": jnp.zeros((16, 32)), "b": jnp.zeros((32,))}
    s = opt.init(p)
    assert s["v"]["w"]["vr"].shape == (16,)
    assert s["v"]["w"]["vc"].shape == (32,)
    assert s["v"]["b"]["v"].shape == (32,)


def test_grad_accum_equivalence():
    cfg = get_config("qwen2-0.5b", smoke=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt = adamw(lr=1e-3)
    toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    s1 = make_train_step(api.loss_fn, opt, grad_accum=1, clip_norm=None)
    s2 = make_train_step(api.loss_fn, opt, grad_accum=2, clip_norm=None)
    p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
    p2, _, m2 = jax.jit(s2)(params, opt.init(params), batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-3)
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=3e-3)


def test_loss_decreases_on_learnable_stream():
    cfg = get_config("qwen2-0.5b", smoke=True).with_(vocab_size=64)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    opt = adamw(lr=3e-3)
    state = opt.init(params)
    step = jax.jit(make_train_step(api.loss_fn, opt))
    gen = lm_batches(LMTaskConfig(vocab_size=64, seq_len=32, batch_size=8))
    losses = []
    for _ in range(30):
        b = next(gen)
        params, state, m = step(params, state,
                                {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2
