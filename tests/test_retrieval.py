import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BitPlanarDB, RetrievalConfig, batched_retrieve,
                        build_database, exact_retrieve, int4_retrieve,
                        quantize_int8, two_stage_retrieve)
from repro.data import retrieval_corpus


def make_db(n=500, d=512, seed=0):
    docs, queries, gold = retrieval_corpus(n, d, num_queries=32, seed=seed)
    qdb = build_database(jnp.asarray(docs))
    return qdb, BitPlanarDB.from_quantized(qdb), queries, gold


def p_at_1(retrieve_fn, queries, gold):
    hits = 0
    for i in range(queries.shape[0]):
        qc, _ = quantize_int8(jnp.asarray(queries[i]))
        res = retrieve_fn(qc)
        hits += int(np.asarray(res.indices)[0] == gold[i])
    return hits / queries.shape[0]


@pytest.mark.parametrize("metric", ["cosine", "mips"])
def test_two_stage_matches_exact_top1(metric):
    """On a planted corpus the hierarchical retrieval's top-1 matches pure
    INT8 retrieval for the overwhelming majority of queries (paper Table I:
    hierarchical ~ INT8)."""
    qdb, bpdb, queries, gold = make_db()
    cfg = RetrievalConfig(k=5, metric=metric)
    agree = 0
    for i in range(queries.shape[0]):
        qc, _ = quantize_int8(jnp.asarray(queries[i]))
        r2 = two_stage_retrieve(qc, bpdb, cfg)
        r8 = exact_retrieve(qc, qdb, cfg)
        agree += int(np.asarray(r2.indices)[0] == np.asarray(r8.indices)[0])
    assert agree >= 31  # >=97% top-1 agreement with pure INT8


def test_precision_ordering_hier_close_to_int8_above_int4():
    """The paper's Table I ordering: P@1(hier) ~= P@1(INT8) > P@1(INT4),
    in the clustered near-duplicate regime where precision decides top-1."""
    docs, queries, gold = retrieval_corpus(
        800, 512, num_queries=64, seed=3, noise=0.15, cluster_size=16,
        cluster_spread=0.15)
    qdb = build_database(jnp.asarray(docs))
    bpdb = BitPlanarDB.from_quantized(qdb)
    cfg = RetrievalConfig(k=5, metric="cosine")
    p_hier = p_at_1(lambda q: two_stage_retrieve(q, bpdb, cfg), queries, gold)
    p_int8 = p_at_1(lambda q: exact_retrieve(q, qdb, cfg), queries, gold)
    p_int4 = p_at_1(lambda q: int4_retrieve(q, bpdb, cfg), queries, gold)
    assert p_hier >= p_int8 - 0.05   # hierarchical ~ INT8
    assert p_int4 <= p_int8 - 0.05   # INT4 visibly worse
    assert p_int8 > 0.9


def test_candidate_policy():
    cfg = RetrievalConfig(k=5)
    assert cfg.num_candidates(100) == 20       # 20% at small corpora
    assert cfg.num_candidates(10000) == 50     # capped at 50
    assert cfg.num_candidates(10) == 5         # never below k


def test_batched_retrieve():
    _, bpdb, queries, _ = make_db(n=200)
    qc, _ = quantize_int8(jnp.asarray(queries[:8]), per_vector=True)
    res = batched_retrieve(qc, bpdb, RetrievalConfig(k=3))
    assert res.indices.shape == (8, 3)
    single = two_stage_retrieve(qc[0], bpdb, RetrievalConfig(k=3))
    np.testing.assert_array_equal(np.asarray(res.indices[0]),
                                  np.asarray(single.indices))


def test_pallas_backend_equals_jnp_backend():
    _, bpdb, queries, _ = make_db(n=300)
    qc, _ = quantize_int8(jnp.asarray(queries[0]))
    for metric in ("cosine", "mips"):
        rj = two_stage_retrieve(qc, bpdb,
                                RetrievalConfig(k=5, metric=metric))
        rp = two_stage_retrieve(qc, bpdb,
                                RetrievalConfig(k=5, metric=metric,
                                                backend="pallas"))
        np.testing.assert_array_equal(np.asarray(rj.indices),
                                      np.asarray(rp.indices))
        np.testing.assert_array_equal(np.asarray(rj.scores),
                                      np.asarray(rp.scores))
