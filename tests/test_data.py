import numpy as np

from repro.data import LMTaskConfig, lm_batches, retrieval_corpus


def test_lm_batches_learnable_structure():
    gen = lm_batches(LMTaskConfig(vocab_size=50, seq_len=12, batch_size=4,
                                  noise=0.0, num_rules=2, seed=1))
    b = next(gen)
    assert b["tokens"].shape == (4, 12) and b["labels"].shape == (4, 12)
    # labels are next-tokens
    b2 = next(gen)
    assert b2["tokens"].max() < 50 and b2["tokens"].min() >= 0


def test_lm_batches_deterministic():
    a = next(lm_batches(LMTaskConfig(50, 8, 2, seed=3)))
    b = next(lm_batches(LMTaskConfig(50, 8, 2, seed=3)))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_retrieval_corpus_planted_relevance():
    docs, queries, gold = retrieval_corpus(200, 64, num_queries=16,
                                           noise=0.1, seed=0)
    assert docs.shape == (200, 64) and queries.shape == (16, 64)
    np.testing.assert_allclose(np.linalg.norm(docs, axis=-1), 1.0,
                               atol=1e-5)
    # gold doc must be the float-cosine argmax at low noise
    sims = queries @ docs.T
    np.testing.assert_array_equal(sims.argmax(-1), gold)
