"""Model-library equivalence tests (small configs, CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention as A
from repro.models import dense, embedder, encdec, mamba2, moe, zamba2
from repro.models.common import ModelConfig


def test_chunked_attention_matches_naive():
    q = jax.random.normal(jax.random.PRNGKey(0), (2, 64, 4, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 2, 16))
    v = jax.random.normal(jax.random.PRNGKey(2), (2, 64, 2, 16))
    for chunk in (8, 16, 32):
        o = A.chunked_attention(q, k, v, chunk=chunk, causal=True)
        np.testing.assert_allclose(np.asarray(o),
                                   np.asarray(A.naive_attention(q, k, v)),
                                   atol=2e-5)
    o = A.chunked_attention(q, k, v, chunk=16, causal=False)
    np.testing.assert_allclose(
        np.asarray(o), np.asarray(A.naive_attention(q, k, v, causal=False)),
        atol=2e-5)


def test_dense_decode_equals_teacher_forcing():
    cfg = ModelConfig(name="t", family="dense", num_layers=3, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=97,
                      qkv_bias=True, attn_chunk=8,
                      compute_dtype="float32", remat=True)
    params = dense.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 97)
    full = dense.forward(params, toks, cfg)
    _, cache = dense.prefill(params, toks[:, :10], cfg, max_len=16)
    outs = []
    for i in range(6):
        lg, cache = dense.decode_step(params, cache, toks[:, 10 + i:11 + i],
                                      cfg)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full[:, 10:16]),
                               atol=1e-4)


def test_ssd_chunked_matches_recurrence():
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 24, 4, 8))
    a = -jnp.abs(jax.random.normal(jax.random.PRNGKey(3), (2, 24, 4))) * 0.5
    b = jax.random.normal(jax.random.PRNGKey(4), (2, 24, 16))
    c = jax.random.normal(jax.random.PRNGKey(5), (2, 24, 16))
    y8, f8 = mamba2.ssd_chunked(x, a, b, c, 8)
    y24, f24 = mamba2.ssd_chunked(x, a, b, c, 24)
    np.testing.assert_allclose(np.asarray(y8), np.asarray(y24), atol=1e-4)
    np.testing.assert_allclose(np.asarray(f8), np.asarray(f24), atol=1e-4)
    st = jnp.zeros((2, 4, 8, 16))
    ys = []
    for t in range(24):
        st = st * jnp.exp(a[:, t])[..., None, None] + jnp.einsum(
            "bhp,bn->bhpn", x[:, t], b[:, t])
        ys.append(jnp.einsum("bhpn,bn->bhp", st, c[:, t]))
    np.testing.assert_allclose(np.asarray(y8),
                               np.asarray(jnp.stack(ys, 1)), atol=1e-4)
    np.testing.assert_allclose(np.asarray(f8), np.asarray(st), atol=1e-4)


def test_mamba2_decode_continues_prefill():
    cfg = ModelConfig(name="m", family="ssm", num_layers=3, d_model=64,
                      num_heads=1, num_kv_heads=1, d_ff=0, vocab_size=89,
                      ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                      compute_dtype="float32", remat=False)
    params = mamba2.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 89)
    full = mamba2.forward(params, toks, cfg)
    lg, cache = mamba2.prefill(params, toks[:, :16], cfg)
    np.testing.assert_allclose(np.asarray(lg), np.asarray(full[:, :16]),
                               atol=1e-4)
    outs = []
    for i in range(8):
        lg, cache = mamba2.decode_step(params, cache, toks[:, 16 + i:17 + i],
                                       cfg)
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full[:, 16:24]), atol=1e-4)


@pytest.mark.parametrize("period", [1, 2])
def test_moe_decode_equals_forward_when_no_drop(period):
    cfg = ModelConfig(name="mo", family="moe", num_layers=4, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=96, vocab_size=101,
                      num_experts=8, moe_layer_period=period,
                      shared_expert=True, capacity_factor=16.0,
                      attn_chunk=8, remat=True)
    params = moe.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, 101)
    full = moe.forward(params, toks[:, :14], cfg)
    _, cache = moe.prefill(params, toks[:, :10], cfg, max_len=20)
    outs = []
    for i in range(4):
        lg, cache = moe.decode_step(params, cache, toks[:, 10 + i:11 + i],
                                    cfg)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1).astype(jnp.float32)
    np.testing.assert_allclose(np.asarray(dec),
                               np.asarray(full[:, 10:14], np.float32),
                               atol=1e-2)


def test_moe_dispatch_conserves_tokens():
    cfg = ModelConfig(name="x", family="moe", num_layers=1, d_model=16,
                      num_heads=2, num_kv_heads=1, d_ff=32, vocab_size=11,
                      num_experts=4, capacity_factor=8.0)
    p = moe.init_moe_ffn(cfg, jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 8, 16))
    y = moe.moe_ffn(p, x, cfg)
    assert y.shape == x.shape and bool(jnp.isfinite(y).all())
    # with capacity 8x nothing is dropped: permutation-invariance of batch
    y2 = moe.moe_ffn(p, x[::-1], cfg)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y[::-1]),
                               atol=1e-5)


def test_zamba2_decode_continues_prefill():
    cfg = ModelConfig(name="z", family="hybrid", num_layers=4, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=83,
                      ssm_state=16, ssm_head_dim=16, ssm_chunk=8,
                      hybrid_attn_period=2, compute_dtype="float32",
                      attn_chunk=8, remat=False)
    params = zamba2.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 83)
    full = zamba2.forward(params, toks, cfg)
    _, cache = zamba2.prefill(params, toks[:, :16], cfg, max_len=24)
    outs = []
    for i in range(8):
        lg, cache = zamba2.decode_step(params, cache, toks[:, 16 + i:17 + i],
                                       cfg)
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full[:, 16:24]), atol=1e-4)


def test_zamba2_shared_block_is_shared():
    cfg = ModelConfig(name="z", family="hybrid", num_layers=4, d_model=32,
                      num_heads=4, num_kv_heads=4, d_ff=64, vocab_size=50,
                      ssm_state=8, ssm_head_dim=8, hybrid_attn_period=2)
    params = zamba2.init_params(cfg, jax.random.PRNGKey(0))
    # one attention block's worth of params, not num_apps copies
    assert params["shared"]["wq"].ndim == 2


def test_encdec_decode_continues_prefill():
    cfg = ModelConfig(name="s", family="encdec", num_layers=3,
                      encoder_layers=3, d_model=64, num_heads=4,
                      num_kv_heads=4, d_ff=128, vocab_size=97,
                      compute_dtype="float32", attn_chunk=8, remat=False)
    p = encdec.init_params(cfg, jax.random.PRNGKey(0))
    frames = jax.random.normal(jax.random.PRNGKey(2), (2, 12, 64))
    toks = jax.random.randint(jax.random.PRNGKey(3), (2, 20), 0, 97)
    full = encdec.forward(p, frames, toks, cfg)
    _, cache = encdec.prefill(p, frames, toks[:, :12], cfg, max_len=20)
    outs = []
    for i in range(8):
        lg, cache = encdec.decode_step(p, cache, toks[:, 12 + i:13 + i], cfg)
        outs.append(lg)
    np.testing.assert_allclose(np.asarray(jnp.concatenate(outs, 1)),
                               np.asarray(full[:, 12:20]), atol=1e-4)


def test_embedder_normalized_and_mask_aware():
    cfg = embedder.MINILM_CFG.with_(num_layers=2, d_model=32, num_heads=4,
                                    num_kv_heads=4, d_ff=64, vocab_size=50,
                                    pooled_dim=16)
    p = embedder.init_params(cfg, jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (3, 10), 0, 50)
    e = embedder.encode(p, toks, cfg)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(e, axis=-1)),
                               np.ones(3), atol=1e-5)
    mask = jnp.ones((3, 10), bool).at[:, 5:].set(False)
    e_m = embedder.encode(p, toks, cfg, mask)
    np.testing.assert_allclose(np.asarray(jnp.linalg.norm(e_m, axis=-1)),
                               np.ones(3), atol=1e-5)
    assert float(jnp.max(jnp.abs(e - e_m))) > 1e-4   # pooling mask matters
