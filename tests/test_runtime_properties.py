"""Property tests: the serving runtime under random request schedules.

For EVERY interleaving of submit/poll/flush with arbitrary tenants,
deadlines, and clock advances, the runtime must:

  * never drop a request (every handle resolves by the final flush),
  * never duplicate one (each handle resolves exactly once, and each
    launch carries each request in exactly one lane),
  * never leak across tenants (every returned slot is owned by the
    submitting tenant), and
  * return results BIT-IDENTICAL to dispatching the same query alone
    through the index (batching/padding reorder work, never answers).

The index is built with fragmented tenants so the batched path runs the
full-arena masked scan, whose per-lane results are independent of batch
composition by construction — making the sequential reference exact.
"""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip(
    "hypothesis", reason="property tests need hypothesis; see requirements.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import RetrievalConfig, quantize_int8  # noqa: E402
from repro.serve.runtime import RuntimeConfig, ServingRuntime  # noqa: E402
from repro.tenancy import MultiTenantIndex  # noqa: E402

DIM = 32
NUM_TENANTS = 3
NUM_QUERIES = 6


def build_index():
    """Fragmented multi-tenant index + per-tenant query pool (module-level
    singleton: hypothesis replays many schedules against one corpus)."""
    rng = np.random.default_rng(42)
    idx = MultiTenantIndex(128, DIM, RetrievalConfig(k=3))
    docs = {t: [] for t in range(NUM_TENANTS)}
    for _ in range(3):                       # interleave => fragmentation
        for t in range(NUM_TENANTS):
            d = rng.normal(size=(4, DIM)).astype(np.float32)
            idx.ingest(t, jnp.asarray(d))
            docs[t].append(d)
    assert all(len(idx.table.segments(t)) > 1 for t in range(NUM_TENANTS))
    pool = {}
    for t in range(NUM_TENANTS):
        d = np.concatenate(docs[t])[:NUM_QUERIES]
        noisy = d + 0.05 * rng.normal(size=d.shape)
        q, _ = quantize_int8(jnp.asarray(noisy.astype(np.float32)),
                             per_vector=True)
        pool[t] = np.asarray(q)
    owner = np.asarray(idx.arena.owner)
    return idx, pool, owner


_IDX, _POOL, _OWNER = build_index()

# The sequential references: one lane, one launch, no batching.
_SEQ = {
    (t, i): _IDX.retrieve(jnp.asarray(_POOL[t][i])[None],
                          np.asarray([t], np.int32))
    for t in range(NUM_TENANTS) for i in range(NUM_QUERIES)
}

schedules = st.lists(
    st.one_of(
        st.tuples(st.just("submit"),
                  st.integers(0, NUM_TENANTS - 1),      # tenant
                  st.integers(0, NUM_QUERIES - 1),      # query id
                  st.floats(0.0, 10.0)),                # deadline slack
        st.tuples(st.just("poll"),
                  st.floats(0.0, 5.0),                  # clock advance
                  st.just(0), st.just(0.0)),
        st.tuples(st.just("flush"), st.just(0), st.just(0), st.just(0.0)),
    ),
    min_size=1, max_size=30)


@settings(max_examples=20, deadline=None)
@given(schedule=schedules,
       max_batch=st.sampled_from([1, 2, 4, 8]),
       fairness=st.sampled_from(["deadline_rr", "fifo"]))
def test_runtime_never_drops_duplicates_or_leaks(schedule, max_batch,
                                                 fairness):
    rt = ServingRuntime(_IDX, RuntimeConfig(
        max_batch=max_batch, max_wait=1.0, fairness=fairness,
        auto_flush=False))
    now = 0.0
    submitted = []                           # (handle, tenant, query id)
    resolved_ids = []
    for op, a, b, c in schedule:
        if op == "submit":
            h = rt.submit(a, _POOL[a][b], now=now, deadline=now + c)
            submitted.append((h, a, b))
        elif op == "poll":
            now += a
            resolved_ids.extend(h.request_id for h in rt.poll(now=now))
        else:
            resolved_ids.extend(h.request_id for h in rt.flush())
    resolved_ids.extend(h.request_id for h in rt.flush())

    # -- never dropped, never duplicated ---------------------------------
    assert rt.pending() == 0
    assert sorted(resolved_ids) == sorted(h.request_id
                                          for h, _, _ in submitted)
    assert len(set(resolved_ids)) == len(resolved_ids)
    assert rt.queries_served == len(submitted)
    # request ids are unique across the runtime's lifetime
    assert len({h.request_id for h, _, _ in submitted}) == len(submitted)

    for h, t, qi in submitted:
        assert h.done()
        res = h.result()
        got = np.asarray(res.indices)
        valid = got[got >= 0]
        # -- no cross-tenant leak ----------------------------------------
        assert (_OWNER[valid] == t).all(), (t, valid.tolist())
        # -- bit-identical to the sequential one-lane dispatch -----------
        ref = _SEQ[(t, qi)]
        assert jnp.array_equal(res.indices, ref.indices[0])
        assert jnp.array_equal(res.scores, ref.scores[0])
        assert jnp.array_equal(res.candidate_indices,
                               ref.candidate_indices[0])


@settings(max_examples=15, deadline=None)
@given(schedule=schedules,
       max_batch=st.sampled_from([1, 2, 4]),
       fairness=st.sampled_from(["deadline_rr", "fifo"]))
def test_trace_completeness_under_random_schedules(schedule, max_batch,
                                                   fairness):
    """Every submitted request yields EXACTLY one balanced submit->resolve
    ("request" B/E) span chain under arbitrary submit/poll/flush
    interleavings — no orphan spans, no duplicates — and the span ids
    are exactly the submitted request ids. Runs under the simulated
    clock, so the whole trace (timestamps included) must be
    deterministic: replaying the schedule yields a bit-identical event
    list."""
    from repro.obs import MetricsRegistry, Tracer

    def drive():
        reg, tracer = MetricsRegistry(), Tracer()
        rt = ServingRuntime(_IDX, RuntimeConfig(
            max_batch=max_batch, max_wait=1.0, fairness=fairness,
            auto_flush=False), registry=reg, tracer=tracer)
        now = 0.0
        submitted = []
        for op, a, b, c in schedule:
            if op == "submit":
                submitted.append(rt.submit(a, _POOL[a][b], now=now,
                                           deadline=now + c))
            elif op == "poll":
                now += a
                rt.poll(now=now)
            else:
                rt.flush()
        rt.flush()
        return reg, tracer, submitted

    reg, tracer, submitted = drive()
    assert tracer.open_spans() == []                  # nothing dangling
    begins = [e for e in tracer.spans("request") if e.ph == "B"]
    ends = [e for e in tracer.spans("request") if e.ph == "E"]
    assert len(begins) == len(ends) == len(submitted)
    want_ids = sorted(h.request_id for h in submitted)
    assert sorted(e.attrs["request"] for e in begins) == want_ids
    assert sorted(e.attrs["request"] for e in ends) == want_ids
    # ids unique in both phases => exactly one chain per request
    assert len({e.attrs["request"] for e in begins}) == len(begins)
    assert len({e.attrs["request"] for e in ends}) == len(ends)
    # resolve never precedes submit, and every resolve names its launch
    t_begin = {e.attrs["request"]: e.ts for e in begins}
    for e in ends:
        assert e.ts >= t_begin[e.attrs["request"]]
        assert e.attrs["launch"] >= 0
    # registry totals agree with the trace
    assert reg.get("counter", "serve_requests_submitted").value == \
        len(submitted)
    assert reg.get("counter", "serve_requests_resolved").value == \
        len(submitted)
    qh = reg.get("histogram", "serve_queue_wait_seconds")
    assert qh.count == len(submitted)
    # simulated clock => the trace is bit-identical on replay
    _, tracer2, _ = drive()
    key = [(e.name, e.ph, e.ts, e.tid, tuple(sorted(e.attrs.items())))
           for e in tracer.spans()]
    key2 = [(e.name, e.ph, e.ts, e.tid, tuple(sorted(e.attrs.items())))
            for e in tracer2.spans()]
    assert key == key2


@settings(max_examples=10, deadline=None)
@given(n=st.integers(1, 12), max_batch=st.sampled_from([2, 4]))
def test_deadlines_eventually_force_every_launch(n, max_batch):
    """poll() at a late-enough clock must resolve everything submitted —
    no request can be stranded behind a partial batch forever."""
    rt = ServingRuntime(_IDX, RuntimeConfig(
        max_batch=max_batch, max_wait=1.0, auto_flush=False))
    handles = [rt.submit(i % NUM_TENANTS, _POOL[i % NUM_TENANTS][0],
                         now=float(i) * 0.01) for i in range(n)]
    rt.poll(now=100.0)
    assert rt.pending() == 0                 # everything dispatched...
    assert all(h.result() is not None for h in handles)   # ...and resolvable
    assert all(h.done() for h in handles)


@settings(max_examples=15, deadline=None)
@given(schedule=schedules,
       max_batch=st.sampled_from([1, 2, 4, 8]),
       fairness=st.sampled_from(["deadline_rr", "fifo"]))
def test_async_pipeline_bit_identical_to_sync(schedule, max_batch, fairness):
    """The tail-latency pipeline contract: async dispatch (launches in
    flight as unresolved device futures, lazily retired) returns results
    BIT-IDENTICAL to the legacy synchronous path under every random
    submit/poll/flush interleaving — pipelining reorders WHEN host work
    happens, never what any request retrieves — and forms the exact same
    launches (same count, same admission order)."""
    def mk(depth):
        return ServingRuntime(_IDX, RuntimeConfig(
            max_batch=max_batch, max_wait=1.0, fairness=fairness,
            auto_flush=False, async_depth=depth))

    rt_sync, rt_async = mk(0), mk(2)
    now = 0.0
    pairs = []
    for op, a, b, c in schedule:
        if op == "submit":
            hs = rt_sync.submit(a, _POOL[a][b], now=now, deadline=now + c)
            ha = rt_async.submit(a, _POOL[a][b], now=now, deadline=now + c)
            pairs.append((hs, ha))
        elif op == "poll":
            now += a
            rt_sync.poll(now=now)
            rt_async.poll(now=now)
            if pairs:
                # mid-schedule non-blocking probe: must be None or the
                # final answer, and must never disturb the pipeline
                pairs[-1][1].result(wait=False)
        else:
            rt_sync.flush()
            rt_async.flush()
    rt_sync.flush()
    rt_async.flush()
    assert rt_async.in_flight() == 0         # flush is a barrier
    assert rt_async.launches == rt_sync.launches
    for hs, ha in pairs:
        assert hs.state == ha.state == "resolved"
        assert ha.launch_index == hs.launch_index
        rs, ra = hs.result(), ha.result()
        assert np.array_equal(rs.indices, ra.indices)
        assert np.array_equal(rs.scores, ra.scores)
        assert np.array_equal(rs.candidate_indices, ra.candidate_indices)


# The cached (slab) path's async-vs-sync parity lives in
# tests/test_serve_runtime.py::test_async_cached_path_parity_and_ledgers —
# alongside a seeded deterministic schedule-parity test — so the pipeline
# contract stays pinned even where hypothesis is unavailable.
