import os
import sys

# tests see the default 1 CPU device (the 512-device override lives ONLY in
# launch/dryrun.py, per the dry-run spec)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
