import jax.numpy as jnp
import numpy as np
import pytest
pytest.importorskip("hypothesis", reason="property tests need hypothesis; see requirements.txt")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import quantization as q


def rand(shape, seed=0, scale=3.0):
    return jnp.asarray(
        np.random.default_rng(seed).normal(size=shape).astype(np.float32)
        * scale)


def test_int8_roundtrip_error_bound():
    x = rand((64, 128))
    codes, scale = q.quantize_int8(x)
    err = jnp.max(jnp.abs(q.dequantize(codes, scale) - x))
    assert float(err) <= float(scale) * 0.5 + 1e-6


def test_per_vector_scale_shape():
    x = rand((32, 64))
    codes, scale = q.quantize_int8(x, per_vector=True)
    assert scale.shape == (32,)
    err = jnp.abs(q.dequantize(codes, scale) - x)
    assert float(jnp.max(err)) <= float(jnp.max(scale)) * 0.5 + 1e-6


def test_int4_range():
    codes, _ = q.quantize_int4(rand((16, 32)))
    assert int(codes.min()) >= -8 and int(codes.max()) <= 7


@given(st.lists(st.integers(-128, 127), min_size=1, max_size=64))
@settings(max_examples=50, deadline=None)
def test_nibble_split_exact(vals):
    v = jnp.asarray(vals, jnp.int8)
    msb, lsb = q.msb_nibble(v), q.lsb_nibble(v)
    assert int(msb.min()) >= -8 and int(msb.max()) <= 7
    assert int(lsb.min()) >= 0 and int(lsb.max()) <= 15
    rec = q.reconstruct_from_nibbles(msb, lsb)
    np.testing.assert_array_equal(np.asarray(rec), np.asarray(v))


def test_msb_is_coarse_quant():
    """msb*16 must be within 16 of the original value (floor to 16s)."""
    v = jnp.arange(-128, 128, dtype=jnp.int8)
    approx = q.msb_nibble(v).astype(np.int32) * 16
    diff = np.asarray(v, np.int32) - np.asarray(approx)
    assert diff.min() >= 0 and diff.max() <= 15


def test_build_database():
    db = q.build_database(rand((100, 512)))
    assert db.values.shape == (100, 512) and db.values.dtype == jnp.int8
    assert db.norms_sq.shape == (100,)
    expect = (np.asarray(db.values, np.int64) ** 2).sum(-1)
    np.testing.assert_array_equal(np.asarray(db.norms_sq, np.int64), expect)
