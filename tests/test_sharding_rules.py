"""PartitionSpec construction for every assigned arch (no devices needed:
specs are pure metadata; validity on 256/512-device meshes is proven by
the dry-run)."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P  # noqa: F401

from repro.configs import ARCH_IDS, get_config
from repro.distributed import sharding as sh
from repro.models import get_model
from repro.train import get_optimizer


def fake_mesh(shape, names):
    """An abstract single-device-backed mesh is enough for spec logic; use
    mesh.shape via a stub object."""
    class M:
        axis_names = names
        def __init__(self):
            self.shape = dict(zip(names, shape))
            self.devices = np.empty(shape, object)
    return M()


MESHES = [((16, 16), ("data", "model")),
          ((2, 16, 16), ("pod", "data", "model"))]


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mshape,mnames", MESHES)
def test_param_specs_divisible(arch, mshape, mnames):
    cfg = get_config(arch)
    api = get_model(cfg)
    mesh = fake_mesh(mshape, mnames)
    aparams = jax.eval_shape(api.init, jax.random.PRNGKey(0))

    def check(path, leaf):
        spec = sh.param_spec(path, leaf.shape, mesh, cfg)
        assert len(spec) <= leaf.ndim
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % total == 0, (path, leaf.shape, spec)
        # each mesh axis used at most once
        used = [a for e in spec if e is not None
                for a in (e if isinstance(e, tuple) else (e,))]
        assert len(used) == len(set(used)), (path, spec)

    jax.tree_util.tree_map_with_path(check, aparams)


@pytest.mark.parametrize("arch", ["deepseek-67b", "llama4-scout-17b-a16e",
                                  "mamba2-2.7b"])
def test_opt_state_specs_rank_match(arch):
    cfg = get_config(arch)
    api = get_model(cfg)
    mesh = fake_mesh((16, 16), ("data", "model"))
    aparams = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    opt = get_optimizer(cfg.optimizer)
    astate = jax.eval_shape(opt.init, aparams)

    # NamedSharding needs a real mesh; validate specs via param_spec-based
    # resolution by monkey-wrapping NamedSharding out of the path
    import repro.distributed.sharding as S

    captured = []
    orig = S.NamedSharding
    S.NamedSharding = lambda m, spec: spec
    try:
        specs = S.opt_state_shardings(astate, aparams, mesh, cfg)
    finally:
        S.NamedSharding = orig

    def check(path, leaf):
        spec = specs
        for e in path:
            key = getattr(e, "key", getattr(e, "idx", None))
            spec = spec[key]
        assert len(spec) <= leaf.ndim, (path, spec, leaf.shape)
        for dim, entry in enumerate(spec):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            total = int(np.prod([mesh.shape[a] for a in axes]))
            assert leaf.shape[dim] % total == 0, (path, leaf.shape, spec)

    jax.tree_util.tree_map_with_path(check, astate)


def test_kv_cache_context_parallel_fallback():
    """deepseek-67b decode: KH=8 < model=16 -> cache shards the SEQ dim."""
    cfg = get_config("deepseek-67b")
    mesh = fake_mesh((16, 16), ("data", "model"))
    shape = (95, 128, 32768, 8, 128)   # (L, B, T, KH, hd)
    from jax.tree_util import DictKey
    spec = sh.cache_spec((DictKey("k"),), shape, mesh, cfg)
    assert spec[2] == "model" and spec[3] is None
    assert spec[1] == "data"


def test_kv_cache_batch1_long_context():
    cfg = get_config("zamba2-2.7b")
    mesh = fake_mesh((16, 16), ("data", "model"))
    shape = (9, 1, 524288, 32, 80)
    from jax.tree_util import DictKey
    spec = sh.cache_spec((DictKey("k"),), shape, mesh, cfg)
    # batch=1 unshardable; KH=32 divisible by model; T picks up data
    assert spec[3] == "model" or spec[2] is not None
