"""Validates the cost model against the paper's published numbers."""
import pytest

from repro.core import energy as en


def test_table2_dram_energy():
    """Paper Table II: DRAM 176 uJ for a 1 MB INT8 database query."""
    cb = en.cost_hierarchical(en.docs_for_db_mb(1.0))
    assert cb.dram_pj * 1e-6 == pytest.approx(176.0, rel=0.01)


def test_table2_sram_energy():
    """Paper Table II: SRAM 1.72 uJ."""
    cb = en.cost_hierarchical(en.docs_for_db_mb(1.0))
    assert cb.sram_pj * 1e-6 == pytest.approx(1.72, rel=0.05)


def test_table2_total_and_share():
    """Abstract: ~177.76 uJ total; Table II: DRAM ~98.83% of energy."""
    cb = en.cost_hierarchical(en.docs_for_db_mb(1.0))
    assert cb.total_uj == pytest.approx(177.76, rel=0.01)
    assert cb.proportions()["DRAM"] == pytest.approx(0.98831, abs=0.002)


def test_fig4_memory_reduction_endpoints():
    """Fig. 4: memory reduction ~30% at 100 chunks -> ~50% at 10000."""
    assert en.memory_reduction(100) == pytest.approx(0.30, abs=0.02)
    assert en.memory_reduction(10000) == pytest.approx(0.495, abs=0.01)


def test_fig4_compute_reduction_endpoints():
    """Fig. 4: computation reduction 55% -> 74.7%."""
    assert en.compute_reduction(100) == pytest.approx(0.55, abs=0.02)
    assert en.compute_reduction(10000) == pytest.approx(0.747, abs=0.005)


def test_hierarchical_beats_int8_energy_always():
    for n in (100, 1000, 5000, 20000):
        hier = en.cost_hierarchical(n).total_pj
        int8 = en.cost_int8(n).total_pj
        int4 = en.cost_int4(n).total_pj
        assert hier < int8
        assert int4 <= hier          # int4 is the energy floor (Fig. 5b)


def test_table3_sciFact_energy_scale():
    """Table III: 337.74 uJ/query on their SciFact subset — our model
    reproduces that magnitude at the inferred corpus size (~4020 docs)."""
    n = 4020
    cb = en.cost_hierarchical(n)
    assert cb.total_uj == pytest.approx(337.74, rel=0.05)


def test_monotone_in_corpus_size():
    vals = [en.cost_hierarchical(n).total_pj for n in (100, 1000, 10000)]
    assert vals[0] < vals[1] < vals[2]
