"""Validates the cost model against the paper's published numbers."""
import pytest

from repro.core import energy as en


def test_table2_dram_energy():
    """Paper Table II: DRAM 176 uJ for a 1 MB INT8 database query."""
    cb = en.cost_hierarchical(en.docs_for_db_mb(1.0))
    assert cb.dram_pj * 1e-6 == pytest.approx(176.0, rel=0.01)


def test_table2_sram_energy():
    """Paper Table II: SRAM 1.72 uJ."""
    cb = en.cost_hierarchical(en.docs_for_db_mb(1.0))
    assert cb.sram_pj * 1e-6 == pytest.approx(1.72, rel=0.05)


def test_table2_total_and_share():
    """Abstract: ~177.76 uJ total; Table II: DRAM ~98.83% of energy."""
    cb = en.cost_hierarchical(en.docs_for_db_mb(1.0))
    assert cb.total_uj == pytest.approx(177.76, rel=0.01)
    assert cb.proportions()["DRAM"] == pytest.approx(0.98831, abs=0.002)


def test_fig4_memory_reduction_endpoints():
    """Fig. 4: memory reduction ~30% at 100 chunks -> ~50% at 10000."""
    assert en.memory_reduction(100) == pytest.approx(0.30, abs=0.02)
    assert en.memory_reduction(10000) == pytest.approx(0.495, abs=0.01)


def test_fig4_compute_reduction_endpoints():
    """Fig. 4: computation reduction 55% -> 74.7%."""
    assert en.compute_reduction(100) == pytest.approx(0.55, abs=0.02)
    assert en.compute_reduction(10000) == pytest.approx(0.747, abs=0.005)


def test_hierarchical_beats_int8_energy_always():
    for n in (100, 1000, 5000, 20000):
        hier = en.cost_hierarchical(n).total_pj
        int8 = en.cost_int8(n).total_pj
        int4 = en.cost_int4(n).total_pj
        assert hier < int8
        assert int4 <= hier          # int4 is the energy floor (Fig. 5b)


def test_table3_sciFact_energy_scale():
    """Table III: 337.74 uJ/query on their SciFact subset — our model
    reproduces that magnitude at the inferred corpus size (~4020 docs)."""
    n = 4020
    cb = en.cost_hierarchical(n)
    assert cb.total_uj == pytest.approx(337.74, rel=0.05)


def test_monotone_in_corpus_size():
    vals = [en.cost_hierarchical(n).total_pj for n in (100, 1000, 10000)]
    assert vals[0] < vals[1] < vals[2]


# ---------------------------------------------------------------------------
# Hot-cluster-cache accounting (the serving runtime's SRAM-rate hits)
# ---------------------------------------------------------------------------

def _cluster_plan(hit_bytes: int, miss_bytes: int, *, batch: int = 8):
    """A cluster-cascade SchedulePlan whose approx stage streamed
    `miss_bytes` from HBM and served `hit_bytes` from the cache."""
    from repro.core import engine
    from repro.core.retrieval import RetrievalConfig
    cfg = RetrievalConfig(k=5, metric="cosine")
    base = engine.plan(cfg, num_docs=16384, dim=256, batch=batch,
                       kind="cluster", num_clusters=64, view_rows=1024)
    return engine.cache_split_plan(base, hbm_bytes=miss_bytes,
                                   sram_bytes=hit_bytes)


def test_fully_warm_trace_charges_zero_stage1_hbm_bytes():
    """Every probed cluster served from the cache => the approx stage's
    HBM ledger is exactly zero, and only the (tiny, resident-codebook)
    prune + exact-gather stages still touch DRAM."""
    total = 8 * 1024 * 128                       # the launch's view bytes
    plan = _cluster_plan(hit_bytes=total, miss_bytes=0)
    approx = [s for s in plan.stages if s.name == "approx"][0]
    assert approx.bytes_hbm == 0 and approx.bytes_sram == total
    assert plan.stage1_bytes == 0 and plan.stage1_bytes_sram == total
    warm = en.cost_cascade(plan.stages, 256, batch=plan.batch)
    cold = en.cost_cascade(_cluster_plan(0, total).stages, 256,
                           batch=plan.batch)
    # the warm launch's DRAM bits are exactly the cold launch's MINUS the
    # whole stage-1 view (only prune + exact remain)
    assert cold.dram_bits - warm.dram_bits == pytest.approx(
        total * 8 / plan.batch)
    # MACs are untouched: cache hits still flow through the PEs
    assert warm.macs == cold.macs
    assert warm.pe_bits == cold.pe_bits
    assert warm.total_pj < cold.total_pj


def test_cost_monotone_in_cache_budget_shrinkage():
    """A smaller cache budget can only move stage-1 bytes from SRAM back
    to HBM; total energy must rise monotonically as the hit share
    shrinks (DRAM pJ/bit >> SRAM pJ/bit)."""
    total = 8 * 1024 * 128
    costs = []
    for hit_frac in (1.0, 0.75, 0.5, 0.25, 0.0):  # shrinking budget
        hit = int(total * hit_frac)
        plan = _cluster_plan(hit_bytes=hit, miss_bytes=total - hit)
        costs.append(en.cost_cascade(plan.stages, 256,
                                     batch=plan.batch).total_pj)
    assert all(a < b for a, b in zip(costs, costs[1:]))


def test_cache_hits_charged_at_sram_not_dram_rates():
    """A hit byte costs 1x SRAM read; a missed byte costs DRAM + 2x SRAM
    (streamed in, read back). The delta per byte must match exactly."""
    total = 1024 * 128
    warm = en.cost_cascade(_cluster_plan(total, 0).stages, 256, batch=1)
    cold = en.cost_cascade(_cluster_plan(0, total).stages, 256, batch=1)
    bits = total * 8
    assert cold.dram_pj - warm.dram_pj == pytest.approx(
        bits * en.PAPER_28NM.dram)
    assert cold.sram_pj - warm.sram_pj == pytest.approx(
        bits * en.PAPER_28NM.sram)       # 2x streamed vs 1x cached read


def test_per_stage_split_matches_fused_cascade():
    """The per-stage export (satellite of the adaptive-precision PR) must
    stay consistent with the fused launch price: each stage's breakdown
    equals the single-stage cascade, the fast linear path `stage_cost_uj`
    prices identically (to round-off), and the stage sum exceeds the
    fused total by exactly the (len-1) duplicated query-load SRAM term."""
    from repro.core import engine
    from repro.core.retrieval import RetrievalConfig
    cfg = RetrievalConfig(k=5, metric="cosine", prescreen_c0=256)
    plan = engine.plan(cfg, num_docs=16384, dim=256, batch=8,
                       kind="cluster", num_clusters=64, view_rows=1024)
    split = engine.cache_split_plan(plan, hbm_bytes=4096, sram_bytes=8192)
    names = [s.name for s in split.stages]
    assert names == ["prune", "prescreen", "approx", "exact"]
    per = en.cost_per_stage(split.stages, 256, batch=split.batch)
    assert set(per) == set(names)
    for s in split.stages:
        assert per[s.name].total_uj == pytest.approx(
            en.cost_cascade((s,), 256, batch=split.batch).total_uj)
        assert en.stage_cost_uj(s, 256, batch=split.batch) == pytest.approx(
            per[s.name].total_uj, rel=1e-12)
    fused = en.cost_cascade(split.stages, 256, batch=split.batch)
    dup_query_loads = (len(names) - 1) * 256 * 8 * en.PAPER_28NM.sram
    assert sum(c.total_pj for c in per.values()) == pytest.approx(
        fused.total_pj + dup_query_loads)
    # the 1-bit prescreen must cost less than the 4-bit full-view scan
    # it replaces (the no-prescreen plan's approx stage): 4x fewer plane
    # bits over the same rows, and DRAM dominates the stage price
    no_ps = engine.plan(RetrievalConfig(k=5, metric="cosine"),
                        num_docs=16384, dim=256, batch=8, kind="cluster",
                        num_clusters=64, view_rows=1024)
    full_view_scan = en.cost_per_stage(no_ps.stages, 256,
                                       batch=no_ps.batch)["approx"]
    assert per["prescreen"].total_uj < 0.5 * full_view_scan.total_uj


def test_per_stage_export_observes_every_ledger_stage():
    """observe_cost(stages=...) lands one labelled histogram sample per
    ledger stage, weighted by the launch's query count — and prices it
    exactly like the fast path (which test above pins to the exact
    single-stage cascade)."""
    pytest.importorskip("repro.obs")
    from repro.core import engine
    from repro.core.retrieval import RetrievalConfig
    from repro.obs import MetricsRegistry
    cfg = RetrievalConfig(k=5, metric="cosine", prescreen_c0=128)
    plan = engine.plan(cfg, num_docs=16384, dim=256, batch=4,
                       kind="cluster", num_clusters=64, view_rows=512)
    reg = MetricsRegistry()
    fused = en.cost_cascade(plan.stages, 256, batch=plan.batch)
    en.observe_cost(reg, fused, queries=3, stages=plan.stages, dim=256,
                    batch=plan.batch)
    for s in plan.stages:
        h = reg.get("histogram", "energy_uj_per_query_stage", stage=s.name)
        assert h is not None and h.count == 3
        assert h.total == pytest.approx(
            3 * en.stage_cost_uj(s, 256, batch=plan.batch))
