"""RetrievalEngine parity suite: every policy, both backends, bit-for-bit.

The engine's contract is that `backend="pallas"` (interpret mode on CPU,
compiled Mosaic on TPU) and `backend="jnp"` run the SAME exact integer
arithmetic, so every policy — plain, masked, windowed, cluster-pruned —
must return identical indices, scores, and candidate sets, for cosine and
MIPS, including fragmented tenants and tenants with fewer live docs than
k. Also pins the single-query wrappers to lanes of the batched core, the
analytic per-stage SchedulePlan byte model, and the cluster cascade's
nprobe=K degeneration to the full scan.
"""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BitPlanarDB, ClusterParams, MaskedPolicy,
                        PlainPolicy, RetrievalConfig, RetrievalEngine,
                        WindowedPolicy, block_table, build_database,
                        cluster_grouped_order, kmeans_int8)
from repro.core import clustering
from repro.core import engine as engine_mod
from repro.core.retrieval import (NO_TENANT, batched_retrieve,
                                  batched_retrieve_masked,
                                  cluster_pruned_retrieve,
                                  two_stage_retrieve,
                                  two_stage_retrieve_masked,
                                  windowed_retrieve_masked)
from repro.core.quantization import quantize_int8
from repro.tenancy import MultiTenantIndex

DIM = 64
N = 192


def make_arena(fragmented: bool, seed=0, k=3, metric="cosine",
               docs=(40, 40, 2)):
    """3 tenants in one arena; tenant 2 holds fewer docs than k.

    fragmented=True interleaves the ingests so tenants span multiple
    segments (only the full-scan masked policy is then correct)."""
    rng = np.random.default_rng(seed)
    idx = MultiTenantIndex(N, DIM, RetrievalConfig(k=k, metric=metric))
    per_tenant = {t: rng.normal(size=(nd, DIM)).astype(np.float32)
                  for t, nd in enumerate(docs)}
    if fragmented:
        chunks = {t: np.array_split(d, 4) for t, d in per_tenant.items()}
        for i in range(4):
            for t in per_tenant:
                if len(chunks[t][i]):
                    idx.ingest(t, jnp.asarray(chunks[t][i]))
    else:
        for t, d in per_tenant.items():
            idx.ingest(t, jnp.asarray(d))
    queries = rng.normal(size=(4, DIM)).astype(np.float32)
    q_codes, _ = quantize_int8(jnp.asarray(queries), per_vector=True)
    return idx, q_codes


def assert_results_equal(a, b):
    np.testing.assert_array_equal(np.asarray(a.indices),
                                  np.asarray(b.indices))
    np.testing.assert_array_equal(np.asarray(a.scores), np.asarray(b.scores))
    np.testing.assert_array_equal(np.asarray(a.candidate_indices),
                                  np.asarray(b.candidate_indices))


def run_both_backends(fn, cfg):
    rj = fn(cfg)
    rp = fn(dataclasses.replace(cfg, backend="pallas"))
    return rj, rp


@pytest.mark.parametrize("metric", ["cosine", "mips"])
def test_plain_policy_backend_parity(metric):
    rng = np.random.default_rng(7)
    db = BitPlanarDB.from_quantized(build_database(
        jnp.asarray(rng.normal(size=(300, DIM)).astype(np.float32))))
    q_codes, _ = quantize_int8(jnp.asarray(
        rng.normal(size=(8, DIM)).astype(np.float32)), per_vector=True)
    cfg = RetrievalConfig(k=5, metric=metric)
    rj, rp = run_both_backends(lambda c: batched_retrieve(q_codes, db, c),
                               cfg)
    assert_results_equal(rj, rp)
    # single-query wrapper == lane 0 of the batch, both backends
    sj, sp = run_both_backends(
        lambda c: two_stage_retrieve(q_codes[0], db, c), cfg)
    assert_results_equal(sj, sp)
    np.testing.assert_array_equal(np.asarray(sj.indices),
                                  np.asarray(rj.indices)[0])


@pytest.mark.parametrize("metric", ["cosine", "mips"])
@pytest.mark.parametrize("fragmented", [False, True])
def test_masked_policy_backend_parity(metric, fragmented):
    """Full-arena masked scan: fragmented tenants and a tenant with fewer
    live docs than k (lane 2), plus a NO_TENANT padding lane."""
    idx, q_codes = make_arena(fragmented, seed=11, metric=metric)
    db = idx.arena.db()
    tids = jnp.asarray([0, 1, 2, NO_TENANT], jnp.int32)
    rj, rp = run_both_backends(
        lambda c: batched_retrieve_masked(q_codes, db, idx.arena.owner,
                                          tids, c), idx.cfg)
    assert_results_equal(rj, rp)
    # the small tenant pads with -1; the padding lane returns nothing
    assert np.asarray(rj.indices)[2].tolist().count(-1) == idx.cfg.k - 2
    assert np.all(np.asarray(rj.indices)[3] == -1)
    sj, sp = run_both_backends(
        lambda c: two_stage_retrieve_masked(q_codes[0], db, idx.arena.owner,
                                            jnp.int32(0), c), idx.cfg)
    assert_results_equal(sj, sp)


@pytest.mark.parametrize("metric", ["cosine", "mips"])
@pytest.mark.parametrize("window", [8, 64])
def test_windowed_policy_backend_parity(metric, window):
    """Contiguous tenants served through per-lane windows, both backends;
    window 8 also exercises window < segment-length clamping of starts."""
    idx, q_codes = make_arena(False, seed=13, metric=metric)
    db = idx.arena.db()
    tids = np.asarray([0, 1, 2, 0], np.int32)
    starts = jnp.asarray([idx.table.segments(int(t))[0][0] for t in tids],
                         jnp.int32)
    rj, rp = run_both_backends(
        lambda c: windowed_retrieve_masked(q_codes, db, idx.arena.owner,
                                           jnp.asarray(tids), starts, c,
                                           window), idx.cfg)
    assert_results_equal(rj, rp)


@pytest.mark.parametrize("metric", ["cosine", "mips"])
def test_index_retrieve_backend_parity_end_to_end(metric):
    """MultiTenantIndex picks the policy host-side; both backends must
    agree through the whole facade (windowed AND fragmented fallback)."""
    for fragmented in (False, True):
        idx, q_codes = make_arena(fragmented, seed=29, metric=metric)
        tids = np.asarray([0, 1, 2, 1], np.int32)
        res_j = idx.retrieve(q_codes, tids)
        expected_kind = "masked" if fragmented else "windowed"
        assert idx.last_plan.kind == expected_kind
        idx.cfg = dataclasses.replace(idx.cfg, backend="pallas")
        res_p = idx.retrieve(q_codes, tids)
        assert_results_equal(res_j, res_p)


def test_windowed_and_masked_policies_agree():
    """The windowed fast path returns exactly what the full scan returns
    when tenants are contiguous (same budget, same masking)."""
    idx, q_codes = make_arena(False, seed=3)
    db = idx.arena.db()
    tids = jnp.asarray([0, 1, 2, 0], jnp.int32)
    full = batched_retrieve_masked(q_codes, db, idx.arena.owner, tids,
                                   idx.cfg)
    window = 64
    starts = jnp.asarray([idx.table.segments(int(t))[0][0] for t in tids],
                         jnp.int32)
    win = windowed_retrieve_masked(q_codes, db, idx.arena.owner, tids,
                                   starts, idx.cfg, window)
    np.testing.assert_array_equal(np.asarray(full.indices),
                                  np.asarray(win.indices))
    np.testing.assert_array_equal(np.asarray(full.scores),
                                  np.asarray(win.scores))


def test_window_smaller_than_k_rejected():
    idx, q_codes = make_arena(False, seed=5, k=5)
    db = idx.arena.db()
    with pytest.raises(ValueError, match="window"):
        windowed_retrieve_masked(q_codes, db, idx.arena.owner,
                                 jnp.zeros(4, jnp.int32),
                                 jnp.zeros(4, jnp.int32), idx.cfg, window=4)


def test_schedule_plan_byte_model():
    """The analytic model: plane-scan policies stream the MSB plane ONCE
    per batch; the vmapped-scalar path streamed it once per query."""
    cfg = RetrievalConfig(k=5)
    eng = RetrievalEngine(cfg)
    rng = np.random.default_rng(0)
    db = BitPlanarDB.from_quantized(build_database(
        jnp.asarray(rng.normal(size=(256, DIM)).astype(np.float32))))
    plane_bytes = 256 * (DIM // 2)
    for policy, kind in [(PlainPolicy(), "plain"),
                         (MaskedPolicy(jnp.zeros(256, jnp.int32),
                                       jnp.zeros(32, jnp.int32)), "masked")]:
        plan = eng.plan_for(db, 32, policy)
        assert plan.kind == kind
        assert plan.stage1_bytes == plane_bytes          # once per BATCH
        assert plan.stage1_bytes_vmapped == 32 * plane_bytes
    wplan = eng.plan_for(db, 32, WindowedPolicy(
        jnp.zeros(256, jnp.int32), jnp.zeros(32, jnp.int32),
        jnp.zeros(32, jnp.int32), window=16))
    assert wplan.kind == "windowed"
    # per-lane windows: bytes scale with B, but only over the window
    assert wplan.stage1_bytes == 32 * 16 * (DIM // 2)
    assert wplan.rows_scanned == 16


def test_engine_batched_equals_vmapped_single_lanes():
    """Lane i of one batched launch == an independent single-query call
    (the old vmapped semantics are preserved exactly)."""
    idx, q_codes = make_arena(True, seed=41)
    db = idx.arena.db()
    tids = jnp.asarray([0, 1, 2, 0], jnp.int32)
    batched = batched_retrieve_masked(q_codes, db, idx.arena.owner, tids,
                                      idx.cfg)
    for i in range(4):
        single = two_stage_retrieve_masked(q_codes[i], db, idx.arena.owner,
                                           tids[i], idx.cfg)
        np.testing.assert_array_equal(np.asarray(batched.indices)[i],
                                      np.asarray(single.indices))
        np.testing.assert_array_equal(np.asarray(batched.scores)[i],
                                      np.asarray(single.scores))


def test_layout_cache_keyed_on_cfg():
    """Replacing idx.cfg (e.g. a larger k) must not serve a stale windowed
    layout sized for the old k — the layout cache is keyed on cfg too."""
    idx, q_codes = make_arena(False, seed=31, k=3, docs=(6, 6, 6))
    tids = np.asarray([0, 1, 2, 0], np.int32)
    idx.retrieve(q_codes, tids)                    # caches window for k=3
    assert idx.last_plan.kind == "windowed"
    idx.cfg = dataclasses.replace(idx.cfg, k=16)   # window 8 would be < k
    res = idx.retrieve(q_codes, tids)              # must not raise
    assert np.asarray(res.indices).shape == (4, 16)


def test_scheduler_ledger_counts_real_requests_only():
    """The flush ledger: streamed bytes include the padded lanes (they ARE
    streamed), but the vmapped comparison counts only real requests — a
    sequential server would never dispatch padding."""
    from repro.tenancy import CrossTenantBatchScheduler
    idx, q_codes = make_arena(False, seed=19)
    sched = CrossTenantBatchScheduler(idx, max_batch=8)
    for i, t in enumerate((0, 1, 0)):          # 3 real requests, padded to 4
        sched.submit(t, np.asarray(q_codes[i]))
    sched.flush()
    plan = idx.last_plan
    assert plan.kind == "windowed" and plan.batch == 4
    window_bytes = plan.rows_scanned * (DIM // 2)
    assert sched.stage1_bytes_streamed == 4 * window_bytes
    assert sched.stage1_bytes_vmapped == 3 * window_bytes


# ---------------------------------------------------------------------------
# Cluster-pruned cascade
# ---------------------------------------------------------------------------

def make_clustered_db(n=512, dim=DIM, k_clusters=16, block_rows=32, seed=0):
    """Single-corpus clustered DB: rows packed in cluster-grouped order,
    plus the codebook / block table / labels the cascade needs."""
    rng = np.random.default_rng(seed)
    docs = rng.normal(size=(n, dim)).astype(np.float32)
    qdb = build_database(jnp.asarray(docs))
    cents, labels = kmeans_int8(np.asarray(qdb.values), k_clusters,
                                iters=4, seed=seed)
    order = cluster_grouped_order(labels)
    db = BitPlanarDB.from_quantized(
        build_database(jnp.asarray(docs[order])))
    labels = labels[order]
    table = block_table(labels, k_clusters, block_rows)
    codebook = clustering.ClusterCodebook.from_codes(cents)
    q, _ = quantize_int8(jnp.asarray(
        rng.normal(size=(4, dim)).astype(np.float32)), per_vector=True)
    return db, codebook, table, labels, q


@pytest.mark.parametrize("metric", ["cosine", "mips"])
@pytest.mark.parametrize("nprobe", [2, 16])
def test_cluster_policy_backend_parity(metric, nprobe):
    """The 3-stage cascade returns identical results on both backends
    (the gathered-scan kernel and its jnp reference are bit-equal, so the
    candidate sets — and everything downstream — agree exactly)."""
    db, codebook, table, labels, q = make_clustered_db()
    cfg = RetrievalConfig(k=5, metric=metric)
    rj, rp = run_both_backends(
        lambda c: cluster_pruned_retrieve(q, db, codebook, table, labels,
                                          c, nprobe=nprobe, block_rows=32),
        cfg)
    assert_results_equal(rj, rp)


def test_cluster_cascade_nprobe_k_recovers_full_scan():
    """Probing every cluster must recover exactly the full two-stage
    scan's top-k SET (row visit order differs, so tie-broken candidate
    sets may differ, but with the budget clamped to the whole corpus the
    exact stage rescoresthe same winners)."""
    db, codebook, table, labels, q = make_clustered_db(n=256, k_clusters=8)
    cfg = RetrievalConfig(k=5, max_candidates=256)
    full = batched_retrieve(q, db, cfg)
    pruned = cluster_pruned_retrieve(q, db, codebook, table, labels, cfg,
                                     nprobe=8, block_rows=32)
    for i in range(q.shape[0]):
        assert (set(np.asarray(full.indices)[i].tolist())
                == set(np.asarray(pruned.indices)[i].tolist()))
        np.testing.assert_array_equal(np.asarray(full.scores)[i],
                                      np.asarray(pruned.scores)[i])


def test_cluster_cascade_never_duplicates_rows():
    """Blocks at cluster boundaries are listed under BOTH clusters; the
    per-row label mask must keep each row visible exactly once, so no
    document can appear twice in one lane's results."""
    db, codebook, table, labels, q = make_clustered_db(n=300, k_clusters=8)
    cfg = RetrievalConfig(k=10, max_candidates=300)
    res = cluster_pruned_retrieve(q, db, codebook, table, labels, cfg,
                                  nprobe=8, block_rows=32)
    for lane in np.asarray(res.indices):
        live = lane[lane >= 0]
        assert len(live) == len(set(live.tolist()))


def test_cluster_schedule_plan_per_stage_ledger():
    """The cluster plan's per-stage ledger: prune streams the K-row
    centroid plane once per batch; approx streams each lane's probed
    blocks; exact streams candidates' full codes. The flat stage1_bytes
    must drop below the full-scan figure by ~K/nprobe."""
    db, codebook, table, labels, q = make_clustered_db(
        n=512, k_clusters=16, block_rows=32)
    cfg = RetrievalConfig(k=5)
    eng = RetrievalEngine(cfg)
    policy = engine_mod.ClusterPolicy(
        owner=jnp.zeros(512, jnp.int32), tenant_ids=jnp.zeros(4, jnp.int32),
        labels=jnp.asarray(labels), centroid_msb=codebook.msb_plane,
        centroid_norms=codebook.norms_sq,
        cluster_blocks=jnp.asarray(table), nprobe=2, block_rows=32)
    plan = eng.plan_for(db, 4, policy)
    assert plan.kind == "cluster"
    mb = table.shape[1]
    probe = 2 * mb * 32
    assert plan.rows_scanned == probe
    assert [s.name for s in plan.stages] == ["prune", "approx", "exact"]
    prune, approx, exact = plan.stages
    assert prune.bytes_hbm == 16 * (DIM // 2)          # codebook, per batch
    assert prune.rows == 16 and prune.bits == 4
    assert approx.bytes_hbm == 4 * probe * (DIM // 2)  # per-lane gathers
    assert approx.bytes_hbm == plan.stage1_bytes
    assert exact.bits == 8
    assert exact.bytes_hbm == plan.stage2_bytes == 4 * plan.candidates * DIM
    # the prune's point: each lane scans a cluster-sized slice, not the
    # arena (the batch-level crossover vs the shared-plane scan happens
    # once N >> B * probe — benchmarks/retrieval_bench.py checks the >=4x
    # reduction at 64k docs)
    assert plan.rows_scanned < 512
    assert plan.stage1_bytes < plan.stage1_bytes_vmapped
    assert plan.stage1_bytes_vmapped == 4 * 512 * (DIM // 2)


# ---------------------------------------------------------------------------
# Device-resident slab policy (the serving runtime's cached path)
# ---------------------------------------------------------------------------

def make_slab_setup(metric="cosine", seed=0):
    """Multi-tenant clustered index + the (policy, host table) layout a
    batched retrieve would run, with a NO_TENANT padding lane."""
    rng = np.random.default_rng(seed)
    idx = MultiTenantIndex(512, DIM, RetrievalConfig(k=3, metric=metric),
                           clusters=ClusterParams(num_clusters=8, nprobe=3,
                                                  block_rows=32))
    for t in range(3):
        idx.ingest(t, jnp.asarray(
            rng.normal(size=(96, DIM)).astype(np.float32)))
    idx.compact()
    tids = np.asarray([0, 1, 1, 2, NO_TENANT], np.int32)
    policy, table = idx.cluster_layout(tids)
    q, _ = quantize_int8(jnp.asarray(
        rng.normal(size=(5, DIM)).astype(np.float32)), per_vector=True)
    return idx, policy, table, tids, q


def make_slab_policy(idx, policy, table, tids, resident_frac, seed=0):
    """Hand-build a SlabPolicy mirroring `resident_frac` of the
    (tenant, cluster) views into a slab extension region, exactly as the
    serving runtime's HotClusterCache does (device block copies)."""
    import jax
    db = idx.arena.db()
    n, d2 = db.msb_plane.shape
    br = policy.block_rows
    rng = np.random.default_rng(seed)
    keys, seen = [], set()
    for i, t in enumerate(tids.tolist()):
        if t < 0:
            continue
        for c in range(table.shape[1]):
            bl = table[i, c]
            bl = bl[bl >= 0]
            if bl.size and (t, c) not in seen:
                seen.add((t, c))
                keys.append((t, c, bl))
    rng.shuffle(keys)
    resident = keys[: round(len(keys) * resident_frac)]
    s_blocks = max(sum(len(bl) for _, _, bl in resident), 1)
    comb = jnp.concatenate([db.msb_plane,
                            jnp.zeros((s_blocks * br, d2), jnp.uint8)])
    nf = jnp.maximum(db.norms_sq.astype(jnp.float32), 1.0)
    inv = jnp.where(db.norms_sq > 0, jax.lax.rsqrt(nf), 0.0)
    inv = jnp.concatenate([inv, jnp.zeros((s_blocks * br,), jnp.float32)])
    slab_tbl = table.copy()
    base, nxt = n // br, 0
    gid0 = np.concatenate([np.arange(base, dtype=np.int32) * br,
                           np.zeros(s_blocks, np.int32)])
    cnt = np.concatenate([np.full(base, br, np.int32),
                          np.zeros(s_blocks, np.int32)])
    src, dst = [], []
    for t, c, bl in resident:
        slots = np.arange(nxt, nxt + len(bl), dtype=np.int32)
        nxt += len(bl)
        for lane in np.nonzero(tids == t)[0]:
            slab_tbl[lane, c, :len(bl)] = slots + base
        # whole-plane-block mirrors: each slot's origin is its source
        # block's first row, at full occupancy
        gid0[slots + base] = bl * br
        cnt[slots + base] = br
        src.extend(bl.tolist())
        dst.extend((slots + base).tolist())
    if src:
        rows_s = (np.asarray(src)[:, None] * br + np.arange(br)).reshape(-1)
        rows_d = (np.asarray(dst)[:, None] * br + np.arange(br)).reshape(-1)
        comb = comb.at[jnp.asarray(rows_d)].set(comb[jnp.asarray(rows_s)])
        inv = inv.at[jnp.asarray(rows_d)].set(inv[jnp.asarray(rows_s)])
    return engine_mod.SlabPolicy(
        packed_labels=engine_mod.packed_membership(
            policy.owner, policy.labels, policy.centroid_msb.shape[0]),
        tenant_ids=policy.tenant_ids, centroid_msb=policy.centroid_msb,
        centroid_norms=policy.centroid_norms,
        cluster_valid=jnp.asarray(table[:, :, 0] >= 0),
        slab_blocks=jnp.asarray(slab_tbl), block_gid0=jnp.asarray(gid0),
        block_count=jnp.asarray(cnt), slab_plane=comb, inv_norms=inv,
        nprobe=policy.nprobe, block_rows=br)


@pytest.mark.parametrize("metric", ["cosine", "mips"])
@pytest.mark.parametrize("resident_frac", [0.0, 0.5, 1.0])
def test_slab_policy_bit_identical_to_cluster_cascade(metric, resident_frac):
    """The slab path — cold (all blocks stream from the plane region),
    mixed hit/miss, and fully warm (every probed view slab-resident) —
    must return results bit-identical to the in-graph ClusterPolicy
    cascade, on both backends, including the NO_TENANT padding lane and
    the aux selection output."""
    idx, policy, table, tids, q = make_slab_setup(metric)
    db = idx.arena.db()
    ref = idx.engine.retrieve(q, db, policy)
    slab = make_slab_policy(idx, policy, table, tids, resident_frac)
    for backend in ("jnp", "pallas"):
        eng = RetrievalEngine(dataclasses.replace(idx.cfg, backend=backend))
        res, tc = eng.retrieve_with_clusters(q, db, slab)
        assert_results_equal(ref, res)
        # selection is the SAME in-graph select_clusters the cold prune runs
        _, ref_tc = eng.retrieve_with_clusters(q, db, policy)
        np.testing.assert_array_equal(np.asarray(tc), np.asarray(ref_tc))
    # padding lane surfaces nothing
    assert np.all(np.asarray(ref.indices)[-1] == -1)


def test_slab_policy_plan_maps_to_cluster_kind():
    idx, policy, table, tids, q = make_slab_setup()
    slab = make_slab_policy(idx, policy, table, tids, 1.0)
    plan = idx.engine.plan_for(idx.arena.db(), len(tids), slab)
    ref = idx.engine.plan_for(idx.arena.db(), len(tids), policy)
    assert plan == ref and plan.kind == "cluster"


def test_multitenant_cluster_path_end_to_end():
    """MultiTenantIndex with clustering: the cascade kind is selected,
    isolation holds, both backends agree, and recall vs the same index
    without clustering stays high on clustered per-tenant corpora."""
    rng = np.random.default_rng(5)
    params = ClusterParams(num_clusters=8, nprobe=3, block_rows=32)
    idx = MultiTenantIndex(1024, DIM, RetrievalConfig(k=3),
                           clusters=params)
    ref = MultiTenantIndex(1024, DIM, RetrievalConfig(k=3))
    for t in range(3):
        docs = rng.normal(size=(120, DIM)).astype(np.float32)
        idx.ingest(t, jnp.asarray(docs))
        ref.ingest(t, jnp.asarray(docs))
    idx.compact()                       # cluster-grouped layout
    q, _ = quantize_int8(jnp.asarray(
        rng.normal(size=(4, DIM)).astype(np.float32)), per_vector=True)
    tids = np.asarray([0, 1, 2, NO_TENANT], np.int32)
    res = idx.retrieve(q, tids)
    assert idx.last_plan.kind == "cluster"
    assert [s.name for s in idx.last_plan.stages] == ["prune", "approx",
                                                      "exact"]
    owner = np.asarray(idx.arena.owner)
    ids = np.asarray(res.indices)
    for i, t in enumerate(tids):
        live = ids[i][ids[i] >= 0]
        assert (owner[live] == t).all()
    assert (ids[3] == -1).all()         # padding lane returns nothing
    idx.cfg = dataclasses.replace(idx.cfg, backend="pallas")
    assert_results_equal(res, idx.retrieve(q, tids))
    # stage-1 bytes: pruned lanes beat the full-arena masked scan
    full_plan = ref.engine.plan_for(ref.arena.db(), 4, MaskedPolicy(
        ref.arena.owner, jnp.asarray(tids)))
    assert idx.last_plan.stage1_bytes < full_plan.stage1_bytes_vmapped


def test_multitenant_cluster_falls_back_until_trained():
    """Before any ingest trains the codebook, retrieval must fall back to
    the windowed/masked paths instead of crashing."""
    idx = MultiTenantIndex(256, DIM, RetrievalConfig(k=3),
                           clusters=ClusterParams(num_clusters=4))
    docs = np.random.default_rng(0).normal(size=(20, DIM)).astype(np.float32)
    q, _ = quantize_int8(jnp.asarray(docs[:2]), per_vector=True)
    # trained already by the first ingest — so drop the codebook to
    # simulate the pre-training window
    idx.ingest(0, jnp.asarray(docs))
    idx.clusters._centroids = None
    res = idx.retrieve(q, np.asarray([0, 0], np.int32))
    assert idx.last_plan.kind in ("windowed", "masked")
    assert np.asarray(res.indices).shape == (2, 3)


def test_scheduler_per_stage_bytes_ledger():
    """Scheduler flushes accumulate the per-stage cascade ledger."""
    from repro.tenancy import CrossTenantBatchScheduler
    rng = np.random.default_rng(9)
    idx = MultiTenantIndex(512, DIM, RetrievalConfig(k=3),
                           clusters=ClusterParams(num_clusters=4, nprobe=2,
                                                  block_rows=32))
    docs = rng.normal(size=(100, DIM)).astype(np.float32)
    idx.ingest(0, jnp.asarray(docs))
    idx.compact()
    sched = CrossTenantBatchScheduler(idx, max_batch=4)
    q, _ = quantize_int8(jnp.asarray(docs[:2]), per_vector=True)
    for i in range(2):
        sched.submit(0, np.asarray(q[i]))
    sched.flush()
    plan = idx.last_plan
    assert plan.kind == "cluster"
    assert sched.stage_bytes == {s.name: s.bytes_hbm for s in plan.stages}


def test_masked_score_floor_is_comparator_safe():
    """engine.MASKED_SCORE**2 must stay below 2**62 (the comparator's limb
    budget) while ranking under every real score."""
    s = int(engine_mod.MASKED_SCORE)
    assert s * s * 1 < 2 ** 62
    assert s < -(512 * 128 * 128)       # below any D<=512 INT8 dot product


# ---------------------------------------------------------------------------
# Stage-0 sign prescreen (the adaptive-precision cascade)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("metric", ["cosine", "mips"])
@pytest.mark.parametrize("policy_kind", ["cluster", "slab"])
def test_prescreen_c0_full_view_is_bit_identical_to_no_prescreen(
        metric, policy_kind):
    """The parity anchor: with c0 >= the probe view the prescreen deletes
    nothing and re-sorts survivors into view order, so the WHOLE cascade
    is bit-identical to the prescreen-off schedule — on both backends,
    for both metrics, through both the cluster and the slab policy."""
    idx, policy, table, tids, q = make_slab_setup(metric)
    db = idx.arena.db()
    view = policy.nprobe * table.shape[2] * policy.block_rows
    cfg_on = dataclasses.replace(idx.cfg, prescreen_c0=view)
    slab = make_slab_policy(idx, policy, table, tids, 0.5)
    pol = policy if policy_kind == "cluster" else slab
    ref = RetrievalEngine(idx.cfg).retrieve(q, db, pol)
    for backend in ("jnp", "pallas"):
        eng = RetrievalEngine(dataclasses.replace(cfg_on, backend=backend))
        assert_results_equal(ref, eng.retrieve(q, db, pol))


@pytest.mark.parametrize("metric", ["cosine", "mips"])
def test_prescreen_backend_parity_and_isolation_at_small_c0(metric):
    """A thinning prescreen (c0 = view/4) changes the candidate set, so
    the anchor is cross-backend bit-parity plus the isolation contract:
    no lane ever surfaces another tenant's rows or a padding result."""
    idx, policy, table, tids, q = make_slab_setup(metric)
    db = idx.arena.db()
    view = policy.nprobe * table.shape[2] * policy.block_rows
    cfg = dataclasses.replace(idx.cfg, prescreen_c0=view // 4)
    slab = make_slab_policy(idx, policy, table, tids, 0.5)
    for pol in (policy, slab):
        rj, rp = run_both_backends(
            lambda c, p=pol: RetrievalEngine(c).retrieve(q, db, p), cfg)
        assert_results_equal(rj, rp)
        owner = np.asarray(idx.arena.owner)
        ids = np.asarray(rj.indices)
        for i, t in enumerate(tids.tolist()):
            live = ids[i][ids[i] >= 0]
            if t < 0:
                assert live.size == 0
            else:
                assert (owner[live] == t).all()


def _check_prescreen_survivors(seed: int, c0: int, deletes: int) -> None:
    """The stage-level property: run CentroidPrune + SignPrescreen in
    isolation on an arena with tombstones and verify every survivor the
    prescreen marks visible (member=True) is a live row of the lane's
    own tenant — stage 0 can never leak a foreign or tombstoned row
    into stage 1's candidate view."""
    from repro.core.bitplanar import sign_pm1
    from repro.core.quantization import msb_nibble
    rng = np.random.default_rng(seed)
    idx = MultiTenantIndex(
        512, DIM, RetrievalConfig(k=3, prescreen_c0=c0),
        clusters=ClusterParams(num_clusters=8, nprobe=3, block_rows=32))
    for t in range(3):
        idx.ingest(t, jnp.asarray(
            rng.normal(size=(96, DIM)).astype(np.float32)))
    idx.compact()
    if deletes:
        live = np.nonzero(np.asarray(idx.arena.owner) >= 0)[0]
        idx.arena.delete(rng.choice(live, size=deletes, replace=False))
    tids = np.asarray([0, 1, 1, 2], np.int32)
    policy, _ = idx.cluster_layout(tids)
    q, _ = quantize_int8(jnp.asarray(
        rng.normal(size=(4, DIM)).astype(np.float32)), per_vector=True)
    ctx = engine_mod._CascadeCtx(
        query_codes=q, q_msb=msb_nibble(q), db=idx.arena.db(),
        policy=policy, cfg=idx.cfg, fns=engine_mod.stage_fns("jnp"),
        q_sign=sign_pm1(q))
    state = engine_mod._CascadeState()
    state = engine_mod.CentroidPrune(policy.nprobe).run(state, ctx)
    state = engine_mod.SignPrescreen(idx.cfg.prescreen_c0).run(state, ctx)
    rows = np.asarray(state.rows)
    member = np.asarray(state.member)
    # the view really was thinned to the clamped budget
    assert rows.shape[1] <= max(idx.cfg.k, c0)
    owner = np.asarray(idx.arena.owner)
    for i, t in enumerate(tids.tolist()):
        surv = rows[i][member[i]]
        assert (surv >= 0).all()
        assert (owner[surv] == t).all()     # same tenant AND live
    # ...and the full cascade agrees end-to-end
    res = idx.retrieve(q, tids)
    ids = np.asarray(res.indices)
    for i, t in enumerate(tids.tolist()):
        live_ids = ids[i][ids[i] >= 0]
        assert (owner[live_ids] == t).all()


try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    _HAVE_HYPOTHESIS = True
except ImportError:          # pragma: no cover - optional dependency
    _HAVE_HYPOTHESIS = False

if _HAVE_HYPOTHESIS:
    @settings(max_examples=25, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), c0=st.integers(3, 512),
           deletes=st.integers(0, 60))
    def test_prescreen_never_surfaces_foreign_or_dead_rows(seed, c0,
                                                           deletes):
        _check_prescreen_survivors(seed, c0, deletes)
else:
    @pytest.mark.parametrize("seed,c0,deletes",
                             [(0, 3, 0), (1, 16, 30), (2, 64, 60),
                              (3, 100, 17), (4, 512, 45)])
    def test_prescreen_never_surfaces_foreign_or_dead_rows(seed, c0,
                                                           deletes):
        """Seeded fallback for the hypothesis property when hypothesis
        is not installed: same check, fixed corpus of cases."""
        _check_prescreen_survivors(seed, c0, deletes)


def test_prescreen_schedule_plan_ledger():
    """The prescreen's StagePlan entry: bits=1, whole view streamed at
    D/8 bytes per row, and the downstream approx stage shrunk to the C0
    survivor budget — all exact arithmetic."""
    db, codebook, table, labels, q = make_clustered_db(
        n=512, k_clusters=16, block_rows=32)
    cfg = RetrievalConfig(k=5, prescreen_c0=128)
    eng = RetrievalEngine(cfg)
    policy = engine_mod.ClusterPolicy(
        owner=jnp.zeros(512, jnp.int32), tenant_ids=jnp.zeros(4, jnp.int32),
        labels=jnp.asarray(labels), centroid_msb=codebook.msb_plane,
        centroid_norms=codebook.norms_sq,
        cluster_blocks=jnp.asarray(table), nprobe=2, block_rows=32)
    plan = eng.plan_for(db, 4, policy)
    assert [s.name for s in plan.stages] == ["prune", "prescreen",
                                             "approx", "exact"]
    view = 2 * table.shape[1] * 32
    prune, pre, approx, exact = plan.stages
    assert pre.rows == view and pre.bits == 1
    assert pre.bytes_hbm == 4 * view * (DIM // 8)     # sign plane, per lane
    assert pre.compares == view
    assert approx.rows == 128                          # C0 survivors only
    assert approx.bytes_hbm == 4 * 128 * (DIM // 2)
    assert plan.stage1_bytes == approx.bytes_hbm
    # the cascade's total stage-0+stage-1 traffic beats the no-prescreen
    # schedule's stage-1 bytes (same policy, prescreen-off config)
    base = RetrievalEngine(RetrievalConfig(k=5)).plan_for(db, 4, policy)
    base_s1 = [s for s in base.stages if s.name == "approx"][0].bytes_hbm
    assert pre.bytes_hbm + approx.bytes_hbm < base_s1
