"""End-to-end system test: train a tiny LM with checkpointing + elastic
restart, then serve it behind the paper's RAG retrieval pipeline."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import RetrievalConfig
from repro.data import LMTaskConfig, lm_batches
from repro.models import embedder, get_model
from repro.runtime import ElasticTrainer, FailureInjector
from repro.serve import RAGPipeline
from repro.train import adamw, make_train_step


def test_train_then_rag_serve(tmp_path):
    cfg = get_config("qwen2-0.5b", smoke=True)
    api = get_model(cfg)
    opt = adamw(lr=2e-3)

    def make_state(mesh):
        params = api.init(jax.random.PRNGKey(0))
        opt_state = opt.init(params)
        raw = jax.jit(make_train_step(api.loss_fn, opt))

        def step_fn(p, o, b, mesh):
            return raw(p, o, b)
        return params, opt_state, step_fn, None

    gen = lm_batches(LMTaskConfig(vocab_size=cfg.vocab_size, seq_len=16,
                                  batch_size=4))
    batches = ({k: jnp.asarray(v) for k, v in b.items()} for b in gen)
    trainer = ElasticTrainer(make_state=make_state,
                             ckpt=CheckpointManager(str(tmp_path)),
                             save_every=5)

    class FakeDev:
        def __init__(self, i):
            self.id = i

    import repro.runtime.elastic as el
    orig = el.build_mesh_from
    el.build_mesh_from = lambda d, mp: orig(jax.devices(), 1)
    try:
        out = trainer.run(batches, num_steps=12,
                          injector=FailureInjector({7: 1}),
                          devices=[FakeDev(0), FakeDev(1)])
    finally:
        el.build_mesh_from = orig
    assert out["restarts"] == 1

    # restore trained params and serve them behind the retrieval pipeline
    params = api.init(jax.random.PRNGKey(0))
    (params, _), step = trainer.ckpt.restore_latest((params, opt.init(params)))
    assert step == 12

    ecfg = embedder.MINILM_CFG.with_(num_layers=2, d_model=32, num_heads=4,
                                     num_kv_heads=4, d_ff=64,
                                     vocab_size=cfg.vocab_size, pooled_dim=32)
    eparams = embedder.init_params(ecfg, jax.random.PRNGKey(5))
    docs = jnp.asarray(np.random.default_rng(1).integers(
        0, cfg.vocab_size, (30, 8)).astype(np.int32))
    pipe = RAGPipeline.build(ecfg, eparams, api, params, docs,
                             RetrievalConfig(k=2))
    out_toks, ids, ledger = pipe.answer(docs[jnp.asarray([3, 9])], max_new=4)
    assert out_toks.shape == (2, 4)
    assert int(np.asarray(ids)[0, 0]) == 3   # query == doc 3
    assert ledger.proportions()["DRAM"] > 0.9
