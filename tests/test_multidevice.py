"""Multi-device behaviour (8 forced host devices in a SUBPROCESS, so the
main pytest process keeps its default single device)."""
import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def run_sub(code: str, timeout=600):
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=8",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=timeout)
    assert out.returncode == 0, out.stdout + "\n" + out.stderr
    return out.stdout


@pytest.mark.slow
def test_sharded_index_tournament_equals_single_shard():
    run_sub("""
import numpy as np, jax, jax.numpy as jnp
from repro.compat import make_mesh
from repro.core import *
from repro.core.index import ShardedIndex
from repro.core.retrieval import RetrievalConfig, two_stage_retrieve
from repro.core.bitplanar import BitPlanarDB
rng = np.random.default_rng(1)
emb = jnp.asarray(rng.normal(size=(1000, 512)).astype(np.float32))
mesh = make_mesh((4, 2), ('data', 'model'))
idx = ShardedIndex.build(emb, mesh)
db = build_database(emb); bp = BitPlanarDB.from_quantized(db)
for metric in ['cosine', 'mips']:
    cfg = RetrievalConfig(k=5, metric=metric)
    ret = idx.retrieve_fn(cfg)
    for seed in range(3):
        q, _ = quantize_int8(jnp.asarray(rng.normal(size=(512,)).astype(np.float32)))
        r = ret(q); r_ref = two_stage_retrieve(q, bp, cfg)
        assert np.array_equal(np.asarray(r.indices), np.asarray(r_ref.indices)), (metric, seed)
print('OK')
""")


@pytest.mark.slow
def test_sharded_train_step_all_families():
    run_sub("""
import jax, jax.numpy as jnp
from repro.compat import make_mesh, set_mesh
from repro.configs import get_config
from repro.models import get_model
from repro.train import get_optimizer, make_train_step
from repro.distributed import sharding as sh
mesh = make_mesh((4, 2), ('data', 'model'))
for aid in ['minitron-4b', 'llama4-maverick-400b-a17b', 'zamba2-2.7b',
            'internvl2-26b', 'seamless-m4t-medium']:
    cfg = get_config(aid, smoke=True)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    aparams = jax.eval_shape(lambda: params)
    pspec = sh.param_shardings(aparams, mesh, cfg)
    params = jax.device_put(params, pspec)
    opt = get_optimizer(cfg.optimizer)
    astate = jax.eval_shape(opt.init, aparams)
    ospec = sh.opt_state_shardings(astate, aparams, mesh, cfg)
    opt_state = jax.jit(opt.init, out_shardings=ospec)(params)
    batch = {'tokens': jnp.zeros((8, 16), jnp.int32),
             'labels': jnp.zeros((8, 16), jnp.int32)}
    if cfg.family == 'encdec':
        batch['frames'] = jnp.zeros((8, 16, cfg.d_model), jnp.float32)
    if cfg.family == 'vlm':
        batch['prefix_embeds'] = jnp.zeros((8, cfg.num_prefix_embeds, cfg.d_model), jnp.float32)
    batch = jax.device_put(batch, sh.batch_shardings(jax.eval_shape(lambda: batch), mesh))
    step = make_train_step(api.loss_fn, opt)
    with set_mesh(mesh):
        p2, o2, m = jax.jit(step)(params, opt_state, batch)
    loss = float(m['loss'])
    assert loss == loss, aid   # not NaN
    print(aid, loss)
print('OK')
""")


@pytest.mark.slow
def test_two_level_compressed_all_reduce_multidevice():
    run_sub("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.compat import make_mesh, shard_map
from repro.distributed import compression as comp
mesh = make_mesh((2, 4), ('pod', 'data'))
reduce_fn = comp.make_two_level_all_reduce(mesh)
g = jax.random.normal(jax.random.PRNGKey(0), (8, 33))
out = shard_map(lambda t: reduce_fn({'w': t})['w'], mesh=mesh,
                in_specs=P(('pod', 'data')), out_specs=P(('pod', 'data')),
                check_vma=False)(g)
want = jnp.broadcast_to(jnp.mean(g, axis=0, keepdims=True), g.shape)
err = float(jnp.max(jnp.abs(out - want)))
scale = float(jnp.max(jnp.abs(g))) / 127.0
assert err <= scale + 1e-5, (err, scale)
print('OK', err)
""")
