"""Multi-tenant streaming index: arena, isolation, scheduler, pipeline."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BitPlanarDB, QuantizedDB, RetrievalConfig,
                        two_stage_retrieve)
from repro.core.quantization import quantize_int8
from repro.core.retrieval import two_stage_retrieve_masked
from repro.data import retrieval_corpus
from repro.tenancy import (Arena, ArenaFull, CrossTenantBatchScheduler,
                           MultiTenantIndex)

DIM = 64


def build_index(num_tenants=3, docs_per_tenant=40, capacity=256, k=3,
                noise=0.05, metric="cosine"):
    """Planted corpora for several tenants packed into one arena. Returns
    (index, per-tenant dict of (docs, queries, gold, slots))."""
    idx = MultiTenantIndex(capacity, DIM,
                           RetrievalConfig(k=k, metric=metric))
    data = {}
    for t in range(num_tenants):
        docs, queries, gold = retrieval_corpus(
            docs_per_tenant, DIM, num_queries=6, seed=t, noise=noise)
        slots = idx.ingest(t, jnp.asarray(docs))
        data[t] = (docs, queries, gold, slots)
    return idx, data


def quantize_query(idx, q):
    codes, _ = quantize_int8(jnp.asarray(q))
    return codes


def test_insert_retrieve_roundtrip():
    idx, data = build_index()
    for t, (docs, queries, gold, slots) in data.items():
        for j in range(3):
            res = idx.retrieve(quantize_query(idx, queries[j]), t)
            assert int(np.asarray(res.indices)[0]) == int(slots[gold[j]])


def test_online_insert_visible_without_rebuild():
    idx, data = build_index()
    new_doc = retrieval_corpus(1, DIM, num_queries=1, seed=99)[0]
    (slot,) = idx.ingest(1, jnp.asarray(new_doc))
    res = idx.retrieve(quantize_query(idx, new_doc[0]), 1)
    assert int(np.asarray(res.indices)[0]) == int(slot)
    assert idx.arena.stats.rebuilds == 0


def test_tombstoned_doc_never_returned():
    idx, data = build_index()
    docs, queries, gold, slots = data[0]
    victim = int(slots[gold[0]])
    q = quantize_query(idx, queries[0])
    assert int(np.asarray(idx.retrieve(q, 0).indices)[0]) == victim
    idx.delete(0, [victim])
    res = idx.retrieve(q, 0)
    assert victim not in np.asarray(res.indices)
    assert victim not in np.asarray(res.candidate_indices)


def test_segment_isolation_even_for_identical_docs():
    """Tenant B holds an EXACT copy of tenant A's best document; A's query
    must still resolve inside A's segments only."""
    docs, queries, gold = retrieval_corpus(30, DIM, num_queries=4, seed=0)
    idx = MultiTenantIndex(128, DIM, RetrievalConfig(k=3))
    slots_a = idx.ingest(0, jnp.asarray(docs))
    slots_b = idx.ingest(1, jnp.asarray(docs))       # identical corpus!
    owner = np.asarray(idx.arena.owner)
    for j in range(4):
        for tenant, slots in ((0, slots_a), (1, slots_b)):
            res = idx.retrieve(quantize_query(idx, queries[j]), tenant)
            got = np.asarray(res.indices)
            got = got[got >= 0]
            assert np.all(owner[got] == tenant)
            assert int(got[0]) == int(slots[gold[j]])


def test_unknown_tenant_gets_nothing():
    idx, _ = build_index()
    q = quantize_query(idx, retrieval_corpus(1, DIM, 1, seed=5)[1][0])
    res = idx.retrieve(q, 42)
    assert np.all(np.asarray(res.indices) == -1)
    assert np.all(np.asarray(res.scores) == 0)


def test_tenant_with_fewer_docs_than_k_pads_invalid():
    idx = MultiTenantIndex(64, DIM, RetrievalConfig(k=5))
    docs = retrieval_corpus(2, DIM, num_queries=1, seed=3)[0]
    slots = idx.ingest(0, jnp.asarray(docs))
    res = idx.retrieve(quantize_query(idx, docs[0]), 0)
    got = np.asarray(res.indices)
    assert set(got[got >= 0]) <= {int(s) for s in slots}
    assert np.sum(got >= 0) == 2 and np.sum(got == -1) == 3


def test_compaction_preserves_results():
    idx, data = build_index(num_tenants=3, docs_per_tenant=30)
    # tombstone a few docs of each tenant (never the gold ones)
    for t, (docs, queries, gold, slots) in data.items():
        victims = [int(s) for i, s in enumerate(slots)
                   if i not in set(gold[:4])][:5]
        idx.delete(t, victims)
    before = {(t, j): np.asarray(
        idx.retrieve(quantize_query(idx, data[t][1][j]), t).indices)
        for t in data for j in range(4)}
    live_before = idx.num_live
    mapping = idx.compact()
    assert idx.num_live == live_before          # compaction drops nothing
    # each tenant is now ONE contiguous segment
    for t in data:
        assert len(idx.table.segments(t)) == 1
    for (t, j), old in before.items():
        after = np.asarray(
            idx.retrieve(quantize_query(idx, data[t][1][j]), t).indices)
        expect = np.where(old >= 0, mapping[np.maximum(old, 0)], -1)
        np.testing.assert_array_equal(after, expect)


def test_mixed_batch_scheduler_equivalence():
    """One flush over a mixed batch == per-request sequential masked
    retrieval == per-tenant standalone two_stage_retrieve (slot-shifted)."""
    idx, data = build_index(num_tenants=4, docs_per_tenant=40)
    sched = CrossTenantBatchScheduler(idx, max_batch=8)
    requests = []
    for t in (2, 0, 3, 1, 2, 0):                 # interleaved tenants
        j = len(requests) % 4
        q = np.asarray(quantize_query(idx, data[t][1][j]))
        requests.append((sched.submit(t, q), t, j, q))
    out = sched.flush()
    assert sched.pending() == 0 and sched.launches == 1

    db = idx.arena.db()
    for rid, t, j, q in requests:
        got = out[rid]
        # (a) identical to the sequential masked call
        seq = two_stage_retrieve_masked(jnp.asarray(q), db, idx.arena.owner,
                                        jnp.int32(t), idx.cfg)
        np.testing.assert_array_equal(np.asarray(got.indices),
                                      np.asarray(seq.indices))
        np.testing.assert_array_equal(np.asarray(got.scores),
                                      np.asarray(seq.scores))
        # (b) top-1 matches a standalone per-tenant database built from
        # the same fixed-scale codes
        docs, _, gold, slots = data[t]
        codes = idx.arena.quantize(jnp.asarray(docs))
        bp = BitPlanarDB.from_quantized(QuantizedDB(
            values=codes, scale=idx.arena.scale,
            norms_sq=jnp.sum(codes.astype(jnp.int32) ** 2, -1)))
        solo = two_stage_retrieve(jnp.asarray(q), bp, idx.cfg)
        assert (int(np.asarray(got.indices)[0]) - int(slots[0])
                == int(np.asarray(solo.indices)[0]))


def test_scheduler_pads_partial_batches_with_no_tenant():
    idx, data = build_index(num_tenants=2)
    sched = CrossTenantBatchScheduler(idx, max_batch=8)
    rid = sched.submit(0, np.asarray(quantize_query(idx, data[0][1][0])))
    out = sched.flush()                          # batch of 1, padded to 1
    assert int(np.asarray(out[rid].indices)[0]) == int(
        data[0][3][data[0][2][0]])


def test_windowed_and_fullscan_paths_agree():
    """The contiguous-segment fast path must return exactly what the
    general full-arena masked scan returns."""
    from repro.core.retrieval import batched_retrieve_masked
    idx, data = build_index(num_tenants=4, docs_per_tenant=40,
                            capacity=4096)      # window << capacity
    tids = np.asarray([0, 1, 2, 3], np.int32)
    Q = jnp.asarray(np.stack(
        [np.asarray(quantize_query(idx, data[t][1][0])) for t in tids]))
    fast = idx.retrieve(Q, tids)                 # windowed (contiguous)
    slow = batched_retrieve_masked(Q, idx.arena.db(), idx.arena.owner,
                                   jnp.asarray(tids), idx.cfg)
    np.testing.assert_array_equal(np.asarray(fast.indices)[:, 0],
                                  np.asarray(slow.indices)[:, 0])
    for t in range(4):
        f = np.asarray(fast.scores[t])
        s = np.asarray(slow.scores[t])
        np.testing.assert_array_equal(f[f != 0], s[:len(f[f != 0])])


def test_mips_metric_masked():
    idx, data = build_index(metric="mips")
    for t in (0, 1):
        docs, queries, gold, slots = data[t]
        res = idx.retrieve(quantize_query(idx, queries[0]), t)
        assert int(np.asarray(res.indices)[0]) == int(slots[gold[0]])


def test_arena_full_and_compaction_reclaims():
    arena = Arena(8, DIM)
    codes = jnp.ones((8, DIM), jnp.int8)
    slots = arena.insert(codes, 0)
    with pytest.raises(ArenaFull):
        arena.insert(codes[:1], 0)
    arena.delete(slots[:4])
    with pytest.raises(ArenaFull):               # tombstones NOT yet free
        arena.insert(codes[:1], 0)
    arena.compact()
    arena.insert(codes[:4], 1)                   # reclaimed after compact
    assert arena.num_live == 8
    assert arena.stats.rebuilds == 0


def test_arena_rejects_negative_tenant_and_bad_dims():
    arena = Arena(8, DIM)
    with pytest.raises(ValueError):
        arena.insert(jnp.ones((1, DIM), jnp.int8), -1)
    with pytest.raises(ValueError):
        arena.insert(jnp.ones((1, DIM + 2), jnp.int8), 0)
    with pytest.raises(ValueError):                  # float rows: quantize!
        arena.insert(jnp.ones((1, DIM), jnp.float32), 0)


def test_duplicate_and_repeated_delete_keeps_num_live_truthful():
    arena = Arena(8, DIM)
    slots = arena.insert(jnp.ones((4, DIM), jnp.int8), 0)
    arena.delete([int(slots[0]), int(slots[0])])     # duplicate ids
    assert arena.num_live == 3
    arena.delete([int(slots[0])])                    # already dead
    assert arena.num_live == 3


def test_sentinel_tenant_ids_cannot_resurrect_tombstones():
    """Querying as 'tenant -1' (the FREE/tombstone owner value) must be
    rejected, not return deleted rows."""
    idx, data = build_index()
    idx.delete(0, data[0][3][:4])
    q = quantize_query(idx, data[0][1][0])
    with pytest.raises(ValueError):
        idx.retrieve(q, -1)
    with pytest.raises(ValueError):
        idx.retrieve(jnp.stack([q]), np.asarray([-1], np.int32))
    sched = CrossTenantBatchScheduler(idx)
    with pytest.raises(ValueError):
        sched.submit(-1, np.asarray(q))


def test_multi_tenant_rag_pipeline_end_to_end():
    import jax
    from repro.configs import get_config
    from repro.models import embedder, get_model
    from repro.serve import MultiTenantRAGPipeline

    gcfg = get_config("qwen2-0.5b", smoke=True)
    api = get_model(gcfg)
    gparams = api.init(jax.random.PRNGKey(0))
    ecfg = embedder.MINILM_CFG.with_(num_layers=2, d_model=32, num_heads=4,
                                     num_kv_heads=4, d_ff=64,
                                     vocab_size=gcfg.vocab_size,
                                     pooled_dim=32)
    eparams = embedder.init_params(ecfg, jax.random.PRNGKey(7))
    pipe = MultiTenantRAGPipeline.create(
        ecfg, eparams, api, gparams, capacity=128, doc_len=10,
        retrieval_cfg=RetrievalConfig(k=2))
    rng = np.random.default_rng(0)
    tok = {t: rng.integers(0, gcfg.vocab_size, (20, 10)).astype(np.int32)
           for t in range(3)}
    slots = {t: pipe.ingest(t, tok[t]) for t in range(3)}

    tids = np.asarray([0, 1, 2], np.int32)
    q = jnp.asarray(np.stack([tok[t][4] for t in range(3)]))
    res, ledger = pipe.retrieve(tids, q)
    for t in range(3):
        assert int(np.asarray(res.indices)[t, 0]) == int(slots[t][4])
    assert ledger.total_uj > 0
    out, ids, _ = pipe.answer(tids, q, max_new=4)
    assert out.shape == (3, 4)

    # delete + compact keeps the token store slot-aligned
    pipe.delete(0, slots[0][:3])
    pipe.compact()
    res, _ = pipe.retrieve(np.asarray([0], np.int32),
                           jnp.asarray(tok[0][4][None]))
    top = int(np.asarray(res.indices)[0, 0])
    assert np.array_equal(pipe.doc_tokens[top], tok[0][4])
