"""Golden recall-regression suite: every retrieval variant pinned.

A seeded 4k-doc planted-relevance corpus (the paper's Table I protocol
shape) runs through every serving-facing retrieval variant — plain
batched, segment-masked, windowed, cluster-pruned cascade (jnp backend)
— and the results are pinned against golden values computed at the time
this suite was written:

  * recall@5 against the planted gold is 80/80 for EVERY variant at this
    operating point (noise 0.1 well inside cluster spread 0.2), and
  * the exact index/score fingerprints of the plain scan and the cascade.

Any future change that silently degrades retrieval accuracy — a kernel
rewrite, a quantization tweak, a prune bug, a masking regression —
trips this suite instead of surfacing as a slow recall drift nobody
measured. All math is exact integer arithmetic, so the pins are stable
across platforms; the floats involved (corpus synthesis, quantization
rounding, the f32 cosine key) are seeded and deterministic.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (BitPlanarDB, RetrievalConfig, build_database,
                        clustering, quantize_int8)
from repro.core.retrieval import (batched_retrieve, batched_retrieve_masked,
                                  cluster_pruned_retrieve,
                                  windowed_retrieve_masked)
from repro.data import retrieval_corpus

N, D, Q, K = 4096, 256, 80, 5
CSIZE, BLOCK_ROWS, NPROBE = 64, 64, 8
SEED = 1234

# -- the golden pins (recomputed only on a DELIBERATE protocol change) ----
GOLDEN_HITS = {"plain": 80, "masked": 80, "windowed": 80, "cascade": 80}
GOLDEN_PLAIN_INDEX_SUM = 881698
GOLDEN_PLAIN_SCORE_SUM = 119156404
GOLDEN_CASCADE_INDEX_SUM = 881698
GOLDEN_CASCADE_SCORE_SUM = 119156404


@pytest.fixture(scope="module")
def corpus():
    docs, queries, gold = retrieval_corpus(
        N, D, num_queries=Q, noise=0.1, cluster_size=CSIZE,
        cluster_spread=0.2, seed=SEED)
    db = BitPlanarDB.from_quantized(build_database(jnp.asarray(docs)))
    q, _ = quantize_int8(jnp.asarray(queries), per_vector=True)
    cfg = RetrievalConfig(k=K, metric="cosine")
    return docs, db, q, gold, cfg


def _hits(indices, gold) -> int:
    idx = np.asarray(indices)
    return int(sum(gold[i] in idx[i][:K] for i in range(Q)))


def test_plain_recall_pinned(corpus):
    _, db, q, gold, cfg = corpus
    res = batched_retrieve(q, db, cfg)
    assert _hits(res.indices, gold) == GOLDEN_HITS["plain"]
    assert int(np.asarray(res.indices, np.int64).sum()) == \
        GOLDEN_PLAIN_INDEX_SUM
    assert int(np.asarray(res.scores, np.int64).sum()) == \
        GOLDEN_PLAIN_SCORE_SUM


def test_masked_recall_pinned(corpus):
    _, db, q, gold, cfg = corpus
    half = N // 2
    owner = jnp.asarray(np.repeat([0, 1], half).astype(np.int32))
    tids = jnp.asarray((gold >= half).astype(np.int32))
    res = batched_retrieve_masked(q, db, owner, tids, cfg)
    assert _hits(res.indices, gold) == GOLDEN_HITS["masked"]


def test_windowed_recall_pinned_and_matches_masked(corpus):
    _, db, q, gold, cfg = corpus
    half = N // 2
    owner = jnp.asarray(np.repeat([0, 1], half).astype(np.int32))
    tids = jnp.asarray((gold >= half).astype(np.int32))
    starts = jnp.asarray((np.asarray(tids) * half).astype(np.int32))
    res = windowed_retrieve_masked(q, db, owner, tids, starts, cfg,
                                   window=half)
    assert _hits(res.indices, gold) == GOLDEN_HITS["windowed"]
    # The windowed fast path must agree with the general masked scan.
    ref = batched_retrieve_masked(q, db, owner, tids, cfg)
    np.testing.assert_array_equal(np.asarray(res.indices),
                                  np.asarray(ref.indices))
    np.testing.assert_array_equal(np.asarray(res.scores),
                                  np.asarray(ref.scores))


def test_cascade_recall_pinned(corpus):
    docs, db, q, gold, cfg = corpus
    labels = (np.arange(N) // CSIZE).astype(np.int32)
    nc = int(labels[-1]) + 1
    centers = np.stack([docs[labels == c].mean(axis=0) for c in range(nc)])
    cents, _ = quantize_int8(jnp.asarray(centers.astype(np.float32)))
    codebook = clustering.ClusterCodebook.from_codes(cents)
    table = clustering.block_table(labels, nc, BLOCK_ROWS)
    res = cluster_pruned_retrieve(q, db, codebook, table, labels, cfg,
                                  nprobe=NPROBE, block_rows=BLOCK_ROWS)
    assert _hits(res.indices, gold) == GOLDEN_HITS["cascade"]
    assert int(np.asarray(res.indices, np.int64).sum()) == \
        GOLDEN_CASCADE_INDEX_SUM
    assert int(np.asarray(res.scores, np.int64).sum()) == \
        GOLDEN_CASCADE_SCORE_SUM


# -- the stage-0 sign prescreen on the same golden protocol ----------------
#
# The probe view at this operating point is NPROBE * 1 block * BLOCK_ROWS
# = 512 rows. The sweep pins recall@5 at every prescreen budget down to
# C0 = view/8, and the stronger property actually measured: down to
# C0 = view/8 = 64 survivors the 1-bit prescreen admits the exact same
# winners — results are BIT-IDENTICAL to the no-prescreen cascade, not
# merely recall-neutral. C0 = view/4 = 128 is the bench's frontier point
# (2x stage-0+stage-1 bytes vs no-prescreen at unchanged results).
PRESCREEN_VIEW = NPROBE * BLOCK_ROWS                      # 512 probe rows
GOLDEN_PRESCREEN_HITS = {512: 80, 256: 80, 128: 80, 64: 80, 32: 80}
PRESCREEN_BIT_IDENTICAL_DOWN_TO = 64


@pytest.fixture(scope="module")
def cascade_setup(corpus):
    docs, db, q, gold, cfg = corpus
    labels = (np.arange(N) // CSIZE).astype(np.int32)
    nc = int(labels[-1]) + 1
    centers = np.stack([docs[labels == c].mean(axis=0) for c in range(nc)])
    cents, _ = quantize_int8(jnp.asarray(centers.astype(np.float32)))
    codebook = clustering.ClusterCodebook.from_codes(cents)
    table = clustering.block_table(labels, nc, BLOCK_ROWS)

    def run(run_cfg):
        return cluster_pruned_retrieve(q, db, codebook, table, labels,
                                       run_cfg, nprobe=NPROBE,
                                       block_rows=BLOCK_ROWS)
    return run, gold, cfg


@pytest.mark.parametrize("c0", sorted(GOLDEN_PRESCREEN_HITS))
def test_prescreen_recall_sweep_pinned(cascade_setup, c0):
    import dataclasses
    run, gold, cfg = cascade_setup
    res = run(dataclasses.replace(cfg, prescreen_c0=c0))
    assert _hits(res.indices, gold) == GOLDEN_PRESCREEN_HITS[c0]
    if c0 >= PRESCREEN_BIT_IDENTICAL_DOWN_TO:
        # not just recall-neutral: the exact golden fingerprints
        assert int(np.asarray(res.indices, np.int64).sum()) == \
            GOLDEN_CASCADE_INDEX_SUM
        assert int(np.asarray(res.scores, np.int64).sum()) == \
            GOLDEN_CASCADE_SCORE_SUM


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_prescreen_c0_full_view_bit_identical_on_golden_corpus(
        cascade_setup, backend):
    """C0 >= the whole probe view deletes nothing: the prescreened
    cascade must reproduce the pinned no-prescreen results bit-for-bit
    on BOTH backends — the golden-corpus anchor of the identity the
    engine suite checks on small shapes."""
    import dataclasses
    run, gold, cfg = cascade_setup
    res = run(dataclasses.replace(cfg, prescreen_c0=PRESCREEN_VIEW,
                                  backend=backend))
    assert _hits(res.indices, gold) == GOLDEN_HITS["cascade"]
    assert int(np.asarray(res.indices, np.int64).sum()) == \
        GOLDEN_CASCADE_INDEX_SUM
    assert int(np.asarray(res.scores, np.int64).sum()) == \
        GOLDEN_CASCADE_SCORE_SUM
