import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "b": {"c": jnp.arange(5), "d": jnp.float32(3.5)}}


def test_roundtrip(tmp_path):
    t = tree()
    save_checkpoint(str(tmp_path), 7, t)
    got, step = restore_checkpoint(str(tmp_path), t)
    assert step == 7
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_latest_step_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree(s))
    mgr.wait()
    assert latest_step(str(tmp_path)) == 4
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_00000003", "step_00000004"]


def test_crash_mid_save_never_corrupts_latest(tmp_path):
    save_checkpoint(str(tmp_path), 1, tree(1))
    # simulate a crash: a stale .tmp dir with garbage
    os.makedirs(tmp_path / "step_00000002.tmp")
    with open(tmp_path / "step_00000002.tmp" / "junk", "w") as f:
        f.write("partial")
    assert latest_step(str(tmp_path)) == 1
    got, step = restore_checkpoint(str(tmp_path), tree(1))
    assert step == 1


def test_restore_with_resharding(tmp_path):
    t = tree(3)
    save_checkpoint(str(tmp_path), 1, t)
    sh = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(jax.devices()[0]), t)
    got, _ = restore_checkpoint(str(tmp_path), t, shardings=sh)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_restore_missing_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        restore_checkpoint(str(tmp_path / "nope"), tree())
