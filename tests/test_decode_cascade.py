"""KV-decode cascade: the engine-backed sparse-KV path (ISSUE 10).

Gates the refactor's contract:
  * the engine path is BIT-IDENTICAL to the legacy hand-rolled
    `sparse_decode_attention_ref` across lengths {0, <top_k, >=top_k},
    mixed-length batches, and both backends — including the paged /
    prescreened schedules at full coverage, where the cascade must
    degenerate to the same selection;
  * the decode StagePlan ledger reconciles with `sparse_bytes_per_step`;
  * the pruned cascade's jnp and Pallas stage kernels agree bit-for-bit;
  * page centroids maintained incrementally equal a from-scratch rebuild;
  * the runtime charges decode through the same registry as retrieval.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import energy, engine
from repro.kernels import ops as kops
from repro.kernels import ref as kref
from repro.serve import sparse_kv

B, T, H, KH, HD = 2, 64, 8, 4, 32


def make_cache(seed=0, b=B, t=T, kh=KH, hd=HD, paged=False, page_rows=8):
    rng = np.random.default_rng(seed)
    k = jnp.asarray(rng.normal(size=(b, t, kh, hd)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, t, kh, hd)), jnp.float32)
    cache = sparse_kv.build_quant_cache(k, v)
    if paged:
        cache = sparse_kv.build_page_centroids(
            cache, jnp.full((b,), t, jnp.int32), page_rows=page_rows)
    return cache, k, v


def make_q(seed=2, b=B, h=H, hd=HD):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=(b, 1, h, hd)), jnp.float32)


# -- bit parity vs the legacy implementation --------------------------------

@pytest.mark.parametrize("length", [0, 3, 17, T])
def test_engine_path_bit_identical_to_legacy(length):
    cache, _, _ = make_cache()
    q = make_q()
    L = jnp.full((B,), length, jnp.int32)
    ref = sparse_kv.sparse_decode_attention_ref(q, cache, L, top_k=16)
    got = sparse_kv.sparse_decode_attention(q, cache, L, top_k=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))


def test_engine_path_bit_identical_mixed_lengths():
    cache, _, _ = make_cache()
    q = make_q()
    L = jnp.asarray([0, 40], jnp.int32)   # one empty lane, one live lane
    ref = sparse_kv.sparse_decode_attention_ref(q, cache, L, top_k=16)
    got = sparse_kv.sparse_decode_attention(q, cache, L, top_k=16)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref))
    assert not np.any(np.isnan(np.asarray(got)))


@pytest.mark.parametrize("backend", ["jnp", "pallas"])
def test_paged_full_coverage_degenerates_to_legacy(backend):
    """npages covering every page + prescreen keeping every row must
    select exactly the legacy candidate set (survivors re-sorted
    ascending), so the cascade output is bit-identical — on BOTH the jnp
    and the Pallas stage kernels."""
    cache, _, _ = make_cache(paged=True)
    q = make_q()
    L = jnp.full((B,), T, jnp.int32)
    ref = sparse_kv.sparse_decode_attention_ref(q, cache, L, top_k=16)
    paged = sparse_kv.sparse_decode_attention(
        q, cache, L, top_k=16, npages=T // 8, backend=backend)
    np.testing.assert_array_equal(np.asarray(paged), np.asarray(ref))
    ps = sparse_kv.sparse_decode_attention(
        q, cache, L, top_k=16, npages=T // 8, prescreen_c0=T,
        backend=backend)
    np.testing.assert_array_equal(np.asarray(ps), np.asarray(ref))


@pytest.mark.parametrize("lengths", [(5, 23), (0, 0), (64, 1)])
def test_pruned_cascade_jnp_vs_pallas_bit_parity(lengths):
    """The PRUNED schedules (partial page coverage, sign prescreen) have
    no legacy twin; their contract is backend equivalence — the Pallas
    prune/prescreen kernels must select the same pages/rows as the jnp
    reference fns, making the whole cascade bit-identical."""
    cache, _, _ = make_cache(paged=True)
    q = make_q()
    L = jnp.asarray(lengths, jnp.int32)
    for kwargs in ({"npages": 4}, {"npages": 6, "prescreen_c0": 24}):
        a = sparse_kv.sparse_decode_attention(q, cache, L, top_k=8,
                                              backend="jnp", **kwargs)
        b = sparse_kv.sparse_decode_attention(q, cache, L, top_k=8,
                                              backend="pallas", **kwargs)
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert not np.any(np.isnan(np.asarray(a)))


def test_empty_cache_paged_returns_zeros():
    cache, _, _ = make_cache(paged=True)
    q = make_q()
    out = sparse_kv.sparse_decode_attention(
        q, cache, jnp.zeros((B,), jnp.int32), top_k=8, npages=4)
    np.testing.assert_array_equal(np.asarray(out), np.zeros(q.shape))


# -- semantics the refactor must preserve -----------------------------------

def test_convergence_to_dense_as_topk_grows():
    """As top_k -> T (pages covering the cache), the cascade converges to
    exact dense attention up to INT8 key-quantization error."""
    from repro.models import attention as A
    cache, k, v = make_cache(paged=True)
    q = make_q()
    L = jnp.full((B,), T, jnp.int32)
    want = A.decode_attention(q, k, v, L)
    errs = []
    for top_k in (4, 16, T):
        got = sparse_kv.sparse_decode_attention(q, cache, L, top_k=top_k,
                                                npages=T // 8)
        errs.append(float(jnp.max(jnp.abs(got - want))))
    assert errs[-1] < 0.05                  # full top_k: quantization only
    assert errs[0] >= errs[-1]              # error shrinks as k grows


def test_gqa_group_max_selection():
    """A key relevant ONLY to the second query head of a group must still
    be selected: stage-1 takes the max over the group's scores, not head
    0's. With kh=1, h=2 the key aligned with head 1 dominates that head's
    attention, so small-top_k output must match full attention."""
    from repro.models import attention as A
    b, t, kh, hd, h = 1, 64, 1, 16, 2
    rng = np.random.default_rng(5)
    k = jnp.asarray(rng.normal(size=(b, t, kh, hd)) * 0.1, jnp.float32)
    q = jnp.asarray(rng.normal(size=(b, 1, h, hd)), jnp.float32)
    k = k.at[0, 37, 0].set(q[0, 0, 1] * 2.0)   # aligns with head 1 ONLY
    v = jnp.asarray(rng.normal(size=(b, t, kh, hd)), jnp.float32)
    L = jnp.full((b,), t, jnp.int32)
    cache = sparse_kv.build_quant_cache(k, v)
    got = sparse_kv.sparse_decode_attention(q, cache, L, top_k=8)
    want = A.decode_attention(q, k, v, L)
    # head 1 is dominated by key 37, so its top-8 output must be close to
    # exact — ONLY possible if the group-max kept the key that head 0's
    # scores alone would have discarded. (Head 0 with its relevance mass
    # spread over pruned keys is the documented approximation regime.)
    assert float(jnp.max(jnp.abs(got[:, :, 1] - want[:, :, 1]))) < 0.25
    # and dropping the group-max entirely (score with head 0 only) loses
    # key 37: head 1's output degrades
    got0 = sparse_kv.sparse_decode_attention(q.at[:, :, 1].set(q[:, :, 0]),
                                             cache, L, top_k=8)
    assert not np.allclose(np.asarray(got0[:, :, 1]),
                           np.asarray(want[:, :, 1]), atol=0.25)


# -- page-centroid maintenance ----------------------------------------------

def test_incremental_centroid_update_matches_rebuild():
    """Appending one key and refreshing ONE page incrementally must equal
    rebuilding every centroid from scratch at the new length."""
    page_rows = 8
    cache, _, _ = make_cache()
    for length in (1, 7, 8, 33):            # page starts, middles, ends
        L = jnp.full((B,), length, jnp.int32)
        full = sparse_kv.build_page_centroids(cache, L, page_rows)
        # start from the PREVIOUS length's centroids
        prev = sparse_kv.build_page_centroids(cache, L - 1, page_rows)
        cm, cs = sparse_kv.update_page_centroids(
            cache.k_msb, cache.k_lsb, cache.k_scale,
            prev.cent_msb, prev.cent_scale, L, page_rows)
        np.testing.assert_array_equal(np.asarray(cm),
                                      np.asarray(full.cent_msb))
        np.testing.assert_array_equal(np.asarray(cs),
                                      np.asarray(full.cent_scale))


def test_centroid_rows_kernel_matches_ref():
    """The named per-lane centroid kernel (KV page prune's stage-0) vs
    its oracle, bit-for-bit."""
    rng = np.random.default_rng(9)
    bq, p, d = 6, 16, 32
    q = jnp.asarray(rng.integers(-8, 8, size=(bq, d)), jnp.int8)
    rows = jnp.asarray(rng.integers(0, 256, size=(bq, p, d // 2)),
                       jnp.uint8)
    got = kops.centroid_scores_rows(q, rows)
    want = kref.centroid_scores_rows_ref(kops.pack_queries_even_odd(q),
                                         rows)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# -- ledger + pricing --------------------------------------------------------

def test_kv_plan_reconciles_with_sparse_bytes_per_step():
    """The no-prune decode ledger divided by (layers * batch * kv_heads)
    IS the byte model — same currency as the retrieval plans."""
    t, hd, k, kh, qh, b, layers = 32768, 128, 256, 8, 32, 4, 16
    plan = sparse_kv.decode_plan(k, batch=b, kv_heads=kh, q_heads=qh,
                                 seq_len=t, head_dim=hd, layers=layers)
    assert plan.kind == "decode"
    per_lane = sum(s.bytes_hbm for s in plan.stages) / (b * kh * layers)
    assert per_lane == sparse_kv.sparse_bytes_per_step(t, hd, k)


def test_kv_plan_page_prune_cuts_scan_bytes():
    cfg = engine.KVCascadeConfig(top_k=256, npages=64, page_rows=16,
                                 prescreen_c0=512)
    kw = dict(batch=4, kv_heads=8, q_heads=32, seq_len=32768, head_dim=128,
              layers=16)
    paged = engine.kv_plan(cfg, **kw)
    flat = engine.kv_plan(engine.KVCascadeConfig(top_k=256), **kw)
    names = [s.name for s in paged.stages]
    assert names == ["prune", "prescreen", "approx", "exact"]
    assert (sum(s.bytes_hbm for s in paged.stages)
            < sum(s.bytes_hbm for s in flat.stages) / 4)


def test_decode_cost_prices_like_retrieval():
    """energy.cost_cascade prices the decode ledger with the same model
    as retrieval ledgers: µJ/token falls when the schedule streams fewer
    bytes, and the dense-vs-sparse byte ratio clears 4x at k << T."""
    t, hd, k = 32768, 128, 256
    kw = dict(batch=4, kv_heads=8, q_heads=32, seq_len=t, head_dim=hd,
              layers=16)
    flat = engine.kv_plan(engine.KVCascadeConfig(top_k=k), **kw)
    paged = engine.kv_plan(engine.KVCascadeConfig(
        top_k=k, npages=64, page_rows=16), **kw)
    c_flat = energy.cost_cascade(flat.stages, hd, batch=flat.batch)
    c_paged = energy.cost_cascade(paged.stages, hd, batch=paged.batch)
    assert 0 < c_paged.total_uj < c_flat.total_uj
    dense = sparse_kv.dense_bytes_per_step(t, hd)
    assert dense / sparse_kv.sparse_bytes_per_step(t, hd, k) > 4


def test_runtime_account_decode_ledger_and_registry():
    from repro.obs import MetricsRegistry
    from repro.serve import RuntimeConfig, ServingRuntime
    from repro.tenancy import MultiTenantIndex
    from repro.core import RetrievalConfig

    idx = MultiTenantIndex(64, 32, RetrievalConfig())
    reg = MetricsRegistry()
    rt = ServingRuntime(idx, RuntimeConfig(), registry=reg)
    plan = engine.kv_plan(engine.KVCascadeConfig(top_k=16), batch=2,
                          kv_heads=2, q_heads=4, seq_len=64, head_dim=32,
                          layers=2)
    cost = rt.account_decode(plan, dim=32, tokens=10)
    assert cost.total_uj > 0
    assert rt.decode_steps == 10
    assert rt.decode_bytes_hbm == 10 * sum(s.bytes_hbm for s in plan.stages)
    hist = reg.snapshot()["histograms"]
    assert hist["energy_uj_per_token"]["count"] == 10
    # stage counters fanned out under the same names as retrieval stages
    counters = reg.snapshot()["counters"]
    assert counters["stage_bytes_hbm{stage=approx}"] > 0
    # non-decode plans are refused — retrieval stays on observe_cost
    rplan = engine.plan(RetrievalConfig(), num_docs=64, dim=32, batch=2,
                        kind="plain")
    with pytest.raises(ValueError):
        rt.account_decode(rplan, dim=32)


# -- end-to-end agent turn ---------------------------------------------------

def test_rag_agent_turn_reports_uj_per_token():
    from repro.models import embedder as emb_mod
    from repro.models.common import ModelConfig
    from repro.models.registry import get_model
    from repro.obs import MetricsRegistry
    from repro.serve import (MultiTenantRAGPipeline, RAGAgent,
                             RuntimeConfig, ServingRuntime)

    emb_cfg = ModelConfig(name="e", family="dense", num_layers=1,
                          d_model=32, num_heads=2, num_kv_heads=2,
                          d_ff=64, vocab_size=64, pooled_dim=32)
    emb_params = emb_mod.init_params(emb_cfg, jax.random.PRNGKey(7))
    gen_cfg = ModelConfig(name="g", family="dense", num_layers=2,
                          d_model=64, num_heads=4, num_kv_heads=2,
                          d_ff=96, vocab_size=64)
    api = get_model(gen_cfg)
    gen_params = api.init(jax.random.PRNGKey(1))
    pipe = MultiTenantRAGPipeline.create(emb_cfg, emb_params, api,
                                         gen_params, capacity=64, doc_len=4)
    rng = np.random.default_rng(0)
    for t in range(2):
        pipe.ingest(t, rng.integers(0, 64, size=(6, 4)))
    reg = MetricsRegistry()
    rt = ServingRuntime(pipe.index,
                        RuntimeConfig(max_batch=2, auto_flush=False),
                        registry=reg)
    agent = RAGAgent(pipeline=pipe, runtime=rt, top_k=16, npages=4,
                     prescreen_c0=24, page_rows=8)
    q = jnp.asarray(rng.integers(0, 64, size=(2, 4)))
    rep = agent.turn(np.array([0, 1]), q, max_new=6, now=0.0)
    assert rep.tokens.shape == (2, 6)
    assert rep.uj_per_query > 0 and rep.uj_per_token > 0
    assert rep.decode_plan.kind == "decode"
    assert rt.decode_steps == 6
    # both workloads landed in ONE registry
    hist = reg.snapshot()["histograms"]
    assert hist["energy_uj_per_query"]["count"] >= 2
    assert hist["energy_uj_per_token"]["count"] == 6
    # greedy decoding is deterministic across turns (cached jits)
    rep2 = agent.turn(np.array([0, 1]), q, max_new=6, now=1.0)
    np.testing.assert_array_equal(np.asarray(rep.tokens),
                                  np.asarray(rep2.tokens))
